"""Guarded serving: admission/shedding, deadlines, census-guarded decode
with quarantine+retry, the per-backend circuit breaker, and the planner's
quarantine re-route.

Most of the file drives ``ServingRuntime`` with a jax-free FakeEngine and
an injectable FakeClock -- every schedule (deadlines, cooldowns, retry
counts) is asserted deterministically, no wall-clock waits. The last
section runs the REAL ``GuardedEngine`` (tiny olmo) end to end under a
chaos schedule and checks the exported status JSON against the injection
schedule, plus greedy-token equivalence across the degradation chain."""

import json
import math

import numpy as np
import pytest

from repro.runtime import (
    AdmissionQueue,
    ChaosMonkey,
    CircuitBreaker,
    Completion,
    DeadlineExceeded,
    Preemption,
    Request,
    RequestRejected,
    ServingRuntime,
    TransientFault,
)

# ----------------------------- fakes ---------------------------------------


class FakeClock:
    def __init__(self):
        self.t = 0.0

    def __call__(self) -> float:
        return self.t

    def advance(self, dt: float) -> None:
        self.t += dt


class FakeEngine:
    """Protocol-conforming, jax-free, bitwise-deterministic engine.

    Slot i's token stream is ``(base[i] + t) % 997`` where ``base`` is the
    prompt sum -- multiplying by the chaos scale (NaN/Inf) makes the value
    non-finite, which the fake census reports per slot exactly like
    ``guarded_logit_stat`` (counts per slot, total appended).
    ``poison_slots`` marks slots whose census NEVER comes clean (the
    persistent-poison path); ``step_cost`` advances ``clock`` per step so
    deadline schedules are exact."""

    def __init__(self, slots=4, *, clock=None, step_cost=0.0,
                 poison_slots=()):
        self.slots = slots
        self.clock = clock
        self.step_cost = float(step_cost)
        self.poison_slots = set(poison_slots)
        self.step_calls = 0
        self.backends_used = []

    def validate(self, prompt, max_new):
        return None

    def _step(self, base, t, scales, backend):
        self.step_calls += 1
        self.backends_used.append(backend)
        if self.clock is not None and self.step_cost:
            self.clock.advance(self.step_cost)
        toks, census = [], []
        for i in range(self.slots):
            if base[i] is None:
                toks.append(0)
                census.append(0.0)
                continue
            v = float(base[i] + t) * float(scales[i])
            bad = (not math.isfinite(v)) or i in self.poison_slots
            census.append(1.0 if bad else 0.0)
            toks.append(-1 if bad else int(v) % 997)
        census.append(sum(census))
        return toks, census

    def start_wave(self, prompts, scales, backend):
        base = [
            int(np.sum(np.asarray(p))) if p is not None else None
            for p in prompts
        ]
        toks, census = self._step(base, 0, scales, backend)
        return {"base": base, "t": 0}, toks, census

    def decode(self, state, scales, backend):
        t = state["t"] + 1
        toks, census = self._step(state["base"], t, scales, backend)
        return {"base": state["base"], "t": t}, toks, census


def _reqs(n, max_new=4, deadline_s=None, plen=8):
    rng = np.random.default_rng(0)
    return [
        Request(rid=i, prompt=rng.integers(0, 100, size=(plen,)),
                max_new=max_new, deadline_s=deadline_s)
        for i in range(n)
    ]


# -------------------------- AdmissionQueue ---------------------------------


def test_queue_sheds_oldest_expired_first():
    q = AdmissionQueue(capacity=2)
    a = Request(0, None, 1, deadline_s=1.0)
    b = Request(1, None, 1, deadline_s=5.0)
    assert q.submit(a, now=0.0) == (True, [])
    assert q.submit(b, now=0.0) == (True, [])
    # a is past-deadline at t=2: the full queue sheds it to admit c
    c = Request(2, None, 1, deadline_s=9.0)
    admitted, shed = q.submit(c, now=2.0)
    assert admitted and [r.rid for r in shed] == [0]
    assert len(q) == 2


def test_queue_refuses_when_nobody_sheddable():
    q = AdmissionQueue(capacity=1)
    assert q.submit(Request(0, None, 1, deadline_s=None), 0.0) == (True, [])
    admitted, shed = q.submit(Request(1, None, 1), 0.0)
    assert not admitted and shed == []
    with pytest.raises(ValueError):
        AdmissionQueue(0)


def test_queue_pop_drops_expired():
    q = AdmissionQueue(capacity=8)
    q.submit(Request(0, None, 1, deadline_s=1.0), 0.0)
    q.submit(Request(1, None, 1, deadline_s=9.0), 0.0)
    wave, expired = q.pop(4, now=2.0)
    assert [r.rid for r in wave] == [1]
    assert [r.rid for r in expired] == [0]


# -------------------------- CircuitBreaker ---------------------------------


def test_breaker_trips_after_threshold_and_degrades():
    clk = FakeClock()
    trips, closes = [], []
    br = CircuitBreaker(chain=("a", "b", "c"), fail_threshold=2,
                        cooldown_s=1.0, clock=clk,
                        on_trip=trips.append, on_close=closes.append)
    assert br.backend() == "a"
    br.record_failure("a")
    assert br.state("a") == "closed"  # below threshold
    br.record_success("a")
    br.record_failure("a")
    assert br.state("a") == "closed"  # success reset the streak
    br.record_failure("a")
    br.record_failure("a")
    assert br.state("a") == "open" and trips == ["a"]
    assert br.backend() == "b" and br.total_trips == 1
    assert closes == []


def test_breaker_half_open_probe_cycle_with_bounded_backoff():
    clk = FakeClock()
    trips, closes = [], []
    br = CircuitBreaker(chain=("a", "b"), fail_threshold=1, cooldown_s=1.0,
                        cooldown_cap_s=3.0, probe_successes=2, clock=clk,
                        on_trip=trips.append, on_close=closes.append)
    br.record_failure("a")
    assert br.backend() == "b"
    clk.advance(1.0)
    assert br.backend() == "a" and br.state("a") == "half_open"
    # failed probe: re-open with cooldown DOUBLED
    br.record_failure("a")
    assert br.state("a") == "open" and trips == ["a", "a"]
    clk.advance(1.0)
    assert br.backend() == "b"  # 1.0 < doubled cooldown 2.0
    clk.advance(1.0)
    assert br.backend() == "a" and br.state("a") == "half_open"
    # another failed probe: 2.0 * 2 capped at 3.0
    br.record_failure("a")
    clk.advance(2.5)
    assert br.backend() == "b"
    clk.advance(0.5)
    assert br.backend() == "a"
    br.record_success("a")
    assert br.state("a") == "half_open" and closes == []
    br.record_success("a")
    assert br.state("a") == "closed" and closes == ["a"]
    assert br.backend() == "a"


def test_breaker_terminal_backend_always_served():
    clk = FakeClock()
    br = CircuitBreaker(chain=("a", "b"), fail_threshold=1, clock=clk)
    br.record_failure("a")
    br.record_failure("b")
    # the terminal backend trips like any other but is still served --
    # something must answer
    assert br.states() == {"a": "open", "b": "open"}
    assert br.backend() == "b"


# ------------------------- ChaosMonkey hooks -------------------------------


def test_chaos_from_seed_deterministic_and_disjoint():
    kw = dict(n_steps=64, nan_rate=0.1, inf_rate=0.05, fail_rate=0.1,
              preempt_rate=0.1)
    c1 = ChaosMonkey.from_seed(7, **kw)
    c2 = ChaosMonkey.from_seed(7, **kw)
    assert (c1.nan_steps, c1.inf_steps, c1.fail_steps, c1.preempt_steps) == \
        (c2.nan_steps, c2.inf_steps, c2.fail_steps, c2.preempt_steps)
    assert ChaosMonkey.from_seed(8, **kw).nan_steps != c1.nan_steps or \
        ChaosMonkey.from_seed(8, **kw).fail_steps != c1.fail_steps
    all_sets = [c1.nan_steps, c1.inf_steps, c1.fail_steps, c1.preempt_steps]
    assert sum(len(s) for s in all_sets) == len(frozenset().union(*all_sets))
    assert all(0 not in s for s in all_sets)  # anchor id stays clean
    assert any(all_sets)


def test_chaos_scale_for_fires_once():
    c = ChaosMonkey(nan_steps=[3], inf_steps=[4])
    assert math.isnan(c.scale_for(3))
    assert c.scale_for(3) == 1.0  # fire-once: the retry sees identity
    assert math.isinf(c.scale_for(4))
    assert c.scale_for(4) == 1.0
    assert c.scale_for(1) == 1.0


def test_chaos_on_request_preempt_vs_fault():
    c = ChaosMonkey(fail_steps=[2], preempt_steps=[5])
    with pytest.raises(Preemption):
        c.on_request(5)
    c.on_request(5)  # fired
    with pytest.raises(TransientFault):
        c.on_request(2)
    c.on_request(2)
    assert issubclass(Preemption, TransientFault)
    assert c.calls == 4


# --------------------- ServingRuntime + FakeEngine -------------------------


def test_clean_serve_returns_completions_in_request_order():
    eng = FakeEngine(slots=3)
    rt = ServingRuntime(eng, clock=FakeClock(), quarantine_planner=False)
    reqs = _reqs(7, max_new=4)
    out = rt.serve(reqs)
    assert [r.rid for r in out] == [r.rid for r in reqs]
    assert all(isinstance(r, Completion) and r.ok for r in out)
    assert all(len(r.tokens) == 4 for r in out)
    snap = rt.metrics.snapshot()
    assert snap["admitted"] == 7 and snap["completed"] == 7
    assert snap["tokens_out"] == 28 and snap["quarantined"] == 0


def test_serve_empty_is_empty():
    rt = ServingRuntime(FakeEngine(), clock=FakeClock(),
                        quarantine_planner=False)
    assert rt.serve([]) == []


def test_chaos_quarantine_retry_reproduces_clean_run_bitwise():
    reqs = _reqs(6, max_new=5)
    clean = ServingRuntime(FakeEngine(slots=3), clock=FakeClock(),
                           quarantine_planner=False).serve(reqs)

    clk = FakeClock()
    chaos = ChaosMonkey(nan_steps=[1], fail_steps=[3], preempt_steps=[4])
    br = CircuitBreaker(chain=("fakeA", "fakeB"), fail_threshold=1,
                        clock=clk)
    eng = FakeEngine(slots=3)
    rt = ServingRuntime(eng, chaos=chaos, breaker=br, clock=clk,
                        quarantine_planner=False)
    out = rt.serve(reqs)

    # the guarded retries reproduce the clean tokens BITWISE: the NaN'd
    # slot's state never committed, the faulted/preempted steps re-ran
    assert [r.tokens for r in out] == [r.tokens for r in clean]
    snap = rt.metrics.snapshot()
    assert snap["quarantined"] == 1  # rid 1's one poisoned attempt
    assert snap["retries"] == 3      # nan + fault + preemption
    assert snap["breaker_trips"] == 1
    assert snap["breaker_states"] == {"fakeA": "open", "fakeB": "closed"}
    assert chaos.fired == {("nan", 1), ("fail", 3), ("preempt", 4)}
    # the faulted wave finished on the degraded backend
    assert "fakeB" in eng.backends_used


def test_seeded_chaos_schedule_reproduces_clean_run_bitwise():
    """The from_seed flavor: a randomly drawn (but deterministic)
    per-request schedule, counters derived from the schedule itself."""
    n = 12
    reqs = _reqs(n, max_new=4)
    clean = ServingRuntime(FakeEngine(slots=4), clock=FakeClock(),
                           quarantine_planner=False).serve(reqs)

    chaos = ChaosMonkey.from_seed(12, n_steps=n, nan_rate=0.2,
                                  fail_rate=0.2, preempt_rate=0.15)
    # seed 12 draws all three kinds: nan {4,5}, fail {6,7}, preempt {1}
    assert chaos.nan_steps and chaos.fail_steps and chaos.preempt_steps
    clk = FakeClock()
    rt = ServingRuntime(
        FakeEngine(slots=4), chaos=chaos, clock=clk,
        breaker=CircuitBreaker(chain=("fakeA", "fakeB"), clock=clk),
        quarantine_planner=False)
    out = rt.serve(reqs)

    assert [r.tokens for r in out] == [r.tokens for r in clean]
    snap = rt.metrics.snapshot()
    assert snap["quarantined"] == len(chaos.nan_steps)
    # every configured injection fired exactly once
    assert chaos.fired == (
        {("nan", s) for s in chaos.nan_steps}
        | {("fail", s) for s in chaos.fail_steps}
        | {("preempt", s) for s in chaos.preempt_steps}
    )
    assert snap["retries"] >= len(chaos.fail_steps) + len(chaos.preempt_steps)


def test_persistently_poisoned_slot_fails_structured_batch_proceeds():
    clk = FakeClock()
    eng = FakeEngine(slots=3, poison_slots={1})
    rt = ServingRuntime(eng, clock=clk, max_step_retries=2,
                        quarantine_planner=False)
    out = rt.serve(_reqs(3, max_new=4))
    assert isinstance(out[1], RequestRejected) and not out[1].ok
    assert "poisoned" in out[1].reason and out[1].tokens == ()
    assert isinstance(out[0], Completion) and len(out[0].tokens) == 4
    assert isinstance(out[2], Completion) and len(out[2].tokens) == 4
    snap = rt.metrics.snapshot()
    # 3 attempts of the first step, each quarantining slot 1 once
    assert snap["quarantined"] == 3
    assert snap["rejected_poisoned"] == 1


def test_deadline_expiry_returns_partial_tokens_and_sheds_queue():
    clk = FakeClock()
    eng = FakeEngine(slots=1, clock=clk, step_cost=0.01)
    rt = ServingRuntime(eng, clock=clk, quarantine_planner=False)
    reqs = [
        Request(rid=i, prompt=np.arange(4), max_new=5, deadline_s=0.035)
        for i in range(2)
    ]
    out = rt.serve(reqs)
    # wave 1 decodes until the clock passes the deadline: partial tokens
    assert isinstance(out[0], DeadlineExceeded)
    assert len(out[0].tokens) == 4
    # wave 2 was still queued when its deadline passed: zero tokens
    assert isinstance(out[1], DeadlineExceeded) and out[1].tokens == ()
    assert rt.metrics.snapshot()["deadline_missed"] == 2


def test_infeasible_deadline_refused_with_estimate():
    clk = FakeClock()
    eng = FakeEngine(slots=2, clock=clk, step_cost=0.01)
    rt = ServingRuntime(eng, clock=clk, quarantine_planner=False)
    rt.serve(_reqs(2, max_new=4))  # primes the EWMA with real step times
    assert rt._step_ewma is not None
    late = Request(rid=99, prompt=np.arange(4), max_new=50,
                   deadline_s=clk() + 0.05)
    assert not rt.submit(late)
    res = rt._results[99]
    assert isinstance(res, RequestRejected) and "infeasible" in res.reason
    assert rt.metrics.snapshot()["shed_infeasible"] == 1


def test_queue_full_sheds_structured():
    rt = ServingRuntime(FakeEngine(slots=2), clock=FakeClock(),
                        queue_capacity=2, quarantine_planner=False)
    reqs = _reqs(4, max_new=2)
    admits = [rt.submit(r) for r in reqs]
    assert admits == [True, True, False, False]
    for rid in (2, 3):
        res = rt._results[rid]
        assert isinstance(res, RequestRejected) and "queue full" in res.reason
    rt.drain()
    out = [rt._results[r.rid] for r in reqs]
    assert [r.ok for r in out] == [True, True, False, False]
    snap = rt.metrics.snapshot()
    assert snap["shed_queue_full"] == 2 and snap["admitted"] == 2


def test_validate_rejects_before_admission():
    class PickyEngine(FakeEngine):
        def validate(self, prompt, max_new):
            return "prompt too long" if len(prompt) > 4 else None

    rt = ServingRuntime(PickyEngine(slots=2), clock=FakeClock(),
                        quarantine_planner=False)
    good = Request(0, np.arange(3), 2)
    bad = Request(1, np.arange(9), 2)
    out = rt.serve([good, bad])
    assert isinstance(out[0], Completion)
    assert isinstance(out[1], RequestRejected)
    assert out[1].reason == "prompt too long"


def test_status_json_counters_match_injection_schedule(tmp_path):
    path = tmp_path / "serve_status.json"
    clk = FakeClock()
    chaos = ChaosMonkey(nan_steps=[1], fail_steps=[3], preempt_steps=[4])
    br = CircuitBreaker(chain=("fakeA", "fakeB"), fail_threshold=1,
                        clock=clk)
    rt = ServingRuntime(FakeEngine(slots=3, clock=clk, step_cost=0.01),
                        chaos=chaos, breaker=br, clock=clk,
                        status_path=path, quarantine_planner=False)
    out = rt.serve(_reqs(6, max_new=3))
    assert all(r.ok for r in out)
    snap = json.loads(path.read_text())
    assert snap["admitted"] == 6 and snap["completed"] == 6
    assert snap["tokens_out"] == 18
    assert snap["quarantined"] == 1 and snap["retries"] == 3
    assert snap["breaker_trips"] == 1
    assert snap["breaker_states"]["fakeA"] == "open"
    assert snap["deadline_missed"] == 0
    assert snap["token_latency_samples"] > 0
    assert snap["token_latency_p99_s"] >= snap["token_latency_p50_s"] > 0


# ------------------- planner quarantine (breaker re-route) -----------------


@pytest.fixture
def clean_quarantine():
    from repro import reduce as R

    yield
    for name in R.quarantined_backends():
        R.reinstate_backend(name)


def test_plan_cache_serves_no_stale_quarantined_plans(clean_quarantine):
    """The breaker-trip regression: a memoized auto ReducePlan carrying a
    quarantined backend must be invalidated, not served."""
    import jax.numpy as jnp

    from repro import reduce as R

    R.plan_cache_clear()
    shape, dtype = (4096,), jnp.float32
    b0 = R.plan_for(shape, dtype).backend
    before = R.plan_cache_info()
    assert R.plan_for(shape, dtype).backend == b0
    assert R.plan_cache_info().hits == before.hits + 1  # memo is live

    R.quarantine_backend(b0)
    assert b0 in R.quarantined_backends()
    b1 = R.plan_for(shape, dtype).backend
    if b0 != "xla":
        assert b1 != b0  # the stale memo would have returned b0
    else:
        assert b1 == "xla"  # terminal: serves even quarantined
    # an explicit pin bypasses quarantine -- the half-open probe path
    assert R.plan_for(shape, dtype, backend=b0).backend == b0
    # the re-routed plan still computes correctly
    x = jnp.arange(float(shape[0]), dtype=dtype)
    assert float(R.reduce(x, kind="sum")) == pytest.approx(
        shape[0] * (shape[0] - 1) / 2, rel=1e-6)

    R.reinstate_backend(b0)
    assert R.plan_for(shape, dtype).backend == b0  # reinstated immediately


def test_quarantine_walks_whole_chain_to_terminal(clean_quarantine):
    import jax.numpy as jnp

    from repro import reduce as R

    for name in ("pallas_fused", "pallas_hier", "mma_jnp"):
        R.quarantine_backend(name)
    assert R.plan_for((4096,), jnp.float32).backend == "xla"
    x = jnp.ones((64,), jnp.float32)
    assert float(R.reduce(x, kind="sum")) == 64.0


def test_scan_plan_cache_serves_no_stale_quarantined_plans(clean_quarantine):
    """The scan twin of the breaker-trip regression: quarantining a backend
    must reroute AUTO ScanPlans and invalidate the memoized scan-plan
    cache -- a stale memo would keep dispatching prefix sums onto the
    quarantined backend for every already-seen shape."""
    import jax.numpy as jnp
    import numpy as np

    from repro import reduce as R

    R.plan_cache_clear()
    shape, dtype = (200_000,), jnp.float32
    b0 = R.scan_plan_for(shape, dtype).backend
    assert b0 != "xla"  # a large float operand auto-routes onto an MMA path
    before = R.scan_plan_cache_info()
    assert R.scan_plan_for(shape, dtype).backend == b0
    assert R.scan_plan_cache_info().hits == before.hits + 1  # memo is live

    R.quarantine_backend(b0)
    assert R.scan_plan_cache_info().currsize == 0  # memo invalidated
    b1 = R.scan_plan_for(shape, dtype).backend
    assert b1 != b0  # the stale memo would have returned b0
    # an explicit pin bypasses quarantine -- the half-open probe path
    assert R.scan_plan_for(shape, dtype, backend=b0).backend == b0
    # the re-routed scan still computes correctly
    x = jnp.ones((256,), dtype)
    np.testing.assert_array_equal(
        np.asarray(R.scan(x)), np.arange(1, 257, dtype=np.float32)
    )

    R.reinstate_backend(b0)
    assert R.scan_plan_for(shape, dtype).backend == b0  # back immediately


def test_scan_quarantine_walks_chain_to_terminal(clean_quarantine):
    import jax.numpy as jnp
    import numpy as np

    from repro import reduce as R

    for name in ("pallas_fused", "mma_jnp"):
        R.quarantine_backend(name)
    assert R.scan_plan_for((200_000,), jnp.float32).backend == "xla"
    x = jnp.ones((64,), jnp.float32)
    np.testing.assert_array_equal(
        np.asarray(R.scan(x)), np.arange(1, 65, dtype=np.float32)
    )


# --------------------- real-engine end to end ------------------------------


def _tiny_engine(cls, slots, prompt_len=8, max_new=4):
    from repro.configs import TINY_ARCHS

    cfg = TINY_ARCHS["olmo-1b"]
    return cls(cfg, prompt_len + max_new + 1, slots), cfg


def _tiny_prompts(cfg, n, prompt_len=8):
    rng = np.random.default_rng(3)
    return [
        rng.integers(0, cfg.vocab_size, size=(prompt_len,)).astype(np.int32)
        for _ in range(n)
    ]


def test_engine_serve_empty_and_cache_overflow_guard():
    from repro.launch.serve import Engine

    eng, cfg = _tiny_engine(Engine, slots=2)
    assert eng.serve([], max_new=4) == []
    with pytest.raises(ValueError, match="s_max"):
        eng.check_fits(prompt_len=10, max_new=4)  # 10 + 4 + 1 > 13
    with pytest.raises(ValueError, match="s_max"):
        eng.serve(_tiny_prompts(cfg, 1, prompt_len=12), max_new=4)


def test_engine_padded_wave_masks_dummy_not_duplicate():
    from repro.launch.serve import Engine

    eng, cfg = _tiny_engine(Engine, slots=2)
    prompts = _tiny_prompts(cfg, 3)
    batched = eng.serve(prompts, max_new=4)
    assert len(batched) == 3  # a 2-slot engine serves 3 via a padded wave
    # the padded wave's live slot must decode exactly as a full wave would
    solo = eng.serve(prompts[2:], max_new=4)
    assert batched[2] == solo[0]


def test_guarded_serving_end_to_end_chaos_status(tmp_path):
    """The acceptance test: real model, per-request chaos, quarantine +
    breaker degradation, and the status JSON matching the injection
    schedule -- with tokens bitwise-identical to the clean run."""
    from repro.launch.serve import GuardedEngine

    eng, cfg = _tiny_engine(GuardedEngine, slots=2)
    prompts = _tiny_prompts(cfg, 4)
    reqs = [Request(rid=i, prompt=p, max_new=4)
            for i, p in enumerate(prompts)]

    clean = ServingRuntime(eng, quarantine_planner=False).serve(reqs)
    assert all(isinstance(r, Completion) for r in clean)

    path = tmp_path / "status.json"
    chaos = ChaosMonkey(nan_steps=[1], fail_steps=[2])
    # default chain, no planner hooks; a frozen clock keeps the tripped
    # breaker OPEN through the run (real step times would otherwise let
    # the half-open probe close it again -- good behavior, bad fixture)
    br = CircuitBreaker(fail_threshold=1, clock=FakeClock())
    rt = ServingRuntime(eng, chaos=chaos, breaker=br, status_path=path,
                        quarantine_planner=False)
    out = rt.serve(reqs)

    # greedy tokens identical under chaos: the NaN'd slot was quarantined
    # and retried from committed state; the tripped breaker degraded the
    # census backend pallas_fused -> mma_jnp without touching the tokens
    assert [r.tokens for r in out] == [r.tokens for r in clean]
    snap = json.loads(path.read_text())
    assert snap["admitted"] == 4 and snap["completed"] == 4
    assert snap["quarantined"] == 1
    assert snap["retries"] == 2  # one census retry + one fault retry
    assert snap["breaker_trips"] == 1
    assert snap["breaker_states"]["pallas_fused"] == "open"
    assert chaos.fired == {("nan", 1), ("fail", 2)}


def test_guarded_tokens_equivalent_across_backend_chain():
    """Pin the census statistic to each backend in the degradation chain
    explicitly: greedy tokens must be identical -- the guard observes the
    logits, it never alters them."""
    from repro.launch.serve import GuardedEngine
    from repro.runtime.serving import DEFAULT_BACKEND_CHAIN

    eng, cfg = _tiny_engine(GuardedEngine, slots=2)
    prompts = _tiny_prompts(cfg, 2)
    scales = np.ones((2,), np.float32)
    per_backend = []
    for backend in DEFAULT_BACKEND_CHAIN:
        state, toks, census = eng.start_wave(list(prompts), scales, backend)
        seq = [list(toks)]
        for _ in range(3):
            state, toks, census = eng.decode(state, scales, backend)
            seq.append(list(toks))
            assert float(census[-1]) == 0.0
        per_backend.append(seq)
    assert per_backend[0] == per_backend[1] == per_backend[2]
