"""The scan differential sweep: every prefix-sum path x every direction.

Mirrors tests/test_differential.py's four-layer structure for the scan op
class (kernels/scan.py; Dakkak triangular-MMA encoding):

  1. ENGINE CELLS  -- (backend x dtype x cores x inclusive x reverse)
     through the public ``repro.scan`` API vs the f64 numpy cumsum oracle,
     within the PER-ELEMENT running-mass budget (every prefix partial is a
     consumer-visible output, so the budget is elementwise).
  2. KERNEL BODY   -- ``mma_scan_pallas`` vs the op-for-op ``ref.scan_ref``
     emulation BIT-FOR-BIT at every core count and inclusivity (the carry
     chain reads tile totals off the (D + T1) corner on both sides, so
     there is no excess-precision exception here), and the acceptance
     invariant: the OUTPUT ARRAY is bitwise identical across
     num_cores in {1, 2, 4}.
  3. TRAFFIC       -- ``cost_model.scan_hbm_bytes().launch_io`` == the
     lowered ``pallas_call`` boundary bytes; the traced MMA splits ==
     ``cost_model.scan_mma_ops``; bf16 ingest lowers staging-free; the
     staged-XLA comparison model shows the ~5x byte ratio.
  4. PROPERTIES    -- hypothesis sweeps: ragged n x dtype x cores x
     direction vs the oracle, num_cores=1 bit-identity against scan_ref,
     and the cumsum VJP against xla autodiff.

Runs as its own CI job (interpret mode) alongside test_differential.py.
"""

import harness
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _optional_hypothesis import hypothesis, st

import repro
from repro import reduce as R
from repro.core import cost_model
from repro.kernels import common
from repro.kernels.mma_reduce import ref
from repro.kernels.scan import mma_scan_jnp, mma_scan_pallas
from repro.reduce import inspect as rinspect

M = common.MXU
GROUP = M * M

# one ragged size that straddles a tile boundary AND leaves a masked tail
N_CELL = GROUP + 4097


def _cell_ids():
    for backend in harness.SCAN_BACKENDS:
        cores = (1, 2) if backend == "pallas_fused" else (1,)
        for dt in harness.DTYPES:
            for c in cores:
                for inclusive in (True, False):
                    for reverse in (True, False):
                        yield backend, dt, c, inclusive, reverse


@pytest.mark.parametrize(
    "backend,dt,num_cores,inclusive,reverse",
    list(_cell_ids()),
    ids=lambda v: str(v),
)
def test_scan_cell_vs_oracle(backend, dt, num_cores, inclusive, reverse):
    """Layer 1: the full (backend x dtype x cores x direction) product."""
    harness.run_scan_cell(
        backend, dt, N_CELL, num_cores, inclusive=inclusive, reverse=reverse
    )


@pytest.mark.parametrize("n", [1, 100, GROUP - 1, GROUP + 1, 50_001])
@pytest.mark.parametrize("inclusive", [True, False])
def test_scan_ragged_cells_pallas(n, inclusive):
    """Layer 1b: ragged boundary sizes through the kernel backend."""
    harness.run_scan_cell(
        "pallas_fused", "float32", n, num_cores=2, inclusive=inclusive, seed=n
    )


# ---------------------- layer 2: kernel body vs emulation --------------------


@pytest.mark.parametrize("dt", ["float32", "bfloat16", "float16"])
@pytest.mark.parametrize("num_cores", [1, 2, 4])
@pytest.mark.parametrize("inclusive", [True, False])
def test_scan_body_bitwise_vs_scan_ref(dt, num_cores, inclusive, rng):
    """The kernel matches the op-for-op emulation bit-for-bit at EVERY core
    count -- the (D + T1)-corner totals rule means the carry phase and the
    owned phase are the same f32 ops in the same order on both sides, so
    unlike the square prologue there is no low-precision exception."""
    x = jnp.asarray(rng.randn(30_000)).astype(dt)
    got = mma_scan_pallas(x, inclusive=inclusive, num_cores=num_cores)
    want = ref.scan_ref(x, inclusive=inclusive, num_cores=num_cores)
    harness.assert_bits_equal(
        got.astype(jnp.float32), want.astype(jnp.float32),
        f"{dt} c={num_cores} incl={inclusive}",
    )


@pytest.mark.parametrize("dt", ["float32", "bfloat16"])
def test_scan_output_bitwise_across_cores(dt, rng):
    """Acceptance: the WHOLE prefix array is bitwise identical at
    num_cores in {1, 2, 4} -- the contiguous-lane carry rebuild replays the
    identical left-to-right f32 fold, so lane count is a pure throughput
    knob, never a numerics knob."""
    for n in (1, GROUP + 1, 40_000):
        x = jnp.asarray(rng.randn(n)).astype(dt)
        outs = [
            np.asarray(mma_scan_pallas(x, num_cores=c).astype(jnp.float32))
            for c in (1, 2, 4)
        ]
        harness.assert_bits_equal(outs[0], outs[1], f"{dt} n={n} c=1 vs 2")
        harness.assert_bits_equal(outs[0], outs[2], f"{dt} n={n} c=1 vs 4")


def test_scan_exclusive_is_exact_shift(rng):
    """The exclusive prefix is the SHIFTED inclusive prefix (strict-U
    encoding), never the re-rounded ``cumsum - x``: out[0] == 0 exactly and
    out[i] == inclusive[i-1] bit-for-bit, on the kernel and both jnp
    routes."""
    x = jnp.asarray(rng.randn(5_000).astype(np.float32))
    for fn in (
        lambda v: mma_scan_pallas(v, inclusive=False),
        lambda v: mma_scan_jnp(v, inclusive=False),
        lambda v: repro.scan(v, inclusive=False, backend="xla"),
    ):
        exc = np.asarray(fn(x))
        assert exc[0] == 0.0
    inc = np.asarray(mma_scan_pallas(x, inclusive=True))
    exc = np.asarray(mma_scan_pallas(x, inclusive=False))
    harness.assert_bits_equal(exc[1:], inc[:-1])


def test_scan_semantics_axis_reverse_int():
    """reverse= is flip-scan-flip (suffix sums), axis= moves the scanned
    dimension, and integer operands accumulate EXACTLY in their own dtype
    on the auto route (f32 would round past 2**24)."""
    x = jnp.arange(12, dtype=jnp.float32).reshape(3, 4)
    np.testing.assert_array_equal(
        np.asarray(repro.scan(x, axis=0, backend="xla")),
        np.cumsum(np.asarray(x), 0),
    )
    np.testing.assert_array_equal(
        np.asarray(repro.scan(x, reverse=True, backend="xla")),
        np.cumsum(np.asarray(x)[:, ::-1], -1)[:, ::-1],
    )
    big = jnp.full((3,), 2**24, jnp.int32)
    got = repro.scan(big)  # auto: non-float -> the exact integer path
    np.testing.assert_array_equal(
        np.asarray(got), [2**24, 2**25, 2**24 * 3]
    )
    assert got.dtype == jnp.int32


def test_scan_plan_auto_routes():
    """Planner contract: integers -> xla; tiny n -> a jnp-level route;
    batched operands -> the einsum route; compute dtype defaults to the
    operand's NATIVE ingest width (consumer-visible partials)."""
    assert R.scan_plan_for((1000,), jnp.int32).backend == "xla"
    assert R.scan_plan_for((8,), jnp.float32).backend in ("xla", "mma_jnp")
    assert R.scan_plan_for((64, 4096), jnp.float32).backend == "mma_jnp"
    assert R.scan_plan_for((200_000,), jnp.bfloat16).compute_dtype \
        == "bfloat16"
    assert R.scan_plan_for((200_000,), jnp.float32).compute_dtype \
        == "float32"
    assert R.scan_plan_for((200_000,), jnp.int32).compute_dtype == "float32"


# ---------------------- layer 3: traffic and trace proofs --------------------


def _io(fn, *args):
    return rinspect.pallas_io_bytes(jax.make_jaxpr(fn)(*args))


@pytest.mark.parametrize("dt,bs", [(jnp.bfloat16, 2), (jnp.float32, 4)])
@pytest.mark.parametrize("num_cores", [1, 2, 4])
def test_scan_hbm_model_matches_lowered_io(dt, bs, num_cores):
    """cost_model.scan_hbm_bytes().launch_io == pallas_io_bytes: the scan
    writes the FULL block-padded prefix array, and the carry-rebuild
    refetch is charged outside the launch boundary (it re-streams blocks
    through the same BlockSpec, invisible to aval accounting)."""
    n = 300_000
    x = jnp.zeros((n,), dt)
    plan = R.scan_plan_for((n,), dt, backend="pallas_fused",
                           num_cores=num_cores)
    model = cost_model.scan_hbm_bytes(
        n, bs, m=plan.m, num_cores=num_cores,
        tiles_per_block=plan.tiles_per_block,
    )
    got = _io(lambda v, p=plan: repro.scan(v, plan=p), x)
    assert got == model.launch_io, (str(dt), num_cores)
    assert plan.hbm_bytes(n, dt).total == model.total


@pytest.mark.parametrize("num_cores", [1, 2, 4])
def test_scan_trace_matches_cost_model(num_cores):
    """ScanTrace's MMA splits == cost_model.scan_mma_ops: 3 MMAs per owned
    tile, 2 per carry-rebuilt tile, and the serial count 3*tiles at c=1."""
    n = 300_000
    x = jnp.zeros((n,), jnp.float32)
    tr = []
    mma_scan_pallas(x, num_cores=num_cores, trace=tr)
    ops_model = cost_model.scan_mma_ops(n, num_cores=num_cores)
    assert tr[0].mma_ops == ops_model.total
    assert tr[0].lane_mma_ops == ops_model.lane_scan
    assert tr[0].carry_mma_ops == ops_model.carry_worst
    assert tr[0].hbm_bytes == cost_model.scan_hbm_bytes(n, 4,
                                                        num_cores=num_cores).total
    if num_cores == 1:
        assert ops_model.total == 3 * ops_model.tiles
        assert ops_model.carry_worst == 0
    else:
        assert ops_model.critical_path < 3 * ops_model.tiles


def test_scan_bf16_single_stream_vs_staged_model():
    """The motivating arithmetic: XLA's sub-f32 cumsum pays the upcast
    round-trip (read 2 + write 4 + read 4 + write 4 + read 4 + write 2
    bytes/elem); the native-ingest kernel streams 2 in + 2 out."""
    n = 1 << 20
    zc = cost_model.scan_hbm_bytes(n, 2).total
    staged = cost_model.staged_scan_hbm_bytes(n, 2).total
    assert staged / zc > 4.5
    # the win is the width asymmetry: at f32 storage the staged penalty is
    # a flat copy overhead, strictly smaller than the bf16 ratio
    f32_ratio = cost_model.staged_scan_hbm_bytes(n, 4).total \
        / cost_model.scan_hbm_bytes(n, 4).total
    assert f32_ratio < staged / zc


def test_scan_bf16_ingest_staging_free_single_launch():
    """Acceptance: a bf16 scan lowers with NO n-sized convert/pad/concat
    outside the pallas_call, and is exactly ONE launch per call."""
    x = jnp.zeros((300_000,), jnp.bfloat16)
    fn = lambda v: repro.scan(v, backend="pallas_fused")
    rinspect.assert_staging_free(fn, x)
    assert rinspect.count_pallas_calls(fn, x) == 1
    # direction/axis relayouts (rev / transpose) must not break the contract
    fn_rev = lambda v: repro.scan(v, reverse=True, backend="pallas_fused")
    rinspect.assert_staging_free(fn_rev, x)
    assert rinspect.count_pallas_calls(fn_rev, x) == 1


# ---------------------- layer 4: property sweeps -----------------------------


@hypothesis.settings(max_examples=40, deadline=None)
@hypothesis.given(
    n=st.integers(1, 100_000),
    seed=st.integers(0, 2**31 - 1),
    num_cores=st.sampled_from([1, 2, 4]),
    dt=st.sampled_from(["bfloat16", "float16", "float32"]),
    inclusive=st.booleans(),
    reverse=st.booleans(),
)
def test_property_scan_cells_vs_oracle(n, seed, num_cores, dt, inclusive,
                                       reverse):
    """(a) ragged n x dtype x cores x direction vs the f64 oracle: the
    masked tail beyond n never leaks into any prefix."""
    harness.run_scan_cell("pallas_fused", dt, n, num_cores,
                          inclusive=inclusive, reverse=reverse, seed=seed)


@hypothesis.settings(max_examples=25, deadline=None)
@hypothesis.given(
    n=st.integers(1, 60_000),
    seed=st.integers(0, 2**31 - 1),
    inclusive=st.booleans(),
)
def test_property_single_core_bitwise_vs_scan_ref(n, seed, inclusive):
    """(b) num_cores=1 is bit-identical to the op-for-op emulation -- the
    PR's backward-compatibility pin for the serial triangular scheme."""
    x = jnp.asarray(np.random.RandomState(seed).randn(n).astype(np.float32))
    got = mma_scan_pallas(x, inclusive=inclusive, num_cores=1)
    want = ref.scan_ref(x, inclusive=inclusive, num_cores=1)
    harness.assert_bits_equal(got, want, f"n={n} incl={inclusive}")


@hypothesis.settings(max_examples=15, deadline=None)
@hypothesis.given(
    n=st.integers(2, 5_000),
    seed=st.integers(0, 2**31 - 1),
    inclusive=st.booleans(),
)
def test_property_scan_grad_matches_xla_autodiff(n, seed, inclusive):
    """(c) the cumsum VJP (reversed same-kind cumsum of the cotangent)
    through the kernel == plain autodiff through the xla backend, within
    f32 re-association tolerance (the two backends fold in different
    orders, so this is a budgeted check, not a bitwise one)."""
    x = jnp.asarray(np.random.RandomState(seed).randn(n).astype(np.float32))
    w = jnp.asarray(np.random.RandomState(seed + 1).randn(n)
                    .astype(np.float32))
    loss = lambda be: jax.grad(
        lambda y: jnp.sum(
            repro.scan(y, inclusive=inclusive, backend=be) * w
        )
    )(x)
    g_kernel = np.asarray(loss("pallas_fused"), np.float64)
    g_xla = np.asarray(loss("xla"), np.float64)
    tol = harness.scan_budget(w, "float32", reverse=True)
    assert (np.abs(g_kernel - g_xla) <= tol).all(), n
