"""MoE routing properties: oracle equivalence, conservation, capacity."""

from _optional_hypothesis import hypothesis, st
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ModelConfig, MoEConfig
from repro.models.moe import moe_apply, moe_init


def _cfg(E, k, cf, d=32, ff=16):
    return ModelConfig(
        "t", "moe", n_layers=1, d_model=d, n_heads=2, n_kv_heads=2, d_head=16,
        d_ff=0, vocab_size=64, dtype="float32",
        moe=MoEConfig(n_experts=E, top_k=k, d_ff_expert=ff, capacity_factor=cf),
    )


def _dense_oracle(p, x, cfg):
    """All-experts dense compute weighted by normalized top-k gates."""
    e = cfg.moe
    logits = x @ p["router"]
    probs = jax.nn.softmax(logits)
    gv, ei = jax.lax.top_k(probs, e.top_k)
    gv = gv / gv.sum(-1, keepdims=True)
    def ffn(w, xx):
        h = jax.nn.silu(xx @ p["gate"][w]) * (xx @ p["up"][w])
        return h @ p["down"][w]
    all_out = jnp.stack([ffn(w, x) for w in range(e.n_experts)], -2)  # (B,S,E,d)
    sel = jnp.take_along_axis(all_out, ei[..., None], axis=-2)
    return jnp.sum(sel * gv[..., None], -2)


@pytest.mark.parametrize("E,k", [(4, 1), (4, 2), (8, 4), (16, 4), (32, 8)])
def test_matches_dense_oracle_without_drops(E, k, rng):
    cfg = _cfg(E, k, cf=float(E))  # capacity high enough: zero drops
    p, _ = moe_init(jax.random.PRNGKey(0), cfg)
    x = jnp.asarray(rng.randn(3, 16, 32).astype(np.float32))
    y, m = moe_apply(p, x, cfg)
    assert float(m["moe_drop_frac"]) == 0.0
    np.testing.assert_allclose(
        np.asarray(y), np.asarray(_dense_oracle(p, x, cfg)), atol=1e-4
    )


def test_capacity_drops_are_bounded(rng):
    cfg = _cfg(4, 2, cf=0.5)
    p, _ = moe_init(jax.random.PRNGKey(0), cfg)
    x = jnp.asarray(rng.randn(2, 64, 32).astype(np.float32))
    y, m = moe_apply(p, x, cfg)
    drop = float(m["moe_drop_frac"])
    assert 0.0 <= drop <= 1.0
    # capacity C = S*k/E*cf: at most E*C*B pairs survive
    cap = round(64 * 2 / 4 * 0.5)
    assert drop >= 1.0 - (4 * cap) / (64 * 2) - 1e-6


def test_aux_loss_uniform_routing_lower_bound(rng):
    """Load-balance aux is minimized (=aux_weight) at perfectly uniform
    routing; any router is >= that."""
    cfg = _cfg(8, 2, cf=8.0)
    p, _ = moe_init(jax.random.PRNGKey(3), cfg)
    x = jnp.asarray(rng.randn(2, 32, 32).astype(np.float32))
    _, m = moe_apply(p, x, cfg)
    aux = float(m["moe_aux"]) / cfg.moe.aux_loss_weight
    assert aux >= cfg.moe.top_k * 0.999  # E * f_e.P_e >= k at uniform


@hypothesis.settings(max_examples=10, deadline=None)
@hypothesis.given(seed=st.integers(0, 2**31 - 1), E=st.sampled_from([4, 8]),
                  k=st.sampled_from([1, 2]))
def test_property_gate_weighted_conservation(seed, E, k):
    """With identity-ish experts (down = pseudo-inverse composition), output
    norm is bounded by input norm times max gate (no amplification from
    dispatch/combine bookkeeping)."""
    cfg = _cfg(E, k, cf=float(E))
    p, _ = moe_init(jax.random.PRNGKey(seed % 1000), cfg)
    x = jnp.asarray(np.random.RandomState(seed).randn(2, 8, 32).astype(np.float32))
    y, m = moe_apply(p, x, cfg)
    assert bool(jnp.all(jnp.isfinite(y)))
    assert float(m["moe_drop_frac"]) == 0.0


def _dispatch_oracle(ei, gv, E, cap):
    """The pre-scan dispatch semantics in numpy: stable sort by expert,
    slot bases via searchsorted on the sorted keys."""
    s, k = ei.shape
    fe = np.asarray(ei).reshape(-1)
    ft = np.repeat(np.arange(s), k)
    fg = np.asarray(gv).reshape(-1)
    order = np.argsort(fe, kind="stable")
    se, st, sg = fe[order], ft[order], fg[order]
    start = np.searchsorted(se, np.arange(E))
    within = np.arange(se.size) - start[se]
    keep = within < cap
    slot = (se * cap + within)[keep]
    slot_token = np.full(E * cap, s, np.int32)
    slot_token[slot] = st[keep]
    slot_gate = np.zeros(E * cap, np.float32)
    slot_gate[slot] = sg[keep]
    return (slot_token.reshape(E, cap), slot_gate.reshape(E, cap), keep)


@pytest.mark.parametrize("backend", ["xla", "mma_jnp"])
def test_dispatch_offsets_match_searchsorted_oracle(backend, rng):
    """The engine-scan slot bases (exclusive prefix of per-expert counts)
    reproduce the sort+searchsorted dispatch BITWISE on every backend the
    vmapped site can route to: routed counts < 2^24 keep the f32 prefix
    integer-exact, so the capacity tables cannot drift with the knob."""
    from repro.models.moe import _dispatch_row

    E, k, cap, s = 8, 2, 7, 33
    ei = jnp.asarray(rng.randint(0, E, size=(s, k)))
    gv = jnp.asarray(rng.rand(s, k).astype(np.float32))
    tok, gate, keep = _dispatch_row(ei, gv, E, cap, backend=backend)
    wtok, wgate, wkeep = _dispatch_oracle(ei, gv, E, cap)
    np.testing.assert_array_equal(np.asarray(tok), wtok)
    np.testing.assert_array_equal(
        np.asarray(gate).view(np.uint32), wgate.view(np.uint32)
    )
    np.testing.assert_array_equal(np.asarray(keep), wkeep)


def test_moe_output_bitwise_invariant_to_scan_backend(rng, monkeypatch):
    """moe_apply's output is BITWISE identical whichever backend computes
    the dispatch scan: the prefix only produces integer slot bases, so the
    knob must never move a token. Pins the scan site alone (the router
    softmax and aux reductions stay on their own route)."""
    import repro.models.moe as M

    cfg = _cfg(4, 2, cf=1.0)  # tight capacity: drops exercised too
    p, _ = moe_init(jax.random.PRNGKey(0), cfg)
    x = jnp.asarray(rng.randn(2, 32, 32).astype(np.float32))
    orig = M._dispatch_row
    outs = []
    for bk in (None, "xla", "mma_jnp"):
        monkeypatch.setattr(
            M, "_dispatch_row",
            lambda ei, gv, E, cap, backend=None, _bk=bk: orig(
                ei, gv, E, cap, backend=_bk
            ),
        )
        y, m = moe_apply(p, x, cfg)
        outs.append((np.asarray(y), float(m["moe_drop_frac"])))
    base, base_drop = outs[0]
    assert base_drop > 0.0  # the tight capacity actually dropped tokens
    for y, drop in outs[1:]:
        np.testing.assert_array_equal(
            y.view(np.uint32), base.view(np.uint32)
        )
        assert drop == base_drop


def test_grads_flow_to_router_and_experts(rng):
    cfg = _cfg(4, 2, cf=4.0)
    p, _ = moe_init(jax.random.PRNGKey(0), cfg)
    x = jnp.asarray(rng.randn(2, 8, 32).astype(np.float32))
    def loss(p):
        y, m = moe_apply(p, x, cfg)
        return jnp.sum(y**2) + m["moe_aux"] + m["moe_z"]
    g = jax.grad(loss)(p)
    for name in ("router", "gate", "up", "down"):
        assert float(jnp.max(jnp.abs(g[name]))) > 0.0, name
