"""Per-arch smoke tests (deliverable f): every assigned architecture, in its
reduced same-family config, runs one forward AND one train step on CPU with
correct output shapes and finite values."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import optim
from repro.configs import ARCHS, TINY_ARCHS, TrainConfig
from repro.launch.steps import make_train_step
from repro.models import forward, init_params
from repro.models.frontends import synth_codebook_tokens, synth_image_embeds

B, S = 2, 24


def _batch(cfg, key):
    if cfg.n_codebooks:
        toks = synth_codebook_tokens(key, B, S, cfg.n_codebooks, cfg.vocab_size)
    else:
        toks = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    feed = {"tokens": toks}
    ctx = None
    if cfg.n_img_tokens:
        ctx = synth_image_embeds(key, B, cfg.n_img_tokens, cfg.d_model,
                                 jnp.dtype(cfg.dtype))
        feed["image_embeds"] = ctx
    return feed, ctx


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_forward_shapes_and_finite(arch):
    cfg = TINY_ARCHS[arch]
    params, axes = init_params(jax.random.PRNGKey(0), cfg)
    feed, ctx = _batch(cfg, jax.random.PRNGKey(1))
    logits, aux = forward(params, cfg, feed["tokens"], ctx)
    if cfg.n_codebooks:
        assert logits.shape == (B, S, cfg.n_codebooks, cfg.vocab_size)
    else:
        assert logits.shape == (B, S, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits)))
    assert bool(jnp.isfinite(aux))
    # axes tree mirrors params tree
    assert jax.tree.structure(axes, is_leaf=lambda a: a is None or isinstance(a, tuple)).num_leaves >= 1


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_train_step_descends(arch):
    cfg = TINY_ARCHS[arch]
    tcfg = TrainConfig(learning_rate=1e-3, total_steps=10, warmup_steps=1,
                       microbatches=2)
    params, _ = init_params(jax.random.PRNGKey(0), cfg)
    opt_state = optim.init_state(params)
    step = jax.jit(make_train_step(cfg, tcfg))
    feed, _ = _batch(cfg, jax.random.PRNGKey(2))
    losses = []
    for i in range(3):
        params, opt_state, metrics = step(params, opt_state, feed)
        losses.append(float(metrics["loss"]))
        assert np.isfinite(losses[-1])
        assert np.isfinite(float(metrics["grad_norm"]))
    # same batch thrice -> loss must drop
    assert losses[-1] < losses[0]


def test_ssm_forward_pinned_across_scan_backends():
    """The SSD block's cumulative-decay prefixes route through the engine
    scan; the backend knob must never move the forward beyond f32
    re-association noise, and the auto route must stay BITWISE the exact
    jnp.cumsum semantics at the chunked extents."""
    import dataclasses

    from repro.models.ssm import ssd_chunked

    r = np.random.RandomState(0)
    b, l, h, p, g, n, chunk = 2, 48, 4, 8, 1, 16, 16
    x = jnp.asarray(r.randn(b, l, h, p).astype(np.float32))
    dt = jnp.asarray(r.rand(b, l, h).astype(np.float32))
    A = -jnp.asarray(r.rand(h).astype(np.float32))
    Bm = jnp.asarray(r.randn(b, l, g, n).astype(np.float32))
    Cm = jnp.asarray(r.randn(b, l, g, n).astype(np.float32))
    y_xla, s_xla = ssd_chunked(x, dt, A, Bm, Cm, chunk, backend="xla")
    y_auto, s_auto = ssd_chunked(x, dt, A, Bm, Cm, chunk, backend=None)
    y_mma, s_mma = ssd_chunked(x, dt, A, Bm, Cm, chunk, backend="mma_jnp")
    # auto picks the exact-cumsum route for chunk-sized batched scans
    np.testing.assert_array_equal(
        np.asarray(y_auto).view(np.uint32), np.asarray(y_xla).view(np.uint32)
    )
    np.testing.assert_array_equal(np.asarray(s_auto), np.asarray(s_xla))
    # the triangular-einsum route re-associates f32 adds -- noise only
    np.testing.assert_allclose(
        np.asarray(y_mma), np.asarray(y_xla), rtol=1e-4, atol=1e-3
    )
    np.testing.assert_allclose(
        np.asarray(s_mma), np.asarray(s_xla), rtol=1e-4, atol=1e-3
    )

    # arch-level: the paper's-technique knob on the full tiny mamba2
    # forward stays within reduction-noise tolerance of the baseline
    cfg_on = TINY_ARCHS["mamba2-780m"]
    cfg_off = dataclasses.replace(cfg_on, mma_reductions=False)
    params, _ = init_params(jax.random.PRNGKey(0), cfg_on)
    feed, _ = _batch(cfg_on, jax.random.PRNGKey(1))
    y_on, _ = forward(params, cfg_on, feed["tokens"], None)
    y_off, _ = forward(params, cfg_off, feed["tokens"], None)
    assert bool(jnp.all(jnp.isfinite(y_on)))
    assert bool(jnp.all(jnp.isfinite(y_off)))
    np.testing.assert_allclose(
        np.asarray(y_on), np.asarray(y_off), rtol=1e-3, atol=5e-3
    )


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_full_config_is_published_dims(arch):
    """Full configs carry the exact assigned dims (guards vs accidental edits)."""
    cfg = ARCHS[arch]
    expected = {
        "mamba2-780m": (48, 1536, 50280),
        "musicgen-medium": (48, 1536, 2048),
        "dbrx-132b": (40, 6144, 100352),
        "granite-moe-1b-a400m": (24, 1024, 49155),
        "olmo-1b": (16, 2048, 50304),
        "deepseek-7b": (30, 4096, 102400),
        "minicpm3-4b": (62, 2560, 73448),
        "internlm2-1.8b": (24, 2048, 92544),
        "recurrentgemma-9b": (38, 4096, 256000),
        "llama-3.2-vision-11b": (40, 4096, 128256),
    }[arch]
    assert (cfg.n_layers, cfg.d_model, cfg.vocab_size) == expected
