"""Per-arch smoke tests (deliverable f): every assigned architecture, in its
reduced same-family config, runs one forward AND one train step on CPU with
correct output shapes and finite values."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import optim
from repro.configs import ARCHS, TINY_ARCHS, TrainConfig
from repro.launch.steps import make_train_step
from repro.models import forward, init_params
from repro.models.frontends import synth_codebook_tokens, synth_image_embeds

B, S = 2, 24


def _batch(cfg, key):
    if cfg.n_codebooks:
        toks = synth_codebook_tokens(key, B, S, cfg.n_codebooks, cfg.vocab_size)
    else:
        toks = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    feed = {"tokens": toks}
    ctx = None
    if cfg.n_img_tokens:
        ctx = synth_image_embeds(key, B, cfg.n_img_tokens, cfg.d_model,
                                 jnp.dtype(cfg.dtype))
        feed["image_embeds"] = ctx
    return feed, ctx


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_forward_shapes_and_finite(arch):
    cfg = TINY_ARCHS[arch]
    params, axes = init_params(jax.random.PRNGKey(0), cfg)
    feed, ctx = _batch(cfg, jax.random.PRNGKey(1))
    logits, aux = forward(params, cfg, feed["tokens"], ctx)
    if cfg.n_codebooks:
        assert logits.shape == (B, S, cfg.n_codebooks, cfg.vocab_size)
    else:
        assert logits.shape == (B, S, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits)))
    assert bool(jnp.isfinite(aux))
    # axes tree mirrors params tree
    assert jax.tree.structure(axes, is_leaf=lambda a: a is None or isinstance(a, tuple)).num_leaves >= 1


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_train_step_descends(arch):
    cfg = TINY_ARCHS[arch]
    tcfg = TrainConfig(learning_rate=1e-3, total_steps=10, warmup_steps=1,
                       microbatches=2)
    params, _ = init_params(jax.random.PRNGKey(0), cfg)
    opt_state = optim.init_state(params)
    step = jax.jit(make_train_step(cfg, tcfg))
    feed, _ = _batch(cfg, jax.random.PRNGKey(2))
    losses = []
    for i in range(3):
        params, opt_state, metrics = step(params, opt_state, feed)
        losses.append(float(metrics["loss"]))
        assert np.isfinite(losses[-1])
        assert np.isfinite(float(metrics["grad_norm"]))
    # same batch thrice -> loss must drop
    assert losses[-1] < losses[0]


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_full_config_is_published_dims(arch):
    """Full configs carry the exact assigned dims (guards vs accidental edits)."""
    cfg = ARCHS[arch]
    expected = {
        "mamba2-780m": (48, 1536, 50280),
        "musicgen-medium": (48, 1536, 2048),
        "dbrx-132b": (40, 6144, 100352),
        "granite-moe-1b-a400m": (24, 1024, 49155),
        "olmo-1b": (16, 2048, 50304),
        "deepseek-7b": (30, 4096, 102400),
        "minicpm3-4b": (62, 2560, 73448),
        "internlm2-1.8b": (24, 2048, 92544),
        "recurrentgemma-9b": (38, 4096, 256000),
        "llama-3.2-vision-11b": (40, 4096, 128256),
    }[arch]
    assert (cfg.n_layers, cfg.d_model, cfg.vocab_size) == expected
