"""Optional-``hypothesis`` shim for the test suite.

``hypothesis`` is an optional dev dependency (``pip install -e .[dev]``).
When it is absent the property tests must *skip cleanly* rather than break
collection of the whole module, so test files import the library through
this shim:

    from _optional_hypothesis import hypothesis, st

With hypothesis installed the real modules pass through untouched. Without
it, ``@hypothesis.given(...)`` degrades to ``pytest.mark.skip`` and the
strategy constructors become inert placeholders (they are only ever consumed
by ``given``).
"""

import pytest

try:
    import hypothesis
    import hypothesis.strategies as st

    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    HAVE_HYPOTHESIS = False

    class _AnyStrategy:
        """Stand-in for hypothesis.strategies: any constructor -> None."""

        def __getattr__(self, name):
            return lambda *a, **k: None

    st = _AnyStrategy()

    class _HypothesisStub:
        @staticmethod
        def settings(*a, **k):
            return lambda fn: fn

        @staticmethod
        def given(*a, **k):
            return pytest.mark.skip(
                reason="hypothesis not installed (pip install -e .[dev])"
            )

    hypothesis = _HypothesisStub()
