"""Serving correctness: prefill + decode_step must reproduce teacher-forcing
logits exactly, for every cache type (full KV, ring KV, MLA latent, SSM
state, RG-LRU state) -- including multi-step decode past the ring window."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import TINY_ARCHS
from repro.models import decode_step, forward, init_params, make_caches, prefill
from repro.models.frontends import synth_codebook_tokens, synth_image_embeds

B = 2

FAMS = ["olmo-1b", "internlm2-1.8b", "minicpm3-4b", "mamba2-780m",
        "recurrentgemma-9b", "llama-3.2-vision-11b", "musicgen-medium",
        "granite-moe-1b-a400m"]


def _inputs(cfg, s, key):
    if cfg.n_codebooks:
        toks = synth_codebook_tokens(key, B, s, cfg.n_codebooks, cfg.vocab_size)
    else:
        toks = jax.random.randint(key, (B, s), 0, cfg.vocab_size)
    ctx = None
    if cfg.n_img_tokens:
        ctx = synth_image_embeds(key, B, cfg.n_img_tokens, cfg.d_model,
                                 jnp.dtype(cfg.dtype))
    return toks, ctx


# minicpm3's decode uses the weight-absorbed MLA reformulation: identical
# algebra, different bf16 contraction order (latent-space R-dim instead of
# per-head d-dim) -> slightly wider numeric envelope than cache-identical
# paths. All other archs decode through the same tensors as training.
ATOL = {"minicpm3-4b": 4e-2}


@pytest.mark.parametrize("arch", FAMS)
def test_prefill_then_multistep_decode_matches_forward(arch):
    cfg = TINY_ARCHS[arch]
    S = 40  # > tiny window (16) so ring caches wrap
    params, _ = init_params(jax.random.PRNGKey(0), cfg)
    toks, ctx = _inputs(cfg, S, jax.random.PRNGKey(1))
    ref_logits, _ = forward(params, cfg, toks, ctx)

    split = S - 6
    caches = make_caches(cfg, B, S)
    lp, caches = prefill(params, cfg, toks[:, :split], caches, ctx)
    atol = ATOL.get(arch, 6e-3)
    np.testing.assert_allclose(
        np.asarray(lp), np.asarray(ref_logits[:, split - 1 : split]),
        atol=atol, rtol=1e-3,
    )
    for pos in range(split, S):
        ld, caches = decode_step(
            params, cfg, toks[:, pos : pos + 1], caches,
            jnp.asarray(pos, jnp.int32), ctx,
        )
        np.testing.assert_allclose(
            np.asarray(ld), np.asarray(ref_logits[:, pos : pos + 1]),
            atol=atol, rtol=1e-3,
        )


def test_ring_cache_eviction_is_exact():
    """Local attention ring cache at window W must equal full attention
    restricted to the window, even after many wraps."""
    cfg = TINY_ARCHS["recurrentgemma-9b"]
    S = 3 * cfg.window + 5
    params, _ = init_params(jax.random.PRNGKey(0), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab_size)
    ref_logits, _ = forward(params, cfg, toks)
    caches = make_caches(cfg, B, S)
    _, caches = prefill(params, cfg, toks[:, :-1], caches)
    ld, _ = decode_step(params, cfg, toks[:, -1:], caches,
                        jnp.asarray(S - 1, jnp.int32))
    np.testing.assert_allclose(
        np.asarray(ld), np.asarray(ref_logits[:, -1:]), atol=2e-3, rtol=1e-3
    )
