"""Chaos harness: deterministic fault injection against the guarded loop.

Proves the three recovery contracts end to end:

  * injected NaN/Inf gradients at step k -> the guarded optimizer passes
    params and optimizer state through BITWISE equal to step k-1 (the
    in-launch census detects, the bitwise blend skips);
  * K consecutive bad steps -> the supervisor rolls back to the last
    COMMITTED checkpoint and the data pipeline replays from its recorded
    step (fire-once injection makes the replay clean, so recovery itself
    is asserted, not just attempted);
  * transient step exceptions -> bounded exponential backoff then success;
    exhaustion re-raises; non-transient exceptions propagate immediately.
"""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro import optim
from repro.checkpoint import CheckpointManager
from repro.configs import TrainConfig
from repro.runtime import (
    ChaosMonkey,
    PreemptionGuard,
    StepGuard,
    TrainSupervisor,
    TransientFault,
)


def _bitwise_equal(a, b):
    la, lb = jax.tree.leaves(a), jax.tree.leaves(b)
    return len(la) == len(lb) and all(
        np.asarray(x).tobytes() == np.asarray(y).tobytes()
        for x, y in zip(la, lb)
    )


class _CountingData:
    """Minimal deterministic pipeline with the seek/state protocol: batch i
    is just the integer i, so replay order is directly assertable."""

    def __init__(self):
        self.step = 0

    def next(self):
        b = {"x": self.step}
        self.step += 1
        return b

    def seek(self, step):
        self.step = int(step)

    def state(self):
        return {"step": self.step}


# ------------------------- ChaosMonkey semantics ---------------------------


def test_monkey_corrupt_fires_once_per_step():
    monkey = ChaosMonkey(nan_steps=(3,), inf_steps=(5,))
    g = {"w": jnp.ones((4,))}
    assert not np.all(np.isfinite(monkey.corrupt(g, 3)["w"]))
    # replaying step 3 (post-rollback) sees clean gradients
    assert np.all(np.isfinite(monkey.corrupt(g, 3)["w"]))
    out5 = np.asarray(monkey.corrupt(g, 5)["w"])
    assert np.isinf(out5).sum() == 1
    assert np.all(np.isfinite(monkey.corrupt(g, 4)["w"]))


def test_monkey_transient_and_preempt():
    guard = PreemptionGuard(install=False)
    monkey = ChaosMonkey(fail_steps=(2,), preempt_at=4)
    monkey.on_step(0, guard)
    with pytest.raises(TransientFault):
        monkey.on_step(2, guard)
    monkey.on_step(2, guard)  # fired already: the retry runs clean
    assert not guard.should_stop
    monkey.on_step(4, guard)
    assert guard.should_stop
    assert monkey.calls == 4


# --------------------------- StepGuard policy ------------------------------


def test_stepguard_retry_backoff_schedule():
    sleeps = []
    sg = StepGuard(max_bad_steps=2, max_retries=4, backoff_s=0.1,
                   backoff_cap_s=0.45, sleep=sleeps.append)
    attempts = {"n": 0}

    def flaky():
        attempts["n"] += 1
        if attempts["n"] <= 3:
            raise TransientFault("boom")
        return "ok"

    assert sg.retry(flaky) == "ok"
    assert attempts["n"] == 4
    assert sleeps == [0.1, 0.2, 0.4]  # doubled, capped at 0.45 next
    assert sg.transient_failures == 3


def test_stepguard_retry_exhaustion_reraises():
    sleeps = []
    sg = StepGuard(max_retries=2, backoff_s=0.01, sleep=sleeps.append)

    def always():
        raise TransientFault("down")

    with pytest.raises(TransientFault):
        sg.retry(always)
    assert len(sleeps) == 2  # retries, not the final re-raise


def test_stepguard_non_transient_propagates_immediately():
    sleeps = []
    sg = StepGuard(sleep=sleeps.append)

    def poisoned():
        raise ValueError("not transient")

    with pytest.raises(ValueError):
        sg.retry(poisoned)
    assert sleeps == []  # no retry, no backoff


def test_stepguard_consecutive_counting():
    sg = StepGuard(max_bad_steps=3)
    sg.record(True)
    sg.record(True)
    assert not sg.should_rollback()
    sg.record(False)  # a good step resets the streak
    sg.record(True)
    sg.record(True)
    assert not sg.should_rollback()
    sg.record(True)
    assert sg.should_rollback()
    sg.reset()
    assert not sg.should_rollback()
    with pytest.raises(ValueError):
        StepGuard(max_bad_steps=0)


# --------------- guarded optimizer x injected faults (step k) --------------


@pytest.mark.parametrize("kind", ("nan", "inf"))
def test_injected_fault_at_step_k_skips_bitwise(kind):
    """The headline contract: corrupt the gradients at step k and the
    guarded update leaves params/opt state BITWISE equal to step k-1."""
    tcfg = TrainConfig()
    monkey = ChaosMonkey(
        nan_steps=(2,) if kind == "nan" else (),
        inf_steps=(2,) if kind == "inf" else (),
    )
    params = {"w": jnp.full((8, 32), 0.5), "b": jnp.ones((100,))}
    state = optim.init_state(params)
    guard = optim.init_guard_state(4)
    history = []
    for step in range(4):
        grads = jax.tree.map(lambda p: 0.01 * jnp.ones_like(p), params)
        grads = monkey.corrupt(grads, step)
        history.append((params, state))
        params, state, guard, m = optim.guarded_apply_updates(
            params, grads, state, tcfg, loss=jnp.float32(1.0 + 0.01 * step),
            guard=guard, reduce_backend="pallas_fused",
        )
        if step == 2:
            assert float(m["skipped"]) == 1.0
            assert float(m["nonfinite"]) == 1.0
            assert _bitwise_equal(params, history[2][0])
            assert _bitwise_equal(state, history[2][1])
        else:
            assert float(m["skipped"]) == 0.0
            assert not _bitwise_equal(params, history[step][0])
    assert int(guard.skipped) == 1


# ------------------- supervisor: rollback + replay + retry -----------------


def _np_step_fn(monkey):
    """Plain-numpy guarded-ish step over {"n", "w"}: corrupts via the
    monkey, reports skipped like guarded_apply_updates' metrics."""

    def step_fn(state, batch):
        step = int(batch["x"])
        monkey.on_step(step)
        g = {"w": np.ones(3, np.float32)}
        g = monkey.corrupt(g, step)
        if not np.all(np.isfinite(np.asarray(jax.tree.leaves(g)[0]))):
            return state, {"skipped": 1.0, "loss": 1.0}
        new = {"n": state["n"] + 1, "w": state["w"] + np.asarray(g["w"])}
        return new, {"skipped": 0.0, "loss": 1.0}

    return step_fn


def test_supervisor_rollback_replays_from_recorded_data_step(tmp_path):
    """K=3 consecutive injected NaN steps -> rollback to the last committed
    checkpoint, data rewound to its recorded step, clean replay recovers
    EVERY batch (fire-once injection), transient fault retried once."""
    monkey = ChaosMonkey(nan_steps=(3, 4, 5), fail_steps=(1,))
    sleeps = []
    sg = StepGuard(max_bad_steps=3, backoff_s=0.05, sleep=sleeps.append)
    ckpt = CheckpointManager(tmp_path)
    data = _CountingData()
    sup = TrainSupervisor(_np_step_fn(monkey), ckpt, data, ckpt_every=2,
                          step_guard=sg)
    state0 = {"n": np.zeros((), np.int32), "w": np.zeros(3, np.float32)}
    state, step, status = sup.run(state0, 8)
    assert status == "done" and step == 8
    assert sg.rollbacks == 1
    assert sg.transient_failures == 1 and sleeps == [0.05]
    # rollback went to the step-2 commit (data step 2); batches 2..7
    # replayed clean: no batch is lost, none applied twice
    assert int(state["n"]) == 8
    np.testing.assert_allclose(np.asarray(state["w"]), 8.0)


def test_supervisor_anchor_checkpoint_enables_early_rollback(tmp_path):
    """Faults before the first periodic checkpoint roll back to the step-0
    anchor the supervisor commits when a step_guard is installed."""
    monkey = ChaosMonkey(nan_steps=(0, 1))
    sg = StepGuard(max_bad_steps=2, sleep=lambda s: None)
    ckpt = CheckpointManager(tmp_path)
    data = _CountingData()
    sup = TrainSupervisor(_np_step_fn(monkey), ckpt, data, ckpt_every=100,
                          step_guard=sg)
    state0 = {"n": np.zeros((), np.int32), "w": np.zeros(3, np.float32)}
    state, step, status = sup.run(state0, 4)
    assert status == "done" and step == 4
    assert sg.rollbacks == 1
    assert int(state["n"]) == 4  # batches 0..3 all recovered via the anchor
    np.testing.assert_allclose(np.asarray(state["w"]), 4.0)


def test_supervisor_never_commits_mid_skip_streak(tmp_path):
    """A periodic save landing on a skipped step must NOT commit: it would
    advance the rollback target's data step past batches whose update never
    applied. nan at step 3 with ckpt_every=4: step 4's save is gated off...
    """
    # nan fires at data steps 3 AND 4 here: supervisor step 4 (the periodic
    # boundary) is a skip, so no commit may happen there
    monkey = ChaosMonkey(nan_steps=(3, 4))
    sg = StepGuard(max_bad_steps=5, sleep=lambda s: None)
    ckpt = CheckpointManager(tmp_path)
    data = _CountingData()
    sup = TrainSupervisor(_np_step_fn(monkey), ckpt, data, ckpt_every=4,
                          step_guard=sg)
    state0 = {"n": np.zeros((), np.int32), "w": np.zeros(3, np.float32)}
    state, step, status = sup.run(state0, 6)
    assert status == "done"
    # commits: the step-0 anchor and... NOT step 4 (skipped); nothing else
    # before 6 hits the boundary, so latest() is still the anchor
    assert ckpt.latest() == 0
    assert int(state["n"]) == 4  # steps 3 and 4 skipped for good (no K trip)


def test_rollback_without_checkpoint_raises(tmp_path):
    ckpt = CheckpointManager(tmp_path)
    data = _CountingData()
    sup = TrainSupervisor(lambda s, b: (s, {}), ckpt, data)
    with pytest.raises(RuntimeError):
        sup._rollback({"w": np.zeros(2, np.float32)})


# ----------------------- per-host / seeded injection -----------------------


def test_monkey_corrupt_shard_targets_one_host():
    """corrupt_shard poisons flat element 0 of exactly the injector's host
    shard -- every other shard stays clean -- and fires once per step."""
    x = jnp.ones((8, 4), jnp.float32)  # 8 shards of 4 when shards=8
    monkey = ChaosMonkey(nan_steps=(2,), host=5)
    out = np.asarray(monkey.corrupt_shard(x, 2, shards=8))
    flat = out.reshape(8, -1)
    assert np.isnan(flat[5, 0])
    assert np.isfinite(np.delete(flat, 5, axis=0)).all()
    assert np.isfinite(flat[5, 1:]).all()
    # fire-once: the post-rollback replay of step 2 is clean
    assert np.isfinite(np.asarray(monkey.corrupt_shard(x, 2, shards=8))).all()
    # non-configured steps are untouched
    assert np.isfinite(np.asarray(monkey.corrupt_shard(x, 3, shards=8))).all()


def test_monkey_corrupt_shard_rejects_ragged_split():
    monkey = ChaosMonkey(nan_steps=(1,))
    with pytest.raises(ValueError):
        monkey.corrupt_shard(jnp.ones((7,)), 1, shards=2)


def test_monkey_from_seed_deterministic_schedule():
    """Same (seed, n_steps, rates) -> the same schedule, on every host and
    every rerun; different seeds diverge; step 0 (the anchor commit) is
    never selected; rates=0 injects nothing."""
    a = ChaosMonkey.from_seed(7, n_steps=200, nan_rate=0.1, fail_rate=0.1)
    b = ChaosMonkey.from_seed(7, n_steps=200, nan_rate=0.1, fail_rate=0.1,
                              host=3)
    assert a.nan_steps == b.nan_steps and a.fail_steps == b.fail_steps
    assert b.host == 3
    c = ChaosMonkey.from_seed(8, n_steps=200, nan_rate=0.1, fail_rate=0.1)
    assert (a.nan_steps, a.fail_steps) != (c.nan_steps, c.fail_steps)
    assert a.nan_steps and a.fail_steps  # 200 steps at 10% each: nonempty
    assert 0 not in a.nan_steps | a.inf_steps | a.fail_steps
    quiet = ChaosMonkey.from_seed(7, n_steps=200)
    assert not (quiet.nan_steps | quiet.inf_steps | quiet.fail_steps)
