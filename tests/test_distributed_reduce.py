"""Distributed guarded reduce: the shard_map-native deterministic combine.

jax locks the host device count at first init, so every test runs in a
subprocess with XLA_FLAGS=8 fake CPU devices (same pattern as
test_collectives_multidevice). The properties under test:

  * ``reduce_tree(census=True, mesh_axes=...)`` produces a BIT-identical
    global statistic + census on every replica, at every device count in
    {1, 2, 4, 8} -- the foundation the cross-host guard agreement stands on;
  * the scalar/many entry points are replica-invariant and run-to-run
    deterministic at P=8, and numerically agree with numpy and with the
    single-device answer;
  * the hand-rolled collectives (ring, hierarchical, compressed) cross-check
    against psum AND the fixed-order combine, including all-zero and
    NaN-bearing shards; ``census_agreement``/``replica_bits_agree`` report
    unanimous bits everywhere and flip on a per-device (desynced) value;
  * the guarded optimizer step under shard_map with ONE host's shard
    poisoned skips bitwise-identically on every replica, and K consecutive
    bad steps trip every per-host rollback counter at the SAME step.
"""

import os
import subprocess
import sys
import textwrap

REPO_SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def run_sub(body: str):
    code = textwrap.dedent(
        """
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import jax, jax.numpy as jnp, numpy as np
        from jax import lax
        from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
        from repro import reduce as R
        from repro.core import collectives as C
        """
    ) + textwrap.dedent(body)
    env = dict(os.environ, PYTHONPATH=REPO_SRC)
    env.pop("XLA_FLAGS", None)
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, env=env, timeout=420)
    assert r.returncode == 0, f"STDOUT:\n{r.stdout}\nSTDERR:\n{r.stderr[-3000:]}"
    return r.stdout


def test_reduce_tree_census_bitwise_across_device_counts():
    """The acceptance criterion: global norm + census from
    ``reduce_tree(census=True, mesh_axes=...)`` are bit-identical on every
    replica AND across device counts {1, 2, 4, 8}, kernel and jnp backends
    alike, with a NaN planted in one leaf."""
    run_sub("""
    w = np.arange(8 * 32, dtype=np.float32).reshape(8, 32) / 7.1
    b = (np.arange(8 * 4, dtype=np.float32) / 3.3).reshape(8, 4)
    b[3, 2] = np.nan   # lands in one device's shard at every P
    tree = {"b": jnp.asarray(b), "w": jnp.asarray(w)}

    for backend in ("pallas_fused", "xla"):
        ref_bytes = None
        for p in (1, 2, 4, 8):
            mesh = jax.make_mesh((p,), ("data",))

            def body(t, backend=backend):
                norm, counts = R.reduce_tree(
                    t, "norm2", backend=backend, census=True,
                    mesh_axes=("data",),
                )
                return norm[None], counts[None, :]

            f = jax.jit(C.shard_map_unchecked(
                body, mesh=mesh, in_specs=(P("data"),),
                out_specs=(P("data"), P("data")),
            ))
            norms, counts = f(tree)
            norms, counts = np.asarray(norms), np.asarray(counts)
            # every replica holds the identical bits
            assert norms.tobytes() == norms[:1].tobytes() * p, (backend, p)
            assert counts.tobytes() == counts[:1].tobytes() * p, (backend, p)
            # census: leaf order (b, w) -> [1 NaN, 0, total 1]
            np.testing.assert_array_equal(counts[0], [1.0, 0.0, 1.0])
            # and the bits do not depend on the device count
            if ref_bytes is None:
                ref_bytes = norms[:1].tobytes()
            assert norms[:1].tobytes() == ref_bytes, (backend, p)
        print(backend, "norm bits stable across P=1,2,4,8")
    """)


def test_scalar_and_many_replica_invariant_and_correct():
    """reduce / reduce_many / moments with mesh_axes at P=8: every replica
    holds the identical bits, two runs produce the identical bits, and the
    values agree numerically with numpy and with the P=1 result. (Cross
    device-count BITWISE equality is a per-kernel-layout property -- the
    census path in the test above guarantees it; raw scalar kinds only
    promise replica-invariance + determinism, since the local summation
    tree changes with the partition.)"""
    run_sub("""
    x = (np.arange(8 * 250, dtype=np.float32) / 17.0).reshape(8, 250) - 50.0
    xs = jnp.asarray(x)
    arrs = [jnp.asarray(x[:, :40]), jnp.asarray(x[:, 40:47])]

    def run(p, backend):
        mesh = jax.make_mesh((p,), ("data",))

        def body(v, a0, a1, backend=backend):
            outs = [
                R.reduce(v, kind=k, backend=backend, mesh_axes=("data",))
                for k in ("sum", "sumsq", "norm2", "mean")
            ]
            mu, var = R.reduce(v, kind="moments", backend=backend,
                               mesh_axes=("data",))
            many = R.reduce_many([a0, a1], kind="sumsq", backend=backend,
                                 mesh_axes=("data",))
            row = jnp.concatenate([jnp.stack(outs + [mu, var]), many])
            return row[None, :]  # one row per replica

        f = jax.jit(C.shard_map_unchecked(
            body, mesh=mesh, in_specs=(P("data"),) * 3,
            out_specs=P("data"),
        ))
        return np.asarray(f(xs, *arrs))

    want = np.array([
        x.sum(dtype=np.float64),
        (x.astype(np.float64) ** 2).sum(),
        np.sqrt((x.astype(np.float64) ** 2).sum()),
        x.mean(dtype=np.float64),
        x.sum(dtype=np.float64),                    # moments = raw (sum,
        (x.astype(np.float64) ** 2).sum(),          #           sumsq) pair
        (x[:, :40].astype(np.float64) ** 2).sum(),
        (x[:, 40:47].astype(np.float64) ** 2).sum(),
    ])
    for backend in ("pallas_fused", "mma_jnp"):
        rows = run(8, backend)
        # replica-invariant: all 8 rows carry the identical bits
        assert rows.tobytes() == rows[:1].tobytes() * 8, backend
        # deterministic: a second run reproduces the bits exactly
        assert run(8, backend).tobytes() == rows.tobytes(), backend
        # numerically right (mean must use the GLOBAL count); rtol spans
        # the kernel backends' MMA compute precision (bf16-input dots)
        np.testing.assert_allclose(rows[0], want, rtol=3e-3)
        # and consistent with the single-device answer
        np.testing.assert_allclose(rows[0], run(1, backend)[0], rtol=3e-3)
        print(backend, "replica-invariant + deterministic + correct")
    """)


def test_collectives_cross_check_zeros_and_nan():
    """ring_all_reduce / hierarchical_psum / compressed_psum vs psum vs the
    fixed-order combine on the 8-device mesh, over normal, ALL-ZERO, and
    NaN-bearing shards; replica_bits_agree is True for the (replicated)
    combined row everywhere and False for a deliberately per-device value."""
    run_sub("""
    mesh = jax.make_mesh((8,), ("data",))
    rng = np.random.RandomState(0)
    cases = {
        "normal": rng.randn(8, 16).astype(np.float32),
        "zeros": np.zeros((8, 16), np.float32),
    }
    nanful = rng.randn(8, 16).astype(np.float32)
    nanful[5, 3] = np.nan  # one device's shard carries the NaN
    cases["nan"] = nanful

    def body(xs):
        ring = C.ring_all_reduce(xs, "data")
        hier = C.hierarchical_psum(xs, ("data",))
        ref = lax.psum(xs, "data")
        fo = C.fixed_order_combine(xs, ("data",))
        row = jnp.stack([jnp.sum(~jnp.isfinite(xs), dtype=jnp.float32)])
        combined, agree = C.census_agreement(row, ("data",))
        desync = C.replica_bits_agree(
            lax.axis_index("data").astype(jnp.float32), ("data",)
        )
        return ring, hier, ref, fo, combined, agree[None], desync[None]

    f = jax.jit(C.shard_map_unchecked(
        body, mesh=mesh, in_specs=P("data", None),
        out_specs=(P("data", None),) * 4 + (P("data"), P("data"), P("data")),
    ))
    for name, x in cases.items():
        ring, hier, ref, fo, combined, agree, desync = f(jnp.asarray(x))
        ring, hier, ref, fo = map(np.asarray, (ring, hier, ref, fo))
        want = x.sum(axis=0, keepdims=True).repeat(8, axis=0)
        np.testing.assert_allclose(ref, want, rtol=1e-4, equal_nan=True)
        np.testing.assert_allclose(ring, ref, rtol=1e-4, equal_nan=True)
        np.testing.assert_array_equal(hier, ref)  # hier IS psum per axis
        np.testing.assert_allclose(fo, ref, rtol=1e-4, equal_nan=True)
        # the fixed-order result is bitwise REPLICA-identical
        assert fo.tobytes() == fo[:1].tobytes() * 8, name
        # census agreement: identical non-finite count on every host
        combined = np.asarray(combined)
        n_bad = float(np.sum(~np.isfinite(x)))
        np.testing.assert_array_equal(combined, [n_bad] * 8)
        assert np.asarray(agree).all(), name
        # the detector DOES flip on a per-device (desynced) value
        assert not np.asarray(desync).any(), name
        print(name, "ok")

    # compressed int8-EF psum: bounded error on finite data, exact on zeros
    def cbody(xs, err):
        out, new_err = C.compressed_psum(xs, "data", err)
        return out, new_err, lax.psum(xs, "data")

    cf = jax.jit(C.shard_map_unchecked(
        cbody, mesh=mesh, in_specs=(P("data", None), P("data", None)),
        out_specs=(P("data", None),) * 3,
    ))
    for name in ("normal", "zeros"):
        x = jnp.asarray(cases[name])
        out, _, ref = cf(x, jnp.zeros_like(x))
        out, ref = np.asarray(out), np.asarray(ref)
        if name == "zeros":
            np.testing.assert_array_equal(out, 0.0)
        else:
            scale = np.max(np.abs(ref))
            assert np.max(np.abs(out - ref)) < 0.05 * scale
    print("compressed ok")
    """)


def test_guarded_step_lockstep_skip_and_rollback():
    """FSDP-style guarded step: params/grads SHARDED along the mesh axis,
    ``guarded_apply_updates(mesh_axes=("data",))`` inside shard_map.
    ChaosMonkey poisons ONE host's shard at steps 3-5: every replica
    reports the identical bitwise skip flag, params pass through bitwise
    unchanged, and 8 per-host StepGuards (fed each replica's own flag)
    trip rollback at the SAME step."""
    run_sub("""
    from repro import optim
    from repro.configs import TrainConfig
    from repro.optim.adamw import AdamWState
    from repro.runtime import ChaosMonkey, StepGuard

    mesh = jax.make_mesh((8,), ("data",))
    tcfg = TrainConfig(learning_rate=1e-2, total_steps=20, warmup_steps=1)
    rng = np.random.RandomState(0)
    params = {
        "w": jnp.asarray(rng.randn(8, 16).astype(np.float32)),
        "b": jnp.asarray(np.linspace(-1.0, 1.0, 8, dtype=np.float32)),
    }
    state = optim.init_state(params)
    guard = optim.init_guard_state(8)
    loss = jnp.float32(1.0)

    pspec = {"w": P("data"), "b": P("data")}
    sspec = AdamWState(step=P(), m=pspec, v=pspec)
    gspec = jax.tree.map(lambda _: P(), guard)

    def body(p, g, s, gu, lo):
        new_p, new_s, new_gu, m = optim.guarded_apply_updates(
            p, g, s, tcfg, loss=lo, guard=gu,
            reduce_backend="pallas_fused", mesh_axes=("data",),
        )
        return new_p, new_s, new_gu, {k: v[None] for k, v in m.items()}

    step_fn = jax.jit(C.shard_map_unchecked(
        body, mesh=mesh,
        in_specs=(pspec, pspec, sspec, gspec, P()),
        out_specs=(pspec, sspec, gspec, P("data")),
    ))

    monkey = ChaosMonkey(nan_steps=(3, 4, 5), host=2)
    guards = [StepGuard(max_bad_steps=3, sleep=lambda s: None)
              for _ in range(8)]
    base_w = jnp.asarray(rng.randn(8, 16).astype(np.float32) * 0.1)
    base_b = jnp.asarray(rng.randn(8).astype(np.float32) * 0.1)
    rollback_at = [None] * 8
    for t in range(1, 9):
        grads = {"w": monkey.corrupt_shard(base_w, t, shards=8),
                 "b": base_b}
        before = jax.tree.map(
            lambda a: np.asarray(a).tobytes(), params
        )
        params, state, guard, m = step_fn(params, grads, state, guard, loss)
        per = {k: np.asarray(v) for k, v in m.items()}
        for k in ("skipped", "grad_norm", "nonfinite", "clip"):
            assert per[k].tobytes() == per[k][:1].tobytes() * 8, (t, k)
        skipped = float(per["skipped"][0]) > 0.0
        assert skipped == (t in (3, 4, 5)), (t, per["skipped"])
        if skipped:
            assert float(per["nonfinite"][0]) > 0.0, t
            after = jax.tree.map(
                lambda a: np.asarray(a).tobytes(), params
            )
            assert after == before, t  # bitwise pass-through
        for h in range(8):
            guards[h].record(float(per["skipped"][h]) > 0.0)
            if rollback_at[h] is None and guards[h].should_rollback():
                rollback_at[h] = t
    assert rollback_at == [5] * 8, rollback_at  # identical rollback step
    print("lockstep skip + rollback at step 5 on all 8 hosts")
    """)
