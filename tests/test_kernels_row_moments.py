"""Fused norm kernels vs oracles: shapes, dtypes, gradients."""

from _optional_hypothesis import hypothesis, st
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.row_moments import (
    layernorm_np,
    layernorm_np_ref,
    rmsnorm,
    rmsnorm_ref,
)

SHAPES = [(1, 8), (7, 64), (4, 13, 256), (2, 3, 5, 128), (300, 1000)]


@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("dtype", [np.float32, np.float16])
def test_rmsnorm_matches(shape, dtype, rng):
    x = jnp.asarray(rng.randn(*shape).astype(dtype))
    g = jnp.asarray(rng.rand(shape[-1]).astype(np.float32) + 0.5)
    got = rmsnorm(x, g)
    want = rmsnorm_ref(x, g)
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32),
        atol=5e-2 if dtype == np.float16 else 5e-3,
    )


@pytest.mark.parametrize("shape", SHAPES)
def test_layernorm_np_matches(shape, rng):
    x = jnp.asarray(rng.randn(*shape).astype(np.float32) * 3 + 1)
    np.testing.assert_allclose(
        np.asarray(layernorm_np(x)), np.asarray(layernorm_np_ref(x)), atol=5e-3
    )


def test_rmsnorm_grads_match_autodiff_of_ref(rng):
    x = jnp.asarray(rng.randn(6, 96).astype(np.float32))
    g = jnp.asarray(rng.rand(96).astype(np.float32) + 0.5)
    f = lambda x, g: jnp.sum(jnp.tanh(rmsnorm(x, g)))
    fr = lambda x, g: jnp.sum(jnp.tanh(rmsnorm_ref(x, g)))
    gx, gg = jax.grad(f, (0, 1))(x, g)
    rx, rg = jax.grad(fr, (0, 1))(x, g)
    np.testing.assert_allclose(np.asarray(gx), np.asarray(rx), atol=5e-3)
    np.testing.assert_allclose(np.asarray(gg), np.asarray(rg), atol=2e-2)


def test_layernorm_np_grads(rng):
    x = jnp.asarray(rng.randn(5, 64).astype(np.float32))
    h = lambda x: jnp.sum(jnp.sin(layernorm_np(x)))
    hr = lambda x: jnp.sum(jnp.sin(layernorm_np_ref(x)))
    np.testing.assert_allclose(
        np.asarray(jax.grad(h)(x)), np.asarray(jax.grad(hr)(x)), atol=5e-3
    )


@hypothesis.settings(max_examples=20, deadline=None)
@hypothesis.given(
    rows=st.integers(1, 64), d=st.integers(2, 512), seed=st.integers(0, 2**31 - 1)
)
def test_property_rmsnorm_unit_rms(rows, d, seed):
    """Invariant: output of rmsnorm with gamma=1 has RMS ~ 1 per row."""
    x = np.random.RandomState(seed).randn(rows, d).astype(np.float32) + 0.1
    y = np.asarray(rmsnorm(jnp.asarray(x), jnp.ones((d,), jnp.float32)))
    rms = np.sqrt((y.astype(np.float64) ** 2).mean(-1))
    np.testing.assert_allclose(rms, 1.0, atol=2e-2)
