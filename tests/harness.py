"""Differential test harness: ONE oracle runner for every kernel path.

Every `(backend x kind x prologue x dtype x num_cores)` cell of the
reduction engine is pinned the same way:

  * against the f64 numpy oracle computed on the QUANTIZED operand (storage
    rounding is part of the input, never part of the error budget), within
    a per-kind budget scaled by the operand's mass and the multiplier width
    the resolved plan actually runs (`budget_for`);
  * against the op-for-op ``ref.py`` emulations, BIT-FOR-BIT wherever the
    contract guarantees it (`expect_bitwise`): f32 compute for any
    prologue, and precision-exact maps (identity / abs) at any width. The
    one open case -- a bf16/f16-compute SQUARE, where XLA's
    excess-precision rules may round the multiply differently inside
    different fusions -- degrades to the mass budget (see the ref.py
    module docstring).

This replaces the copy-pasted closeness checks that used to live in
test_reduce_dispatch.py / test_zero_copy_ingest.py / test_kernels_mma_reduce.py:
those files now import `mass_tol` / `storage_rel` from here, and the full
cell sweep lives in tests/test_differential.py (run as its own CI job so a
kernel-body regression is attributed separately from a dispatch one).
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro import reduce as R

BACKENDS = ("xla", "mma_jnp", "pallas_hier", "pallas_fused")
PALLAS_BACKENDS = ("pallas_hier", "pallas_fused")
SCAN_BACKENDS = ("xla", "mma_jnp", "pallas_fused")
KINDS = R.KINDS
PROLOGUES = ("identity", "square", "abs", "moments")
DTYPES = ("bfloat16", "float16", "float32")

# kind -> the elementwise prologue its full reduction runs in-kernel
KIND_PROLOGUE = {
    "sum": "identity",
    "mean": "identity",
    "sumsq": "square",
    "norm2": "square",
    "moments": "moments",
}

# Relative error per accumulated unit of mass, by MULTIPLIER width (the
# plan's compute dtype): one rounding per element at that width dominates;
# f32 compute only pays f32 accumulation noise (sqrt(n) * eps32, bounded
# here for n <= ~1e6).
COMPUTE_REL = {"bfloat16": 8e-3, "float16": 1e-3, "float32": 2e-4}


def storage_rel(dtype) -> float:
    """The legacy per-storage-width closeness scale (bf16 multipliers
    assumed): 16-bit storage quantizes the data on top of the multiplier
    rounding."""
    return 4e-3 if jnp.dtype(dtype) == jnp.float32 else 1.6e-2


def mass_tol(x, rel: float = 4e-3, floor: float = 1.0) -> float:
    """The engine-wide closeness budget: ``rel`` per unit of absolute mass
    (error of a width-limited multiplier path scales with the mass moved
    through it, not with the result, which may cancel to ~0)."""
    return rel * max(float(np.abs(np.asarray(x, np.float64)).sum()), floor)


def make_operand(n: int, dtype, seed: int = 0) -> jnp.ndarray:
    """Deterministic ragged operand, quantized to ``dtype`` storage."""
    return jnp.asarray(np.random.RandomState(seed).randn(n)).astype(dtype)


def oracle(x, kind: str):
    """f64 numpy ground truth on the quantized operand (pair for moments;
    empty-mean follows the engine's 0 convention)."""
    x64 = np.asarray(x, np.float64).reshape(-1)
    s, ss = x64.sum(), (x64 * x64).sum()
    if kind == "sum":
        return s
    if kind == "mean":
        return s / x64.size if x64.size else 0.0
    if kind == "sumsq":
        return ss
    if kind == "norm2":
        return np.sqrt(ss)
    return s, ss  # moments


def budget_for(x, kind: str, plan=None, compute_dtype=None) -> float:
    """Per-kind error budget for one cell, in result units.

    Scaled by the mass the kind actually accumulates (|x| for sum-like
    kinds, x^2 for square kinds) times the resolved plan's multiplier
    width; norm2 propagates the sumsq budget through the square root.
    """
    x64 = np.asarray(x, np.float64).reshape(-1)
    if compute_dtype is None:
        compute_dtype = plan.compute_dtype if plan is not None else "bfloat16"
    rel = COMPUTE_REL[str(jnp.dtype(compute_dtype))]
    mass = max(np.abs(x64).sum(), 1e-3)
    mass_sq = max((x64 * x64).sum(), 1e-3)
    if kind in ("sum", "mean"):
        tol = rel * mass
        return tol / x64.size if (kind == "mean" and x64.size) else tol
    if kind == "sumsq":
        return rel * mass_sq
    if kind == "norm2":
        # d sqrt(s) = ds / (2 sqrt(s))
        return rel * mass_sq / (2.0 * np.sqrt(mass_sq)) + 1e-6
    raise ValueError(f"budget_for: scalar kinds only, got {kind!r}")


def expect_bitwise(prologue: str, compute_dtype) -> bool:
    """True when kernel-vs-emulation agreement is guaranteed BIT-FOR-BIT:
    f32 compute (every op exact or identically rounded) or a
    precision-exact map (identity/abs introduce no rounding of their own).
    The bf16/f16 square is the documented excess-precision exception."""
    # (a bf16/f16 "moments" squares too -- same exception as "square")
    return (
        jnp.dtype(compute_dtype) == jnp.float32
        or prologue in ("identity", "abs")
    )


def assert_bits_equal(got, want, msg=""):
    got = np.asarray(got, np.float32)
    want = np.asarray(want, np.float32)
    np.testing.assert_array_equal(
        got.view(np.uint32), want.view(np.uint32), err_msg=msg
    )


def scan_oracle(x, inclusive: bool = True, reverse: bool = False):
    """f64 numpy cumsum ground truth on the quantized operand, over the
    LAST axis, in the requested direction and inclusivity."""
    x64 = np.asarray(x, np.float64)
    if reverse:
        x64 = x64[..., ::-1]
    out = np.cumsum(x64, -1)
    if not inclusive:
        out = np.concatenate([np.zeros_like(out[..., :1]), out[..., :-1]], -1)
    if reverse:
        out = out[..., ::-1]
    return out


def scan_budget(x, compute_dtype, reverse: bool = False, floor: float = 1.0):
    """PER-ELEMENT scan error budget: prefix i has accumulated the running
    absolute mass |x[:i+1]| (or the suffix mass when reversed), so its
    budget is that mass times the multiplier-width rel -- the scan analogue
    of ``budget_for``, elementwise because every partial is an output."""
    rel = COMPUTE_REL[str(jnp.dtype(compute_dtype))]
    a = np.abs(np.asarray(x, np.float64))
    mass = (
        np.cumsum(a[..., ::-1], -1)[..., ::-1] if reverse else np.cumsum(a, -1)
    )
    return rel * np.maximum(mass, floor)


def run_scan_cell(
    backend: str,
    dtype,
    n: int,
    num_cores: int = 1,
    inclusive: bool = True,
    reverse: bool = False,
    seed: int = 0,
) -> None:
    """Pin one scan cell against the f64 oracle within the per-element
    mass budget of the plan's resolved compute width."""
    x = make_operand(n, dtype, seed)
    plan = R.scan_plan_for(
        (n,), jnp.dtype(dtype), backend=backend, num_cores=num_cores
    )
    got = np.asarray(
        R.scan(x, inclusive=inclusive, reverse=reverse, plan=plan), np.float64
    )
    want = scan_oracle(x, inclusive, reverse)
    tol = scan_budget(x, plan.compute_dtype, reverse=reverse)
    err = np.abs(got - want)
    label = (backend, str(jnp.dtype(dtype)), n, num_cores, inclusive, reverse)
    assert (err <= tol).all(), (label, float(err.max()), float(tol.min()))


def run_cell(
    backend: str,
    kind: str,
    dtype,
    n: int,
    num_cores: int = 1,
    seed: int = 0,
) -> None:
    """Pin one engine cell against the f64 oracle within its budget."""
    x = make_operand(n, dtype, seed)
    plan = R.plan_for(
        (n,), jnp.dtype(dtype), kind=kind, backend=backend,
        num_cores=num_cores,
    )
    got = R.reduce(x, kind=kind, plan=plan)
    label = (backend, kind, str(jnp.dtype(dtype)), n, num_cores)
    if kind == "moments":
        ws, wss = oracle(x, kind)
        assert abs(float(got[0]) - ws) <= budget_for(x, "sum", plan), label
        assert abs(float(got[1]) - wss) <= budget_for(x, "sumsq", plan), label
        return
    want = oracle(x, kind)
    assert abs(float(got) - want) <= budget_for(x, kind, plan), (
        label, float(got), want
    )
