"""Paper's analytic claims (section IV.B / V), validated exactly."""

import math

import pytest

from repro.core import cost_model as cm


def test_eq16_t_tc():
    assert cm.t_tensor_core(16**2, 16) == pytest.approx(5.0)
    assert cm.t_tensor_core((16**2) ** 3, 16) == pytest.approx(15.0)
    assert cm.t_tensor_core(2**20, 4) == pytest.approx(5 * math.log(2**20, 16))


def test_classic_4log2():
    assert cm.t_classic(2**10) == pytest.approx(40.0)


def test_eq17_speedup_closed_form():
    """S = (4/5) log2(m^2); paper section V: S(4) ~ 3.2, S(16) ~ 6.4,
    and S > 1 already at the minimum m = 2."""
    assert cm.speedup_model(4) == pytest.approx(3.2)
    assert cm.speedup_model(16) == pytest.approx(6.4)
    assert cm.speedup_model(2) == pytest.approx(1.6) and cm.speedup_model(2) > 1
    # TPU MXU tile: the model extrapolates to S ~ 11.2 at m = 128
    assert cm.speedup_model(128) == pytest.approx(11.2)


def test_ratio_equals_closed_form():
    """T_classic/T_tc == S independent of n (both are log n)."""
    for m in (2, 4, 16, 128):
        for n in (2**12, 2**24):
            ratio = cm.t_classic(n) / cm.t_tensor_core(n, m)
            assert ratio == pytest.approx(cm.speedup_model(m), rel=1e-9)


def test_tpu_roofline_terms():
    rl = cm.tpu_reduction_roofline(1 << 24, bytes_per_el=2)
    # cold reductions are HBM-bound: both compute paths fit under ~1.5x the
    # stream time at this size
    assert rl.hbm_s > 0 and rl.vpu_s > 0 and rl.mxu_s > 0
    assert rl.mxu_s < 1.5 * rl.hbm_s
    assert rl.cold_bound_s >= rl.hbm_s
    # monotonic in n
    rl2 = cm.tpu_reduction_roofline(1 << 26, bytes_per_el=2)
    assert rl2.hbm_s > rl.hbm_s and rl2.mxu_s > rl.mxu_s


def test_model_table_rows():
    rows = cm.model_table(ns=(2**16,), ms=(4, 16))
    assert len(rows) == 2
    for r in rows:
        assert r["speedup"] == pytest.approx(r["speedup_closed_form"], rel=1e-9)
