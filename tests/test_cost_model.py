"""Paper's analytic claims (section IV.B / V), validated exactly."""

import math

import pytest

from repro.core import cost_model as cm


def test_eq16_t_tc():
    assert cm.t_tensor_core(16**2, 16) == pytest.approx(5.0)
    assert cm.t_tensor_core((16**2) ** 3, 16) == pytest.approx(15.0)
    assert cm.t_tensor_core(2**20, 4) == pytest.approx(5 * math.log(2**20, 16))


def test_classic_4log2():
    assert cm.t_classic(2**10) == pytest.approx(40.0)


def test_eq17_speedup_closed_form():
    """S = (4/5) log2(m^2); paper section V: S(4) ~ 3.2, S(16) ~ 6.4,
    and S > 1 already at the minimum m = 2."""
    assert cm.speedup_model(4) == pytest.approx(3.2)
    assert cm.speedup_model(16) == pytest.approx(6.4)
    assert cm.speedup_model(2) == pytest.approx(1.6) and cm.speedup_model(2) > 1
    # TPU MXU tile: the model extrapolates to S ~ 11.2 at m = 128
    assert cm.speedup_model(128) == pytest.approx(11.2)


def test_ratio_equals_closed_form():
    """T_classic/T_tc == S independent of n (both are log n)."""
    for m in (2, 4, 16, 128):
        for n in (2**12, 2**24):
            ratio = cm.t_classic(n) / cm.t_tensor_core(n, m)
            assert ratio == pytest.approx(cm.speedup_model(m), rel=1e-9)


def test_multicore_mma_counts():
    """The striped-pipeline model: n/(m^2 c) + c MMAs on the critical path,
    recovering the serial fused count n/m^2 + 2 at c = 1."""
    n = 1 << 24  # 1024 tiles at m=128
    serial = cm.fused_mma_ops(n, num_cores=1)
    assert serial.lane == 1024 and serial.combine == 2
    assert serial.total == 1024 + 2 and serial.critical_path == 1026
    c4 = cm.fused_mma_ops(n, num_cores=4)
    assert c4.num_cores == 4 and c4.lane == 256 and c4.combine == 5
    assert c4.total == 4 * 256 + 5
    # striping cuts the critical path ~c-fold while total stays ~n/m^2
    assert c4.critical_path < serial.critical_path / 3
    # lanes never exceed the block count (tiny problems stay serial)
    tiny = cm.fused_mma_ops(100, num_cores=8)
    assert tiny.num_cores == 1 and tiny.lane == 1
    # monotone: more lanes never lengthens the critical path
    paths = [
        cm.fused_mma_ops(n, num_cores=c).critical_path for c in (1, 2, 4, 8)
    ]
    assert paths == sorted(paths, reverse=True)


def test_segmented_mma_counts():
    segments, tiles = 32, 4096
    serial = cm.segmented_mma_ops(
        tiles * 128 * 128, tiles=tiles, flushes=segments, num_cores=1
    )
    assert serial.total == tiles + segments  # n/m^2 + S
    c2 = cm.segmented_mma_ops(
        tiles * 128 * 128, tiles=tiles, flushes=40, num_cores=2
    )
    assert c2.lane == tiles // 2 and c2.combine == 40
    assert c2.critical_path < serial.critical_path
    # flushes run INSIDE their lanes concurrently: with the worst lane's
    # share known, only that share sits on the critical path (total MMAs
    # issued chip-wide are unchanged)
    c2b = cm.segmented_mma_ops(
        tiles * 128 * 128, tiles=tiles, flushes=40, num_cores=2,
        max_lane_flushes=22,
    )
    assert c2b.total == c2.total
    assert c2b.critical_path == tiles // 2 + 22


def test_tpu_roofline_terms():
    rl = cm.tpu_reduction_roofline(1 << 24, bytes_per_el=2)
    # cold reductions are HBM-bound: both compute paths fit under ~1.5x the
    # stream time at this size
    assert rl.hbm_s > 0 and rl.vpu_s > 0 and rl.mxu_s > 0
    assert rl.mxu_s < 1.5 * rl.hbm_s
    assert rl.cold_bound_s >= rl.hbm_s
    # monotonic in n
    rl2 = cm.tpu_reduction_roofline(1 << 26, bytes_per_el=2)
    assert rl2.hbm_s > rl.hbm_s and rl2.mxu_s > rl.mxu_s


def test_model_table_rows():
    rows = cm.model_table(ns=(2**16,), ms=(4, 16))
    assert len(rows) == 2
    for r in rows:
        assert r["speedup"] == pytest.approx(r["speedup_closed_form"], rel=1e-9)
