"""Fused CE kernel vs oracle: vocab sweeps incl. non-multiple-of-block."""

from _optional_hypothesis import hypothesis, st
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.cross_entropy import cross_entropy, cross_entropy_ref


@pytest.mark.parametrize("rows,vocab", [(1, 7), (5, 100), (16, 2048),
                                        (37, 5000), (8, 50304), (3, 100352)])
def test_matches_oracle(rows, vocab, rng):
    logits = jnp.asarray(rng.randn(rows, vocab).astype(np.float32) * 3)
    labels = jnp.asarray(rng.randint(0, vocab, rows))
    got = cross_entropy(logits, labels)
    want = cross_entropy_ref(logits, labels)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=5e-3)


def test_batched_shape_and_grad(rng):
    logits = jnp.asarray(rng.randn(2, 9, 512).astype(np.float32))
    labels = jnp.asarray(rng.randint(0, 512, (2, 9)))
    out = cross_entropy(logits, labels)
    assert out.shape == (2, 9)
    g = jax.grad(lambda l: jnp.mean(cross_entropy(l, labels)))(logits)
    # dCE/dlogits = (softmax - onehot)/N
    p = jax.nn.softmax(logits, -1)
    want = (p - jax.nn.one_hot(labels, 512)) / 18
    np.testing.assert_allclose(np.asarray(g), np.asarray(want), atol=1e-6)


def test_extreme_logits_stable(rng):
    """Online logsumexp must survive +-1e4 logits (softcap-free archs)."""
    logits = jnp.asarray(rng.randn(4, 1000).astype(np.float32) * 1e4)
    labels = jnp.asarray(rng.randint(0, 1000, 4))
    got = cross_entropy(logits, labels)
    assert bool(jnp.all(jnp.isfinite(got)))
    want = cross_entropy_ref(logits, labels)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5)


@hypothesis.settings(max_examples=20, deadline=None)
@hypothesis.given(
    rows=st.integers(1, 24), vocab=st.integers(2, 4096),
    seed=st.integers(0, 2**31 - 1), scale=st.floats(0.1, 30.0),
)
def test_property_positive_and_exact(rows, vocab, seed, scale):
    r = np.random.RandomState(seed)
    logits = jnp.asarray(r.randn(rows, vocab).astype(np.float32) * scale)
    labels = jnp.asarray(r.randint(0, vocab, rows))
    got = np.asarray(cross_entropy(logits, labels))
    assert (got >= -1e-4).all()  # CE is non-negative
    want = np.asarray(cross_entropy_ref(logits, labels))
    np.testing.assert_allclose(got, want, atol=1e-2, rtol=1e-4)
