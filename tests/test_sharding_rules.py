"""Sharding-rule unit tests (pure logic; no devices needed) + the HLO
collective/depth parsers on synthetic module text."""

import jax
import pytest
from jax.sharding import PartitionSpec as P

from repro.launch import sharding as SH


@pytest.fixture(scope="module")
def mesh():
    return jax.make_mesh((1, 1), ("data", "model"))


class FakeMesh:
    """Shape-only stand-in so divisibility logic is testable without 256
    devices."""
    def __init__(self, shape):
        self.shape = shape
        self.axis_names = tuple(shape)


def spec(axes, shape=None, rules=SH.DEFAULT_RULES, mesh_shape=None):
    m = FakeMesh(mesh_shape or {"data": 16, "model": 16})
    return SH.spec_for(axes, rules, m, shape)


def test_basic_mapping():
    assert spec(("embed", "ffn")) == P("data", "model")
    assert spec(("vocab", None)) == P("model")
    assert spec(None) == P()


def test_divisibility_guard():
    # vocab 50280 % 16 != 0 -> partition dropped
    assert spec(("vocab", "embed"), shape=(50280, 1536)) == P(None, "data")
    assert spec(("vocab", "embed"), shape=(50432, 1536)) == P("model", "data")


def test_axis_used_once():
    # both dims want "model": second falls back to None
    assert spec(("ffn", "heads")) == P("model")


def test_multi_axis_fsdp():
    m3 = {"pod": 2, "data": 16, "model": 16}
    s = spec(("embed", "ffn"), shape=(6144, 10752),
             rules=SH.BIG_MODEL_RULES, mesh_shape=m3)
    assert s == P(("pod", "data"), "model")
    # on a single-pod mesh the pod axis is skipped
    s1 = spec(("embed", "ffn"), shape=(6144, 10752), rules=SH.BIG_MODEL_RULES)
    assert s1 == P("data", "model")


def test_small_model_rules_drop_tp():
    assert spec(("embed", "ffn"), rules=SH.SMALL_MODEL_RULES) == P("data")
    assert spec(("embed", "heads"), rules=SH.SMALL_MODEL_RULES) == P("data")
    # experts keep EP
    assert spec(("experts", "embed", "ffn"), rules=SH.SMALL_MODEL_RULES) == \
        P("model", "data")


def test_batch_partition_guard(mesh):
    big = jax.make_mesh((1, 1), ("data", "model"))
    assert SH.batch_partition(big, 8) == "data"
    assert SH.batch_partition(big, 7) == "data"  # 7 % 1 == 0
    fake = FakeMesh({"data": 16, "model": 16})
    assert SH.batch_partition(fake, 1) is None    # long_500k: replicated
    assert SH.batch_partition(fake, 256) == "data"


# --------------------------- HLO parsers -------------------------------------

SYNTH_HLO = """
%region_inner (arg: (s32[], f32[8,128])) -> (s32[], f32[8,128]) {
  %ar = f32[8,128]{1,0} all-reduce(%x), replica_groups={}, to_apply=%add
  ROOT %t = (s32[], f32[8,128]) tuple(%i, %ar)
}

%region_outer (arg: (s32[], f32[8,128])) -> (s32[], f32[8,128]) {
  %ag = f32[16,128]{1,0} all-gather(%y), dimensions={0}
  %w = (s32[], f32[8,128]) while(%arg), condition=%c, body=%region_inner
  ROOT %t2 = (s32[], f32[8,128]) tuple(%i2, %x2)
}

ENTRY %main (p0: f32[8,128]) -> f32[8,128] {
  %g = f32[256]{0} all-reduce(%p), to_apply=%add
  %w0 = (s32[], f32[8,128]) while(%init), condition=%c0, body=%region_outer
  ROOT %r = f32[8,128] get-tuple-element(%w0), index=1
}
"""


def test_collective_entry_vs_loop_buckets():
    from repro.launch.dryrun import parse_collective_bytes

    out = parse_collective_bytes(SYNTH_HLO)
    assert out["entry"]["all-reduce"]["count"] == 1
    assert out["entry"]["all-reduce"]["bytes"] == 256 * 4
    assert out["loop"]["all-reduce"]["count"] == 1
    assert out["loop"]["all-gather"]["count"] == 1
    # wire factors: AR x2, AG x1
    assert out["entry_wire_bytes"] == 2 * 256 * 4


def test_collective_depth_attribution():
    from repro.launch.dryrun import parse_collective_depths

    d = parse_collective_depths(SYNTH_HLO)
    assert d["0"] == 2 * 256 * 4                 # entry AR, wire x2
    assert d["1"] == 16 * 128 * 4                # AG in the depth-1 body
    assert d["2"] == 2 * 8 * 128 * 4             # AR in the depth-2 body
