"""Checkpoint manager: atomicity, keep-N, resume, corruption tolerance."""

import json
import pathlib
import shutil

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager


def _tree(seed=0):
    k = jax.random.PRNGKey(seed)
    return {
        "w": jax.random.normal(k, (16, 8)),
        "nested": {"b": jnp.arange(5.0), "step": jnp.asarray(3)},
    }


def test_save_restore_roundtrip(tmp_path):
    cm = CheckpointManager(tmp_path, keep=2)
    tree = _tree()
    cm.save(10, tree, extra={"data_step": 10}, blocking=True)
    assert cm.latest() == 10
    out = cm.restore(10, jax.tree.map(jnp.zeros_like, tree))
    for a, b in zip(jax.tree.leaves(out), jax.tree.leaves(tree)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert cm.manifest(10)["extra"]["data_step"] == 10


def test_keep_n_garbage_collection(tmp_path):
    cm = CheckpointManager(tmp_path, keep=2)
    for s in (1, 2, 3, 4):
        cm.save(s, _tree(s), blocking=True)
    steps = sorted(int(p.name.split("_")[1]) for p in
                   pathlib.Path(tmp_path).glob("step_*"))
    assert steps == [3, 4]


def test_uncommitted_checkpoint_ignored(tmp_path):
    cm = CheckpointManager(tmp_path)
    cm.save(5, _tree(), blocking=True)
    # simulate a writer preempted mid-flush at a later step
    broken = pathlib.Path(tmp_path) / "step_00000009"
    broken.mkdir()
    (broken / "shard_00000.npz").write_bytes(b"garbage")
    assert cm.latest() == 5  # _COMMITTED missing -> ignored
    with pytest.raises(FileNotFoundError):
        cm.restore(9, _tree())


def test_restore_validates_shapes(tmp_path):
    cm = CheckpointManager(tmp_path)
    cm.save(1, {"w": jnp.zeros((4, 4))}, blocking=True)
    with pytest.raises(ValueError):
        cm.restore(1, {"w": jnp.zeros((5, 4))})


def test_elastic_reshard_device_put(tmp_path):
    """restore(shardings=...) re-device_puts on the current (1-device) mesh;
    the API contract for elastic restarts."""
    from jax.sharding import NamedSharding, PartitionSpec as P

    mesh = jax.make_mesh((1,), ("data",))
    cm = CheckpointManager(tmp_path)
    tree = {"w": jnp.ones((8, 4))}
    cm.save(2, tree, blocking=True)
    sh = {"w": NamedSharding(mesh, P("data", None))}
    out = cm.restore(2, tree, shardings=sh)
    assert out["w"].sharding == sh["w"]


def test_resume_continuity_exact(tmp_path):
    """train 2+2 steps with restore == train 4 straight (bitwise losses)."""
    from repro.configs import TINY_ARCHS, TrainConfig
    from repro.launch.steps import make_train_step
    from repro.models import init_params
    from repro import optim

    cfg = TINY_ARCHS["olmo-1b"]
    tcfg = TrainConfig(learning_rate=1e-3, total_steps=8, warmup_steps=1)
    step = jax.jit(make_train_step(cfg, tcfg))
    toks = jax.random.randint(jax.random.PRNGKey(7), (2, 16), 0, cfg.vocab_size)
    feed = {"tokens": toks}

    def run(n, params, opt):
        losses = []
        for _ in range(n):
            params, opt, m = step(params, opt, feed)
            losses.append(float(m["loss"]))
        return params, opt, losses

    p0, _ = init_params(jax.random.PRNGKey(0), cfg)
    o0 = optim.init_state(p0)
    _, _, straight = run(4, p0, o0)

    p1, _ = init_params(jax.random.PRNGKey(0), cfg)
    o1 = optim.init_state(p1)
    p1, o1, first = run(2, p1, o1)
    cm = CheckpointManager(tmp_path)
    cm.save(2, (p1, o1), blocking=True)
    p2, o2 = cm.restore(2, (p1, o1))
    _, _, second = run(2, p2, o2)
    np.testing.assert_allclose(first + second, straight, rtol=1e-6)
