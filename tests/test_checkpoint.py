"""Checkpoint manager: atomicity, keep-N, resume, corruption tolerance."""

import json
import pathlib
import shutil

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager


def _tree(seed=0):
    k = jax.random.PRNGKey(seed)
    return {
        "w": jax.random.normal(k, (16, 8)),
        "nested": {"b": jnp.arange(5.0), "step": jnp.asarray(3)},
    }


def test_save_restore_roundtrip(tmp_path):
    cm = CheckpointManager(tmp_path, keep=2)
    tree = _tree()
    cm.save(10, tree, extra={"data_step": 10}, blocking=True)
    assert cm.latest() == 10
    out = cm.restore(10, jax.tree.map(jnp.zeros_like, tree))
    for a, b in zip(jax.tree.leaves(out), jax.tree.leaves(tree)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert cm.manifest(10)["extra"]["data_step"] == 10


def test_keep_n_garbage_collection(tmp_path):
    cm = CheckpointManager(tmp_path, keep=2)
    for s in (1, 2, 3, 4):
        cm.save(s, _tree(s), blocking=True)
    steps = sorted(int(p.name.split("_")[1]) for p in
                   pathlib.Path(tmp_path).glob("step_*"))
    assert steps == [3, 4]


def test_uncommitted_checkpoint_ignored(tmp_path):
    cm = CheckpointManager(tmp_path)
    cm.save(5, _tree(), blocking=True)
    # simulate a writer preempted mid-flush at a later step
    broken = pathlib.Path(tmp_path) / "step_00000009"
    broken.mkdir()
    (broken / "shard_00000.npz").write_bytes(b"garbage")
    assert cm.latest() == 5  # _COMMITTED missing -> ignored
    with pytest.raises(FileNotFoundError):
        cm.restore(9, _tree())


def test_restore_validates_shapes(tmp_path):
    cm = CheckpointManager(tmp_path)
    cm.save(1, {"w": jnp.zeros((4, 4))}, blocking=True)
    with pytest.raises(ValueError):
        cm.restore(1, {"w": jnp.zeros((5, 4))})


def test_elastic_reshard_device_put(tmp_path):
    """restore(shardings=...) re-device_puts on the current (1-device) mesh;
    the API contract for elastic restarts."""
    from jax.sharding import NamedSharding, PartitionSpec as P

    mesh = jax.make_mesh((1,), ("data",))
    cm = CheckpointManager(tmp_path)
    tree = {"w": jnp.ones((8, 4))}
    cm.save(2, tree, blocking=True)
    sh = {"w": NamedSharding(mesh, P("data", None))}
    out = cm.restore(2, tree, shardings=sh)
    assert out["w"].sharding == sh["w"]


def test_resume_continuity_exact(tmp_path):
    """train 2+2 steps with restore == train 4 straight (bitwise losses)."""
    from repro.configs import TINY_ARCHS, TrainConfig
    from repro.launch.steps import make_train_step
    from repro.models import init_params
    from repro import optim

    cfg = TINY_ARCHS["olmo-1b"]
    tcfg = TrainConfig(learning_rate=1e-3, total_steps=8, warmup_steps=1)
    step = jax.jit(make_train_step(cfg, tcfg))
    toks = jax.random.randint(jax.random.PRNGKey(7), (2, 16), 0, cfg.vocab_size)
    feed = {"tokens": toks}

    def run(n, params, opt):
        losses = []
        for _ in range(n):
            params, opt, m = step(params, opt, feed)
            losses.append(float(m["loss"]))
        return params, opt, losses

    p0, _ = init_params(jax.random.PRNGKey(0), cfg)
    o0 = optim.init_state(p0)
    _, _, straight = run(4, p0, o0)

    p1, _ = init_params(jax.random.PRNGKey(0), cfg)
    o1 = optim.init_state(p1)
    p1, o1, first = run(2, p1, o1)
    cm = CheckpointManager(tmp_path)
    cm.save(2, (p1, o1), blocking=True)
    p2, o2 = cm.restore(2, (p1, o1))
    _, _, second = run(2, p2, o2)
    np.testing.assert_allclose(first + second, straight, rtol=1e-6)


# ------------------------ integrity (CRC32) --------------------------------


def _rewrite_leaf(ckpt_dir, step, key, mutate):
    """Rewrite one leaf inside the committed shard npz WITHOUT updating the
    manifest -- a readable archive whose bytes no longer match the CRCs
    recorded at save time (the bit-rot scenario)."""
    shard = pathlib.Path(ckpt_dir) / f"step_{step:08d}" / "shard_00000.npz"
    with np.load(shard) as z:
        data = {k: z[k] for k in z.files}
    data[key] = mutate(data[key])
    np.savez(shard, **data)


def test_manifest_records_per_leaf_crc(tmp_path):
    cm = CheckpointManager(tmp_path)
    cm.save(1, _tree(), blocking=True)
    leaves = cm.manifest(1)["leaves"]
    assert leaves and all("crc32" in v for v in leaves.values())


def test_bit_flip_detected_on_restore(tmp_path):
    """The regression: flip one value in a committed shard and restore must
    raise CheckpointCorruptionError, not hand the model silent garbage."""
    from repro.checkpoint import CheckpointCorruptionError

    cm = CheckpointManager(tmp_path)
    tree = _tree()
    cm.save(1, tree, blocking=True)
    key = next(iter(cm.manifest(1)["leaves"]))

    def flip(a):
        buf = bytearray(np.ascontiguousarray(a).tobytes())
        buf[0] ^= 1  # one flipped bit, the minimal corruption
        return np.frombuffer(bytes(buf), dtype=a.dtype).reshape(a.shape)

    _rewrite_leaf(tmp_path, 1, key, flip)
    with pytest.raises(CheckpointCorruptionError, match="CRC mismatch"):
        cm.restore(1, jax.tree.map(jnp.zeros_like, tree))
    # verify=False is the explicit escape hatch (forensics)
    cm.restore(1, jax.tree.map(jnp.zeros_like, tree), verify=False)


def test_truncated_shard_detected(tmp_path):
    from repro.checkpoint import CheckpointCorruptionError

    cm = CheckpointManager(tmp_path)
    cm.save(3, _tree(), blocking=True)
    shard = pathlib.Path(tmp_path) / "step_00000003" / "shard_00000.npz"
    shard.write_bytes(shard.read_bytes()[: shard.stat().st_size // 2])
    with pytest.raises(CheckpointCorruptionError, match="unreadable shard"):
        cm.restore(3, _tree())


def test_quarantine_hides_step_from_latest(tmp_path):
    cm = CheckpointManager(tmp_path)
    cm.save(1, _tree(1), blocking=True)
    cm.save(2, _tree(2), blocking=True)
    assert cm.latest() == 2
    dst = cm.quarantine(2)
    assert dst.exists() and cm.latest() == 1
    # the quarantined dir never re-enters the committed scan
    assert 2 not in cm._committed_steps()


def test_restore_latest_valid_falls_back_past_corruption(tmp_path):
    """Corrupt the NEWEST commit: restore_latest_valid must quarantine it
    and return the previous committed step's (intact) state."""
    cm = CheckpointManager(tmp_path)
    t1, t2 = _tree(1), _tree(2)
    cm.save(1, t1, blocking=True)
    cm.save(2, t2, blocking=True)
    key = next(iter(cm.manifest(2)["leaves"]))
    _rewrite_leaf(tmp_path, 2, key, lambda a: a + 1)
    like = jax.tree.map(jnp.zeros_like, t1)
    out, step = cm.restore_latest_valid(like)
    assert step == 1
    for a, b in zip(jax.tree.leaves(out), jax.tree.leaves(t1)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert (pathlib.Path(tmp_path) / "quarantine_step_00000002").exists()
    # everything corrupt -> explicit failure, not a silent empty resume
    _rewrite_leaf(tmp_path, 1, key, lambda a: a + 1)
    with pytest.raises(FileNotFoundError):
        cm.restore_latest_valid(like)
