"""The unified reduction engine: every backend must agree with the "xla"
oracle on every kind, across dtypes, shapes and plan overrides -- and stay
differentiable throughout."""

import harness
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro import reduce as R

BACKENDS = ("xla", "mma_jnp", "pallas_hier", "pallas_fused")
MMA_BACKENDS = tuple(b for b in BACKENDS if b != "xla")
SEG_BACKENDS = BACKENDS + ("segmented",)

# (shape, axis) cases: scalar, tiny, ragged, multi-axis, > m^2 extents
FULL_CASES = [((), None), ((7,), None), ((1000,), None), ((20_000,), None)]
AXIS_CASES = [((33, 700), -1), ((6, 50, 40), (1, 2)), ((4, 130), 1),
              ((2, 3, 5), (0, 2))]


def _make(shape, dtype, rng):
    if np.issubdtype(dtype, np.integer):
        return jnp.asarray(rng.randint(-40, 40, size=shape or ()), dtype)
    return jnp.asarray(np.asarray(rng.randn(*shape), np.float32)).astype(dtype)


def _oracle_sum(x, axis):
    return np.asarray(x).astype(np.float64).sum(axis=axis)


def _tol(x):
    # bf16 multipliers: error scales with the mass of the operand
    # (the engine-wide budget; see tests/harness.py)
    return harness.mass_tol(x)


def test_registry_contains_all_four_backends():
    assert set(BACKENDS) <= set(R.available_backends())
    with pytest.raises(KeyError, match="unknown reduce backend"):
        R.get_backend("nope")
    with pytest.raises(ValueError, match="unknown kind"):
        R.reduce(jnp.ones(4), kind="max")


@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("dtype", [np.float32, jnp.bfloat16, np.int32])
@pytest.mark.parametrize("shape,axis", FULL_CASES + AXIS_CASES)
def test_all_backends_agree_with_oracle(backend, dtype, shape, axis, rng):
    x = _make(shape, dtype, rng)
    ax = axis if not isinstance(axis, int) else (axis % max(x.ndim, 1),)
    ax_np = tuple(ax) if axis is not None else None
    got = R.reduce(x, axis=axis, backend=backend)
    want = _oracle_sum(x, ax_np)
    np.testing.assert_allclose(
        np.asarray(got, np.float64), want, atol=_tol(x), rtol=1e-3
    )


@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("kind", R.KINDS)
def test_every_kind_on_every_backend(backend, kind, rng):
    x = jnp.asarray(rng.randn(5000).astype(np.float32))
    xf = np.asarray(x).astype(np.float64)
    got = R.reduce(x, kind=kind, backend=backend)
    if kind == "moments":
        np.testing.assert_allclose(float(got[0]), xf.sum(), atol=_tol(x))
        np.testing.assert_allclose(float(got[1]), (xf**2).sum(), atol=_tol(x))
        return
    want = {
        "sum": xf.sum(),
        "mean": xf.mean(),
        "sumsq": (xf**2).sum(),
        "norm2": np.sqrt((xf**2).sum()),
    }[kind]
    np.testing.assert_allclose(float(got), want, atol=_tol(x), rtol=1e-3)


@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("kind", ["sum", "mean", "sumsq", "norm2"])
def test_gradients_per_backend(backend, kind, rng):
    x = jnp.asarray((rng.rand(400) + 0.5).astype(np.float32))
    g = jax.grad(lambda y: R.reduce(y, kind=kind, backend=backend))(x)
    xf = np.asarray(x).astype(np.float64)
    want = {
        "sum": np.ones_like(xf),
        "mean": np.ones_like(xf) / xf.size,
        "sumsq": 2 * xf,
        "norm2": xf / np.sqrt((xf**2).sum()),
    }[kind]
    np.testing.assert_allclose(np.asarray(g), want, rtol=2e-3, atol=1e-5)


@pytest.mark.parametrize("backend", BACKENDS)
def test_moments_gradient(backend, rng):
    x = jnp.asarray(rng.randn(12, 300).astype(np.float32))

    def f(y):
        s, ss = R.reduce(y, axis=-1, kind="moments", backend=backend)
        return jnp.sum(s) + jnp.sum(ss)

    g = jax.grad(f)(x)
    want = 1.0 + 2 * np.asarray(x).astype(np.float64)
    np.testing.assert_allclose(np.asarray(g), want, rtol=2e-3, atol=1e-4)


def test_out_of_range_axis_raises(rng):
    """Bad axes must raise (numpy semantics), never silently wrap."""
    x = jnp.ones((3, 4))
    for bad in (2, 5, -3):
        with pytest.raises(ValueError, match="out of range"):
            R.reduce(x, axis=bad)
    # numpy convention: 0-d arrays accept axis 0 / -1, reject the rest
    assert float(R.reduce(jnp.asarray(3.0), axis=0)) == 3.0
    with pytest.raises(ValueError, match="out of range"):
        R.reduce(jnp.asarray(3.0), axis=1)
    # duplicate axes raise (numpy semantics), never silently dedup
    with pytest.raises(ValueError, match="duplicate axis"):
        R.reduce(x, axis=(0, -2))


def test_pallas_backends_reject_non_mxu_tile(rng):
    """The kernels implement the 128-wide MXU tile only; a pinned m != 128
    must raise rather than silently run the wrong configuration."""
    x = jnp.asarray(rng.randn(1000).astype(np.float32))
    with pytest.raises(ValueError, match="m=128 MXU tile"):
        R.reduce(x, backend="pallas_fused", m=16)
    # tile-size ablations go through the algorithmic backend
    assert np.isfinite(float(R.reduce(x, backend="mma_jnp", m=16)))


def test_empty_axis_tuple_is_identity(rng):
    """axis=() follows the numpy convention: reduce over NO axes."""
    x = jnp.asarray(rng.randn(8).astype(np.float32))
    out = R.reduce(x, axis=(), backend="mma_jnp")
    assert out.shape == x.shape
    np.testing.assert_allclose(np.asarray(out), np.asarray(x), rtol=1e-6)
    np.testing.assert_allclose(
        np.asarray(R.reduce(x, axis=(), kind="sumsq")),
        np.asarray(x) ** 2,
        rtol=1e-6,
    )


def test_forward_mode_autodiff_on_native_backends(rng):
    """jvp/jacfwd/hessian must flow through the jnp-level backends, exactly
    as they did through the pre-engine jnp.sum / row_sum_mma call sites."""
    x = jnp.asarray(rng.randn(256).astype(np.float32))
    t = jnp.ones_like(x)
    for b in ("xla", "mma_jnp"):
        _, dy = jax.jvp(lambda v: R.reduce(v, backend=b), (x,), (t,))
        np.testing.assert_allclose(float(dy), x.size, rtol=1e-2)
        _, dy = jax.jvp(
            lambda v: R.reduce(v, axis=-1, backend=b), (x.reshape(8, 32),),
            (t.reshape(8, 32),),
        )
        np.testing.assert_allclose(np.asarray(dy), 32.0, rtol=1e-2)
    h = jax.hessian(lambda v: R.reduce(v, kind="sumsq", backend="xla"))(x[:8])
    np.testing.assert_allclose(np.asarray(h), 2 * np.eye(8), atol=1e-5)


def test_moments_axis_is_one_fused_dot():
    """Both moments must ride a single stacked all-ones dot (one MXU pass),
    like the row_moments_mma path this replaced."""
    x = jnp.ones((4, 300), jnp.float32)
    jaxpr = jax.make_jaxpr(
        lambda v: R.reduce(v, axis=-1, kind="moments", backend="mma_jnp")
    )(x)
    ndots = sum(
        1 for eqn in jaxpr.jaxpr.eqns if eqn.primitive.name == "dot_general"
    )
    assert ndots == 1, jaxpr


def test_pallas_row_reductions_use_batched_dot_not_kernel_loop():
    """A process-wide Pallas override must not serialize row reductions into
    per-row kernel launches: rows always take the eq. (9) batched dot."""
    x = jnp.ones((16, 128), jnp.float32)
    jaxpr = jax.make_jaxpr(
        lambda v: R.reduce(v, axis=-1, backend="pallas_fused")
    )(x)
    prims = {eqn.primitive.name for eqn in jaxpr.jaxpr.eqns}
    assert "dot_general" in prims
    assert not any("scan" in p or "while" in p for p in prims), prims


@pytest.mark.parametrize("backend", BACKENDS)
def test_zero_size_inputs(backend):
    assert float(R.reduce(jnp.zeros((0,)), backend=backend)) == 0.0
    assert R.reduce(jnp.zeros((4, 0)), axis=-1, backend=backend).shape == (4,)
    assert R.reduce(jnp.zeros((0, 4)), axis=-1, backend=backend).shape == (0,)


# ------------------------------ plan control ---------------------------------


def test_plan_overrides_respected(rng):
    x = jnp.asarray(rng.randn(10_000).astype(np.float32))
    want = np.asarray(x).astype(np.float64).sum()
    for m in (4, 16, 128):
        got = float(R.reduce(x, backend="mma_jnp", m=m, compute_dtype="float32"))
        np.testing.assert_allclose(got, want, rtol=1e-5)
    # an explicit plan object is honoured verbatim and replace() adjusts it
    plan = R.plan_for(x.shape, x.dtype, backend="pallas_fused", tiles_per_block=2)
    assert plan.backend == "pallas_fused" and plan.tiles_per_block == 2
    got = float(R.reduce(x, plan=plan))
    np.testing.assert_allclose(got, want, atol=_tol(x))
    got32 = float(R.reduce(x, plan=plan, compute_dtype="float32", backend="mma_jnp"))
    np.testing.assert_allclose(got32, want, rtol=1e-5)


def test_plan_rejects_bad_fields():
    with pytest.raises(ValueError, match="m must be >= 2"):
        R.ReducePlan(m=1)
    with pytest.raises(ValueError, match="precision"):
        R.ReducePlan(precision="exactly")
    with pytest.raises(ValueError, match="num_cores"):
        R.ReducePlan(num_cores=0)


def test_plan_num_cores_resolution(rng):
    """Off-TPU (this container) the planner's lane default is 1 -- interpret
    mode runs lanes sequentially; pinning the knob must stick on both the
    planner and the public reduce() override path."""
    assert R.plan_for((100_000,), jnp.float32).num_cores == 1
    p = R.plan_for((100_000,), jnp.float32, backend="pallas_fused", num_cores=4)
    assert p.num_cores == 4
    # replace() path: a pinned plan adjusted per call
    x = jnp.asarray(rng.randn(70_000).astype(np.float32))
    want = np.asarray(x).astype(np.float64).sum()
    got = float(R.reduce(x, plan=p.replace(num_cores=2)))
    np.testing.assert_allclose(got, want, atol=_tol(x))
    got = float(R.reduce(x, backend="pallas_fused", num_cores=3))
    np.testing.assert_allclose(got, want, atol=_tol(x))


def test_autotune_sweeps_num_cores():
    """autotune's tuned winner carries its lane count back through auto
    plan_for (the knob is swept alongside tiles_per_block)."""
    R.plan_cache_clear(clear_tuned=True)
    try:
        best = R.autotune(
            (40_000,), jnp.float32, backends=("pallas_fused",),
            tiles_per_block_candidates=(2,), num_cores_candidates=(2,),
            repeats=1,
        )
        assert best.backend == "pallas_fused" and best.num_cores == 2
        tuned = R.plan_for((40_000,), jnp.float32, backend="auto")
        assert tuned.num_cores == 2
        # explicit overrides still beat the tuned entry
        pinned = R.plan_for((40_000,), jnp.float32, backend="auto", num_cores=1)
        assert pinned.num_cores == 1
    finally:
        R.plan_cache_clear(clear_tuned=True)


def test_planner_heuristics():
    # integers take the exact path
    assert R.plan_for((1000,), jnp.int32, backend="auto").backend == "xla"
    # batched row reductions take the eq. (9) single-dot path
    assert (
        R.plan_for((32, 4096), jnp.float32, axis=(1,), backend="auto").backend
        == "mma_jnp"
    )
    # tiny full reductions are not worth any MMA plumbing
    assert R.plan_for((8,), jnp.float32, backend="auto").backend == "xla"
    # exact-sensitive kinds multiply at f32
    assert (
        R.plan_for((4096,), jnp.float32, kind="norm2").compute_dtype
        == "float32"
    )
    assert R.plan_for((4096,), jnp.float32).compute_dtype == "bfloat16"


def test_default_backend_resolution(monkeypatch):
    monkeypatch.delenv(R.BACKEND_ENV, raising=False)
    R.set_default_backend(None)
    assert R.default_backend() == "auto"
    monkeypatch.setenv(R.BACKEND_ENV, "xla")
    assert R.default_backend() == "xla"
    assert R.backend_for_flags(True) == "xla"  # env overrides legacy flags
    R.set_default_backend("pallas_hier")
    assert R.default_backend() == "pallas_hier"
    assert R.backend_for_flags(False) == "pallas_hier"
    R.set_default_backend(None)
    monkeypatch.delenv(R.BACKEND_ENV)
    assert R.backend_for_flags(True) == "mma_jnp"
    assert R.backend_for_flags(True, use_pallas=True) == "pallas_fused"
    assert R.backend_for_flags(False) == "xla"


def test_custom_backend_registration(rng):
    class Doubling(R.Backend):
        name = "doubling"

        def sum_all(self, x, plan):
            return 2.0 * jnp.sum(x.astype(plan.accum_jnp))

        def sum_axis(self, x, plan):
            return 2.0 * jnp.sum(x.astype(plan.accum_jnp), -1)

    try:
        R.register_backend(Doubling())
        x = jnp.ones(10)
        assert float(R.reduce(x, backend="doubling")) == 20.0
        # PRE-PROLOGUE compatibility: a legacy subclass whose sum_all has no
        # prologue parameter keeps serving every kind -- the engine degrades
        # to the host-side map it always used (regression: the in-kernel
        # prologue rewire must not break third-party backends).
        assert float(R.reduce(x, kind="sumsq", backend="doubling")) == 20.0
        s, ss = R.reduce(x, kind="moments", backend="doubling")
        assert float(s) == 20.0 and float(ss) == 20.0
        np.testing.assert_allclose(
            float(R.reduce(x, kind="norm2", backend="doubling")),
            np.sqrt(20.0), rtol=1e-6,
        )
    finally:
        from repro.reduce import backends as B

        B._REGISTRY.pop("doubling", None)


# ------------------------------ precision policy -----------------------------


def test_kahan_policy_is_orthogonal_to_backend():
    """An adversarial combine (one 2^25-mass block, seven 1.0-mass blocks)
    loses the small partials in a naive f32 accumulation; the compensated
    combine must recover them on every backend."""
    block = R.ReducePlan().kahan_block
    x = np.empty(8 * block, np.float32)
    x[:block] = 8192.0      # block sum 2^25
    x[block:] = 2.0**-12    # each remaining block sums to exactly 1.0
    xj = jnp.asarray(x)
    exact = x.astype(np.float64).sum()
    for backend in BACKENDS:
        e_native = abs(
            float(R.reduce(xj, backend=backend, compute_dtype="float32"))
            - exact
        )
        e_kahan = abs(
            float(
                R.reduce(
                    xj,
                    backend=backend,
                    compute_dtype="float32",
                    precision="kahan",
                )
            )
            - exact
        )
        assert e_kahan < e_native, backend
        assert e_kahan <= 1.0, backend  # only the final f32 rounding remains


# ------------------------------ pytree reductions ----------------------------


@pytest.mark.parametrize("backend", BACKENDS)
def test_reduce_tree_matches_oracle(backend, rng):
    tree = {
        "w": jnp.asarray(rng.randn(37, 129).astype(np.float32)),
        "b": [
            jnp.asarray(rng.randn(1000).astype(np.float32)),
            jnp.asarray(np.float32(rng.randn())),  # scalar leaf
        ],
    }
    leaves = [np.asarray(v).astype(np.float64) for v in jax.tree.leaves(tree)]
    want_sq = sum((v**2).sum() for v in leaves)
    want_sum = sum(v.sum() for v in leaves)
    np.testing.assert_allclose(
        float(R.reduce_tree(tree, "sumsq", backend=backend)), want_sq, rtol=1e-5
    )
    np.testing.assert_allclose(
        float(R.reduce_tree(tree, "norm2", backend=backend)),
        np.sqrt(want_sq),
        rtol=1e-5,
    )
    np.testing.assert_allclose(
        float(R.reduce_tree(tree, "sum", backend=backend)), want_sum, rtol=1e-4
    )
    assert float(R.reduce_tree({}, "sumsq", backend=backend)) == 0.0


def test_reduce_tree_is_differentiable(rng):
    tree = {"a": jnp.asarray(rng.randn(64).astype(np.float32))}
    g = jax.grad(lambda t: R.reduce_tree(t, "sumsq", backend="mma_jnp"))(tree)
    np.testing.assert_allclose(
        np.asarray(g["a"]), 2 * np.asarray(tree["a"]), rtol=1e-5
    )


# ------------------------------ segmented multi-reduce -----------------------


# Adversarial segment layouts: empty segment list handled separately; here:
# single-element segments, exact-tile and non-tile-multiple sizes, empty
# segments in the middle, a > m^2 segment, mixed ranks.
SEG_SHAPES = [(1,), (127,), (), (128 * 128,), (0,), (40, 33), (16390,), (3, 1, 5)]


def _seg_arrays(rng, dtype=np.float32):
    return [
        jnp.asarray(np.asarray(rng.randn(*s), np.float64).astype(dtype))
        for s in SEG_SHAPES
    ]


@pytest.mark.parametrize("backend", SEG_BACKENDS)
@pytest.mark.parametrize("kind", R.KINDS)
@pytest.mark.parametrize("dtype", [np.float32, jnp.bfloat16])
def test_reduce_many_matches_per_array_oracle(backend, kind, dtype, rng):
    """reduce_many == [reduce(a) for a] on the xla oracle, every backend x
    kind x dtype, across single-element / empty / ragged / huge segments."""
    arrs = _seg_arrays(rng, dtype)
    got = R.reduce_many(arrs, kind=kind, backend=backend)
    # reduce_many defines the mean of an empty segment as 0 (the oracle's
    # 0/0 is nan); everything else must match the per-array engine calls.
    want = [
        jnp.zeros(()) if kind == "mean" and a.size == 0
        else R.reduce(a, kind=kind, backend="xla")
        for a in arrs
    ]
    tol = max(_tol(a) for a in arrs)
    if kind == "moments":
        gs, gss = got
        np.testing.assert_allclose(
            np.asarray(gs, np.float64), [float(w[0]) for w in want], atol=tol
        )
        np.testing.assert_allclose(
            np.asarray(gss, np.float64), [float(w[1]) for w in want], atol=tol
        )
        return
    assert got.shape == (len(arrs),)
    np.testing.assert_allclose(
        np.asarray(got, np.float64), [float(w) for w in want],
        atol=tol, rtol=2e-2,
    )


@pytest.mark.parametrize("backend", SEG_BACKENDS)
def test_reduce_many_empty_segment_list(backend):
    out = R.reduce_many([], backend=backend)
    assert out.shape == (0,)
    s, ss = R.reduce_many([], kind="moments", backend=backend)
    assert s.shape == (0,) and ss.shape == (0,)
    assert R.reduce_many([], axis=-1, backend=backend) == []


@pytest.mark.parametrize("backend", SEG_BACKENDS)
def test_reduce_many_int_segments_exact(backend, rng):
    arrs = [jnp.asarray(rng.randint(-9, 9, size=s), jnp.int32) for s in [(3,), (400,)]]
    got = R.reduce_many(arrs, backend=backend)
    want = [int(np.asarray(a).sum()) for a in arrs]
    np.testing.assert_array_equal(np.asarray(got, np.int64), want)


@pytest.mark.parametrize("backend", SEG_BACKENDS)
def test_reduce_many_grads_match_per_array_reduce(backend, rng):
    """Per-segment cotangents: d(sum_s w_s * out_s)/dx must equal the
    per-array reduce gradients on every backend and kind."""
    arrs = [
        jnp.asarray((rng.rand(*s) + 0.5).astype(np.float32))
        for s in [(5,), (300,), (4, 33)]
    ]
    w = jnp.asarray([1.0, -2.0, 0.5])
    for kind in ("sum", "mean", "sumsq", "norm2"):
        g_many = jax.grad(
            lambda a: jnp.sum(R.reduce_many(a, kind=kind, backend=backend) * w)
        )(arrs)
        g_loop = jax.grad(
            lambda a: sum(
                wi * R.reduce(ai, kind=kind, backend="xla")
                for wi, ai in zip(w, a)
            )
        )(arrs)
        for gm, gl in zip(g_many, g_loop):
            np.testing.assert_allclose(
                np.asarray(gm), np.asarray(gl), rtol=2e-3, atol=1e-5
            )


@pytest.mark.parametrize("backend", SEG_BACKENDS)
def test_reduce_many_rows_ragged_widths(backend, rng):
    """axis=-1: per-array row reductions with differing widths ride one
    width-padded pass and match the per-array oracle."""
    arrs = [
        jnp.asarray(rng.randn(4, 300).astype(np.float32)),
        jnp.asarray(rng.randn(2, 3, 70).astype(np.float32)),
        jnp.asarray(rng.randn(5).astype(np.float32)),
    ]
    for kind in ("sum", "mean", "sumsq", "norm2"):
        outs = R.reduce_many(arrs, kind=kind, axis=-1, backend=backend)
        for o, a in zip(outs, arrs):
            want = R.reduce(a, kind=kind, axis=-1, backend="xla")
            assert o.shape == want.shape
            np.testing.assert_allclose(
                np.asarray(o, np.float64), np.asarray(want, np.float64),
                atol=_tol(a), rtol=2e-2,
            )
    s_l, ss_l = R.reduce_many(arrs, kind="moments", axis=-1, backend=backend)
    for s_, ss_, a in zip(s_l, ss_l, arrs):
        ws, wss = R.reduce(a, kind="moments", axis=-1, backend="xla")
        np.testing.assert_allclose(np.asarray(s_), np.asarray(ws), atol=_tol(a))
        np.testing.assert_allclose(np.asarray(ss_), np.asarray(wss), atol=_tol(a))


@pytest.mark.parametrize("backend", SEG_BACKENDS)
def test_reduce_many_rows_zero_size_leaves(backend, rng):
    """Regression: a zero-width or zero-batch leaf mixed with live leaves
    must come back as the identity, not crash the packing."""
    arrs = [
        jnp.zeros((5, 0), jnp.float32),
        jnp.asarray(rng.randn(3, 4).astype(np.float32)),
        jnp.zeros((0, 7), jnp.float32),
    ]
    outs = R.reduce_many(arrs, kind="sum", axis=-1, backend=backend)
    assert outs[0].shape == (5,) and not outs[0].any()
    assert outs[2].shape == (0,)
    np.testing.assert_allclose(
        np.asarray(outs[1]), np.asarray(arrs[1], np.float64).sum(-1),
        atol=1e-2,
    )


def test_reduce_many_rows_gradient(rng):
    arrs = [
        jnp.asarray(rng.randn(4, 30).astype(np.float32)),
        jnp.asarray(rng.randn(2, 50).astype(np.float32)),
    ]

    def f(a):
        outs = R.reduce_many(a, kind="sumsq", axis=-1, backend="mma_jnp",
                             compute_dtype="float32")
        return sum(jnp.sum(o) for o in outs)

    g = jax.grad(f)(arrs)
    for gi, ai in zip(g, arrs):
        np.testing.assert_allclose(
            np.asarray(gi), 2 * np.asarray(ai), rtol=1e-4, atol=1e-5
        )


def test_reduce_many_rejects_bad_args(rng):
    with pytest.raises(ValueError, match="unknown kind"):
        R.reduce_many([jnp.ones(3)], kind="max")
    with pytest.raises(ValueError, match="axis"):
        R.reduce_many([jnp.ones(3)], axis=0)
    with pytest.raises(ValueError, match="ndim >= 1"):
        R.reduce_many([jnp.asarray(1.0)], axis=-1)


@pytest.mark.parametrize("backend", ("mma_jnp", "pallas_fused", "segmented"))
def test_reduce_many_jit_and_pytree_input(backend, rng):
    """reduce_many accepts an arbitrary pytree and works under jit."""
    tree = {
        "a": jnp.asarray(rng.randn(129).astype(np.float32)),
        "b": (jnp.asarray(rng.randn(2, 40).astype(np.float32)),),
    }
    got = jax.jit(lambda t: R.reduce_many(t, backend=backend))(tree)
    want = [np.asarray(v, np.float64).sum() for v in jax.tree.leaves(tree)]
    np.testing.assert_allclose(np.asarray(got, np.float64), want, atol=1e-2)


def test_global_norm_is_single_pallas_launch():
    """Acceptance: one jitted AdamW global_norm over a multi-leaf pytree on
    the Pallas backends lowers to a SINGLE pallas_call -- the per-leaf work
    is eq. (9) dots; only the packed segmented pass hits the kernel. The
    striped grid must preserve the property at every lane count: the lanes
    live INSIDE the one launch, never one launch per lane."""
    from repro.optim import adamw

    tree = {
        "w": jnp.ones((4, 256)),
        "b": [jnp.ones((300,)), jnp.ones(())],
        "e": jnp.ones((2, 3, 64)),
    }
    for backend in ("pallas_fused", "pallas_hier"):
        for num_cores in (None, 1, 2, 4):
            jaxpr = jax.make_jaxpr(
                lambda g: R.reduce_tree(
                    g, "norm2", backend=backend, num_cores=num_cores
                )
            )(tree)
            assert str(jaxpr).count("pallas_call") == 1, (backend, num_cores)
        lowered = jax.jit(
            lambda g: adamw.global_norm(g, backend=backend)
        ).lower(tree).as_text()
        assert lowered  # lowering succeeds end-to-end
    # and the statistic itself is right, at any lane count
    want = np.sqrt(4 * 256 + 300 + 1 + 2 * 3 * 64)
    got = float(jax.jit(
        lambda g: adamw.global_norm(g, backend="pallas_fused")
    )(tree))
    np.testing.assert_allclose(got, want, rtol=1e-4)
    got2 = float(jax.jit(
        lambda g: R.reduce_tree(g, "norm2", backend="pallas_fused", num_cores=2)
    )(tree))
    np.testing.assert_allclose(got2, want, rtol=1e-4)


@pytest.mark.parametrize("backend", BACKENDS)
def test_reduce_tree_mixed_shape_pytree(backend, rng):
    """Mixed-rank / zero-size / scalar leaves through the segmented path."""
    tree = {
        "w": jnp.asarray(rng.randn(37, 129).astype(np.float32)),
        "z": jnp.zeros((0, 7), jnp.float32),
        "s": jnp.asarray(np.float32(rng.randn())),
        "t3": jnp.asarray(rng.randn(2, 3, 40).astype(np.float32)),
    }
    leaves = [np.asarray(v, np.float64) for v in jax.tree.leaves(tree)]
    want = sum((v**2).sum() for v in leaves)
    np.testing.assert_allclose(
        float(R.reduce_tree(tree, "sumsq", backend=backend)), want, rtol=1e-4
    )


def test_segmented_backend_route_and_registration():
    """The planner marks multi-reduce problems for the registered
    "segmented" auto-route; the route resolves a concrete executor."""
    assert "segmented" in R.available_backends()
    plan = R.plan_for((100_000,), jnp.float32, segments=16, backend="auto")
    assert plan.backend == "segmented"
    # non-segmented problems never route there
    assert R.plan_for((100_000,), jnp.float32).backend != "segmented"
    # concrete resolution: ints -> xla; floats off-TPU -> mma_jnp
    assert R.segmented_backend_for(1000, jnp.int32, 128) == "xla"
    assert R.segmented_backend_for(100_000, jnp.float32, 128) in (
        "mma_jnp", "pallas_fused"
    )


# ------------------------------ plan cache + autotune -------------------------


def test_plan_for_is_memoized():
    """Same args -> the SAME plan object, served from cache (no recompute)."""
    R.plan_cache_clear()
    args = dict(kind="sumsq", axis=(1,), tiles_per_block=4)
    p1 = R.plan_for((64, 4096), jnp.float32, **args)
    before = R.plan_cache_info()
    p2 = R.plan_for((64, 4096), jnp.float32, **args)
    after = R.plan_cache_info()
    assert p1 is p2
    assert after.hits == before.hits + 1 and after.misses == before.misses
    # a changed process default must MISS, never serve the stale auto plan
    try:
        R.set_default_backend("xla")
        assert R.plan_for((64, 4096), jnp.float32, **args).backend == "xla"
    finally:
        R.set_default_backend(None)


def test_plan_for_forwards_kahan_block():
    """Regression: plan_for used to drop the kahan_block knob entirely."""
    assert R.plan_for((100,), jnp.float32, kahan_block=512).kahan_block == 512
    assert R.plan_for((100,), jnp.float32).kahan_block == 4096
    with pytest.raises(ValueError, match="kahan_block"):
        R.ReducePlan(kahan_block=0)
    # and the public reduce() override reaches the compensated combine
    x = jnp.ones(2048, jnp.float32)
    got = float(
        R.reduce(x, backend="mma_jnp", precision="kahan", kahan_block=256)
    )
    np.testing.assert_allclose(got, 2048.0, rtol=1e-6)


def test_autotune_axis_key_matches_reduce_normalization():
    """Regression: autotune(axis=-1) winners must land on the same cache key
    reduce()'s normalized (non-negative) axis looks up."""
    R.plan_cache_clear(clear_tuned=True)
    try:
        best = R.autotune(
            (8, 64), jnp.float32, kind="sumsq", axis=-1,
            backends=("xla",), repeats=1,
        )
        assert best.backend == "xla"
        for ax in (-1, (1,), 1):
            assert R.plan_for(
                (8, 64), jnp.float32, kind="sumsq", axis=ax, backend="auto"
            ).backend == "xla", ax
    finally:
        R.plan_cache_clear(clear_tuned=True)


def test_autotune_feeds_plan_cache(rng):
    """Opt-in autotune records its winner; later auto plan_for returns it."""
    shape, dt = (4096,), jnp.float32
    R.plan_cache_clear(clear_tuned=True)
    try:
        best = R.autotune(
            shape, dt, backends=("xla", "mma_jnp"), repeats=1
        )
        assert best.backend in ("xla", "mma_jnp")
        tuned = R.plan_for(shape, dt, backend="auto")
        assert tuned is best or tuned == best
        # explicit overrides still beat the tuned entry
        pinned = R.plan_for(shape, dt, backend="pallas_fused")
        assert pinned.backend == "pallas_fused"
    finally:
        R.plan_cache_clear(clear_tuned=True)


# ------------------------------ jit + legacy shims ---------------------------


@pytest.mark.parametrize("backend", BACKENDS)
def test_reduce_is_jittable(backend, rng):
    x = jnp.asarray(rng.randn(3000).astype(np.float32))
    got = float(jax.jit(lambda y: R.reduce(y, backend=backend))(x))
    np.testing.assert_allclose(got, np.asarray(x).sum(), atol=_tol(x))


def test_legacy_core_names_warn_and_delegate(rng):
    import repro.core as C

    x = jnp.asarray(rng.randn(500).astype(np.float32))
    with pytest.deprecated_call():
        legacy = float(C.mma_sum(x, compute_dtype=jnp.float32))
    np.testing.assert_allclose(
        legacy,
        float(R.reduce(x, backend="mma_jnp", compute_dtype="float32")),
        rtol=1e-6,
    )
    with pytest.deprecated_call():
        legacy_norm = float(C.global_norm_sq_mma({"a": x}))
    np.testing.assert_allclose(
        legacy_norm,
        float(R.reduce_tree({"a": x}, "sumsq", backend="mma_jnp")),
        rtol=1e-6,
    )
    assert C.reduce is R  # repro.core re-exports the engine
