"""Shared fixtures. NOTE: no XLA_FLAGS here by design -- tests run on the
single CPU device; only launch/dryrun.py forces 512 placeholder devices."""

import numpy as np
import pytest


@pytest.fixture
def rng():
    return np.random.RandomState(0)
