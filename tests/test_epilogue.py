"""In-launch epilogues: the post-combine scalar chains (sqrt / scale /
clip_coeff / rsqrt / add_eps) applied to a reduced result inside the same
pallas_call. Every backend must agree with the host-side ``apply_epilogue``
reference, the empty chain must be the pre-epilogue code path bit-for-bit,
the custom VJPs must match the xla oracle's gradients, and the kernel paths
must keep the one-launch / zero-host-eqn / zero-extra-bytes properties the
cost model claims."""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro import reduce as R
from repro.kernels import common as kcommon
from repro.reduce import backends as B
from repro.reduce import inspect as I

BACKENDS = ("xla", "mma_jnp", "pallas_hier", "pallas_fused")
KERNEL_BACKENDS = ("pallas_hier", "pallas_fused")
CLIP = ("clip_coeff", 1.0, 1e-9)


def _tree(rng):
    return {
        "a": jnp.asarray(rng.randn(300).astype(np.float32)),
        "b": jnp.asarray(rng.randn(5, 1).astype(np.float32)),
        "c": jnp.asarray(rng.randn(7, 100).astype(np.float32)),
    }


# ---------------------------------------------------------------------------
# chain normalization and evaluation
# ---------------------------------------------------------------------------


def test_normalize_epilogue_forms():
    assert kcommon.normalize_epilogue(None) == ()
    assert kcommon.normalize_epilogue("identity") == ()
    assert kcommon.normalize_epilogue(()) == ()
    assert kcommon.normalize_epilogue("sqrt") == (("sqrt",),)
    assert kcommon.normalize_epilogue(("scale", 2)) == (("scale", 2.0),)
    assert kcommon.normalize_epilogue((("sqrt",), ("scale", 3))) == (
        ("sqrt",),
        ("scale", 3.0),
    )
    # identity steps are stripped out of chains
    assert kcommon.normalize_epilogue((("identity",), ("sqrt",))) == (
        ("sqrt",),
    )
    # a fork is a LIST of chains; anything else is a single chain
    assert kcommon.normalize_epilogue_fork([(), "sqrt"]) == ((), (("sqrt",),))
    assert kcommon.normalize_epilogue_fork("sqrt") == ((("sqrt",),),)


def test_normalize_epilogue_rejects():
    with pytest.raises(ValueError, match="unknown epilogue"):
        kcommon.normalize_epilogue("exp")
    with pytest.raises(ValueError, match="parameter"):
        kcommon.normalize_epilogue(("sqrt", 1.0))
    with pytest.raises(ValueError, match="parameter"):
        kcommon.normalize_epilogue(("scale",))
    with pytest.raises(ValueError, match="at least one chain"):
        kcommon.normalize_epilogue_fork([])


def test_apply_epilogue_reference_values():
    t = jnp.asarray(4.0, jnp.float32)
    assert float(kcommon.apply_epilogue(t, (("sqrt",),))) == 2.0
    assert float(kcommon.apply_epilogue(t, (("scale", 0.5),))) == 2.0
    assert float(kcommon.apply_epilogue(t, (("rsqrt",),))) == 0.5
    assert float(kcommon.apply_epilogue(t, (("add_eps", 1.0),))) == 5.0
    assert float(
        kcommon.apply_epilogue(t, (("clip_coeff", 2.0, 1e-9),))
    ) == 0.5
    # chains compose left to right: sqrt then clip sees the NORM
    assert float(
        kcommon.apply_epilogue(t, (("sqrt",), ("clip_coeff", 1.0, 1e-9)))
    ) == 0.5


# ---------------------------------------------------------------------------
# reduce(): values, folding, bit-identity, gradients
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("backend", BACKENDS + ("segmented",))
def test_reduce_epilogue_matches_host_reference(backend, rng):
    x = jnp.asarray(rng.randn(4000).astype(np.float32))
    plain = R.reduce(x, kind="norm2", backend=backend,
                     compute_dtype="float32")
    got = R.reduce(x, kind="norm2", backend=backend,
                   compute_dtype="float32", epilogue=CLIP)
    ref = kcommon.apply_epilogue(plain, (CLIP,))
    np.testing.assert_allclose(float(got), float(ref), rtol=1e-6)
    # sum + scale
    got = R.reduce(x, backend=backend, compute_dtype="float32",
                   epilogue=("scale", 3.0))
    ref = 3.0 * R.reduce(x, backend=backend, compute_dtype="float32")
    np.testing.assert_allclose(float(got), float(ref), rtol=1e-6)


@pytest.mark.parametrize("backend", BACKENDS)
def test_reduce_mean_folds_into_chain(backend, rng):
    x = jnp.asarray(rng.randn(2048).astype(np.float32))
    got = R.reduce(x, kind="mean", backend=backend,
                   compute_dtype="float32", epilogue=("scale", 2.0))
    ref = 2.0 * R.reduce(x, kind="mean", backend=backend,
                         compute_dtype="float32")
    np.testing.assert_allclose(float(got), float(ref), rtol=1e-6)


@pytest.mark.parametrize("backend", KERNEL_BACKENDS)
@pytest.mark.parametrize("num_cores", (1, 2, 4))
def test_identity_epilogue_is_bitwise_prior_path(backend, num_cores, rng):
    """epilogue='identity' is the empty chain: the PR-5 code path
    byte-for-byte, at every lane count."""
    x = jnp.asarray(rng.randn(5000).astype(np.float32))
    a = np.asarray(R.reduce(x, kind="norm2", backend=backend,
                            num_cores=num_cores))
    b = np.asarray(R.reduce(x, kind="norm2", backend=backend,
                            num_cores=num_cores, epilogue="identity"))
    assert a.tobytes() == b.tobytes()


@pytest.mark.parametrize("backend", BACKENDS)
def test_reduce_epilogue_grad_matches_oracle(backend, rng):
    x = jnp.asarray(rng.randn(3000).astype(np.float32))

    def f(b):
        return lambda v: R.reduce(v, kind="norm2", backend=b,
                                  compute_dtype="float32", epilogue=CLIP)

    gref = jax.grad(f("xla"))(x)
    g = jax.grad(f(backend))(x)
    np.testing.assert_allclose(np.asarray(g), np.asarray(gref),
                               rtol=1e-5, atol=1e-8)


def test_reduce_epilogue_rejects_axis_and_moments(rng):
    x = jnp.asarray(rng.randn(8, 16).astype(np.float32))
    with pytest.raises(ValueError, match="FULL reduction"):
        R.reduce(x, axis=-1, epilogue="sqrt")
    with pytest.raises(ValueError, match="moments"):
        R.reduce(x, kind="moments", epilogue="sqrt")


# ---------------------------------------------------------------------------
# reduce_many(): per-slot chains
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("backend", BACKENDS + ("segmented",))
def test_reduce_many_epilogue_maps_every_slot(backend, rng):
    arrs = [jnp.asarray(rng.randn(s).astype(np.float32))
            for s in (130, 5, 700)]
    got = np.asarray(R.reduce_many(arrs, kind="norm2", backend=backend,
                                   compute_dtype="float32",
                                   epilogue=("scale", 3.0)))
    ref = 3.0 * np.asarray(R.reduce_many(arrs, kind="norm2", backend=backend,
                                         compute_dtype="float32"))
    np.testing.assert_allclose(got, ref, rtol=1e-6)


def test_reduce_many_epilogue_rejects_mean_and_axis(rng):
    arrs = [jnp.asarray(rng.randn(8, 4).astype(np.float32))]
    with pytest.raises(ValueError, match="mean"):
        R.reduce_many(arrs, kind="mean", epilogue="sqrt")
    with pytest.raises(ValueError, match="axis"):
        R.reduce_many(arrs, kind="sum", axis=-1, epilogue="sqrt")


# ---------------------------------------------------------------------------
# reduce_tree(): the fork, per-leaf slots, one launch, zero extra bytes
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("backend", BACKENDS + ("segmented",))
@pytest.mark.parametrize("num_cores", (1, 2, 4))
def test_reduce_tree_fork_values(backend, num_cores, rng):
    tree = _tree(rng)
    leaves = [np.asarray(x, np.float64) for x in jax.tree.leaves(tree)]
    tot = sum(float((v * v).sum()) for v in leaves)
    gnorm = np.sqrt(tot)
    per, out = R.reduce_tree(
        tree, kind="norm2", backend=backend, num_cores=num_cores,
        epilogue=[(), CLIP], return_per_leaf=True,
    )
    per, out = np.asarray(per), np.asarray(out)
    assert per.shape == (3,) and out.shape == (2,)
    np.testing.assert_allclose(
        per, [float((v * v).sum()) for v in leaves], rtol=1e-5
    )
    np.testing.assert_allclose(out[0], gnorm, rtol=1e-6)
    np.testing.assert_allclose(out[1], min(1.0, 1.0 / gnorm), rtol=1e-6)
    # a single chain returns a scalar
    clip = R.reduce_tree(tree, kind="norm2", backend=backend,
                         num_cores=num_cores, epilogue=CLIP)
    assert jnp.ndim(clip) == 0
    np.testing.assert_allclose(float(clip), out[1], rtol=1e-7)


@pytest.mark.parametrize("backend", KERNEL_BACKENDS)
@pytest.mark.parametrize("num_cores", (1, 2, 4))
def test_reduce_tree_identity_epilogue_bitwise(backend, num_cores, rng):
    tree = _tree(rng)
    a = np.asarray(R.reduce_tree(tree, kind="norm2", backend=backend,
                                 num_cores=num_cores))
    b = np.asarray(R.reduce_tree(tree, kind="norm2", backend=backend,
                                 num_cores=num_cores, epilogue="identity"))
    assert a.tobytes() == b.tobytes()


@pytest.mark.parametrize("backend", KERNEL_BACKENDS)
@pytest.mark.parametrize("num_cores", (1, 2, 4))
def test_fork_clip_bitwise_equals_two_launch_reference(backend, num_cores,
                                                       rng):
    """The in-launch clip coefficient is BITWISE the host-side
    sqrt+minimum reference at f32 compute: the kernel's chain runs the
    same jnp scalar ops on the same f32 total."""
    tree = _tree(rng)
    out = np.asarray(R.reduce_tree(tree, kind="norm2", backend=backend,
                                   num_cores=num_cores,
                                   epilogue=[(), CLIP]))
    gnorm = R.reduce_tree(tree, kind="norm2", backend=backend,
                          num_cores=num_cores)
    ref_clip = jnp.minimum(1.0, 1.0 / jnp.maximum(gnorm, 1e-9))
    assert out[:1].tobytes() == np.asarray(gnorm).reshape(1).tobytes()
    assert out[1:].tobytes() == np.asarray(ref_clip).reshape(1).tobytes()


@pytest.mark.parametrize("backend", KERNEL_BACKENDS)
def test_fork_is_one_launch_and_epilogue_free(backend, rng):
    tree = _tree(rng)

    def stat(t):
        return R.reduce_tree(t, kind="norm2", backend=backend,
                             epilogue=[(), CLIP])

    assert I.count_pallas_calls(stat, tree) == 1
    I.assert_epilogue_free(stat, tree)


def test_assert_epilogue_free_catches_host_chain(rng):
    tree = _tree(rng)

    def host_stat(t):
        n = R.reduce_tree(t, kind="norm2", backend="pallas_fused")
        return jnp.minimum(1.0, 1.0 / jnp.maximum(n, 1e-9))

    with pytest.raises(AssertionError, match="epilogue contract"):
        I.assert_epilogue_free(host_stat, tree)


def test_fork_adds_zero_input_bytes_modeled_and_measured(rng):
    """The chains cost NO extra reads: modeled launch_io (segments + K
    output slots) equals the lowered pallas_call boundary bytes exactly."""
    tree = _tree(rng)
    leaves = jax.tree.leaves(tree)
    n = sum(int(v.size) for v in leaves)
    plan = R.plan_for((n,), "float32", backend="pallas_fused",
                      compute_dtype="float32",
                      segments=len(leaves)).replace(backend="pallas_fused")

    def stat(t):
        return R.reduce_tree(t, kind="norm2", backend="pallas_fused",
                             epilogue=[(), CLIP])

    modeled = plan.hbm_bytes(n, "float32", segments=len(leaves),
                             prologue="square", epilogue=2)
    measured = I.pallas_io_bytes(jax.make_jaxpr(stat)(tree))
    assert modeled.launch_io == measured
    # vs the chain-free launch: exactly K * 4 more output bytes, 0 more in
    base = plan.hbm_bytes(n, "float32", segments=len(leaves),
                          prologue="square")
    assert modeled.kernel_read == base.kernel_read
    assert modeled.kernel_write == base.kernel_write + 2 * 4


@pytest.mark.parametrize("backend", BACKENDS)
def test_reduce_tree_fork_grad_matches_oracle(backend, rng):
    tree = _tree(rng)

    def f(b):
        def g(t):
            per, out = R.reduce_tree(t, kind="norm2", backend=b,
                                     epilogue=[(), CLIP],
                                     return_per_leaf=True)
            return out[0] + 2.0 * out[1] + jnp.sum(per)
        return g

    gref = jax.grad(f("xla"))(tree)
    got = jax.grad(f(backend))(tree)
    for k in tree:
        np.testing.assert_allclose(np.asarray(got[k]), np.asarray(gref[k]),
                                   rtol=1e-5, atol=1e-7)


def test_reduce_tree_empty_tree_fork(rng):
    per, out = R.reduce_tree({}, kind="norm2", backend="xla",
                             epilogue=[(), CLIP], return_per_leaf=True)
    assert per.shape == (0,)
    assert np.asarray(out).shape == (2,)
    assert float(out[0]) == 0.0
    assert float(out[1]) == 1.0  # clip of a zero norm is min(1, c/eps) = 1


# ---------------------------------------------------------------------------
# backend-layer composition errors and legacy-subclass degradation
# ---------------------------------------------------------------------------


def test_segments_epilogue_rejects_moments(rng):
    flat = jnp.asarray(rng.randn(300).astype(np.float32))
    plan = R.plan_for((300,), "float32", backend="xla",
                      segments=2).replace(backend="xla")
    with pytest.raises(ValueError, match="moments"):
        B.get_backend("xla").sum_segments(flat, (0, 100, 300), plan,
                                          "moments", epilogue=(("sqrt",),))


def test_parts_total_rejects_moments(rng):
    parts = (jnp.asarray(rng.randn(100).astype(np.float32)),)
    plan = R.plan_for((100,), "float32", backend="pallas_fused",
                      segments=1).replace(backend="pallas_fused")
    for name in ("xla", "pallas_fused"):
        with pytest.raises(ValueError, match="moments"):
            B.get_backend(name).sum_parts_total(
                parts, plan.replace(backend=name), "moments", ((),)
            )


def test_moments_kahan_error_names_both_knobs_kernel_layer():
    """Satellite: the kernel-layer raise must name BOTH knobs (moments,
    kahan) and the supported fallback (precision='native')."""
    from repro.kernels.mma_reduce import kernel as K

    x = jnp.ones(256, jnp.float32)
    with pytest.raises(ValueError) as ei:
        K.reduce_fused(x, kahan=True, prologue="moments")
    msg = str(ei.value)
    assert "moments" in msg and "Kahan" in msg.replace("kahan", "Kahan")
    assert "native" in msg


def test_moments_kahan_error_has_plan_repr_and_fallback(rng):
    """Satellite: the backend-layer raise carries the offending plan's repr
    plus the supported fallback, so the message is actionable."""
    x = jnp.asarray(rng.randn(512).astype(np.float32))
    plan = R.plan_for((512,), "float32", backend="pallas_fused",
                      precision="kahan").replace(backend="pallas_fused",
                                                 precision="kahan")
    with pytest.raises(ValueError) as ei:
        B.get_backend("pallas_fused").moments_all(x, plan)
    msg = str(ei.value)
    assert "moments" in msg and "kahan" in msg
    assert "ReducePlan" in msg            # the plan repr
    assert "precision='native'" in msg    # the supported fallback


def test_legacy_backend_gets_host_side_epilogue():
    """A pre-epilogue Backend subclass keeps serving chained reductions:
    the engine applies the identical chain host-side on its total."""

    class Doubling(R.Backend):
        name = "doubling_epi"
        native_autodiff = True

        def sum_all(self, x, plan):
            return 2.0 * jnp.sum(x.astype(plan.accum_jnp))

        def sum_axis(self, x, plan):  # pragma: no cover - unused here
            return 2.0 * jnp.sum(x.astype(plan.accum_jnp), -1)

    try:
        R.register_backend(Doubling())
        x = jnp.ones(8, jnp.float32)
        got = float(R.reduce(x, backend="doubling_epi",
                             epilogue=("scale", 0.5)))
        assert got == 8.0  # 2 * 8 * 0.5
    finally:
        B._REGISTRY.pop("doubling_epi", None)


# ---------------------------------------------------------------------------
# plan/cost-model: epilogue adds zero input bytes on every modeled path
# ---------------------------------------------------------------------------


def test_plan_hbm_bytes_epilogue_is_zero_extra_input():
    plan = R.plan_for((100_000,), "bfloat16",
                      backend="pallas_fused").replace(backend="pallas_fused")
    base = plan.hbm_bytes(100_000, "bfloat16", segments=4,
                          prologue="square")
    fork = plan.hbm_bytes(100_000, "bfloat16", segments=4,
                          prologue="square", epilogue=2)
    assert fork.kernel_read == base.kernel_read
    assert fork.kernel_write == base.kernel_write + 8


def test_fused_epilogue_model_requires_single_lane():
    from repro.core import cost_model

    with pytest.raises(ValueError, match="single-lane"):
        cost_model.fused_hbm_bytes(1 << 20, 2, num_cores=4, epilogue=True)
    t = cost_model.fused_hbm_bytes(1 << 20, 2, num_cores=1, epilogue=True)
    assert t.kernel_write == 4  # one finished f32, not lane partials
