"""Zero-copy native-dtype ingestion: proven on values, bits, jaxprs, bytes.

Four angles on the same contract:

  * tail-masking sweep -- every ragged n (incl. n < m^2, m^2 +- 1) x dtype
    {bf16, f16, f32} x num_cores {1, 2, 4} agrees with the jnp.sum oracle
    AND the updated op-for-op ``ref.py`` emulation (which models the masked
    loads as zero-padding);
  * bit-compatibility -- tile-multiple f32 inputs reproduce the PR-3
    (staged-ingestion) kernels bit-for-bit at every lane count, because a
    masked zero and a padded zero are the same zero;
  * staging-free jaxprs -- lowering ``reduce`` / ``reduce_many`` on bf16
    never materializes an n-sized convert/pad/concatenate outside the
    pallas_call (``repro.reduce.inspect``);
  * traffic -- ``cost_model.hbm_bytes`` equals the bytes actually crossing
    the lowered pallas_call boundary (asserted exactly for the fused and
    parts paths; upper bound for non-aligned segmented gathers, exact when
    aligned), and bf16 ingestion moves n*2 + O(c m^2).
"""

from _optional_hypothesis import hypothesis, st
import harness
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import reduce as R
from repro.core import cost_model
from repro.kernels import common
from repro.kernels.mma_reduce import kernel as K
from repro.kernels.mma_reduce import ops, ref
from repro.reduce import inspect as rinspect

M = common.MXU
GROUP = M * M
PALLAS_BACKENDS = ["pallas_fused", "pallas_hier"]

# the tail-masking sweep: below one tile, one tile +- 1, straddling block
# and lane boundaries, and a large ragged stream
TAIL_SIZES = [1, 7, 100, GROUP - 1, GROUP, GROUP + 1, 3 * GROUP - 5, 100_000]
DTYPES = [jnp.bfloat16, jnp.float16, jnp.float32]


def _tol(x64: np.ndarray, dt) -> float:
    # bf16 multipliers everywhere; bf16/f16 STORAGE also quantizes the data
    # (shared budget; see tests/harness.py)
    return harness.mass_tol(x64, harness.storage_rel(dt))


@pytest.mark.parametrize("num_cores", [1, 2, 4])
@pytest.mark.parametrize("dt", DTYPES, ids=lambda d: jnp.dtype(d).name)
@pytest.mark.parametrize("n", TAIL_SIZES)
def test_tail_masking_sweep(n, dt, num_cores, rng):
    """Ragged n x native dtype x lane count vs the jnp.sum oracle."""
    x = jnp.asarray(rng.randn(n), dt)
    x64 = np.asarray(x, np.float64)
    for backend in PALLAS_BACKENDS:
        got = float(R.reduce(x, backend=backend, num_cores=num_cores))
        assert abs(got - x64.sum()) <= _tol(x64, dt), (backend, n, dt)


@pytest.mark.parametrize("dt", DTYPES, ids=lambda d: jnp.dtype(d).name)
@pytest.mark.parametrize("n", [100, GROUP + 1, 50_000])
def test_tail_masking_matches_ref_emulation_bitwise(n, dt, rng):
    """The kernel's masked loads == the emulation's zero-pad model, to the
    BIT, for every native dtype (pins cast order: native -> compute directly,
    mask after cast)."""
    x = jnp.asarray(rng.randn(n), dt)
    for c in (1, 2, 4):
        got = np.asarray(K.reduce_fused(x.reshape(-1), num_cores=c))
        want = np.asarray(ref.fused_lanes_ref(x, num_cores=c))
        np.testing.assert_array_equal(
            got.view(np.uint32), want.view(np.uint32), err_msg=f"{n} {dt} {c}"
        )


@pytest.mark.parametrize("num_cores", [1, 2, 4])
def test_tile_multiple_f32_bit_identical_to_staged_kernels(num_cores, rng):
    """Acceptance: tile-multiple f32 inputs reproduce the PR-3 kernels
    bit-for-bit at every lane count. The PR-3 kernel consumed a host-padded
    f32 (T, m, m) stream; feeding the SAME bytes through the zero-copy path
    must produce identical partials (mask statically elided) and identical
    final bits through the combine."""
    n = 24 * GROUP  # tile- AND block-multiple: no masking anywhere
    x = jnp.asarray(rng.randn(n).astype(np.float32))
    got = np.asarray(K.reduce_fused(x, num_cores=num_cores))
    # the staged path == emulation (pinned since PR 3); transitively the
    # zero-copy kernel must equal it
    want = np.asarray(ref.fused_lanes_ref(x, num_cores=num_cores))
    np.testing.assert_array_equal(got.view(np.uint32), want.view(np.uint32))
    # end-to-end bits through the public API as well
    a = np.asarray(
        R.reduce(x, backend="pallas_fused", num_cores=num_cores), np.float32
    )
    b = np.asarray(
        ops.combine_lane_partials(jnp.asarray(want)), np.float32
    )
    np.testing.assert_array_equal(a.view(np.uint32), b.view(np.uint32))
    # hierarchical mode: bit-identical to the eq. (13) emulation
    got_h = float(R.reduce(x, backend="pallas_hier", num_cores=num_cores))
    assert got_h == float(ref.hierarchy_ref(x))


def test_non_contiguous_and_transposed_views(rng):
    """Transposed / strided views reduce correctly on every Pallas path
    (XLA materializes the view once -- a layout copy, not ingestion
    staging; the kernel then streams it zero-copy)."""
    base = jnp.asarray(rng.randn(257, 129).astype(np.float32))
    views = [
        base.T,                      # transposed
        base[::2, ::3],              # strided slice
        jnp.swapaxes(base.reshape(257, 3, 43), 0, 2),  # permuted 3-d
    ]
    for v in views:
        want = float(np.asarray(v, np.float64).sum())
        for backend in PALLAS_BACKENDS:
            for c in (1, 2):
                got = float(R.reduce(v, backend=backend, num_cores=c))
                assert abs(got - want) <= 4e-3 * max(
                    np.abs(np.asarray(v, np.float64)).sum(), 1.0
                ), (backend, c, v.shape)
        many = np.asarray(R.reduce_many([v, v[:5]], backend="pallas_fused"))
        want2 = float(np.asarray(v[:5], np.float64).sum())
        for got, w, part in zip(many, (want, want2), (v, v[:5])):
            tol = 4e-3 * max(np.abs(np.asarray(part, np.float64)).sum(), 1.0)
            assert abs(float(got) - w) <= tol, (v.shape, got, w)


@pytest.mark.parametrize("backend", PALLAS_BACKENDS)
def test_reduce_staging_free_jaxpr(backend):
    """Satellite gate (mirrored in benchmarks/check_bench.py): no n-sized
    convert/pad/concatenate outside the pallas_call for bf16 ingestion."""
    x = jnp.zeros((300_000,), jnp.bfloat16)
    rinspect.assert_staging_free(
        lambda v: R.reduce(v, backend=backend), x
    )
    rinspect.assert_staging_free(
        lambda v: R.reduce(v, backend=backend, num_cores=2), x
    )


@pytest.mark.parametrize("backend", PALLAS_BACKENDS)
def test_reduce_many_staging_free_jaxpr(backend):
    arrs = [jnp.zeros((s,), jnp.bfloat16) for s in (70_000, 33, 20_000)]
    rinspect.assert_staging_free(
        lambda a: R.reduce_many(a, backend=backend), arrs
    )
    # f16 and f32 parts are native too
    arrs = [jnp.zeros((s,), jnp.float16) for s in (300, 5)]
    rinspect.assert_staging_free(
        lambda a: R.reduce_many(a, backend=backend), arrs
    )


def test_reduce_tree_no_partial_concatenation():
    """reduce_tree feeds per-leaf partials as separate operands: no
    concatenate at ANY size in the lowered program, and still one launch."""
    tree = {
        "w": jnp.ones((40, 256)),
        "b": [jnp.ones((3000,)), jnp.ones(())],
        "e": jnp.ones((0, 8)),
    }
    jaxpr = jax.make_jaxpr(
        lambda g: R.reduce_tree(g, "norm2", backend="pallas_fused")
    )(tree)
    assert not rinspect.staging_eqns(jaxpr, 2), rinspect.staging_eqns(jaxpr, 2)
    assert rinspect.count_pallas_calls(
        lambda g: R.reduce_tree(g, "norm2", backend="pallas_fused"), tree
    ) == 1


@pytest.mark.parametrize("dt,bs", [(jnp.bfloat16, 2), (jnp.float16, 2),
                                   (jnp.float32, 4)])
def test_fused_hbm_bytes_match_traced_geometry(dt, bs):
    """Acceptance: hbm_bytes(pallas_fused, bf16) == n*2 + O(c m^2), and the
    model's launch_io equals the bytes crossing the lowered pallas_call
    boundary EXACTLY, for every dtype x n x lane count."""
    for n in (5, GROUP, 100_000, 300_000):
        x = jnp.zeros((n,), dt)
        for c in (1, 2, 4):
            model = cost_model.fused_hbm_bytes(n, bs, num_cores=c)
            jaxpr = jax.make_jaxpr(
                lambda v, c=c: R.reduce(v, backend="pallas_fused", num_cores=c)
            )(x)
            assert rinspect.pallas_io_bytes(jaxpr) == model.launch_io, (n, dt, c)
            # n*itemsize + O(c m^2): the overhead term is exactly the
            # partial round-trip + result
            eff_c = cost_model.stripe_geometry(
                max(1, -(-n // GROUP)), 8, c
            )[1]
            assert model.total == n * bs + (2 * eff_c * GROUP * 4 + 4)
            # trace agrees with the model
            tr = []
            ops.mma_sum_pallas(x, num_cores=c, trace=tr)
            assert tr[0].hbm_bytes == model.total


def test_parts_hbm_bytes_match_traced_geometry():
    sizes = (70_000, 33, 20_000, 0)
    arrs = [jnp.zeros((s,), jnp.bfloat16) for s in sizes]
    model = cost_model.parts_hbm_bytes(
        sum(a.nbytes for a in arrs), segments=len(arrs)
    )
    jaxpr = jax.make_jaxpr(
        lambda a: R.reduce_many(a, backend="pallas_fused")
    )(arrs)
    assert rinspect.pallas_io_bytes(jaxpr) == model.launch_io
    tr = []
    ops.mma_sum_parts_pallas(arrs, trace=tr)
    assert tr[0].hbm_bytes == model.total


def test_segmented_hbm_bytes_aligned_exact_unaligned_bounded():
    plan = R.plan_for((5 * GROUP,), jnp.float32, backend="pallas_fused",
                      segments=2, num_cores=2)
    backend = R.get_backend("pallas_fused")
    for sizes, aligned in (
        ((2 * GROUP, 3 * GROUP), True),     # tile-aligned: exact equality
        ((20_000, 20_000), False),          # straddled boundary: re-fetch
    ):
        offsets = tuple(np.concatenate([[0], np.cumsum(sizes)]).tolist())
        flat = jnp.zeros((int(offsets[-1]),), jnp.float32)
        _, src, seg, lo, hi = ops.segment_cover_layout(offsets, GROUP)
        fetched = ops._cover_fetched_elems(src, flat.size, GROUP)
        model = cost_model.segmented_hbm_bytes(
            fetched, 4, segments=len(sizes), tiles=int(src.size), num_cores=2
        )
        jaxpr = jax.make_jaxpr(
            lambda v: backend.sum_segments(v, offsets, plan)
        )(flat)
        measured = rinspect.pallas_io_bytes(jaxpr)
        if aligned:
            assert measured == model.launch_io, (sizes, measured)
            assert fetched == int(flat.size)
        else:
            # the model charges the straddled block twice; the operand aval
            # counts it once -- measured is a strict lower bound
            assert measured < model.launch_io
            assert fetched > int(flat.size)
            # and the remainder overhead is bounded by one block per
            # non-aligned boundary
            assert fetched - int(flat.size) <= len(sizes) * GROUP


def test_staged_ingestion_costs_3x_on_bf16():
    """The motivating arithmetic: the old cast+pad staging moved ~3x the
    bytes of the zero-copy path for bf16 operands (2 + 4 + 4 per element vs
    2), and >2x even for f32."""
    n = 1 << 20
    zc = cost_model.hbm_bytes("fused", n, 2).total
    staged = cost_model.hbm_bytes("fused_staged", n, 2).total
    assert staged / zc > 3.0
    assert cost_model.hbm_bytes("fused_staged", n, 4).total \
        / cost_model.hbm_bytes("fused", n, 4).total > 2.0


def test_plan_hbm_bytes_threads_backend_paths():
    n = 1 << 20
    fused = R.plan_for((n,), jnp.bfloat16, backend="pallas_fused")
    assert fused.hbm_bytes(n, jnp.bfloat16).total == \
        cost_model.fused_hbm_bytes(n, 2, num_cores=fused.num_cores).total
    hier = fused.replace(backend="pallas_hier")
    assert hier.hbm_bytes(n, jnp.bfloat16).total == \
        cost_model.hier_hbm_bytes(n, 2).total
    # non-native dtypes pay the documented staged pre-cast
    assert fused.hbm_bytes(n, jnp.int32).total == \
        cost_model.staged_fused_hbm_bytes(
            n, 4, num_cores=fused.num_cores
        ).total
    # jnp-level backends: one native stream
    xla = fused.replace(backend="xla")
    assert xla.hbm_bytes(n, jnp.bfloat16).total == n * 2 + 4
    # segmented multi-reduce routes to the parts model on kernel backends
    assert fused.hbm_bytes(n, jnp.bfloat16, segments=8).total == \
        cost_model.parts_hbm_bytes(n * 2, segments=8).total


def test_ingest_fallback_dtypes_still_exact(rng):
    """f64 / int / bool inputs pre-cast to f32 (the documented staging
    fallback) and reduce exactly where exactness is representable."""
    xi = jnp.asarray(rng.randint(-50, 50, size=30_000), jnp.int32)
    for backend in PALLAS_BACKENDS:
        got = float(R.reduce(xi, backend=backend, compute_dtype="float32"))
        assert got == float(np.asarray(xi).sum())
    xb = jnp.asarray(rng.rand(1000) > 0.5)
    got = float(R.reduce(xb, backend="pallas_fused", compute_dtype="float32"))
    assert got == float(np.asarray(xb).sum())


def test_parts_kernel_fallback_past_threshold(rng):
    """More live parts than PARTS_KERNEL_MAX: the backend falls back to the
    packed stream (documented), stays correct, and still launches once."""
    nseg = ops.PARTS_KERNEL_MAX + 3
    arrs = [jnp.asarray(rng.randn(7).astype(np.float32)) for _ in range(nseg)]
    got = np.asarray(R.reduce_many(arrs, backend="pallas_fused"))
    want = np.asarray([np.asarray(a).sum() for a in arrs])
    tol = 4e-3 * np.maximum(
        np.asarray([np.abs(np.asarray(a)).sum() for a in arrs]), 1.0
    )
    assert np.all(np.abs(got - want) <= tol)
    assert rinspect.count_pallas_calls(
        lambda a: R.reduce_many(a, backend="pallas_fused"), arrs
    ) == 1


def test_segment_cover_layout_maps():
    """Cover-map algebra: aligned segments reuse the buffer's own blocks;
    straddled boundaries share a block with two masked windows."""
    tcounts, src, seg, lo, hi = ops.segment_cover_layout(
        (0, 5, 5, 40), 16
    )
    assert tcounts == (1, 0, 3)
    np.testing.assert_array_equal(src, [0, 0, 1, 2])
    np.testing.assert_array_equal(seg, [0, 2, 2, 2])
    np.testing.assert_array_equal(lo, [0, 5, 0, 0])
    np.testing.assert_array_equal(hi, [5, 16, 16, 8])
    # block 0 is fetched twice (segments 0 and 2 share it), masked disjointly
    assert ops._cover_fetched_elems(src, 40, 16) == 16 + 16 + 16 + 8


@hypothesis.settings(max_examples=30, deadline=None)
@hypothesis.given(
    n=st.integers(1, 60_000),
    seed=st.integers(0, 2**31 - 1),
    num_cores=st.sampled_from([1, 2, 4]),
    dt=st.sampled_from(["bfloat16", "float16", "float32"]),
)
def test_property_zero_copy_vs_oracle(n, seed, num_cores, dt):
    """Property sweep: ragged n x native dtype x lanes, zero-copy fused
    kernel vs the f64 oracle on the quantized data."""
    x = jnp.asarray(
        np.random.RandomState(seed).randn(n), jnp.dtype(dt)
    )
    x64 = np.asarray(x, np.float64)
    got = float(R.reduce(x, backend="pallas_fused", num_cores=num_cores))
    tol = (4e-3 if dt == "float32" else 1.6e-2) * max(np.abs(x64).sum(), 1e-3)
    assert abs(got - x64.sum()) <= tol
