"""Flash attention kernel vs dense oracle across attention modes."""

from _optional_hypothesis import hypothesis, st
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention import (
    attention_ref,
    flash_attention,
    flash_attention_diff,
)

CASES = [
    # b, hq, hkv, sq, skv, d, causal, window, q_offset
    (2, 4, 4, 256, 256, 64, True, None, 0),       # MHA causal
    (1, 8, 2, 200, 200, 64, True, None, 0),       # GQA, non-multiple lengths
    (1, 4, 1, 128, 384, 32, False, None, 0),      # MQA cross-attention
    (1, 4, 2, 128, 512, 64, True, 256, 0),        # sliding window
    (1, 4, 2, 1, 512, 64, True, None, 511),       # single-token decode
    (1, 2, 2, 64, 512, 64, True, 128, 448),       # offset append + window
    (2, 2, 2, 96, 96, 128, True, None, 0),        # d=128 head
]


@pytest.mark.parametrize("case", CASES)
def test_matches_oracle(case, rng):
    b, hq, hkv, sq, skv, d, causal, window, qoff = case
    q = jnp.asarray(rng.randn(b, hq, sq, d).astype(np.float32)) * 0.5
    k = jnp.asarray(rng.randn(b, hkv, skv, d).astype(np.float32)) * 0.5
    v = jnp.asarray(rng.randn(b, hkv, skv, d).astype(np.float32)) * 0.5
    o = flash_attention(q, k, v, causal=causal, window=window, q_offset=qoff)
    r = attention_ref(q, k, v, causal=causal, window=window, q_offset=qoff)
    assert float(jnp.max(jnp.abs(o - r))) < 2e-2


def test_block_size_invariance(rng):
    """Output must not depend on the BlockSpec tiling (pure schedule knob)."""
    q = jnp.asarray(rng.randn(1, 2, 256, 64).astype(np.float32))
    k = jnp.asarray(rng.randn(1, 2, 256, 64).astype(np.float32))
    v = jnp.asarray(rng.randn(1, 2, 256, 64).astype(np.float32))
    a = flash_attention(q, k, v, block_q=64, block_k=64)
    b = flash_attention(q, k, v, block_q=128, block_k=256)
    assert float(jnp.max(jnp.abs(a - b))) < 5e-3


def test_gradients_flow(rng):
    q = jnp.asarray(rng.randn(1, 2, 64, 32).astype(np.float32))
    k = jnp.asarray(rng.randn(1, 2, 64, 32).astype(np.float32))
    v = jnp.asarray(rng.randn(1, 2, 64, 32).astype(np.float32))
    loss = lambda q, k, v: jnp.sum(flash_attention_diff(q, k, v) ** 2)
    gq, gk, gv = jax.grad(loss, (0, 1, 2))(q, k, v)
    rloss = lambda q, k, v: jnp.sum(attention_ref(q, k, v) ** 2)
    rq, rk, rv = jax.grad(rloss, (0, 1, 2))(q, k, v)
    for g, r in ((gq, rq), (gk, rk), (gv, rv)):
        assert float(jnp.max(jnp.abs(g - r))) < 3e-2
        assert bool(jnp.all(jnp.isfinite(g)))


@hypothesis.settings(max_examples=12, deadline=None)
@hypothesis.given(
    sq=st.integers(1, 96),
    skv=st.integers(8, 160),
    hkv=st.sampled_from([1, 2]),
    g=st.sampled_from([1, 2]),
    causal=st.booleans(),
    seed=st.integers(0, 2**31 - 1),
)
def test_property_matches_oracle(sq, skv, hkv, g, causal, seed):
    if causal and sq > skv:
        sq = skv
    r = np.random.RandomState(seed)
    q = jnp.asarray(r.randn(1, hkv * g, sq, 32).astype(np.float32)) * 0.3
    k = jnp.asarray(r.randn(1, hkv, skv, 32).astype(np.float32)) * 0.3
    v = jnp.asarray(r.randn(1, hkv, skv, 32).astype(np.float32)) * 0.3
    qoff = max(0, skv - sq) if causal else 0
    o = flash_attention(q, k, v, causal=causal, q_offset=qoff)
    ref = attention_ref(q, k, v, causal=causal, q_offset=qoff)
    assert float(jnp.max(jnp.abs(o - ref))) < 2e-2
