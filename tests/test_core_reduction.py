"""Core (pure-JAX) MMA reduction algorithm: paper step-count claims +
precision. Backend-dispatch coverage lives in test_reduce_dispatch.py; this
module exercises the implementation (repro.core.mma_reduce) directly."""

from _optional_hypothesis import hypothesis, st
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import reduce as R
from repro.core import cost_model, precision
from repro.core.mma_reduce import (
    classic_tree_sum,
    mma_sum,
    mma_sum_axis,
    mma_sum_diff,
    row_moments_mma,
    row_sum_mma,
)


@pytest.mark.parametrize("m", [2, 4, 16, 128])
@pytest.mark.parametrize("k", [1, 2, 3])
def test_step_count_matches_eq16_for_exact_powers(m, k, rng):
    """T_tc(n) = 5 log_{m^2}(n): for n = (m^2)^k the implemented driver
    executes exactly k levels = 5k model steps (paper eq. 15-16)."""
    n = (m * m) ** k
    if n > 1 << 22:
        pytest.skip("large")
    x = jnp.asarray(rng.randn(n).astype(np.float32))
    trace = []
    mma_sum(x, m=m, trace=trace)
    assert trace[0].levels == k
    assert trace[0].model_steps == 5 * k
    assert abs(trace[0].predicted_steps - 5 * k) < 1e-9


def test_classic_baseline_step_count(rng):
    """Pairwise baseline: log2(n) levels for powers of two (paper's 4log2n
    model counts 4 units per level)."""
    x = jnp.asarray(rng.randn(1 << 12).astype(np.float32))
    trace = []
    classic_tree_sum(x, trace=trace)
    assert trace[0].levels == 12


def test_ceil_recurrence_levels():
    assert cost_model.levels(1, 16) == 0
    assert cost_model.levels(256, 16) == 1
    assert cost_model.levels(257, 16) == 2
    assert cost_model.levels(128**2 + 1, 128) == 2


def test_correctness_various_m(rng):
    x = rng.randn(10_000).astype(np.float32)
    want = x.astype(np.float64).sum()
    for m in (2, 4, 16, 128):
        got = float(mma_sum(jnp.asarray(x), m=m, compute_dtype=jnp.float32))
        np.testing.assert_allclose(got, want, rtol=1e-5)


def test_axis_reduction(rng):
    x = jnp.asarray(rng.randn(6, 50, 40).astype(np.float32))
    got = mma_sum_axis(x, (1, 2))
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(jnp.sum(x, (1, 2))), rtol=3e-2
    )


def test_row_reductions(rng):
    x = jnp.asarray(rng.randn(33, 700).astype(np.float32))
    np.testing.assert_allclose(
        np.asarray(row_sum_mma(x, compute_dtype=jnp.float32)),
        np.asarray(jnp.sum(x, -1)), rtol=1e-5, atol=1e-3,
    )
    s, ss = row_moments_mma(x)
    np.testing.assert_allclose(np.asarray(ss), np.asarray(jnp.sum(x * x, -1)),
                               rtol=2e-2, atol=1.0)


def test_global_norm_matches(rng):
    tree = {
        "a": jnp.asarray(rng.randn(37, 129).astype(np.float32)),
        "b": [jnp.asarray(rng.randn(1000).astype(np.float32)),
              jnp.asarray(rng.randn(3, 4, 5).astype(np.float32))],
    }
    got = float(R.reduce_tree(tree, kind="sumsq", backend="mma_jnp"))
    want = sum(float((np.asarray(x).astype(np.float64) ** 2).sum())
               for x in jax.tree.leaves(tree))
    np.testing.assert_allclose(got, want, rtol=1e-5)


def test_zero_size_inputs_reduce_to_identity():
    """Regression: empty operands must return the additive identity (0.0)
    instead of erroring or looping on a degenerate pad."""
    trace = []
    assert float(mma_sum(jnp.zeros((0,)), trace=trace)) == 0.0
    assert trace[0].levels == 0 and trace[0].mma_ops == 0
    assert float(classic_tree_sum(jnp.zeros((0,)))) == 0.0
    assert float(mma_sum(jnp.zeros((0, 7)))) == 0.0
    g = jax.grad(lambda y: mma_sum_diff(y, 128))(jnp.zeros((0,)))
    assert g.shape == (0,)


def test_gradient_is_broadcast(rng):
    x = jnp.asarray(rng.randn(5000).astype(np.float32))
    g = jax.grad(lambda y: mma_sum_diff(y, 128))(x)
    np.testing.assert_allclose(np.asarray(g), 1.0)


# ------------------------------- precision ----------------------------------


def test_precision_hierarchy(rng):
    """Paper section V future work: refined variants reduce error.
    kahan(serial f32) <= blocked-kahan-MMA <= plain bf16 MMA, vs f64 truth."""
    x = (rng.randn(1 << 16) * rng.rand(1 << 16)).astype(np.float32)
    exact = x.astype(np.float64).sum()
    e_mma = abs(float(mma_sum(jnp.asarray(x))) - exact)
    e_bk = abs(float(precision.blocked_kahan_mma(jnp.asarray(x))) - exact)
    e_kahan = abs(float(precision.kahan_sum(jnp.asarray(x))) - exact)
    assert e_kahan <= e_bk + 1e-5
    assert e_bk <= e_mma + 1e-5


@hypothesis.settings(max_examples=25, deadline=None)
@hypothesis.given(
    n=st.integers(1, 30_000), m=st.sampled_from([2, 4, 16, 128]),
    seed=st.integers(0, 2**31 - 1),
)
def test_property_f32_mma_exactish(n, m, seed):
    x = np.random.RandomState(seed).randn(n).astype(np.float32)
    got = float(mma_sum(jnp.asarray(x), m=m, compute_dtype=jnp.float32))
    np.testing.assert_allclose(got, x.astype(np.float64).sum(), rtol=1e-4,
                               atol=1e-3)
