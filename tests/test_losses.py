"""Loss paths: chunked CE == dense CE (values and grads), MMA on/off parity."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import TINY_ARCHS
from repro.models import forward, init_params
from repro.models.losses import lm_loss, lm_loss_chunked
from repro.models.model import forward_hidden


@pytest.mark.parametrize("arch", ["olmo-1b", "musicgen-medium"])
def test_chunked_equals_dense(arch, rng):
    cfg = TINY_ARCHS[arch]
    params, _ = init_params(jax.random.PRNGKey(0), cfg)
    if cfg.n_codebooks:
        toks = jnp.asarray(rng.randint(0, cfg.vocab_size, (2, 21, cfg.n_codebooks)))
    else:
        toks = jnp.asarray(rng.randint(0, cfg.vocab_size, (2, 21)))
    h, aux = forward_hidden(params, cfg, toks)
    logits, _ = forward(params, cfg, toks)
    dense, _ = lm_loss(logits, toks, aux, cfg)
    if cfg.n_codebooks:
        dense = None  # lm_loss handles (B,S,K,V) via per-token mean inside chunked only
    chunked, _ = lm_loss_chunked(params, cfg, h, toks, aux, seq_chunk=8)
    if dense is not None:
        # bf16 all-ones-dot rounding differs between chunk groupings
        np.testing.assert_allclose(float(chunked), float(dense), rtol=5e-3)
    assert np.isfinite(float(chunked))


def test_chunked_grads_match_dense(rng):
    cfg = TINY_ARCHS["olmo-1b"]
    params, _ = init_params(jax.random.PRNGKey(0), cfg)
    toks = jnp.asarray(rng.randint(0, cfg.vocab_size, (2, 16)))

    def loss_dense(p):
        logits, aux = forward(p, cfg, toks)
        return lm_loss(logits, toks, aux, cfg)[0]

    def loss_chunked(p):
        h, aux = forward_hidden(p, cfg, toks)
        return lm_loss_chunked(p, cfg, h, toks, aux, seq_chunk=4)[0]

    gd = jax.grad(loss_dense)(params)
    gc = jax.grad(loss_chunked)(params)
    for a, b in zip(jax.tree.leaves(gd), jax.tree.leaves(gc)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=2e-4)


def test_mma_flag_changes_schedule_not_value(rng):
    """Paper-technique on/off must be numerically equivalent (within bf16
    rounding of the all-ones dot) -- it is a schedule change, not a math
    change."""
    cfg = TINY_ARCHS["olmo-1b"]
    cfg_off = dataclasses.replace(cfg, mma_reductions=False)
    params, _ = init_params(jax.random.PRNGKey(0), cfg)
    toks = jnp.asarray(rng.randint(0, cfg.vocab_size, (2, 16)))
    lon, _ = forward(params, cfg, toks)
    loff, _ = forward(params, cfg_off, toks)
    # bf16 all-ones-dot denominators vs f32 jnp.sum: small per-logit drift
    np.testing.assert_allclose(np.asarray(lon), np.asarray(loff), atol=3e-2)
