"""Optimizer: descent, clipping via MMA global norm, schedule shape."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import optim
from repro.configs import TrainConfig


def test_adamw_descends_quadratic():
    tcfg = TrainConfig(learning_rate=0.1, warmup_steps=1, total_steps=200,
                       weight_decay=0.0, grad_clip=1e9)
    params = {"x": jnp.asarray([5.0, -3.0])}
    state = optim.init_state(params)
    for _ in range(150):
        grads = {"x": 2 * params["x"]}
        params, state, m = optim.apply_updates(params, grads, state, tcfg)
    assert float(jnp.max(jnp.abs(params["x"]))) < 0.2


def test_clipping_engages():
    tcfg = TrainConfig(learning_rate=0.0, grad_clip=1.0)
    params = {"x": jnp.zeros(4)}
    state = optim.init_state(params)
    big = {"x": jnp.full(4, 100.0)}
    _, _, m = optim.apply_updates(params, big, state, tcfg)
    np.testing.assert_allclose(float(m["grad_norm"]), 200.0, rtol=1e-4)
    assert float(m["clip"]) == pytest.approx(1.0 / 200.0, rel=1e-4)


def test_mma_and_plain_global_norm_agree(rng):
    tree = {"a": jnp.asarray(rng.randn(777).astype(np.float32)),
            "b": jnp.asarray(rng.randn(33, 5).astype(np.float32))}
    a = float(optim.global_norm(tree, mma=True))
    b = float(optim.global_norm(tree, mma=False))
    np.testing.assert_allclose(a, b, rtol=1e-5)


def test_cosine_schedule_shape():
    tcfg = TrainConfig(learning_rate=1.0, warmup_steps=10, total_steps=100)
    lr = [float(optim.cosine_lr(tcfg, s)) for s in range(101)]
    assert lr[0] == 0.0
    assert lr[10] == pytest.approx(1.0)
    assert lr[100] == pytest.approx(0.0, abs=1e-6)
    assert all(x >= y - 1e-9 for x, y in zip(lr[10:], lr[11:]))  # decays


def test_weight_decay_decouples():
    tcfg = TrainConfig(learning_rate=0.1, warmup_steps=1, total_steps=10,
                       weight_decay=0.5, grad_clip=1e9)
    params = {"x": jnp.asarray([10.0])}
    state = optim.init_state(params)
    zero = {"x": jnp.zeros(1)}
    out, _, _ = optim.apply_updates(params, zero, state, tcfg)
    assert float(out["x"][0]) < 10.0  # decay shrinks even at zero gradient


# ---------------------------------------------------------------------------
# one-launch clip fork
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("backend", ("xla", "pallas_fused"))
def test_global_norm_and_clip_agrees_with_manual(backend, rng):
    tree = {"a": jnp.asarray(rng.randn(777).astype(np.float32)),
            "b": jnp.asarray(rng.randn(33, 5).astype(np.float32))}
    gnorm, clip = optim.global_norm_and_clip(tree, 1.0, backend=backend)
    ref_n = optim.global_norm(tree, backend=backend)
    ref_c = jnp.minimum(1.0, 1.0 / jnp.maximum(ref_n, optim.GNORM_EPS))
    np.testing.assert_allclose(float(gnorm), float(ref_n), rtol=1e-6)
    np.testing.assert_allclose(float(clip), float(ref_c), rtol=1e-6)
    per, gnorm2, _ = optim.global_norm_and_clip(
        tree, 1.0, backend=backend, return_per_leaf=True
    )
    assert per.shape == (2,)
    np.testing.assert_allclose(
        float(jnp.sqrt(jnp.sum(per))), float(gnorm2), rtol=1e-6
    )


@pytest.mark.parametrize("backend", ("xla", "pallas_fused"))
@pytest.mark.parametrize("fused", (False, True))
def test_zero_gradient_tree_clips_finite_updates_zero(backend, fused, rng):
    """Satellite regression: an all-zero gradient tree must produce a
    FINITE clip coefficient (the GNORM_EPS floor: min(1, c/eps) = 1, not
    c/0 = inf) and, at weight_decay=0, an update that is exactly zero."""
    tcfg = TrainConfig(learning_rate=0.1, warmup_steps=1, total_steps=10,
                       weight_decay=0.0, grad_clip=1.0)
    params = {"a": jnp.asarray(rng.randn(130).astype(np.float32)),
              "b": jnp.asarray(rng.randn(7, 3).astype(np.float32))}
    state = optim.init_state(params, fused_second_moment=fused)
    zero = jax.tree.map(jnp.zeros_like, params)
    out, new_state, m = optim.apply_updates(
        params, zero, state, tcfg, reduce_backend=backend,
        fused_second_moment=fused,
    )
    assert np.isfinite(float(m["clip"]))
    assert float(m["clip"]) == 1.0
    assert float(m["grad_norm"]) == 0.0
    for k in params:  # bitwise: zero grad + zero decay moves nothing
        assert np.asarray(out[k]).tobytes() == np.asarray(params[k]).tobytes()


def test_fused_second_moment_descends_quadratic():
    tcfg = TrainConfig(learning_rate=0.05, warmup_steps=1, total_steps=1000,
                       weight_decay=0.0, grad_clip=1e9)
    params = {"x": jnp.asarray([5.0, -3.0, 2.0])}
    state = optim.init_state(params, fused_second_moment=True)
    assert state.v["x"].shape == ()  # scalar EMA, not elementwise
    loss0 = float(jnp.sum(params["x"] ** 2))
    for _ in range(60):
        grads = {"x": 2 * params["x"]}
        params, state, m = optim.apply_updates(
            params, grads, state, tcfg, fused_second_moment=True
        )
    assert float(jnp.sum(params["x"] ** 2)) < 0.7 * loss0
    assert state.v["x"].shape == ()


def test_fused_and_standard_agree_at_first_step(rng):
    """With a fresh state and per-leaf-constant gradients, the fused scalar
    EMA sees the same E[g^2] the elementwise v does, so step 1 matches."""
    tcfg = TrainConfig(learning_rate=0.1, warmup_steps=1, total_steps=10,
                       weight_decay=0.0, grad_clip=1e9)
    params = {"x": jnp.asarray(rng.randn(16).astype(np.float32))}
    grads = {"x": jnp.full(16, 0.5, jnp.float32)}
    p1, _, _ = optim.apply_updates(
        params, grads, optim.init_state(params), tcfg
    )
    p2, _, _ = optim.apply_updates(
        params, grads, optim.init_state(params, fused_second_moment=True),
        tcfg, fused_second_moment=True,
    )
    np.testing.assert_allclose(np.asarray(p1["x"]), np.asarray(p2["x"]),
                               rtol=1e-6)


# ---------------------------------------------------------------------------
# buffer donation
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("fused", (False, True))
def test_jitted_train_step_donates_param_and_opt_buffers(fused):
    """Satellite: the compiled train step reports params AND opt-state
    inputs as donated (aliased to outputs), so the update writes in place
    instead of doubling the resident weights."""
    from repro.configs import TINY_ARCHS
    from repro.launch.steps import make_jitted_train_step
    from repro.models import init_params

    cfg = TINY_ARCHS["olmo-1b"]
    tcfg = TrainConfig(learning_rate=1e-3, total_steps=10, warmup_steps=1,
                       fused_second_moment=fused)
    params, _ = init_params(jax.random.PRNGKey(0), cfg)
    opt_state = optim.init_state(params, fused_second_moment=fused)
    step = make_jitted_train_step(cfg, tcfg)
    feed = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0,
                                         cfg.vocab_size)}
    txt = step.lower(params, opt_state, feed).as_text()
    donated = txt.count("jax.buffer_donor") + txt.count("tf.aliasing_output")
    n_leaves = len(jax.tree.leaves((params, opt_state)))
    assert donated == n_leaves, (donated, n_leaves)
    # and the step actually runs with the donated buffers
    params, opt_state, metrics = step(params, opt_state, feed)
    assert np.isfinite(float(metrics["loss"]))
