"""Optimizer: descent, clipping via MMA global norm, schedule shape."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import optim
from repro.configs import TrainConfig


def test_adamw_descends_quadratic():
    tcfg = TrainConfig(learning_rate=0.1, warmup_steps=1, total_steps=200,
                       weight_decay=0.0, grad_clip=1e9)
    params = {"x": jnp.asarray([5.0, -3.0])}
    state = optim.init_state(params)
    for _ in range(150):
        grads = {"x": 2 * params["x"]}
        params, state, m = optim.apply_updates(params, grads, state, tcfg)
    assert float(jnp.max(jnp.abs(params["x"]))) < 0.2


def test_clipping_engages():
    tcfg = TrainConfig(learning_rate=0.0, grad_clip=1.0)
    params = {"x": jnp.zeros(4)}
    state = optim.init_state(params)
    big = {"x": jnp.full(4, 100.0)}
    _, _, m = optim.apply_updates(params, big, state, tcfg)
    np.testing.assert_allclose(float(m["grad_norm"]), 200.0, rtol=1e-4)
    assert float(m["clip"]) == pytest.approx(1.0 / 200.0, rel=1e-4)


def test_mma_and_plain_global_norm_agree(rng):
    tree = {"a": jnp.asarray(rng.randn(777).astype(np.float32)),
            "b": jnp.asarray(rng.randn(33, 5).astype(np.float32))}
    a = float(optim.global_norm(tree, mma=True))
    b = float(optim.global_norm(tree, mma=False))
    np.testing.assert_allclose(a, b, rtol=1e-5)


def test_cosine_schedule_shape():
    tcfg = TrainConfig(learning_rate=1.0, warmup_steps=10, total_steps=100)
    lr = [float(optim.cosine_lr(tcfg, s)) for s in range(101)]
    assert lr[0] == 0.0
    assert lr[10] == pytest.approx(1.0)
    assert lr[100] == pytest.approx(0.0, abs=1e-6)
    assert all(x >= y - 1e-9 for x, y in zip(lr[10:], lr[11:]))  # decays


def test_weight_decay_decouples():
    tcfg = TrainConfig(learning_rate=0.1, warmup_steps=1, total_steps=10,
                       weight_decay=0.5, grad_clip=1e9)
    params = {"x": jnp.asarray([10.0])}
    state = optim.init_state(params)
    zero = {"x": jnp.zeros(1)}
    out, _, _ = optim.apply_updates(params, zero, state, tcfg)
    assert float(out["x"][0]) < 10.0  # decay shrinks even at zero gradient
