"""End-to-end behaviour tests: train loss descends, serving produces stable
generations, checkpoint-resume is continuous at system level."""

import numpy as np
import pytest


def test_train_loss_descends_e2e(tmp_path):
    from repro.launch.train import main

    losses = main([
        "--arch", "olmo-1b", "--tiny", "--steps", "14", "--batch", "4",
        "--seq", "48", "--log-every", "7", "--lr", "3e-3",
    ])
    assert len(losses) == 14
    assert losses[-1] < losses[0]
    assert all(np.isfinite(l) for l in losses)


def test_train_resume_e2e(tmp_path):
    from repro.launch.train import main

    def args(steps):
        return ["--arch", "internlm2-1.8b", "--tiny", "--steps", str(steps),
                "--batch", "2", "--seq", "32", "--ckpt-dir", str(tmp_path),
                "--ckpt-every", "4", "--log-every", "4"]

    main(args(8))               # runs 8 steps, ckpt at 4 and 8
    resumed = main(args(10))    # resumes at 8, runs 2 more
    assert len(resumed) == 2
    assert all(np.isfinite(l) for l in resumed)


def test_serve_batched_e2e():
    from repro.launch.serve import main

    args = ["--arch", "olmo-1b", "--tiny", "--requests", "5",
            "--batch-slots", "2", "--prompt-len", "12", "--max-new", "6"]
    outs = main(args)
    assert len(outs) == 5
    assert all(len(o) == 6 for o in outs)
    assert outs == main(args)  # greedy decode is deterministic


def test_serve_ssm_arch_e2e():
    from repro.launch.serve import main

    outs = main([
        "--arch", "mamba2-780m", "--tiny", "--requests", "3",
        "--batch-slots", "3", "--prompt-len", "10", "--max-new", "5",
    ])
    assert len(outs) == 3 and all(len(o) == 5 for o in outs)
