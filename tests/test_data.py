"""Data pipeline: determinism, shard disjointness, O(1) seek-resume."""

import numpy as np
import pytest

from repro.data import MemmapTokens, Prefetcher, ShardInfo, SyntheticLM


def test_synthetic_deterministic_and_seekable():
    a = SyntheticLM(1000, 32, 4, seed=7)
    b = SyntheticLM(1000, 32, 4, seed=7)
    b.seek(2)
    batches_a = [a.next() for _ in range(4)]
    np.testing.assert_array_equal(batches_a[2]["tokens"], b.next()["tokens"])
    np.testing.assert_array_equal(batches_a[3]["tokens"], b.next()["tokens"])


def test_synthetic_shards_differ():
    a = SyntheticLM(1000, 32, 4, ShardInfo(0, 4), seed=7)
    b = SyntheticLM(1000, 32, 4, ShardInfo(1, 4), seed=7)
    assert not np.array_equal(a.next()["tokens"], b.next()["tokens"])


def test_state_roundtrip():
    a = SyntheticLM(100, 8, 2, seed=1)
    [a.next() for _ in range(5)]
    st = a.state()
    b = SyntheticLM(100, 8, 2, seed=1)
    b.load_state(st)
    np.testing.assert_array_equal(a.next()["tokens"], b.next()["tokens"])


def test_codebook_shape():
    a = SyntheticLM(64, 8, 2, seed=0, n_codebooks=4)
    assert a.next()["tokens"].shape == (2, 9, 4)


@pytest.fixture
def token_file(tmp_path):
    path = tmp_path / "tokens.bin"
    np.arange(10_000, dtype=np.uint32).tofile(path)
    return str(path)


def test_memmap_shards_disjoint_within_step(token_file):
    s0 = MemmapTokens(token_file, 32, 2, ShardInfo(0, 2), seed=3)
    s1 = MemmapTokens(token_file, 32, 2, ShardInfo(1, 2), seed=3)
    a, b = s0.next()["tokens"], s1.next()["tokens"]
    assert set(a[:, 0]).isdisjoint(set(b[:, 0]))


def test_memmap_epoch_reshuffles(token_file):
    src = MemmapTokens(token_file, 32, 2, ShardInfo(0, 1), seed=3)
    steps = src.n_windows // 2
    first_epoch = [src.next()["tokens"][:, 0].copy() for _ in range(steps)]
    second_epoch = [src.next()["tokens"][:, 0].copy() for _ in range(steps)]
    assert not all(
        np.array_equal(x, y) for x, y in zip(first_epoch, second_epoch)
    )
    # coverage identical up to the sub-batch remainder of the permutation
    a = set(np.concatenate(first_epoch))
    b = set(np.concatenate(second_epoch))
    assert len(a ^ b) <= 2 * (src.n_windows % 2 + 2)


def test_memmap_seek_matches_straight_run(token_file):
    a = MemmapTokens(token_file, 32, 2, seed=5)
    want = [a.next()["tokens"] for _ in range(6)][5]
    b = MemmapTokens(token_file, 32, 2, seed=5)
    b.seek(5)
    np.testing.assert_array_equal(b.next()["tokens"], want)


def test_packing_offsets_match_host_cumsum():
    """The engine-scan packing offsets == the host numpy cumsum EXACTLY on
    every backend the 1D site can take (totals < 2^24 keep the f32 prefix
    integer-exact), including zero-length documents."""
    from repro.data import packing_offsets

    lengths = [5, 0, 3, 128, 1]
    want = np.concatenate([[0], np.cumsum(lengths)]).astype(np.int32)
    for backend in (None, "xla", "mma_jnp"):
        got = np.asarray(packing_offsets(lengths, backend=backend))
        np.testing.assert_array_equal(got, want)
        assert got.dtype == np.int32
    # a realistic ragged shard: hundreds of documents, offsets into the
    # millions -- still exact
    big = np.random.RandomState(0).randint(0, 2048, size=513)
    want = np.concatenate([[0], np.cumsum(big)]).astype(np.int32)
    for backend in ("xla", "mma_jnp"):
        np.testing.assert_array_equal(
            np.asarray(packing_offsets(big, backend=backend)), want
        )


def test_packing_offsets_rejects_batched_lengths():
    from repro.data import packing_offsets

    with pytest.raises(ValueError):
        packing_offsets(np.zeros((2, 3), np.int32))


def test_prefetcher_preserves_order():
    src = SyntheticLM(100, 8, 2, seed=2)
    ref = SyntheticLM(100, 8, 2, seed=2)
    pf = Prefetcher(src)
    try:
        for _ in range(5):
            np.testing.assert_array_equal(pf.next()["tokens"], ref.next()["tokens"])
    finally:
        pf.close()
