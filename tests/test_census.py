"""In-launch non-finite census + the guarded (bitwise-skip) optimizer.

The census is the guarded training loop's detector: the SAME launch that
computes the clipping statistic also counts every NaN/Inf gradient element
(per leaf and total, zero extra HBM input bytes on the kernel backends).
These tests pin:

  * count agreement across every registered backend, including NaN in the
    ragged masked-tail region and Inf, per-leaf layout and the total slot;
  * clean trees count zero AND the statistic is unchanged by asking;
  * gradients still flow through a census launch (counts are piecewise
    constant: their cotangents drop);
  * the direct kernel entry points (fused scalar, segmented);
  * the empty-"mean" NaN is DEFINED, not a fault: the census never counts
    a statistic, only input elements (satellite: mean empty-input pin);
  * legacy Backend subclasses that predate the census parameter degrade to
    the host reference census, same layout and values;
  * the guarded optimizer: unskipped steps BITWISE equal ``apply_updates``,
    poisoned/spiking steps pass params and state through BITWISE unchanged,
    the loss window only advances on accepted steps, and the whole jitted
    update lowers with no is_finite/select_n outside the kernel.
"""

import warnings

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro import optim
from repro import reduce as R
from repro.configs import TrainConfig
from repro.kernels.mma_reduce import ops
from repro.optim import adamw
from repro.reduce import backends as B
from repro.reduce import inspect as rinspect

BACKENDS = R.available_backends()


def _bitwise_equal(a, b):
    la, lb = jax.tree.leaves(a), jax.tree.leaves(b)
    return len(la) == len(lb) and all(
        np.asarray(x).tobytes() == np.asarray(y).tobytes()
        for x, y in zip(la, lb)
    )


def _poisoned_tree():
    """Leaf order (tree_leaves, dict keys sorted): b[0], b[1], w.
    Expected per-leaf non-finite counts [2, 0, 1], total 3."""
    b0 = np.linspace(-1, 1, 3000).astype(np.float32)
    b0[7] = np.inf
    b0[2999] = np.nan  # last element: the ragged masked-tail region
    w = np.full((17, 33), 0.25, np.float32)
    w[3, 5] = np.nan
    return {
        "w": jnp.asarray(w, jnp.bfloat16),
        "b": [jnp.asarray(b0), jnp.ones((), jnp.float32)],
    }


@pytest.mark.parametrize("backend", BACKENDS)
def test_census_counts_agree_across_backends(backend):
    tree = _poisoned_tree()
    out, counts = R.reduce_tree(tree, "sumsq", backend=backend, census=True)
    assert counts.shape == (4,)
    np.testing.assert_array_equal(np.asarray(counts), [2.0, 0.0, 1.0, 3.0])
    assert not np.isfinite(float(out))  # the statistic itself is poisoned


@pytest.mark.parametrize("backend", BACKENDS)
def test_census_clean_tree_counts_zero_and_stat_unchanged(backend):
    tree = {
        "w": jnp.full((40, 256), 0.5, jnp.bfloat16),
        "b": [jnp.linspace(0, 1, 3001, dtype=jnp.float32), jnp.ones(())],
    }
    plain = R.reduce_tree(tree, "norm2", backend=backend)
    out, counts = R.reduce_tree(tree, "norm2", backend=backend, census=True)
    np.testing.assert_array_equal(np.asarray(counts), 0.0)
    assert float(out) == pytest.approx(float(plain), rel=1e-6)


def test_census_per_leaf_and_fork_layout():
    """return_per_leaf + epilogue fork + census from the ONE launch: the
    4-tuple unpack the fused-second-moment guarded optimizer relies on."""
    tree = _poisoned_tree()
    per_leaf, gnorm, clip, counts = adamw.global_norm_and_clip(
        tree, 1.0, backend="pallas_fused", return_per_leaf=True, census=True
    )
    assert per_leaf.shape == (3,)
    assert counts.shape == (4,)
    assert float(counts[-1]) == 3.0
    assert float(counts[-1]) == float(jnp.sum(counts[:-1]))


def test_census_empty_tree():
    out, counts = R.reduce_tree({}, "sumsq", census=True)
    assert float(out) == 0.0
    np.testing.assert_array_equal(np.asarray(counts), [0.0])


def test_census_empty_leaf_counts_zero():
    tree = {"a": jnp.zeros((0,), jnp.float32), "b": jnp.ones((5,))}
    out, counts = R.reduce_tree(tree, "sum", backend="xla", census=True)
    np.testing.assert_array_equal(np.asarray(counts), [0.0, 0.0, 0.0])
    assert float(out) == 5.0


def test_census_integer_leaves_count_zero():
    tree = {"i": jnp.arange(7, dtype=jnp.int32), "x": jnp.ones((9,))}
    _, counts = R.reduce_tree(tree, "sum", backend="mma_jnp", census=True)
    np.testing.assert_array_equal(np.asarray(counts), [0.0, 0.0, 0.0])


@pytest.mark.parametrize("backend", ("pallas_fused", "pallas_hier"))
def test_grads_flow_through_census_launch(backend):
    tree = {"w": jnp.linspace(-1.0, 1.0, 600).reshape(3, 200)}

    def stat(t):
        out, _ = R.reduce_tree(t, "sumsq", backend=backend, census=True)
        return out

    g = jax.grad(stat)(tree)
    np.testing.assert_allclose(
        np.asarray(g["w"]), 2.0 * np.asarray(tree["w"]), rtol=2e-2, atol=1e-3
    )


def test_fused_scalar_census_entry():
    x = np.linspace(0, 2, 70_001).astype(np.float32)
    x[13] = np.nan
    x[70_000] = np.inf  # last element: lives in the masked ragged tail tile
    total, cnt = ops.mma_sum_pallas(jnp.asarray(x), census=True)
    assert float(cnt) == 2.0
    clean = np.nan_to_num(x, nan=0.0, posinf=0.0)
    total2, cnt2 = ops.mma_sum_pallas(jnp.asarray(clean), census=True)
    assert float(cnt2) == 0.0
    assert float(total2) == pytest.approx(float(np.sum(clean)), rel=2e-2)
    assert not np.isfinite(float(total))


def test_segmented_census_entry():
    n = 40_000
    x = np.ones(n, np.float32)
    offsets = (0, 1000, 1000, 25_000, n)  # segment 1 is empty
    x[0] = np.nan
    x[24_999] = np.inf
    out = ops.mma_sum_segments_pallas(jnp.asarray(x), offsets, census=True)
    nseg = len(offsets) - 1
    assert out.shape == (2 * nseg,)
    np.testing.assert_array_equal(np.asarray(out[nseg:]), [1.0, 0.0, 1.0, 0.0])
    # empty segment: additive identity, zero count
    assert float(out[1]) == 0.0


@pytest.mark.parametrize("num_cores", (1, 2, 3))
def test_segmented_offsets_zero_length_middle_segment(num_cores):
    """OFFSETS-path pin (the existing empty-segment coverage rode the parts
    path): a zero-length MIDDLE segment contributes exactly the additive
    identity 0.0 and a census count of 0, at every lane count -- its
    neighbours' totals are unaffected (no tile of the cover may leak across
    the empty boundary)."""
    n = 40_000
    x = np.ones(n, np.float32)
    offsets = (0, 1000, 1000, 25_000, n)  # segment 1 is empty, mid-buffer
    out = ops.mma_sum_segments_pallas(
        jnp.asarray(x), offsets, num_cores=num_cores, census=True
    )
    np.testing.assert_array_equal(
        np.asarray(out), [1000.0, 0.0, 24_000.0, 15_000.0, 0, 0, 0, 0]
    )
    # a poisoned neighbour never bleeds its count into the empty slot
    x[999] = np.nan   # last element of segment 0
    x[1000] = np.inf  # first element of segment 2
    out = ops.mma_sum_segments_pallas(
        jnp.asarray(x), offsets, num_cores=num_cores, census=True
    )
    np.testing.assert_array_equal(np.asarray(out[4:]), [1.0, 0.0, 1.0, 0.0])
    assert float(out[1]) == 0.0


@pytest.mark.parametrize("num_cores", (1, 2))
def test_segmented_empty_middle_segment_epilogue_lane_invariant(num_cores):
    """REGRESSION (found by the zero-length-middle sweep): an empty segment
    never flushes, so the IN-KERNEL epilogue (single-lane launches) never
    mapped its slot -- it came back as raw 0.0 while the multi-lane host
    path and the all-empty path return epilogue(0) (= 1.0 for clip_coeff:
    zero norm clips nothing). The epilogue'd result must not depend on
    num_cores."""
    x = jnp.ones((40_000,), jnp.float32)
    offsets = (0, 1000, 1000, 25_000, 40_000)
    chain = ("clip_coeff", 100.0, 1e-6)
    out = np.asarray(ops.mma_sum_segments_pallas(
        x, offsets, num_cores=num_cores, epilogue=chain,
        compute_dtype=jnp.float32,
    ))
    from repro.kernels import common as _c
    want_empty = float(_c.apply_epilogue(
        jnp.zeros(()), _c.normalize_epilogue(chain)
    ))
    assert out[1] == want_empty, (num_cores, out)
    # non-empty slots: min(1, 100/size), identical at every lane count
    np.testing.assert_allclose(
        out[[0, 2, 3]], [0.1, 100.0 / 24_000, 100.0 / 15_000], rtol=1e-5
    )


def test_mean_empty_is_defined_nan_not_a_fault():
    """Satellite pin: an empty full "mean" is 0/0 -> NaN BY DEFINITION
    (numpy semantics), not a faulted step -- and the census tallies INPUT
    elements only, so the empty mean never increments it."""
    r = R.reduce(jnp.zeros((0,), jnp.float32), kind="mean")
    assert np.isnan(float(r))
    with warnings.catch_warnings():  # numpy warns on its own 0/0 here
        warnings.simplefilter("ignore", RuntimeWarning)
        assert np.isnan(float(np.mean(np.zeros((0,), np.float32))))
    _, counts = R.reduce_tree(
        {"e": jnp.zeros((0,), jnp.float32)}, "sum", census=True
    )
    np.testing.assert_array_equal(np.asarray(counts), [0.0, 0.0])


def test_legacy_backend_without_census_param_degrades():
    """A Backend subclass written before the census parameter existed must
    still serve census=True: the dispatcher appends the host reference
    census to its row -- same layout, same values as the in-kernel count."""
    xla_cls = type(B.get_backend("xla"))

    class Legacy(xla_cls):
        name = "legacy-test"

        def sum_parts_total(self, parts, plan, prologue="identity",
                            total_chains=((),)):
            return super().sum_parts_total(parts, plan, prologue, total_chains)

    tree = _poisoned_tree()
    parts = jax.tree.leaves(tree)
    plan = R.plan_for(
        (sum(p.size for p in parts),), jnp.float32, kind="sum", backend="xla",
        segments=len(parts),
    )
    legacy = B.sum_parts_total_with_census(
        Legacy(), parts, plan, "identity", ((),), True
    )
    native = B.sum_parts_total_with_census(
        B.get_backend("xla"), parts, plan, "identity", ((),), True
    )
    assert legacy.shape == native.shape
    np.testing.assert_array_equal(  # census slots: [S+K:] with K=1
        np.asarray(legacy[-4:]), np.asarray(native[-4:])
    )
    np.testing.assert_array_equal(np.asarray(legacy[-4:]), [2.0, 0.0, 1.0, 3.0])


# --------------------- guarded optimizer (bitwise skip) ---------------------


def _params():
    return {
        "w": jnp.full((40, 64), 0.5, jnp.float32),
        "b": jnp.linspace(-1, 1, 300, dtype=jnp.float32),
    }


@pytest.mark.parametrize("fused", (False, True))
def test_guarded_clean_step_bitwise_equals_unguarded(fused):
    tcfg = TrainConfig()
    params = _params()
    grads = jax.tree.map(lambda p: 0.01 * jnp.ones_like(p), params)
    state = optim.init_state(params, fused_second_moment=fused)
    ref_p, ref_s, _ = optim.apply_updates(
        params, grads, state, tcfg, reduce_backend="pallas_fused",
        fused_second_moment=fused,
    )
    new_p, new_s, guard, metrics = optim.guarded_apply_updates(
        params, grads, state, tcfg, loss=jnp.float32(1.0),
        guard=optim.init_guard_state(8), reduce_backend="pallas_fused",
        fused_second_moment=fused,
    )
    assert _bitwise_equal(new_p, ref_p)
    assert _bitwise_equal(new_s, ref_s)
    assert float(metrics["skipped"]) == 0.0
    assert float(metrics["nonfinite"]) == 0.0
    assert int(guard.skipped) == 0
    assert int(guard.filled) == 1  # accepted finite loss entered the window


@pytest.mark.parametrize("bad", (np.nan, np.inf, -np.inf))
def test_guarded_skips_poisoned_step_bitwise(bad):
    tcfg = TrainConfig()
    params = _params()
    g = np.full((40, 64), 0.01, np.float32)
    g[11, 3] = bad
    grads = {"w": jnp.asarray(g), "b": 0.01 * jnp.ones((300,), jnp.float32)}
    state = optim.init_state(params)
    guard0 = optim.init_guard_state(8)
    new_p, new_s, guard, metrics = optim.guarded_apply_updates(
        params, grads, state, tcfg, loss=jnp.float32(1.0), guard=guard0,
        reduce_backend="pallas_fused",
    )
    assert _bitwise_equal(new_p, params)
    assert _bitwise_equal(new_s, state)
    assert float(metrics["skipped"]) == 1.0
    assert float(metrics["nonfinite"]) == 1.0
    assert int(guard.skipped) == 1
    # skipped steps must not advance the loss window either
    assert _bitwise_equal(guard.window, guard0.window)
    assert int(guard.filled) == 0


def test_loss_spike_forces_skip_and_recovers():
    tcfg = TrainConfig()
    params = _params()
    grads = jax.tree.map(lambda p: 0.01 * jnp.ones_like(p), params)
    state = optim.init_state(params)
    guard = optim.init_guard_state(8)
    # fill the window with accepted ~1.0 losses (slight spread: a genuine
    # MAD so the detector has a scale)
    for i in range(8):
        params, state, guard, m = optim.guarded_apply_updates(
            params, grads, state, tcfg, loss=jnp.float32(1.0 + 0.01 * i),
            guard=guard, reduce_backend="pallas_fused",
        )
        assert float(m["skipped"]) == 0.0
    assert int(guard.filled) == 8
    p_before, s_before = params, state
    params, state, guard, m = optim.guarded_apply_updates(
        params, grads, state, tcfg, loss=jnp.float32(50.0), guard=guard,
        reduce_backend="pallas_fused",
    )
    assert float(m["spike"]) == 1.0 and float(m["skipped"]) == 1.0
    assert _bitwise_equal(params, p_before)
    assert _bitwise_equal(state, s_before)
    # a normal loss right after is accepted again (window never ate the 50)
    params, state, guard, m = optim.guarded_apply_updates(
        params, grads, state, tcfg, loss=jnp.float32(1.05), guard=guard,
        reduce_backend="pallas_fused",
    )
    assert float(m["skipped"]) == 0.0


def test_guarded_update_lowers_census_free_single_launch():
    tcfg = TrainConfig()
    params = _params()
    grads = jax.tree.map(jnp.ones_like, params)
    state = optim.init_state(params)
    guard = optim.init_guard_state(8)
    loss = jnp.zeros((), jnp.float32)

    def gstep(p, g, s, gu, lo):
        return optim.guarded_apply_updates(
            p, g, s, tcfg, loss=lo, guard=gu, reduce_backend="pallas_fused"
        )

    rinspect.assert_census_free(gstep, params, grads, state, guard, loss)
    n = rinspect.count_pallas_calls(gstep, params, grads, state, guard, loss)
    assert n == 1


def test_guarded_update_donation_safe():
    """donate params/state/guard: the bitwise blend writes into the donated
    buffers on skip and advance alike -- two chained calls must work."""
    tcfg = TrainConfig()
    params = _params()
    grads = jax.tree.map(lambda p: 0.01 * jnp.ones_like(p), params)
    state = optim.init_state(params)
    guard = optim.init_guard_state(4)

    donating = jax.jit(
        lambda p, g, s, gu, lo: optim.guarded_apply_updates(
            p, g, s, tcfg, loss=lo, guard=gu, reduce_backend="pallas_fused"
        ),
        donate_argnums=(0, 2, 3),
    )
    params, state, guard, m1 = donating(
        params, grads, state, guard, jnp.float32(1.0)
    )
    params, state, guard, m2 = donating(
        params, grads, state, guard, jnp.float32(1.1)
    )
    assert float(m1["skipped"]) == 0.0 and float(m2["skipped"]) == 0.0
    assert int(guard.filled) == 2
