"""Multi-device collective tests. jax locks the host device count at first
init, so these run in a subprocess with XLA_FLAGS=8 fake devices -- keeping
the main pytest process single-device per the dry-run isolation rule."""

import os
import subprocess
import sys
import textwrap

import pytest

REPO_SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def run_sub(body: str):
    code = textwrap.dedent(
        """
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
        from repro.core import collectives as C
        """
    ) + textwrap.dedent(body)
    env = dict(os.environ, PYTHONPATH=REPO_SRC)
    env.pop("XLA_FLAGS", None)
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, env=env, timeout=420)
    assert r.returncode == 0, f"STDOUT:\n{r.stdout}\nSTDERR:\n{r.stderr[-3000:]}"
    return r.stdout


def test_hierarchical_psum_and_mma_local():
    run_sub("""
    mesh = jax.make_mesh((2, 4), ("data", "model"))
    x = jnp.arange(32.0).reshape(8, 4)

    def body(xs):
        return C.local_mma_then_psum(xs, ("model", "data"))

    out = jax.jit(C.shard_map(body, mesh=mesh,
                                in_specs=P("data", "model"),
                                out_specs=P()))(x)
    np.testing.assert_allclose(float(out), float(x.sum()), rtol=1e-5)
    print("hierarchical ok")
    """)


def test_ring_all_reduce_matches_psum():
    run_sub("""
    mesh = jax.make_mesh((8,), ("data",))
    x = jnp.arange(8 * 13, dtype=jnp.float32).reshape(8, 13)

    def body(xs):
        ring = C.ring_all_reduce(xs, "data")
        ref = jax.lax.psum(xs, "data")
        return ring, ref

    ring, ref = jax.jit(C.shard_map(body, mesh=mesh,
                                      in_specs=P("data", None),
                                      out_specs=(P("data", None), P("data", None))))(x)
    np.testing.assert_allclose(np.asarray(ring), np.asarray(ref), rtol=1e-6)
    print("ring ok")
    """)


def test_compressed_psum_error_feedback():
    run_sub("""
    mesh = jax.make_mesh((8,), ("pod",))
    x = jax.random.normal(jax.random.PRNGKey(0), (8, 64))

    def body(xs, err):
        out, new_err = C.compressed_psum(xs, "pod", err)
        ref = jax.lax.psum(xs, "pod")
        return out, new_err, ref

    f = jax.jit(C.shard_map(body, mesh=mesh,
                              in_specs=(P("pod", None), P("pod", None)),
                              out_specs=(P("pod", None),) * 3))
    err = jnp.zeros_like(x)
    out, err, ref = f(x, err)
    rel = float(jnp.max(jnp.abs(out - ref)) / jnp.max(jnp.abs(ref)))
    assert rel < 0.05, rel          # int8 quantization error bounded
    # error feedback: the residual carried forward equals what was lost
    # so repeated reduction of a CONSTANT gradient converges in mean
    acc = jnp.zeros_like(out)
    e = jnp.zeros_like(x)
    for i in range(20):
        o, e, _ = f(x, e)
        acc = acc + o
    drift = float(jnp.max(jnp.abs(acc / 20 - ref)))
    assert drift < float(jnp.max(jnp.abs(ref))) * 0.01, drift
    print("compressed ok")
    """)


def test_sharded_train_step_runs_on_mesh():
    """End-to-end: FSDP+TP sharded train step on a (2,4) mesh, real numerics
    (tiny olmo), asserting the loss is finite and params update."""
    run_sub("""
    import dataclasses
    from repro.configs import TINY_ARCHS, TrainConfig
    from repro.launch import sharding as SH
    from repro.launch.steps import make_train_step
    from repro.models import init_params, context as CTX
    from repro import optim

    mesh = jax.make_mesh((2, 4), ("data", "model"))
    CTX.set_activation_sharding(NamedSharding(mesh, P("data", None, None)))
    cfg = TINY_ARCHS["internlm2-1.8b"]
    params, axes = init_params(jax.random.PRNGKey(0), cfg)
    pshard = SH.param_shardings(axes, mesh, SH.DEFAULT_RULES, params)
    params = jax.tree.map(jax.device_put, params, pshard)
    opt = optim.init_state(params)
    step = jax.jit(make_train_step(cfg, TrainConfig(microbatches=2), mesh,
                                   param_shardings=pshard))
    toks = jax.random.randint(jax.random.PRNGKey(1), (8, 32), 0, cfg.vocab_size)
    toks = jax.device_put(toks, NamedSharding(mesh, P("data", None)))
    p1, o1, m = step(params, opt, {"tokens": toks})
    assert np.isfinite(float(m["loss"]))
    delta = sum(float(jnp.max(jnp.abs(a - b))) for a, b in
                zip(jax.tree.leaves(p1), jax.tree.leaves(params)))
    assert delta > 0
    print("sharded step ok, loss", float(m["loss"]))
    """)
