"""Fused matmul+moments kernel vs oracle (the epilogue-fusion deployment of
the paper's reduction)."""

from _optional_hypothesis import hypothesis, st
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.matmul_stats import matmul_stats, matmul_stats_ref


@pytest.mark.parametrize("m,k,n", [(8, 16, 32), (64, 128, 256), (100, 300, 500),
                                   (256, 512, 384), (33, 65, 129)])
def test_matches_oracle(m, k, n, rng):
    x = jnp.asarray(rng.randn(m, k).astype(np.float32)) * 0.3
    w = jnp.asarray(rng.randn(k, n).astype(np.float32)) * 0.3
    y, s, ss = matmul_stats(x, w, bm=64, bn=128, bk=128)
    yr, sr, ssr = matmul_stats_ref(x, w)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr), atol=1e-4)
    np.testing.assert_allclose(np.asarray(s), np.asarray(sr), atol=1e-2)
    np.testing.assert_allclose(np.asarray(ss), np.asarray(ssr), rtol=1e-3,
                               atol=1e-2)


def test_block_shape_invariance(rng):
    x = jnp.asarray(rng.randn(128, 256).astype(np.float32))
    w = jnp.asarray(rng.randn(256, 512).astype(np.float32))
    a = matmul_stats(x, w, bm=128, bn=512, bk=256)
    b = matmul_stats(x, w, bm=64, bn=128, bk=64)
    for u, v in zip(a, b):
        np.testing.assert_allclose(np.asarray(u), np.asarray(v), rtol=1e-4,
                                   atol=1e-2)


@hypothesis.settings(max_examples=10, deadline=None)
@hypothesis.given(m=st.integers(1, 96), k=st.integers(2, 200),
                  n=st.integers(2, 200), seed=st.integers(0, 2**31 - 1))
def test_property_moments_consistent(m, k, n, seed):
    """sumsq >= sum^2 / N (Cauchy-Schwarz) and both match the oracle."""
    r = np.random.RandomState(seed)
    x = jnp.asarray(r.randn(m, k).astype(np.float32)) * 0.2
    w = jnp.asarray(r.randn(k, n).astype(np.float32)) * 0.2
    _, s, ss = matmul_stats(x, w, bm=32, bn=64, bk=64)
    s, ss = np.asarray(s, np.float64), np.asarray(ss, np.float64)
    assert (ss + 1e-4 >= s**2 / n).all()
