"""The differential harness sweep: every kernel path x every prologue.

Four layers, all driven through tests/harness.py so each cell is judged by
the same oracle and the same budget:

  1. ENGINE CELLS  -- (backend x kind x dtype x num_cores) through the
     public ``reduce`` API vs the f64 numpy oracle.
  2. KERNEL BODIES -- all four Pallas kernel bodies (fused, tile-partials,
     segmented gather, parts) x all prologues (identity / square / abs /
     moments) against the op-for-op ``ref.py`` emulations -- BIT-FOR-BIT
     wherever the contract guarantees it (f32 compute; precision-exact
     maps), budgeted on the one documented exception (bf16/f16 square
     under XLA excess precision).
  3. TRAFFIC       -- ``cost_model.hbm_bytes`` == the bytes crossing the
     lowered ``pallas_call`` boundary for every prologue x path
     combination, and the traced MMA splits == the cost model.
  4. PROPERTIES    -- hypothesis sweeps: ragged n x dtype x cores x kind
     vs the oracle (tail-masked squares never contribute), num_cores=1
     bit-identity against the jnp emulation, and the norm2 gradient
     against xla autodiff.

This file runs as its OWN CI job (interpret mode) so kernel-body
regressions are attributed separately from dispatch regressions.
"""

import harness
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _optional_hypothesis import hypothesis, st

from repro import reduce as R
from repro.core import cost_model
from repro.kernels import common
from repro.kernels.mma_reduce import kernel as K
from repro.kernels.mma_reduce import ops, ref
from repro.reduce import inspect as rinspect

M = common.MXU
GROUP = M * M

# one ragged size that straddles a tile boundary AND leaves a masked tail
N_CELL = GROUP + 4097


def _cell_ids():
    for backend in harness.BACKENDS:
        cores = (1, 2) if backend in harness.PALLAS_BACKENDS else (1,)
        for kind in harness.KINDS:
            for dt in harness.DTYPES:
                for c in cores:
                    yield backend, kind, dt, c


@pytest.mark.parametrize(
    "backend,kind,dt,num_cores",
    list(_cell_ids()),
    ids=lambda v: str(v),
)
def test_engine_cell_vs_oracle(backend, kind, dt, num_cores):
    """Layer 1: the full (backend x kind x dtype x cores) product."""
    harness.run_cell(backend, kind, dt, N_CELL, num_cores)


@pytest.mark.parametrize("n", [1, 100, GROUP - 1, GROUP + 1, 50_001])
@pytest.mark.parametrize("kind", ["sum", "sumsq", "norm2", "moments"])
def test_ragged_cells_all_pallas(n, kind):
    """Layer 1b: ragged boundary sizes through both kernel backends."""
    for backend in harness.PALLAS_BACKENDS:
        harness.run_cell(backend, kind, "float32", n, num_cores=2, seed=n)


# ---------------------- layer 2: kernel bodies x prologues -------------------


@pytest.mark.parametrize("prologue", harness.PROLOGUES)
@pytest.mark.parametrize("num_cores", [1, 2, 3])
def test_fused_body_matches_emulation(prologue, num_cores, rng):
    """fused_accumulate / fused_moments lane partials vs fused_lanes_ref:
    bit-exact at f32 compute for EVERY prologue and lane geometry."""
    x = jnp.asarray(rng.randn(50_001).astype(np.float32))
    got = K.reduce_fused(
        x, num_cores=num_cores, prologue=prologue, compute_dtype=jnp.float32
    )
    want = ref.fused_lanes_ref(
        x, num_cores=num_cores, prologue=prologue, compute_dtype=jnp.float32
    )
    harness.assert_bits_equal(got, want, f"{prologue} c={num_cores}")


@pytest.mark.parametrize("dt", ["bfloat16", "float16"])
@pytest.mark.parametrize("prologue", harness.PROLOGUES)
def test_fused_body_low_precision_contract(dt, prologue, rng):
    """The documented low-precision contract: identity/abs stay bitwise at
    any compute width; bf16/f16 square (and the moments squares) agree
    within the mass budget (XLA excess-precision exception)."""
    x = jnp.asarray(rng.randn(30_000)).astype(dt)
    cd = jnp.dtype(dt)
    got = np.asarray(K.reduce_fused(x, num_cores=2, prologue=prologue,
                                    compute_dtype=cd))
    want = np.asarray(ref.fused_lanes_ref(x, num_cores=2, prologue=prologue,
                                          compute_dtype=cd))
    if harness.expect_bitwise(prologue, cd):
        harness.assert_bits_equal(got, want, f"{prologue} {dt}")
    else:
        tol = harness.mass_tol(
            np.square(np.asarray(x, np.float64)), rel=harness.COMPUTE_REL[dt]
        )
        assert float(np.abs(got - want).max()) <= tol, (prologue, dt)


@pytest.mark.parametrize("prologue", harness.PROLOGUES)
def test_tile_partials_body_matches_two_mma_ref(prologue, rng):
    """tile_partials_kernel x prologue vs the eq. (9)-(12) emulation."""
    n = 5 * GROUP + 321
    x = jnp.asarray(rng.randn(n).astype(np.float32))
    got = K.reduce_tiles(x, compute_dtype=jnp.float32, prologue=prologue)
    tpad = -(-n // GROUP)
    tiles = ref._native_tiles(x, tpad, M).astype(jnp.float32)
    if prologue == "moments":
        assert got.shape == (tpad, 2)
        want = jnp.stack(
            [
                ref.two_mma_ref(tiles, compute_dtype=jnp.float32),
                ref.two_mma_ref(tiles * tiles, compute_dtype=jnp.float32),
            ],
            axis=1,
        )
    else:
        want = ref.two_mma_ref(
            common.apply_prologue(tiles, prologue), compute_dtype=jnp.float32
        )
    harness.assert_bits_equal(got, want, prologue)


@pytest.mark.parametrize("prologue", harness.PROLOGUES)
@pytest.mark.parametrize("num_cores", [1, 2, 3])
def test_segmented_body_all_prologues(prologue, num_cores, rng):
    """segmented_gather_kernel x prologue vs the per-segment oracle,
    across boundary-hostile layouts ("moments": the widened 2S layout)."""
    for sizes in ([100, 64, 1, 200], [16384, 1, 16385], [0, 3, 0], [7] * 9):
        offsets = np.concatenate([[0], np.cumsum(sizes)]).tolist()
        flat = jnp.asarray(rng.randn(int(offsets[-1])).astype(np.float32))
        got = ops.mma_sum_segments_pallas(
            flat, offsets, num_cores=num_cores,
            compute_dtype=jnp.float32, prologue=prologue,
        )
        want = ref.segmented_sum_ref(flat, offsets, prologue)
        assert got.shape == want.shape, (sizes, prologue)
        np.testing.assert_allclose(
            np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-4,
            err_msg=f"sizes={sizes} prologue={prologue} c={num_cores}",
        )


def test_parts_body_mixed_prologues(rng):
    """parts_accumulate_kernel with a DIFFERENT prologue per part (incl. the
    dual-accumulator), one launch, vs parts_sum_ref."""
    arrs = [
        jnp.asarray(rng.randn(s).astype(np.float32))
        for s in (5, GROUP, GROUP + 33, 1, 20_000)
    ]
    pros = ("identity", "square", "abs", "moments", "moments")
    got = ops.mma_sum_parts_pallas(
        arrs, compute_dtype=jnp.float32, prologue=pros
    )
    want = ref.parts_sum_ref(arrs, pros)
    assert got.shape == (2 * len(arrs),)
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-4
    )
    # non-moments parts leave their square slot at the additive identity
    assert float(got[len(arrs) + 0]) == 0.0
    assert float(got[len(arrs) + 1]) == 0.0


@pytest.mark.parametrize("num_cores", [1, 2, 4])
def test_f32_tile_multiple_sum_bit_identical_across_prologue_rewire(
    num_cores, rng
):
    """Acceptance: the identity prologue adds NO ops, so f32 tile-multiple
    kind="sum" results stay bit-identical to the PR-4 kernels (pinned
    through the unchanged emulation) at every lane count."""
    x = jnp.asarray(rng.randn(24 * GROUP).astype(np.float32))
    got = K.reduce_fused(x, num_cores=num_cores)
    want = ref.fused_lanes_ref(x, num_cores=num_cores)
    harness.assert_bits_equal(got, want)
    a = np.asarray(
        R.reduce(x, backend="pallas_fused", num_cores=num_cores), np.float32
    )
    b = np.asarray(ops.combine_lane_partials(jnp.asarray(want)), np.float32)
    harness.assert_bits_equal(a, b)


# ---------------------- layer 3: traffic and trace proofs --------------------


def _io(fn, *args):
    return rinspect.pallas_io_bytes(jax.make_jaxpr(fn)(*args))


@pytest.mark.parametrize("dt,bs", [(jnp.bfloat16, 2), (jnp.float32, 4)])
def test_fused_prologue_hbm_model_matches_lowered_io(dt, bs):
    """cost_model == pallas_io_bytes for the fused path x every prologue:
    square/abs move the SAME bytes as identity (the single-stream win);
    moments doubles only the partial term."""
    n = 300_000
    x = jnp.zeros((n,), dt)
    for c in (1, 2):
        plan = R.plan_for((n,), dt, kind="sumsq", backend="pallas_fused",
                          num_cores=c)
        model = cost_model.fused_hbm_bytes(n, bs, num_cores=c)
        for kind in ("sumsq", "norm2"):
            got = _io(lambda v, k=kind, p=plan: R.reduce(v, kind=k, plan=p), x)
            assert got == model.launch_io, (kind, c)
            assert plan.hbm_bytes(n, dt, prologue="square").total == model.total
        # identity baseline: byte-identical launch
        plan_s = R.plan_for((n,), dt, backend="pallas_fused", num_cores=c)
        assert _io(lambda v, p=plan_s: R.reduce(v, plan=p), x) == model.launch_io
        # moments: the dual-accumulator partials
        dual = cost_model.fused_hbm_bytes(n, bs, num_cores=c, dual=True)
        plan_m = R.plan_for((n,), dt, kind="moments", backend="pallas_fused",
                            num_cores=c)
        got = _io(lambda v, p=plan_m: R.reduce(v, kind="moments", plan=p), x)
        assert got == dual.launch_io, c
        assert plan_m.hbm_bytes(n, dt, prologue="moments").total == dual.total
        tr = []
        ops.mma_moments_pallas(x, num_cores=c, trace=tr)
        assert tr[0].hbm_bytes == dual.total


def test_hier_prologue_hbm_model_matches_lowered_io():
    n = 300_000
    x = jnp.zeros((n,), jnp.bfloat16)
    plan = R.plan_for((n,), jnp.bfloat16, kind="sumsq", backend="pallas_hier")
    model = cost_model.hier_hbm_bytes(n, 2)
    got = _io(lambda v, p=plan: R.reduce(v, kind="sumsq", plan=p), x)
    assert got == model.launch_io
    assert plan.hbm_bytes(n, jnp.bfloat16, prologue="square").total == model.total
    # moments: dual level-0 emit + two f32 column hierarchies
    dual = cost_model.hier_moments_hbm_bytes(n, 2)
    plan_m = plan.replace(backend="pallas_hier")
    got = _io(
        lambda v, p=plan_m: R.reduce(v, kind="moments", plan=p,
                                     backend="pallas_hier"), x
    )
    assert got == dual.launch_io
    assert plan_m.hbm_bytes(n, jnp.bfloat16, prologue="moments").total \
        == dual.total
    tr = []
    ops.mma_moments_pallas(x, mode="hierarchical", trace=tr)
    assert tr[0].hbm_bytes == dual.total


def test_parts_prologue_hbm_model_matches_lowered_io():
    sizes = (70_000, 33, 20_000, 0)
    arrs = [jnp.zeros((s,), jnp.bfloat16) for s in sizes]
    nbytes = sum(a.nbytes for a in arrs)
    # square: identical bytes to the identity parts pass
    model = cost_model.parts_hbm_bytes(nbytes, segments=len(arrs))
    got = _io(
        lambda a: R.reduce_many(a, kind="sumsq", backend="pallas_fused"), arrs
    )
    assert got == model.launch_io
    # moments: same reads, widened (2S,) output
    dual = cost_model.parts_hbm_bytes(nbytes, segments=2 * len(arrs))
    got = _io(
        lambda a: R.reduce_many(a, kind="moments", backend="pallas_fused"),
        arrs,
    )
    assert got == dual.launch_io
    tr = []
    ops.mma_sum_parts_pallas(arrs, prologue="moments", trace=tr)
    assert tr[0].hbm_bytes == dual.total


def test_segmented_prologue_hbm_model_matches_lowered_io():
    plan = R.plan_for((5 * GROUP,), jnp.float32, backend="pallas_fused",
                      segments=2, num_cores=2)
    backend = R.get_backend("pallas_fused")
    sizes = (2 * GROUP, 3 * GROUP)  # tile-aligned: exact equality
    offsets = tuple(np.concatenate([[0], np.cumsum(sizes)]).tolist())
    flat = jnp.zeros((int(offsets[-1]),), jnp.float32)
    _, src, *_ = ops.segment_cover_layout(offsets, GROUP)
    for pro, slots in (("square", 2), ("moments", 4)):
        model = cost_model.segmented_hbm_bytes(
            int(flat.size), 4, segments=slots, tiles=int(src.size),
            num_cores=2,
        )
        got = _io(
            lambda v, p=pro: backend.sum_segments(v, offsets, plan, p), flat
        )
        assert got == model.launch_io, pro


def test_traced_mma_counts_match_cost_model_dual():
    """fused_trace(dual) == cost_model.fused_mma_ops(dual): the moments
    pass costs exactly twice the identity MMAs, never a second stream."""
    for n in (1, 130_000, 1 << 20):
        for c in (1, 2, 4):
            tr = ops.fused_trace(n, 8, c, dual=True)
            mc = cost_model.fused_mma_ops(n, num_cores=c, dual=True)
            assert tr.mma_ops == mc.total
            assert tr.lane_mma_ops == mc.lane
            assert tr.combine_mma_ops == mc.combine
            single = cost_model.fused_mma_ops(n, num_cores=c)
            assert mc.total == 2 * single.total


def test_sumsq_two_pass_comparison_model():
    """The motivating arithmetic: the PR-4 sumsq path (host square + f32
    staging write + f32 kernel stream) moved ~5x the bytes of the
    single-stream square prologue on bf16."""
    n = 1 << 20
    zc = cost_model.hbm_bytes("fused", n, 2).total
    staged = cost_model.hbm_bytes("sumsq_staged", n, 2).total
    assert staged / zc > 4.5
    assert cost_model.hbm_bytes("sumsq_staged", n, 4).total \
        / cost_model.hbm_bytes("fused", n, 4).total > 2.0


# ---------------------- layer 3b: staging-free + launch counts ---------------


@pytest.mark.parametrize("backend", harness.PALLAS_BACKENDS)
def test_prologue_kinds_staging_free(backend):
    """Acceptance: bf16 sumsq / norm2 / moments lower with NO n-sized
    convert/pad/concat -- and no n-sized host mul/pow/sign either (the
    elementwise prologue pass itself) -- outside the pallas_call."""
    x = jnp.zeros((300_000,), jnp.bfloat16)
    for kind in ("sumsq", "norm2", "moments"):
        rinspect.assert_staging_free(
            lambda v, k=kind: R.reduce(v, kind=k, backend=backend), x,
            extra_primitives=rinspect.PROLOGUE_PRIMITIVES,
        )
    arrs = [jnp.zeros((s,), jnp.bfloat16) for s in (70_000, 33, 20_000)]
    for kind in ("sumsq", "norm2", "moments"):
        rinspect.assert_staging_free(
            lambda a, k=kind: R.reduce_many(a, kind=k, backend=backend), arrs,
            extra_primitives=rinspect.PROLOGUE_PRIMITIVES,
        )


@pytest.mark.parametrize("backend", harness.PALLAS_BACKENDS)
def test_reduce_tree_norm2_staging_free_single_launch(backend):
    """Acceptance: the jitted multi-leaf bf16 global-norm statistic is ONE
    pallas_call with zero host-side staging or squaring."""
    tree = {
        "w": jnp.zeros((40, 256), jnp.bfloat16),
        "b": [jnp.zeros((3000,), jnp.bfloat16), jnp.zeros((), jnp.bfloat16)],
        "e": jnp.zeros((0, 8), jnp.bfloat16),
    }
    fn = jax.jit(lambda g: R.reduce_tree(g, "norm2", backend=backend))
    rinspect.assert_staging_free(
        fn, tree, extra_primitives=rinspect.PROLOGUE_PRIMITIVES
    )
    assert rinspect.count_pallas_calls(fn, tree) == 1
    # and the value is right
    got = float(fn({"w": jnp.ones((40, 256), jnp.bfloat16),
                    "b": [jnp.ones((3000,), jnp.bfloat16),
                          jnp.ones((), jnp.bfloat16)],
                    "e": jnp.zeros((0, 8), jnp.bfloat16)}))
    np.testing.assert_allclose(got, np.sqrt(40 * 256 + 3000 + 1), rtol=1e-4)


def test_sumsq_single_launch_on_fused():
    x = jnp.zeros((300_000,), jnp.bfloat16)
    for kind, want in (("sumsq", 1), ("norm2", 1), ("moments", 1)):
        n = rinspect.count_pallas_calls(
            lambda v, k=kind: R.reduce(v, kind=k, backend="pallas_fused"), x
        )
        assert n == want, kind


# ---------------------- layer 4: property sweeps -----------------------------


@hypothesis.settings(max_examples=40, deadline=None)
@hypothesis.given(
    n=st.integers(1, 100_000),
    seed=st.integers(0, 2**31 - 1),
    num_cores=st.sampled_from([1, 2, 4]),
    dt=st.sampled_from(["bfloat16", "float16", "float32"]),
    kind=st.sampled_from(["sum", "sumsq", "norm2", "moments"]),
)
def test_property_prologue_cells_vs_oracle(n, seed, num_cores, dt, kind):
    """(a) ragged n x dtype x cores x kind vs the f64 oracle: the
    tail-masked squares beyond n never contribute to any statistic."""
    harness.run_cell("pallas_fused", kind, dt, n, num_cores, seed)


@hypothesis.settings(max_examples=25, deadline=None)
@hypothesis.given(
    n=st.integers(1, 60_000),
    seed=st.integers(0, 2**31 - 1),
    prologue=st.sampled_from(["identity", "square", "abs", "moments"]),
)
def test_property_single_core_bit_identical_to_emulation(n, seed, prologue):
    """(b) num_cores=1 is bit-identical to the mma_jnp emulation of the
    kernel (f32 compute -- the guaranteed-bitwise regime)."""
    x = jnp.asarray(np.random.RandomState(seed).randn(n).astype(np.float32))
    got = K.reduce_fused(x, num_cores=1, prologue=prologue,
                         compute_dtype=jnp.float32)
    want = ref.fused_lanes_ref(x, num_cores=1, prologue=prologue,
                               compute_dtype=jnp.float32)
    harness.assert_bits_equal(got, want, f"n={n} {prologue}")


@hypothesis.settings(max_examples=15, deadline=None)
@hypothesis.given(n=st.integers(2, 5_000), seed=st.integers(0, 2**31 - 1))
def test_property_norm2_grad_matches_xla_autodiff(n, seed):
    """(c) grad of norm2 through the kernel VJP (2x cotangent chained
    through sqrt) == plain autodiff through the xla backend: x / ||x||."""
    x = jnp.asarray(
        (np.random.RandomState(seed).rand(n) + 0.5).astype(np.float32)
    )
    g_kernel = jax.grad(
        lambda y: R.reduce(y, kind="norm2", backend="pallas_fused")
    )(x)
    g_xla = jax.grad(lambda y: R.reduce(y, kind="norm2", backend="xla"))(x)
    np.testing.assert_allclose(
        np.asarray(g_kernel), np.asarray(g_xla), rtol=2e-4, atol=1e-6
    )
