"""Cross-host agreement checking + guard observability (fast, in-process:
the transports these plug into are the multi-host launcher's business; the
decision logic and counters are plain Python)."""

import json

import numpy as np
import pytest

from repro.runtime import (
    AgreementChecker,
    DivergenceError,
    GuardMetrics,
    HeartbeatTracker,
    StepGuard,
    TrainSupervisor,
    fingerprint,
    step_fingerprint,
)
from repro.checkpoint import CheckpointManager


# --------------------------- fingerprint -----------------------------------


def test_fingerprint_stable_and_bit_sensitive():
    a = np.arange(6, dtype=np.float32)
    assert fingerprint(a, 3, "tag") == fingerprint(a.copy(), 3, "tag")
    # one flipped mantissa bit must change the digest -- the whole point of
    # the bitwise-deterministic combine is that last-ulp drift is visible
    b = a.copy()
    b[0] = np.nextafter(b[0], 1.0)
    assert fingerprint(a) != fingerprint(b)
    # shape/dtype are part of the identity, not just the bytes
    assert fingerprint(a) != fingerprint(a.reshape(2, 3))
    assert fingerprint(a) != fingerprint(a.astype(np.float64).astype(np.float32).view(np.uint32))


def test_fingerprint_nan_safe_and_structured():
    x = np.array([1.0, np.nan], np.float32)
    assert fingerprint(x) == fingerprint(x.copy())  # NaN bits hash fine
    assert fingerprint({"b": 1, "a": 2}) == fingerprint({"a": 2, "b": 1})
    assert fingerprint((1, 2)) != fingerprint((2, 1))
    assert step_fingerprint(3, x, 1.0, 2.5) == step_fingerprint(3, x, 1.0, 2.5)
    assert step_fingerprint(3, x, 1.0, 2.5) != step_fingerprint(4, x, 1.0, 2.5)


# ------------------------- AgreementChecker --------------------------------


def test_agreement_unanimous_steps_pass():
    chk = AgreementChecker(4)
    for step in (1, 2):
        fp = step_fingerprint(step, [0.0], 0.0, 7.25)
        for h in range(4):
            chk.record(step, h, fp)
        assert chk.check(step)
    assert chk.checks_passed == 2


def test_agreement_divergence_names_first_host_and_step():
    """The negative test: one deliberately desynced replica must raise a
    DivergenceError carrying the FIRST disagreeing host id and the step."""
    chk = AgreementChecker(4)
    good = step_fingerprint(5, [0.0], 0.0, 7.25)
    bad = step_fingerprint(5, [0.0], 0.0, np.nextafter(7.25, 8))
    chk.record(5, 3, bad)  # drifted by one ulp; no reference yet, no verdict
    chk.record(5, 2, bad)
    with pytest.raises(DivergenceError) as ei:
        chk.record(5, 0, good)  # reference lands: LOWEST bad id is reported
    assert ei.value.step == 5 and ei.value.host == 2
    assert ei.value.expected != ei.value.got


def test_agreement_divergence_detected_at_check_time():
    chk = AgreementChecker(2)
    chk.record(9, 1, "aaaa")  # arrives before the reference: no verdict yet
    with pytest.raises(DivergenceError) as ei:
        chk.record(9, 0, "bbbb")
    assert ei.value.host == 1 and ei.value.step == 9


def test_agreement_missing_host_is_not_divergence():
    chk = AgreementChecker(3)
    chk.record(1, 0, "x")
    chk.record(1, 1, "x")
    with pytest.raises(RuntimeError, match="host\\(s\\) \\[2\\]"):
        chk.check(1)  # silent host: liveness problem, distinct error
    assert chk.checks_passed == 0


def test_agreement_rejects_bad_geometry():
    with pytest.raises(ValueError):
        AgreementChecker(0)
    with pytest.raises(ValueError):
        AgreementChecker(2).record(0, 2, "x")


# --------------------------- GuardMetrics ----------------------------------


def test_guard_metrics_counters_and_snapshot():
    m = GuardMetrics()
    m.record_step(1, skipped=False)
    m.record_step(2, skipped=True, census_total=3.0)
    m.record_retry(2)
    m.record_rollback()
    m.record_commit()
    m.record_agreement(5)
    snap = m.snapshot()
    assert snap["steps_total"] == 2 and snap["steps_skipped"] == 1
    assert snap["retries"] == 2 and snap["rollbacks"] == 1
    assert snap["commits"] == 1 and snap["last_step"] == 2
    assert snap["last_census_total"] == 3.0
    assert snap["divergence_checks_passed"] == 5


def test_guard_metrics_atomic_json_export(tmp_path):
    m = GuardMetrics()
    m.record_step(7, skipped=True, census_total=1.0)
    path = tmp_path / "status.json"
    m.write(path)
    got = json.loads(path.read_text())
    assert got == m.snapshot()
    m.record_step(8, skipped=False)
    m.write(path)  # overwrite via os.replace, never a torn read
    assert json.loads(path.read_text())["steps_total"] == 2
    assert not list(tmp_path.glob(".guard_metrics_*"))  # no tmp litter


# ---------------------- supervisor / tracker wiring ------------------------


def test_heartbeat_carries_guard_metrics():
    t = HeartbeatTracker(2)
    t.beat(0, 0.1, metrics={"steps_skipped": 3})
    t.beat(1, 0.1)
    assert t.last_metrics[0] == {"steps_skipped": 3}
    assert 1 not in t.last_metrics


class _Data:
    def __init__(self):
        self.step = 0

    def next(self):
        self.step += 1
        return self.step - 1

    def seek(self, step):
        self.step = int(step)

    def state(self):
        return {"step": self.step}


def test_supervisor_exports_metrics_and_status_file(tmp_path):
    """End-to-end counters: skips at steps 3-5 trigger one rollback (K=3);
    the supervisor's GuardMetrics tallies steps/skips/rollback and rewrites
    the JSON status file at every commit."""
    skip_at = {3, 4, 5}
    seen = set()

    def step_fn(state, batch):
        skipped = batch in skip_at and batch not in seen
        seen.add(batch)
        return (state + (0 if skipped else 1)).astype(np.int32), {
            "skipped": 1.0 if skipped else 0.0,
            "nonfinite": 2.0 if skipped else 0.0,
        }

    metrics = GuardMetrics()
    status = tmp_path / "guard.json"
    sup = TrainSupervisor(
        step_fn, CheckpointManager(tmp_path / "ckpt"), _Data(),
        ckpt_every=2, step_guard=StepGuard(3, sleep=lambda s: None),
        metrics=metrics, status_path=status,
    )
    state, step, done = sup.run(np.zeros((), np.int32), 8)
    assert done == "done" and step == 8
    snap = metrics.snapshot()
    assert snap["rollbacks"] == 1
    assert snap["steps_skipped"] == 3
    assert snap["last_census_total"] == 0.0  # last step was clean
    assert snap["commits"] >= 1
    got = json.loads(status.read_text())
    assert got["rollbacks"] == 1
    # the tracker's beats carry the same counters
    assert sup.tracker.last_metrics[0]["rollbacks"] == 1


# ------------------- Transport / exchange (real loopback) ------------------
#
# The checker above is transport-agnostic; these tests close the loop with
# the concrete FileTransport -- first in-process, then across REAL OS
# processes (spawned, jax-free children), which is the scenario the ABC
# exists for.

from repro.runtime import FileTransport, Transport, exchange  # noqa: E402


def test_file_transport_publish_fetch_roundtrip(tmp_path):
    tr = FileTransport(tmp_path / "fp")
    assert isinstance(tr, Transport)
    assert tr.fetch(3) == {}
    tr.publish(3, 0, "aaa")
    tr.publish(3, 2, "ccc")
    tr.publish(4, 0, "zzz")  # another step must not bleed in
    assert tr.fetch(3) == {0: "aaa", 2: "ccc"}
    tr.publish(3, 2, "CCC")  # republish overwrites atomically
    assert tr.fetch(3)[2] == "CCC"
    # stray files (tmp leftovers, other schemas) are ignored
    (tmp_path / "fp" / "step000000000003.hostX").write_text("junk")
    assert set(tr.fetch(3)) == {0, 2}


def test_exchange_roundtrip_in_process(tmp_path):
    tr = FileTransport(tmp_path)
    fp = step_fingerprint(5, [1.0], 0.0, 2.5)
    checkers = [AgreementChecker(3) for _ in range(3)]
    # host order is adversarial: the last host publishes first
    for host in (2, 0, 1):
        tr.publish(5, host, fp)
    for host, chk in enumerate(checkers):
        assert exchange(chk, tr, 5, host, fp, timeout_s=1.0)
        assert chk.checks_passed == 1


def test_exchange_divergence_and_timeout(tmp_path):
    tr = FileTransport(tmp_path / "a")
    tr.publish(7, 1, "deadbeef")
    with pytest.raises(DivergenceError) as e:
        exchange(AgreementChecker(2), tr, 7, 0, "cafe", timeout_s=1.0)
    assert e.value.host == 1 and e.value.step == 7

    # a dead host: the poller must give up, not hang -- injected clock
    # so the test takes no wall time
    t = [0.0]

    def clock():
        return t[0]

    def sleep(dt):
        t[0] += dt

    with pytest.raises(TimeoutError, match=r"host\(s\) \[1\]"):
        exchange(AgreementChecker(2), FileTransport(tmp_path / "b"),
                 1, 0, "cafe", timeout_s=0.5, clock=clock, sleep=sleep)


def _exchange_child(root, n_hosts, step, host, fp):
    """Spawned-process target: publish + exchange over the shared dir.
    Exit codes: 0 agreed, 7 divergence, 9 timeout. Children import only
    the jax-free runtime modules."""
    import sys

    from repro.runtime import AgreementChecker, DivergenceError
    from repro.runtime import FileTransport as FT
    from repro.runtime import exchange as ex

    try:
        ex(AgreementChecker(n_hosts), FT(root), step, host, fp,
           timeout_s=60.0)
        sys.exit(0)
    except DivergenceError:
        sys.exit(7)
    except TimeoutError:
        sys.exit(9)


def test_exchange_across_real_processes(tmp_path):
    import multiprocessing as mp

    ctx = mp.get_context("spawn")
    fp = step_fingerprint(11, [3.0], 0.0, 1.5)
    procs = [
        ctx.Process(target=_exchange_child,
                    args=(str(tmp_path), 3, 11, host, fp))
        for host in range(3)
    ]
    for p in procs:
        p.start()
    for p in procs:
        p.join(timeout=120)
    assert [p.exitcode for p in procs] == [0, 0, 0]


def test_exchange_across_real_processes_divergence(tmp_path):
    import multiprocessing as mp

    ctx = mp.get_context("spawn")
    good = step_fingerprint(12, [3.0], 0.0, 1.5)
    bad = step_fingerprint(12, [3.0], 1.0, 1.5)  # host 1 took the skip
    procs = [
        ctx.Process(target=_exchange_child,
                    args=(str(tmp_path), 2, 12, host,
                          good if host == 0 else bad))
        for host in range(2)
    ]
    for p in procs:
        p.start()
    for p in procs:
        p.join(timeout=120)
    # every process must detect the divergence -- it is symmetric: the
    # roster both hosts fetch contains the disagreeing pair
    assert [p.exitcode for p in procs] == [7, 7]
