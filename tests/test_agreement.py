"""Cross-host agreement checking + guard observability (fast, in-process:
the transports these plug into are the multi-host launcher's business; the
decision logic and counters are plain Python)."""

import json

import numpy as np
import pytest

from repro.runtime import (
    AgreementChecker,
    DivergenceError,
    GuardMetrics,
    HeartbeatTracker,
    StepGuard,
    TrainSupervisor,
    fingerprint,
    step_fingerprint,
)
from repro.checkpoint import CheckpointManager


# --------------------------- fingerprint -----------------------------------


def test_fingerprint_stable_and_bit_sensitive():
    a = np.arange(6, dtype=np.float32)
    assert fingerprint(a, 3, "tag") == fingerprint(a.copy(), 3, "tag")
    # one flipped mantissa bit must change the digest -- the whole point of
    # the bitwise-deterministic combine is that last-ulp drift is visible
    b = a.copy()
    b[0] = np.nextafter(b[0], 1.0)
    assert fingerprint(a) != fingerprint(b)
    # shape/dtype are part of the identity, not just the bytes
    assert fingerprint(a) != fingerprint(a.reshape(2, 3))
    assert fingerprint(a) != fingerprint(a.astype(np.float64).astype(np.float32).view(np.uint32))


def test_fingerprint_nan_safe_and_structured():
    x = np.array([1.0, np.nan], np.float32)
    assert fingerprint(x) == fingerprint(x.copy())  # NaN bits hash fine
    assert fingerprint({"b": 1, "a": 2}) == fingerprint({"a": 2, "b": 1})
    assert fingerprint((1, 2)) != fingerprint((2, 1))
    assert step_fingerprint(3, x, 1.0, 2.5) == step_fingerprint(3, x, 1.0, 2.5)
    assert step_fingerprint(3, x, 1.0, 2.5) != step_fingerprint(4, x, 1.0, 2.5)


# ------------------------- AgreementChecker --------------------------------


def test_agreement_unanimous_steps_pass():
    chk = AgreementChecker(4)
    for step in (1, 2):
        fp = step_fingerprint(step, [0.0], 0.0, 7.25)
        for h in range(4):
            chk.record(step, h, fp)
        assert chk.check(step)
    assert chk.checks_passed == 2


def test_agreement_divergence_names_first_host_and_step():
    """The negative test: one deliberately desynced replica must raise a
    DivergenceError carrying the FIRST disagreeing host id and the step."""
    chk = AgreementChecker(4)
    good = step_fingerprint(5, [0.0], 0.0, 7.25)
    bad = step_fingerprint(5, [0.0], 0.0, np.nextafter(7.25, 8))
    chk.record(5, 3, bad)  # drifted by one ulp; no reference yet, no verdict
    chk.record(5, 2, bad)
    with pytest.raises(DivergenceError) as ei:
        chk.record(5, 0, good)  # reference lands: LOWEST bad id is reported
    assert ei.value.step == 5 and ei.value.host == 2
    assert ei.value.expected != ei.value.got


def test_agreement_divergence_detected_at_check_time():
    chk = AgreementChecker(2)
    chk.record(9, 1, "aaaa")  # arrives before the reference: no verdict yet
    with pytest.raises(DivergenceError) as ei:
        chk.record(9, 0, "bbbb")
    assert ei.value.host == 1 and ei.value.step == 9


def test_agreement_missing_host_is_not_divergence():
    chk = AgreementChecker(3)
    chk.record(1, 0, "x")
    chk.record(1, 1, "x")
    with pytest.raises(RuntimeError, match="host\\(s\\) \\[2\\]"):
        chk.check(1)  # silent host: liveness problem, distinct error
    assert chk.checks_passed == 0


def test_agreement_rejects_bad_geometry():
    with pytest.raises(ValueError):
        AgreementChecker(0)
    with pytest.raises(ValueError):
        AgreementChecker(2).record(0, 2, "x")


# --------------------------- GuardMetrics ----------------------------------


def test_guard_metrics_counters_and_snapshot():
    m = GuardMetrics()
    m.record_step(1, skipped=False)
    m.record_step(2, skipped=True, census_total=3.0)
    m.record_retry(2)
    m.record_rollback()
    m.record_commit()
    m.record_agreement(5)
    snap = m.snapshot()
    assert snap["steps_total"] == 2 and snap["steps_skipped"] == 1
    assert snap["retries"] == 2 and snap["rollbacks"] == 1
    assert snap["commits"] == 1 and snap["last_step"] == 2
    assert snap["last_census_total"] == 3.0
    assert snap["divergence_checks_passed"] == 5


def test_guard_metrics_atomic_json_export(tmp_path):
    m = GuardMetrics()
    m.record_step(7, skipped=True, census_total=1.0)
    path = tmp_path / "status.json"
    m.write(path)
    got = json.loads(path.read_text())
    assert got == m.snapshot()
    m.record_step(8, skipped=False)
    m.write(path)  # overwrite via os.replace, never a torn read
    assert json.loads(path.read_text())["steps_total"] == 2
    assert not list(tmp_path.glob(".guard_metrics_*"))  # no tmp litter


# ---------------------- supervisor / tracker wiring ------------------------


def test_heartbeat_carries_guard_metrics():
    t = HeartbeatTracker(2)
    t.beat(0, 0.1, metrics={"steps_skipped": 3})
    t.beat(1, 0.1)
    assert t.last_metrics[0] == {"steps_skipped": 3}
    assert 1 not in t.last_metrics


class _Data:
    def __init__(self):
        self.step = 0

    def next(self):
        self.step += 1
        return self.step - 1

    def seek(self, step):
        self.step = int(step)

    def state(self):
        return {"step": self.step}


def test_supervisor_exports_metrics_and_status_file(tmp_path):
    """End-to-end counters: skips at steps 3-5 trigger one rollback (K=3);
    the supervisor's GuardMetrics tallies steps/skips/rollback and rewrites
    the JSON status file at every commit."""
    skip_at = {3, 4, 5}
    seen = set()

    def step_fn(state, batch):
        skipped = batch in skip_at and batch not in seen
        seen.add(batch)
        return (state + (0 if skipped else 1)).astype(np.int32), {
            "skipped": 1.0 if skipped else 0.0,
            "nonfinite": 2.0 if skipped else 0.0,
        }

    metrics = GuardMetrics()
    status = tmp_path / "guard.json"
    sup = TrainSupervisor(
        step_fn, CheckpointManager(tmp_path / "ckpt"), _Data(),
        ckpt_every=2, step_guard=StepGuard(3, sleep=lambda s: None),
        metrics=metrics, status_path=status,
    )
    state, step, done = sup.run(np.zeros((), np.int32), 8)
    assert done == "done" and step == 8
    snap = metrics.snapshot()
    assert snap["rollbacks"] == 1
    assert snap["steps_skipped"] == 3
    assert snap["last_census_total"] == 0.0  # last step was clean
    assert snap["commits"] >= 1
    got = json.loads(status.read_text())
    assert got["rollbacks"] == 1
    # the tracker's beats carry the same counters
    assert sup.tracker.last_metrics[0]["rollbacks"] == 1
