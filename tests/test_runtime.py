"""Fault-tolerance runtime: heartbeats, stragglers, elastic replan,
preemption-safe supervision with resume."""

import numpy as np
import pytest

from repro.checkpoint import CheckpointManager
from repro.data import SyntheticLM
from repro.runtime import (
    ElasticPlan,
    HeartbeatTracker,
    PreemptionGuard,
    TrainSupervisor,
)


def test_heartbeat_dead_host_detection():
    hb = HeartbeatTracker(4, timeout_s=10.0)
    now = 1000.0
    for h in range(4):
        hb.beat(h, 1.0, now=now)
    hb.beat(0, 1.0, now=now + 50)
    hb.beat(1, 1.0, now=now + 50)
    hb.beat(2, 1.0, now=now + 50)
    assert hb.dead_hosts(now=now + 55) == [3]
    assert hb.healthy(now=now + 55) == [0, 1, 2]


def test_straggler_detection():
    hb = HeartbeatTracker(5, straggler_factor=2.0)
    for h in range(5):
        hb.beat(h, step_time_s=1.0 if h != 2 else 5.0)
    assert hb.stragglers() == [2]


def test_elastic_plan_preserves_model_degree():
    ep = ElasticPlan(n_hosts=8, devices_per_host=64, model_degree=16,
                     global_batch=256)
    full = ep.plan(list(range(8)))
    assert full["mesh_shape"] == (32, 16)
    lost_one = ep.plan(list(range(7)))
    assert lost_one["mesh_shape"][1] == 16
    assert lost_one["mesh_shape"][0] * 16 <= 7 * 64
    # batch still divides the new data degree
    mb = 256 // lost_one["microbatches"]
    assert mb % lost_one["mesh_shape"][0] == 0


def test_elastic_plan_raises_when_too_few():
    ep = ElasticPlan(n_hosts=2, devices_per_host=4, model_degree=16,
                     global_batch=32)
    with pytest.raises(RuntimeError):
        ep.plan([0])


def test_supervisor_preemption_and_resume(tmp_path):
    """Preempt mid-run -> checkpoint written -> fresh supervisor resumes at
    the same step with the same data position."""
    data = SyntheticLM(100, 8, 2, seed=0)
    ckpt = CheckpointManager(tmp_path, keep=3)
    calls = []

    def step_fn(state, batch):
        calls.append(batch["tokens"][0, 0])
        return {"w": state["w"] + 1.0}, {}

    guard = PreemptionGuard(install=False)
    sup = TrainSupervisor(step_fn, ckpt, data, ckpt_every=3, guard=guard)
    state = {"w": np.zeros(2, np.float32)}
    # trigger preemption after a few steps via a wrapper
    orig_next = data.next
    count = {"n": 0}

    def poking_next():
        count["n"] += 1
        if count["n"] == 5:
            guard.trigger()
        return orig_next()

    data.next = poking_next
    state, step, status = sup.run(state, n_steps=100)
    assert status == "preempted"
    assert ckpt.latest() == step

    # resume fresh
    data2 = SyntheticLM(100, 8, 2, seed=0)
    sup2 = TrainSupervisor(step_fn, ckpt, data2, ckpt_every=100,
                           guard=PreemptionGuard(install=False))
    state2, step2, status2 = sup2.run({"w": np.zeros(2, np.float32)}, n_steps=step + 2)
    assert status2 == "done"
    assert step2 == step + 2
    assert float(state2["w"][0]) == pytest.approx(step + 2)  # no lost steps
