"""Fault-tolerance runtime: heartbeats, stragglers, elastic replan,
preemption-safe supervision with resume."""

import threading

import numpy as np
import pytest

from repro.checkpoint import CheckpointManager
from repro.data import SyntheticLM
from repro.runtime import (
    ElasticPlan,
    HeartbeatTracker,
    PreemptionGuard,
    TrainSupervisor,
)


def test_heartbeat_dead_host_detection():
    hb = HeartbeatTracker(4, timeout_s=10.0)
    now = 1000.0
    for h in range(4):
        hb.beat(h, 1.0, now=now)
    hb.beat(0, 1.0, now=now + 50)
    hb.beat(1, 1.0, now=now + 50)
    hb.beat(2, 1.0, now=now + 50)
    assert hb.dead_hosts(now=now + 55) == [3]
    assert hb.healthy(now=now + 55) == [0, 1, 2]


def test_straggler_detection():
    hb = HeartbeatTracker(5, straggler_factor=2.0)
    for h in range(5):
        hb.beat(h, step_time_s=1.0 if h != 2 else 5.0)
    assert hb.stragglers() == [2]


def test_straggler_flap_resistance():
    """One slow step (GC pause, checkpoint flush) must NOT flag a healthy
    host; sustained slowness that shifts the window median must."""
    hb = HeartbeatTracker(4, straggler_factor=2.0)
    for _ in range(8):
        for h in range(4):
            hb.beat(h, step_time_s=1.0)
    hb.beat(2, step_time_s=30.0)  # a single 30x outlier step
    assert hb.stragglers() == []
    for _ in range(10):  # genuine straggler: the whole window shifts
        hb.beat(2, step_time_s=5.0)
    assert hb.stragglers() == [2]


def test_straggler_quorum():
    """With fewer than half the fleet reporting there is no meaningful
    fleet median -- nobody gets flagged off two hosts' data."""
    hb = HeartbeatTracker(8, straggler_factor=2.0)
    hb.beat(0, step_time_s=10.0)
    hb.beat(1, step_time_s=1.0)
    assert hb.stragglers() == []


def test_preemption_guard_off_main_thread():
    """signal.signal raises ValueError off the main thread; the guard must
    swallow it (install degrades to trigger()-only) instead of crashing
    worker threads that construct one."""
    out = {}

    def make():
        try:
            out["g"] = PreemptionGuard(install=True)
        except Exception as e:  # pragma: no cover - the failure under test
            out["err"] = e

    t = threading.Thread(target=make)
    t.start()
    t.join()
    assert "err" not in out, out
    g = out["g"]
    assert not g.should_stop
    g.trigger()
    assert g.should_stop


def test_elastic_plan_preserves_model_degree():
    ep = ElasticPlan(n_hosts=8, devices_per_host=64, model_degree=16,
                     global_batch=256)
    full = ep.plan(list(range(8)))
    assert full["mesh_shape"] == (32, 16)
    lost_one = ep.plan(list(range(7)))
    assert lost_one["mesh_shape"][1] == 16
    assert lost_one["mesh_shape"][0] * 16 <= 7 * 64
    # batch still divides the new data degree
    mb = 256 // lost_one["microbatches"]
    assert mb % lost_one["mesh_shape"][0] == 0


def test_elastic_plan_raises_when_too_few():
    ep = ElasticPlan(n_hosts=2, devices_per_host=4, model_degree=16,
                     global_batch=32)
    with pytest.raises(RuntimeError):
        ep.plan([0])


def test_elastic_plan_survivors_below_one_replica():
    """A fleet that supports exactly one model replica raises as soon as
    survivors dip below it (24 devices cannot host a 32-way replica)."""
    ep = ElasticPlan(n_hosts=4, devices_per_host=8, model_degree=32,
                     global_batch=64)
    assert ep.plan(list(range(4)))["mesh_shape"] == (1, 32)
    with pytest.raises(RuntimeError):
        ep.plan(list(range(3)))


class _DelayedFlushCkpt:
    """CheckpointManager wrapper whose flush blocks on an Event: makes the
    save-then-immediate-restart race deterministic instead of timing-bound.
    """

    def __init__(self, inner):
        self.inner = inner
        self.release = threading.Event()
        self._t = None

    def save(self, step, tree, extra=None):
        def run():
            self.release.wait()
            self.inner.save(step, tree, extra=extra, blocking=True)

        self._t = threading.Thread(target=run, daemon=True)
        self._t.start()

    def wait(self):
        self.release.set()
        if self._t is not None:
            self._t.join()
            self._t = None
        self.inner.wait()

    def latest(self):
        return self.inner.latest()

    def restore(self, *a, **k):
        return self.inner.restore(*a, **k)

    def manifest(self, step):
        return self.inner.manifest(step)


def test_resume_waits_for_inflight_save(tmp_path):
    """Save-then-immediate-restart: ``save()`` flushes on a background
    thread, so ``latest()`` polled right after save can MISS the newest
    checkpoint. ``TrainSupervisor.resume`` must drain the writer first and
    resume from the save, not from one checkpoint earlier."""
    ckpt = _DelayedFlushCkpt(CheckpointManager(tmp_path))
    state = {"w": np.full(2, 7.0, np.float32)}
    ckpt.save(7, state, extra={"data_step": 7})
    # the race window is real: the flush has not landed yet
    assert ckpt.latest() is None

    data = SyntheticLM(100, 8, 2, seed=0)
    sup = TrainSupervisor(lambda s, b: (s, {}), ckpt, data)
    got, start = sup.resume({"w": np.zeros(2, np.float32)})
    assert start == 7
    assert float(got["w"][0]) == 7.0
    assert data.state()["step"] == 7
    """Preempt mid-run -> checkpoint written -> fresh supervisor resumes at
    the same step with the same data position."""
    data = SyntheticLM(100, 8, 2, seed=0)
    ckpt = CheckpointManager(tmp_path, keep=3)
    calls = []

    def step_fn(state, batch):
        calls.append(batch["tokens"][0, 0])
        return {"w": state["w"] + 1.0}, {}

    guard = PreemptionGuard(install=False)
    sup = TrainSupervisor(step_fn, ckpt, data, ckpt_every=3, guard=guard)
    state = {"w": np.zeros(2, np.float32)}
    # trigger preemption after a few steps via a wrapper
    orig_next = data.next
    count = {"n": 0}

    def poking_next():
        count["n"] += 1
        if count["n"] == 5:
            guard.trigger()
        return orig_next()

    data.next = poking_next
    state, step, status = sup.run(state, n_steps=100)
    assert status == "preempted"
    assert ckpt.latest() == step

    # resume fresh
    data2 = SyntheticLM(100, 8, 2, seed=0)
    sup2 = TrainSupervisor(step_fn, ckpt, data2, ckpt_every=100,
                           guard=PreemptionGuard(install=False))
    state2, step2, status2 = sup2.run({"w": np.zeros(2, np.float32)}, n_steps=step + 2)
    assert status2 == "done"
    assert step2 == step + 2
    assert float(state2["w"][0]) == pytest.approx(step + 2)  # no lost steps
