"""Pallas mma_reduce backends vs pure-jnp oracle, driven through the unified
``repro.reduce`` engine (+ hypothesis property tests)."""

from _optional_hypothesis import hypothesis, st
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import reduce as R
from repro.kernels.mma_reduce import ref

SIZES = [1, 5, 127, 128, 16384, 16385, 100_000, 300_000]
DTYPES = [np.float32, np.float16]
PALLAS_BACKENDS = ["pallas_hier", "pallas_fused"]


@pytest.mark.parametrize("n", SIZES)
@pytest.mark.parametrize("dtype", DTYPES)
@pytest.mark.parametrize("backend", PALLAS_BACKENDS)
def test_matches_sum_oracle(n, dtype, backend, rng):
    x = rng.randn(n).astype(dtype)
    got = float(R.reduce(jnp.asarray(x), backend=backend))
    want = float(ref.sum_ref(jnp.asarray(x)))
    tol = 4e-3 * max(np.abs(x.astype(np.float64)).sum(), 1.0)  # bf16 multipliers
    assert abs(got - want) <= tol, (got, want)


@pytest.mark.parametrize("n", [128 * 128, 3 * 128 * 128, 130_000])
def test_hierarchical_matches_eq13_oracle_exactly(n, rng):
    """The kernel's hierarchical mode must match the eq. (13) jnp emulation
    bit-for-bit (same tiling, same bf16 rounding)."""
    x = rng.randn(n).astype(np.float32)
    got = float(R.reduce(jnp.asarray(x), backend="pallas_hier"))
    want = float(ref.hierarchy_ref(jnp.asarray(x)))
    assert got == want


def test_two_mma_tile_algebra(rng):
    """Eq. (9)-(12): per-tile partials equal replicated row/col sums."""
    tiles = jnp.asarray(rng.randn(4, 16, 16).astype(np.float32))
    got = ref.two_mma_ref(tiles, compute_dtype=jnp.float32)
    want = jnp.sum(tiles, axis=(1, 2))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5)


def test_fused_mode_more_accurate_than_hierarchical(rng):
    """The C-accumulator variant keeps partials in f32 -> strictly less
    rounding than the paper's write-back-and-relaunch hierarchy."""
    x = rng.randn(1 << 20).astype(np.float32)
    exact = x.astype(np.float64).sum()
    err_h = abs(float(R.reduce(jnp.asarray(x), backend="pallas_hier")) - exact)
    err_f = abs(float(R.reduce(jnp.asarray(x), backend="pallas_fused")) - exact)
    assert err_f <= err_h + 1e-6


def test_gradient():
    x = jnp.arange(300.0, dtype=jnp.float32)
    g = jax.grad(lambda y: R.reduce(y, backend="pallas_fused"))(x)
    np.testing.assert_allclose(np.asarray(g), 1.0)


def test_zero_size_input_is_additive_identity():
    """Regression: empty operands reduce to 0.0 on both kernel modes rather
    than erroring on a degenerate pad."""
    for backend in PALLAS_BACKENDS:
        assert float(R.reduce(jnp.zeros((0,)), backend=backend)) == 0.0


def test_segmented_kernel_matches_ref(rng):
    """The single-launch segmented kernel vs the per-segment oracle, across
    boundary-hostile layouts (boundaries inside and across tile blocks)."""
    from repro.kernels.mma_reduce import ops

    for sizes in (
        [100, 64, 1, 200],
        [5],
        [0, 3, 0],
        [16384, 1, 16385],          # exact tile, then straddling
        [7] * 19,                   # many boundaries inside one block
    ):
        offsets = np.concatenate([[0], np.cumsum(sizes)]).tolist()
        flat = jnp.asarray(rng.randn(int(offsets[-1])).astype(np.float32))
        for tpb in (1, 2, 8):
            got = ops.mma_sum_segments_pallas(
                flat, offsets, tiles_per_block=tpb,
                compute_dtype=jnp.float32,
            )
            want = ref.segmented_sum_ref(flat, offsets)
            np.testing.assert_allclose(
                np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-4,
                err_msg=f"sizes={sizes} tiles_per_block={tpb}",
            )


def test_segmented_kernel_empty_cases():
    from repro.kernels.mma_reduce import ops

    assert ops.mma_sum_segments_pallas(jnp.zeros((0,)), (0,)).shape == (0,)
    out = ops.mma_sum_segments_pallas(jnp.zeros((0,)), (0, 0, 0))
    np.testing.assert_array_equal(np.asarray(out), [0.0, 0.0])


def test_segment_tile_layout_static_maps():
    from repro.kernels.mma_reduce import ops

    tcounts, seg_of, flush = ops.segment_tile_layout((0, 5, 5, 40), 16)
    assert tcounts == (1, 0, 3)
    np.testing.assert_array_equal(seg_of, [0, 2, 2, 2])
    np.testing.assert_array_equal(flush, [1, 0, 0, 1])


def test_legacy_shim_still_works(rng):
    """The pre-engine entry points survive as deprecation shims."""
    import repro.kernels as K

    x = jnp.asarray(rng.randn(1000).astype(np.float32))
    with pytest.deprecated_call():
        got = float(K.mma_sum_pallas(x, mode="fused"))
    np.testing.assert_allclose(
        got, float(R.reduce(x, backend="pallas_fused")), rtol=1e-6
    )


@hypothesis.settings(max_examples=25, deadline=None)
@hypothesis.given(
    n=st.integers(1, 40_000),
    seed=st.integers(0, 2**31 - 1),
    scale=st.floats(0.01, 100.0),
)
def test_property_sum_equivalence(n, seed, scale):
    x = np.random.RandomState(seed).randn(n).astype(np.float32) * scale
    got = float(R.reduce(jnp.asarray(x), backend="pallas_fused"))
    want = float(x.astype(np.float64).sum())
    tol = 4e-3 * max(np.abs(x.astype(np.float64)).sum(), 1e-3)
    assert abs(got - want) <= tol
