"""Pallas mma_reduce kernel vs pure-jnp oracle: shape/dtype sweeps +
hypothesis property tests (deliverable c)."""

import hypothesis
import hypothesis.strategies as st
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.mma_reduce import mma_sum_pallas, mma_sum_pallas_diff, ref

SIZES = [1, 5, 127, 128, 16384, 16385, 100_000, 300_000]
DTYPES = [np.float32, np.float16]


@pytest.mark.parametrize("n", SIZES)
@pytest.mark.parametrize("dtype", DTYPES)
@pytest.mark.parametrize("mode", ["hierarchical", "fused"])
def test_matches_sum_oracle(n, dtype, mode, rng):
    x = rng.randn(n).astype(dtype)
    got = float(mma_sum_pallas(jnp.asarray(x), mode=mode))
    want = float(ref.sum_ref(jnp.asarray(x)))
    tol = 4e-3 * max(np.abs(x.astype(np.float64)).sum(), 1.0)  # bf16 multipliers
    assert abs(got - want) <= tol, (got, want)


@pytest.mark.parametrize("n", [128 * 128, 3 * 128 * 128, 130_000])
def test_hierarchical_matches_eq13_oracle_exactly(n, rng):
    """The kernel's hierarchical mode must match the eq. (13) jnp emulation
    bit-for-bit (same tiling, same bf16 rounding)."""
    x = rng.randn(n).astype(np.float32)
    got = float(mma_sum_pallas(jnp.asarray(x), mode="hierarchical"))
    want = float(ref.hierarchy_ref(jnp.asarray(x)))
    assert got == want


def test_two_mma_tile_algebra(rng):
    """Eq. (9)-(12): per-tile partials equal replicated row/col sums."""
    tiles = jnp.asarray(rng.randn(4, 16, 16).astype(np.float32))
    got = ref.two_mma_ref(tiles, compute_dtype=jnp.float32)
    want = jnp.sum(tiles, axis=(1, 2))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5)


def test_fused_mode_more_accurate_than_hierarchical(rng):
    """The C-accumulator variant keeps partials in f32 -> strictly less
    rounding than the paper's write-back-and-relaunch hierarchy."""
    x = rng.randn(1 << 20).astype(np.float32)
    exact = x.astype(np.float64).sum()
    err_h = abs(float(mma_sum_pallas(jnp.asarray(x), mode="hierarchical")) - exact)
    err_f = abs(float(mma_sum_pallas(jnp.asarray(x), mode="fused")) - exact)
    assert err_f <= err_h + 1e-6


def test_gradient():
    x = jnp.arange(300.0, dtype=jnp.float32)
    g = jax.grad(lambda y: mma_sum_pallas_diff(y, "fused"))(x)
    np.testing.assert_allclose(np.asarray(g), 1.0)


@hypothesis.settings(max_examples=25, deadline=None)
@hypothesis.given(
    n=st.integers(1, 40_000),
    seed=st.integers(0, 2**31 - 1),
    scale=st.floats(0.01, 100.0),
)
def test_property_sum_equivalence(n, seed, scale):
    x = np.random.RandomState(seed).randn(n).astype(np.float32) * scale
    got = float(mma_sum_pallas(jnp.asarray(x), mode="fused"))
    want = float(x.astype(np.float64).sum())
    tol = 4e-3 * max(np.abs(x.astype(np.float64)).sum(), 1e-3)
    assert abs(got - want) <= tol
