"""Pallas mma_reduce backends vs pure-jnp oracle, driven through the unified
``repro.reduce`` engine (+ hypothesis property tests)."""

from _optional_hypothesis import hypothesis, st
import harness
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import reduce as R
from repro.kernels.mma_reduce import ref

SIZES = [1, 5, 127, 128, 16384, 16385, 100_000, 300_000]
DTYPES = [np.float32, np.float16]
PALLAS_BACKENDS = ["pallas_hier", "pallas_fused"]


@pytest.mark.parametrize("n", SIZES)
@pytest.mark.parametrize("dtype", DTYPES)
@pytest.mark.parametrize("backend", PALLAS_BACKENDS)
def test_matches_sum_oracle(n, dtype, backend, rng):
    x = rng.randn(n).astype(dtype)
    got = float(R.reduce(jnp.asarray(x), backend=backend))
    want = float(ref.sum_ref(jnp.asarray(x)))
    tol = harness.mass_tol(x)  # bf16 multipliers; shared budget
    assert abs(got - want) <= tol, (got, want)


@pytest.mark.parametrize("n", [128 * 128, 3 * 128 * 128, 130_000])
def test_hierarchical_matches_eq13_oracle_exactly(n, rng):
    """The kernel's hierarchical mode must match the eq. (13) jnp emulation
    bit-for-bit (same tiling, same bf16 rounding)."""
    x = rng.randn(n).astype(np.float32)
    got = float(R.reduce(jnp.asarray(x), backend="pallas_hier"))
    want = float(ref.hierarchy_ref(jnp.asarray(x)))
    assert got == want


def test_two_mma_tile_algebra(rng):
    """Eq. (9)-(12): per-tile partials equal replicated row/col sums."""
    tiles = jnp.asarray(rng.randn(4, 16, 16).astype(np.float32))
    got = ref.two_mma_ref(tiles, compute_dtype=jnp.float32)
    want = jnp.sum(tiles, axis=(1, 2))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5)


def test_fused_mode_more_accurate_than_hierarchical(rng):
    """The C-accumulator variant keeps partials in f32 -> strictly less
    rounding than the paper's write-back-and-relaunch hierarchy."""
    x = rng.randn(1 << 20).astype(np.float32)
    exact = x.astype(np.float64).sum()
    err_h = abs(float(R.reduce(jnp.asarray(x), backend="pallas_hier")) - exact)
    err_f = abs(float(R.reduce(jnp.asarray(x), backend="pallas_fused")) - exact)
    assert err_f <= err_h + 1e-6


def test_gradient():
    x = jnp.arange(300.0, dtype=jnp.float32)
    g = jax.grad(lambda y: R.reduce(y, backend="pallas_fused"))(x)
    np.testing.assert_allclose(np.asarray(g), 1.0)


def test_zero_size_input_is_additive_identity():
    """Regression: empty operands reduce to 0.0 on both kernel modes rather
    than erroring on a degenerate pad."""
    for backend in PALLAS_BACKENDS:
        assert float(R.reduce(jnp.zeros((0,)), backend=backend)) == 0.0


def test_segmented_kernel_matches_ref(rng):
    """The single-launch segmented kernel vs the per-segment oracle, across
    boundary-hostile layouts (boundaries inside and across tile blocks)."""
    from repro.kernels.mma_reduce import ops

    for sizes in (
        [100, 64, 1, 200],
        [5],
        [0, 3, 0],
        [16384, 1, 16385],          # exact tile, then straddling
        [7] * 19,                   # many boundaries inside one block
    ):
        offsets = np.concatenate([[0], np.cumsum(sizes)]).tolist()
        flat = jnp.asarray(rng.randn(int(offsets[-1])).astype(np.float32))
        for tpb in (1, 2, 8):
            got = ops.mma_sum_segments_pallas(
                flat, offsets, tiles_per_block=tpb,
                compute_dtype=jnp.float32,
            )
            want = ref.segmented_sum_ref(flat, offsets)
            np.testing.assert_allclose(
                np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-4,
                err_msg=f"sizes={sizes} tiles_per_block={tpb}",
            )


def test_segmented_kernel_empty_cases():
    from repro.kernels.mma_reduce import ops

    assert ops.mma_sum_segments_pallas(jnp.zeros((0,)), (0,)).shape == (0,)
    out = ops.mma_sum_segments_pallas(jnp.zeros((0,)), (0, 0, 0))
    np.testing.assert_array_equal(np.asarray(out), [0.0, 0.0])


def test_segment_tile_layout_static_maps():
    from repro.kernels.mma_reduce import ops

    tcounts, seg_of, flush = ops.segment_tile_layout((0, 5, 5, 40), 16)
    assert tcounts == (1, 0, 3)
    np.testing.assert_array_equal(seg_of, [0, 2, 2, 2])
    np.testing.assert_array_equal(flush, [1, 0, 0, 1])


def test_legacy_shim_still_works(rng):
    """The pre-engine entry points survive as deprecation shims."""
    import repro.kernels as K

    x = jnp.asarray(rng.randn(1000).astype(np.float32))
    with pytest.deprecated_call():
        got = float(K.mma_sum_pallas(x, mode="fused"))
    np.testing.assert_allclose(
        got, float(R.reduce(x, backend="pallas_fused")), rtol=1e-6
    )


@hypothesis.settings(max_examples=25, deadline=None)
@hypothesis.given(
    n=st.integers(1, 40_000),
    seed=st.integers(0, 2**31 - 1),
    scale=st.floats(0.01, 100.0),
)
def test_property_sum_equivalence(n, seed, scale):
    x = np.random.RandomState(seed).randn(n).astype(np.float32) * scale
    got = float(R.reduce(jnp.asarray(x), backend="pallas_fused"))
    want = float(x.astype(np.float64).sum())
    tol = harness.mass_tol(x, floor=1e-3)
    assert abs(got - want) <= tol


# ------------------- multi-core striped grid (tentpole) ----------------------


@pytest.mark.parametrize("num_cores", [1, 2, 3, 5])
@pytest.mark.parametrize("tpb", [1, 4, 8])
def test_multicore_lane_partials_bit_exact(num_cores, tpb, rng):
    """The striped kernel must match the op-for-op jnp emulation bit-for-bit
    for every lane geometry -- this pins striping, the masked-tail loads,
    and the per-lane carry, and (at num_cores=1) the pre-striping kernel's
    exact behavior. The kernel now ingests the FLAT buffer zero-copy; the
    emulation models the in-kernel masking as zero-padding (value-identical)."""
    from repro.kernels.mma_reduce import kernel as K

    x = jnp.asarray(rng.randn(100_000).astype(np.float32))
    got = np.asarray(
        K.reduce_fused(x, tiles_per_block=tpb, num_cores=num_cores)
    )
    want = np.asarray(
        ref.fused_lanes_ref(x, tiles_per_block=tpb, num_cores=num_cores)
    )
    assert got.shape == want.shape
    np.testing.assert_array_equal(
        got.view(np.uint32), want.view(np.uint32)
    )


@pytest.mark.parametrize("backend", PALLAS_BACKENDS)
@pytest.mark.parametrize("num_cores", [2, 4])
def test_multicore_matches_oracle(backend, num_cores, rng):
    """num_cores > 1 agrees with the xla oracle to the existing tolerances
    (pallas_hier ignores the knob -- its grid is already fully parallel)."""
    for n in (127, 16384, 100_000, 300_000):
        x = rng.randn(n).astype(np.float32)
        got = float(
            R.reduce(jnp.asarray(x), backend=backend, num_cores=num_cores)
        )
        want = float(x.astype(np.float64).sum())
        tol = harness.mass_tol(x)
        assert abs(got - want) <= tol, (n, got, want)


def test_multicore_exact_when_f32_and_integer_valued(rng):
    """With f32 multipliers and integer-valued data every partial is exact,
    so ANY lane count must give the exact per-segment sums -- this pins the
    lane-aware flush maps (no tile double-counted, none dropped)."""
    from repro.kernels.mma_reduce import ops

    for sizes in ([100, 64, 1, 200], [16384, 1, 16385], [7] * 19, [0, 3, 0]):
        offsets = np.concatenate([[0], np.cumsum(sizes)]).tolist()
        flat = jnp.asarray(
            rng.randint(-8, 8, size=int(offsets[-1])).astype(np.float32)
        )
        want = [
            float(np.asarray(flat[offsets[s] : offsets[s + 1]]).sum())
            for s in range(len(sizes))
        ]
        for c in (1, 2, 3, 4):
            for tpb in (1, 2, 8):
                got = ops.mma_sum_segments_pallas(
                    flat, offsets, tiles_per_block=tpb, num_cores=c,
                    compute_dtype=jnp.float32,
                )
                np.testing.assert_array_equal(
                    np.asarray(got), want,
                    err_msg=f"sizes={sizes} c={c} tpb={tpb}",
                )
        x = jnp.asarray(rng.randint(-8, 8, size=50_000).astype(np.float32))
        for c in (1, 2, 3):
            got = ops.mma_sum_pallas(
                x, mode="fused", num_cores=c, compute_dtype=jnp.float32
            )
            assert float(got) == float(np.asarray(x).sum()), c


@pytest.mark.parametrize("num_cores", [1, 2, 4])
def test_multicore_run_to_run_deterministic(num_cores, rng):
    """Two independent evaluations (fresh jit each) -> identical bits: the
    fixed-order lane combine must leave nothing schedule-dependent."""
    x = jnp.asarray(rng.randn(200_000).astype(np.float32))
    arrs = [x[:333], x[333:70_000], x[70_000:]]

    def full():
        return jax.jit(
            lambda a: R.reduce(a, backend="pallas_fused", num_cores=num_cores)
        )(x)

    def many():
        return jax.jit(
            lambda *a: R.reduce_many(
                a, backend="pallas_fused", num_cores=num_cores
            )
        )(*arrs)

    a, b = np.asarray(full()), np.asarray(full())
    np.testing.assert_array_equal(a.view(np.uint32), b.view(np.uint32))
    a, b = np.asarray(many()), np.asarray(many())
    np.testing.assert_array_equal(a.view(np.uint32), b.view(np.uint32))


def test_multicore_lane_flush_map():
    """Lane-aware boundary flags: every (lane, segment) group flushes exactly
    once, at its lane-maximal tile; C=1 reduces to the serial map."""
    from repro.kernels.mma_reduce import ops

    seg_of = np.asarray([0, 0, 0, 1, 1, 2, 2, 2], np.int32)
    serial = ops.lane_flush_map(seg_of, 1, 1)
    np.testing.assert_array_equal(serial, [0, 0, 1, 0, 1, 0, 0, 1])
    # r=1, c=2: lane 0 owns tiles 0,2,4,6; lane 1 owns 1,3,5,7
    striped = ops.lane_flush_map(seg_of, 1, 2)
    # lane 0 leaves seg0 after tile 2, seg1 after 4, seg2 after 6;
    # lane 1 leaves seg0 after tile 1, seg1 after 3, seg2 after 7
    np.testing.assert_array_equal(striped, [0, 1, 1, 1, 1, 0, 1, 1])
    for c in (1, 2, 3):
        f = ops.lane_flush_map(seg_of, 2, c)
        assert f.sum() >= 3  # every segment flushes at least once
        assert f.sum() <= 3 * c  # at most one flush per (lane, segment) visit


def test_segmented_kernel_pads_non_multiple_streams(rng):
    """Regression (carried over): ``reduce_segments`` pads the COVER MAPS
    itself when the tile count is not a multiple of the lane count -- pad
    tiles are fully-masked no-ops (lo == hi == 0), so a 3-tile cover on 2
    lanes reduces exactly."""
    from repro.kernels.mma_reduce import kernel as K
    from repro.kernels.mma_reduce import ops

    m = 128
    group = m * m
    flat = jnp.asarray(rng.randn(3 * group).astype(np.float32))
    offsets = (0, 2 * group, 3 * group)
    _, src, seg_of, lo, hi = ops.segment_cover_layout(offsets, group)
    flush = ops.lane_flush_map(seg_of, 1, 2)
    sub = K.reduce_segments(
        flat, src, seg_of, flush, lo, hi, 2, num_cores=2,
        compute_dtype=jnp.float32,
    )
    got = np.asarray(sub).sum(0)
    want = [float(jnp.sum(flat[: 2 * group])), float(jnp.sum(flat[2 * group :]))]
    np.testing.assert_allclose(got, want, rtol=1e-4)


def test_multicore_trace_counts_match_cost_model():
    """ops' static ReductionTrace split == cost_model.fused_mma_ops: the
    geometry the kernel runs and the model the planner trusts must agree."""
    from repro.core import cost_model
    from repro.kernels.mma_reduce import ops

    for n in (1, 130_000, 1 << 20, 1 << 24):
        for tpb in (2, 8):
            for c in (1, 2, 4, 16):
                tr = ops.fused_trace(n, tpb, c)
                mc = cost_model.fused_mma_ops(
                    n, num_cores=c, tiles_per_block=tpb
                )
                assert tr.num_cores == mc.num_cores
                assert tr.lane_mma_ops == mc.lane
                assert tr.combine_mma_ops == mc.combine
                assert tr.mma_ops == mc.total, (n, tpb, c)
    # num_cores=1 recovers the serial fused count: n/m^2 (+pad) + 2
    assert ops.fused_trace(1 << 20, 8, 1).mma_ops == 64 + 2
    # segmented: traced flush count == in-kernel collapse MMAs
    tr: list = []
    ops.mma_sum_segments_pallas(
        jnp.ones(40_000), (0, 20_000, 40_000), num_cores=2, trace=tr
    )
    (t,) = tr
    # each segment pads to whole tiles: 2 x ceil(20_000 / 128^2) = 4 tiles
    mc = cost_model.segmented_mma_ops(
        40_000, tiles=4, flushes=t.combine_mma_ops, num_cores=2
    )
    assert t.mma_ops == mc.total
    assert t.lane_mma_ops == mc.lane and t.num_cores == mc.num_cores


@hypothesis.settings(max_examples=30, deadline=None)
@hypothesis.given(
    n=st.integers(1, 40_000),
    seed=st.integers(0, 2**31 - 1),
    num_cores=st.integers(1, 5),
    tpb=st.sampled_from([1, 2, 4, 8]),
    dtype=st.sampled_from([np.float32, np.float16]),
)
def test_property_multicore_grid_vs_oracle(n, seed, num_cores, tpb, dtype):
    """Acceptance sweep: the grid-parallel kernel pinned to the xla oracle
    across ragged n x dtype x num_cores x tiles_per_block."""
    x = np.random.RandomState(seed).randn(n).astype(dtype)
    got = float(
        R.reduce(
            jnp.asarray(x),
            backend="pallas_fused",
            num_cores=num_cores,
            tiles_per_block=tpb,
        )
    )
    want = float(x.astype(np.float64).sum())
    tol = harness.mass_tol(x, floor=1e-3)
    assert abs(got - want) <= tol


@pytest.mark.parametrize("num_cores", [1, 2])
def test_multicore_kahan_single_launch_and_accurate(num_cores, rng):
    """precision="kahan" on pallas_fused carries the compensation in-kernel:
    still ONE pallas_call, and at least as accurate as the native carry."""
    x = jnp.asarray((rng.randn(300_000) * 100).astype(np.float32))
    jaxpr = jax.make_jaxpr(
        lambda v: R.reduce(
            v, backend="pallas_fused", precision="kahan", num_cores=num_cores
        )
    )(x)
    assert str(jaxpr).count("pallas_call") == 1
    exact = np.asarray(x).astype(np.float64).sum()
    e_native = abs(
        float(
            R.reduce(
                x, backend="pallas_fused", compute_dtype="float32",
                num_cores=num_cores,
            )
        )
        - exact
    )
    e_kahan = abs(
        float(
            R.reduce(
                x, backend="pallas_fused", compute_dtype="float32",
                precision="kahan", num_cores=num_cores,
            )
        )
        - exact
    )
    assert e_kahan <= e_native + 1e-9
