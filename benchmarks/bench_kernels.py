"""Kernel micro-bench: wall time of the reduction engine's backends swept
through the one public API (interpret mode on this CPU container -- a
correctness-side timing, NOT TPU perf; the TPU numbers come from the dry-run
roofline) plus the fused kernels that ride along."""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import reduce as R
from repro.core import cost_model
from repro.kernels import flash_attention, rmsnorm
from repro.kernels.cross_entropy import cross_entropy
from repro.kernels.mma_reduce import ops as mma_ops
from repro.reduce import inspect as rinspect


def _time(fn, *args, reps=3):
    # Warm-up must BLOCK: dispatch is async, so without block_until_ready the
    # first timed iteration still waits on the compile + warm-up execution
    # and JIT time gets averaged into the reported microseconds.
    jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / reps * 1e6  # us


def run():
    csv = []
    rng = np.random.RandomState(0)
    x = jnp.asarray(rng.randn(1 << 18).astype(np.float32))

    # every registered backend through the single reduce() entry point;
    # jnp-level backends run as real XLA CPU code, kernel backends emulate
    # under Pallas interpret mode on this container
    for name in R.available_backends():
        fn = jax.jit(lambda a, n=name: R.reduce(a, backend=n))
        mode = "xla_cpu" if R.get_backend(name).native_autodiff else "interpret"
        csv.append(f"reduce_{name}_262k,{_time(fn, x):.0f},{mode}")
    # the planner's own pick for this shape
    plan = R.plan_for(x.shape, x.dtype, backend="auto")
    csv.append(
        f"reduce_auto_262k,{_time(jax.jit(lambda a: R.reduce(a)), x):.0f},"
        f"plan={plan.backend}"
    )

    # multi-core striping: the fused kernel swept over lane counts. On this
    # CPU container interpret mode runs the lanes sequentially, so the row is
    # a correctness-side combine-overhead bench, not the parallel win; the
    # trace rows carry the STATIC per-lane/combine MMA split of the plan the
    # timed call actually executed (n/tpb embedded in the derived column so
    # benchmarks/check_bench.py can recompute the cost model and fail CI on
    # drift).
    for c in (1, 2, 4):
        plan_c = R.plan_for(
            x.shape, x.dtype, backend="pallas_fused", num_cores=c
        )
        fn = jax.jit(lambda a, p=plan_c: R.reduce(a, plan=p))
        csv.append(f"reduce_pallas_fused_262k_c{c},{_time(fn, x):.0f},interpret")
        tr = mma_ops.fused_trace(x.size, plan_c.tiles_per_block, c)
        assert tr.mma_ops == cost_model.fused_mma_ops(
            x.size, num_cores=c, tiles_per_block=plan_c.tiles_per_block
        ).total
        csv.append(
            f"mma_fused_262k_c{c},{tr.mma_ops},"
            f"lane={tr.lane_mma_ops};combine={tr.combine_mma_ops};"
            f"n={x.size};tpb={plan_c.tiles_per_block}"
        )

    # zero-copy ingestion: bf16 vs f32 native streams through the SAME fused
    # kernel (in-kernel cast; no host-side staging). The timing rows are
    # interpret-mode relative numbers; the hbm_* rows carry the MODELED
    # bytes (value) plus the lowered program's actual pallas_call boundary
    # bytes (measured=, from the jaxpr -- asserted == the model's launch_io
    # by check_bench), and the staged-f32 comparison row models the
    # pre-zero-copy cast+pad ingestion this PR removed (~3x the bytes on
    # bf16).
    n = x.size
    xb = x.astype(jnp.bfloat16)
    for arr, dt_name in ((xb, "bf16"), (x, "f32")):
        # resolve the SAME plan the timed/traced call runs, and thread its
        # geometry into both the model and the derived column -- never
        # assume c=1/tpb=8 (the planner defaults num_cores to the device's
        # core count, so on a real TPU runner the lowered program differs)
        plan_h = R.plan_for(arr.shape, arr.dtype, backend="pallas_fused")
        fn = jax.jit(lambda a, p=plan_h: R.reduce(a, plan=p))
        csv.append(
            f"reduce_pallas_fused_262k_{dt_name},{_time(fn, arr):.0f},"
            "interpret_native_ingest"
        )
        bs = arr.dtype.itemsize
        model = cost_model.hbm_bytes(
            "fused", n, bs, num_cores=plan_h.num_cores,
            tiles_per_block=plan_h.tiles_per_block,
        )
        measured = rinspect.pallas_io_bytes(
            jax.make_jaxpr(lambda a, p=plan_h: R.reduce(a, plan=p))(arr)
        )
        csv.append(
            f"hbm_fused_262k_{dt_name},{model.total},"
            f"path=fused;n={n};itemsize={bs};c={plan_h.num_cores};"
            f"tpb={plan_h.tiles_per_block};measured={measured}"
        )
    staged = cost_model.hbm_bytes("fused_staged", n, 2)
    csv.append(
        f"hbm_fused_staged_262k_bf16,{staged.total},"
        f"path=fused_staged;n={n};itemsize=2"
    )

    # tensor-core prefix sums: the triangular-MMA scan swept over lane
    # counts. Timing rows are interpret-mode relative numbers; the
    # mma_scan_* rows carry the trace-counted MMA split (3 per owned tile,
    # 2 per carry-rebuilt tile) of the plan the timed call executed, and
    # the hbm_scan_* rows carry the modeled traffic plus the lowered
    # program's pallas_call boundary bytes -- check_bench recomputes
    # scan_mma_ops / scan_hbm_bytes from the derived params and fails CI on
    # drift. The staged row models the XLA two-pass bf16 route (upcast
    # copy + f32 scan + downcast) the native-ingest kernel replaces.
    from repro.kernels import scan as kscan

    for c in (1, 2, 4):
        plan_s = R.scan_plan_for(
            x.shape, x.dtype, backend="pallas_fused", num_cores=c
        )
        fn = jax.jit(lambda a, p=plan_s: R.scan(a, plan=p))
        csv.append(f"scan_pallas_fused_262k_c{c},{_time(fn, x):.0f},interpret")
        str_ = []
        kscan.mma_scan_pallas(
            x, num_cores=c, tiles_per_block=plan_s.tiles_per_block, trace=str_
        )
        tr_s = str_[0]
        assert tr_s.mma_ops == cost_model.scan_mma_ops(
            x.size, num_cores=c, tiles_per_block=plan_s.tiles_per_block
        ).total
        csv.append(
            f"mma_scan_262k_c{c},{tr_s.mma_ops},"
            f"lane={tr_s.lane_mma_ops};carry={tr_s.carry_mma_ops};"
            f"n={x.size};tpb={plan_s.tiles_per_block}"
        )
    for arr, dt_name in ((xb, "bf16"), (x, "f32")):
        plan_sh = R.scan_plan_for(arr.shape, arr.dtype, backend="pallas_fused")
        fn = jax.jit(lambda a, p=plan_sh: R.scan(a, plan=p))
        csv.append(
            f"scan_pallas_fused_262k_{dt_name},{_time(fn, arr):.0f},"
            "interpret_native_ingest"
        )
        bs = arr.dtype.itemsize
        model_s = cost_model.hbm_bytes(
            "scan", n, bs, num_cores=plan_sh.num_cores,
            tiles_per_block=plan_sh.tiles_per_block,
        )
        measured_s = rinspect.pallas_io_bytes(
            jax.make_jaxpr(lambda a, p=plan_sh: R.scan(a, plan=p))(arr)
        )
        csv.append(
            f"hbm_scan_262k_{dt_name},{model_s.total},"
            f"path=scan;n={n};itemsize={bs};c={plan_sh.num_cores};"
            f"tpb={plan_sh.tiles_per_block};measured={measured_s}"
        )
    staged_s = cost_model.hbm_bytes("scan_staged", n, 2)
    csv.append(
        f"hbm_scan_staged_262k_bf16,{staged_s.total},"
        f"path=scan_staged;n={n};itemsize=2"
    )

    # single-stream norms: the in-kernel square prologue. A bf16 sumsq /
    # norm2 now streams the raw buffer ONCE (byte-identical launch to the
    # plain sum -- path=fused); the *_staged comparison row models the
    # PR-4 two-pass route (host f32 square pass + staged f32 stream:
    # n*2 + n*4 + n*4 bytes). check_bench recomputes both models and
    # requires the >4x win plus measured == launch_io on the lowered
    # program.
    plan_sq = R.plan_for(xb.shape, xb.dtype, kind="sumsq",
                         backend="pallas_fused")
    fn = jax.jit(lambda a, p=plan_sq: R.reduce(a, kind="sumsq", plan=p))
    csv.append(
        f"reduce_sumsq_262k_bf16,{_time(fn, xb):.0f},interpret_single_stream"
    )
    model_sq = cost_model.hbm_bytes(
        "fused", n, 2, num_cores=plan_sq.num_cores,
        tiles_per_block=plan_sq.tiles_per_block,
    )
    measured_sq = rinspect.pallas_io_bytes(
        jax.make_jaxpr(lambda a, p=plan_sq: R.reduce(a, kind="sumsq", plan=p))(
            xb
        )
    )
    csv.append(
        f"hbm_sumsq_262k_bf16,{model_sq.total},"
        f"path=fused;n={n};itemsize=2;c={plan_sq.num_cores};"
        f"tpb={plan_sq.tiles_per_block};measured={measured_sq}"
    )
    staged_sq = cost_model.hbm_bytes("sumsq_staged", n, 2)
    csv.append(
        f"hbm_sumsq_staged_262k_bf16,{staged_sq.total},"
        f"path=sumsq_staged;n={n};itemsize=2"
    )
    # the optimizer's statistic: jitted multi-leaf bf16 norm2, one launch,
    # leaves squared in-kernel (parts path)
    tree_leaves = tuple(
        jnp.asarray(rng.randn(s).astype(np.float32)).astype(jnp.bfloat16)
        for s in (1 << 16, 1 << 14, 333)
    )
    fn_tree = jax.jit(
        lambda *g: R.reduce_tree(list(g), "norm2", backend="pallas_fused")
    )
    csv.append(
        f"reduce_tree_norm2_3leaf_bf16,{_time(fn_tree, *tree_leaves):.0f},"
        "interpret_single_stream"
    )
    tree_bytes = sum(v.nbytes for v in tree_leaves)
    model_tree = cost_model.hbm_bytes(
        "parts", tree_bytes // 2, 2, segments=len(tree_leaves)
    )
    measured_tree = rinspect.pallas_io_bytes(
        jax.make_jaxpr(fn_tree)(*tree_leaves)
    )
    csv.append(
        f"hbm_tree_norm2_3leaf_bf16,{model_tree.total},"
        f"path=parts;n={tree_bytes // 2};itemsize=2;"
        f"segments={len(tree_leaves)};measured={measured_tree}"
    )

    # one-HBM-trip optimizer step: the epilogue fork. global_norm_and_clip
    # finishes the norm's sqrt AND the AdamW clip coefficient (min/max/div)
    # inside the SAME parts launch that reads the grad leaves, and returns
    # the per-leaf sumsq slots that feed the fused second moment -- so the
    # whole statistic side of a step is ONE read of each grad byte. The
    # hbm_step rows carry the modeled traffic (parts read + S+2 f32 output
    # slots: per-leaf sumsq plus the [gnorm, clip] chain results) and the
    # lowered program's measured launch-boundary bytes; check_bench
    # recomputes the model from the derived params and additionally gates
    # total <= 1.25x the raw grad bytes.
    from repro.optim import adamw

    for dt, dt_name in ((jnp.bfloat16, "bf16"), (jnp.float32, "f32")):
        leaves = {
            f"l{i}": jnp.asarray(rng.randn(s).astype(np.float32)).astype(dt)
            for i, s in enumerate((1 << 16, 1 << 14, 333))
        }
        stat = lambda g: adamw.global_norm_and_clip(
            g, 1.0, backend="pallas_fused", return_per_leaf=True
        )
        csv.append(
            f"reduce_step_stat_3leaf_{dt_name},"
            f"{_time(jax.jit(stat), leaves):.0f},interpret_one_launch"
        )
        grad_bytes = sum(v.nbytes for v in leaves.values())
        itemsize = jnp.dtype(dt).itemsize
        seg = len(leaves) + 2  # per-leaf sumsq slots + the (gnorm, clip) fork
        model_step = cost_model.hbm_bytes(
            "parts", grad_bytes // itemsize, itemsize, segments=seg
        )
        measured_step = rinspect.pallas_io_bytes(jax.make_jaxpr(stat)(leaves))
        csv.append(
            f"hbm_step_grads_{dt_name},{model_step.total},"
            f"path=parts;n={grad_bytes // itemsize};itemsize={itemsize};"
            f"segments={seg};measured={measured_step}"
        )
        # the route this PR replaced: norm launch + host sqrt/min chain +
        # the standard update's second elementwise read of every grad leaf
        two_trip = (
            cost_model.hbm_bytes(
                "parts", grad_bytes // itemsize, itemsize,
                segments=len(leaves),
            ).total
            + grad_bytes
        )
        csv.append(
            f"hbm_step_grads_2trip_{dt_name},{two_trip},"
            f"path=parts_2trip;n={grad_bytes // itemsize};"
            f"itemsize={itemsize};segments={len(leaves)}"
        )

    # segmented multi-reduce: 32 ragged segments, one pass vs one launch per
    # segment (the loop is what reduce_tree/reduce_many replaced)
    segs = tuple(
        jnp.asarray(rng.randn(n).astype(np.float32))
        for n in (33, 1 << 10, 1 << 14, 1 << 17) * 8
    )
    many = jax.jit(lambda *a: R.reduce_many(a, backend="mma_jnp"))
    looped = jax.jit(
        lambda *a: jnp.stack([R.reduce(x, backend="mma_jnp") for x in a])
    )
    csv.append(f"reduce_many_32seg_mma_jnp,{_time(many, *segs):.0f},one_pass")
    csv.append(f"reduce_loop_32seg_mma_jnp,{_time(looped, *segs):.0f},n_launches")
    many_pl = jax.jit(lambda *a: R.reduce_many(a, backend="pallas_fused"))
    csv.append(
        f"reduce_many_32seg_pallas,{_time(many_pl, *segs):.0f},one_launch_interpret"
    )
    # zero-copy multi-reduce traffic: every part is its own launch operand
    total_parts = sum(int(s.size) for s in segs)
    parts_model = cost_model.hbm_bytes(
        "parts", total_parts, 4, segments=len(segs)
    )
    parts_measured = rinspect.pallas_io_bytes(
        jax.make_jaxpr(lambda *a: R.reduce_many(a, backend="pallas_fused"))(
            *segs
        )
    )
    csv.append(
        f"hbm_parts_32seg_f32,{parts_model.total},"
        f"path=parts;n={total_parts};itemsize=4;segments={len(segs)};"
        f"measured={parts_measured}"
    )

    h = jnp.asarray(rng.randn(512, 1024).astype(np.float32))
    g = jnp.ones((1024,), jnp.float32)
    csv.append(f"kernel_rmsnorm_512x1024,{_time(rmsnorm, h, g):.0f},interpret")

    q = jnp.asarray(rng.randn(1, 4, 256, 64).astype(np.float32))
    csv.append(
        f"kernel_flash_attn_256,{_time(lambda q: flash_attention(q, q, q), q):.0f},interpret"
    )

    logits = jnp.asarray(rng.randn(64, 8192).astype(np.float32))
    labels = jnp.asarray(rng.randint(0, 8192, 64))
    csv.append(f"kernel_cross_entropy_64x8192,{_time(cross_entropy, logits, labels):.0f},interpret")
    return csv
