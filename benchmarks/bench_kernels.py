"""Kernel micro-bench: wall time of Pallas kernels (interpret mode on this
CPU container -- a correctness-side timing, NOT TPU perf; the TPU numbers
come from the dry-run roofline) plus the MMA-op counts that feed the model."""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import mma_sum
from repro.kernels import flash_attention, mma_sum_pallas, rmsnorm
from repro.kernels.cross_entropy import cross_entropy


def _time(fn, *args, reps=3):
    fn(*args)  # compile/warm
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / reps * 1e6  # us


def run():
    csv = []
    rng = np.random.RandomState(0)
    x = jnp.asarray(rng.randn(1 << 18).astype(np.float32))
    csv.append(f"kernel_mma_reduce_fused_262k,{_time(lambda a: mma_sum_pallas(a, mode='fused'), x):.0f},interpret")
    csv.append(f"kernel_mma_reduce_hier_262k,{_time(lambda a: mma_sum_pallas(a, mode='hierarchical'), x):.0f},interpret")
    csv.append(f"xla_mma_reduce_262k,{_time(jax.jit(mma_sum), x):.0f},xla_cpu")

    h = jnp.asarray(rng.randn(512, 1024).astype(np.float32))
    g = jnp.ones((1024,), jnp.float32)
    csv.append(f"kernel_rmsnorm_512x1024,{_time(rmsnorm, h, g):.0f},interpret")

    q = jnp.asarray(rng.randn(1, 4, 256, 64).astype(np.float32))
    csv.append(
        f"kernel_flash_attn_256,{_time(lambda q: flash_attention(q, q, q), q):.0f},interpret"
    )

    logits = jnp.asarray(rng.randn(64, 8192).astype(np.float32))
    labels = jnp.asarray(rng.randint(0, 8192, 64))
    csv.append(f"kernel_cross_entropy_64x8192,{_time(cross_entropy, logits, labels):.0f},interpret")
    return csv
