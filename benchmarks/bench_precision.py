"""Paper section V future work: precision loss of low-precision MMA
reductions, with the Markidis-style refinements (f32 accumulation, Kahan).

Distributions matter for summation error, so three input regimes are
measured against f64 ground truth: standard normal, shifted (non-zero
mean, cancellation-free), and adversarial (large+tiny mix)."""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.core import precision
from repro.core.mma_reduce import classic_tree_sum, mma_sum


def _inputs(kind: str, n: int, rng):
    if kind == "normal":
        return rng.randn(n).astype(np.float32)
    if kind == "shifted":
        return (rng.rand(n) + 1.0).astype(np.float32)
    if kind == "adversarial":
        x = rng.randn(n).astype(np.float32)
        x[:: 1000] *= 1e5
        return x
    raise ValueError(kind)


def run():
    csv = []
    rng = np.random.RandomState(42)
    n = 1 << 20
    for kind in ("normal", "shifted", "adversarial"):
        x = _inputs(kind, n, rng)
        exact = x.astype(np.float64).sum()
        xj = jnp.asarray(x)
        variants = {
            "mma_bf16mul_f32acc": mma_sum(xj),
            "mma_f32": mma_sum(xj, compute_dtype=jnp.float32),
            "mma_fp16mul": mma_sum(xj, compute_dtype=jnp.float16),
            "classic_pairwise_f32": classic_tree_sum(xj),
            "blocked_kahan_mma": precision.blocked_kahan_mma(xj),
        }
        for name, v in variants.items():
            rel = abs(float(v) - exact) / max(abs(exact), 1e-30)
            csv.append(f"precision_{kind}_{name},{rel:.3e},n={n}")
    return csv
