"""Generate the §Dry-run and §Roofline tables in EXPERIMENTS.md from
artifacts/dryrun/*.json. Idempotent: rewrites everything after the
GENERATED marker."""

from __future__ import annotations

import json
import pathlib

from benchmarks import roofline as RL

ROOT = pathlib.Path(__file__).resolve().parents[1]
MARKER = "<!-- GENERATED TABLES BELOW -->"


def dryrun_table(arts) -> str:
    hdr = ("| arch | shape | mesh | status | compile s | args GB/dev | "
           "temp GB/dev | coll ops | wire MB static |\n" + "|---|" * 9)
    rows = [hdr]
    for d in arts:
        if d["status"] == "skipped":
            rows.append(
                f"| {d['arch']} | {d['shape']} | {d['mesh']} | SKIP | — | — | — | — | "
                f"{d['reason'][:60]} |"
            )
            continue
        mem = d.get("memory", {})
        coll = d.get("collectives", {})
        nops = sum(
            v["count"] for bkt in ("entry", "loop")
            for v in coll.get(bkt, {}).values() if isinstance(v, dict)
        )
        rows.append(
            f"| {d['arch']} | {d['shape']} | {d['mesh']} | ok | "
            f"{d.get('compile_s', 0):.0f} | "
            f"{mem.get('argument_size_in_bytes', 0)/1e9:.2f} | "
            f"{mem.get('temp_size_in_bytes', 0)/1e9:.2f} | {nops} | "
            f"{coll.get('total_wire_bytes', 0)/1e6:.1f} |"
        )
    return "\n".join(rows)


def main():
    arts = []
    for p in sorted(RL.ART_DIR.glob("*.json")):
        d = json.loads(p.read_text())
        if not d.get("tag"):
            arts.append(d)
    arts.sort(key=lambda d: (d["arch"], d["shape"], d["mesh"]))

    out = ["", MARKER, ""]
    out.append("## §Dry-run table (80 cells; per-device numbers)\n")
    out.append(dryrun_table(arts))
    for mesh in ("single", "multi"):
        rows = RL.load_rows(mesh=mesh)
        rows.sort(key=lambda r: (r["arch"], r["shape"]))
        out.append(f"\n## §Roofline table — {mesh}-pod mesh "
                   f"({'256' if mesh == 'single' else '512'} chips)\n")
        out.append(RL.render_markdown(rows))
    out.append(
        "\nReading the fractions: decode shapes are memory-bound by design "
        "(cache streaming); train shapes on FSDP meshes report unoverlapped "
        "collective terms (lower-bound fractions; the TPU runtime overlaps "
        "FSDP gathers with compute). `6ND/HLO` < 0.75 reflects remat "
        "recompute + attention/CE/MMA-encoding overhead, itemized in "
        "benchmarks/roofline.py.\n"
    )

    md = (ROOT / "EXPERIMENTS.md").read_text()
    base = md.split(MARKER)[0].rstrip() + "\n"
    (ROOT / "EXPERIMENTS.md").write_text(base + "\n".join(out) + "\n")
    print(f"rendered {len(arts)} artifacts into EXPERIMENTS.md")


if __name__ == "__main__":
    main()
