"""Roofline analysis from dry-run artifacts (deliverable g).

Three terms per (arch x shape x mesh), in seconds per step:

  compute    = FLOPs_step / (chips * 197e12)       [bf16 peak]
  memory     = HBM_bytes_step / (chips * 819e9)
  collective = wire_bytes_step / (chips * 50e9)    [per-link ICI]

Methodology notes (full discussion in EXPERIMENTS.md):
  * XLA's static cost_analysis counts while-loop bodies ONCE, so raw HLO
    numbers undercount scanned layers/microbatches. FLOPs and HBM bytes are
    therefore derived analytically from the architecture (itemized below,
    including remat recompute, causal-attention averaging, MoE top-k, the
    MMA-reduction redundancy, optimizer traffic), and cross-checked against
    cost_analysis on the single-unit probe identity.
  * Collective bytes ARE taken from the compiled HLO (exact shard shapes),
    split into entry-computation ops (once per step: gradient reductions)
    and loop-body ops (scaled by the structural trip counts recorded in the
    artifact: n_units x microbatches).
  * MODEL_FLOPS = 6 * N_active * tokens (the "useful" flops); the ratio
    MODEL_FLOPS / FLOPs_step exposes remat/attention/redundancy overhead.
"""

from __future__ import annotations

import json
import pathlib

from repro.configs import ARCHS, SHAPES, get_arch, get_shape

PEAK = 197e12
HBM = 819e9
ICI = 50e9

ART_DIR = pathlib.Path(__file__).resolve().parents[1] / "artifacts" / "dryrun"


# ----------------------------- analytic FLOPs -------------------------------


def _layer_flops_per_token(cfg, kind: str, s_ctx: float) -> float:
    """Forward matmul FLOPs per token for one layer of `kind`; s_ctx is the
    average attended context length."""
    d = cfg.d_model
    f = 0.0
    if kind in ("attn", "local_attn", "xattn"):
        if cfg.mla is not None and kind != "xattn":
            m = cfg.mla
            qk = m.qk_nope_dim + m.qk_rope_dim
            f += 2 * d * m.q_lora_rank + 2 * m.q_lora_rank * cfg.n_heads * qk
            f += 2 * d * (m.kv_lora_rank + m.qk_rope_dim)
            f += 2 * m.kv_lora_rank * cfg.n_heads * (m.qk_nope_dim + m.v_head_dim)
            f += 2 * cfg.n_heads * m.v_head_dim * d
            f += 2 * s_ctx * cfg.n_heads * (qk + m.v_head_dim)
        else:
            hd = cfg.n_heads * cfg.d_head
            kvd = cfg.n_kv_heads * cfg.d_head
            f += 2 * d * (hd + 2 * kvd) + 2 * hd * d
            f += 4 * s_ctx * hd  # scores + pv
        f += _ffn_flops_per_token(cfg)  # ffn attached to attention blocks
    elif kind == "ssm":
        s = cfg.ssm
        di = s.expand * d
        nh = di // s.headdim
        gn = s.n_groups * s.d_state
        f += 2 * d * (2 * di + 2 * gn + nh)        # z / xBC / dt projections
        f += 2 * di * d                            # out proj
        f += 2 * s.conv_width * (di + 2 * gn)      # depthwise conv
        q = s.chunk
        # SSD chunked algebra per token (CB^T, y_diag, states, y_off)
        f += 2 * nh * (q * s.d_state / s.n_groups * 0 + q)  # CB row (amortized)
        f += 2 * q * gn + 2 * q * di + 4 * s.d_state * di
    elif kind == "rec":
        w = (cfg.rglru.lru_width or d)
        f += 2 * d * w * 2 + 2 * w * d             # two in-proj + out
        f += 2 * cfg.rglru.conv_width * w
        f += 2 * 2 * w * (w // 16)                 # block-diag gates
        f += 10 * w                                # scan elementwise
        f += _ffn_flops_per_token(cfg)
    return f


def _ffn_flops_per_token(cfg) -> float:
    d = cfg.d_model
    if cfg.moe is not None:
        e = cfg.moe
        per = (3 if cfg.ffn_kind == "swiglu" else 2) * 2 * d * e.d_ff_expert
        return e.top_k * per + 2 * d * e.n_experts  # + router
    return (3 if cfg.ffn_kind == "swiglu" else 2) * 2 * d * cfg.d_ff


def _head_flops_per_token(cfg) -> float:
    k = max(1, cfg.n_codebooks)
    return 2 * cfg.d_model * cfg.vocab_size * k


def _mma_overhead_per_token(cfg, s_ctx: float) -> float:
    """Extra FLOPs from encoding reductions as 128-wide all-ones dots:
    2 norms/layer (2 moments) + attention softmax denominators + CE denom."""
    d = cfg.d_model
    per_norm = 2 * d * 128 * 2
    n_attn = sum(1 for kk in cfg.pattern_layers if kk in ("attn", "local_attn", "xattn"))
    denom = 2 * s_ctx * 128 * cfg.n_heads if n_attn else 0.0
    ce = 2 * cfg.vocab_size * 128 * max(1, cfg.n_codebooks)
    return cfg.n_layers * per_norm + n_attn * denom + ce


def analytic_flops(arch: str, shape_name: str) -> dict:
    """Itemized GLOBAL FLOPs for one step of the cell."""
    cfg = get_arch(arch)
    shape = get_shape(shape_name)
    if shape.mode == "train":
        tokens = shape.global_batch * (shape.seq_len - 1)
        s_ctx_full = shape.seq_len / 2  # causal average
    elif shape.mode == "prefill":
        tokens = shape.global_batch * shape.seq_len
        s_ctx_full = shape.seq_len / 2
    else:  # decode: one token per sequence, attends the whole cache
        tokens = shape.global_batch
        s_ctx_full = shape.seq_len

    fwd = 0.0
    for kind in cfg.pattern_layers:
        s_ctx = s_ctx_full
        if kind == "local_attn" and cfg.window:
            s_ctx = min(s_ctx_full, cfg.window)
        if kind == "xattn":
            s_ctx = cfg.n_img_tokens
        fwd += _layer_flops_per_token(cfg, kind, s_ctx)
    fwd_total = fwd * tokens
    head = _head_flops_per_token(cfg) * tokens
    mma_over = _mma_overhead_per_token(cfg, s_ctx_full) * tokens

    if shape.mode == "train":
        # fwd + remat-recompute + 2x bwd, for backbone and checkpointed head
        total = 4 * (fwd_total + head) + 2 * mma_over
        items = dict(fwd=fwd_total, head=head, bwd=2 * (fwd_total + head),
                     remat=fwd_total + head, mma_overhead=2 * mma_over)
    else:
        total = fwd_total + head + mma_over
        items = dict(fwd=fwd_total, head=head, mma_overhead=mma_over)
    model_flops = 6 * cfg.active_param_count() * tokens if shape.mode == "train" \
        else 2 * cfg.active_param_count() * tokens
    return dict(total=total, model_flops=model_flops, tokens=tokens, **items)


# ----------------------------- analytic bytes -------------------------------


def analytic_bytes(arch: str, shape_name: str, struct: dict) -> dict:
    """Per-device HBM traffic per step (bytes)."""
    cfg = get_arch(arch)
    shape = get_shape(shape_name)
    n = cfg.param_count()
    tp = struct["model_degree"]
    fsdp = struct["data_degree"]
    micro = struct["microbatches"]
    dev = tp * fsdp
    if shape.mode == "train":
        tokens_dev = shape.global_batch * shape.seq_len / fsdp
        # weights streamed fwd+recompute+bwd per microbatch (gathered to the
        # TP shard), optimizer f32 m/v/p r/w, f32 grad accum r/w per micro
        w = 3 * micro * 2 * n / tp
        opt = 20 * n / dev
        gacc = 2 * micro * 4 * n / dev
        act = 12 * cfg.d_model * 2 * tokens_dev * cfg.n_layers / max(tp, 1)
        total = w + opt + gacc + act
        items = dict(weights=w, optimizer=opt, grad_accum=gacc, activations=act)
    elif shape.mode == "prefill":
        tokens_dev = shape.global_batch * shape.seq_len / fsdp
        w = 2 * n / tp
        act = 8 * cfg.d_model * 2 * tokens_dev * cfg.n_layers / max(tp, 1)
        cache = _cache_bytes(cfg, shape) / dev
        total = w + act + cache
        items = dict(weights=w, activations=act, cache_write=cache)
    else:  # decode: stream the whole cache + the TP weight shard once
        cache = _cache_bytes(cfg, shape) / dev
        w = 2 * n / tp
        total = w + cache
        items = dict(weights=w, cache_read=cache)
    return dict(total=total, **items)


def _cache_bytes(cfg, shape) -> float:
    b, s = shape.global_batch, shape.seq_len
    total = 0.0
    for kind in cfg.pattern_layers:
        if kind in ("attn", "local_attn"):
            if cfg.mla is not None:
                total += b * s * (cfg.mla.kv_lora_rank + cfg.mla.qk_rope_dim) * 2
            else:
                eff = min(s, cfg.window) if (kind == "local_attn" and cfg.window) else s
                total += 2 * b * eff * cfg.n_kv_heads * cfg.d_head * 2
        elif kind == "xattn":
            total += 2 * b * cfg.n_img_tokens * cfg.n_kv_heads * cfg.d_head * 2
        elif kind == "ssm":
            ssm = cfg.ssm
            di = ssm.expand * cfg.d_model
            total += b * (di // ssm.headdim) * ssm.headdim * ssm.d_state * 4
        elif kind == "rec":
            total += b * (cfg.rglru.lru_width or cfg.d_model) * 4
    return total


# ------------------------------- assembly -----------------------------------


def roofline_row(artifact: dict) -> dict | None:
    if artifact.get("status") != "ok":
        return None
    arch, shape_name = artifact["arch"], artifact["shape"]
    struct = artifact["struct"]
    n_dev = artifact["n_devices"]
    fl = analytic_flops(arch, shape_name)
    by = analytic_bytes(arch, shape_name, struct)
    u, m = struct["n_units"], struct["microbatches"]
    depths = artifact.get("collective_depths")
    if depths:
        # depth 0: once/step; depth 1: per microbatch (train) or per unit
        # (serve: the unit scan is the outermost loop); depth >= 2: per unit
        # per microbatch (FSDP gathers, TP activation reduces, chunk loops)
        is_train = artifact["mode"] == "train"
        d1_mult = m if is_train else u
        wire = (
            depths.get("0", 0)
            + depths.get("1", 0) * d1_mult
            + sum(v for k, v in depths.items() if int(k) >= 2) * max(1, u * m)
        )
    else:  # legacy artifacts
        coll = artifact["collectives"]
        wire = coll["entry_wire_bytes"] + coll["loop_wire_bytes"] * max(1, u * m)
    t_compute = fl["total"] / (n_dev * PEAK)
    t_memory = by["total"] / HBM  # per-device bytes already
    t_coll = wire / ICI           # per-device wire bytes over one link
    dominant = max(
        ("compute", t_compute), ("memory", t_memory), ("collective", t_coll),
        key=lambda kv: kv[1],
    )[0]
    useful = fl["model_flops"] / fl["total"] if fl["total"] else 0.0
    frac = {
        "compute": t_compute / max(t_compute, t_memory, t_coll),
        "memory": t_memory / max(t_compute, t_memory, t_coll),
        "collective": t_coll / max(t_compute, t_memory, t_coll),
    }
    hlo_flops_dev = artifact.get("cost", {}).get("flops")
    return dict(
        arch=arch, shape=shape_name, mesh=artifact["mesh"],
        t_compute_s=t_compute, t_memory_s=t_memory, t_collective_s=t_coll,
        dominant=dominant, useful_ratio=useful,
        step_s=max(t_compute, t_memory, t_coll),
        roofline_fraction=t_compute / max(t_compute, t_memory, t_coll),
        wire_bytes_dev=wire, model_flops=fl["model_flops"],
        analytic_flops=fl["total"], hlo_flops_dev_raw=hlo_flops_dev,
        memory_gb_dev=(artifact["memory"].get("temp_size_in_bytes", 0)
                       + artifact["memory"].get("argument_size_in_bytes", 0)) / 1e9,
    )


def load_rows(art_dir=ART_DIR, mesh: str | None = "single", tag: str = ""):
    rows = []
    for p in sorted(art_dir.glob("*.json")):
        d = json.loads(p.read_text())
        if d.get("tag", "") != tag:
            continue
        if mesh and d.get("mesh") != mesh:
            continue
        r = roofline_row(d)
        if r:
            rows.append(r)
    return rows


def bottleneck_note(r: dict) -> str:
    if r["dominant"] == "compute":
        return "raise useful-flops share (remat policy / fuse MMA-overhead)"
    if r["dominant"] == "memory":
        return "cut HBM traffic (microbatch depth, weight/cache dtype, fusion)"
    return "cut wire bytes (reduce-scatter grads, compress cross-pod hop)"


def render_markdown(rows) -> str:
    hdr = ("| arch | shape | compute s | memory s | collective s | dominant | "
           "roofline frac | 6ND/HLO | note |\n|---|---|---|---|---|---|---|---|---|")
    out = [hdr]
    for r in rows:
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['t_compute_s']:.3e} | "
            f"{r['t_memory_s']:.3e} | {r['t_collective_s']:.3e} | "
            f"{r['dominant']} | {r['roofline_fraction']:.2f} | "
            f"{r['useful_ratio']:.2f} | {bottleneck_note(r)} |"
        )
    return "\n".join(out)


def run():
    rows = load_rows()
    csv = []
    for r in rows:
        csv.append(
            f"roofline_{r['arch']}_{r['shape']},{r['step_s']*1e3:.3f},"
            f"dom={r['dominant']};frac={r['roofline_fraction']:.2f};"
            f"useful={r['useful_ratio']:.2f}"
        )
    if not csv:
        csv.append("roofline_pending,0,run launch/dryrun.py first")
    return csv


if __name__ == "__main__":
    print(render_markdown(load_rows()))
