"""Paper Table 1 equivalent: measured step counts vs eq. (15)-(17).

The paper is analytic; this harness validates the claims with the
*implemented* algorithm: the hierarchical driver's instrumented level count
must equal log_{m^2}(n) for exact powers (5 model-steps per level), the
classic baseline log2(n), and their ratio the closed-form speedup."""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.core import cost_model
from repro.core.mma_reduce import classic_tree_sum, mma_sum


def rows():
    out = []
    rng = np.random.RandomState(0)
    for m in (2, 4, 16, 128):
        for k in (1, 2, 3):
            n = (m * m) ** k
            if n > 1 << 22:
                continue
            x = jnp.asarray(rng.randn(n).astype(np.float32))
            tr, tc = [], []
            mma_sum(x, m=m, trace=tr)
            classic_tree_sum(x, trace=tc)
            t_tc_meas = tr[0].model_steps
            t_cl_meas = 4 * tc[0].levels
            out.append(
                dict(
                    n=n, m=m,
                    levels_measured=tr[0].levels,
                    t_tc_measured=t_tc_meas,
                    t_tc_eq16=cost_model.t_tensor_core(n, m),
                    t_classic_measured=t_cl_meas,
                    t_classic_model=cost_model.t_classic(n),
                    speedup_measured=t_cl_meas / t_tc_meas,
                    speedup_eq17=cost_model.speedup_model(m),
                    mma_ops=tr[0].mma_ops,
                )
            )
    return out


def run():
    print("# bench_steps: T_tc(n)=5log_{m^2}n vs measured levels (paper eq.15-17)")
    csv = []
    for r in rows():
        ok = abs(r["t_tc_measured"] - r["t_tc_eq16"]) < 1e-9
        csv.append(
            f"steps_m{r['m']}_n{r['n']},{r['t_tc_measured']},"
            f"eq16={r['t_tc_eq16']:.1f};speedup={r['speedup_measured']:.2f};"
            f"eq17={r['speedup_eq17']:.2f};match={ok}"
        )
    return csv
