"""Paper Table 1 equivalent: measured step counts vs eq. (15)-(17).

The paper is analytic; this harness validates the claims with the
*implemented* algorithm: the hierarchical driver's instrumented level count
must equal log_{m^2}(n) for exact powers (5 model-steps per level), the
classic baseline log2(n), and their ratio the closed-form speedup."""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.core import cost_model
from repro.core.mma_reduce import classic_tree_sum, mma_sum


def rows():
    out = []
    rng = np.random.RandomState(0)
    for m in (2, 4, 16, 128):
        for k in (1, 2, 3):
            n = (m * m) ** k
            if n > 1 << 22:
                continue
            x = jnp.asarray(rng.randn(n).astype(np.float32))
            tr, tc = [], []
            mma_sum(x, m=m, trace=tr)
            classic_tree_sum(x, trace=tc)
            t_tc_meas = tr[0].model_steps
            t_cl_meas = 4 * tc[0].levels
            out.append(
                dict(
                    n=n, m=m,
                    levels_measured=tr[0].levels,
                    t_tc_measured=t_tc_meas,
                    t_tc_eq16=cost_model.t_tensor_core(n, m),
                    t_classic_measured=t_cl_meas,
                    t_classic_model=cost_model.t_classic(n),
                    speedup_measured=t_cl_meas / t_tc_meas,
                    speedup_eq17=cost_model.speedup_model(m),
                    mma_ops=tr[0].mma_ops,
                )
            )
    return out


def optimizer_step_rows():
    """Re-baselined optimizer-step wall clock (CPU/XLA numbers -- relative,
    not TPU perf): jitted clipped-AdamW update over a synthetic grad tree,
    standard elementwise v vs the fused scalar second moment, both behind
    donated buffers. The fused variant drops the n-sized sqrt/divide pass
    and the elementwise v state; the statistic side is the same one-launch
    epilogue fork either way."""
    import time

    import jax

    from repro import optim
    from repro.configs import TrainConfig

    rng = np.random.RandomState(0)
    host = {
        f"l{i}": rng.randn(s).astype(np.float32)
        for i, s in enumerate((1 << 18, 1 << 16, 1 << 12))
    }
    grads = {k: jnp.asarray(0.01 * v) for k, v in host.items()}
    tcfg = TrainConfig(learning_rate=1e-3, warmup_steps=1, total_steps=100)
    out = []
    for fused in (False, True):
        # fresh device copies per variant: the donated buffers from the
        # previous variant's steps are dead
        params = {k: jnp.asarray(v) for k, v in host.items()}
        state = optim.init_state(params, fused_second_moment=fused)
        fn = jax.jit(
            lambda p, g, s, f=fused: optim.apply_updates(
                p, g, s, tcfg, fused_second_moment=f
            ),
            donate_argnums=(0, 2),
        )
        # warm-up must block: compile time would otherwise pollute rep 1
        p, s, _ = fn(params, grads, state)
        jax.block_until_ready(p)
        t0 = time.perf_counter()
        reps = 20
        for _ in range(reps):
            p, s, m = fn(p, grads, s)
        jax.block_until_ready(p)
        us = (time.perf_counter() - t0) / reps * 1e6
        name = "fused_nu" if fused else "standard_v"
        out.append(
            f"optstep_adamw_{name},{us:.0f},"
            f"donated=params+opt;leaves={len(params)};us_per_step"
        )
    return out


def run():
    print("# bench_steps: T_tc(n)=5log_{m^2}n vs measured levels (paper eq.15-17)")
    csv = []
    for r in rows():
        ok = abs(r["t_tc_measured"] - r["t_tc_eq16"]) < 1e-9
        csv.append(
            f"steps_m{r['m']}_n{r['n']},{r['t_tc_measured']},"
            f"eq16={r['t_tc_eq16']:.1f};speedup={r['speedup_measured']:.2f};"
            f"eq17={r['speedup_eq17']:.2f};match={ok}"
        )
    csv.extend(optimizer_step_rows())
    return csv
