"""Paper Table 1 equivalent: measured step counts vs eq. (15)-(17).

The paper is analytic; this harness validates the claims with the
*implemented* algorithm: the hierarchical driver's instrumented level count
must equal log_{m^2}(n) for exact powers (5 model-steps per level), the
classic baseline log2(n), and their ratio the closed-form speedup."""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.core import cost_model
from repro.core.mma_reduce import classic_tree_sum, mma_sum


def rows():
    out = []
    rng = np.random.RandomState(0)
    for m in (2, 4, 16, 128):
        for k in (1, 2, 3):
            n = (m * m) ** k
            if n > 1 << 22:
                continue
            x = jnp.asarray(rng.randn(n).astype(np.float32))
            tr, tc = [], []
            mma_sum(x, m=m, trace=tr)
            classic_tree_sum(x, trace=tc)
            t_tc_meas = tr[0].model_steps
            t_cl_meas = 4 * tc[0].levels
            out.append(
                dict(
                    n=n, m=m,
                    levels_measured=tr[0].levels,
                    t_tc_measured=t_tc_meas,
                    t_tc_eq16=cost_model.t_tensor_core(n, m),
                    t_classic_measured=t_cl_meas,
                    t_classic_model=cost_model.t_classic(n),
                    speedup_measured=t_cl_meas / t_tc_meas,
                    speedup_eq17=cost_model.speedup_model(m),
                    mma_ops=tr[0].mma_ops,
                )
            )
    return out


def optimizer_step_rows():
    """Re-baselined optimizer-step wall clock (CPU/XLA numbers -- relative,
    not TPU perf): jitted clipped-AdamW update over a synthetic grad tree,
    standard elementwise v vs the fused scalar second moment, both behind
    donated buffers. The fused variant drops the n-sized sqrt/divide pass
    and the elementwise v state; the statistic side is the same one-launch
    epilogue fork either way."""
    import time

    import jax

    from repro import optim
    from repro.configs import TrainConfig

    rng = np.random.RandomState(0)
    host = {
        f"l{i}": rng.randn(s).astype(np.float32)
        for i, s in enumerate((1 << 18, 1 << 16, 1 << 12))
    }
    grads = {k: jnp.asarray(0.01 * v) for k, v in host.items()}
    tcfg = TrainConfig(learning_rate=1e-3, warmup_steps=1, total_steps=100)
    out = []
    for fused in (False, True):
        # fresh device copies per variant: the donated buffers from the
        # previous variant's steps are dead
        params = {k: jnp.asarray(v) for k, v in host.items()}
        state = optim.init_state(params, fused_second_moment=fused)
        fn = jax.jit(
            lambda p, g, s, f=fused: optim.apply_updates(
                p, g, s, tcfg, fused_second_moment=f
            ),
            donate_argnums=(0, 2),
        )
        # warm-up must block: compile time would otherwise pollute rep 1
        p, s, _ = fn(params, grads, state)
        jax.block_until_ready(p)
        t0 = time.perf_counter()
        reps = 20
        for _ in range(reps):
            p, s, m = fn(p, grads, s)
        jax.block_until_ready(p)
        us = (time.perf_counter() - t0) / reps * 1e6
        name = "fused_nu" if fused else "standard_v"
        out.append(
            f"optstep_adamw_{name},{us:.0f},"
            f"donated=params+opt;leaves={len(params)};us_per_step"
        )
    return out


def serving_rows():
    """Guarded-serving SLO under load: a zipf-skewed request mix (rank r
    asks for 32//r tokens -- a few long generations, a tail of short ones)
    through ``runtime.ServingRuntime`` on an injected clock, with a
    deterministic seeded chaos schedule. Everything is fake-time, so the
    shed rate, deadline-miss count and p99 step latency are exact numbers,
    not measurements -- the row is a REGRESSION GATE on the admission +
    quarantine policy, not a perf claim."""
    import math

    from repro.runtime import ChaosMonkey, Request, ServingRuntime

    class _Clock:
        t = 0.0

        def __call__(self):
            return self.t

    class _Engine:
        """Protocol fake: deterministic tokens, per-step clock advance,
        census flags any slot whose chaos scale went non-finite."""

        slots = 4

        def __init__(self, clock, step_cost):
            self.clock, self.step_cost = clock, step_cost

        def validate(self, prompt, max_new):
            return None

        def _step(self, base, t, scales):
            self.clock.t += self.step_cost
            census = [
                0.0 if b is None or math.isfinite((b + t) * s) else 1.0
                for b, s in zip(base, scales)
            ]
            toks = [0 if b is None else (b + t) % 997 for b in base]
            return toks, census + [sum(census)]

        def start_wave(self, prompts, scales, backend):
            base = [None if p is None else int(np.sum(p)) for p in prompts]
            toks, census = self._step(base, 0, scales)
            return {"base": base, "t": 0}, toks, census

        def decode(self, state, scales, backend):
            t = state["t"] + 1
            toks, census = self._step(state["base"], t, scales)
            return {"base": state["base"], "t": t}, toks, census

    out = []
    rng = np.random.RandomState(7)
    n_req, step_cost = 64, 0.010
    lengths = [max(1, 32 // (1 + i % 8)) for i in range(n_req)]
    rng.shuffle(lengths)
    for name, deadline, chaos_rate in (
        ("lax", 4.0, 0.0),       # generous deadline, clean traffic
        ("tight", 0.35, 0.0),    # deadline < worst-case queue wait
        ("chaotic", 4.0, 0.25),  # generous deadline, heavy injection
    ):
        clock = _Clock()
        chaos = (
            ChaosMonkey.from_seed(7, n_steps=n_req, nan_rate=chaos_rate)
            if chaos_rate else None
        )
        rt = ServingRuntime(_Engine(clock, step_cost), chaos=chaos,
                            clock=clock, queue_capacity=n_req,
                            quarantine_planner=False)
        results = rt.serve([
            Request(rid=i, prompt=np.full((4,), i), max_new=lengths[i],
                    deadline_s=deadline)
            for i in range(n_req)
        ])
        snap = rt.metrics.snapshot()
        ok = sum(r.ok for r in results)
        out.append(
            f"serve_guard_{name},{ok},"
            f"of={n_req};shed={snap['shed_queue_full']}"
            f"+{snap['shed_infeasible']};missed={snap['deadline_missed']};"
            f"quarantined={snap['quarantined']};retries={snap['retries']};"
            f"p99_step_ms={snap['token_latency_p99_s'] * 1e3:.1f}"
        )
    return out


def run():
    print("# bench_steps: T_tc(n)=5log_{m^2}n vs measured levels (paper eq.15-17)")
    csv = []
    for r in rows():
        ok = abs(r["t_tc_measured"] - r["t_tc_eq16"]) < 1e-9
        csv.append(
            f"steps_m{r['m']}_n{r['n']},{r['t_tc_measured']},"
            f"eq16={r['t_tc_eq16']:.1f};speedup={r['speedup_measured']:.2f};"
            f"eq17={r['speedup_eq17']:.2f};match={ok}"
        )
    csv.extend(optimizer_step_rows())
    csv.extend(serving_rows())
    return csv
