"""Benchmark orchestrator: one section per paper table/claim + the roofline.

Prints ``name,value,derived`` CSV rows (value units depend on the bench:
model steps, relative error, microseconds, or milliseconds-per-step for the
roofline) and mirrors every section into a machine-readable
``BENCH_reduce.json`` (``--json``; per-section name/value/derived rows) so
CI and dashboards can consume the numbers without CSV scraping. ``--only``
filters sections by title substring -- the CI smoke step runs
``--only kernel`` so bench rot fails the build.
"""

from __future__ import annotations

import argparse
import json
import sys
import traceback


def _parse_row(row: str) -> dict:
    name, _, rest = row.partition(",")
    value_s, _, derived = rest.partition(",")
    try:
        value = float(value_s)
    except ValueError:
        value = value_s
    return {"name": name, "value": value, "derived": derived}


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument(
        "--json",
        default="BENCH_reduce.json",
        help="path for the machine-readable mirror of the CSV rows",
    )
    ap.add_argument(
        "--only",
        default=None,
        help="run only sections whose title contains this substring",
    )
    args = ap.parse_args(argv)

    from benchmarks import (
        bench_kernels,
        bench_precision,
        bench_speedup_model,
        bench_steps,
        roofline,
    )

    sections = [
        ("paper eq.15-17: step counts & speedups", bench_steps.run),
        ("paper section V: speedup table + TPU extension", bench_speedup_model.run),
        ("paper future-work: precision loss", bench_precision.run),
        ("kernel microbench (interpret mode)", bench_kernels.run),
        ("roofline from dry-run artifacts", roofline.run),
    ]
    if args.only:
        sections = [(t, fn) for t, fn in sections if args.only in t]

    failures = 0
    report = []
    print("name,value,derived")
    for title, fn in sections:
        print(f"# --- {title} ---")
        rows = []
        try:
            for row in fn():
                print(row)
                rows.append(_parse_row(row))
        except Exception as e:  # pragma: no cover
            failures += 1
            err = f"bench_error_{fn.__module__},nan,{type(e).__name__}:{e}"
            print(err)
            rows.append(_parse_row(err))
            traceback.print_exc(file=sys.stderr)
        report.append({"title": title, "rows": rows})
    with open(args.json, "w") as f:
        json.dump({"sections": report}, f, indent=2)
        f.write("\n")
    if failures:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
