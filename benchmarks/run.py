"""Benchmark orchestrator: one section per paper table/claim + the roofline.

Prints ``name,value,derived`` CSV rows (value units depend on the bench:
model steps, relative error, microseconds, or milliseconds-per-step for the
roofline)."""

from __future__ import annotations

import sys
import traceback


def main() -> None:
    from benchmarks import (
        bench_kernels,
        bench_precision,
        bench_speedup_model,
        bench_steps,
        roofline,
    )

    sections = [
        ("paper eq.15-17: step counts & speedups", bench_steps.run),
        ("paper section V: speedup table + TPU extension", bench_speedup_model.run),
        ("paper future-work: precision loss", bench_precision.run),
        ("kernel microbench (interpret mode)", bench_kernels.run),
        ("roofline from dry-run artifacts", roofline.run),
    ]
    failures = 0
    print("name,value,derived")
    for title, fn in sections:
        print(f"# --- {title} ---")
        try:
            for row in fn():
                print(row)
        except Exception as e:  # pragma: no cover
            failures += 1
            print(f"bench_error_{fn.__module__},nan,{type(e).__name__}:{e}")
            traceback.print_exc(file=sys.stderr)
    if failures:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
