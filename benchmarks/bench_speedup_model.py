"""Paper section V table: model speedups S=(4/5)log2(m^2) for the hardware
tile sizes discussed (m=4 HW, m=16 WMMA) + the TPU MXU extrapolation
(m=128), plus the bandwidth-extended TPU roofline terms this work adds."""

from __future__ import annotations

from repro.core import cost_model as cm


def run():
    csv = []
    for m, label in ((2, "minimum"), (4, "V100_hw"), (16, "wmma_api"),
                     (128, "tpu_mxu")):
        csv.append(f"speedup_model_{label}_m{m},{cm.speedup_model(m):.3f},S>1={cm.speedup_model(m) > 1}")
    # TPU extension: where the MMA reduction actually lands on v5e
    for n in (1 << 16, 1 << 20, 1 << 24, 1 << 28):
        rl = cm.tpu_reduction_roofline(n)
        csv.append(
            f"tpu_roofline_n{n},{rl.mxu_s * 1e6:.2f},"
            f"hbm_us={rl.hbm_s*1e6:.2f};vpu_us={rl.vpu_s*1e6:.2f};"
            f"bw_neutral={rl.mxu_bandwidth_neutral}"
        )
    return csv
