"""CI gate over ``BENCH_reduce.json``: structure, launch counts, MMA totals.

``benchmarks/run.py --json`` mirrors every bench row into a machine-readable
report; this checker turns the two perf invariants the engine advertises into
build failures instead of silent drift:

  1. LAUNCH COUNT -- one ``reduce_many`` batch (and the whole-pytree
     ``reduce_tree`` statistic) lowers to EXACTLY one ``pallas_call`` on the
     Pallas backends, including with ``num_cores > 1`` (the striped grid must
     never fall back to one launch per lane or per segment).
  2. MMA TOTALS -- the trace-counted MMA rows the kernel bench emits
     (``mma_fused_262k_c{c}``) match ``cost_model.fused_mma_ops``:
     n/(m^2 c) + c per lane. A mismatch means the kernel geometry and the
     cost model (which the planner trusts) have diverged.

Run as ``python -m benchmarks.check_bench BENCH_reduce.json``.
"""

from __future__ import annotations

import json
import sys

import jax
import jax.numpy as jnp


def check_report(path: str) -> None:
    """Structural checks over the JSON mirror (no recompute)."""
    with open(path) as f:
        d = json.load(f)
    assert d["sections"], "no bench sections ran"
    rows = [r for s in d["sections"] for r in s["rows"]]
    assert rows, "bench produced no rows"
    bad = [r for r in rows if str(r["name"]).startswith("bench_error")]
    assert not bad, f"bench sections errored: {bad}"
    assert any("reduce_many" in str(r["name"]) for r in rows), rows
    # trace-counted MMA totals must match the cost model the planner trusts
    from repro.core import cost_model

    mma_rows = {
        r["name"]: r for r in rows if str(r["name"]).startswith("mma_fused_")
    }
    assert mma_rows, "kernel bench no longer emits mma_fused_* trace rows"
    for name, row in mma_rows.items():
        c = int(name.rsplit("_c", 1)[1])
        # problem size and block depth of the plan the bench actually ran
        # travel in the derived column -- never assumed here
        kv = dict(p.split("=", 1) for p in str(row["derived"]).split(";"))
        want = cost_model.fused_mma_ops(
            int(kv["n"]), num_cores=c, tiles_per_block=int(kv["tpb"])
        ).total
        got = int(row["value"])
        assert got == want, (
            f"{name}: traced {got} MMAs but cost model says {want} -- kernel "
            "geometry and cost_model.fused_mma_ops have diverged"
        )


def check_launch_counts() -> None:
    """The 1-launch property, asserted on the lowered jaxprs (cheap: no
    execution, trace only -- safe on the CI CPU)."""
    from repro import reduce as R
    from repro.optim import adamw

    arrs = [jnp.ones((300,)), jnp.ones((4, 65)), jnp.ones(())]
    tree = {"w": jnp.ones((4, 256)), "b": [jnp.ones((300,)), jnp.ones(())]}
    for backend in ("pallas_fused", "pallas_hier"):
        for c in (1, 2):
            jx = jax.make_jaxpr(
                lambda a, b=backend, c=c: R.reduce_many(a, backend=b, num_cores=c)
            )(arrs)
            n = str(jx).count("pallas_call")
            assert n == 1, f"reduce_many[{backend}, c={c}]: {n} pallas_calls"
            jx = jax.make_jaxpr(
                lambda g, b=backend, c=c: R.reduce_tree(
                    g, "norm2", backend=b, num_cores=c
                )
            )(tree)
            n = str(jx).count("pallas_call")
            assert n == 1, f"reduce_tree[{backend}, c={c}]: {n} pallas_calls"
    # and the optimizer-facing entry point rides the same single launch
    jx = jax.make_jaxpr(
        lambda g: adamw.global_norm(g, backend="pallas_fused")
    )(tree)
    assert str(jx).count("pallas_call") == 1, "global_norm launch count drifted"


def main(argv=None) -> None:
    args = list(sys.argv[1:] if argv is None else argv)
    path = args[0] if args else "BENCH_reduce.json"
    check_report(path)
    check_launch_counts()
    print(f"check_bench: {path} OK (structure, MMA totals, launch counts)")


if __name__ == "__main__":
    main()
