"""CI gate over ``BENCH_reduce.json``: structure, launch counts, MMA totals,
HBM traffic, and the zero-copy staging-free property.

``benchmarks/run.py --json`` mirrors every bench row into a machine-readable
report; this checker turns the perf invariants the engine advertises into
build failures instead of silent drift:

  1. LAUNCH COUNT -- one ``reduce_many`` batch (and the whole-pytree
     ``reduce_tree`` statistic) lowers to EXACTLY one ``pallas_call`` on the
     Pallas backends, including with ``num_cores > 1`` (the striped grid must
     never fall back to one launch per lane, per segment, or per part).
  2. MMA TOTALS -- the trace-counted MMA rows the kernel bench emits
     (``mma_fused_262k_c{c}``) match ``cost_model.fused_mma_ops``:
     n/(m^2 c) + c per lane. A mismatch means the kernel geometry and the
     cost model (which the planner trusts) have diverged.
  3. STAGING-FREE INGESTION -- lowering ``reduce`` / ``reduce_many`` on bf16
     inputs for both Pallas backends produces NO n-sized
     ``convert_element_type``, ``pad``, or ``concatenate`` outside the
     pallas_call (``repro.reduce.inspect.assert_staging_free``): the kernels
     read the caller's buffer directly, in its native dtype. The NORM path
     (sumsq / norm2 / moments, incl. ``reduce_tree``'s clipping statistic)
     additionally forbids n-sized ``mul``/``integer_pow``/``sign`` outside
     the kernel: the elementwise prologue runs IN-kernel, so the whole
     norm is single-stream (one read of the raw leaf, one launch).
  4. HBM BYTES -- the ``hbm_*`` rows the kernel bench emits match
     ``cost_model.hbm_bytes`` for the plan they ran, the zero-copy bf16
     model stays at n*2 + O(c m^2), and the launch-boundary bytes of the
     lowered program (``inspect.pallas_io_bytes``) equal the model's
     ``launch_io`` -- traffic asserted against the traced geometry, not
     just claimed.
  5. ONE-TRIP OPTIMIZER STEP -- the clipped-AdamW statistic
     (``optim.global_norm_and_clip``) is epilogue-free on the Pallas
     backends (NO sqrt/rsqrt/div/min/max eqns of any size outside the
     pallas_call: the norm's sqrt and the clip coefficient finish
     in-launch), a jitted ``apply_updates`` lowers to exactly one
     reduction launch, the launch moves <= 1.25x the raw grad bytes
     (== the parts model with the fork's output slots), and the
     fused-second-moment update keeps its elementwise pass free of
     n-sized sqrt/div/min (the ``hbm_step_grads_*`` rows witness the
     byte claim in the artifact).
  6. GUARDED STEP CENSUS -- the clip statistic with the in-launch
     non-finite census is still one pallas_call, adds f32 OUTPUT slots
     only (kernel reads byte-identical to the unguarded model), and the
     whole jitted guarded update (bitwise skip + spike detector) lowers
     with no ``is_finite``/``select_n`` outside the kernel.
  7. SERVE GUARD -- the census-guarded DECODE statistic
     (``runtime.serving.guarded_logit_stat`` and the single-array
     ``reduce(..., census=True)``) is one pallas_call on both Pallas
     backends, census-free in lowering, and reads exactly the bytes the
     unguarded statistic reads (``--serve`` runs it standalone).
  8. MMA SCAN -- the triangular-MMA prefix sum is one pallas_call at
     every lane count, staging-free on bf16 ingest (incl. reverse /
     exclusive), its trace-counted MMA rows (``mma_scan_262k_c{c}``)
     match ``cost_model.scan_mma_ops`` including the lane/carry split,
     and the lowered launch-boundary bytes equal
     ``cost_model.scan_hbm_bytes``'s ``launch_io`` -- with the bf16
     single-stream row beating the staged two-pass model by >4x.

Run as ``python -m benchmarks.check_bench BENCH_reduce.json``.
"""

from __future__ import annotations

import json
import sys

import jax.numpy as jnp


def check_report(path: str) -> None:
    """Checks over the JSON mirror (structure + model recomputation)."""
    with open(path) as f:
        d = json.load(f)
    assert d["sections"], "no bench sections ran"
    rows = [r for s in d["sections"] for r in s["rows"]]
    assert rows, "bench produced no rows"
    bad = [r for r in rows if str(r["name"]).startswith("bench_error")]
    assert not bad, f"bench sections errored: {bad}"
    assert any("reduce_many" in str(r["name"]) for r in rows), rows
    # trace-counted MMA totals must match the cost model the planner trusts
    from repro.core import cost_model

    mma_rows = {
        r["name"]: r for r in rows if str(r["name"]).startswith("mma_fused_")
    }
    assert mma_rows, "kernel bench no longer emits mma_fused_* trace rows"
    for name, row in mma_rows.items():
        c = int(name.rsplit("_c", 1)[1])
        # problem size and block depth of the plan the bench actually ran
        # travel in the derived column -- never assumed here
        kv = dict(p.split("=", 1) for p in str(row["derived"]).split(";"))
        want = cost_model.fused_mma_ops(
            int(kv["n"]), num_cores=c, tiles_per_block=int(kv["tpb"])
        ).total
        got = int(row["value"])
        assert got == want, (
            f"{name}: traced {got} MMAs but cost model says {want} -- kernel "
            "geometry and cost_model.fused_mma_ops have diverged"
        )
    # the scan kernel's trace rows against the triangular-scan cost model:
    # total AND the lane/carry split (the carry-rebuild overhead is the
    # Dakkak trade the planner reasons about, so its drift is a failure too)
    scan_rows = {
        r["name"]: r for r in rows if str(r["name"]).startswith("mma_scan_")
    }
    assert scan_rows, "kernel bench no longer emits mma_scan_* trace rows"
    for name, row in scan_rows.items():
        c = int(name.rsplit("_c", 1)[1])
        kv = dict(p.split("=", 1) for p in str(row["derived"]).split(";"))
        want = cost_model.scan_mma_ops(
            int(kv["n"]), num_cores=c, tiles_per_block=int(kv["tpb"])
        )
        got = int(row["value"])
        assert got == want.total, (
            f"{name}: traced {got} MMAs but cost model says {want.total} -- "
            "scan kernel geometry and cost_model.scan_mma_ops have diverged"
        )
        assert int(kv["lane"]) == want.lane_scan, (name, kv, want)
        assert int(kv["carry"]) == want.carry_worst, (name, kv, want)
    check_hbm_rows(rows)


def check_hbm_rows(rows) -> None:
    """The hbm_* traffic rows: recompute the model from each row's derived
    params and require the zero-copy bf16 win over the staged-f32 path."""
    from repro.core import cost_model

    hbm = {r["name"]: r for r in rows if str(r["name"]).startswith("hbm_")}
    assert hbm, "kernel bench no longer emits hbm_* traffic rows"
    modeled = {}
    for name, row in hbm.items():
        kv = dict(p.split("=", 1) for p in str(row["derived"]).split(";"))
        want = cost_model.hbm_bytes(
            kv["path"],
            int(kv["n"]),
            int(kv["itemsize"]),
            num_cores=int(kv.get("c", 1)),
            tiles_per_block=int(kv.get("tpb", 8)),
            segments=int(kv.get("segments", 1)),
        )
        got = int(row["value"])
        assert got == want.total, (
            f"{name}: bench emitted {got} modeled HBM bytes but "
            f"cost_model.hbm_bytes says {want.total}"
        )
        if "measured" in kv:  # launch-boundary bytes of the lowered program
            assert int(kv["measured"]) == want.launch_io, (
                f"{name}: lowered pallas_call moves {kv['measured']} bytes "
                f"but the model's launch_io is {want.launch_io} -- kernel "
                "operands and the traffic model have diverged"
            )
        # keyed by ROW NAME: the sumsq row intentionally reuses path=fused
        # (the single-stream identity), so a (path, itemsize) key would let
        # one row silently shadow the other
        modeled[str(name)] = want.total

    def _row(prefix):
        matches = [v for k, v in modeled.items() if k.startswith(prefix)]
        assert matches, f"kernel bench no longer emits the {prefix}* row"
        return matches[0]

    # the whole point, as an inequality the artifact must witness:
    # zero-copy bf16 ingestion moves < half the staged-f32 bytes
    n2 = _row("hbm_fused_262k_bf16")
    staged = _row("hbm_fused_staged_262k_bf16")
    assert n2 * 2 < staged, (n2, staged)
    # single-stream norms: the in-kernel square prologue makes bf16 sumsq
    # byte-identical to the plain sum and >4x cheaper than the PR-4
    # two-pass route (host square + staged f32 stream)
    sumsq = _row("hbm_sumsq_262k_bf16")
    staged_sq = _row("hbm_sumsq_staged_262k_bf16")
    assert sumsq * 4 < staged_sq, (sumsq, staged_sq)
    # the scan analogue: a bf16 prefix sum streams AND writes at native
    # width in one launch, >4x cheaper than the XLA two-pass f32 route
    # (upcast copy + f32 scan + downcast) it replaced
    scan_zc = _row("hbm_scan_262k_bf16")
    scan_staged = _row("hbm_scan_staged_262k_bf16")
    assert scan_zc * 4 < scan_staged, (scan_zc, scan_staged)
    _row("hbm_tree_norm2")  # the optimizer-statistic row must exist
    # the one-HBM-trip step: for both dtypes, the whole statistic side of an
    # optimizer step (per-leaf sumsq + gnorm + clip, one launch) stays
    # within 25% of the raw grad bytes -- i.e. one trip, not two -- and
    # beats the modeled two-trip route it replaced
    for dt_name in ("bf16", "f32"):
        row = hbm[f"hbm_step_grads_{dt_name}"]
        kv = dict(p.split("=", 1) for p in str(row["derived"]).split(";"))
        grad_bytes = int(kv["n"]) * int(kv["itemsize"])
        got = int(row["value"])
        assert got <= 1.25 * grad_bytes, (
            f"hbm_step_grads_{dt_name}: modeled step statistic moves {got} "
            f"bytes for {grad_bytes} grad bytes -- the one-trip property "
            "drifted"
        )
        assert got < _row(f"hbm_step_grads_2trip_{dt_name}")


def check_launch_counts() -> None:
    """The 1-launch property, asserted on the lowered jaxprs (cheap: no
    execution, trace only -- safe on the CI CPU)."""
    from repro import reduce as R
    from repro.optim import adamw
    from repro.reduce import inspect as rinspect

    arrs = [jnp.ones((300,)), jnp.ones((4, 65)), jnp.ones(())]
    tree = {"w": jnp.ones((4, 256)), "b": [jnp.ones((300,)), jnp.ones(())]}
    for backend in ("pallas_fused", "pallas_hier"):
        for c in (1, 2):
            n = rinspect.count_pallas_calls(
                lambda a, b=backend, c=c: R.reduce_many(a, backend=b, num_cores=c),
                arrs,
            )
            assert n == 1, f"reduce_many[{backend}, c={c}]: {n} pallas_calls"
            n = rinspect.count_pallas_calls(
                lambda g, b=backend, c=c: R.reduce_tree(
                    g, "norm2", backend=b, num_cores=c
                ),
                tree,
            )
            assert n == 1, f"reduce_tree[{backend}, c={c}]: {n} pallas_calls"
    # and the optimizer-facing entry point rides the same single launch
    n = rinspect.count_pallas_calls(
        lambda g: adamw.global_norm(g, backend="pallas_fused"), tree
    )
    assert n == 1, "global_norm launch count drifted"
    # the prologue kinds stay single-launch on the fused backend: the
    # square / dual-accumulator maps run INSIDE the one kernel
    x = jnp.ones((300_000,), jnp.bfloat16)
    for kind in ("sumsq", "norm2", "moments"):
        n = rinspect.count_pallas_calls(
            lambda v, k=kind: R.reduce(v, kind=k, backend="pallas_fused"), x
        )
        assert n == 1, f"reduce[{kind}, pallas_fused]: {n} pallas_calls"
    for backend in ("pallas_fused", "pallas_hier"):
        n = rinspect.count_pallas_calls(
            lambda g, b=backend: R.reduce_tree(g, "norm2", backend=b), tree
        )
        assert n == 1, f"reduce_tree norm2[{backend}]: {n} pallas_calls"


def check_staging_free() -> None:
    """Zero-copy proven on the lowered jaxpr: reducing a bf16 stream on the
    Pallas backends must not cast, pad, or concatenate anything stream-sized
    outside the pallas_call (trace only -- safe on the CI CPU). The norm
    path additionally forbids n-sized mul/pow/sign OUTSIDE the kernel --
    the host-side square pass the in-kernel prologues removed."""
    from repro import reduce as R
    from repro.reduce import inspect as rinspect

    x = jnp.zeros((300_000,), jnp.bfloat16)  # ragged: tail-masked in-kernel
    arrs = [jnp.zeros((s,), jnp.bfloat16) for s in (70_000, 33, 20_000)]
    tree = {
        "w": jnp.zeros((40, 256), jnp.bfloat16),
        "b": [jnp.zeros((3000,), jnp.bfloat16), jnp.zeros((), jnp.bfloat16)],
    }
    for backend in ("pallas_fused", "pallas_hier"):
        rinspect.assert_staging_free(
            lambda v, b=backend: R.reduce(v, backend=b), x
        )
        rinspect.assert_staging_free(
            lambda a, b=backend: R.reduce_many(a, backend=b), arrs
        )
        # single-stream norms: sumsq / norm2 / moments square in-kernel
        for kind in ("sumsq", "norm2", "moments"):
            rinspect.assert_staging_free(
                lambda v, b=backend, k=kind: R.reduce(v, kind=k, backend=b),
                x,
                extra_primitives=rinspect.PROLOGUE_PRIMITIVES,
            )
            rinspect.assert_staging_free(
                lambda a, b=backend, k=kind: R.reduce_many(
                    a, kind=k, backend=b
                ),
                arrs,
                extra_primitives=rinspect.PROLOGUE_PRIMITIVES,
            )
        rinspect.assert_staging_free(
            lambda g, b=backend: R.reduce_tree(g, "norm2", backend=b),
            tree,
            extra_primitives=rinspect.PROLOGUE_PRIMITIVES,
        )
    # (gradients are exempt by design: the VJP's cotangent broadcast-and-
    # cast IS the n-sized output being produced, not ingestion staging.)


def check_optimizer_step() -> None:
    """The one-HBM-trip optimizer step, gated on lowered jaxprs (trace only
    -- safe on the CI CPU):

      a. the clip statistic is EPILOGUE-FREE on the Pallas backends: no
         sqrt/rsqrt/div/min/max eqns of ANY size outside the pallas_call --
         the norm's sqrt and the clip coefficient's min/max/div finish
         inside the launch (``inspect.assert_epilogue_free``; scalar eqns
         are invisible to the n-sized staging walker, hence the dedicated
         any-size check);
      b. a jitted AdamW update lowers to EXACTLY one reduction launch
         (standard and fused second moment alike);
      c. the launch moves at most 1.25x the raw grad bytes (measured
         pallas_call boundary bytes == the parts model with the fork's +2
         output slots);
      d. the fused-second-moment update has NO n-sized sqrt/div/min outside
         the kernel: the scalar nu EMA carries the sqrt/divide, so the
         elementwise pass is mul/add only (the standard variant keeps its
         elementwise v sqrt -- only the fused path advertises this).
    """
    import jax

    from repro import optim
    from repro.configs import TrainConfig
    from repro.optim import adamw
    from repro.reduce import inspect as rinspect

    tree = {
        "w": jnp.ones((40, 256), jnp.bfloat16),
        "b": [jnp.ones((3000,), jnp.bfloat16), jnp.ones((), jnp.bfloat16)],
    }
    for backend in ("pallas_fused", "pallas_hier"):
        stat = lambda g, b=backend: adamw.global_norm_and_clip(
            g, 1.0, backend=b, return_per_leaf=True
        )
        rinspect.assert_epilogue_free(stat, tree)  # (a)
        n = rinspect.count_pallas_calls(stat, tree)
        assert n == 1, f"global_norm_and_clip[{backend}]: {n} pallas_calls"
        grad_bytes = sum(v.nbytes for v in jax.tree.leaves(tree))
        measured = rinspect.pallas_io_bytes(jax.make_jaxpr(stat)(tree))
        assert measured <= 1.25 * grad_bytes, (backend, measured, grad_bytes)  # (c)
        from repro.core import cost_model

        nleaves = len(jax.tree.leaves(tree))
        want = cost_model.hbm_bytes(
            "parts", grad_bytes // 2, 2, segments=nleaves + 2
        )
        assert measured == want.launch_io, (backend, measured, want)

    # (b) + (d): the full update step. f32 params/grads keep the jaxpr free
    # of the legitimate mixed-precision casts so the walker sees only the
    # update math itself.
    tcfg = TrainConfig()
    params = {"w": jnp.ones((40, 256)), "b": jnp.ones((3000,))}
    grads = jax.tree.map(jnp.ones_like, params)
    for fused in (False, True):
        state = optim.init_state(params, fused_second_moment=fused)
        step = lambda p, g, s, f=fused: optim.apply_updates(
            p, g, s, tcfg, reduce_backend="pallas_fused",
            fused_second_moment=f,
        )
        n = rinspect.count_pallas_calls(step, params, grads, state)
        assert n == 1, f"apply_updates[fused={fused}]: {n} pallas_calls"
        if fused:
            # EPILOGUE_PRIMITIVES only: the update legitimately multiplies
            # n-sized (m EMA, the scalar-coefficient apply), so the
            # PROLOGUE mul gate does not apply here -- the claim is no
            # n-sized sqrt/div/min pass, the elementwise math the scalar
            # nu reciprocal replaced
            rinspect.assert_staging_free(
                step, params, grads, state,
                extra_primitives=rinspect.EPILOGUE_PRIMITIVES,
            )


def check_guarded_step() -> None:
    """The guarded step costs NOTHING extra on the input side, gated on
    lowered jaxprs (trace only -- safe on the CI CPU):

      a. the clip statistic WITH the non-finite census is still exactly one
         pallas_call on both Pallas backends -- the 0/1 isfinite mask rides
         the same MMA tiles, it is not a second reduction;
      b. measured launch-boundary bytes == the parts model widened by the
         census slots (``census=nleaves+1``: per-leaf counts + total), and
         the KERNEL-READ side is byte-identical to the unguarded model --
         census adds f32 OUTPUT slots only, zero extra HBM input bytes;
      c. the whole jitted guarded update (census + bitwise skip + spike
         detector) lowers with NO ``is_finite``/``select_n`` of any size
         outside the pallas_call (``inspect.assert_census_free``, strict
         ``min_elems=1``) and still exactly one reduction launch: the
         skip is a bitwise blend, not a branch.
    """
    import jax

    from repro import optim
    from repro.configs import TrainConfig
    from repro.core import cost_model
    from repro.optim import adamw
    from repro.reduce import inspect as rinspect

    tree = {
        "w": jnp.ones((40, 256), jnp.bfloat16),
        "b": [jnp.ones((3000,), jnp.bfloat16), jnp.ones((), jnp.bfloat16)],
    }
    grad_bytes = sum(v.nbytes for v in jax.tree.leaves(tree))
    nleaves = len(jax.tree.leaves(tree))
    plain = cost_model.hbm_bytes("parts", grad_bytes // 2, 2,
                                 segments=nleaves + 2)
    want = cost_model.hbm_bytes("parts", grad_bytes // 2, 2,
                                segments=nleaves + 2, census=nleaves + 1)
    assert want.kernel_read == plain.kernel_read, (want, plain)  # (b) input
    for backend in ("pallas_fused", "pallas_hier"):
        stat = lambda g, b=backend: adamw.global_norm_and_clip(
            g, 1.0, backend=b, return_per_leaf=True, census=True
        )
        n = rinspect.count_pallas_calls(stat, tree)
        assert n == 1, f"census stat[{backend}]: {n} pallas_calls"  # (a)
        measured = rinspect.pallas_io_bytes(jax.make_jaxpr(stat)(tree))
        assert measured == want.launch_io, (backend, measured, want)  # (b)

    # (c): the full guarded update -- f32 params/grads as in
    # check_optimizer_step so the walker sees only the update math
    tcfg = TrainConfig()
    params = {"w": jnp.ones((40, 256)), "b": jnp.ones((3000,))}
    grads = jax.tree.map(jnp.ones_like, params)
    state = optim.init_state(params)
    guard = optim.init_guard_state(8)
    loss = jnp.zeros((), jnp.float32)

    def gstep(p, g, s, gu, lo):
        return optim.guarded_apply_updates(
            p, g, s, tcfg, loss=lo, guard=gu, reduce_backend="pallas_fused"
        )

    rinspect.assert_census_free(gstep, params, grads, state, guard, loss)
    n = rinspect.count_pallas_calls(gstep, params, grads, state, guard, loss)
    assert n == 1, f"guarded_apply_updates: {n} pallas_calls"


def check_serve_guard() -> None:
    """The census-guarded DECODE statistic costs nothing extra on the input
    side, gated on lowered jaxprs (trace only -- safe on the CI CPU):

      a. ``runtime.serving.guarded_logit_stat`` (per-slot sumsq + per-slot
         non-finite census over one decode step's logits) is EXACTLY one
         pallas_call on both Pallas backends -- the per-slot statistic,
         the cross-slot total, and the census all ride one launch;
      b. the lowering is census-free: NO ``is_finite``/``select_n`` of any
         size outside the pallas_call (the guard is the in-kernel second
         accumulator, not a host-side mask pass);
      c. measured launch-boundary bytes == the parts model widened by the
         census slots, and the KERNEL-READ side is byte-identical to the
         UNGUARDED statistic's lowering -- the guard adds (slots+1) f32
         OUTPUT slots only, zero extra kernel input bytes;
      d. the single-array form ``reduce(x, census=True)`` holds the same
         three properties (one launch, census-free, read-identical).
    """
    import jax

    from repro import reduce as R
    from repro.core import cost_model
    from repro.reduce import inspect as rinspect
    from repro.runtime.serving import guarded_logit_stat

    slots, vocab = 4, 4096
    logits = jnp.ones((slots, 1, vocab), jnp.float32)
    n = logits.size
    plain = cost_model.hbm_bytes("parts", n, 4, segments=slots + 1)
    want = cost_model.hbm_bytes("parts", n, 4, segments=slots + 1,
                                census=slots + 1)
    assert want.kernel_read == plain.kernel_read, (want, plain)
    for backend in ("pallas_fused", "pallas_hier"):
        guarded = lambda lg, b=backend: guarded_logit_stat(lg, backend=b)
        unguarded = lambda lg, b=backend: R.reduce_tree(
            [lg[i] for i in range(lg.shape[0])], "sumsq", backend=b,
            return_per_leaf=True,
        )
        nc = rinspect.count_pallas_calls(guarded, logits)
        assert nc == 1, f"guarded decode stat[{backend}]: {nc} pallas_calls"
        rinspect.assert_census_free(guarded, logits)  # (b)
        measured = rinspect.pallas_io_bytes(jax.make_jaxpr(guarded)(logits))
        assert measured == want.launch_io, (backend, measured, want)  # (c)
        base = rinspect.pallas_io_bytes(jax.make_jaxpr(unguarded)(logits))
        # the guard's whole cost: (slots + 1) f32 census OUTPUT slots
        assert measured - base == (slots + 1) * 4, (backend, measured, base)
        assert want.kernel_read == plain.kernel_read  # reads identical

        # (d) the single-array serving guard: reduce(x, census=True). Its
        # baseline is the same parts-kernel lowering WITHOUT the census
        # (reduce_tree's one-leaf fork) -- the plain reduce() rides the
        # single-operand kernel whose block padding differs by design.
        x = jnp.ones((n,), jnp.bfloat16)
        one = lambda v, b=backend: R.reduce(v, kind="sumsq", census=True,
                                            backend=b)
        nc = rinspect.count_pallas_calls(one, x)
        assert nc == 1, f"reduce census[{backend}]: {nc} pallas_calls"
        rinspect.assert_census_free(one, x)
        m1 = rinspect.pallas_io_bytes(jax.make_jaxpr(one)(x))
        m0 = rinspect.pallas_io_bytes(
            jax.make_jaxpr(
                lambda v, b=backend: R.reduce_tree(
                    [v], "sumsq", backend=b, return_per_leaf=True
                )
            )(x)
        )
        # the guard's whole cost: 2 census slots (part count + total)
        assert m1 - m0 == 2 * 4, (backend, m1, m0)
        want1 = cost_model.hbm_bytes("parts", n, 2, segments=2, census=2)
        plain1 = cost_model.hbm_bytes("parts", n, 2, segments=2)
        assert m1 == want1.launch_io, (backend, m1, want1)
        assert want1.kernel_read == plain1.kernel_read, (want1, plain1)
    print(
        "check_bench --serve: OK (guarded decode stat = 1 launch, "
        "census-free lowering, kernel reads byte-identical to unguarded)"
    )


def check_scan() -> None:
    """The triangular-MMA scan's perf contract, gated on lowered jaxprs
    (trace only -- safe on the CI CPU):

      a. a 1D scan on the Pallas backend is EXACTLY one pallas_call at
         every lane count -- the striped (c, c*bpl) grid with its in-kernel
         carry rebuild never falls back to one launch per lane or to a
         host combine pass;
      b. bf16 ingestion is staging-free: NO n-sized convert_element_type /
         pad / concatenate outside the pallas_call, including the
         reverse-direction relayout and the exclusive prefix (whose exact
         shift is sliced inside the kernel's own output, not re-padded);
      c. measured launch-boundary bytes == ``cost_model.scan_hbm_bytes``'s
         ``launch_io`` at cores in {1, 2, 4} for both native dtypes: one
         native read of the caller's buffer plus the block-padded prefix
         write, with the carry-rebuild refetch charged OUTSIDE the launch
         boundary (it re-streams blocks through the same BlockSpec, so a
         drift here means the kernel grew a real extra operand).
    """
    import jax

    from repro import reduce as R
    from repro.core import cost_model
    from repro.reduce import inspect as rinspect

    n = 300_000
    xb = jnp.zeros((n,), jnp.bfloat16)
    xf = jnp.zeros((n,), jnp.float32)
    for c in (1, 2, 4):
        for x in (xb, xf):
            plan = R.scan_plan_for(
                x.shape, x.dtype, backend="pallas_fused", num_cores=c
            )
            fn = lambda v, p=plan: R.scan(v, plan=p)
            nc = rinspect.count_pallas_calls(fn, x)
            assert nc == 1, f"scan[{x.dtype}, c={c}]: {nc} pallas_calls"  # (a)
            model = cost_model.scan_hbm_bytes(
                n, x.dtype.itemsize, m=plan.m, num_cores=c,
                tiles_per_block=plan.tiles_per_block,
            )
            measured = rinspect.pallas_io_bytes(jax.make_jaxpr(fn)(x))
            assert measured == model.launch_io, (
                f"scan[{x.dtype}, c={c}]: lowered pallas_call moves "
                f"{measured} bytes but scan_hbm_bytes models "
                f"{model.launch_io} -- kernel operands and the traffic "
                "model have diverged"
            )  # (c)
    # (b) bf16 staging-free, incl. the direction/inclusivity variants
    for kw in ({}, {"reverse": True}, {"inclusive": False}):
        fn = lambda v, k=kw: R.scan(v, backend="pallas_fused", **k)
        rinspect.assert_staging_free(fn, xb)
        nc = rinspect.count_pallas_calls(fn, xb)
        assert nc == 1, f"scan[bf16, {kw}]: {nc} pallas_calls"


def check_distributed_reduce() -> None:
    """The mesh_axes= reduce path, gated on the lowered shard_map program
    (run under ``XLA_FLAGS=--xla_force_host_platform_device_count=8`` in
    the multidevice CI job; degrades gracefully to fewer devices):

      a. the guarded clipping statistic with census inside a shard_map body
         is still EXACTLY one pallas_call -- one launch PER DEVICE, the
         local shard's whole additive row (per-leaf sums, raw total,
         census) from a single kernel;
      b. modeled interconnect bytes == the lowered program's collective
         receive bytes: ``cost_model.interconnect_bytes(slots, world)``
         against ``inspect.collective_recv_bytes`` -- the same
         model==lowered discipline as the HBM gate;
      c. the only collectives in the lowering are ``all_gather`` -- no
         opaque ``psum`` whose wire-reduction order could break the
         bitwise-replica-identical contract.
    """
    import jax
    from jax.sharding import PartitionSpec as P

    from repro import reduce as R
    from repro.core import collectives as coll
    from repro.core import cost_model
    from repro.reduce import inspect as rinspect

    world = len(jax.devices())
    mesh = jax.make_mesh((world,), ("data",))
    tree = {
        "w": jnp.ones((world * 40, 64), jnp.bfloat16),
        "b": jnp.ones((world * 300,), jnp.bfloat16),
    }
    nleaves = len(jax.tree.leaves(tree))

    def stat(t):
        return R.reduce_tree(
            t, "norm2", backend="pallas_fused", census=True,
            mesh_axes=("data",),
        )

    fn = coll.shard_map_unchecked(
        stat, mesh=mesh, in_specs=(P("data"),), out_specs=P()
    )
    n = rinspect.count_pallas_calls(fn, tree)
    assert n == 1, f"distributed census stat: {n} pallas_calls/device"  # (a)
    jaxpr = jax.make_jaxpr(fn)(tree)
    names = {name for name, _, _ in rinspect.collective_eqns(jaxpr)}
    assert names <= {"all_gather"}, (
        f"opaque collectives in the deterministic combine lowering: "
        f"{names - {'all_gather'}}"
    )  # (c)
    # row = per-leaf sums + raw total + census counts (per-leaf + total)
    slots = nleaves + 1 + (nleaves + 1)
    want = cost_model.interconnect_bytes(slots, world)
    measured = rinspect.collective_recv_bytes(jaxpr)
    assert measured == want.recv_per_device, (
        f"distributed combine receives {measured} B/device but "
        f"interconnect_bytes({slots}, {world}) models "
        f"{want.recv_per_device} -- row layout and the ICI model diverged"
    )  # (b)
    print(
        f"check_bench --distributed: OK ({world} devices, 1 launch/device, "
        f"{measured} B/device over all_gather == model)"
    )


def main(argv=None) -> None:
    args = list(sys.argv[1:] if argv is None else argv)
    if "--distributed" in args:
        # standalone multidevice gate: no BENCH json required (the bench
        # artifact is the single-device job's business)
        check_distributed_reduce()
        return
    if "--serve" in args:
        # standalone serving gate (the serve CI job): no BENCH json required
        check_serve_guard()
        return
    path = args[0] if args else "BENCH_reduce.json"
    check_report(path)
    check_launch_counts()
    check_staging_free()
    check_optimizer_step()
    check_guarded_step()
    check_serve_guard()
    check_scan()
    print(
        f"check_bench: {path} OK (structure, MMA totals, HBM traffic, "
        "launch counts, staging-free ingestion, one-trip optimizer step, "
        "guarded step census, serve guard, mma scan)"
    )


if __name__ == "__main__":
    main()
