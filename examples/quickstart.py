"""Quickstart: the paper's MMA reduction as a library, then a tiny LM trained
with every reduction in the stack routed through it.

    PYTHONPATH=src python examples/quickstart.py
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro import reduce as R
from repro.core import cost_model
from repro.core.mma_reduce import classic_tree_sum, mma_sum

# --- 1. the reduction itself -------------------------------------------------
x = jnp.asarray(np.random.RandomState(0).randn(1 << 20).astype(np.float32))

trace = []
total = mma_sum(x, m=128, trace=trace)          # pure-JAX algorithm (eq. 13)
print(f"mma_sum            = {float(total):.4f}  "
      f"(levels={trace[0].levels}, model steps={trace[0].model_steps}, "
      f"T_tc eq.16={trace[0].predicted_steps:.1f})")

total_k = R.reduce(x, backend="pallas_fused")    # Pallas TPU kernel (interpret on CPU)
print(f"reduce pallas_fused= {float(total_k):.4f}  (C-accumulator fused mode)")

print(f"classic_tree_sum   = {float(classic_tree_sum(x)):.4f}  "
      f"(paper's 4log2(n) baseline)")
print(f"model speedup S(m=128) = {cost_model.speedup_model(128):.1f}x  (eq. 17)\n")

# --- 2. a model whose norms/softmax/CE/grad-norm all ride the MMA path -------
from repro.configs import TINY_ARCHS, TrainConfig
from repro.launch.steps import make_train_step
from repro.models import init_params
from repro import optim

cfg = TINY_ARCHS["olmo-1b"]          # non-parametric LN: pure MMA statistics
params, _ = init_params(jax.random.PRNGKey(0), cfg)
opt = optim.init_state(params)
step = jax.jit(make_train_step(cfg, TrainConfig(learning_rate=3e-3,
                                                total_steps=30, warmup_steps=3)))
toks = jax.random.randint(jax.random.PRNGKey(1), (4, 64), 0, cfg.vocab_size)
for i in range(10):
    params, opt, m = step(params, opt, {"tokens": toks})
    if i % 3 == 0:
        print(f"step {i}: loss={float(m['loss']):.4f} "
              f"grad_norm(MMA)={float(m['grad_norm']):.3f}")
print("\nquickstart OK")
