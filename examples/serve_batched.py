"""Batched serving demo: prefill + multi-wave greedy decode on two cache
disciplines (full KV for a dense arch, O(1) state for the SSM arch).

    PYTHONPATH=src python examples/serve_batched.py
"""

from repro.launch.serve import main

print("=== dense arch (full KV cache) ===")
main(["--arch", "olmo-1b", "--tiny", "--requests", "6", "--batch-slots", "3",
      "--prompt-len", "16", "--max-new", "8"])

print("\n=== SSM arch (O(1) recurrent state, no KV growth) ===")
main(["--arch", "mamba2-780m", "--tiny", "--requests", "4", "--batch-slots", "2",
      "--prompt-len", "16", "--max-new", "8"])
