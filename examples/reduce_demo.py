"""The paper, end to end: encode a reduction as MMAs, count the steps,
check eq. (16)/(17), and measure the fp16/bf16 precision loss the paper
left as future work.

    PYTHONPATH=src python examples/reduce_demo.py
"""

import jax.numpy as jnp
import numpy as np

from repro.core import cost_model, precision
from repro.core.mma_reduce import classic_tree_sum, mma_sum

rng = np.random.RandomState(0)

print("=== step counts: T_tc(n) = 5 log_{m^2}(n)   [eq. 15-16] ===")
print(f"{'n':>10} {'m':>4} {'levels':>7} {'steps':>6} {'eq16':>6} "
      f"{'classic':>8} {'S meas':>7} {'S eq17':>7}")
for m in (4, 16, 128):
    for k in (1, 2):
        n = (m * m) ** k
        if n > 1 << 22:
            continue
        x = jnp.asarray(rng.randn(n).astype(np.float32))
        tr, tc = [], []
        mma_sum(x, m=m, trace=tr)
        classic_tree_sum(x, trace=tc)
        s_meas = 4 * tc[0].levels / tr[0].model_steps
        print(f"{n:>10} {m:>4} {tr[0].levels:>7} {tr[0].model_steps:>6} "
              f"{cost_model.t_tensor_core(n, m):>6.1f} {4*tc[0].levels:>8} "
              f"{s_meas:>7.2f} {cost_model.speedup_model(m):>7.2f}")

print("\n=== precision loss (paper section V future work) ===")
x = jnp.asarray(rng.randn(1 << 20).astype(np.float32))
exact = np.asarray(x).astype(np.float64).sum()
for name, val in [
    ("mma bf16 multipliers + f32 accum", mma_sum(x)),
    ("mma fp16 multipliers (V100 mode)", mma_sum(x, compute_dtype=jnp.float16)),
    ("mma f32 (exact-ish)", mma_sum(x, compute_dtype=jnp.float32)),
    ("classic pairwise f32", classic_tree_sum(x)),
    ("blocked Kahan + MMA (Markidis-style)", precision.blocked_kahan_mma(x)),
]:
    rel = abs(float(val) - exact) / abs(exact)
    print(f"  {name:40s} rel err = {rel:.3e}")

print("\n=== segmented multi-reduce: N reductions, ONE pass ===")
from repro import reduce as R  # noqa: E402

segs = [jnp.asarray(rng.randn(n).astype(np.float32)) for n in (33, 1000, 16385)]
batched = R.reduce_many(segs, kind="sumsq")
for a, got in zip(segs, np.asarray(batched)):
    exact = (np.asarray(a, np.float64) ** 2).sum()
    print(f"  segment n={a.size:>6}: batched={got:12.4f} exact={exact:12.4f}")
print("  plan:", R.plan_for((sum(a.size for a in segs),), jnp.float32,
                            kind='sumsq', segments=len(segs)))

print("\n=== where it lands on TPU v5e (this work's extension) ===")
for n in (1 << 16, 1 << 24):
    rl = cost_model.tpu_reduction_roofline(n)
    print(f"  n={n:>10}: HBM {rl.hbm_s*1e6:7.2f}us  VPU {rl.vpu_s*1e6:7.2f}us  "
          f"MXU {rl.mxu_s*1e6:7.2f}us  bandwidth-neutral={rl.mxu_bandwidth_neutral}")
print("cold reductions are HBM-bound; the MMA encoding wins as a VPU offload "
      "inside fused kernels (norms, softmax, CE) -- see DESIGN.md section 2.1")
