"""End-to-end training driver for a ~100M-parameter model.

On a TPU slice this runs the real thing (a few hundred steps of a 110M
llama-family config on the production mesh):

    PYTHONPATH=src python examples/train_100m.py --steps 300 --batch 64

On this CPU container, --smoke trains a reduced-width sibling for a few
steps to prove the path end to end (CI default).
"""

import argparse
import dataclasses

import jax

from repro.configs.base import ModelConfig, TrainConfig
from repro.data import Prefetcher, ShardInfo, SyntheticLM
from repro.launch.steps import make_train_step
from repro.models import init_params
from repro import optim

# ~110M params: 12L x 768, GPT-2-small-shaped llama-style stack
CFG_100M = ModelConfig(
    name="llama-110m", family="dense", n_layers=12, d_model=768, n_heads=12,
    n_kv_heads=12, d_head=64, d_ff=3072, vocab_size=32000, norm="rmsnorm",
    dtype="float32",
)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=512)
    ap.add_argument("--smoke", action="store_true")
    args = ap.parse_args(argv)

    cfg = CFG_100M
    if args.smoke:
        cfg = dataclasses.replace(cfg, n_layers=2, d_model=128, n_heads=4,
                                  n_kv_heads=4, d_head=32, d_ff=512,
                                  vocab_size=2048, name="llama-110m-smoke")
        args.steps, args.batch, args.seq = min(args.steps, 6), 4, 128

    params, _ = init_params(jax.random.PRNGKey(0), cfg)
    n = sum(x.size for x in jax.tree.leaves(params))
    print(f"{cfg.name}: {n/1e6:.1f}M params, {args.steps} steps, "
          f"batch {args.batch} x seq {args.seq}")
    tcfg = TrainConfig(learning_rate=6e-4, total_steps=args.steps,
                       warmup_steps=max(1, args.steps // 20))
    step = jax.jit(make_train_step(cfg, tcfg))
    opt = optim.init_state(params)
    data = Prefetcher(SyntheticLM(cfg.vocab_size, args.seq, args.batch,
                                  ShardInfo(), seed=0))
    import jax.numpy as jnp
    losses = []
    for i in range(args.steps):
        batch = {"tokens": jnp.asarray(data.next()["tokens"])}
        params, opt, m = step(params, opt, batch)
        losses.append(float(m["loss"]))
        if (i + 1) % max(1, args.steps // 10) == 0:
            print(f"step {i+1:4d}  loss {losses[-1]:.4f}")
    data.close()
    print(f"loss {losses[0]:.3f} -> {losses[-1]:.3f}")
    assert losses[-1] < losses[0]
    return losses


if __name__ == "__main__":
    main()
