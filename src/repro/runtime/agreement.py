"""Cross-host agreement checking for the guarded training loop.

The distributed guarded step (``optim.guarded_apply_updates`` with
``mesh_axes=``) is DESIGNED so that every replica computes bit-identical
statistics -- the fixed-order combine makes the skip flag, the census and
the clip coefficient replica-invariant by construction. This module is the
belt to that suspenders: each host fingerprints its view of the step
(state hash, census counts, guard decision) and an ``AgreementChecker``
cross-verifies the fingerprints, raising a structured ``DivergenceError``
that names the FIRST disagreeing host and the step the moment any replica
departs from the fleet.

Everything here is plain Python + numpy-at-the-edges (no jax at module
import, like ``chaos``): the checker is transport-agnostic glue a launcher
can feed from an allgather, a key-value store, or -- in tests -- a plain
in-process dict.

The ``Transport`` ABC closes the loop to REAL processes: ``publish`` one
host's fingerprint, ``fetch`` the roster seen so far, and ``exchange``
drives a full record-poll-check round against any implementation.
``FileTransport`` is the minimal loopback -- one atomically-renamed file
per (step, host) under a shared directory -- enough for multi-process
tests and single-node launchers; a KV store or an RPC mesh implements the
same two methods for the fleet case.
"""

from __future__ import annotations

import abc
import hashlib
import os
import tempfile
import time
from typing import Mapping


class DivergenceError(RuntimeError):
    """A replica's fingerprint disagrees with the fleet reference.

    Attributes name the first (lowest-id) disagreeing host and the step,
    plus both fingerprints, so the launcher can fence exactly the replica
    that went wrong instead of restarting the world blind.
    """

    def __init__(self, step: int, host: int, expected: str, got: str):
        self.step = int(step)
        self.host = int(host)
        self.expected = expected
        self.got = got
        super().__init__(
            f"replica divergence at step {step}: host {host} reports "
            f"fingerprint {got[:16]}.. but the fleet reference (host 0) "
            f"is {expected[:16]}.."
        )


def fingerprint(*parts) -> str:
    """sha256 hex digest over a heterogeneous tuple of step artifacts.

    Arrays hash their raw bytes PLUS shape/dtype tags (so a transposed or
    recast array cannot collide); floats hash their IEEE bits via numpy
    (so two hosts disagreeing only in the last ulp still diverge -- the
    whole point of the bitwise-deterministic combine); str/bytes/int hash
    their obvious encodings. Nested tuples/lists/dicts recurse with
    delimiters. Deliberately NOT Python ``hash()``: must be stable across
    processes and hosts.
    """
    import numpy as np

    h = hashlib.sha256()

    def feed(x):
        if isinstance(x, (tuple, list)):
            h.update(b"(")
            for item in x:
                feed(item)
                h.update(b",")
            h.update(b")")
        elif isinstance(x, Mapping):
            h.update(b"{")
            for k in sorted(x):
                feed(str(k))
                h.update(b":")
                feed(x[k])
                h.update(b",")
            h.update(b"}")
        elif isinstance(x, bytes):
            h.update(b"b" + x)
        elif isinstance(x, str):
            h.update(b"s" + x.encode())
        elif isinstance(x, bool):
            h.update(b"B1" if x else b"B0")
        elif isinstance(x, int):
            h.update(b"i" + str(x).encode())
        elif isinstance(x, float):
            h.update(b"f" + np.float64(x).tobytes())
        elif x is None:
            h.update(b"N")
        else:  # ndarray / jax array / anything exposing the array protocol
            a = np.asarray(x)
            h.update(b"a" + str(a.shape).encode() + str(a.dtype).encode())
            h.update(np.ascontiguousarray(a).tobytes())

    for part in parts:
        feed(part)
        h.update(b";")
    return h.hexdigest()


def step_fingerprint(step: int, census, skipped, statistic) -> str:
    """The canonical guard fingerprint: step number + census counts +
    skip decision + the combined statistic's bits. Hosts running the
    deterministic mesh path MUST produce identical strings."""
    return fingerprint(int(step), census, skipped, statistic)


class AgreementChecker:
    """Cross-verify per-host fingerprints against the host-0 reference.

    Feed it with ``record(step, host, fp)`` in any order (the transport --
    allgather, KV store, test dict -- is the caller's business). Once the
    reference (host 0) for a step is known, every other host's record is
    checked immediately; ``check(step)`` additionally verifies the roster
    is complete. The first disagreement raises ``DivergenceError`` naming
    the lowest disagreeing host id. ``checks_passed`` counts fully-agreed
    steps for the metrics exporter.
    """

    def __init__(self, n_hosts: int):
        if n_hosts < 1:
            raise ValueError(f"n_hosts must be >= 1; got {n_hosts}")
        self.n_hosts = int(n_hosts)
        self._steps: dict[int, dict[int, str]] = {}
        self.checks_passed = 0

    def record(self, step: int, host: int, fp: str) -> None:
        step, host = int(step), int(host)
        if not 0 <= host < self.n_hosts:
            raise ValueError(f"host {host} out of range [0, {self.n_hosts})")
        seen = self._steps.setdefault(step, {})
        seen[host] = fp
        ref = seen.get(0)
        if ref is None:
            return
        for h in sorted(seen):
            if seen[h] != ref:
                raise DivergenceError(step, h, ref, seen[h])

    def check(self, step: int) -> bool:
        """Assert the step's roster is complete and unanimous. Returns
        True (and bumps ``checks_passed``) or raises."""
        step = int(step)
        seen = self._steps.get(step, {})
        missing = [h for h in range(self.n_hosts) if h not in seen]
        if missing:
            raise RuntimeError(
                f"agreement check at step {step}: no fingerprint from "
                f"host(s) {missing} (dead or silent -- heartbeat's problem, "
                f"not a divergence)"
            )
        ref = seen[0]
        for h in range(1, self.n_hosts):
            if seen[h] != ref:
                raise DivergenceError(step, h, ref, seen[h])
        self.checks_passed += 1
        del self._steps[step]  # bounded memory across a long run
        return True


class Transport(abc.ABC):
    """Fingerprint exchange between REAL processes (the checker itself is
    transport-agnostic; this is the wire). Implementations must make
    ``publish`` atomic-per-record and ``fetch`` return only complete
    records -- a reader must never observe a torn fingerprint."""

    @abc.abstractmethod
    def publish(self, step: int, host: int, fp: str) -> None:
        """Make (step, host) -> fp visible to every other participant."""

    @abc.abstractmethod
    def fetch(self, step: int) -> dict:
        """All fingerprints published for ``step`` so far: {host: fp}."""


class FileTransport(Transport):
    """Shared-directory loopback transport: one file per (step, host),
    written tmp + ``os.replace`` (the same atomicity discipline as the
    metrics exporter) so concurrent readers in other processes see either
    nothing or the whole fingerprint. Works across real OS processes on
    one node (tests) or any shared filesystem (NFS caveat: rename is
    atomic per POSIX, visibility lag is the poller's timeout problem)."""

    def __init__(self, root):
        self.root = os.fspath(root)
        os.makedirs(self.root, exist_ok=True)

    def _path(self, step: int, host: int) -> str:
        return os.path.join(self.root, f"step{int(step):012d}.host{int(host)}")

    def publish(self, step: int, host: int, fp: str) -> None:
        fd, tmp = tempfile.mkstemp(dir=self.root, prefix=".fp_")
        try:
            with os.fdopen(fd, "w") as f:
                f.write(fp)
            os.replace(tmp, self._path(step, host))
        except BaseException:
            try:
                os.unlink(tmp)
            except OSError:
                pass
            raise

    def fetch(self, step: int) -> dict:
        prefix = f"step{int(step):012d}.host"
        out = {}
        for name in os.listdir(self.root):
            if not name.startswith(prefix):
                continue
            try:
                host = int(name[len(prefix):])
            except ValueError:
                continue
            with open(os.path.join(self.root, name)) as f:
                out[host] = f.read()
        return out


def exchange(
    checker: AgreementChecker,
    transport: Transport,
    step: int,
    host: int,
    fp: str,
    *,
    timeout_s: float = 30.0,
    poll_s: float = 0.02,
    clock=time.monotonic,
    sleep=time.sleep,
) -> bool:
    """One full agreement round over a real transport: publish this host's
    fingerprint, poll until the roster for ``step`` is complete (or
    ``timeout_s``), feed every record to the checker, and run the final
    unanimity check. Raises ``DivergenceError`` the moment any fetched
    fingerprint disagrees with the host-0 reference, ``TimeoutError`` if
    the roster never fills (a dead host -- the heartbeat's problem, but
    the caller must not hang forever waiting to learn it). ``clock`` and
    ``sleep`` are injectable for deterministic tests."""
    transport.publish(step, host, fp)
    deadline = clock() + timeout_s
    while True:
        seen = transport.fetch(step)
        if len(seen) >= checker.n_hosts:
            break
        if clock() >= deadline:
            missing = [
                h for h in range(checker.n_hosts) if h not in seen
            ]
            raise TimeoutError(
                f"agreement exchange at step {step}: no fingerprint from "
                f"host(s) {missing} within {timeout_s}s"
            )
        sleep(poll_s)
    for h in sorted(seen):
        checker.record(step, h, seen[h])
    return checker.check(step)
