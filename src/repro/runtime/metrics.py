"""Guard observability: counters for the skip/retry/rollback machinery.

A guarded run that silently skips 30% of its steps is a broken run that
LOOKS healthy; these counters make the guard's behavior visible. The
supervisor records one entry per step, the launcher logs the snapshot at
every checkpoint commit, and ``write()`` exports an atomic JSON status
file that an external watchdog (or the next incarnation after a restart)
can poll without touching the training process.

Plain Python, no jax at module import -- callers pass already-materialized
floats/ints (the supervisor reads them off the step's metrics dict).
"""

from __future__ import annotations

import json
import os
import tempfile


class GuardMetrics:
    """Monotone counters + last-seen gauges for the guarded loop."""

    def __init__(self):
        self.steps_total = 0
        self.steps_skipped = 0
        self.retries = 0
        self.rollbacks = 0
        self.commits = 0
        self.last_census_total = 0.0
        self.last_step = -1
        self.divergence_checks_passed = 0

    def record_step(self, step: int, *, skipped: bool,
                    census_total: float = 0.0) -> None:
        self.steps_total += 1
        self.last_step = int(step)
        self.last_census_total = float(census_total)
        if skipped:
            self.steps_skipped += 1

    def record_retry(self, n: int = 1) -> None:
        self.retries += int(n)

    def record_rollback(self) -> None:
        self.rollbacks += 1

    def record_commit(self) -> None:
        self.commits += 1

    def record_agreement(self, checks_passed: int) -> None:
        """Absolute counter from ``AgreementChecker.checks_passed``."""
        self.divergence_checks_passed = int(checks_passed)

    def snapshot(self) -> dict:
        return {
            "steps_total": self.steps_total,
            "steps_skipped": self.steps_skipped,
            "retries": self.retries,
            "rollbacks": self.rollbacks,
            "commits": self.commits,
            "last_census_total": self.last_census_total,
            "last_step": self.last_step,
            "divergence_checks_passed": self.divergence_checks_passed,
        }

    def write(self, path) -> None:
        """Atomic JSON export: write-to-temp + ``os.replace`` so a poller
        never observes a torn file, even if the trainer dies mid-write."""
        path = os.fspath(path)
        d = os.path.dirname(path) or "."
        fd, tmp = tempfile.mkstemp(dir=d, prefix=".guard_metrics_")
        try:
            with os.fdopen(fd, "w") as f:
                json.dump(self.snapshot(), f, indent=1, sort_keys=True)
            os.replace(tmp, path)
        except BaseException:
            try:
                os.unlink(tmp)
            except OSError:
                pass
            raise


def _percentile(sorted_vals, q: float) -> float:
    """Nearest-rank percentile over an already-sorted list (no numpy: this
    module stays import-light for watchdog processes)."""
    if not sorted_vals:
        return 0.0
    k = max(0, min(len(sorted_vals) - 1, int(round(q * (len(sorted_vals) - 1)))))
    return float(sorted_vals[k])


class ServeMetrics(GuardMetrics):
    """The serving runtime's SLO counters, layered on the guard counters.

    Admission (admitted/shed_queue_full/shed_infeasible), deadline misses,
    per-slot quarantines, breaker trips + live per-backend breaker states,
    completed requests/tokens, and a bounded reservoir of per-token decode
    latencies summarized as p50/p99 in the snapshot. Everything exports
    through the same atomic-JSON ``write()`` (``--status-path``) the
    training supervisor uses, so one watchdog polls both shapes."""

    def __init__(self, latency_window: int = 4096):
        super().__init__()
        self.admitted = 0
        self.shed_queue_full = 0
        self.shed_infeasible = 0
        self.deadline_missed = 0
        self.quarantined = 0
        self.rejected_poisoned = 0
        self.breaker_trips = 0
        self.completed = 0
        self.tokens_out = 0
        self.breaker_states: dict = {}
        self._latency_window = int(latency_window)
        self._latencies: list = []

    def record_admit(self) -> None:
        self.admitted += 1

    def record_shed(self, *, infeasible: bool = False) -> None:
        if infeasible:
            self.shed_infeasible += 1
        else:
            self.shed_queue_full += 1

    def record_deadline_miss(self) -> None:
        self.deadline_missed += 1

    def record_quarantine(self, n: int = 1) -> None:
        self.quarantined += int(n)

    def record_poisoned(self) -> None:
        self.rejected_poisoned += 1

    def record_breaker_trip(self) -> None:
        self.breaker_trips += 1

    def record_breaker_states(self, states: dict) -> None:
        """Live gauge: {backend name: "closed"|"open"|"half_open"}."""
        self.breaker_states = dict(states)

    def record_completed(self, n_tokens: int) -> None:
        self.completed += 1
        self.tokens_out += int(n_tokens)

    def record_token_latency(self, seconds: float) -> None:
        """One decode step's wall time (one token per active slot). The
        reservoir keeps the newest ``latency_window`` samples -- a long-
        running server's tail stays current, not lifetime-averaged."""
        self._latencies.append(float(seconds))
        if len(self._latencies) > self._latency_window:
            del self._latencies[: len(self._latencies) - self._latency_window]

    def snapshot(self) -> dict:
        lat = sorted(self._latencies)
        snap = super().snapshot()
        snap.update(
            {
                "admitted": self.admitted,
                "shed_queue_full": self.shed_queue_full,
                "shed_infeasible": self.shed_infeasible,
                "deadline_missed": self.deadline_missed,
                "quarantined": self.quarantined,
                "rejected_poisoned": self.rejected_poisoned,
                "breaker_trips": self.breaker_trips,
                "breaker_states": self.breaker_states,
                "completed": self.completed,
                "tokens_out": self.tokens_out,
                "token_latency_p50_s": _percentile(lat, 0.50),
                "token_latency_p99_s": _percentile(lat, 0.99),
                "token_latency_samples": len(lat),
            }
        )
        return snap
