"""Guard observability: counters for the skip/retry/rollback machinery.

A guarded run that silently skips 30% of its steps is a broken run that
LOOKS healthy; these counters make the guard's behavior visible. The
supervisor records one entry per step, the launcher logs the snapshot at
every checkpoint commit, and ``write()`` exports an atomic JSON status
file that an external watchdog (or the next incarnation after a restart)
can poll without touching the training process.

Plain Python, no jax at module import -- callers pass already-materialized
floats/ints (the supervisor reads them off the step's metrics dict).
"""

from __future__ import annotations

import json
import os
import tempfile


class GuardMetrics:
    """Monotone counters + last-seen gauges for the guarded loop."""

    def __init__(self):
        self.steps_total = 0
        self.steps_skipped = 0
        self.retries = 0
        self.rollbacks = 0
        self.commits = 0
        self.last_census_total = 0.0
        self.last_step = -1
        self.divergence_checks_passed = 0

    def record_step(self, step: int, *, skipped: bool,
                    census_total: float = 0.0) -> None:
        self.steps_total += 1
        self.last_step = int(step)
        self.last_census_total = float(census_total)
        if skipped:
            self.steps_skipped += 1

    def record_retry(self, n: int = 1) -> None:
        self.retries += int(n)

    def record_rollback(self) -> None:
        self.rollbacks += 1

    def record_commit(self) -> None:
        self.commits += 1

    def record_agreement(self, checks_passed: int) -> None:
        """Absolute counter from ``AgreementChecker.checks_passed``."""
        self.divergence_checks_passed = int(checks_passed)

    def snapshot(self) -> dict:
        return {
            "steps_total": self.steps_total,
            "steps_skipped": self.steps_skipped,
            "retries": self.retries,
            "rollbacks": self.rollbacks,
            "commits": self.commits,
            "last_census_total": self.last_census_total,
            "last_step": self.last_step,
            "divergence_checks_passed": self.divergence_checks_passed,
        }

    def write(self, path) -> None:
        """Atomic JSON export: write-to-temp + ``os.replace`` so a poller
        never observes a torn file, even if the trainer dies mid-write."""
        path = os.fspath(path)
        d = os.path.dirname(path) or "."
        fd, tmp = tempfile.mkstemp(dir=d, prefix=".guard_metrics_")
        try:
            with os.fdopen(fd, "w") as f:
                json.dump(self.snapshot(), f, indent=1, sort_keys=True)
            os.replace(tmp, path)
        except BaseException:
            try:
                os.unlink(tmp)
            except OSError:
                pass
            raise
