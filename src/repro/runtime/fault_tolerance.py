"""Fault-tolerance runtime for 1000+ node deployments.

Pieces (all testable single-host; the transport is pluggable):

  HeartbeatTracker    -- per-host liveness + per-step timing; marks hosts
                         dead after `timeout_s` silence and flags stragglers
                         whose step time exceeds `straggler_factor` x the
                         fleet median (the standard mitigation is to swap
                         the straggler's shard onto a hot spare and/or drop
                         it from the mesh at the next elastic boundary).
  PreemptionGuard     -- SIGTERM/SIGINT -> "checkpoint then exit" flag, the
                         contract preemptible TPU/TRN fleets expect.
  ElasticPlan         -- given the surviving host set, computes the next
                         mesh shape (largest (data x model) grid that the
                         survivors support with model-degree preserved) and
                         the batch re-split; restore goes through
                         CheckpointManager.restore(reshard=...).
  TrainSupervisor     -- glue: wraps a step function with heartbeat
                         recording, preemption checks, periodic checkpoints
                         and automatic resume.
"""

from __future__ import annotations

import dataclasses
import signal
import time
from typing import Callable


class HeartbeatTracker:
    def __init__(self, n_hosts: int, timeout_s: float = 60.0,
                 straggler_factor: float = 2.0):
        self.n_hosts = n_hosts
        self.timeout_s = timeout_s
        self.straggler_factor = straggler_factor
        self.last_seen = {h: time.monotonic() for h in range(n_hosts)}
        self.step_times: dict[int, list] = {h: [] for h in range(n_hosts)}
        # last guard-metrics snapshot each host attached to a beat: lets
        # the supervisor's liveness channel double as the guard-health
        # channel (a host that is alive but skipping every step shows up
        # here, not in dead_hosts)
        self.last_metrics: dict[int, dict] = {}

    def beat(self, host: int, step_time_s: float | None = None,
             now: float | None = None, metrics: dict | None = None) -> None:
        now = time.monotonic() if now is None else now
        self.last_seen[host] = now
        if step_time_s is not None:
            t = self.step_times[host]
            t.append(step_time_s)
            if len(t) > 32:
                del t[:-32]
        if metrics is not None:
            self.last_metrics[host] = dict(metrics)

    def dead_hosts(self, now: float | None = None) -> list[int]:
        now = time.monotonic() if now is None else now
        return [h for h, t in self.last_seen.items()
                if now - t > self.timeout_s]

    @staticmethod
    def _median(xs: list) -> float:
        s = sorted(xs)
        n = len(s)
        return 0.5 * (s[(n - 1) // 2] + s[n // 2])

    def stragglers(self) -> list[int]:
        """Hosts whose RECENT-WINDOW median step time exceeds
        ``straggler_factor`` x the fleet median of those medians. Keying
        off each host's window median (the 32-sample ``beat`` buffer)
        instead of its single last step means one slow step -- a GC pause,
        a checkpoint flush -- cannot flag a healthy host; a genuine
        straggler shifts its whole window and still trips the factor."""
        meds = {
            h: self._median(t) for h, t in self.step_times.items() if t
        }
        if len(meds) < max(2, self.n_hosts // 2):
            return []
        fleet = self._median(list(meds.values()))
        return [
            h for h, m in meds.items()
            if m > self.straggler_factor * fleet
        ]

    def healthy(self, now: float | None = None) -> list[int]:
        dead = set(self.dead_hosts(now))
        return [h for h in range(self.n_hosts) if h not in dead]


class PreemptionGuard:
    """SIGTERM -> graceful "checkpoint and exit". Poll `should_stop`."""

    def __init__(self, install: bool = True):
        self._flag = False
        if install:
            try:
                signal.signal(signal.SIGTERM, self._handler)
                signal.signal(signal.SIGINT, self._handler)
            except ValueError:
                pass  # not main thread (tests)

    def _handler(self, signum, frame):
        self._flag = True

    def trigger(self) -> None:  # testing / external schedulers
        self._flag = True

    @property
    def should_stop(self) -> bool:
        return self._flag


@dataclasses.dataclass(frozen=True)
class ElasticPlan:
    """Next-incarnation topology after losing hosts.

    Model-parallel degree is preserved (param layouts stay valid, only the
    data axis shrinks), so restore is a pure re-device_put -- no weight
    resharding math. Batch is re-split over the surviving data degree;
    global batch is kept by raising grad-accumulation microbatches.
    """

    n_hosts: int
    devices_per_host: int
    model_degree: int
    global_batch: int

    def plan(self, survivors: list[int]) -> dict:
        n = len(survivors)
        total = n * self.devices_per_host
        if total % self.model_degree:
            # drop hosts to the largest multiple that preserves model degree
            keep = (total // self.model_degree) * self.model_degree
            n = keep // self.devices_per_host
            survivors = survivors[:n]
            total = n * self.devices_per_host
        data_degree = total // self.model_degree
        if data_degree == 0:
            raise RuntimeError("not enough survivors for one model replica")
        micro = 1
        while (self.global_batch // micro) % data_degree or \
                (self.global_batch // micro) // data_degree > 64:
            micro += 1
            if micro > self.global_batch:
                raise RuntimeError("cannot split batch over survivors")
        return {
            "hosts": survivors,
            "mesh_shape": (data_degree, self.model_degree),
            "microbatches": micro,
            "local_batch": self.global_batch // micro // data_degree,
        }


class TrainSupervisor:
    """Single-host view of the supervision loop (transport pluggable)."""

    def __init__(self, step_fn: Callable, ckpt, data, *, host_id: int = 0,
                 n_hosts: int = 1, ckpt_every: int = 100,
                 guard: PreemptionGuard | None = None,
                 step_guard=None, metrics=None, status_path=None):
        self.step_fn = step_fn
        self.ckpt = ckpt
        self.data = data
        self.host_id = host_id
        self.tracker = HeartbeatTracker(n_hosts)
        self.guard = guard or PreemptionGuard(install=False)
        self.ckpt_every = ckpt_every
        # Duck-typed chaos.StepGuard: retry(fn, ...)/record(skipped)/
        # should_rollback()/reset(). None = pre-guard behavior exactly.
        self.step_guard = step_guard
        # Duck-typed metrics.GuardMetrics: record_step/record_retry/
        # record_rollback/record_commit/snapshot/write. None = no-op.
        # status_path: atomic JSON status file, rewritten at every commit.
        self.metrics = metrics
        self.status_path = status_path

    def _export_metrics(self) -> None:
        if self.metrics is None:
            return
        self.metrics.record_commit()
        if self.status_path is not None:
            self.metrics.write(self.status_path)

    def resume(self, state):
        """state = (params, opt_state). Returns (state, start_step).

        BARRIER FIRST: ``save()`` snapshots synchronously but FLUSHES on a
        background thread, so a prior incarnation's save can still be
        mid-flush (tmp dir, no ``_COMMITTED``) when the restart scans for
        checkpoints -- ``latest()`` would silently resume one checkpoint
        early and replay data the flushing save already covered. Draining
        the writer makes resume-after-save deterministic: whatever
        ``save()`` was called is either committed and found, or its
        incarnation died pre-commit and the previous commit is genuinely
        the newest state."""
        wait = getattr(self.ckpt, "wait", None)
        if callable(wait):
            wait()
        latest = self.ckpt.latest()
        if latest is None:
            return state, 0
        tree = self.ckpt.restore(latest, state)
        man = self.ckpt.manifest(latest)
        self.data.seek(man["extra"].get("data_step", latest))
        return tree, latest

    def _rollback(self, state):
        """Restore the last COMMITTED checkpoint and rewind the data
        pipeline to its recorded step. Returns (state, step)."""
        self.ckpt.wait()
        latest = self.ckpt.latest()
        if latest is None:
            raise RuntimeError(
                "rollback requested but no committed checkpoint exists; "
                "the supervisor saves a step-0 anchor when a step_guard is "
                "installed, so this means the checkpoint dir was removed "
                "out from under the run"
            )
        tree = self.ckpt.restore(latest, state)
        man = self.ckpt.manifest(latest)
        self.data.seek(man["extra"].get("data_step", latest))
        return tree, latest

    def run(self, state, n_steps: int):
        state, start = self.resume(state)
        step = start
        if self.step_guard is not None and self.ckpt.latest() is None:
            # anchor commit: rollback must always have a target, even if
            # the guard trips before the first periodic checkpoint
            self.ckpt.save(
                0, state, extra={"data_step": self.data.state()["step"]}
            )
        while step < n_steps:
            t0 = time.monotonic()
            batch = self.data.next()
            if self.step_guard is not None:
                before = self.step_guard.transient_failures
                state, metrics = self.step_guard.retry(
                    self.step_fn, state, batch
                )
                if self.metrics is not None:
                    self.metrics.record_retry(
                        self.step_guard.transient_failures - before
                    )
            else:
                state, metrics = self.step_fn(state, batch)
            step += 1
            skipped = False
            census_total = 0.0
            if self.step_guard is not None:
                if isinstance(metrics, dict):
                    skipped = float(metrics.get("skipped", 0.0)) > 0.0
                    census_total = float(metrics.get("nonfinite", 0.0))
                self.step_guard.record(skipped)
            if self.metrics is not None:
                self.metrics.record_step(
                    step, skipped=skipped, census_total=census_total
                )
            self.tracker.beat(
                self.host_id, time.monotonic() - t0,
                metrics=(
                    self.metrics.snapshot()
                    if self.metrics is not None else None
                ),
            )
            if self.step_guard is not None and \
                    self.step_guard.should_rollback():
                state, step = self._rollback(state)
                self.step_guard.reset()
                self.step_guard.rollbacks = (
                    getattr(self.step_guard, "rollbacks", 0) + 1
                )
                if self.metrics is not None:
                    self.metrics.record_rollback()
                    if self.status_path is not None:
                        self.metrics.write(self.status_path)
                continue
            # never COMMIT mid-skip-streak: a periodic save after a skipped
            # step would record a data position past batches whose update
            # never applied, silently shrinking the rollback window
            if (step % self.ckpt_every == 0 and not skipped) \
                    or self.guard.should_stop:
                self.ckpt.save(
                    step, state, extra={"data_step": self.data.state()["step"]}
                )
                self._export_metrics()
            if self.guard.should_stop:
                self.ckpt.wait()
                return state, step, "preempted"
        self.ckpt.wait()
        return state, step, "done"
