from repro.runtime.chaos import (  # noqa: F401
    ChaosMonkey,
    StepGuard,
    TransientFault,
)
from repro.runtime.fault_tolerance import (  # noqa: F401
    ElasticPlan,
    HeartbeatTracker,
    PreemptionGuard,
    TrainSupervisor,
)
