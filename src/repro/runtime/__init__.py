from repro.runtime.agreement import (  # noqa: F401
    AgreementChecker,
    DivergenceError,
    FileTransport,
    Transport,
    exchange,
    fingerprint,
    step_fingerprint,
)
from repro.runtime.chaos import (  # noqa: F401
    ChaosMonkey,
    Preemption,
    StepGuard,
    TransientFault,
)
from repro.runtime.fault_tolerance import (  # noqa: F401
    ElasticPlan,
    HeartbeatTracker,
    PreemptionGuard,
    TrainSupervisor,
)
from repro.runtime.metrics import GuardMetrics, ServeMetrics  # noqa: F401
from repro.runtime.serving import (  # noqa: F401
    AdmissionQueue,
    CircuitBreaker,
    Completion,
    DeadlineExceeded,
    Request,
    RequestRejected,
    ServingRuntime,
    guarded_logit_stat,
)
