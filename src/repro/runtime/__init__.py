from repro.runtime.agreement import (  # noqa: F401
    AgreementChecker,
    DivergenceError,
    fingerprint,
    step_fingerprint,
)
from repro.runtime.chaos import (  # noqa: F401
    ChaosMonkey,
    StepGuard,
    TransientFault,
)
from repro.runtime.fault_tolerance import (  # noqa: F401
    ElasticPlan,
    HeartbeatTracker,
    PreemptionGuard,
    TrainSupervisor,
)
from repro.runtime.metrics import GuardMetrics  # noqa: F401
