from repro.runtime.fault_tolerance import (  # noqa: F401
    ElasticPlan,
    HeartbeatTracker,
    PreemptionGuard,
    TrainSupervisor,
)
