"""Resilient serving runtime: admission control, deadlines, census-guarded
decode, and per-backend circuit breaking.

The training guard stack (PRs 7-8) protects a loop that can afford to skip
and rewind; serving cannot -- a request either completes in its deadline or
fails STRUCTURED. This module is the serving-side counterpart, built from
the same primitives:

  admission      -- a bounded FIFO with load shedding: a full queue sheds
                    the oldest already-past-deadline request first
                    (``AdmissionQueue``), and the scheduler refuses work it
                    cannot finish before its deadline (EWMA per-step time),
                    returning ``RequestRejected`` instead of queueing a
                    guaranteed miss.
  census guard   -- every decode step's logit statistic rides
                    ``reduce(..., census=True)`` / ``reduce_tree``'s
                    per-slot fork (``guarded_logit_stat``): the SAME launch
                    that computes the statistic counts NaN/Inf logits per
                    slot, zero extra HBM input bytes. A poisoned slot is
                    quarantined for the step and the step retried WITHOUT
                    restarting the batch -- state commits only on a clean
                    census, so a transient NaN (fire-once chaos, a flaky
                    unit) reproduces the clean run bitwise.
  circuit breaker-- repeated kernel faults (``TransientFault``) trip a
                    per-backend breaker (``CircuitBreaker``) that degrades
                    along the registry chain pallas -> mma_jnp -> xla and
                    probes the failed backend half-open after a bounded
                    exponential cooldown. Tripping also quarantines the
                    backend in the PLANNER (``reduce.quarantine_backend``)
                    so auto-selected plans elsewhere in the process cannot
                    resurrect it; half-open probes address it explicitly.
  observability  -- ``ServeMetrics`` (admitted/shed/deadline-missed/
                    quarantined/breaker state, p50/p99 per-token latency)
                    exported through the atomic-JSON ``--status-path``
                    mechanism shared with the training supervisor.

The runtime is ENGINE-AGNOSTIC: anything with the three-method protocol
below serves (``launch.serve.GuardedEngine`` adapts the real model; tests
drive a jax-free fake). Plain Python, no jax at module import -- only
``guarded_logit_stat`` imports jax, lazily, when an engine actually calls
it.

Engine protocol::

    engine.slots                       # int, batch width
    engine.validate(prompt, max_new)   # -> error str | None
    engine.start_wave(prompts, scales) # -> (state, tokens, census)
    engine.decode(state, scales, backend) -> (state', tokens, census)

``prompts`` is a list of per-slot prompt arrays (None = masked dummy
slot); ``scales`` a per-slot float multiplier applied to the slot's logits
(1.0 = bitwise identity -- the chaos hook); ``tokens`` per-slot ints;
``census`` the per-slot non-finite counts with the total in the last slot
(``guarded_logit_stat``'s layout). Steps must be FUNCTIONAL: the runtime
re-issues a step from the same ``state`` on retry, so an engine must not
mutate caches in place. Faults raise ``TransientFault`` (charged to the
breaker) or ``Preemption`` (retried free).
"""

from __future__ import annotations

import dataclasses
import math
import time
from typing import Callable, Optional, Sequence

from repro.runtime.chaos import ChaosMonkey, Preemption, TransientFault
from repro.runtime.metrics import ServeMetrics

# The default degradation order: the kernel backend first, the pure-JAX
# MMA emulation behind it, the always-available XLA fallback terminal.
DEFAULT_BACKEND_CHAIN = ("pallas_fused", "mma_jnp", "xla")


@dataclasses.dataclass(frozen=True)
class Request:
    """One serving request. ``deadline_s`` is ABSOLUTE on the runtime's
    clock (``None`` = no deadline); the CLI converts relative timeouts."""

    rid: int
    prompt: object  # token array (np.ndarray); opaque to the runtime
    max_new: int
    deadline_s: Optional[float] = None


@dataclasses.dataclass(frozen=True)
class Completion:
    rid: int
    tokens: tuple

    @property
    def ok(self) -> bool:
        return True


@dataclasses.dataclass(frozen=True)
class RequestRejected:
    """Refused before (admission/feasibility/validation) or during
    (persistently poisoned slot) service; ``reason`` says which."""

    rid: int
    reason: str
    tokens: tuple = ()

    @property
    def ok(self) -> bool:
        return False


@dataclasses.dataclass(frozen=True)
class DeadlineExceeded:
    """Ran out of deadline; ``tokens`` carries whatever was decoded in
    time (empty if shed while still queued)."""

    rid: int
    tokens: tuple = ()

    @property
    def ok(self) -> bool:
        return False


class AdmissionQueue:
    """Bounded FIFO with shed-oldest-past-deadline-first load shedding.

    ``submit`` returns ``(admitted, shed)``: when the queue is full it
    first sheds queued requests already past their deadline (oldest
    first) to make room -- they are the cheapest loss, the new arrival
    still has its whole deadline ahead. Only if nobody is sheddable is
    the new request itself refused."""

    def __init__(self, capacity: int):
        if capacity < 1:
            raise ValueError(f"capacity must be >= 1; got {capacity}")
        self.capacity = int(capacity)
        self._q: list = []

    def __len__(self) -> int:
        return len(self._q)

    def submit(self, req: Request, now: float):
        shed = []
        while len(self._q) >= self.capacity:
            victim_i = next(
                (
                    i
                    for i, r in enumerate(self._q)
                    if r.deadline_s is not None and now > r.deadline_s
                ),
                None,
            )
            if victim_i is None:
                return False, shed
            shed.append(self._q.pop(victim_i))
        self._q.append(req)
        return True, shed

    def pop(self, n: int, now: float):
        """Up to ``n`` requests for the next wave, dropping (and returning
        as ``expired``) queued requests already past deadline: they would
        only waste slots. -> (wave, expired)."""
        wave, expired = [], []
        while self._q and len(wave) < n:
            r = self._q.pop(0)
            if r.deadline_s is not None and now > r.deadline_s:
                expired.append(r)
            else:
                wave.append(r)
        return wave, expired


class CircuitBreaker:
    """Per-backend closed -> open -> half-open breaker over a degradation
    chain.

    ``backend()`` returns the first usable backend in ``chain``: a CLOSED
    one, or an OPEN one whose bounded-exponential cooldown has elapsed
    (it turns HALF_OPEN and gets probe traffic). ``fail_threshold``
    consecutive ``record_failure`` calls trip a backend OPEN (the
    ``on_trip`` hook fires -- the runtime wires it to
    ``reduce.quarantine_backend`` so stale auto plans cannot resurrect
    it); a half-open probe failing re-opens with the cooldown doubled (up
    to ``cooldown_cap_s``); ``probe_successes`` clean probes close it
    (``on_close`` -> ``reinstate_backend``). The chain's LAST backend is
    never refused -- something must serve."""

    CLOSED, OPEN, HALF_OPEN = "closed", "open", "half_open"

    def __init__(
        self,
        chain: Sequence[str] = DEFAULT_BACKEND_CHAIN,
        *,
        fail_threshold: int = 3,
        cooldown_s: float = 0.5,
        cooldown_cap_s: float = 30.0,
        probe_successes: int = 2,
        clock: Callable[[], float] = time.monotonic,
        on_trip: Optional[Callable[[str], None]] = None,
        on_close: Optional[Callable[[str], None]] = None,
    ):
        if not chain:
            raise ValueError("backend chain must be non-empty")
        if fail_threshold < 1:
            raise ValueError(f"fail_threshold must be >= 1; got {fail_threshold}")
        self.chain = tuple(chain)
        self.fail_threshold = int(fail_threshold)
        self.cooldown_s = float(cooldown_s)
        self.cooldown_cap_s = float(cooldown_cap_s)
        self.probe_successes = int(probe_successes)
        self._clock = clock
        self._on_trip = on_trip
        self._on_close = on_close
        self.total_trips = 0
        self._st = {
            name: {
                "state": self.CLOSED,
                "fails": 0,
                "opened_at": 0.0,
                "cooldown": self.cooldown_s,
                "probes": 0,
            }
            for name in self.chain
        }

    def backend(self) -> str:
        now = self._clock()
        for name in self.chain[:-1]:
            st = self._st[name]
            if st["state"] == self.CLOSED:
                return name
            if st["state"] == self.OPEN:
                if now - st["opened_at"] >= st["cooldown"]:
                    st["state"] = self.HALF_OPEN
                    st["probes"] = 0
                    return name
                continue
            return name  # HALF_OPEN keeps probing until verdict
        return self.chain[-1]

    def _trip(self, name: str, st: dict) -> None:
        st["state"] = self.OPEN
        st["opened_at"] = self._clock()
        st["fails"] = 0
        st["probes"] = 0
        self.total_trips += 1
        if self._on_trip is not None:
            self._on_trip(name)

    def record_failure(self, name: str) -> None:
        st = self._st.get(name)
        if st is None:
            return
        if st["state"] == self.HALF_OPEN:
            # failed probe: back to OPEN, cooldown doubled (bounded)
            st["cooldown"] = min(st["cooldown"] * 2.0, self.cooldown_cap_s)
            self._trip(name, st)
            return
        if st["state"] == self.CLOSED:
            st["fails"] += 1
            if st["fails"] >= self.fail_threshold:
                st["cooldown"] = self.cooldown_s
                self._trip(name, st)

    def record_success(self, name: str) -> None:
        st = self._st.get(name)
        if st is None:
            return
        if st["state"] == self.HALF_OPEN:
            st["probes"] += 1
            if st["probes"] >= self.probe_successes:
                st["state"] = self.CLOSED
                st["fails"] = 0
                st["cooldown"] = self.cooldown_s
                if self._on_close is not None:
                    self._on_close(name)
        elif st["state"] == self.CLOSED:
            st["fails"] = 0

    def state(self, name: str) -> str:
        return self._st[name]["state"]

    def states(self) -> dict:
        return {name: st["state"] for name, st in self._st.items()}


def _planner_trip(name: str) -> None:
    from repro import reduce as R

    R.quarantine_backend(name)


def _planner_close(name: str) -> None:
    from repro import reduce as R

    R.reinstate_backend(name)


def guarded_logit_stat(logits, *, backend: Optional[str] = None):
    """Per-slot logit sumsq + in-launch non-finite census, ONE launch.

    ``logits``: (B, ...) -- slot-major decode logits. Each slot enters the
    parts kernel as its own leaf, so the return is ``(stat, counts)``:
    per-slot sum-of-squares (B,) and per-slot NaN/Inf counts with the
    cross-slot total appended (B + 1,). On the Pallas backends this is one
    ``pallas_call`` reading exactly the logits bytes the statistic alone
    would read (the census rides the second in-kernel accumulator --
    ``check_bench.check_serve_guard`` gates both properties); the census
    tells the runtime WHICH slot to quarantine, not just that something is
    wrong. ``backend=None`` lets the planner choose (breaker-quarantined
    backends excluded); the breaker passes its selection explicitly."""
    from repro import reduce as R

    b = logits.shape[0]
    leaves = [logits[i] for i in range(b)]
    stat, _totals, counts = R.reduce_tree(
        leaves,
        "sumsq",
        backend=backend,
        return_per_leaf=True,
        census=True,
    )
    return stat, counts


class ServingRuntime:
    """The guarded serving loop over any protocol-conforming engine.

    ``serve(requests)`` admits through the bounded queue, packs waves of
    ``engine.slots``, and for every step: checks deadlines, applies the
    chaos schedule (per-request, fire-once), runs the engine step on the
    breaker's backend, and commits state ONLY if the step's census is
    clean for every live slot -- otherwise the poisoned slots are
    quarantined for the step and the step retried from the committed
    state (``max_step_retries`` bounds it; slots still poisoned on the
    final attempt fail as ``RequestRejected('poisoned')`` while the rest
    of the batch proceeds). ``TransientFault`` retries charge the
    breaker; ``Preemption`` retries are free. All timing flows through
    the injectable ``clock`` so every schedule is testable without
    wall-clock waits."""

    def __init__(
        self,
        engine,
        *,
        queue_capacity: int = 64,
        breaker: Optional[CircuitBreaker] = None,
        chaos: Optional[ChaosMonkey] = None,
        metrics: Optional[ServeMetrics] = None,
        clock: Callable[[], float] = time.monotonic,
        max_step_retries: int = 4,
        status_path=None,
        quarantine_planner: bool = True,
    ):
        self.engine = engine
        self.queue = AdmissionQueue(queue_capacity)
        if breaker is None:
            breaker = CircuitBreaker(
                clock=clock,
                on_trip=_planner_trip if quarantine_planner else None,
                on_close=_planner_close if quarantine_planner else None,
            )
        self.breaker = breaker
        self.chaos = chaos
        self.metrics = metrics if metrics is not None else ServeMetrics()
        self.clock = clock
        self.max_step_retries = int(max_step_retries)
        self.status_path = status_path
        # EWMA of one decode step's wall time; None until the first wave
        # has been measured (feasibility refusals need real evidence).
        self._step_ewma: Optional[float] = None
        self._results: dict = {}

    # -- admission ---------------------------------------------------------

    def _estimate_serve_s(self, req: Request) -> Optional[float]:
        if self._step_ewma is None:
            return None
        # queued waves ahead of this request, plus its own wave's steps
        waves_ahead = math.ceil((len(self.queue) + 1) / self.engine.slots)
        return self._step_ewma * req.max_new * waves_ahead

    def submit(self, req: Request) -> bool:
        """Admit ``req`` or record a structured refusal. Returns True iff
        admitted (the result then arrives via ``serve``'s drain)."""
        now = self.clock()
        err = None
        validate = getattr(self.engine, "validate", None)
        if validate is not None:
            err = validate(req.prompt, req.max_new)
        if err:
            self._results[req.rid] = RequestRejected(req.rid, err)
            self.metrics.record_shed(infeasible=True)
            return False
        if req.deadline_s is not None:
            est = self._estimate_serve_s(req)
            if now > req.deadline_s or (
                est is not None and now + est > req.deadline_s
            ):
                self._results[req.rid] = RequestRejected(
                    req.rid,
                    "infeasible: deadline cannot be met "
                    f"(estimated {est if est is not None else 0.0:.4f}s)",
                )
                self.metrics.record_shed(infeasible=True)
                return False
        admitted, shed = self.queue.submit(req, now)
        for victim in shed:
            self._results[victim.rid] = DeadlineExceeded(victim.rid)
            self.metrics.record_deadline_miss()
        if not admitted:
            self._results[req.rid] = RequestRejected(
                req.rid, f"queue full (capacity {self.queue.capacity})"
            )
            self.metrics.record_shed()
            return False
        self.metrics.record_admit()
        return True

    # -- the guarded step --------------------------------------------------

    def _chaos_precheck(self, rids) -> None:
        if self.chaos is None:
            return
        for rid in rids:
            self.chaos.on_request(rid)

    def _scales(self, wave) -> list:
        scales = []
        for slot in wave:
            if slot is None or self.chaos is None:
                scales.append(1.0)
            else:
                scales.append(self.chaos.scale_for(slot.rid))
        return scales

    def _guarded_call(self, wave, live, call):
        """Run one engine step until its census is clean for every live
        slot (or retries run out). ``call(scales, backend)`` issues the
        step from the COMMITTED state. Returns (state, tokens, poisoned):
        ``poisoned`` is the set of slot indices still non-finite on the
        final attempt (their state never commits -- they are dead)."""
        last_poisoned: set = set()
        for attempt in range(self.max_step_retries + 1):
            backend = self.breaker.backend()
            try:
                self._chaos_precheck(
                    wave[i].rid for i in sorted(live)
                )
                scales = self._scales(
                    [wave[i] if i in live else None for i in range(len(wave))]
                )
                state, tokens, census = call(scales, backend)
            except Preemption:
                self.metrics.record_retry()
                continue
            except TransientFault:
                self.breaker.record_failure(backend)
                self.metrics.record_retry()
                continue
            poisoned = {
                i for i in live if float(census[i]) > 0.0
            }
            if not poisoned:
                self.breaker.record_success(backend)
                return state, tokens, set()
            self.metrics.record_quarantine(len(poisoned))
            self.metrics.record_retry()
            last_poisoned = poisoned
            if attempt == self.max_step_retries:
                return state, tokens, poisoned
        # every attempt raised: surface the persistent fault
        raise TransientFault(
            f"step failed after {self.max_step_retries + 1} attempts "
            f"(breaker states: {self.breaker.states()})"
        )

    # -- the wave loop -----------------------------------------------------

    def _finish(self, req: Request, tokens: list) -> None:
        self._results[req.rid] = Completion(req.rid, tuple(tokens))
        self.metrics.record_completed(len(tokens))

    def _run_wave(self, wave_reqs) -> None:
        slots = self.engine.slots
        wave = list(wave_reqs) + [None] * (slots - len(wave_reqs))
        live = {i for i, r in enumerate(wave) if r is not None}
        toks: dict = {i: [] for i in live}
        max_new = max(r.max_new for r in wave_reqs)

        def expire(now: float) -> None:
            for i in sorted(live):
                r = wave[i]
                if r.deadline_s is not None and now > r.deadline_s:
                    self._results[r.rid] = DeadlineExceeded(
                        r.rid, tuple(toks[i])
                    )
                    self.metrics.record_deadline_miss()
                    live.discard(i)

        def kill_poisoned(poisoned) -> None:
            for i in sorted(poisoned):
                r = wave[i]
                self._results[r.rid] = RequestRejected(
                    r.rid,
                    "poisoned: non-finite logits persisted across "
                    f"{self.max_step_retries + 1} attempts",
                    tuple(toks[i]),
                )
                self.metrics.record_poisoned()
                live.discard(i)

        prompts = [r.prompt if r is not None else None for r in wave]
        t0 = self.clock()
        expire(t0)
        if not live:
            return
        state, tokens, poisoned = self._guarded_call(
            wave, live, lambda scales, backend: self.engine.start_wave(
                prompts, scales, backend
            )
        )
        self._record_step_time(self.clock() - t0)
        kill_poisoned(poisoned)
        for i in live:
            if len(toks[i]) < wave[i].max_new:
                toks[i].append(int(tokens[i]))
        for t in range(1, max_new):
            done = {i for i in live if len(toks[i]) >= wave[i].max_new}
            for i in sorted(done):
                self._finish(wave[i], toks[i])
                live.discard(i)
            expire(self.clock())
            if not live:
                break
            t1 = self.clock()
            new_state, tokens, poisoned = self._guarded_call(
                wave, live, lambda scales, backend: self.engine.decode(
                    state, scales, backend
                )
            )
            self._record_step_time(self.clock() - t1)
            state = new_state
            kill_poisoned(poisoned)
            for i in live:
                toks[i].append(int(tokens[i]))
        for i in sorted(live):
            self._finish(wave[i], toks[i])

    def _record_step_time(self, dt: float) -> None:
        self.metrics.record_token_latency(dt)
        if self._step_ewma is None:
            self._step_ewma = dt
        else:
            self._step_ewma = 0.8 * self._step_ewma + 0.2 * dt

    def _export(self) -> None:
        self.metrics.breaker_trips = self.breaker.total_trips
        self.metrics.record_breaker_states(self.breaker.states())
        if self.status_path is not None:
            self.metrics.write(self.status_path)

    def serve(self, requests: Sequence[Request]):
        """Admit + drain: returns one structured result PER REQUEST, in
        request order -- ``Completion`` | ``RequestRejected`` |
        ``DeadlineExceeded``. Never raises on a bad request; the engine
        erroring persistently (every backend, every retry) does raise
        ``TransientFault`` -- at that point nothing can serve."""
        for req in requests:
            self.submit(req)
        self.drain()
        return [self._results[r.rid] for r in requests]

    def drain(self) -> None:
        """Run queued waves to completion, exporting status every wave."""
        while len(self.queue):
            wave, expired = self.queue.pop(self.engine.slots, self.clock())
            for r in expired:
                self._results[r.rid] = DeadlineExceeded(r.rid)
                self.metrics.record_deadline_miss()
            if wave:
                self._run_wave(wave)
            self._export()
        self._export()
