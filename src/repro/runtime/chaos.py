"""Deterministic fault injection + retry/rollback policy for the guarded
training loop.

Production training dies in three characteristic ways, and each has a
distinct correct response that this module makes testable on a laptop:

  non-finite gradients  -- a NaN/Inf element poisons the norm, the update,
                           and every checkpoint after it. Detection lives
                           in the kernel (the reduction launch's non-finite
                           census, ``reduce_tree(census=True)``); response
                           is ``optim.guarded_apply_updates``'s bitwise
                           skip. ``ChaosMonkey.corrupt`` injects the NaN.
  transient exceptions  -- a flaky interconnect collective, a preempted
                           DMA: the step RAISES but the state is intact.
                           Response is bounded-backoff retry
                           (``StepGuard.retry``). ``ChaosMonkey.on_step``
                           raises the ``TransientFault``.
  persistent badness    -- K consecutive skipped/bad steps means the state
                           or the data is already poisoned; response is
                           rollback to the last COMMITTED checkpoint with
                           data-pipeline rewind (``TrainSupervisor`` +
                           ``StepGuard.should_rollback``).

Injection is deterministic and FIRE-ONCE: each configured step fires at
most one fault, so a post-rollback REPLAY of the same step sees clean
inputs and the recovery path is itself testable (exactly the semantics of
a real transient: the cosmic ray does not strike twice on replay).
Everything here is plain Python -- no jax at module import -- so the
supervisor loop stays usable with non-jax step functions; ``corrupt``
imports jax lazily when it actually has to poke an array.
"""

from __future__ import annotations

import time
from typing import Callable, Sequence


class TransientFault(RuntimeError):
    """An injected (or real) recoverable step failure: state is intact,
    retrying the step is the correct response."""


class Preemption(TransientFault):
    """A slot/step preemption (duty-cycled capacity, a descheduled core):
    retry like any transient, but do NOT charge the backend's circuit
    breaker -- the kernel did nothing wrong."""


class ChaosMonkey:
    """Deterministic fault injector for supervisor/guard tests.

    nan_steps / inf_steps: step numbers whose gradients get one element
      corrupted (leaf ``leaf`` in flatten order, flat element 0) with
      NaN / Inf respectively -- apply via ``corrupt(grads, step)`` between
      the grad computation and the optimizer update (or corrupt the batch
      and let the loss go non-finite; element-level grad corruption is the
      sharper test of the census).
    fail_steps: step numbers where ``on_step(step)`` raises
      ``TransientFault`` -- wrap the step call in ``StepGuard.retry``.
    preempt_at: step number where ``on_step`` triggers ``guard.trigger()``
      (simulated SIGTERM) when a ``PreemptionGuard`` is passed.
    host: this injector's host id (default 0). Multi-host chaos builds one
      ``ChaosMonkey(host=h)`` per host from the SAME step lists; per-host
      targeting happens in ``corrupt_shard`` (only the targeted host's
      shard gets poisoned) while ``corrupt``/``on_step`` fire identically
      everywhere -- the distributed-lockstep tests need both shapes.

    Every configured (kind, step) fires AT MOST ONCE (``fired``), so
    retries and post-rollback replays of the same step run clean. ``calls``
    counts every ``on_step`` for assertions on retry schedules.
    """

    def __init__(
        self,
        *,
        nan_steps: Sequence[int] = (),
        inf_steps: Sequence[int] = (),
        fail_steps: Sequence[int] = (),
        preempt_steps: Sequence[int] = (),
        preempt_at: int | None = None,
        leaf: int = 0,
        host: int = 0,
    ):
        self.nan_steps = frozenset(int(s) for s in nan_steps)
        self.inf_steps = frozenset(int(s) for s in inf_steps)
        self.fail_steps = frozenset(int(s) for s in fail_steps)
        self.preempt_steps = frozenset(int(s) for s in preempt_steps)
        self.preempt_at = preempt_at
        self.leaf = int(leaf)
        self.host = int(host)
        self.fired: set = set()
        self.calls = 0

    @classmethod
    def from_seed(
        cls,
        seed: int,
        *,
        n_steps: int,
        nan_rate: float = 0.0,
        inf_rate: float = 0.0,
        fail_rate: float = 0.0,
        preempt_rate: float = 0.0,
        leaf: int = 0,
        host: int = 0,
    ) -> "ChaosMonkey":
        """Deterministic random schedule: the same (seed, n_steps, rates)
        yields the same injector on every host and every rerun -- chaos
        that reproduces. Step 0 is never selected (the supervisor's anchor
        commit must stay clean so rollback always has a target). The step
        numbers double as SERVING request ids (``scale_for`` /
        ``on_request``): the same schedule then reads "request 3 decodes a
        NaN logit once, request 7's launch faults once"."""
        import random

        rng = random.Random(int(seed))
        nan_steps, inf_steps, fail_steps, preempt_steps = [], [], [], []
        for step in range(1, int(n_steps)):
            r = rng.random()
            if r < nan_rate:
                nan_steps.append(step)
            elif r < nan_rate + inf_rate:
                inf_steps.append(step)
            elif r < nan_rate + inf_rate + fail_rate:
                fail_steps.append(step)
            elif r < nan_rate + inf_rate + fail_rate + preempt_rate:
                preempt_steps.append(step)
        return cls(
            nan_steps=nan_steps, inf_steps=inf_steps, fail_steps=fail_steps,
            preempt_steps=preempt_steps, leaf=leaf, host=host,
        )

    def _fire(self, kind: str, step: int) -> bool:
        key = (kind, int(step))
        if key in self.fired:
            return False
        self.fired.add(key)
        return True

    def corrupt(self, grads, step: int):
        """Return ``grads`` with one element poisoned iff ``step`` is a
        configured (unfired) nan/inf step; otherwise ``grads`` unchanged.
        """
        kind = None
        if step in self.nan_steps and self._fire("nan", step):
            kind = "nan"
        elif step in self.inf_steps and self._fire("inf", step):
            kind = "inf"
        if kind is None:
            return grads
        import jax
        import jax.numpy as jnp

        leaves, treedef = jax.tree_util.tree_flatten(grads)
        i = self.leaf % len(leaves)
        flat = jnp.ravel(leaves[i]).at[0].set(
            jnp.nan if kind == "nan" else jnp.inf
        )
        leaves[i] = flat.reshape(jnp.shape(leaves[i]))
        return jax.tree_util.tree_unflatten(treedef, leaves)

    def corrupt_shard(self, x, step: int, *, shards: int):
        """Per-host corruption of a GLOBAL array that will be sharded over
        ``shards`` equal pieces along a flattened view: poisons flat element
        0 of shard ``self.host`` only, iff ``step`` is a configured
        (unfired) nan/inf step. Run on the global array BEFORE shard_map
        splits it, this models exactly one host's shard going bad while
        every other host's local data stays clean -- the scenario where
        only a cross-device census (not any local check) can make all
        hosts skip in lockstep."""
        kind = None
        if step in self.nan_steps and self._fire("nan", step):
            kind = "nan"
        elif step in self.inf_steps and self._fire("inf", step):
            kind = "inf"
        if kind is None:
            return x
        import jax.numpy as jnp

        if jnp.size(x) % shards:
            raise ValueError(
                f"array of size {jnp.size(x)} does not split into "
                f"{shards} equal shards"
            )
        flat = jnp.ravel(x).reshape(shards, -1)
        flat = flat.at[self.host % shards, 0].set(
            jnp.nan if kind == "nan" else jnp.inf
        )
        return flat.reshape(-1).reshape(jnp.shape(x))

    def on_step(self, step: int, guard=None) -> None:
        """Call at the top of each step attempt: raises ``TransientFault``
        on a configured (unfired) fail step; trips ``guard`` at
        ``preempt_at``."""
        self.calls += 1
        if (
            guard is not None
            and self.preempt_at is not None
            and step >= self.preempt_at
            and self._fire("preempt", self.preempt_at)
        ):
            guard.trigger()
        if step in self.fail_steps and self._fire("fail", step):
            raise TransientFault(f"injected transient failure at step {step}")

    # -- per-request serving hooks (same schedule, keyed by request id) --

    def scale_for(self, request_id: int) -> float:
        """Chaos multiplier for one request's decode step: NaN / Inf iff
        ``request_id`` is a configured (unfired) nan/inf id, else 1.0.
        The serving engine multiplies the slot's logits by it -- x1.0 is
        bitwise identity, so a clean request's tokens are untouched and a
        poisoned slot's retry (fire-once) reproduces the clean run."""
        rid = int(request_id)
        if rid in self.nan_steps and self._fire("nan", rid):
            return float("nan")
        if rid in self.inf_steps and self._fire("inf", rid):
            return float("inf")
        return 1.0

    def on_request(self, request_id: int) -> None:
        """Call once per decode attempt per active request: raises
        ``Preemption`` on a configured (unfired) preempt id (retry, no
        breaker charge) and ``TransientFault`` on a fail id (retry AND
        charge the backend's breaker)."""
        rid = int(request_id)
        self.calls += 1
        if rid in self.preempt_steps and self._fire("preempt", rid):
            raise Preemption(f"injected preemption for request {rid}")
        if rid in self.fail_steps and self._fire("fail", rid):
            raise TransientFault(
                f"injected transient kernel fault for request {rid}"
            )


class StepGuard:
    """Consecutive-bad-step counter + bounded-backoff retry policy.

    The supervisor feeds it: ``retry(fn, ...)`` wraps each step attempt
    (``TransientFault`` -> sleep ``backoff_s * 2^attempt`` capped at
    ``backoff_cap_s``, up to ``max_retries`` retries, then re-raise);
    ``record(skipped)`` tracks the guarded optimizer's skip flag; after
    ``max_bad_steps`` CONSECUTIVE skips ``should_rollback()`` turns true
    and the supervisor restores the last committed checkpoint (then calls
    ``reset()``). ``sleep`` is injectable so tests assert the schedule
    without wall-clock waits."""

    def __init__(
        self,
        max_bad_steps: int = 3,
        *,
        max_retries: int = 3,
        backoff_s: float = 0.05,
        backoff_cap_s: float = 2.0,
        sleep: Callable[[float], None] = time.sleep,
    ):
        if max_bad_steps < 1:
            raise ValueError(f"max_bad_steps must be >= 1; got {max_bad_steps}")
        self.max_bad_steps = int(max_bad_steps)
        self.max_retries = int(max_retries)
        self.backoff_s = float(backoff_s)
        self.backoff_cap_s = float(backoff_cap_s)
        self._sleep = sleep
        self.consecutive_bad = 0
        self.transient_failures = 0
        self.rollbacks = 0

    def retry(self, fn: Callable, *args, **kwargs):
        """Run ``fn(*args, **kwargs)``, retrying ``TransientFault`` with
        bounded exponential backoff; any other exception propagates
        immediately (a poisoned step is NOT transient -- it must reach the
        skip/rollback machinery, not be retried)."""
        delay = self.backoff_s
        for attempt in range(self.max_retries + 1):
            try:
                return fn(*args, **kwargs)
            except TransientFault:
                self.transient_failures += 1
                if attempt == self.max_retries:
                    raise
                self._sleep(delay)
                delay = min(delay * 2.0, self.backoff_cap_s)

    def record(self, skipped: bool) -> None:
        self.consecutive_bad = self.consecutive_bad + 1 if skipped else 0

    def should_rollback(self) -> bool:
        return self.consecutive_bad >= self.max_bad_steps

    def reset(self) -> None:
        self.consecutive_bad = 0
