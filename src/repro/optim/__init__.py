from repro.optim.adamw import (  # noqa: F401
    ADAM_EPS,
    GNORM_EPS,
    AdamWState,
    apply_updates,
    cosine_lr,
    global_norm,
    global_norm_and_clip,
    init_state,
)
