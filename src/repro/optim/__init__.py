from repro.optim.adamw import (  # noqa: F401
    AdamWState,
    apply_updates,
    cosine_lr,
    global_norm,
    init_state,
)
