from repro.optim.adamw import (  # noqa: F401
    ADAM_EPS,
    GNORM_EPS,
    AdamWState,
    GuardState,
    apply_updates,
    cosine_lr,
    global_norm,
    global_norm_and_clip,
    guarded_apply_updates,
    init_guard_state,
    init_state,
)
