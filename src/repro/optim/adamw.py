"""AdamW with decoupled weight decay, cosine schedule and MMA global-norm
clipping. Hand-rolled (no optax dependency); state is a pytree mirroring the
params so the sharding rules apply verbatim (m/v inherit the param sharding
-- ZeRO-style partitioned optimizer state for free under FSDP).

The gradient-clipping statistic -- the largest full reduction in a training
step -- routes through the unified reduction engine. On the Pallas backends
the whole-pytree norm is SINGLE-STREAM: every raw grad leaf (bf16 included)
enters one parts-kernel launch as its own zero-copy operand and is squared
IN-KERNEL (the square prologue), and the norm's sqrt AND the clip
coefficient's min/max/div finish inside the same launch as an EPILOGUE fork
(``reduce_tree(kind="norm2", epilogue=[(), ("clip_coeff", ...)])`` ->
``(gnorm, clip)`` from one pallas_call, zero host-side scalar eqns --
``inspect.assert_epilogue_free`` gates exactly this in
benchmarks/check_bench.py). The jnp-level backends keep the sharding-safe
per-leaf row-partial route with the same chain applied host-side.

``fused_second_moment`` (olmax-style) keeps ONE SCALAR second-moment EMA
per leaf instead of a full elementwise ``v`` tensor: the per-leaf sumsq
slots of the SAME norm launch feed ``nu <- b2 nu + (1-b2) E[g^2]``, and the
update multiplies by the scalar reciprocal ``1/(sqrt(nuhat)+eps)`` -- so a
grad leaf makes ONE HBM trip per step (norm+stats+update) instead of
three, and the n-sized sqrt/divide of the elementwise path disappears.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro import reduce as R
from repro.configs.base import TrainConfig

# Gradient-norm floor for the clip coefficient: clip = min(1, c/max(g, EPS)).
# A Python float stays WEAK-TYPED: it folds into the epilogue chain's kernel
# constants and, host-side, binds to gnorm's dtype instead of materializing
# an f32 literal that would upcast the statistic under a bf16 policy (the
# old inline ``jnp.maximum(gnorm, 1e-9)`` pitfall).
GNORM_EPS = 1e-9

# Adam denominator fuzz (the standard 1e-8); same weak-typing rationale.
ADAM_EPS = 1e-8


@dataclasses.dataclass(frozen=True)
class AdamWState:
    step: Any
    m: Any
    v: Any


jax.tree_util.register_dataclass(
    AdamWState, data_fields=["step", "m", "v"], meta_fields=[]
)


def init_state(params, *, fused_second_moment: bool = False) -> AdamWState:
    """Optimizer state. ``fused_second_moment=True`` replaces each leaf's
    elementwise ``v`` tensor with ONE f32 scalar (the olmax-style E[g^2]
    EMA fed by the norm launch's per-leaf sumsq slots) -- the state
    shrinks by ~half and the update loses its n-sized sqrt/divide."""
    zeros = lambda p: jnp.zeros_like(p, dtype=jnp.float32)
    second = (
        (lambda p: jnp.zeros((), jnp.float32)) if fused_second_moment
        else zeros
    )
    return AdamWState(
        step=jnp.zeros((), jnp.int32),
        m=jax.tree.map(zeros, params),
        v=jax.tree.map(second, params),
    )


def cosine_lr(cfg: TrainConfig, step):
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip(
        (step - cfg.warmup_steps) / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
        0.0,
        1.0,
    )
    return cfg.learning_rate * warm * 0.5 * (1 + jnp.cos(jnp.pi * prog))


def global_norm(
    grads,
    *,
    mma: bool = True,
    backend: Optional[str] = None,
    num_cores: Optional[int] = None,
    mesh_axes=None,
):
    """L2 norm over the gradient pytree via the reduction engine. ``backend``
    overrides the legacy ``mma`` flag when given; on the Pallas backends the
    leaves stream zero-copy through the in-kernel square prologue (one
    launch, one read per gradient byte). ``num_cores`` stripes the kernel
    lanes (planner default when None). ``mesh_axes`` (inside a shard_map
    body) makes the norm GLOBAL over the sharded tree via the deterministic
    fixed-order combine -- bit-identical on every replica."""
    if backend is None:
        backend = R.backend_for_flags(mma)
    return R.reduce_tree(grads, kind="norm2", backend=backend,
                         num_cores=num_cores, mesh_axes=mesh_axes)


def global_norm_and_clip(
    grads,
    max_norm,
    *,
    mma: bool = True,
    backend: Optional[str] = None,
    num_cores: Optional[int] = None,
    return_per_leaf: bool = False,
    census: bool = False,
    mesh_axes=None,
):
    """``(gnorm, clip)`` from ONE reduction launch: the epilogue fork
    finishes both the norm's sqrt and ``clip = min(1, max_norm /
    max(gnorm, GNORM_EPS))`` inside the launch that reduced the leaves
    (kernel backends -- zero host-side sqrt/min/div eqns; jnp backends
    apply the identical chain host-side). ``return_per_leaf=True``
    additionally returns the raw per-leaf sumsq slots first, from the same
    single launch -- the fused second-moment feed. ``census=True`` appends
    the (S + 1,) non-finite counts vector (per-leaf counts then their
    total), counted by the SAME launch on the tiles it already streams --
    the guarded step's NaN/Inf detector at zero extra input bytes.
    ``mesh_axes`` (inside a shard_map body, over SHARDED grads) makes norm,
    clip, per-leaf slots AND census global across the mesh through the
    deterministic fixed-order combine: every replica sees the identical
    bits, so a skip decision keyed off any of them is provably in
    lockstep."""
    if backend is None:
        backend = R.backend_for_flags(mma)
    fork = [(), ("clip_coeff", float(max_norm), GNORM_EPS)]
    out = R.reduce_tree(
        grads, kind="norm2", backend=backend, num_cores=num_cores,
        epilogue=fork, return_per_leaf=return_per_leaf, census=census,
        mesh_axes=mesh_axes,
    )
    if return_per_leaf:
        if census:
            per_leaf, fork_out, counts = out
            return per_leaf, fork_out[0], fork_out[1], counts
        per_leaf, fork_out = out
        return per_leaf, fork_out[0], fork_out[1]
    if census:
        fork_out, counts = out
        return fork_out[0], fork_out[1], counts
    return out[0], out[1]


def _adamw_core(
    params,
    grads,
    state: AdamWState,
    cfg: TrainConfig,
    *,
    clip,
    per_leaf=None,
    fused_second_moment: bool = False,
):
    """The AdamW update arithmetic given an already-computed clip
    coefficient (and, for the fused second moment, the per-leaf sumsq
    slots): returns ``(new_params, new_state, lr)``. Split out so
    ``apply_updates`` and ``guarded_apply_updates`` share one code path --
    an unskipped guarded step is BITWISE the unguarded step."""
    step = state.step + 1
    lr = cosine_lr(cfg, step)
    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1 - b1**step.astype(jnp.float32)
    bc2 = 1 - b2**step.astype(jnp.float32)

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state.m)
    flat_v = treedef.flatten_up_to(state.v)

    if fused_second_moment:

        def upd(p, g, m, nu, sumsq):
            n = max(int(g.size), 1)
            # scalar EMA of E[(clip g)^2]; all moment math is size-1
            nu_new = b2 * nu + (1 - b2) * (clip * clip) * (sumsq / n)
            rcp = 1.0 / (jnp.sqrt(nu_new / bc2) + ADAM_EPS)  # scalar
            gf = g.astype(jnp.float32) * clip
            m_new = b1 * m + (1 - b1) * gf
            # n-sized ops: multiplies and adds only (the scalar coefficient
            # carries the sqrt/divide) -- no elementwise sqrt/div pass
            pf = p.astype(jnp.float32)
            new_p = pf - (lr * rcp / bc1) * m_new - (lr * cfg.weight_decay) * pf
            return new_p.astype(p.dtype), m_new, nu_new

        out = [
            upd(p, g, m, nu, per_leaf[i])
            for i, (p, g, m, nu) in enumerate(
                zip(flat_p, flat_g, flat_m, flat_v)
            )
        ]
    else:

        def upd(p, g, m, v):
            gf = g.astype(jnp.float32) * clip
            m_new = b1 * m + (1 - b1) * gf
            v_new = b2 * v + (1 - b2) * gf * gf
            mhat = m_new / bc1
            vhat = v_new / bc2
            delta = mhat / (jnp.sqrt(vhat) + ADAM_EPS) + cfg.weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m_new, v_new

        out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    return new_p, AdamWState(step=step, m=new_m, v=new_v), lr


def apply_updates(
    params,
    grads,
    state: AdamWState,
    cfg: TrainConfig,
    *,
    mma: bool = True,
    reduce_backend: Optional[str] = None,
    fused_second_moment: bool = False,
    mesh_axes=None,
):
    """One AdamW step. Returns (new_params, new_state, metrics).

    ``fused_second_moment`` must match the ``init_state`` that built
    ``state`` (scalar-v leaves). On the kernel backends one reduction
    launch feeds everything the step needs from the grads: the per-leaf
    sumsq slots (fused second moment) plus the (gnorm, clip) epilogue
    fork -- a grad leaf makes ONE HBM trip per step."""
    if fused_second_moment:
        per_leaf, gnorm, clip = global_norm_and_clip(
            grads, cfg.grad_clip, mma=mma, backend=reduce_backend,
            return_per_leaf=True, mesh_axes=mesh_axes,
        )
    else:
        per_leaf = None
        gnorm, clip = global_norm_and_clip(
            grads, cfg.grad_clip, mma=mma, backend=reduce_backend,
            mesh_axes=mesh_axes,
        )
    new_p, new_state, lr = _adamw_core(
        params, grads, state, cfg, clip=clip, per_leaf=per_leaf,
        fused_second_moment=fused_second_moment,
    )
    metrics = {"grad_norm": gnorm, "lr": lr, "clip": clip}
    return new_p, new_state, metrics


# Unsigned views for the bitwise keep/advance blend, by itemsize.
_BLEND_UINT = {1: jnp.uint8, 2: jnp.uint16, 4: jnp.uint32, 8: jnp.uint64}


def _bitwise_keep(keep_old, old, new):
    """Branchless, donation-safe select: ``old`` where ``keep_old`` (a
    traced bool scalar) else ``new`` -- by integer bit-blend, NOT
    ``jnp.where``. ``select_n`` at leaf size is exactly what the guarded
    step's lowering contract forbids (``inspect.CENSUS_PRIMITIVES``); the
    blend lowers to and/or/broadcast on an unsigned view, bitcast back, so
    the kept side is BITWISE identical to its input (NaN payloads, -0.0,
    bf16 bits -- everything survives untouched). The mask is the unsigned
    wraparound ``0 - flag``: all-ones when keeping, all-zeros when
    advancing."""
    old = jnp.asarray(old)
    new = jnp.asarray(new)
    dtype = old.dtype
    itype = _BLEND_UINT[dtype.itemsize]
    mask = jnp.zeros((), itype) - keep_old.astype(itype)
    ob = jax.lax.bitcast_convert_type(old, itype)
    nb = jax.lax.bitcast_convert_type(new, itype)
    return jax.lax.bitcast_convert_type((ob & mask) | (nb & ~mask), dtype)


@dataclasses.dataclass(frozen=True)
class GuardState:
    """Loss-spike detector state: a rolling window of the last W ACCEPTED
    (non-skipped, finite) losses, how many of its slots are valid, and the
    cumulative skipped-step counter. A registered pytree so it jits and
    donates like the optimizer state."""

    window: Any  # (W,) f32 recent accepted losses
    filled: Any  # int32 valid slots (spike detection waits for a full W)
    skipped: Any  # int32 cumulative skipped steps


jax.tree_util.register_dataclass(
    GuardState, data_fields=["window", "filled", "skipped"], meta_fields=[]
)


def init_guard_state(window: int = 16) -> GuardState:
    return GuardState(
        window=jnp.zeros((int(window),), jnp.float32),
        filled=jnp.zeros((), jnp.int32),
        skipped=jnp.zeros((), jnp.int32),
    )


def _sorted_median(v):
    """Median via one sort + static slots -- jnp.median's quantile path can
    lower a select_n, which the guarded lowering contract forbids."""
    s = jnp.sort(v)
    w = v.shape[0]
    return 0.5 * (s[(w - 1) // 2] + s[w // 2])


def _finite_scalar(x):
    """is_finite without the ``is_finite`` primitive: finite iff x - x == 0
    (NaN - NaN = NaN, Inf - Inf = NaN; both compare unequal). Keeps the
    guarded lowering free of the primitives its own audit forbids."""
    return (x - x) == jnp.zeros((), x.dtype)


def _loss_spike(guard: GuardState, loss, spike_z: float):
    """Robust z-score spike test against the accepted-loss window: spike
    iff the window is full, the loss is finite (a NON-finite loss is the
    census/guard's business, not the spike detector's), and
    ``loss - median > spike_z * scale`` with the MAD-based scale
    ``1.4826 * mad + 1e-6 * |median| + 1e-12`` (the relative floor keeps a
    flat window from flagging float noise)."""
    w = guard.window.shape[0]
    med = _sorted_median(guard.window)
    mad = _sorted_median(jnp.abs(guard.window - med))
    scale = 1.4826 * mad + 1e-6 * jnp.abs(med) + 1e-12
    full = guard.filled >= w
    return full & _finite_scalar(loss) & ((loss - med) > spike_z * scale)


def guarded_apply_updates(
    params,
    grads,
    state: AdamWState,
    cfg: TrainConfig,
    *,
    loss=None,
    guard: Optional[GuardState] = None,
    spike_z: float = 6.0,
    mma: bool = True,
    reduce_backend: Optional[str] = None,
    fused_second_moment: bool = False,
    mesh_axes=None,
):
    """One GUARDED AdamW step: the same single-launch statistic as
    ``apply_updates`` plus the in-launch non-finite census, and a
    branchless skip -- if any grad element is NaN/Inf (or the windowed
    loss-spike detector fires) the params AND the optimizer state pass
    through BITWISE unchanged. Returns
    ``(new_params, new_state, new_guard, metrics)``.

    Jit/donation-safe by construction: no ``lax.cond`` (both sides are one
    fused region; the update arithmetic is cheap next to the grad
    computation), no ``select_n`` and no host ``is_finite`` anywhere in
    the lowering (``inspect.assert_census_free`` gates this) -- the census
    count comes out of the reduction launch and the keep/advance choice is
    an integer bit-blend per leaf. An unskipped step is bitwise identical
    to ``apply_updates``; a skipped step's only state change is the guard
    bookkeeping.

    ``loss``/``guard`` feed the spike detector (either None disables it):
    the window records ACCEPTED finite losses only, so one spike cannot
    poison the statistic it is judged against. ``metrics['skipped']`` is
    this step's skip flag (0/1 f32) -- the supervisor's consecutive-bad-
    step counter keys off it; ``metrics['nonfinite']`` the census total.

    ``mesh_axes`` (inside a shard_map body, params/grads/state SHARDED
    along the mesh) runs the guarded step distributed: the statistic,
    census and clip come out of the fixed-order cross-device combine
    bit-identical on every replica, so the skip flag -- and therefore the
    bit-blend, the guard bookkeeping, and a supervisor's rollback counter
    keyed off ``metrics['skipped']`` -- is provably in lockstep on all
    hosts while each device touches only its own shard. The caller's
    ``loss`` must already be replicated (e.g. psum'd/combined by the loss
    computation) for the spike detector to agree.
    """
    if fused_second_moment:
        per_leaf, gnorm, clip, counts = global_norm_and_clip(
            grads, cfg.grad_clip, mma=mma, backend=reduce_backend,
            return_per_leaf=True, census=True, mesh_axes=mesh_axes,
        )
    else:
        per_leaf = None
        gnorm, clip, counts = global_norm_and_clip(
            grads, cfg.grad_clip, mma=mma, backend=reduce_backend,
            census=True, mesh_axes=mesh_axes,
        )
    nonfinite = counts[-1]
    bad = nonfinite > 0
    if loss is not None and guard is not None:
        spike = _loss_spike(guard, jnp.asarray(loss, jnp.float32), spike_z)
    else:
        spike = jnp.zeros((), bool)
    skip = bad | spike

    cand_p, cand_state, lr = _adamw_core(
        params, grads, state, cfg, clip=clip, per_leaf=per_leaf,
        fused_second_moment=fused_second_moment,
    )
    new_p = jax.tree.map(
        lambda old, new: _bitwise_keep(skip, old, new), params, cand_p
    )
    new_state = jax.tree.map(
        lambda old, new: _bitwise_keep(skip, old, new), state, cand_state
    )

    new_guard = guard
    if guard is not None:
        accept = ~skip
        record = (
            accept & _finite_scalar(jnp.asarray(loss, jnp.float32))
            if loss is not None
            else jnp.zeros((), bool)
        )
        if loss is not None:
            rolled = jnp.roll(guard.window, -1).at[-1].set(
                jnp.asarray(loss, jnp.float32)
            )
            window = _bitwise_keep(~record, guard.window, rolled)
        else:
            window = guard.window
        new_guard = GuardState(
            window=window,
            filled=jnp.minimum(
                guard.filled + record.astype(jnp.int32),
                guard.window.shape[0],
            ),
            skipped=guard.skipped + skip.astype(jnp.int32),
        )

    metrics = {
        "grad_norm": gnorm,
        "lr": lr,
        "clip": clip,
        "nonfinite": nonfinite,
        "skipped": skip.astype(jnp.float32),
        "spike": spike.astype(jnp.float32),
    }
    return new_p, new_state, new_guard, metrics
