"""AdamW with decoupled weight decay, cosine schedule and MMA global-norm
clipping. Hand-rolled (no optax dependency); state is a pytree mirroring the
params so the sharding rules apply verbatim (m/v inherit the param sharding
-- ZeRO-style partitioned optimizer state for free under FSDP).

The gradient-clipping statistic -- the largest full reduction in a training
step -- routes through the unified reduction engine
(``repro.reduce.reduce_tree(grads, kind="norm2")``). On the Pallas backends
the whole-pytree norm is SINGLE-STREAM: every raw grad leaf (bf16 included)
enters one parts-kernel launch as its own zero-copy operand and is squared
IN-KERNEL (the square prologue), so the step's biggest reduction reads each
gradient byte exactly once -- no host-side square pass, no f32 staging
write, one pallas_call (asserted in tests/test_reduce_dispatch.py and gated
in benchmarks/check_bench.py). The jnp-level backends keep the
sharding-safe per-leaf row-partial route.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro import reduce as R
from repro.configs.base import TrainConfig


@dataclasses.dataclass(frozen=True)
class AdamWState:
    step: Any
    m: Any
    v: Any


jax.tree_util.register_dataclass(
    AdamWState, data_fields=["step", "m", "v"], meta_fields=[]
)


def init_state(params) -> AdamWState:
    zeros = lambda p: jnp.zeros_like(p, dtype=jnp.float32)
    return AdamWState(
        step=jnp.zeros((), jnp.int32),
        m=jax.tree.map(zeros, params),
        v=jax.tree.map(zeros, params),
    )


def cosine_lr(cfg: TrainConfig, step):
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip(
        (step - cfg.warmup_steps) / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
        0.0,
        1.0,
    )
    return cfg.learning_rate * warm * 0.5 * (1 + jnp.cos(jnp.pi * prog))


def global_norm(
    grads,
    *,
    mma: bool = True,
    backend: Optional[str] = None,
    num_cores: Optional[int] = None,
):
    """L2 norm over the gradient pytree via the reduction engine. ``backend``
    overrides the legacy ``mma`` flag when given; on the Pallas backends the
    leaves stream zero-copy through the in-kernel square prologue (one
    launch, one read per gradient byte). ``num_cores`` stripes the kernel
    lanes (planner default when None)."""
    if backend is None:
        backend = R.backend_for_flags(mma)
    return R.reduce_tree(grads, kind="norm2", backend=backend,
                         num_cores=num_cores)


def apply_updates(
    params,
    grads,
    state: AdamWState,
    cfg: TrainConfig,
    *,
    mma: bool = True,
    reduce_backend: Optional[str] = None,
):
    """One AdamW step. Returns (new_params, new_state, metrics)."""
    step = state.step + 1
    gnorm = global_norm(grads, mma=mma, backend=reduce_backend)
    clip = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-9))
    lr = cosine_lr(cfg, step)
    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1 - b1**step.astype(jnp.float32)
    bc2 = 1 - b2**step.astype(jnp.float32)

    def upd(p, g, m, v):
        gf = g.astype(jnp.float32) * clip
        m_new = b1 * m + (1 - b1) * gf
        v_new = b2 * v + (1 - b2) * gf * gf
        mhat = m_new / bc1
        vhat = v_new / bc2
        delta = mhat / (jnp.sqrt(vhat) + 1e-8) + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m_new, v_new

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state.m)
    flat_v = treedef.flatten_up_to(state.v)
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    metrics = {"grad_norm": gnorm, "lr": lr, "clip": clip}
    return new_p, AdamWState(step=step, m=new_m, v=new_v), metrics
