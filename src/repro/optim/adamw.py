"""AdamW with decoupled weight decay, cosine schedule and MMA global-norm
clipping. Hand-rolled (no optax dependency); state is a pytree mirroring the
params so the sharding rules apply verbatim (m/v inherit the param sharding
-- ZeRO-style partitioned optimizer state for free under FSDP).

The gradient-clipping statistic -- the largest full reduction in a training
step -- routes through the unified reduction engine. On the Pallas backends
the whole-pytree norm is SINGLE-STREAM: every raw grad leaf (bf16 included)
enters one parts-kernel launch as its own zero-copy operand and is squared
IN-KERNEL (the square prologue), and the norm's sqrt AND the clip
coefficient's min/max/div finish inside the same launch as an EPILOGUE fork
(``reduce_tree(kind="norm2", epilogue=[(), ("clip_coeff", ...)])`` ->
``(gnorm, clip)`` from one pallas_call, zero host-side scalar eqns --
``inspect.assert_epilogue_free`` gates exactly this in
benchmarks/check_bench.py). The jnp-level backends keep the sharding-safe
per-leaf row-partial route with the same chain applied host-side.

``fused_second_moment`` (olmax-style) keeps ONE SCALAR second-moment EMA
per leaf instead of a full elementwise ``v`` tensor: the per-leaf sumsq
slots of the SAME norm launch feed ``nu <- b2 nu + (1-b2) E[g^2]``, and the
update multiplies by the scalar reciprocal ``1/(sqrt(nuhat)+eps)`` -- so a
grad leaf makes ONE HBM trip per step (norm+stats+update) instead of
three, and the n-sized sqrt/divide of the elementwise path disappears.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro import reduce as R
from repro.configs.base import TrainConfig

# Gradient-norm floor for the clip coefficient: clip = min(1, c/max(g, EPS)).
# A Python float stays WEAK-TYPED: it folds into the epilogue chain's kernel
# constants and, host-side, binds to gnorm's dtype instead of materializing
# an f32 literal that would upcast the statistic under a bf16 policy (the
# old inline ``jnp.maximum(gnorm, 1e-9)`` pitfall).
GNORM_EPS = 1e-9

# Adam denominator fuzz (the standard 1e-8); same weak-typing rationale.
ADAM_EPS = 1e-8


@dataclasses.dataclass(frozen=True)
class AdamWState:
    step: Any
    m: Any
    v: Any


jax.tree_util.register_dataclass(
    AdamWState, data_fields=["step", "m", "v"], meta_fields=[]
)


def init_state(params, *, fused_second_moment: bool = False) -> AdamWState:
    """Optimizer state. ``fused_second_moment=True`` replaces each leaf's
    elementwise ``v`` tensor with ONE f32 scalar (the olmax-style E[g^2]
    EMA fed by the norm launch's per-leaf sumsq slots) -- the state
    shrinks by ~half and the update loses its n-sized sqrt/divide."""
    zeros = lambda p: jnp.zeros_like(p, dtype=jnp.float32)
    second = (
        (lambda p: jnp.zeros((), jnp.float32)) if fused_second_moment
        else zeros
    )
    return AdamWState(
        step=jnp.zeros((), jnp.int32),
        m=jax.tree.map(zeros, params),
        v=jax.tree.map(second, params),
    )


def cosine_lr(cfg: TrainConfig, step):
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip(
        (step - cfg.warmup_steps) / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
        0.0,
        1.0,
    )
    return cfg.learning_rate * warm * 0.5 * (1 + jnp.cos(jnp.pi * prog))


def global_norm(
    grads,
    *,
    mma: bool = True,
    backend: Optional[str] = None,
    num_cores: Optional[int] = None,
):
    """L2 norm over the gradient pytree via the reduction engine. ``backend``
    overrides the legacy ``mma`` flag when given; on the Pallas backends the
    leaves stream zero-copy through the in-kernel square prologue (one
    launch, one read per gradient byte). ``num_cores`` stripes the kernel
    lanes (planner default when None)."""
    if backend is None:
        backend = R.backend_for_flags(mma)
    return R.reduce_tree(grads, kind="norm2", backend=backend,
                         num_cores=num_cores)


def global_norm_and_clip(
    grads,
    max_norm,
    *,
    mma: bool = True,
    backend: Optional[str] = None,
    num_cores: Optional[int] = None,
    return_per_leaf: bool = False,
):
    """``(gnorm, clip)`` from ONE reduction launch: the epilogue fork
    finishes both the norm's sqrt and ``clip = min(1, max_norm /
    max(gnorm, GNORM_EPS))`` inside the launch that reduced the leaves
    (kernel backends -- zero host-side sqrt/min/div eqns; jnp backends
    apply the identical chain host-side). ``return_per_leaf=True``
    additionally returns the raw per-leaf sumsq slots first, from the same
    single launch -- the fused second-moment feed."""
    if backend is None:
        backend = R.backend_for_flags(mma)
    fork = [(), ("clip_coeff", float(max_norm), GNORM_EPS)]
    if return_per_leaf:
        per_leaf, out = R.reduce_tree(
            grads, kind="norm2", backend=backend, num_cores=num_cores,
            epilogue=fork, return_per_leaf=True,
        )
        return per_leaf, out[0], out[1]
    out = R.reduce_tree(grads, kind="norm2", backend=backend,
                        num_cores=num_cores, epilogue=fork)
    return out[0], out[1]


def apply_updates(
    params,
    grads,
    state: AdamWState,
    cfg: TrainConfig,
    *,
    mma: bool = True,
    reduce_backend: Optional[str] = None,
    fused_second_moment: bool = False,
):
    """One AdamW step. Returns (new_params, new_state, metrics).

    ``fused_second_moment`` must match the ``init_state`` that built
    ``state`` (scalar-v leaves)."""
    step = state.step + 1
    lr = cosine_lr(cfg, step)
    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1 - b1**step.astype(jnp.float32)
    bc2 = 1 - b2**step.astype(jnp.float32)

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state.m)
    flat_v = treedef.flatten_up_to(state.v)

    if fused_second_moment:
        # One launch feeds EVERYTHING the step needs from the grads: the
        # per-leaf sumsq slots (-> each leaf's scalar E[g^2] EMA) plus the
        # (gnorm, clip) epilogue fork. The grad leaves' only other read is
        # the fused update itself -> one HBM trip per leaf per step.
        per_leaf, gnorm, clip = global_norm_and_clip(
            grads, cfg.grad_clip, mma=mma, backend=reduce_backend,
            return_per_leaf=True,
        )

        def upd(p, g, m, nu, sumsq):
            n = max(int(g.size), 1)
            # scalar EMA of E[(clip g)^2]; all moment math is size-1
            nu_new = b2 * nu + (1 - b2) * (clip * clip) * (sumsq / n)
            rcp = 1.0 / (jnp.sqrt(nu_new / bc2) + ADAM_EPS)  # scalar
            gf = g.astype(jnp.float32) * clip
            m_new = b1 * m + (1 - b1) * gf
            # n-sized ops: multiplies and adds only (the scalar coefficient
            # carries the sqrt/divide) -- no elementwise sqrt/div pass
            pf = p.astype(jnp.float32)
            new_p = pf - (lr * rcp / bc1) * m_new - (lr * cfg.weight_decay) * pf
            return new_p.astype(p.dtype), m_new, nu_new

        out = [
            upd(p, g, m, nu, per_leaf[i])
            for i, (p, g, m, nu) in enumerate(
                zip(flat_p, flat_g, flat_m, flat_v)
            )
        ]
    else:
        gnorm, clip = global_norm_and_clip(
            grads, cfg.grad_clip, mma=mma, backend=reduce_backend
        )

        def upd(p, g, m, v):
            gf = g.astype(jnp.float32) * clip
            m_new = b1 * m + (1 - b1) * gf
            v_new = b2 * v + (1 - b2) * gf * gf
            mhat = m_new / bc1
            vhat = v_new / bc2
            delta = mhat / (jnp.sqrt(vhat) + ADAM_EPS) + cfg.weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m_new, v_new

        out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    metrics = {"grad_norm": gnorm, "lr": lr, "clip": clip}
    return new_p, AdamWState(step=step, m=new_m, v=new_v), metrics
