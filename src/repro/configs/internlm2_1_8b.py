"""internlm2-1.8b [dense] -- GQA. [arXiv:2403.17297]

24L d_model=2048 16H (GQA kv=8) d_ff=8192 vocab=92544.
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="internlm2-1.8b",
    family="dense",
    n_layers=24,
    d_model=2048,
    n_heads=16,
    n_kv_heads=8,
    d_head=128,
    d_ff=8192,
    vocab_size=92544,
    norm="rmsnorm",
)

TINY = ModelConfig(
    name="internlm2-tiny",
    family="dense",
    n_layers=3,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    d_head=16,
    d_ff=128,
    vocab_size=256,
    norm="rmsnorm",
    dtype="float32",
)
