"""mamba2-780m [ssm] -- SSD (state-space duality). [arXiv:2405.21060]

48L d_model=1536, attention-free (d_ff=0: the Mamba block is the whole
layer), vocab=50280, ssm_state=128. Sub-quadratic: long_500k RUNS.
"""

from repro.configs.base import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="mamba2-780m",
    family="ssm",
    n_layers=48,
    d_model=1536,
    n_heads=0,
    n_kv_heads=0,
    d_head=0,
    d_ff=0,
    vocab_size=50280,
    block_pattern=("ssm",),
    norm="rmsnorm",
    ssm=SSMConfig(d_state=128, expand=2, headdim=64, n_groups=1, conv_width=4, chunk=256),
    tie_embeddings=True,  # mamba2 reference ties embeddings
)

TINY = ModelConfig(
    name="mamba2-tiny",
    family="ssm",
    n_layers=4,
    d_model=64,
    n_heads=0,
    n_kv_heads=0,
    d_head=0,
    d_ff=0,
    vocab_size=256,
    block_pattern=("ssm",),
    norm="rmsnorm",
    ssm=SSMConfig(d_state=16, expand=2, headdim=16, n_groups=1, conv_width=4, chunk=16),
    tie_embeddings=True,
    dtype="float32",
)
