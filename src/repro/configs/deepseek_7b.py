"""deepseek-7b [dense] -- llama-arch reference dense model. [arXiv:2401.02954]

30L d_model=4096 32H (GQA kv=32 -> MHA) d_ff=11008 vocab=102400.
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-7b",
    family="dense",
    n_layers=30,
    d_model=4096,
    n_heads=32,
    n_kv_heads=32,
    d_head=128,
    d_ff=11008,
    vocab_size=102400,
    norm="rmsnorm",
)

TINY = ModelConfig(
    name="deepseek-tiny",
    family="dense",
    n_layers=3,
    d_model=64,
    n_heads=4,
    n_kv_heads=4,
    d_head=16,
    d_ff=160,
    vocab_size=256,
    norm="rmsnorm",
    dtype="float32",
)
