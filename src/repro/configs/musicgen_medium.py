"""musicgen-medium [audio] -- decoder-only over EnCodec tokens.
[arXiv:2306.05284]

48L d_model=1536 24H (GQA kv=24 -> MHA) d_ff=6144 vocab=2048, 4 codebook
streams (delay-pattern handling is upstream tokenization; the backbone sees
the (B, S, 4) grid, sums codebook embeddings in, and emits 4 heads).
Frontend (EnCodec) is a stub per spec. MusicGen uses LayerNorm + GeLU FFN.

NOTE: 24 heads is not divisible by the 16-way model axis; GSPMD pads uneven
head shards (recorded in EXPERIMENTS.md Dry-run).
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="musicgen-medium",
    family="audio",
    n_layers=48,
    d_model=1536,
    n_heads=24,
    n_kv_heads=24,
    d_head=64,
    d_ff=6144,
    vocab_size=2048,
    norm="layernorm",
    ffn_kind="gelu",
    n_codebooks=4,
)

TINY = ModelConfig(
    name="musicgen-tiny",
    family="audio",
    n_layers=3,
    d_model=64,
    n_heads=4,
    n_kv_heads=4,
    d_head=16,
    d_ff=128,
    vocab_size=64,
    norm="layernorm",
    ffn_kind="gelu",
    n_codebooks=4,
    dtype="float32",
)
