"""dbrx-132b [moe] -- 16 experts top-4, fine-grained. [hf:databricks/dbrx-base]

40L d_model=6144 48H (GQA kv=8) d_ff=10752 (per expert) vocab=100352.
The largest assigned model (~132B total / ~36B active): FSDP parameter
sharding over the data axis + EP/TP over model + gradient-accumulation
microbatching are required to fit (see launch/sharding.py).
"""

from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="dbrx-132b",
    family="moe",
    n_layers=40,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    d_head=128,
    d_ff=0,
    vocab_size=100352,
    norm="rmsnorm",
    moe=MoEConfig(n_experts=16, top_k=4, d_ff_expert=10752),
)

TINY = ModelConfig(
    name="dbrx-tiny",
    family="moe",
    n_layers=3,
    d_model=64,
    n_heads=8,
    n_kv_heads=2,
    d_head=8,
    d_ff=0,
    vocab_size=256,
    norm="rmsnorm",
    moe=MoEConfig(n_experts=4, top_k=2, d_ff_expert=48, capacity_factor=2.0),
    dtype="float32",
)
