"""recurrentgemma-9b [hybrid] -- RG-LRU + local attention, 1 attn : 2 rec.
[arXiv:2402.19427]

38L d_model=4096 16H (MQA kv=1) d_ff=12288 vocab=256000, window=2048,
lru_width=4096. Pattern (rec, rec, local_attn): 12 full units + 2-layer
tail (the scan-over-units machinery handles the remainder).
Sub-quadratic (bounded ring KV + O(1) recurrent state): long_500k RUNS.
"""

from repro.configs.base import ModelConfig, RGLRUConfig

CONFIG = ModelConfig(
    name="recurrentgemma-9b",
    family="hybrid",
    n_layers=38,
    d_model=4096,
    n_heads=16,
    n_kv_heads=1,
    d_head=256,
    d_ff=12288,
    vocab_size=256000,
    block_pattern=("rec", "rec", "local_attn"),
    window=2048,
    norm="rmsnorm",
    rglru=RGLRUConfig(lru_width=4096, conv_width=4),
    logits_softcap=30.0,
)

TINY = ModelConfig(
    name="recurrentgemma-tiny",
    family="hybrid",
    n_layers=5,
    d_model=64,
    n_heads=4,
    n_kv_heads=1,
    d_head=16,
    d_ff=128,
    vocab_size=256,
    block_pattern=("rec", "rec", "local_attn"),
    window=16,
    norm="rmsnorm",
    rglru=RGLRUConfig(lru_width=64, conv_width=4),
    logits_softcap=30.0,
    dtype="float32",
)
