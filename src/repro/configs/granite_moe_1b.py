"""granite-moe-1b-a400m [moe] -- 32 experts top-8, fine-grained (d_ff=512).
[hf:ibm-granite/granite-3.0-1b-a400m-base]

24L d_model=1024 16H (GQA kv=8) d_ff=512 per expert, vocab=49155.
"""

from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="granite-moe-1b-a400m",
    family="moe",
    n_layers=24,
    d_model=1024,
    n_heads=16,
    n_kv_heads=8,
    d_head=64,
    d_ff=0,
    vocab_size=49155,
    norm="rmsnorm",
    moe=MoEConfig(n_experts=32, top_k=8, d_ff_expert=512),
    tie_embeddings=True,
)

TINY = ModelConfig(
    name="granite-moe-tiny",
    family="moe",
    n_layers=3,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    d_head=16,
    d_ff=0,
    vocab_size=256,
    norm="rmsnorm",
    moe=MoEConfig(n_experts=8, top_k=4, d_ff_expert=16, capacity_factor=2.0),
    tie_embeddings=True,
    dtype="float32",
)
