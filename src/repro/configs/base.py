"""Config system: model, shape, mesh and run configs.

Every assigned architecture is a `ModelConfig`; every assigned input shape a
`ShapeConfig`. Dataclasses are frozen (hashable) so they can be static
arguments to jit and keys into compile caches.
"""

from __future__ import annotations

import dataclasses
from typing import Optional


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_ff_expert: int
    capacity_factor: float = 1.25
    aux_loss_weight: float = 0.01
    router_z_weight: float = 1e-3


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    """Mamba-2 / SSD hyperparameters."""

    d_state: int = 128
    expand: int = 2
    headdim: int = 64
    n_groups: int = 1
    conv_width: int = 4
    chunk: int = 256
    dt_min: float = 0.001
    dt_max: float = 0.1


@dataclasses.dataclass(frozen=True)
class MLAConfig:
    """Multi-head Latent Attention (DeepSeek-V2 / MiniCPM3)."""

    q_lora_rank: int = 768
    kv_lora_rank: int = 256
    qk_nope_dim: int = 64
    qk_rope_dim: int = 32
    v_head_dim: int = 64


@dataclasses.dataclass(frozen=True)
class RGLRUConfig:
    """Griffin / RecurrentGemma RG-LRU block."""

    lru_width: int = 0          # 0 -> d_model
    conv_width: int = 4
    c: float = 8.0              # Griffin's fixed decay sharpness


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                    # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_head: int
    d_ff: int
    vocab_size: int
    # Repeating layer pattern; cycled to n_layers (tail truncated).
    # kinds: "attn" (global self-attn + FFN), "local_attn" (windowed),
    #        "xattn" (cross-attn to frontend embeds + FFN),
    #        "ssm" (Mamba2 block, no FFN), "rec" (RG-LRU block + FFN)
    block_pattern: tuple[str, ...] = ("attn",)
    norm: str = "rmsnorm"          # rmsnorm | layernorm | layernorm_np
    ffn_kind: str = "swiglu"       # swiglu | gelu
    window: Optional[int] = None   # local_attn window size
    rope_theta: float = 10000.0
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    mla: Optional[MLAConfig] = None
    rglru: Optional[RGLRUConfig] = None
    n_img_tokens: int = 0          # vlm stub frontend tokens
    n_codebooks: int = 0           # audio codebook streams (musicgen)
    qkv_bias: bool = False
    tie_embeddings: bool = False
    norm_eps: float = 1e-5
    # --- framework knobs (not architecture) ---
    dtype: str = "bfloat16"        # params/activations dtype
    use_pallas: bool = False       # route hot-spots to Pallas kernels (TPU)
    mma_reductions: bool = True    # paper's technique on/off (off = baseline)
    remat: bool = True             # activation checkpointing per layer-unit
    logits_softcap: float = 0.0

    @property
    def pattern_layers(self) -> tuple[str, ...]:
        reps = -(-self.n_layers // len(self.block_pattern))
        return (self.block_pattern * reps)[: self.n_layers]

    @property
    def attention_free(self) -> bool:
        return all(k in ("ssm", "rec") for k in self.pattern_layers)

    @property
    def subquadratic(self) -> bool:
        """True if the arch can decode with O(1)-or-bounded state per token
        (SSM/recurrent state or bounded local-attention window)."""
        return all(
            k in ("ssm", "rec") or (k == "local_attn" and self.window)
            for k in self.pattern_layers
        )

    def param_count(self) -> int:
        """Analytic parameter count (embeddings + blocks + head)."""
        d = self.d_model
        total = self.vocab_size * d  # embed
        if self.n_codebooks:
            total += (self.n_codebooks - 1) * self.vocab_size * d
        if not self.tie_embeddings:
            total += self.vocab_size * d * max(1, self.n_codebooks or 1)
        for kind in self.pattern_layers:
            if kind in ("attn", "local_attn"):
                if self.mla is not None:
                    m = self.mla
                    total += d * m.q_lora_rank
                    total += m.q_lora_rank * self.n_heads * (m.qk_nope_dim + m.qk_rope_dim)
                    total += d * (m.kv_lora_rank + m.qk_rope_dim)
                    total += m.kv_lora_rank * self.n_heads * (m.qk_nope_dim + m.v_head_dim)
                    total += self.n_heads * m.v_head_dim * d
                else:
                    total += d * self.n_heads * self.d_head
                    total += 2 * d * self.n_kv_heads * self.d_head
                    total += self.n_heads * self.d_head * d
                total += self._ffn_params()
            elif kind == "xattn":
                total += d * self.n_heads * self.d_head
                total += 2 * d * self.n_kv_heads * self.d_head
                total += self.n_heads * self.d_head * d
                total += self._ffn_params()
            elif kind == "ssm":
                s = self.ssm
                d_in = s.expand * d
                conv_dim = d_in + 2 * s.n_groups * s.d_state
                nh = d_in // s.headdim
                total += d * (2 * d_in + 2 * s.n_groups * s.d_state + nh)
                total += conv_dim * s.conv_width
                total += d_in * d
                total += d_in + 2 * nh  # gated-norm gamma + A, D, dt_bias approx
            elif kind == "rec":
                r = self.rglru or RGLRUConfig()
                w = r.lru_width or d
                total += 2 * d * w + w * d + r.conv_width * w + 3 * w
                total += self._ffn_params()
        return int(total)

    def _ffn_params(self) -> int:
        d = self.d_model
        if self.moe is not None:
            e = self.moe
            per = 3 * d * e.d_ff_expert if self.ffn_kind == "swiglu" else 2 * d * e.d_ff_expert
            return e.n_experts * per + d * e.n_experts
        return 3 * d * self.d_ff if self.ffn_kind == "swiglu" else 2 * d * self.d_ff

    def active_param_count(self) -> int:
        """Params touched per token (MoE: only top-k experts) -- the N in
        MODEL_FLOPS = 6*N_active*D."""
        if self.moe is None:
            return self.param_count()
        e = self.moe
        total = self.param_count()
        per = (3 if self.ffn_kind == "swiglu" else 2) * self.d_model * e.d_ff_expert
        n_ffn_layers = sum(
            1 for k in self.pattern_layers if k in ("attn", "local_attn", "xattn")
        )
        total -= n_ffn_layers * (e.n_experts - e.top_k) * per
        return int(total)


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    mode: str  # "train" | "prefill" | "decode"

    @property
    def tokens_per_step(self) -> int:
        if self.mode == "decode":
            return self.global_batch  # one new token per sequence
        return self.seq_len * self.global_batch


# The four assigned LM shape cells.
TRAIN_4K = ShapeConfig("train_4k", 4096, 256, "train")
PREFILL_32K = ShapeConfig("prefill_32k", 32768, 32, "prefill")
DECODE_32K = ShapeConfig("decode_32k", 32768, 128, "decode")
LONG_500K = ShapeConfig("long_500k", 524288, 1, "decode")
ALL_SHAPES = (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)


def shape_applicable(model: ModelConfig, shape: ShapeConfig) -> tuple[bool, str]:
    """Spec rule: long_500k needs sub-quadratic attention; decoders run all
    decode shapes. Returns (runs, reason-if-skipped)."""
    if shape.name == "long_500k" and not model.subquadratic:
        return False, "full attention: 500k dense KV decode is the quadratic regime the spec excludes"
    return True, ""


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    learning_rate: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 1000
    weight_decay: float = 0.1
    b1: float = 0.9
    b2: float = 0.95
    grad_clip: float = 1.0
    microbatches: int = 1          # gradient-accumulation chunks per step
    grad_compression: bool = False  # int8 EF on cross-pod gradient hop
    # olmax-style scalar second-moment EMA per leaf, fed by the norm
    # launch's per-leaf sumsq slots: one HBM trip per grad leaf per step
    # (see optim.adamw); must match the init_state that built the opt state
    fused_second_moment: bool = False
    seed: int = 0
