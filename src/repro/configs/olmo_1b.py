"""olmo-1b [dense] -- non-parametric LayerNorm. [arXiv:2402.00838]

16L d_model=2048 16H (GQA kv=16 -> MHA) d_ff=8192 vocab=50304.
OLMo's LN has no scale/bias -- the *pure statistics* case of the paper's
MMA-reduction (kernels/row_moments.layernorm_np).
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="olmo-1b",
    family="dense",
    n_layers=16,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_head=128,
    d_ff=8192,
    vocab_size=50304,
    norm="layernorm_np",
    tie_embeddings=True,
)

TINY = ModelConfig(
    name="olmo-tiny",
    family="dense",
    n_layers=3,
    d_model=64,
    n_heads=4,
    n_kv_heads=4,
    d_head=16,
    d_ff=128,
    vocab_size=256,
    norm="layernorm_np",
    tie_embeddings=True,
    dtype="float32",
)
