"""minicpm3-4b [dense] -- Multi-head Latent Attention. [hf:openbmb/MiniCPM3-4B]

62L d_model=2560 40H d_ff=6400 vocab=73448, MLA with q_lora_rank=768,
kv_lora_rank=256, qk_nope=64, qk_rope=32, v_head=64 (official config).

NOTE: 40 heads is not divisible by the 16-way model axis; GSPMD pads the
head shards. Recorded in EXPERIMENTS.md Dry-run; the hillclimb cells use
divisible archs.
"""

from repro.configs.base import MLAConfig, ModelConfig

CONFIG = ModelConfig(
    name="minicpm3-4b",
    family="dense",
    n_layers=62,
    d_model=2560,
    n_heads=40,
    n_kv_heads=40,
    d_head=96,  # qk_nope + qk_rope
    d_ff=6400,
    vocab_size=73448,
    norm="rmsnorm",
    mla=MLAConfig(
        q_lora_rank=768, kv_lora_rank=256, qk_nope_dim=64, qk_rope_dim=32, v_head_dim=64
    ),
)

TINY = ModelConfig(
    name="minicpm3-tiny",
    family="dense",
    n_layers=3,
    d_model=64,
    n_heads=4,
    n_kv_heads=4,
    d_head=24,
    d_ff=128,
    vocab_size=256,
    norm="rmsnorm",
    mla=MLAConfig(q_lora_rank=32, kv_lora_rank=16, qk_nope_dim=16, qk_rope_dim=8, v_head_dim=16),
    dtype="float32",
)
