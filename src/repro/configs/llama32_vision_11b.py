"""llama-3.2-vision-11b [vlm] -- cross-attention image layers.
[hf:meta-llama/Llama-3.2-11B-Vision]

40L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=128256. Cross-attention
layers interleaved 1-per-5 (8 of 40); the ViT frontend is a STUB per spec --
input_specs provides (B, 1032, d_model) precomputed patch embeddings.
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llama-3.2-vision-11b",
    family="vlm",
    n_layers=40,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_head=128,
    d_ff=14336,
    vocab_size=128256,
    block_pattern=("attn", "attn", "attn", "attn", "xattn"),
    norm="rmsnorm",
    n_img_tokens=1032,  # 1025-token tile x 1 + pad to sublane multiple
    rope_theta=500000.0,
)

TINY = ModelConfig(
    name="llama32v-tiny",
    family="vlm",
    n_layers=5,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    d_head=16,
    d_ff=128,
    vocab_size=256,
    block_pattern=("attn", "attn", "attn", "attn", "xattn"),
    norm="rmsnorm",
    n_img_tokens=16,
    dtype="float32",
)
