"""Architecture registry: ``--arch <id>`` resolution for every launcher.

ARCHS maps arch id -> full ModelConfig (the assigned published dims);
TINY_ARCHS maps arch id -> reduced same-family smoke config (CPU-runnable).
"""

from __future__ import annotations

from repro.configs import (
    base,
    dbrx_132b,
    deepseek_7b,
    granite_moe_1b,
    internlm2_1_8b,
    llama32_vision_11b,
    mamba2_780m,
    minicpm3_4b,
    musicgen_medium,
    olmo_1b,
    recurrentgemma_9b,
)
from repro.configs.base import (  # noqa: F401
    ALL_SHAPES,
    DECODE_32K,
    LONG_500K,
    PREFILL_32K,
    TRAIN_4K,
    MLAConfig,
    ModelConfig,
    MoEConfig,
    RGLRUConfig,
    SSMConfig,
    ShapeConfig,
    TrainConfig,
    shape_applicable,
)

_MODULES = (
    mamba2_780m,
    musicgen_medium,
    dbrx_132b,
    granite_moe_1b,
    olmo_1b,
    deepseek_7b,
    minicpm3_4b,
    internlm2_1_8b,
    recurrentgemma_9b,
    llama32_vision_11b,
)

ARCHS: dict[str, ModelConfig] = {m.CONFIG.name: m.CONFIG for m in _MODULES}
TINY_ARCHS: dict[str, ModelConfig] = {m.CONFIG.name: m.TINY for m in _MODULES}
SHAPES: dict[str, ShapeConfig] = {s.name: s for s in ALL_SHAPES}


def get_arch(name: str, tiny: bool = False) -> ModelConfig:
    table = TINY_ARCHS if tiny else ARCHS
    if name not in table:
        raise KeyError(f"unknown arch {name!r}; available: {sorted(table)}")
    return table[name]


def get_shape(name: str) -> ShapeConfig:
    if name not in SHAPES:
        raise KeyError(f"unknown shape {name!r}; available: {sorted(SHAPES)}")
    return SHAPES[name]
