"""`repro.reduce.reduce` -- the single entry point for every reduction.

One function, five kinds, any registered backend:

    reduce(x)                            # full sum, planner picks the path
    reduce(x, axis=-1, kind="moments")   # (sum, sumsq) rows for norm layers
    reduce(g, kind="norm2", backend="pallas_fused")
    reduce_tree(grads, kind="norm2")     # the optimizer's clipping statistic

Kinds are composed from the backend primitives, so each of them is available
on each backend.

Differentiation: backends built from jnp/dot code (``native_autodiff``)
differentiate natively in BOTH reverse and forward mode -- ``jax.jvp`` /
``jacfwd`` / ``hessian`` flow straight through, exactly as they did through
the pre-engine ``jnp.sum`` / ``row_sum_mma`` call sites. Only kernel-backed
full reductions (the Pallas backends) are wrapped in a ``jax.custom_vjp``
(the VJP of a sum is a broadcast of the cotangent, independent of the
reduction schedule); those support reverse mode only, like any Pallas
kernel. Batched row reductions run as native dots on every backend.
"""

from __future__ import annotations

import functools
import math
from typing import Optional, Sequence, Union

import jax
import jax.numpy as jnp

from repro.reduce import backends as _backends
from repro.reduce.plan import ReducePlan, plan_for

Axis = Union[None, int, Sequence[int]]

KINDS = ("sum", "mean", "sumsq", "norm2", "moments")

# sentinel for axis=(): numpy semantics -- reduce over NO axes (identity)
_NO_AXES = ()


def _normalize_axis(axis: Axis, ndim: int):
    """-> None (reduce everything), () (reduce nothing -- numpy semantics for
    an empty axis tuple), or a sorted tuple of unique non-negative axes."""
    if axis is None:
        return None
    axes = (axis,) if isinstance(axis, int) else tuple(axis)
    if not axes:
        return _NO_AXES
    out = []
    for a in axes:
        if ndim == 0:
            # numpy convention: 0-d arrays accept axis 0 / -1 (full reduce)
            if a not in (0, -1):
                raise ValueError(f"axis {a} out of range for 0-d array")
            continue
        if not -ndim <= a < ndim:
            raise ValueError(f"axis {a} out of range for ndim {ndim}")
        a %= ndim
        if a in out:
            raise ValueError(f"duplicate axis {a} in reduction axes")
        out.append(a)
    if ndim == 0 or len(out) == ndim:
        return None  # covers every axis: a full reduction
    return tuple(sorted(out))


def _kahan_sum_all(x, plan: ReducePlan, backend) -> jax.Array:
    """Blocked compensated combine: backend-reduce each block, Kahan the
    partials (Markidis-style refinement; orthogonal to the backend)."""
    from repro.core import precision as _precision

    flat = x.reshape(-1).astype(plan.accum_jnp)
    block = plan.kahan_block
    if flat.size <= block:
        return backend.sum_all(flat, plan)
    nblk = -(-flat.size // block)
    pad = nblk * block - flat.size
    if pad:
        flat = jnp.pad(flat, (0, pad))
    partials = jax.lax.map(
        lambda b: backend.sum_all(b, plan), flat.reshape(nblk, block)
    )
    return _precision.kahan_sum(partials, dtype=plan.accum_jnp)


def _sum_all_impl(x: jax.Array, plan: ReducePlan) -> jax.Array:
    backend = _backends.get_backend(plan.backend)
    accum = plan.accum_jnp
    if x.size == 0:
        return jnp.zeros((), accum)
    if plan.precision == "kahan":
        return _kahan_sum_all(x, plan, backend).astype(accum)
    return backend.sum_all(x, plan).astype(accum)


def _to_rows(x: jax.Array, axis):
    """Move the reduced axes last and flatten them: -> ((..., L), batch_shape)."""
    keep = tuple(a for a in range(x.ndim) if a not in axis)
    xt = jnp.transpose(x, keep + axis)
    batch_shape = xt.shape[: len(keep)]
    red = int(math.prod(xt.shape[len(keep):]))
    return xt.reshape(batch_shape + (red,)), batch_shape, red


def _row_plan(plan: ReducePlan) -> ReducePlan:
    if plan.precision == "kahan":
        # Row reductions have no serial combine to compensate; the policy
        # degrades gracefully to exact-accumulator multipliers.
        return plan.replace(compute_dtype=plan.accum_dtype)
    return plan


def _sum_axis_impl(x: jax.Array, axis, plan: ReducePlan) -> jax.Array:
    backend = _backends.get_backend(plan.backend)
    accum = plan.accum_jnp
    flat, batch_shape, red = _to_rows(x, axis)
    if red == 0 or 0 in batch_shape:
        return jnp.zeros(batch_shape, accum)
    return backend.sum_axis(flat, _row_plan(plan)).astype(accum)


def _moments_axis_impl(x: jax.Array, axis, plan: ReducePlan):
    backend = _backends.get_backend(plan.backend)
    accum = plan.accum_jnp
    flat, batch_shape, red = _to_rows(x, axis)
    if red == 0 or 0 in batch_shape:
        z = jnp.zeros(batch_shape, accum)
        return z, z
    s, ss = backend.moments_axis(flat, _row_plan(plan))
    return s.astype(accum), ss.astype(accum)


# Kernel-backed full reductions (no native autodiff) get the one custom VJP:
# the backward of a sum is a broadcast of the cotangent, independent of the
# reduction schedule, so the Pallas forward never needs differentiating.


@functools.partial(jax.custom_vjp, nondiff_argnums=(1,))
def _ksum(x: jax.Array, plan: ReducePlan) -> jax.Array:
    return _sum_all_impl(x, plan)


def _ksum_fwd(x, plan):
    # zero-size residual carries shape+dtype without retaining x
    return _sum_all_impl(x, plan), jnp.zeros((0,) + x.shape, x.dtype)


def _ksum_bwd(plan, res, g):
    return (jnp.broadcast_to(g, res.shape[1:]).astype(res.dtype),)


_ksum.defvjp(_ksum_fwd, _ksum_bwd)


def _sum(x: jax.Array, axis, plan: ReducePlan) -> jax.Array:
    """Differentiable sum dispatch (see module docstring)."""
    if axis is not None:
        return _sum_axis_impl(x, axis, plan)
    if _backends.get_backend(plan.backend).native_autodiff:
        return _sum_all_impl(x, plan)
    return _ksum(x, plan)


def _resolve_plan(x, axis, kind, plan, backend, m, tiles_per_block,
                  compute_dtype, accum_dtype, precision) -> ReducePlan:
    if plan is None:
        return plan_for(
            x.shape,
            x.dtype,
            kind=kind,
            axis=axis if axis != _NO_AXES else None,
            backend=backend,
            m=m,
            tiles_per_block=tiles_per_block,
            compute_dtype=compute_dtype,
            accum_dtype=accum_dtype,
            precision=precision,
        )
    overrides = {}
    if backend is not None:
        overrides["backend"] = backend
    if m is not None:
        overrides["m"] = int(m)
    if tiles_per_block is not None:
        overrides["tiles_per_block"] = int(tiles_per_block)
    if compute_dtype is not None:
        overrides["compute_dtype"] = str(jnp.dtype(compute_dtype))
    if accum_dtype is not None:
        overrides["accum_dtype"] = str(jnp.dtype(accum_dtype))
    if precision is not None:
        overrides["precision"] = precision
    return plan.replace(**overrides) if overrides else plan


def reduce(
    x,
    axis: Axis = None,
    kind: str = "sum",
    *,
    plan: Optional[ReducePlan] = None,
    backend: Optional[str] = None,
    m: Optional[int] = None,
    tiles_per_block: Optional[int] = None,
    compute_dtype=None,
    accum_dtype=None,
    precision: Optional[str] = None,
):
    """Reduce ``x`` over ``axis`` (None = all elements; () = no axes,
    matching numpy's empty-tuple convention).

    kind:
      "sum"     -- plain sum, result dtype = plan.accum_dtype.
      "mean"    -- sum / reduced-element count.
      "sumsq"   -- sum of squares (squares taken at accumulator precision).
      "norm2"   -- sqrt(sumsq): the L2 norm / clipping statistic.
      "moments" -- (sum, sumsq) pair: exactly what LayerNorm/RMSNorm need;
                   axis reductions fuse both moments into one stacked
                   all-ones dot (one MXU pass).

    ``plan`` pins the full execution strategy; the keyword overrides adjust
    individual fields (of the given plan, or of the planner's choice). All
    kinds are differentiable on all backends (Pallas backends: reverse mode).
    """
    if kind not in KINDS:
        raise ValueError(f"unknown kind {kind!r}; expected one of {KINDS}")
    x = jnp.asarray(x)
    axis_t = _normalize_axis(axis, x.ndim)
    p = _resolve_plan(x, axis_t, kind, plan, backend, m, tiles_per_block,
                      compute_dtype, accum_dtype, precision)
    if axis_t == _NO_AXES and axis is not None:
        # reduce over no axes: the elementwise identity of each kind
        xf = x.astype(p.accum_jnp)
        if kind in ("sum", "mean"):
            return xf
        if kind == "sumsq":
            return xf * xf
        if kind == "norm2":
            return jnp.abs(xf)
        return xf, xf * xf  # moments
    if kind == "sum":
        return _sum(x, axis_t, p)
    if kind == "mean":
        count = (
            x.size
            if axis_t is None
            else int(math.prod(x.shape[a] for a in axis_t))
        )
        return _sum(x, axis_t, p) / count
    xf = x.astype(p.accum_jnp)
    if kind == "sumsq":
        return _sum(xf * xf, axis_t, p)
    if kind == "norm2":
        return jnp.sqrt(_sum(xf * xf, axis_t, p))
    # moments
    if axis_t is None:
        return _sum(x, None, p), _sum(xf * xf, None, p)
    return _moments_axis_impl(x, axis_t, p)


def reduce_tree(
    tree,
    kind: str = "sumsq",
    *,
    plan: Optional[ReducePlan] = None,
    backend: Optional[str] = None,
    m: Optional[int] = None,
):
    """Reduce a whole pytree to one scalar ("sum", "sumsq" or "norm2").

    This is the optimizer's gradient-clipping statistic -- the highest-volume
    full reduction in a training step -- routed through the engine.

    SHARDING-CRITICAL: each leaf is reduced as a *last-axis* all-ones dot
    (eq. 9) followed by a small residual sum. Flattening a leaf into
    (k, m, m) tiles first would reshape across sharded dimensions and force
    GSPMD to all-gather the full tensor (for a 132B model that is a 169 GB
    gather per step -- caught by the dry-run; see EXPERIMENTS.md). The
    last-axis dot keeps every MMA on the local shard, and the cross-device
    rungs of the paper's hierarchy are GSPMD's own reduce of the scalar
    partials -- eq. (13) continued over the mesh, as designed.
    """
    if kind not in ("sum", "sumsq", "norm2"):
        raise ValueError(f"reduce_tree supports sum/sumsq/norm2; got {kind!r}")
    leaves = jax.tree_util.tree_leaves(tree)
    square = kind in ("sumsq", "norm2")
    if plan is None:
        probe = leaves[0].shape if leaves else ()
        plan = plan_for(
            probe,
            jnp.float32,
            kind="sumsq" if square else "sum",
            backend=backend,
            m=m,
            compute_dtype="float32",  # exactness matters for clipping
        )
    elif backend is not None or m is not None:
        plan = plan.replace(
            **{
                k: v
                for k, v in (("backend", backend), ("m", m))
                if v is not None
            }
        )
    accum = plan.accum_jnp
    if not leaves:
        return jnp.zeros((), accum)
    partials = []
    for leaf in leaves:
        xf = jnp.asarray(leaf).astype(accum)
        v = xf * xf if square else xf
        if v.ndim == 0:
            partials.append(v)
            continue
        rs = _sum(v, (v.ndim - 1,), plan)
        # remaining dims are small -- plain sum of the row partials
        partials.append(jnp.sum(rs))
    total = _sum(jnp.stack(partials), None, plan)
    return jnp.sqrt(total) if kind == "norm2" else total
