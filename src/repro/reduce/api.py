"""`repro.reduce.reduce` -- the single entry point for every reduction.

One function, five kinds, any registered backend:

    reduce(x)                            # full sum, planner picks the path
    reduce(x, axis=-1, kind="moments")   # (sum, sumsq) rows for norm layers
    reduce(g, kind="norm2", backend="pallas_fused")
    reduce_many(arrays, kind="sumsq")    # N reductions, ONE launch
    reduce_tree(grads, kind="norm2")     # the optimizer's clipping statistic

Kinds are composed from the backend primitives, so each of them is available
on each backend.

``reduce_many`` is the multi-reduce entry point: N independent arrays are
reduced in a single backend pass (one ``segment_sum`` / one batched dot /
one multi-operand Pallas launch, by backend) instead of N separate
launches. On the kernel backends every array enters the launch as its OWN
operand in its native dtype (``sum_parts``) -- nothing is packed, cast, or
concatenated host-side; the jnp-level backends pack internally where XLA
fuses it. ``reduce_tree`` rides the same machinery for the optimizer's
whole-pytree clipping statistic.

Differentiation: backends built from jnp/dot code (``native_autodiff``)
differentiate natively in BOTH reverse and forward mode -- ``jax.jvp`` /
``jacfwd`` / ``hessian`` flow straight through, exactly as they did through
the pre-engine ``jnp.sum`` / ``row_sum_mma`` call sites. Only kernel-backed
full reductions (the Pallas backends) are wrapped in a ``jax.custom_vjp``
(the VJP of a sum is a broadcast of the cotangent, independent of the
reduction schedule); those support reverse mode only, like any Pallas
kernel. Batched row reductions run as native dots on every backend.
"""

from __future__ import annotations

import functools
import math
from typing import Optional, Sequence, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import common as _kcommon
from repro.reduce import backends as _backends
from repro.reduce.plan import ReducePlan, norm_mesh_axes, plan_for

Axis = Union[None, int, Sequence[int]]

KINDS = ("sum", "mean", "sumsq", "norm2", "moments")

# sentinel for axis=(): numpy semantics -- reduce over NO axes (identity)
_NO_AXES = ()


def _normalize_axis(axis: Axis, ndim: int):
    """-> None (reduce everything), () (reduce nothing -- numpy semantics for
    an empty axis tuple), or a sorted tuple of unique non-negative axes."""
    if axis is None:
        return None
    axes = (axis,) if isinstance(axis, int) else tuple(axis)
    if not axes:
        return _NO_AXES
    out = []
    for a in axes:
        if ndim == 0:
            # numpy convention: 0-d arrays accept axis 0 / -1 (full reduce)
            if a not in (0, -1):
                raise ValueError(f"axis {a} out of range for 0-d array")
            continue
        if not -ndim <= a < ndim:
            raise ValueError(f"axis {a} out of range for ndim {ndim}")
        a %= ndim
        if a in out:
            raise ValueError(f"duplicate axis {a} in reduction axes")
        out.append(a)
    if ndim == 0 or len(out) == ndim:
        return None  # covers every axis: a full reduction
    return tuple(sorted(out))


def _backend_sum_all(backend, x, plan, prologue, epilogue=()):
    """sum_all with the prologue and (optional) epilogue chain; third-party
    backends that predate either keep working for every kind (host-side
    map degradation -- see backends.sum_all_with_epilogue)."""
    return _backends.sum_all_with_epilogue(backend, x, plan, prologue,
                                           epilogue)


def _kahan_sum_all(x, plan: ReducePlan, backend, prologue="identity") -> jax.Array:
    """Blocked compensated combine: backend-reduce each (prologue-mapped)
    block, Kahan the partials (Markidis-style refinement; orthogonal to the
    backend -- zero-padding stays exact because 0 is a fixed point of every
    prologue)."""
    from repro.core import precision as _precision

    flat = x.reshape(-1).astype(plan.accum_jnp)
    block = plan.kahan_block
    if flat.size <= block:
        return _backend_sum_all(backend, flat, plan, prologue)
    nblk = -(-flat.size // block)
    pad = nblk * block - flat.size
    if pad:
        flat = jnp.pad(flat, (0, pad))
    partials = jax.lax.map(
        lambda b: _backend_sum_all(backend, b, plan, prologue),
        flat.reshape(nblk, block),
    )
    return _precision.kahan_sum(partials, dtype=plan.accum_jnp)


def _sum_all_impl(
    x: jax.Array,
    plan: ReducePlan,
    prologue: str = "identity",
    epilogue: tuple = (),
) -> jax.Array:
    backend = _backends.get_backend(plan.backend)
    accum = plan.accum_jnp
    if x.size == 0:
        return _kcommon.apply_epilogue(jnp.zeros((), accum), epilogue)
    if plan.precision == "kahan" and not backend.native_kahan:
        # Backends without an in-kernel carry get the blocked compensated
        # combine; native_kahan backends (pallas_fused) compensate inside
        # their single launch instead. The epilogue maps the compensated
        # total (it is a post-combine chain by definition).
        out = _kahan_sum_all(x, plan, backend, prologue)
        return _kcommon.apply_epilogue(out, epilogue).astype(accum)
    return _backend_sum_all(backend, x, plan, prologue, epilogue).astype(
        accum
    )


def _to_rows(x: jax.Array, axis):
    """Move the reduced axes last and flatten them: -> ((..., L), batch_shape)."""
    keep = tuple(a for a in range(x.ndim) if a not in axis)
    xt = jnp.transpose(x, keep + axis)
    batch_shape = xt.shape[: len(keep)]
    red = int(math.prod(xt.shape[len(keep):]))
    return xt.reshape(batch_shape + (red,)), batch_shape, red


def _row_plan(plan: ReducePlan) -> ReducePlan:
    if plan.precision == "kahan":
        # Row reductions have no serial combine to compensate; the policy
        # degrades gracefully to exact-accumulator multipliers.
        return plan.replace(compute_dtype=plan.accum_dtype)
    return plan


def _sum_axis_impl(x: jax.Array, axis, plan: ReducePlan) -> jax.Array:
    backend = _backends.get_backend(plan.backend)
    accum = plan.accum_jnp
    flat, batch_shape, red = _to_rows(x, axis)
    if red == 0 or 0 in batch_shape:
        return jnp.zeros(batch_shape, accum)
    return backend.sum_axis(flat, _row_plan(plan)).astype(accum)


def _moments_axis_impl(x: jax.Array, axis, plan: ReducePlan):
    backend = _backends.get_backend(plan.backend)
    accum = plan.accum_jnp
    flat, batch_shape, red = _to_rows(x, axis)
    if red == 0 or 0 in batch_shape:
        z = jnp.zeros(batch_shape, accum)
        return z, z
    s, ss = backend.moments_axis(flat, _row_plan(plan))
    return s.astype(accum), ss.astype(accum)


# Kernel-backed full reductions (no native autodiff) get the custom VJPs.
# The kernel input is now the RAW leaf (the prologue maps it in-kernel), so
# the cotangent is the prologue's chain rule, not always a plain broadcast:
#   identity: dx = g            (broadcast of the cotangent)
#   square:   dx = 2 x g        (d/dx x^2)
#   abs:      dx = sign(x) g
# square/abs therefore retain x as the residual; identity keeps the
# zero-size shape carrier. An epilogue chain prepends its own scalar
# chain rule: the cotangent flows through jax.vjp of apply_epilogue at the
# RAW reduced total (kept as a residual by the fwd pass, which computes
# the reduction epilogue-free and applies the chain host-side -- same jnp
# ops on the same f32 scalar as the in-kernel primal, so the values
# match bitwise while the chain stays differentiable).


@functools.partial(jax.custom_vjp, nondiff_argnums=(1, 2, 3))
def _ksum(
    x: jax.Array,
    plan: ReducePlan,
    prologue: str = "identity",
    epilogue: tuple = (),
) -> jax.Array:
    return _sum_all_impl(x, plan, prologue, epilogue)


def _ksum_fwd(x, plan, prologue, epilogue):
    res = x if prologue != "identity" else jnp.zeros((0,) + x.shape, x.dtype)
    if not epilogue:
        return _sum_all_impl(x, plan, prologue), (res, None)
    raw = _sum_all_impl(x, plan, prologue)
    return _kcommon.apply_epilogue(raw, epilogue), (res, raw)


def _ksum_bwd(plan, prologue, epilogue, resid, g):
    res, raw = resid
    if epilogue:
        _, vjp_fn = jax.vjp(
            lambda s: _kcommon.apply_epilogue(s, epilogue), raw
        )
        (g,) = vjp_fn(g.astype(raw.dtype))
    if prologue == "identity":
        return (jnp.broadcast_to(g, res.shape[1:]).astype(res.dtype),)
    xf = res.astype(plan.accum_jnp)
    if prologue == "square":
        dx = 2.0 * xf * g
    else:  # abs
        dx = jnp.sign(xf) * g
    return (dx.astype(res.dtype),)


_ksum.defvjp(_ksum_fwd, _ksum_bwd)


def _sum(
    x: jax.Array,
    axis,
    plan: ReducePlan,
    prologue: str = "identity",
    epilogue: tuple = (),
) -> jax.Array:
    """Differentiable sum dispatch (see module docstring). ``prologue`` and
    ``epilogue`` are only meaningful for full reductions (axis=None);
    callers pre-map the rows of axis reductions (a fusible jnp op on the
    row backends)."""
    if axis is not None:
        return _sum_axis_impl(x, axis, plan)
    if _backends.get_backend(plan.backend).native_autodiff:
        return _sum_all_impl(x, plan, prologue, epilogue)
    return _ksum(x, plan, prologue, epilogue)


# Full-array moments: the (sum, sumsq) pair from one backend pass (the
# kernel backends run the paired dual-accumulator prologue -- one launch,
# one read of the raw leaf).


@functools.partial(jax.custom_vjp, nondiff_argnums=(1,))
def _kmoments(x: jax.Array, plan: ReducePlan):
    backend = _backends.get_backend(plan.backend)
    return backend.moments_all(x, plan)


def _kmoments_fwd(x, plan):
    return _kmoments(x, plan), x


def _kmoments_bwd(plan, res, g):
    gs, gss = g
    xf = res.astype(plan.accum_jnp)
    return ((gs + 2.0 * xf * gss).astype(res.dtype),)


_kmoments.defvjp(_kmoments_fwd, _kmoments_bwd)


def _moments_all(x: jax.Array, plan: ReducePlan):
    """Differentiable full-array (sum, sumsq) dispatch."""
    accum = plan.accum_jnp
    if x.size == 0:
        z = jnp.zeros((), accum)
        return z, z
    if plan.precision == "kahan":
        # The compensated combine wraps sum_all per statistic (two blocked
        # passes); the dual-accumulator kernel has no compensation rows.
        return (
            _sum(x, None, plan),
            _sum(x, None, plan, prologue="square"),
        )
    backend = _backends.get_backend(plan.backend)
    if backend.native_autodiff:
        s, ss = backend.moments_all(x, plan)
    else:
        s, ss = _kmoments(x, plan)
    return s.astype(accum), ss.astype(accum)


# ---------------------------------------------------------------------------
# Parts multi-reduce: S SEPARATE arrays summed in one backend pass with no
# packing copy (each part is its own kernel operand on the Pallas backends).
# This is the zero-copy engine behind reduce_many(axis=None) / reduce_tree.
# ---------------------------------------------------------------------------


def _sum_parts_impl(
    parts, plan: ReducePlan, prologue="identity", epilogue: tuple = ()
) -> jax.Array:
    backend = _backends.get_backend(plan.backend)
    accum = plan.accum_jnp
    if not parts:
        return jnp.zeros((0,), accum)
    if plan.precision == "kahan":
        # Parts have no serial combine to compensate (each flushes once);
        # degrade gracefully to exact-accumulator multipliers, like rows.
        plan = plan.replace(compute_dtype=plan.accum_dtype)
    if epilogue:
        return backend.sum_parts(
            tuple(parts), plan, prologue, epilogue=epilogue
        ).astype(accum)
    if prologue == "identity":
        return backend.sum_parts(tuple(parts), plan).astype(accum)
    return backend.sum_parts(tuple(parts), plan, prologue).astype(accum)


def _sum_parts_total_impl(
    parts, plan: ReducePlan, prologue="identity", chains=((),),
    census: bool = False,
) -> jax.Array:
    """(S + K,) vector: per-part sums plus chain k of the cross-part total
    at slot S + k -- one backend pass (the Pallas parts kernel finishes the
    chains in-launch via its total accumulator). ``census=True`` widens by
    S + 1 more slots: per-part non-finite counts then their total, counted
    in-kernel on the same pass (host reference on census-less backends)."""
    backend = _backends.get_backend(plan.backend)
    accum = plan.accum_jnp
    if plan.precision == "kahan":
        plan = plan.replace(compute_dtype=plan.accum_dtype)
    return _backends.sum_parts_total_with_census(
        backend, tuple(parts), plan, prologue, chains, census
    ).astype(accum)


@functools.partial(jax.custom_vjp, nondiff_argnums=(1, 2, 3))
def _ksum_parts(
    parts, plan: ReducePlan, prologue="identity", epilogue: tuple = ()
) -> jax.Array:
    return _sum_parts_impl(parts, plan, prologue, epilogue)


def _kparts_res(parts, prologue):
    # zero-size residuals carry identity parts' shape+dtype without
    # retaining them; mapped parts keep x for their chain rule
    pros = _kcommon.normalize_part_prologues(prologue, len(parts))
    return tuple(
        p if pro != "identity" else jnp.zeros((0,) + p.shape, p.dtype)
        for p, pro in zip(parts, pros)
    )


def _kparts_fwd(parts, plan, prologue, epilogue):
    res = _kparts_res(parts, prologue)
    if not epilogue:
        return _sum_parts_impl(parts, plan, prologue), (res, None)
    raw = _sum_parts_impl(parts, plan, prologue)
    return _kcommon.apply_epilogue(raw, epilogue), (res, raw)


def _kparts_chain_rule(plan, prologue, res, g):
    # Per-part cotangent: the prologue's chain rule against that part's
    # slot(s) -- identity: g[s] broadcast; square: 2 x g[s]; abs:
    # sign(x) g[s]; moments: g[s] + 2 x g[S + s] (both slots feed back).
    pros = _kcommon.normalize_part_prologues(prologue, len(res))
    nseg = len(res)
    accum = plan.accum_jnp
    outs = []
    for s, (r, pro) in enumerate(zip(res, pros)):
        if pro == "identity":
            outs.append(jnp.broadcast_to(g[s], r.shape[1:]).astype(r.dtype))
            continue
        xf = r.astype(accum)
        if pro == "square":
            dx = 2.0 * xf * g[s]
        elif pro == "abs":
            dx = jnp.sign(xf) * g[s]
        else:  # moments
            dx = g[s] + 2.0 * xf * g[nseg + s]
        outs.append(dx.astype(r.dtype))
    return (tuple(outs),)


def _kparts_bwd(plan, prologue, epilogue, resid, g):
    res, raw = resid
    if epilogue:
        # every epilogue step is elementwise, so one vjp over the (S,) raw
        # totals maps the cotangent back through the whole chain at once
        _, vjp_fn = jax.vjp(
            lambda s: _kcommon.apply_epilogue(s, epilogue), raw
        )
        (g,) = vjp_fn(g.astype(raw.dtype))
    return _kparts_chain_rule(plan, prologue, res, g)


_ksum_parts.defvjp(_kparts_fwd, _kparts_bwd)


@functools.partial(jax.custom_vjp, nondiff_argnums=(1, 2, 3, 4))
def _ksum_parts_total(
    parts, plan: ReducePlan, prologue="identity", chains=((),),
    census: bool = False,
) -> jax.Array:
    return _sum_parts_total_impl(parts, plan, prologue, chains, census)


def _kparts_total_fwd(parts, plan, prologue, chains, census):
    res = _kparts_res(parts, prologue)
    per = _sum_parts_impl(parts, plan, prologue)
    total = jnp.sum(per)
    totals = jnp.stack(
        [_kcommon.apply_epilogue(total, ch) for ch in chains]
    ).astype(per.dtype)
    pieces = [per, totals]
    if census:
        # differentiated forward only (the primal path stays in-kernel):
        # the reference host census fills the count slots
        pieces.append(_backends.host_nonfinite_census(parts, per.dtype))
    return jnp.concatenate(pieces), (res, total)


def _kparts_total_bwd(plan, prologue, chains, census, resid, g):
    # Slot s feeds both its own output g[s] and (through the cross-part
    # total) every chain output g[S + k], each mapped back through jax.vjp
    # of its chain at the raw total. The census count slots (when present)
    # are piecewise-constant in the inputs -- zero cotangent, dropped.
    res, total = resid
    nseg = len(res)
    gtot = jnp.zeros((), total.dtype)
    for k, ch in enumerate(chains):
        _, vjp_fn = jax.vjp(
            lambda s, _ch=ch: _kcommon.apply_epilogue(s, _ch), total
        )
        (dk,) = vjp_fn(g[nseg + k].astype(total.dtype))
        gtot = gtot + dk
    gslots = g[:nseg] + gtot
    return _kparts_chain_rule(plan, prologue, res, gslots)


_ksum_parts_total.defvjp(_kparts_total_fwd, _kparts_total_bwd)


def _sum_parts(
    parts, plan: ReducePlan, prologue="identity", epilogue: tuple = ()
) -> jax.Array:
    """Differentiable parts-sum dispatch (see module docstring)."""
    parts = tuple(parts)
    if not isinstance(prologue, str):
        prologue = tuple(prologue)  # hashable custom_vjp nondiff argument
    if _backends.get_backend(plan.backend).native_autodiff:
        return _sum_parts_impl(parts, plan, prologue, epilogue)
    return _ksum_parts(parts, plan, prologue, epilogue)


def _sum_parts_total(
    parts, plan: ReducePlan, prologue="identity", chains=((),),
    census: bool = False,
) -> jax.Array:
    """Differentiable parts-sum-plus-epilogue'd-total dispatch."""
    parts = tuple(parts)
    if not isinstance(prologue, str):
        prologue = tuple(prologue)
    if _backends.get_backend(plan.backend).native_autodiff:
        return _sum_parts_total_impl(parts, plan, prologue, chains, census)
    return _ksum_parts_total(parts, plan, prologue, chains, census)


def _resolve_plan(x, axis, kind, plan, backend, m, tiles_per_block,
                  compute_dtype, accum_dtype, precision,
                  kahan_block=None, segments=None, num_cores=None,
                  mesh_axes=None) -> ReducePlan:
    if plan is None:
        return plan_for(
            x.shape,
            x.dtype,
            kind=kind,
            axis=axis if axis != _NO_AXES else None,
            backend=backend,
            m=m,
            tiles_per_block=tiles_per_block,
            num_cores=num_cores,
            compute_dtype=compute_dtype,
            accum_dtype=accum_dtype,
            precision=precision,
            kahan_block=kahan_block,
            segments=segments,
            mesh_axes=mesh_axes,
        )
    overrides = {}
    if backend is not None:
        overrides["backend"] = backend
    if m is not None:
        overrides["m"] = int(m)
    if tiles_per_block is not None:
        overrides["tiles_per_block"] = int(tiles_per_block)
    if num_cores is not None:
        overrides["num_cores"] = int(num_cores)
    if compute_dtype is not None:
        overrides["compute_dtype"] = str(jnp.dtype(compute_dtype))
    if accum_dtype is not None:
        overrides["accum_dtype"] = str(jnp.dtype(accum_dtype))
    if precision is not None:
        overrides["precision"] = precision
    if kahan_block is not None:
        overrides["kahan_block"] = int(kahan_block)
    if mesh_axes is not None:
        overrides["mesh_axes"] = norm_mesh_axes(mesh_axes)
    return plan.replace(**overrides) if overrides else plan


def _cross_combine(row: jax.Array, plan: ReducePlan) -> jax.Array:
    """Fold per-device ADDITIVE partials across plan.mesh_axes (the
    deterministic fixed-order combine; see Backend.cross_device_combine)."""
    return _backends.get_backend(plan.backend).cross_device_combine(row, plan)


def _reduce_mesh_full(x: jax.Array, kind: str, p: ReducePlan, chain: tuple):
    """Full reduction inside a shard_map body (``p.mesh_axes`` bound): the
    local launch computes the shard's ADDITIVE statistic exactly as the
    single-device path would (one pallas_call per device on the kernel
    backends), one deterministic fixed-order combine folds the per-device
    partials in static device order, and the kind's finisher plus the
    epilogue chain apply host-side to the combined total -- identical jnp
    ops on identical replicated values, so the global statistic is
    BIT-identical on every replica at any device count. The finishers run
    post-combine by necessity: sqrt/mean/chains are not additive, so they
    cannot be applied before the cross-device fold without changing the
    statistic."""
    from repro.core import collectives as _coll  # deferred: import cycle

    lp = p.replace(mesh_axes=())
    if kind == "moments":
        s, ss = _moments_all(x, lp)
        row = _cross_combine(jnp.stack([s, ss]), p)
        return row[0], row[1]
    if kind in ("sumsq", "norm2"):
        local = _sum(x, None, lp, prologue="square")
    else:
        local = _sum(x, None, lp)
    total = _cross_combine(local, p)
    if kind == "mean":
        # global count: equal shards by shard_map construction. An empty
        # mean keeps the 0/0 -> NaN semantics of the single-device path.
        count = x.size * _coll.mesh_world_size(p.mesh_axes)
        total = total * ((1.0 / count) if count else float("nan"))
    if kind == "norm2":
        total = jnp.sqrt(total)
    return _kcommon.apply_epilogue(total, chain)


def _reduce_census_full(x: jax.Array, kind: str, p: ReducePlan, chain: tuple):
    """Full reduction + in-launch non-finite census of one array: the
    ``reduce_tree(census=True)`` row machinery restricted to a single leaf.
    Returns ``(statistic, count)``. The kind's finisher (norm2's sqrt,
    mean's 1/n) and the epilogue chain fold into the launch's total chain
    on the kernel backends; the count comes back in the same row's tail
    slot -- zero extra HBM input bytes, one launch. Under ``p.mesh_axes``
    the additive row (per-leaf sum, raw total, counts) rides the one
    fixed-order combine and the finishers apply post-combine on the
    replicated totals -- statistic AND count bit-identical per replica."""
    accum = p.accum_jnp
    prologue = "square" if kind in ("sumsq", "norm2") else "identity"
    post = chain
    if kind == "norm2":
        post = (("sqrt",),) + post
    if kind == "mean":
        n = x.size
        if p.mesh_axes:
            from repro.core import collectives as _coll  # deferred: cycle

            n = n * _coll.mesh_world_size(p.mesh_axes)
        post = (("scale", 1.0 / n if n else float("nan")),) + post
    if x.size == 0:
        z = jnp.zeros((), accum)
        return _kcommon.apply_epilogue(z, post).astype(accum), z
    if p.mesh_axes:
        lp = p.replace(mesh_axes=())
        row = _sum_parts_total([x], lp, prologue, ((),), True)
        row = _cross_combine(row, p)
        return _kcommon.apply_epilogue(row[1], post).astype(accum), row[3]
    row = _sum_parts_total([x], p, prologue, (post,), True)
    # row layout: [per-part sum (1) | chain output (1) | counts (2: part0,
    # total)] -- the finished statistic is slot 1, the total count slot 3
    return row[1], row[3]


def reduce(
    x,
    axis: Axis = None,
    kind: str = "sum",
    *,
    plan: Optional[ReducePlan] = None,
    backend: Optional[str] = None,
    m: Optional[int] = None,
    tiles_per_block: Optional[int] = None,
    num_cores: Optional[int] = None,
    compute_dtype=None,
    accum_dtype=None,
    precision: Optional[str] = None,
    kahan_block: Optional[int] = None,
    epilogue=None,
    census: bool = False,
    mesh_axes=None,
):
    """Reduce ``x`` over ``axis`` (None = all elements; () = no axes,
    matching numpy's empty-tuple convention).

    kind:
      "sum"     -- plain sum, result dtype = plan.accum_dtype.
      "mean"    -- sum / reduced-element count. An EMPTY full reduction is
                   the 0/0 indeterminate and returns NaN BY DEFINITION
                   (numpy's empty-mean semantics) on every backend and on
                   both the plain and the epilogue (in-launch 1/n scale)
                   paths. Guarded consumers must treat that NaN as a
                   defined statistic, not a fault: the non-finite census
                   (``reduce_tree(census=True)``) tallies INPUT elements
                   only, so an empty mean never increments it.
      "sumsq"   -- sum of squares. Full reductions square IN-KERNEL at
                   plan.compute_dtype on the kernel backends (f32 by
                   planner default for sumsq/norm2 -- pin compute_dtype
                   to trade accuracy for width) and at accumulator
                   precision on the jnp-level backends; axis reductions
                   always square at accumulator precision.
      "norm2"   -- sqrt(sumsq): the L2 norm / clipping statistic.
      "moments" -- (sum, sumsq) pair: exactly what LayerNorm/RMSNorm need;
                   axis reductions fuse both moments into one stacked
                   all-ones dot (one MXU pass); full reductions ride the
                   kernel backends' (x, x^2) dual accumulator -- one pass
                   over the raw leaf, squares at plan.compute_dtype (bf16
                   by default for this kind).

    ``plan`` pins the full execution strategy; the keyword overrides adjust
    individual fields (of the given plan, or of the planner's choice) --
    ``num_cores`` stripes the Pallas kernels across that many parallel
    lanes, ``kahan_block`` sizes the compensated combine when
    ``precision="kahan"``. All kinds are differentiable on all backends
    (Pallas backends: reverse mode).

    ``epilogue`` appends a scalar post-combine chain to a FULL reduction
    (axis=None; not "moments"): a step name ("sqrt"), a ``(name, *params)``
    step, or a tuple of steps -- see ``kernels.common.EPILOGUES``. The
    chain composes AFTER the kind's own folding (norm2's sqrt and mean's
    1/n scale become leading chain steps), and on the Pallas backends it
    runs inside the reduction launch wherever the final combine does --
    ``reduce(g, kind="norm2", epilogue=("clip_coeff", max_norm))`` returns
    the clipping coefficient with no host-side sqrt/min/div eqns.
    ``epilogue=None`` / ``"identity"`` / ``()`` is the empty chain: the
    pre-epilogue code path, byte-for-byte.

    ``census=True`` makes the SAME launch also count the NaN/Inf elements
    of ``x``: the return becomes a ``(statistic, count)`` pair, the count a
    scalar in plan.accum_dtype. On the kernel backends the count rides the
    second in-kernel accumulator over the tiles already streaming -- zero
    extra HBM input bytes, exactly the ``reduce_tree(census=True)``
    machinery restricted to one leaf -- so a serving engine's per-step
    logit statistic doubles as its non-finite detector for free. FULL
    reductions only (axis=None), kinds sum/mean/sumsq/norm2; composes with
    ``epilogue`` (the chain finishes the statistic, the count is raw) and
    with ``mesh_axes`` (both halves ride the one fixed-order combine). The
    count tallies INPUT elements only -- an empty mean's definitional NaN
    never increments it.

    ``mesh_axes`` (an axis name or tuple of names, bound by an enclosing
    ``shard_map``) makes a FULL reduction global across the mesh: the local
    shard runs the normal backend launch, then a deterministic fixed-order
    all-gather fold (``core.collectives.fixed_order_combine`` -- never an
    opaque ``psum``) combines the per-device partials, so the returned
    statistic is replicated AND bit-identical on every device at any
    device count. Finishers (norm2's sqrt, mean's 1/n, the epilogue chain)
    apply after the combine on the replicated total.
    """
    if kind not in KINDS:
        raise ValueError(f"unknown kind {kind!r}; expected one of {KINDS}")
    chain = _kcommon.normalize_epilogue(epilogue)
    x = jnp.asarray(x)
    axis_t = _normalize_axis(axis, x.ndim)
    if census:
        if axis_t is not None:
            raise ValueError(
                "census=True applies to FULL reductions (axis=None): the "
                "count shares the statistic's launch; got axis="
                f"{axis!r}"
            )
        if kind == "moments":
            raise ValueError(
                "census=True does not compose with kind='moments' (the "
                "dual accumulator already uses the second slot); census "
                "the statistic you need instead"
            )
    if chain:
        if axis_t is not None:
            raise ValueError(
                "epilogue chains apply to the single scalar a FULL "
                f"reduction produces; got axis={axis!r}"
            )
        if kind == "moments":
            raise ValueError(
                "epilogue chains do not compose with kind='moments' (two "
                "coupled outputs); chain the statistic you need instead"
            )
    p = _resolve_plan(x, axis_t, kind, plan, backend, m, tiles_per_block,
                      compute_dtype, accum_dtype, precision, kahan_block,
                      num_cores=num_cores, mesh_axes=mesh_axes)
    if census:
        return _reduce_census_full(x, kind, p, chain)
    if p.mesh_axes:
        if axis_t is not None:
            raise ValueError(
                "mesh_axes= applies to FULL reductions (axis=None): the "
                "cross-device combine produces one global statistic; got "
                f"axis={axis!r}"
            )
        return _reduce_mesh_full(x, kind, p, chain)
    if axis_t == _NO_AXES and axis is not None:
        # reduce over no axes: the elementwise identity of each kind
        xf = x.astype(p.accum_jnp)
        if kind in ("sum", "mean"):
            return xf
        if kind == "sumsq":
            return xf * xf
        if kind == "norm2":
            return jnp.abs(xf)
        return xf, xf * xf  # moments
    if kind == "sum":
        return _sum(x, axis_t, p, epilogue=chain)
    if kind == "mean":
        count = (
            x.size
            if axis_t is None
            else int(math.prod(x.shape[a] for a in axis_t))
        )
        if chain:
            # fold the 1/n into the chain: the mean (and everything after
            # it) finishes inside the launch (empty x: nan scale keeps the
            # 0/0 semantics of the plain path)
            inv = 1.0 / count if count else float("nan")
            return _sum(x, None, p, epilogue=(("scale", inv),) + chain)
        return _sum(x, axis_t, p) / count
    if axis_t is None:
        # Full reductions run the IN-KERNEL prologue: the backend squares
        # (or pairs, for moments) each element after its own native-dtype
        # ingest, so the raw leaf streams exactly once -- no host-side
        # n-sized square, no f32 staging write (jnp-level backends apply
        # the same map as fusible XLA code at accumulator precision).
        if kind == "sumsq":
            return _sum(x, None, p, prologue="square", epilogue=chain)
        if kind == "norm2":
            if chain:
                # the norm's sqrt becomes the chain's leading step, so the
                # whole statistic (norm -> clip/rsqrt/...) stays in-launch
                return _sum(
                    x, None, p, prologue="square",
                    epilogue=(("sqrt",),) + chain,
                )
            return jnp.sqrt(_sum(x, None, p, prologue="square"))
        return _moments_all(x, p)
    # Axis (row) reductions are batched eq. (9) dots on every backend; the
    # square is host-side jnp code XLA fuses into the dot's operand.
    xf = x.astype(p.accum_jnp)
    if kind == "sumsq":
        return _sum(xf * xf, axis_t, p)
    if kind == "norm2":
        return jnp.sqrt(_sum(xf * xf, axis_t, p))
    return _moments_axis_impl(x, axis_t, p)


def _reduce_many_full(arrs, kind, plan: ReducePlan, chain: tuple = ()):
    """Per-array FULL reductions via one parts pass (see reduce_many).

    Every leaf is handed to the backend as its own operand in its NATIVE
    dtype -- the packed accumulator-dtype stream (an n-sized
    convert+concatenate staging copy on the kernel backends) is gone; the
    jnp-level backends still pack internally, where XLA fuses it. Squares
    for sumsq/norm2/moments are the IN-KERNEL prologue on the kernel
    backends (the raw leaves stream exactly once; moments rides the paired
    dual accumulator, so both statistics come from the same single read)
    and fusible accumulator-precision jnp code on the rest. ``chain`` (a
    normalized epilogue; sum/sumsq/norm2 only) maps every per-array
    statistic at its flush."""
    accum = plan.accum_jnp
    sizes = [int(a.size) for a in arrs]

    if kind in ("sum", "mean"):
        out = _sum_parts(arrs, plan, epilogue=chain)
        if kind == "mean":
            out = out / jnp.asarray([max(s, 1) for s in sizes], accum)
        return out
    if kind == "sumsq":
        return _sum_parts(arrs, plan, prologue="square", epilogue=chain)
    if kind == "norm2":
        if chain:
            return _sum_parts(
                arrs, plan, prologue="square", epilogue=(("sqrt",),) + chain
            )
        return jnp.sqrt(_sum_parts(arrs, plan, prologue="square"))
    # moments: both statistics ride the SAME single pass (the widened
    # (2S,) layout -- sums in [0, S), sums of squares in [S, 2S))
    out = _sum_parts(arrs, plan, prologue="moments")
    s = len(arrs)
    return out[:s], out[s:]


def _reduce_many_full_mesh(arrs, kind, p: ReducePlan, chain: tuple):
    """``reduce_many(axis=None)`` inside a shard_map body: one local parts
    launch produces the shard's additive (N,) (or (2N,) moments) vector,
    one fixed-order combine folds the per-device vectors elementwise, and
    the finishers/chain map the replicated global vector -- every slot
    bit-identical on every replica (see _reduce_mesh_full)."""
    from repro.core import collectives as _coll  # deferred: import cycle

    lp = p.replace(mesh_axes=())
    accum = lp.accum_jnp
    s = len(arrs)
    if kind == "moments":
        out = _cross_combine(_sum_parts(arrs, lp, prologue="moments"), p)
        return out[:s], out[s:]
    pro = "square" if kind in ("sumsq", "norm2") else "identity"
    out = _cross_combine(_sum_parts(arrs, lp, prologue=pro), p)
    if kind == "mean":
        world = _coll.mesh_world_size(p.mesh_axes)
        out = out / jnp.asarray(
            [max(int(a.size) * world, 1) for a in arrs], accum
        )
    if kind == "norm2":
        out = jnp.sqrt(out)
    return _kcommon.apply_epilogue(out, chain)


def _reduce_many_rows(arrs, kind, plan: ReducePlan):
    """Per-array LAST-AXIS reductions in one width-padded backend pass.

    Arrays of differing widths are zero-padded to the widest row (exact for
    sum/sumsq: f32 accumulation of zeros is the identity) and concatenated
    into one (sum-of-batches, L_max) row stream, so the statistics of every
    array ride a single eq. (9) dot. Native jnp throughout -> jvp and vjp
    both flow, like any engine row reduction.
    """
    accum = plan.accum_jnp
    for a in arrs:
        if a.ndim == 0:
            raise ValueError("reduce_many(axis=-1) needs arrays of ndim >= 1")
    batch_shapes = [a.shape[:-1] for a in arrs]
    widths = [int(a.shape[-1]) for a in arrs]
    rows_per = [int(math.prod(bs)) for bs in batch_shapes]
    # Degenerate leaves (zero width or zero batch) contribute nothing to the
    # stream; they come back as additive identities of the correct shapes,
    # matching reduce()'s zero-size convention.
    live = [i for i in range(len(arrs)) if widths[i] > 0 and rows_per[i] > 0]

    def _identities():
        return [jnp.zeros(bs, accum) for bs in batch_shapes]

    if not live:
        z = _identities()
        return (z, _identities()) if kind == "moments" else z
    lmax = max(widths[i] for i in live)

    def _stream(parts):
        rows = []
        for i in live:
            r = parts[i].astype(accum).reshape(-1, widths[i])
            if widths[i] < lmax:
                r = jnp.pad(r, ((0, 0), (0, lmax - widths[i])))
            rows.append(r)
        return rows[0] if len(rows) == 1 else jnp.concatenate(rows, 0)

    def _split(flat_out):
        bounds = np.cumsum([rows_per[i] for i in live])[:-1]
        pieces = jnp.split(flat_out, [int(b) for b in bounds], axis=0)
        outs = _identities()
        for i, p_ in zip(live, pieces):
            outs[i] = p_.reshape(batch_shapes[i])
        return outs

    rp = _row_plan(plan)
    backend = _backends.get_backend(rp.backend)
    if kind == "moments":
        s, ss = backend.moments_axis(_stream(arrs), rp)
        return _split(s.astype(accum)), _split(ss.astype(accum))
    if kind in ("sumsq", "norm2"):
        src = [jnp.square(a.astype(accum)) for a in arrs]
    else:
        src = list(arrs)
    out = backend.sum_axis(_stream(src), rp).astype(accum)
    outs = _split(out)
    if kind == "mean":
        outs = [o / max(w, 1) for o, w in zip(outs, widths)]
    elif kind == "norm2":
        outs = [jnp.sqrt(o) for o in outs]
    return outs


def reduce_many(
    arrays,
    kind: str = "sum",
    *,
    axis: Optional[int] = None,
    plan: Optional[ReducePlan] = None,
    backend: Optional[str] = None,
    m: Optional[int] = None,
    tiles_per_block: Optional[int] = None,
    num_cores: Optional[int] = None,
    compute_dtype=None,
    accum_dtype=None,
    precision: Optional[str] = None,
    kahan_block: Optional[int] = None,
    epilogue=None,
    mesh_axes=None,
):
    """Reduce N independent arrays in ONE backend pass (segmented
    multi-reduce) instead of N separate launches.

    ``arrays`` is any pytree (typically a list); leaves are reduced in
    ``tree_leaves`` order. With ``axis=None`` every leaf is fully reduced
    and the result is a single stacked ``(N,)`` vector (``kind="moments"``:
    a ``(sums, sumsqs)`` pair of ``(N,)`` vectors -- both moments ride the
    same pass as 2N segments). With ``axis=-1`` (the only supported axis)
    each leaf is reduced over its own last axis -- widths may differ -- and
    the result is a *list* of per-leaf arrays (moments: a pair of lists).

    Execution: one ``jax.ops.segment_sum`` (xla), one batched eq. (9) dot
    over the zero-padded tile stream (mma_jnp), or one multi-operand launch
    of the parts kernel (both pallas modes; each leaf streams zero-copy in
    its native dtype as its own operand) -- ``n/m^2 + N`` MMAs for the
    whole batch and no packing copy. The planner's auto route is the
    registered "segmented" backend. Differentiation: the custom VJP
    generalizes the broadcast-cotangent rule per part, so ``jax.grad``
    flows through every backend.

    ``epilogue`` (full reductions; "sum"/"sumsq"/"norm2" only) maps every
    per-array statistic through one scalar chain at its in-kernel flush --
    see ``reduce``. "mean" is excluded because its per-array 1/n scales
    differ, and a chain carries one parameter set per launch.

    ``mesh_axes`` (inside a shard_map body; ``axis=None`` only) makes every
    per-array statistic global across the mesh via the deterministic
    fixed-order combine -- the whole (N,) vector rides ONE all-gather, and
    each slot is bit-identical on every device. See ``reduce``.
    """
    if kind not in KINDS:
        raise ValueError(f"unknown kind {kind!r}; expected one of {KINDS}")
    if axis not in (None, -1):
        raise ValueError(
            f"reduce_many reduces each array fully (axis=None) or over its "
            f"last axis (axis=-1); got axis={axis!r}"
        )
    chain = _kcommon.normalize_epilogue(epilogue)
    if chain:
        if axis is not None:
            raise ValueError(
                "reduce_many epilogues apply to full reductions "
                f"(axis=None); got axis={axis!r}"
            )
        if kind in ("mean", "moments"):
            raise ValueError(
                f"reduce_many epilogues do not compose with kind={kind!r} "
                "(mean: per-array 1/n scales differ; moments: two coupled "
                "outputs)"
            )
    arrs = [jnp.asarray(a) for a in jax.tree_util.tree_leaves(arrays)]
    nseg = len(arrs)
    if nseg == 0:
        accum = jnp.dtype(accum_dtype) if accum_dtype is not None else jnp.float32
        z = jnp.zeros((0,), accum)
        return ((z, z) if kind == "moments" else z) if axis is None else \
            (([], []) if kind == "moments" else [])
    total = sum(int(a.size) for a in arrs)
    probe = jax.ShapeDtypeStruct((total,), jnp.result_type(*arrs))
    p = _resolve_plan(
        probe, None if axis is None else (-1,), kind, plan, backend, m,
        tiles_per_block, compute_dtype, accum_dtype, precision, kahan_block,
        segments=nseg, num_cores=num_cores, mesh_axes=mesh_axes,
    )
    if p.mesh_axes:
        if axis is not None:
            raise ValueError(
                "mesh_axes= applies to FULL per-array reductions "
                f"(axis=None); got axis={axis!r}"
            )
        return _reduce_many_full_mesh(arrs, kind, p, chain)
    if axis is None:
        return _reduce_many_full(arrs, kind, p, chain)
    return _reduce_many_rows(arrs, kind, p)


def reduce_tree(
    tree,
    kind: str = "sumsq",
    *,
    plan: Optional[ReducePlan] = None,
    backend: Optional[str] = None,
    m: Optional[int] = None,
    num_cores: Optional[int] = None,
    epilogue=None,
    return_per_leaf: bool = False,
    census: bool = False,
    mesh_axes=None,
):
    """Reduce a whole pytree to one scalar ("sum", "sumsq" or "norm2").

    This is the optimizer's gradient-clipping statistic -- the highest-volume
    full reduction in a training step -- routed through the engine. Every
    leaf's row partials feed ONE multi-operand pass (``sum_parts``): on the
    Pallas backends the whole pytree costs a single kernel launch with each
    partial entering as its own operand -- no intermediate f32
    concatenation -- where the pre-segmented engine paid one XLA reduce per
    leaf plus a launch for the stacked partials. The trailing combine of
    the S per-leaf scalars is a plain ``jnp.sum`` (S = leaf count,
    trivially small).

    On the KERNEL backends (``native_prologue``) the leaves themselves are
    the launch operands: each raw bf16/f16/f32 leaf streams zero-copy into
    the parts kernel, which squares it in-kernel (the square prologue) --
    the whole-pytree norm is ONE launch, ONE read of every leaf, with no
    host-side square pass and no f32 staging write. Pallas kernels are
    single-device executors, so leaf-direct ingestion costs nothing there.

    SHARDING-CRITICAL (jnp-level backends): each leaf is reduced as a
    *last-axis* all-ones dot (eq. 9) BEFORE packing -- only the small local
    row partials enter the concatenated stream, never the sharded leaves
    themselves. Flattening a leaf into (k, m, m) tiles first would reshape
    across sharded dimensions and force GSPMD to all-gather the full tensor
    (for a 132B model that is a 169 GB gather per step -- caught by the
    dry-run; see EXPERIMENTS.md). The last-axis dot keeps every MMA on the
    local shard, and the cross-device rungs of the paper's hierarchy are
    GSPMD's own reduce of the packed partials -- eq. (13) continued over
    the mesh, as designed. Under GSPMD, route through mma_jnp/xla (the
    planner's auto route off-TPU), which keep exactly this property.

    ``epilogue`` finishes the tree statistic inside the same launch: one
    chain (``("clip_coeff", max_norm)``) or a LIST of chains -- the fork --
    for several scalars from the one reduction (``[(), ("clip_coeff",
    c)]`` -> the ``(statistic, clip)`` pair the optimizer wants). Chains
    apply to the KIND's statistic (for "norm2" the norm itself -- the sqrt
    becomes each chain's leading step), and on the kernel backends they run
    in the parts kernel's in-launch total accumulator at ANY num_cores --
    zero host-side sqrt/min/div eqns (``inspect.assert_epilogue_free``
    checks exactly this). A fork returns a ``(K,)`` vector, chain k's
    scalar at slot k; a single chain returns a scalar.
    ``return_per_leaf=True`` additionally returns the RAW per-leaf partial
    sums (no sqrt, no chain) as ``(per_leaf, result)`` -- the fused
    second-moment consumer reads per-leaf sumsq and the clip coefficient
    from the same single launch.

    ``census=True`` makes the SAME launch also count every NaN/Inf element
    of the tree: the return gains a trailing ``counts`` vector of S + 1
    f32 slots -- per-leaf non-finite counts then their total -- so the
    full shape is ``(result, counts)`` or ``(per_leaf, result, counts)``.
    On the kernel backends the counts ride a second in-kernel accumulator
    over the tiles already streaming (zero extra HBM input bytes; only the
    output row widens -- this is the guarded optimizer's NaN/Inf detector);
    jnp-level backends compute the same counts as fusible host code. The
    counts tally INPUT elements only: statistics that are legitimately NaN
    by definition (e.g. an empty ``kind="mean"``'s 0/0 -- see ``reduce``)
    never enter the census.

    ``mesh_axes`` (an axis name or tuple, bound by an enclosing
    ``shard_map``) makes the whole statistic GLOBAL across the mesh: each
    device runs its normal local launch over its shard's leaves (still one
    pallas_call on the kernel backends, census counted in-kernel), the
    additive row -- per-leaf sums, raw cross-leaf total, census counts --
    rides ONE deterministic fixed-order all-gather fold
    (``core.collectives.fixed_order_combine``, never an opaque ``psum``),
    and the chains (norm2's sqrt included) finish on the replicated global
    total. Statistic, per-leaf partials, chain outputs, AND census counts
    are bit-identical on every replica at any device count -- which is what
    makes a guarded optimizer's skip decision provably the same on all
    hosts. Chains run host-side post-combine on this path by necessity
    (they must see the global total, which exists only after the
    cross-device fold).
    """
    if kind not in ("sum", "sumsq", "norm2"):
        raise ValueError(f"reduce_tree supports sum/sumsq/norm2; got {kind!r}")
    chains = None
    if epilogue is not None or return_per_leaf or census:
        chains = _kcommon.normalize_epilogue_fork(
            epilogue if epilogue is not None else ()
        )
        if kind == "norm2":
            # the norm's sqrt leads every chain: chains see the NORM
            chains = tuple((("sqrt",),) + ch for ch in chains)
    leaves = jax.tree_util.tree_leaves(tree)
    square = kind in ("sumsq", "norm2")
    if plan is None:
        # Probe with the TOTAL element count: the auto heuristic must see
        # the real problem size, not the (arbitrary) first leaf's shape.
        total = sum(int(math.prod(jnp.shape(leaf))) for leaf in leaves)
        plan = plan_for(
            (total,),
            jnp.float32,
            kind="sumsq" if square else "sum",
            backend=backend,
            m=m,
            num_cores=num_cores,
            compute_dtype="float32",  # exactness matters for clipping
            segments=len(leaves) or None,
            mesh_axes=mesh_axes,
        )
    elif backend is not None or m is not None or num_cores is not None \
            or mesh_axes is not None:
        plan = plan.replace(
            **{
                k: v
                for k, v in (
                    ("backend", backend),
                    ("m", m),
                    ("num_cores", num_cores),
                    ("mesh_axes", None if mesh_axes is None
                     else norm_mesh_axes(mesh_axes)),
                )
                if v is not None
            }
        )
    accum = plan.accum_jnp

    def _finish(per_leaf, out, counts=None):
        # fork of K chains -> (K,) vector; single chain -> its scalar
        if chains is not None and len(chains) == 1:
            out = out.reshape(())
        pieces = (out,)
        if return_per_leaf:
            pieces = (per_leaf,) + pieces
        if census:
            pieces = pieces + (counts,)
        return pieces[0] if len(pieces) == 1 else pieces

    if not leaves:
        if chains is None:
            return jnp.zeros((), accum)
        totals = jnp.stack(
            [
                _kcommon.apply_epilogue(jnp.zeros((), accum), ch)
                for ch in chains
            ]
        )
        # an empty tree streams nothing -> a lone zero total-count slot
        return _finish(
            jnp.zeros((0,), accum), totals, jnp.zeros((1,), accum)
        )
    if plan.mesh_axes:
        # Distributed path (inside a shard_map body): the local launch
        # produces the shard's ADDITIVE row -- per-leaf sums, the raw
        # cross-leaf total, the non-finite counts -- then ONE fixed-order
        # combine folds the per-device rows in static device order. Every
        # downstream value derives from the replicated combined row by
        # identical jnp ops, so all outputs are bit-identical per replica.
        lp = plan.replace(mesh_axes=())
        prologue = "square" if square else "identity"
        s = len(leaves)
        if _backends.get_backend(lp.backend).native_prologue:
            # one launch per device: the identity total chain makes
            # _sum_parts_total emit exactly [per-leaf | raw total | counts],
            # census counted in-kernel (zero extra input bytes)
            arrs = [jnp.asarray(leaf) for leaf in leaves]
            row = _sum_parts_total(arrs, lp, prologue, ((),), census)
        else:
            partials = []
            for leaf in leaves:
                xf = jnp.asarray(leaf).astype(accum)
                v = xf * xf if square else xf
                partials.append(
                    v.reshape(1) if v.ndim == 0
                    else _sum(v, (v.ndim - 1,), lp).reshape(-1)
                )
            per = _sum_parts(partials, lp)
            pieces = [per, jnp.sum(per)[None]]
            if census:
                pieces.append(
                    _backends.host_nonfinite_census(
                        [jnp.asarray(leaf) for leaf in leaves], accum
                    )
                )
            row = jnp.concatenate(pieces)
        row = _cross_combine(row, plan)
        total = row[s]
        if chains is None:
            return jnp.sqrt(total) if kind == "norm2" else total
        totals = jnp.stack(
            [_kcommon.apply_epilogue(total, ch) for ch in chains]
        ).astype(accum)
        return _finish(row[:s], totals, row[s + 1:] if census else None)
    if _backends.get_backend(plan.backend).native_prologue:
        # Kernel backends: the raw leaves ARE the launch operands; the
        # square runs in-kernel (single stream, single launch -- see the
        # docstring). No astype, no host square, no partial row pass.
        arrs = [jnp.asarray(leaf) for leaf in leaves]
        prologue = "square" if square else "identity"
        if chains is not None:
            # sum_parts_total: the cross-leaf total folds in-launch and the
            # chains finish it there too -- one launch, zero host eqns
            # (census: the counts come back in the same row's tail slots)
            out = _sum_parts_total(arrs, plan, prologue, chains, census)
            s, k = len(arrs), len(chains)
            if census:
                return _finish(out[:s], out[s:s + k], out[s + k:])
            return _finish(out[:s], out[s:])
        per_leaf = _sum_parts(arrs, plan, prologue=prologue)
        total = jnp.sum(per_leaf)
        return jnp.sqrt(total) if kind == "norm2" else total
    partials = []
    for leaf in leaves:
        xf = jnp.asarray(leaf).astype(accum)
        v = xf * xf if square else xf
        if v.ndim == 0:
            partials.append(v.reshape(1))
            continue
        rs = _sum(v, (v.ndim - 1,), plan)  # local last-axis dot per leaf
        partials.append(rs.reshape(-1))
    # ONE launch over every leaf's row partials, each entering the backend
    # as its own operand -- the old intermediate f32 concatenation of the
    # partials never materializes on the kernel backends.
    per_leaf = _sum_parts(partials, plan)
    total = jnp.sum(per_leaf)
    if chains is not None:
        # host-map reference semantics: same chains, same values (census:
        # the same reference counts over the raw leaves)
        totals = jnp.stack(
            [_kcommon.apply_epilogue(total, ch) for ch in chains]
        ).astype(accum)
        counts = (
            _backends.host_nonfinite_census(
                [jnp.asarray(leaf) for leaf in leaves], accum
            )
            if census
            else None
        )
        return _finish(per_leaf, totals, counts)
    return jnp.sqrt(total) if kind == "norm2" else total
