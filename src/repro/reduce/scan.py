"""``repro.scan``: prefix sums as a first-class engine op.

The scan analogue of ``repro.reduce.api``: resolve a ``ScanPlan`` (cost-
model auto-selection, memoized, quarantine-aware), normalize the axis and
direction at the ops layer, dispatch to the planned backend's
``scan_axis`` primitive, and wrap kernel-backed executions in a
``jax.custom_vjp`` (the cumsum cotangent rule: d/dx cumsum = the REVERSED
same-kind cumsum of the cotangent).

Direction and axis are pure layout: ``reverse=True`` is flip-scan-flip and
a non-last ``axis`` is moveaxis-scan-moveaxis, both OUTSIDE the custom
VJP (JAX differentiates the flips natively) and both absent from the
lowering's staging-primitive set -- ``rev``/``transpose`` are relayouts,
not copies, so the staging-free HLO contract survives them.

Dtype contract: the result is always ``x.dtype``, on every backend. The
COMPUTE dtype defaults to the operand's own native ingest dtype (see
``ScanPlan``) -- unlike reductions, every partial of a scan is consumer-
visible, and the MoE/data-packing offset consumers rely on f32-exact
integer prefixes.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.reduce import backends as _backends
from repro.reduce.plan import ScanPlan, scan_plan_for

SCAN_KINDS = ("cumsum",)


def _resolve_scan_plan(
    shape,
    dtype,
    plan: Optional[ScanPlan],
    backend,
    m,
    tiles_per_block,
    num_cores,
    compute_dtype,
) -> ScanPlan:
    """Explicit plan wins, with any explicit keyword merged over it (the
    ``api._resolve_plan`` override discipline); otherwise the memoized
    cost-model selection."""
    if plan is not None:
        kw = {}
        if backend is not None:
            kw["backend"] = backend
        if m is not None:
            kw["m"] = int(m)
        if tiles_per_block is not None:
            kw["tiles_per_block"] = int(tiles_per_block)
        if num_cores is not None:
            kw["num_cores"] = int(num_cores)
        if compute_dtype is not None:
            kw["compute_dtype"] = str(jnp.dtype(compute_dtype))
        return plan.replace(**kw) if kw else plan
    return scan_plan_for(
        shape,
        dtype,
        backend=backend,
        m=m,
        tiles_per_block=tiles_per_block,
        num_cores=num_cores,
        compute_dtype=compute_dtype,
    )


def _scan_impl(x, plan: ScanPlan, inclusive: bool, trace=None):
    return _backends.get_backend(plan.backend).scan_axis(
        x, plan, inclusive=inclusive, trace=trace
    )


@functools.partial(jax.custom_vjp, nondiff_argnums=(1, 2))
def _kscan(x, plan: ScanPlan, inclusive: bool):
    """Kernel-backed last-axis scan under the cumsum cotangent rule.

    y = cumsum(x) (inclusive)  =>  dx_i = sum_{k >= i} g_k  -- the reversed
    INCLUSIVE cumsum of g; the exclusive scan's cotangent is the reversed
    EXCLUSIVE cumsum (dx_i = sum_{k > i} g_k). Both are one more engine
    scan under the SAME plan, so the backward pass stays in the kernel
    economy instead of falling back to XLA."""
    return _scan_impl(x, plan, inclusive)


def _kscan_fwd(x, plan, inclusive):
    return _kscan(x, plan, inclusive), None


def _kscan_bwd(plan, inclusive, _res, g):
    dx = jnp.flip(_scan_impl(jnp.flip(g, -1), plan, inclusive), -1)
    return (dx,)


_kscan.defvjp(_kscan_fwd, _kscan_bwd)


def scan(
    x,
    axis: int = -1,
    kind: str = "cumsum",
    inclusive: bool = True,
    reverse: bool = False,
    *,
    plan: Optional[ScanPlan] = None,
    backend: Optional[str] = None,
    m: Optional[int] = None,
    tiles_per_block: Optional[int] = None,
    num_cores: Optional[int] = None,
    compute_dtype=None,
    trace: Optional[list] = None,
) -> jax.Array:
    """Prefix sum of ``x`` along ``axis`` through the engine's backends.

    ``inclusive=False`` emits the exclusive prefix (out[..., 0] == 0);
    ``reverse=True`` scans back-to-front (suffix sums). The result has
    ``x``'s shape and dtype on every backend. ``trace`` (a list) collects
    kernel instrumentation (``kernels.scan.ScanTrace``); passing it takes
    the non-differentiable direct path, so keep it to inspection code.
    """
    if kind not in SCAN_KINDS:
        raise ValueError(
            f"unknown scan kind {kind!r}; expected one of {SCAN_KINDS}"
        )
    x = jnp.asarray(x)
    if x.ndim == 0:
        raise ValueError("scan needs an operand with at least one axis")
    ax = int(axis) % x.ndim
    moved = jnp.moveaxis(x, ax, -1) if ax != x.ndim - 1 else x
    if reverse:
        moved = jnp.flip(moved, -1)
    rplan = _resolve_scan_plan(
        moved.shape, moved.dtype, plan, backend, m, tiles_per_block,
        num_cores, compute_dtype,
    )
    bk = _backends.get_backend(rplan.backend)
    if bk.native_autodiff or trace is not None:
        out = bk.scan_axis(moved, rplan, inclusive=inclusive, trace=trace)
    else:
        out = _kscan(moved, rplan, inclusive)
    if reverse:
        out = jnp.flip(out, -1)
    return jnp.moveaxis(out, -1, ax) if ax != x.ndim - 1 else out
