"""Unified reduction engine: one dispatch layer over every MMA-reduction path.

The paper's contribution is a single algorithmic idea -- encode the reduction
of ``n`` numbers as chained m x m MMA operations, ``T(n) = 5 log_{m^2}(n)`` --
and this package is its single entry point. ``reduce()`` serves every kind
("sum", "mean", "sumsq", "norm2", "moments") over every registered backend:

  xla          -- jnp baseline / oracle
  mma_jnp      -- the paper's hierarchy as pure-JAX dots (runs anywhere)
  pallas_hier  -- Pallas TPU kernel, paper-faithful multi-launch recurrence
  pallas_fused -- Pallas TPU kernel, single-launch C-accumulator variant

with a cost-model-driven planner (``ReducePlan`` / ``plan_for``) choosing the
backend, tile size ``m``, block depth, and dtypes per problem shape, and a
Kahan-compensated precision policy as an orthogonal option. Everything is
differentiable (custom VJP: broadcast of the cotangent).

Model, optimizer, launch and benchmark code all route reductions through
here; ``repro.core.mma_reduce`` and ``repro.kernels.mma_reduce`` are the
backend *implementations* and should not be called directly by new code.
"""

from repro.reduce.api import KINDS, reduce, reduce_tree  # noqa: F401
from repro.reduce.backends import (  # noqa: F401
    Backend,
    available_backends,
    get_backend,
    register_backend,
)
from repro.reduce.plan import (  # noqa: F401
    BACKEND_ENV,
    ReducePlan,
    backend_for_flags,
    default_backend,
    plan_for,
    set_default_backend,
)
