"""Unified reduction engine: one dispatch layer over every MMA-reduction path.

The paper's contribution is a single algorithmic idea -- encode the reduction
of ``n`` numbers as chained m x m MMA operations, ``T(n) = 5 log_{m^2}(n)`` --
and this package is its single entry point. ``reduce()`` serves every kind
("sum", "mean", "sumsq", "norm2", "moments") over every registered backend:

  xla          -- jnp baseline / oracle
  mma_jnp      -- the paper's hierarchy as pure-JAX dots (runs anywhere)
  pallas_hier  -- Pallas TPU kernel, paper-faithful multi-launch recurrence
  pallas_fused -- Pallas TPU kernel, single-launch C-accumulator variant
  segmented    -- auto-route for multi-reduce problems (resolves per call)

with a cost-model-driven planner (``ReducePlan`` / ``plan_for`` -- memoized,
with an opt-in empirical ``autotune``) choosing the backend, tile size ``m``,
block depth, lane count ``num_cores`` (the Pallas kernels stream a striped
("parallel", "arbitrary") grid -- one accumulator lane per TPU core, with a
deterministic fixed-order combine), and dtypes per problem shape, and a
Kahan-compensated precision policy as an orthogonal option. Everything is differentiable (custom VJP:
broadcast of the cotangent, per segment for the batched paths).

``reduce_many`` batches N independent reductions into ONE backend pass (one
segment_sum / one eq. (9) dot / one multi-operand Pallas launch), and
``reduce_tree`` rides the same machinery so a whole pytree's clipping
statistic costs a single kernel launch.

``scan`` (also exported as ``repro.scan``) extends the same encoding to
PREFIX sums with triangular MMA operands (Dakkak et al., PAPERS.md): a
``ScanPlan`` / ``scan_plan_for`` route over the same registry (xla
cumsum, mma_jnp triangular einsum, pallas_fused triangular kernel), the
same zero-copy native ingest and quarantine machinery, and a custom VJP
(cumsum cotangent = reversed cumsum).

Zero-copy ingestion: the Pallas paths read the caller's buffer directly --
flat native-dtype (bf16/f16/f32) BlockSpecs with the tile reshape, compute
cast, and tail masking done in-VMEM -- so a bf16 reduction moves n*2 HBM
bytes instead of the staged read-n*2 + write-n*4 + read-n*4. In-kernel
prologues extend the same property to the norm kinds: sumsq/norm2 square
(and moments pairs, via a dual accumulator) INSIDE the kernel body, so the
whole norm path -- including reduce_tree's clipping statistic -- streams
the raw leaf exactly once with no host-side elementwise pass.
``repro.reduce.inspect`` proves the property on lowered jaxprs
(``assert_staging_free`` / ``measured_hbm_bytes``) and
``cost_model.hbm_bytes`` models it; ``benchmarks/check_bench.py`` gates CI
on both.

Model, optimizer, launch and benchmark code all route reductions through
here; ``repro.core.mma_reduce`` and ``repro.kernels.mma_reduce`` are the
backend *implementations* and should not be called directly by new code.
"""

from repro.reduce.api import (  # noqa: F401
    KINDS,
    reduce,
    reduce_many,
    reduce_tree,
)
from repro.reduce.scan import (  # noqa: F401
    SCAN_KINDS,
    scan,
)
from repro.reduce.backends import (  # noqa: F401
    Backend,
    available_backends,
    get_backend,
    register_backend,
)
from repro.reduce.plan import (  # noqa: F401
    BACKEND_ENV,
    ReducePlan,
    ScanPlan,
    autotune,
    backend_for_flags,
    default_backend,
    plan_cache_clear,
    plan_cache_info,
    plan_for,
    quarantine_backend,
    quarantined_backends,
    reinstate_backend,
    scan_plan_cache_info,
    scan_plan_for,
    segmented_backend_for,
    set_default_backend,
)
