"""Jaxpr introspection for the reduction engine's zero-copy contract.

"Zero-copy proven, not claimed": the engine advertises that its Pallas
paths read the caller's buffer directly -- no n-sized
``convert_element_type`` (staging cast), ``pad`` (tile padding copy), or
``concatenate`` (stream packing) ever materializes outside the
``pallas_call`` itself. This module turns that sentence into a checkable
predicate over lowered jaxprs, plus a traffic meter that sums the bytes the
lowered kernels actually touch, so ``benchmarks/check_bench.py`` (CI), the
microbenches, and the test suite all audit the same property from the same
walker instead of re-implementing jaxpr string scraping.

The walker descends every sub-jaxpr (pjit bodies, custom_vjp calls, scan
branches, ...) EXCEPT the kernel jaxpr inside a ``pallas_call`` -- in-VMEM
reshape/cast/mask work is exactly what the zero-copy contract moves into
the kernel, so ops inside it are the solution, not a violation.
"""

from __future__ import annotations

import math
from typing import Iterator

import jax

try:  # jax >= 0.4.x exposes the public aliases under jax.extend
    from jax.extend import core as _core
except ImportError:  # pragma: no cover - older jax
    from jax import core as _core  # type: ignore

# Primitives that materialize a staging copy of their operand when they run
# at stream size outside the kernel. (reshape is absent on purpose: a
# same-size reshape of a contiguous buffer is metadata-only at the XLA
# level, and flat ingestion relies on exactly that.)
STAGING_PRIMITIVES = ("convert_element_type", "pad", "concatenate")


def _sub_jaxprs(params) -> Iterator[object]:
    """Every jaxpr reachable from an eqn's params (lists/tuples included)."""
    for v in params.values():
        vs = v if isinstance(v, (list, tuple)) else (v,)
        for u in vs:
            if isinstance(u, (_core.Jaxpr, _core.ClosedJaxpr)):
                yield u


def iter_eqns(jaxpr, *, _inside_pallas: bool = False):
    """Yield ``(eqn, inside_pallas)`` for every eqn in ``jaxpr`` and its
    sub-jaxprs; ``inside_pallas`` marks eqns lowered INTO a pallas kernel
    body (where the zero-copy contract places the reshape/cast/mask work).
    """
    if isinstance(jaxpr, _core.ClosedJaxpr):
        jaxpr = jaxpr.jaxpr
    for eqn in jaxpr.eqns:
        yield eqn, _inside_pallas
        nested = _inside_pallas or eqn.primitive.name == "pallas_call"
        for sub in _sub_jaxprs(eqn.params):
            yield from iter_eqns(sub, _inside_pallas=nested)


def _out_elems(eqn) -> int:
    return max(
        (int(math.prod(v.aval.shape)) for v in eqn.outvars), default=0
    )


def _out_bytes(eqn) -> int:
    return sum(
        int(math.prod(v.aval.shape)) * v.aval.dtype.itemsize
        for v in eqn.outvars
    )


# Additional primitives a caller can flag when auditing a MAPPED reduction
# (sumsq/norm2/moments): an n-sized multiply / power / sign outside the
# kernel is the host-side elementwise prologue pass the in-kernel prologues
# removed. Not in STAGING_PRIMITIVES by default because gradients
# legitimately produce n-sized multiplies (the 2x*g cotangent IS the
# output being built, not ingestion staging) -- only forward lowerings
# should be audited with these.
PROLOGUE_PRIMITIVES = ("mul", "integer_pow", "sign", "abs")


def staging_eqns(jaxpr, min_elems: int, extra_primitives: tuple = ()):
    """Staging copies at or above ``min_elems`` elements OUTSIDE any
    pallas_call: the ops the zero-copy ingestion contract forbids
    (``extra_primitives`` widens the audit, e.g. ``PROLOGUE_PRIMITIVES``
    for the single-stream sumsq/norm2 gate).

    Returns ``[(primitive_name, out_elems, out_bytes), ...]`` -- empty iff
    the lowered program never casts, pads, or concatenates a stream-sized
    buffer on the host side of the kernel boundary."""
    names = STAGING_PRIMITIVES + tuple(extra_primitives)
    found = []
    for eqn, inside in iter_eqns(jaxpr):
        if inside or eqn.primitive.name not in names:
            continue
        elems = _out_elems(eqn)
        if elems >= min_elems:
            found.append((eqn.primitive.name, elems, _out_bytes(eqn)))
    return found


# Primitives an in-launch EPILOGUE chain removes from the host side: the
# scalar post-combine math (a norm's sqrt, the clip coefficient's min/div,
# an rsqrt's reciprocal). These eqns are SIZE-1, so the n-sized
# ``staging_eqns`` walker can never see them -- ``assert_epilogue_free``
# audits them at ANY size instead. Only apply it to computations whose
# entire scalar tail is expected in-kernel (e.g. the optimizer's
# norm-and-clip statistic); ordinary model code uses these ops
# legitimately.
EPILOGUE_PRIMITIVES = ("sqrt", "rsqrt", "div", "min", "max")


def epilogue_eqns(jaxpr, primitives: tuple = EPILOGUE_PRIMITIVES):
    """Host-side (outside every pallas_call) occurrences of the epilogue
    primitives at any size: ``[(primitive_name, out_elems), ...]``."""
    found = []
    for eqn, inside in iter_eqns(jaxpr):
        if not inside and eqn.primitive.name in primitives:
            found.append((eqn.primitive.name, _out_elems(eqn)))
    return found


def assert_epilogue_free(
    fn, *args, primitives: tuple = EPILOGUE_PRIMITIVES
) -> None:
    """Trace ``fn(*args)`` and fail if any epilogue primitive survives on
    the host side of the kernel boundary -- the one-launch statistic's
    'no host-side sqrt/min/div eqns' property, checkable because scalar
    eqns are invisible to the n-sized staging walker."""
    jaxpr = jax.make_jaxpr(fn)(*args)
    bad = epilogue_eqns(jaxpr, primitives)
    assert not bad, (
        f"epilogue contract violated: post-combine scalar ops outside the "
        f"pallas_call: {bad}"
    )


# Primitives the in-kernel non-finite CENSUS removes from the host side:
# the n-sized ``is_finite`` sweep a host NaN/Inf check would lower to, and
# the n-sized ``select_n`` a masked skip would need. The guarded optimizer
# replaces both -- the census counts inside the reduction launch and the
# skip is an integer bit-blend (and/or/broadcast, never a select) -- so a
# guarded update's lowering should contain NEITHER at any size. Only apply
# to the optimizer-update computation: model forward passes use select_n
# legitimately (attention masks, dropout).
CENSUS_PRIMITIVES = ("is_finite", "select_n")


def census_eqns(jaxpr, min_elems: int = 1,
                primitives: tuple = CENSUS_PRIMITIVES):
    """Host-side (outside every pallas_call) occurrences of the census /
    masked-skip primitives at or above ``min_elems`` elements:
    ``[(primitive_name, out_elems), ...]``."""
    found = []
    for eqn, inside in iter_eqns(jaxpr):
        if inside or eqn.primitive.name not in primitives:
            continue
        elems = _out_elems(eqn)
        if elems >= min_elems:
            found.append((eqn.primitive.name, elems))
    return found


def assert_census_free(
    fn, *args, min_elems: int = 1, primitives: tuple = CENSUS_PRIMITIVES
) -> None:
    """Trace ``fn(*args)`` and fail if any ``is_finite`` / ``select_n``
    survives on the host side of the kernel boundary -- the guarded step's
    'the NaN check rides the reduction launch and the skip is a bit-blend'
    property. Default ``min_elems=1`` is the strict audit (no host
    occurrence at ANY size)."""
    jaxpr = jax.make_jaxpr(fn)(*args)
    bad = census_eqns(jaxpr, min_elems, primitives)
    assert not bad, (
        f"census contract violated: is_finite/select_n outside the "
        f"pallas_call (>= {min_elems} elems): {bad}"
    )


def assert_staging_free(
    fn, *args, min_elems: int | None = None, extra_primitives: tuple = ()
) -> None:
    """Trace ``fn(*args)`` and fail if any n-sized staging op survives
    outside the pallas_call. ``min_elems`` defaults to the largest operand's
    element count -- "n-sized" relative to the problem actually traced.
    Pass ``extra_primitives=PROLOGUE_PRIMITIVES`` to additionally forbid
    host-side elementwise prologue passes (the sumsq/norm2 single-stream
    property)."""
    if min_elems is None:
        min_elems = max(
            (int(math.prod(jax.numpy.shape(a))) for a in jax.tree_util.tree_leaves(args)),
            default=1,
        )
    jaxpr = jax.make_jaxpr(fn)(*args)
    bad = staging_eqns(jaxpr, min_elems, extra_primitives)
    assert not bad, (
        f"zero-copy contract violated: stream-sized staging ops outside the "
        f"pallas_call (>= {min_elems} elems): {bad}"
    )


def _aval_bytes(v) -> int:
    aval = v.aval
    return int(math.prod(aval.shape)) * aval.dtype.itemsize


def pallas_io_bytes(jaxpr) -> int:
    """Bytes crossing every pallas_call boundary in the lowered program:
    the sum of all kernel operands (data + scalar-prefetched maps) and
    results. For the zero-copy kernels this IS the modeled HBM traffic of
    the launch (each operand block is DMA'd once; dwelled parts blocks are
    not re-fetched), which is what makes the 'measured' column of the
    benchmark's HBM table honest on a CPU container: it is derived from the
    lowered program's actual operands, not from the model being checked."""
    total = 0
    for eqn, inside in iter_eqns(jaxpr):
        if inside or eqn.primitive.name != "pallas_call":
            continue
        total += sum(_aval_bytes(v) for v in eqn.invars)
        total += sum(_aval_bytes(v) for v in eqn.outvars)
    return total


# Cross-device collectives a distributed reduce may lower to. The
# deterministic fixed-order combine uses exactly ONE kind -- all_gather --
# so the distributed gate can both meter its wire bytes and assert that no
# opaque reduction collective (psum & friends, whose combine order is an
# implementation detail) sneaks into a path that promises bitwise
# reproducibility.
COLLECTIVE_PRIMITIVES = (
    "all_gather", "psum", "ppermute", "all_to_all", "reduce_scatter",
    "pmax", "pmin",
)


def collective_eqns(jaxpr):
    """Cross-device collective eqns outside every pallas_call:
    ``[(primitive_name, in_bytes, out_bytes), ...]``. The walker descends
    shard_map bodies, so collectives emitted inside a per-device program are
    visible."""
    found = []
    for eqn, inside in iter_eqns(jaxpr):
        if inside or eqn.primitive.name not in COLLECTIVE_PRIMITIVES:
            continue
        inb = sum(_aval_bytes(v) for v in eqn.invars if hasattr(v.aval, "shape"))
        found.append((eqn.primitive.name, inb, _out_bytes(eqn)))
    return found


def collective_recv_bytes(jaxpr) -> int:
    """Per-device interconnect bytes RECEIVED by the lowered program's
    ``all_gather`` eqns: each gather's output holds the local shard plus
    P-1 remote shards, so ``out_bytes - in_bytes = (P-1) * shard_bytes`` is
    exactly the wire traffic into this device. This is the 'lowered' side of
    ``cost_model.interconnect_bytes`` -- derived from the traced program's
    collectives, not from the model being checked."""
    return sum(
        out - inb
        for name, inb, out in collective_eqns(jaxpr)
        if name == "all_gather"
    )


def measured_hbm_bytes(fn, *args, min_elems: int = 0) -> int:
    """Traffic meter for one traced call: pallas_call boundary bytes plus
    the bytes of any host-side staging ops at/above ``min_elems`` (so a
    staged path is charged for its copies and a zero-copy path is not)."""
    jaxpr = jax.make_jaxpr(fn)(*args)
    staged = sum(
        nbytes for _, _, nbytes in staging_eqns(jaxpr, max(min_elems, 1))
    )
    return pallas_io_bytes(jaxpr) + staged


def count_pallas_calls(fn, *args) -> int:
    """Number of pallas_call launches in the lowered program (the 1-launch
    property check, without string scraping)."""
    jaxpr = jax.make_jaxpr(fn)(*args)
    return sum(
        1
        for eqn, inside in iter_eqns(jaxpr)
        if not inside and eqn.primitive.name == "pallas_call"
    )
