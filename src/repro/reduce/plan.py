"""Reduction planning: pick a backend + tile geometry from the problem shape.

A ``ReducePlan`` is the complete, hashable description of *how* one reduction
runs: which registered backend executes it, the linear MMA tile size ``m``,
the Pallas block depth ``tiles_per_block``, the multiplier/accumulator dtypes,
and the (orthogonal) precision policy. Plans are static metadata -- they are
resolved at trace time from shapes and feed ``jax.custom_vjp`` nondiff
arguments, so every field is a plain hashable Python value (dtypes are stored
as strings, not ``jnp.dtype`` objects).

``plan_for`` is the cost-model-driven selector: it consults
``repro.core.cost_model``'s TPU roofline (eq. 16's step model extended with
HBM/VPU/MXU terms) to decide whether the paper's MMA encoding pays for a
given extent, and which implementation of it to use. The default can be
overridden per call (``reduce(..., backend=...)``), per process
(``set_default_backend``), or per environment (``REPRO_REDUCE_BACKEND``).
Segmented multi-reduce problems (``segments=N``; see ``reduce_many``) route
to the registered "segmented" backend, which resolves its concrete executor
per call through ``segmented_backend_for``.

Plan cache: ``plan_for`` is memoized (process-wide LRU of
``_PLAN_CACHE_SIZE`` entries) on the fully-normalized argument tuple --
shape, dtype, kind, axis, segment count, and every explicit override. The
mutable process default (``set_default_backend`` / $REPRO_REDUCE_BACKEND) is
resolved *before* the cache lookup, so changing the default can never serve
a stale plan. A hit returns the *same* frozen ``ReducePlan`` object with no
cost-model re-run (plans also compare equal structurally, so identity is an
optimization, not a contract callers must rely on). ``plan_cache_info()`` /
``plan_cache_clear()`` expose the cache to tests and long-running servers.

Quarantine: ``quarantine_backend(name)`` takes a backend out of AUTO
rotation (the serving circuit breaker's trip hook) -- auto selections and
the segmented per-call route degrade along pallas -> mma_jnp -> xla, and
the memoized plans are invalidated so no stale plan can resurrect the
failed backend. Explicit pins still reach a quarantined backend (half-open
probes). ``reinstate_backend`` reverses it.

Autotuning: ``autotune(shape, dtype, ...)`` is the *opt-in* empirical
counterpart to the cost model. It compiles and times every candidate
backend x ``tiles_per_block`` on the live device (best-of-``repeats``,
``block_until_ready``) and records the winner in a tuned-plan table that
``plan_for`` consults whenever the backend would otherwise be auto-selected
for that problem key. Recording a tuned plan invalidates the LRU cache, and
explicit per-call overrides (``backend=`` / ``tiles_per_block=``) always
beat the tuned entry. The table is process-local and never persisted:
timings are only valid for the device that produced them.
"""

from __future__ import annotations

import dataclasses
import functools
import math
import os
import time
from typing import Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import cost_model

# Environment override for the process-wide default backend.
BACKEND_ENV = "REPRO_REDUCE_BACKEND"

# The auto heuristic only routes through Pallas below when the extent spans at
# least this many full MXU tiles; smaller problems are not worth a kernel
# launch (interpret-mode or real).
_MIN_PALLAS_TILES = 2

# plan_for memoization depth; see module docstring ("Plan cache").
_PLAN_CACHE_SIZE = 1024

_default_backend: Optional[str] = None

# autotune()'s winners, keyed like the plan cache (shape, dtype, kind, axis,
# segments); consulted by _plan_for_cached when the backend is auto-selected.
_TUNED: Dict[Tuple, "ReducePlan"] = {}

# Backends a circuit breaker (or operator) has taken out of AUTO rotation --
# see quarantine_backend(). Explicit pins (backend= / plan=) still select a
# quarantined backend: half-open probes need to address it directly.
_QUARANTINED: set = set()

# Degradation order when an auto-selected backend is quarantined. "xla" is
# terminal: the always-available jnp fallback is never rerouted away from.
_QUARANTINE_FALLBACK = {
    "pallas_fused": "mma_jnp",
    "pallas_hier": "mma_jnp",
    "mma_jnp": "xla",
}


@dataclasses.dataclass(frozen=True)
class ReducePlan:
    """Static description of one reduction's execution strategy.

    backend         -- registry name: "xla" | "mma_jnp" | "pallas_hier" |
                       "pallas_fused" | "segmented" (or anything registered
                       later).
    m               -- linear MMA tile size; 128 = TPU MXU, 16 = WMMA, 4 = V100.
    tiles_per_block -- (m, m) tiles staged per Pallas grid step.
    num_cores       -- lanes of the striped ("parallel", "arbitrary") Pallas
                       grid; the planner defaults it to the live device's
                       TPU core count (interpret mode / non-TPU: 1). The
                       cost model charges n/(m^2 c) + c MMAs per lane
                       (``cost_model.fused_mma_ops``). Ignored by the
                       jnp-level backends.
    compute_dtype   -- dtype fed to the MMA multipliers (string name).
    accum_dtype     -- accumulator / result dtype (string name).
    precision       -- "native" or "kahan" (compensated combine; the
                       Markidis-style refinement, orthogonal to the backend.
                       Backends with ``native_kahan`` carry the compensation
                       in-kernel; the rest use the blocked combine).
    kahan_block     -- block length for the blocked compensated combine.
    mesh_axes       -- bound shard_map mesh axis names the reduction combines
                       across AFTER the local launch (deterministic
                       fixed-order all-gather fold; see
                       ``core.collectives.fixed_order_combine``). Empty =
                       single-device semantics. Stored as a tuple of strings
                       so plans stay hashable custom_vjp nondiff arguments.
    """

    backend: str = "mma_jnp"
    m: int = cost_model.MXU_DIM
    tiles_per_block: int = 8
    num_cores: int = 1
    compute_dtype: str = "bfloat16"
    accum_dtype: str = "float32"
    precision: str = "native"
    kahan_block: int = 4096
    mesh_axes: Tuple[str, ...] = ()

    def __post_init__(self):
        if self.m < 2:
            raise ValueError(f"m must be >= 2 (paper section V); got {self.m}")
        if self.num_cores < 1:
            raise ValueError(f"num_cores must be >= 1; got {self.num_cores}")
        if self.precision not in ("native", "kahan"):
            raise ValueError(f"unknown precision policy {self.precision!r}")
        if self.kahan_block < 1:
            raise ValueError(f"kahan_block must be >= 1; got {self.kahan_block}")
        if not isinstance(self.mesh_axes, tuple) or not all(
            isinstance(a, str) and a for a in self.mesh_axes
        ):
            raise ValueError(
                f"mesh_axes must be a tuple of axis-name strings; "
                f"got {self.mesh_axes!r}"
            )

    @property
    def compute_jnp(self) -> jnp.dtype:
        return jnp.dtype(self.compute_dtype)

    @property
    def accum_jnp(self) -> jnp.dtype:
        return jnp.dtype(self.accum_dtype)

    def replace(self, **kw) -> "ReducePlan":
        return dataclasses.replace(self, **kw)

    def hbm_bytes(
        self,
        n: int,
        dtype,
        *,
        segments: Optional[int] = None,
        prologue: str = "identity",
        epilogue: int = 0,
        census: bool = False,
    ) -> "cost_model.HbmTraffic":
        """Modeled HBM traffic of reducing ``n`` elements of ``dtype`` under
        this plan (``cost_model.hbm_bytes`` dispatched by backend).

        The Pallas paths ingest bf16/f16/f32 zero-copy (n * itemsize moved
        once); other dtypes pay the documented f32 pre-cast, modeled as the
        staged path. The jnp-level backends are modeled as one native
        stream read (XLA fuses their upcasts into the reduction loop).
        ``segments`` selects the multi-reduce models ("parts" for the
        kernel backends -- ``reduce_many``'s route -- with the exact
        per-part byte count available via ``cost_model.parts_hbm_bytes``).
        ``prologue`` is the in-kernel elementwise map: square/abs move NO
        extra bytes (that is the single-stream norm-path win this model
        exists to state -- the pre-prologue sumsq paid n*itemsize +
        2*n*4 more, see ``cost_model.staged_sumsq_hbm_bytes``); "moments"
        doubles the partial/output term (the dual accumulator).
        ``epilogue`` models the in-kernel post-combine chains, which cost
        ZERO input bytes: for multi-reduce (``segments``) it is the number
        of EXTRA finished-scalar output slots (a ``reduce_tree`` fork's K
        chains -> K more f32 slots in the one output vector); for scalar
        full reductions any truthy value marks the single-lane fused
        launch whose partials write collapses to one finished f32.
        ``census=True`` models the in-kernel non-finite census the same
        way: zero extra input bytes, ``segments + 1`` extra f32 output
        slots (per-part counts plus the total) on the multi-reduce paths.
        """
        from repro.kernels import common as _kcommon  # no circular import:
        # kernels.common depends only on jax

        dt = jnp.dtype(dtype)
        itemsize = dt.itemsize
        native = _kcommon.native_ingest_dtype(dt)
        dual = prologue == "moments"
        kernel = self.backend in ("pallas_fused", "pallas_hier", "segmented")
        census_slots = (int(segments) + 1) if census and segments else 0
        if segments is not None and kernel:
            return cost_model.hbm_bytes(
                "parts", n, itemsize if native else 4,
                segments=((2 * segments) if dual else segments)
                + int(epilogue),
                census=census_slots,
            )
        if segments is not None:
            # segmented census layout is the dual (2S,) widening: counts
            # in [S, 2S), no separate total slot
            return cost_model.hbm_bytes(
                "segmented", n, itemsize,
                segments=(2 * segments) if dual else segments,
                num_cores=self.num_cores,
                census=int(segments) if census else 0,
            )
        if self.backend == "pallas_hier":
            if native:
                path = "hier_moments" if dual else "hier"
            else:
                path = "fused_staged"
        elif kernel:
            path = "fused" if native else "fused_staged"
        else:
            # jnp-level backends: one fused stream over the native buffer
            # (4 bytes out per emitted statistic: the f32 result(s)).
            return cost_model.HbmTraffic(
                kernel_read=n * itemsize, kernel_write=8 if dual else 4
            )
        return cost_model.hbm_bytes(
            path, n, itemsize, m=self.m, num_cores=self.num_cores,
            tiles_per_block=self.tiles_per_block,
            kahan=self.precision == "kahan" and self.backend == "pallas_fused",
            dual=dual and path == "fused",
            epilogue=bool(epilogue) and path == "fused",
        )


def set_default_backend(name: Optional[str]) -> None:
    """Set the process-wide default backend (None restores auto-selection)."""
    global _default_backend
    _default_backend = name


def default_backend() -> str:
    """Resolution order: set_default_backend > $REPRO_REDUCE_BACKEND > auto."""
    if _default_backend is not None:
        return _default_backend
    return os.environ.get(BACKEND_ENV) or "auto"


def quarantine_backend(name: str) -> None:
    """Take ``name`` out of AUTO backend rotation (circuit-breaker trip).

    Every subsequent auto selection (``_auto_backend`` and the segmented
    per-call route ``segmented_backend_for``) degrades along
    pallas -> mma_jnp -> xla instead of returning a quarantined name.
    Explicit pins (``reduce(..., backend=...)`` / a prebuilt plan) still
    address the backend directly -- that is how a breaker's half-open
    probe tests it. Invalidate the memoized plans: a cached auto plan
    carrying the quarantined backend must never be served again
    (satellite of the breaker re-route; regression via
    ``plan_cache_info``). Scan plans are memoized separately and go stale
    for exactly the same reason, so both caches drop together."""
    _QUARANTINED.add(str(name))
    _plan_for_cached.cache_clear()
    _scan_plan_cached.cache_clear()


def reinstate_backend(name: str) -> None:
    """Undo ``quarantine_backend`` (breaker close); drops memoized plans so
    auto selection immediately returns to the reinstated backend."""
    _QUARANTINED.discard(str(name))
    _plan_for_cached.cache_clear()
    _scan_plan_cached.cache_clear()


def quarantined_backends() -> Tuple[str, ...]:
    """Currently quarantined backend names (sorted, for status exports)."""
    return tuple(sorted(_QUARANTINED))


def _dequarantine(name: str) -> str:
    """Walk the degradation chain until the name is out of quarantine (or
    terminal). Applied to AUTO selections only."""
    while name in _QUARANTINED:
        nxt = _QUARANTINE_FALLBACK.get(name)
        if nxt is None:
            return name  # terminal fallback: serve it even quarantined
        name = nxt
    return name


def backend_for_flags(mma: bool, use_pallas: bool = False) -> str:
    """Map the legacy config pair (cfg.mma_reductions, cfg.use_pallas) onto a
    registry name. Kept so model/optimizer code keeps honouring the flags the
    EXPERIMENTS.md ablations are defined in terms of. An explicit process
    default (``set_default_backend`` / $REPRO_REDUCE_BACKEND -- e.g. the
    launchers' ``--reduce-backend``) overrides the flag mapping."""
    override = _default_backend or os.environ.get(BACKEND_ENV)
    if override:
        return override
    if not mma:
        return "xla"
    return "pallas_fused" if use_pallas else "mma_jnp"


@functools.lru_cache(maxsize=1)
def _device_num_cores() -> int:
    """Default lane count for the striped Pallas kernels.

    The TPU core count of device 0 when running compiled (megacore chips
    report 2), else 1 -- off-TPU the kernels run under Pallas interpret
    mode, where the grid executes sequentially and extra lanes only add
    combine work. Process-constant, so caching is safe."""
    try:
        dev = jax.devices()[0]
    except Exception:  # pragma: no cover - backendless environments
        return 1
    if getattr(dev, "platform", None) != "tpu":
        return 1
    for attr in ("num_cores", "core_count"):
        v = getattr(dev, attr, None)
        if isinstance(v, int) and v >= 1:
            return v
    return 1  # pragma: no cover - TPU runtimes without a core-count attr


def _reduced_extent(shape: Sequence[int], axis) -> int:
    if axis is None:
        return int(math.prod(shape)) if shape else 1
    return int(math.prod(shape[a] for a in axis))


def segmented_backend_for(n: int, dtype, m: int) -> str:
    """Concrete executor for a segmented multi-reduce of ``n`` total elements.

    This is the call-time resolution behind the registered "segmented"
    auto-route: exact arithmetic for non-float data, the single-launch
    Pallas kernel for large streams on a real TPU (MXU tile only), and the
    one-dot-plus-exact-combine jnp path everywhere else."""
    if not jnp.issubdtype(jnp.dtype(dtype), jnp.floating):
        return "xla"
    if n <= m:
        return "xla"
    if (
        jax.default_backend() == "tpu"
        and m == cost_model.MXU_DIM
        and n >= _MIN_PALLAS_TILES * m * m
    ):
        return _dequarantine("pallas_fused")
    return _dequarantine("mma_jnp")


def _auto_backend(shape, dtype, *, kind: str, axis, m: int, segments=None) -> str:
    """Cost-model-driven selection (see module docstring)."""
    n = _reduced_extent(shape, axis)
    if segments is not None:
        # N independent reductions: one launch for the whole batch. The
        # registered "segmented" backend resolves the concrete executor at
        # call time (segmented_backend_for).
        return "segmented"
    if not jnp.issubdtype(jnp.dtype(dtype), jnp.floating):
        # Integer/bool reductions want exact arithmetic; the MMA encoding
        # buys nothing there (XLA lowers them to exact integer adds).
        return "xla"
    if axis is not None:
        # Batched row reductions are a single all-ones dot (eq. 9) -- the
        # jnp algorithmic path already lands on the MXU; the Pallas scalar
        # kernels would serialize over rows.
        return "mma_jnp" if n > m else "xla"
    if n < _MIN_PALLAS_TILES * m * m:
        return "mma_jnp" if n > m else "xla"
    # Full reduction over a large extent. On a real TPU the fused
    # C-accumulator kernel wins (n/m^2 + 2 MMAs vs ~2.008 n/m^2 for the
    # hierarchical relaunch; EXPERIMENTS.md): take it whenever the roofline
    # says the MMA encoding is bandwidth-neutral, else stay paper-faithful.
    if jax.default_backend() == "tpu":
        rl = cost_model.tpu_reduction_roofline(n)
        return "pallas_fused" if rl.mxu_bandwidth_neutral else "pallas_hier"
    # Off-TPU (CPU/interpret) the Pallas kernels run but only emulate; the
    # algorithmic path is the fast default. Explicit overrides still select
    # the kernels (that is how the CPU test sweep exercises them).
    return "mma_jnp"


def _problem_key(shape, dtype_s, kind, axis, segments) -> Tuple:
    return (shape, dtype_s, kind, axis, segments)


@functools.lru_cache(maxsize=_PLAN_CACHE_SIZE)
def _plan_for_cached(
    shape: Tuple[int, ...],
    dtype_s: str,
    kind: str,
    axis,
    backend: str,
    m: Optional[int],
    tiles_per_block: Optional[int],
    num_cores: Optional[int],
    compute_dtype: Optional[str],
    accum_dtype: Optional[str],
    precision: Optional[str],
    kahan_block: Optional[int],
    segments: Optional[int],
    mesh_axes: Tuple[str, ...] = (),
) -> ReducePlan:
    dt = jnp.dtype(dtype_s)
    m_ = m if m is not None else cost_model.MXU_DIM
    if backend == "auto":
        tuned = _TUNED.get(_problem_key(shape, dtype_s, kind, axis, segments))
        if tuned is not None:
            backend = tuned.backend
            if tiles_per_block is None:
                tiles_per_block = tuned.tiles_per_block
            if num_cores is None:
                num_cores = tuned.num_cores
        else:
            backend = _auto_backend(
                shape, dt, kind=kind, axis=axis, m=m_, segments=segments
            )
        # the quarantine re-route applies to ANY auto resolution (tuned
        # winners included); explicit pins bypass it by construction
        backend = _dequarantine(backend)
    if accum_dtype is None:
        accum_dtype = "float64" if dt == jnp.float64 else "float32"
    if compute_dtype is None:
        if dt == jnp.float64:
            compute_dtype = "float64"
        elif not jnp.issubdtype(dt, jnp.floating):
            compute_dtype = "float32"
        elif kind in ("sumsq", "norm2"):
            # Exactness matters for the gradient-clipping statistic.
            compute_dtype = "float32"
        else:
            compute_dtype = "bfloat16"
    return ReducePlan(
        backend=backend,
        m=m_,
        tiles_per_block=tiles_per_block if tiles_per_block is not None else 8,
        num_cores=num_cores if num_cores is not None else _device_num_cores(),
        compute_dtype=str(jnp.dtype(compute_dtype)),
        accum_dtype=str(jnp.dtype(accum_dtype)),
        precision=precision if precision is not None else "native",
        kahan_block=kahan_block if kahan_block is not None else 4096,
        mesh_axes=mesh_axes,
    )


def norm_mesh_axes(mesh_axes) -> Tuple[str, ...]:
    """Canonical hashable form of a mesh_axes argument: a bare axis name
    becomes a 1-tuple, any sequence becomes a tuple, None/empty becomes ()."""
    if mesh_axes is None:
        return ()
    if isinstance(mesh_axes, str):
        return (mesh_axes,)
    return tuple(str(a) for a in mesh_axes)


def _norm_axis_arg(axis, ndim: int):
    """Canonical cache-key form of ``axis``: sorted non-negative tuple (or
    None). Must agree with api._normalize_axis so ``autotune`` winners land
    on the same key ``reduce()`` looks up."""
    if axis is None or ndim == 0:
        return None
    axes = (axis,) if isinstance(axis, int) else tuple(axis)
    return tuple(sorted(int(a) % ndim for a in axes))


def plan_for(
    shape: Sequence[int],
    dtype,
    *,
    kind: str = "sum",
    axis=None,
    backend: Optional[str] = None,
    m: Optional[int] = None,
    tiles_per_block: Optional[int] = None,
    num_cores: Optional[int] = None,
    compute_dtype=None,
    accum_dtype=None,
    precision: Optional[str] = None,
    kahan_block: Optional[int] = None,
    segments: Optional[int] = None,
    mesh_axes=None,
) -> ReducePlan:
    """Build the ReducePlan for reducing ``shape``/``dtype`` over ``axis``.

    Every field can be pinned by the caller; unset fields are chosen from the
    problem: exact-sensitive kinds ("sumsq", "norm2" -- the clipping
    statistic) multiply at f32, other float reductions at bf16 (the tensor-
    core mode the paper analyzes), f64 stays f64, non-float inputs are
    upcast to f32 before any MMA, and ``num_cores`` defaults to the live
    device's TPU core count (1 off-TPU / in interpret mode). ``segments=N``
    marks the problem as a segmented multi-reduce of N independent pieces
    (``shape`` then describes the packed stream). Results are memoized --
    see the module docstring.
    """
    shape_t = tuple(int(s) for s in shape)
    return _plan_for_cached(
        shape_t,
        str(jnp.dtype(dtype)),
        kind,
        _norm_axis_arg(axis, len(shape_t)),
        backend if backend is not None else default_backend(),
        None if m is None else int(m),
        None if tiles_per_block is None else int(tiles_per_block),
        None if num_cores is None else int(num_cores),
        None if compute_dtype is None else str(jnp.dtype(compute_dtype)),
        None if accum_dtype is None else str(jnp.dtype(accum_dtype)),
        precision,
        None if kahan_block is None else int(kahan_block),
        None if segments is None else int(segments),
        norm_mesh_axes(mesh_axes),
    )


def plan_cache_info():
    """(hits, misses, maxsize, currsize) of the plan_for memo cache."""
    return _plan_for_cached.cache_info()


def plan_cache_clear(clear_tuned: bool = False) -> None:
    """Drop every memoized plan -- reduce AND scan -- (and, optionally, the
    autotuned winners)."""
    _plan_for_cached.cache_clear()
    _scan_plan_cached.cache_clear()
    if clear_tuned:
        _TUNED.clear()


# ------------------------------- scan plans ----------------------------------


@dataclasses.dataclass(frozen=True)
class ScanPlan:
    """Static description of one prefix-sum's execution strategy.

    The scan analogue of ``ReducePlan`` (same hashability contract: plans
    feed ``jax.custom_vjp`` nondiff arguments). Fields mirror the reduce
    plan where they mean the same thing; the one deliberate divergence is
    ``compute_dtype``: scans default to the operand's NATIVE ingest dtype
    (f32 stays f32) instead of the reduce path's bf16 demotion, because a
    scan's every partial result is consumer-visible -- the MoE/data-packing
    offset consumers rely on f32-exact integer prefixes, and demoting them
    would be a visible precision change, not an internal one.

    backend -- "xla" (jnp.cumsum at f32) | "mma_jnp" (batched triangular
    einsum) | "pallas_fused" (the triangular-MMA kernel, 1D streams).
    """

    backend: str = "mma_jnp"
    m: int = cost_model.MXU_DIM
    tiles_per_block: int = 8
    num_cores: int = 1
    compute_dtype: str = "float32"
    accum_dtype: str = "float32"

    def __post_init__(self):
        if self.m < 2:
            raise ValueError(f"m must be >= 2 (paper section V); got {self.m}")
        if self.num_cores < 1:
            raise ValueError(f"num_cores must be >= 1; got {self.num_cores}")

    @property
    def compute_jnp(self) -> jnp.dtype:
        return jnp.dtype(self.compute_dtype)

    @property
    def accum_jnp(self) -> jnp.dtype:
        return jnp.dtype(self.accum_dtype)

    def replace(self, **kw) -> "ScanPlan":
        return dataclasses.replace(self, **kw)

    def hbm_bytes(self, n: int, dtype) -> "cost_model.HbmTraffic":
        """Modeled HBM traffic of scanning ``n`` elements of ``dtype`` under
        this plan. The Pallas path is ``cost_model.scan_hbm_bytes`` (native
        single stream in, block-padded prefix array out, carry-rebuild
        refetch charged outside ``launch_io``); the jnp-level backends are
        one native read + one native write (XLA fuses the f32 upcast)."""
        from repro.kernels import common as _kcommon

        dt = jnp.dtype(dtype)
        if self.backend in ("pallas_fused", "pallas_hier"):
            native = _kcommon.native_ingest_dtype(dt)
            itemsize = dt.itemsize if native else 4
            return cost_model.scan_hbm_bytes(
                n, itemsize, m=self.m, num_cores=self.num_cores,
                tiles_per_block=self.tiles_per_block,
            )
        return cost_model.HbmTraffic(
            kernel_read=n * dt.itemsize, kernel_write=n * dt.itemsize
        )


def _auto_scan_backend(shape, dtype, *, m: int) -> str:
    """Cost-model-driven scan backend selection (quarantine-aware).

    Non-float data wants exact integer adds -> xla. Batched (ndim > 1)
    scans are a single triangular einsum already on the MXU -> mma_jnp.
    Small 1D extents are not worth a launch -> mma_jnp/xla by extent. Large
    1D streams on a real TPU take the triangular kernel; off-TPU the
    algorithmic path is the fast default (explicit pins still select the
    kernel -- the CPU test sweep's route)."""
    n = int(shape[-1]) if shape else 1
    if not jnp.issubdtype(jnp.dtype(dtype), jnp.floating):
        return "xla"
    if len(shape) > 1:
        return "mma_jnp" if n > m else "xla"
    if n < _MIN_PALLAS_TILES * m * m:
        return "mma_jnp" if n > m else "xla"
    if jax.default_backend() == "tpu":
        return "pallas_fused"
    return "mma_jnp"


def _native_scan_compute(dtype_s: str) -> str:
    """The ScanPlan compute-dtype default: the operand's own ingest dtype
    (bf16 scans multiply at bf16, f32 at f32); non-native falls back to the
    documented f32 pre-cast width."""
    from repro.kernels import common as _kcommon

    dt = jnp.dtype(dtype_s)
    return dtype_s if _kcommon.native_ingest_dtype(dt) else "float32"


@functools.lru_cache(maxsize=_PLAN_CACHE_SIZE)
def _scan_plan_cached(
    shape: Tuple[int, ...],
    dtype_s: str,
    backend: str,
    m: Optional[int],
    tiles_per_block: Optional[int],
    num_cores: Optional[int],
    compute_dtype: Optional[str],
) -> ScanPlan:
    m_ = m if m is not None else cost_model.MXU_DIM
    if backend == "auto":
        backend = _dequarantine(
            _auto_scan_backend(shape, jnp.dtype(dtype_s), m=m_)
        )
    if compute_dtype is None:
        compute_dtype = _native_scan_compute(dtype_s)
    return ScanPlan(
        backend=backend,
        m=m_,
        tiles_per_block=tiles_per_block if tiles_per_block is not None else 8,
        num_cores=num_cores if num_cores is not None else _device_num_cores(),
        compute_dtype=str(jnp.dtype(compute_dtype)),
        accum_dtype="float32",
    )


def scan_plan_for(
    shape: Sequence[int],
    dtype,
    *,
    backend: Optional[str] = None,
    m: Optional[int] = None,
    tiles_per_block: Optional[int] = None,
    num_cores: Optional[int] = None,
    compute_dtype=None,
) -> ScanPlan:
    """Build the ScanPlan for scanning ``shape``/``dtype`` over the LAST
    axis (``repro.scan`` normalizes ``axis=`` before planning). Unset
    fields follow the scan defaults (see ``ScanPlan``); backend resolution
    honours the same ``set_default_backend`` / $REPRO_REDUCE_BACKEND /
    quarantine machinery as ``plan_for``. Results are memoized; the cache
    drops together with the reduce plan cache on quarantine, reinstate,
    ``plan_cache_clear`` and autotune events."""
    shape_t = tuple(int(s) for s in shape)
    return _scan_plan_cached(
        shape_t,
        str(jnp.dtype(dtype)),
        backend if backend is not None else default_backend(),
        None if m is None else int(m),
        None if tiles_per_block is None else int(tiles_per_block),
        None if num_cores is None else int(num_cores),
        None if compute_dtype is None else str(jnp.dtype(compute_dtype)),
    )


def scan_plan_cache_info():
    """(hits, misses, maxsize, currsize) of the scan_plan_for memo cache."""
    return _scan_plan_cached.cache_info()


def autotune(
    shape: Sequence[int],
    dtype,
    *,
    kind: str = "sum",
    axis=None,
    segments: Optional[int] = None,
    backends: Optional[Sequence[str]] = None,
    tiles_per_block_candidates: Sequence[int] = (2, 4, 8, 16),
    num_cores_candidates: Sequence[int] = (1, 2, 4),
    repeats: int = 3,
    seed: int = 0,
) -> ReducePlan:
    """Empirically pick the fastest plan for one problem ON THE LIVE DEVICE.

    Opt-in (never runs implicitly -- timing inside a trace would be
    meaningless): compiles ``reduce`` once per candidate backend x
    ``tiles_per_block`` x ``num_cores`` (block depth and lane count only
    swept for the Pallas kernels), times ``repeats`` runs, and records the
    best-of winner in the tuned-plan table so every later ``plan_for`` with
    an auto-selected backend for this problem returns it. With ``segments=N`` the timed workload is the real
    segmented pass -- ``reduce_many`` over ``shape`` split into N equal
    pieces -- so ``sum_segments`` boundary handling is part of what is
    measured. Returns the winning plan. Candidates that fail to compile or
    run are skipped (e.g. kernel backends with a pinned m != 128).
    """
    from repro.reduce import api as _api  # deferred: api imports this module
    from repro.reduce import backends as _backends  # deferred, same reason

    shape_t = tuple(int(s) for s in shape)
    axis_t = _norm_axis_arg(axis, len(shape_t))
    dt = jnp.dtype(dtype)
    if backends is None:
        backends = tuple(
            n for n in _backends.available_backends() if n != "segmented"
        )
    if jnp.issubdtype(dt, jnp.floating):
        x = jnp.asarray(
            np.random.RandomState(seed).standard_normal(shape_t), dt
        )
    else:
        x = jnp.ones(shape_t, dt)
    if segments:
        # time the REAL segmented pass: the stream split into N pieces
        x = tuple(
            jnp.asarray(c) for c in np.array_split(np.asarray(x).ravel(), segments)
        )
    best: Optional[ReducePlan] = None
    best_t = math.inf
    for name in backends:
        is_pallas = name.startswith("pallas")
        tpbs = tuple(tiles_per_block_candidates) if is_pallas else (None,)
        ncs = tuple(num_cores_candidates) if is_pallas else (None,)
        for tpb, nc in ((t, n) for t in tpbs for n in ncs):
            cand = plan_for(
                shape_t,
                dt,
                kind=kind,
                axis=axis_t,
                backend=name,
                tiles_per_block=tpb,
                num_cores=nc,
                segments=segments,
            )
            try:
                if segments:
                    fn = jax.jit(
                        lambda *a, p=cand: _api.reduce_many(a, kind=kind, plan=p)
                    )
                else:
                    fn = jax.jit(
                        lambda a, p=cand: _api.reduce(
                            a, axis=axis_t, kind=kind, plan=p
                        )
                    )
                jax.block_until_ready(fn(*x) if segments else fn(x))  # warm
                elapsed = math.inf
                for _ in range(max(1, repeats)):
                    t0 = time.perf_counter()
                    jax.block_until_ready(fn(*x) if segments else fn(x))
                    elapsed = min(elapsed, time.perf_counter() - t0)
            except Exception:
                continue
            if elapsed < best_t:
                best, best_t = cand, elapsed
    if best is None:
        raise RuntimeError(
            f"autotune: no candidate backend ran for shape={shape_t} "
            f"dtype={dt} kind={kind!r}"
        )
    _TUNED[_problem_key(shape_t, str(dt), kind, axis_t, segments)] = best
    _plan_for_cached.cache_clear()  # cached auto plans may now be stale
    _scan_plan_cached.cache_clear()
    return best
