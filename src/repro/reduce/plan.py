"""Reduction planning: pick a backend + tile geometry from the problem shape.

A ``ReducePlan`` is the complete, hashable description of *how* one reduction
runs: which registered backend executes it, the linear MMA tile size ``m``,
the Pallas block depth ``tiles_per_block``, the multiplier/accumulator dtypes,
and the (orthogonal) precision policy. Plans are static metadata -- they are
resolved at trace time from shapes and feed ``jax.custom_vjp`` nondiff
arguments, so every field is a plain hashable Python value (dtypes are stored
as strings, not ``jnp.dtype`` objects).

``plan_for`` is the cost-model-driven selector: it consults
``repro.core.cost_model``'s TPU roofline (eq. 16's step model extended with
HBM/VPU/MXU terms) to decide whether the paper's MMA encoding pays for a
given extent, and which implementation of it to use. The default can be
overridden per call (``reduce(..., backend=...)``), per process
(``set_default_backend``), or per environment (``REPRO_REDUCE_BACKEND``).
"""

from __future__ import annotations

import dataclasses
import math
import os
from typing import Optional, Sequence

import jax
import jax.numpy as jnp

from repro.core import cost_model

# Environment override for the process-wide default backend.
BACKEND_ENV = "REPRO_REDUCE_BACKEND"

# The auto heuristic only routes through Pallas below when the extent spans at
# least this many full MXU tiles; smaller problems are not worth a kernel
# launch (interpret-mode or real).
_MIN_PALLAS_TILES = 2

_default_backend: Optional[str] = None


@dataclasses.dataclass(frozen=True)
class ReducePlan:
    """Static description of one reduction's execution strategy.

    backend         -- registry name: "xla" | "mma_jnp" | "pallas_hier" |
                       "pallas_fused" (or anything registered later).
    m               -- linear MMA tile size; 128 = TPU MXU, 16 = WMMA, 4 = V100.
    tiles_per_block -- (m, m) tiles staged per Pallas grid step.
    compute_dtype   -- dtype fed to the MMA multipliers (string name).
    accum_dtype     -- accumulator / result dtype (string name).
    precision       -- "native" or "kahan" (blocked compensated combine; the
                       Markidis-style refinement, orthogonal to the backend).
    kahan_block     -- block length for the compensated combine.
    """

    backend: str = "mma_jnp"
    m: int = cost_model.MXU_DIM
    tiles_per_block: int = 8
    compute_dtype: str = "bfloat16"
    accum_dtype: str = "float32"
    precision: str = "native"
    kahan_block: int = 4096

    def __post_init__(self):
        if self.m < 2:
            raise ValueError(f"m must be >= 2 (paper section V); got {self.m}")
        if self.precision not in ("native", "kahan"):
            raise ValueError(f"unknown precision policy {self.precision!r}")

    @property
    def compute_jnp(self) -> jnp.dtype:
        return jnp.dtype(self.compute_dtype)

    @property
    def accum_jnp(self) -> jnp.dtype:
        return jnp.dtype(self.accum_dtype)

    def replace(self, **kw) -> "ReducePlan":
        return dataclasses.replace(self, **kw)


def set_default_backend(name: Optional[str]) -> None:
    """Set the process-wide default backend (None restores auto-selection)."""
    global _default_backend
    _default_backend = name


def default_backend() -> str:
    """Resolution order: set_default_backend > $REPRO_REDUCE_BACKEND > auto."""
    if _default_backend is not None:
        return _default_backend
    return os.environ.get(BACKEND_ENV) or "auto"


def backend_for_flags(mma: bool, use_pallas: bool = False) -> str:
    """Map the legacy config pair (cfg.mma_reductions, cfg.use_pallas) onto a
    registry name. Kept so model/optimizer code keeps honouring the flags the
    EXPERIMENTS.md ablations are defined in terms of. An explicit process
    default (``set_default_backend`` / $REPRO_REDUCE_BACKEND -- e.g. the
    launchers' ``--reduce-backend``) overrides the flag mapping."""
    override = _default_backend or os.environ.get(BACKEND_ENV)
    if override:
        return override
    if not mma:
        return "xla"
    return "pallas_fused" if use_pallas else "mma_jnp"


def _reduced_extent(shape: Sequence[int], axis) -> int:
    if axis is None:
        return int(math.prod(shape)) if shape else 1
    return int(math.prod(shape[a] for a in axis))


def _auto_backend(shape, dtype, *, kind: str, axis, m: int) -> str:
    """Cost-model-driven selection (see module docstring)."""
    n = _reduced_extent(shape, axis)
    if not jnp.issubdtype(jnp.dtype(dtype), jnp.floating):
        # Integer/bool reductions want exact arithmetic; the MMA encoding
        # buys nothing there (XLA lowers them to exact integer adds).
        return "xla"
    if axis is not None:
        # Batched row reductions are a single all-ones dot (eq. 9) -- the
        # jnp algorithmic path already lands on the MXU; the Pallas scalar
        # kernels would serialize over rows.
        return "mma_jnp" if n > m else "xla"
    if n < _MIN_PALLAS_TILES * m * m:
        return "mma_jnp" if n > m else "xla"
    # Full reduction over a large extent. On a real TPU the fused
    # C-accumulator kernel wins (n/m^2 + 2 MMAs vs ~2.008 n/m^2 for the
    # hierarchical relaunch; EXPERIMENTS.md): take it whenever the roofline
    # says the MMA encoding is bandwidth-neutral, else stay paper-faithful.
    if jax.default_backend() == "tpu":
        rl = cost_model.tpu_reduction_roofline(n)
        return "pallas_fused" if rl.mxu_bandwidth_neutral else "pallas_hier"
    # Off-TPU (CPU/interpret) the Pallas kernels run but only emulate; the
    # algorithmic path is the fast default. Explicit overrides still select
    # the kernels (that is how the CPU test sweep exercises them).
    return "mma_jnp"


def plan_for(
    shape: Sequence[int],
    dtype,
    *,
    kind: str = "sum",
    axis=None,
    backend: Optional[str] = None,
    m: Optional[int] = None,
    tiles_per_block: Optional[int] = None,
    compute_dtype=None,
    accum_dtype=None,
    precision: Optional[str] = None,
) -> ReducePlan:
    """Build the ReducePlan for reducing ``shape``/``dtype`` over ``axis``.

    Every field can be pinned by the caller; unset fields are chosen from the
    problem: exact-sensitive kinds ("sumsq", "norm2" -- the clipping
    statistic) multiply at f32, other float reductions at bf16 (the tensor-
    core mode the paper analyzes), f64 stays f64, non-float inputs are
    upcast to f32 before any MMA.
    """
    dt = jnp.dtype(dtype)
    m_ = int(m) if m is not None else cost_model.MXU_DIM
    if backend is None:
        backend = default_backend()
    if backend == "auto":
        backend = _auto_backend(shape, dt, kind=kind, axis=axis, m=m_)
    if accum_dtype is None:
        accum_dtype = "float64" if dt == jnp.float64 else "float32"
    if compute_dtype is None:
        if dt == jnp.float64:
            compute_dtype = "float64"
        elif not jnp.issubdtype(dt, jnp.floating):
            compute_dtype = "float32"
        elif kind in ("sumsq", "norm2"):
            # Exactness matters for the gradient-clipping statistic.
            compute_dtype = "float32"
        else:
            compute_dtype = "bfloat16"
    return ReducePlan(
        backend=backend,
        m=m_,
        tiles_per_block=(
            int(tiles_per_block) if tiles_per_block is not None else 8
        ),
        compute_dtype=str(jnp.dtype(compute_dtype)),
        accum_dtype=str(jnp.dtype(accum_dtype)),
        precision=precision if precision is not None else "native",
    )
