"""Backend registry: the interchangeable executors behind ``repro.reduce``.

A backend implements seven primitives and nothing else:

  sum_all(x, plan, prologue)
                       -- every element of ``x``, mapped by the elementwise
                          ``prologue`` ("identity" | "square" | "abs"),
                          -> scalar of plan.accum_dtype.
  sum_axis(x, plan)    -- ``(..., L) -> (...)`` sum over the last axis.
  scan_axis(x, plan, inclusive)
                       -- ``(..., L) -> (..., L)`` prefix sum over the last
                          axis (``plan`` is a ``ScanPlan``); the new op
                          class behind ``repro.scan``. Default: exact-shift
                          ``jnp.cumsum`` reference semantics, so pre-scan
                          subclasses inherit it for free.
  moments_axis(x, plan)-- ``(..., L) -> ((...), (...))`` fused (sum, sumsq).
  moments_all(x, plan) -- full-array (sum, sumsq) scalar pair; the kernel
                          backends run the paired (x, x^2) dual-accumulator
                          prologue (ONE pass over the raw leaf).
  sum_segments(flat, offsets, plan, prologue)
                       -- S independent prologue'd sums over static slices
                          of one packed 1-D stream -> (S,) ("moments":
                          (2S,) -- sums then sumsqs).
  sum_parts(parts, plan, prologue)
                       -- S independent sums over S SEPARATE arrays
                          -> (S,); the zero-copy multi-reduce primitive
                          behind ``reduce_many`` / ``reduce_tree`` (ONE
                          launch for a whole training step's worth of
                          small reductions, with no packing concatenation
                          on the kernel backends). ``prologue`` is a name
                          or one name per part; any "moments" part widens
                          the result to (2S,).

Every reduction kind ("mean", "sumsq", "norm2", "moments") is composed from
these in ``api.py``, so a new backend (GPU wgmma, autotuned) only has to
supply them to light up the whole API; ``sum_segments``, ``sum_parts``,
``sum_parts_total`` and ``moments_all`` have correct (if staged/
multi-launch) defaults, so third-party backends inherit the batched APIs
for free.

Epilogue contract: every sum primitive also accepts a normalized scalar
``epilogue`` chain (see ``kernels.common.EPILOGUES``) applied to the
REDUCED result -- in-kernel on the Pallas backends wherever the final
combine happens inside the launch, host-side (``apply_epilogue``, the
reference semantics) on the jnp-level backends and legacy subclasses.
``sum_parts_total(parts, plan, prologue, total_chains)`` additionally
appends chain k of the *cross-part total* at slot S + k -- the one-launch
whole-tree norm/clip statistic behind ``reduce_tree(epilogue=...)`` --
and ``census=True`` widens the same row by S + 1 non-finite counts (the
guarded optimizer's NaN/Inf detector; ``sum_parts_total_with_census``
degrades pre-census subclasses to the host reference census).

Prologue contract: kernel backends (``native_prologue = True``) apply the
map INSIDE the kernel at compute precision, after the native -> compute
cast and the tail mask -- so ``reduce(kind="sumsq")`` streams the caller's
raw bf16/f16/f32 leaf exactly once, with no host-side n-sized square or
f32 staging write. The jnp-level backends apply the same map at accumulator
precision, where XLA fuses it into the reduction loop (the reference
semantics the differential harness pins the kernels against; with the
planner's f32 compute for sumsq/norm2 the two are value-identical).

Differentiation contract: backends whose primitives are plain jnp/dot code
set ``native_autodiff = True`` and support both reverse- AND forward-mode
autodiff; kernel-backed backends leave it False and ``api`` wraps their
full reductions in a ``jax.custom_vjp`` (broadcast-of-cotangent rule).
Batched row reductions are *always* executed as native dot/sum code -- the
scalar kernels have no batched form, and serializing one launch per row
would be catastrophic in training hot paths -- so axis reductions stay
forward-differentiable on every backend.

Registered here:

  xla          -- ``jnp.sum`` baseline (the paper's comparison point, and the
                  oracle the test sweep checks every other backend against).
  mma_jnp      -- the paper's hierarchical 2-MMA algorithm in pure JAX
                  (``repro.core.mma_reduce``); rows via the eq. (9) all-ones
                  dot, full reductions via the eq. (13) recurrence.
  pallas_hier  -- Pallas TPU kernel, paper-faithful multi-launch hierarchy
                  (full reductions; rows ride the same eq. (9) dot as
                  mma_jnp -- that IS the MXU-native row reduction).
  pallas_fused -- Pallas TPU kernel, single-launch C-accumulator variant,
                  striped across plan.num_cores parallel lanes with a
                  deterministic fixed-order lane combine (n/(m^2 c) + c
                  MMAs per lane; see EXPERIMENTS.md "Multi-core scaling").
  segmented    -- auto-routing registry entry for multi-reduce problems:
                  resolves the concrete executor per call
                  (``plan.segmented_backend_for``) and delegates.
"""

from __future__ import annotations

import functools as _functools
import inspect as _pyinspect
from typing import Dict, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import mma_reduce as _core
from repro.kernels import common as _kcommon
from repro.kernels import scan as _scan_kernels
from repro.kernels.mma_reduce import ops as _pallas_ops
from repro.reduce.plan import ReducePlan, segmented_backend_for


def _host_prologue(x: jax.Array, plan: ReducePlan, prologue: str) -> jax.Array:
    """Reference (jnp-level) prologue semantics: the elementwise map at
    accumulator precision, fused by XLA into whatever reduction consumes
    it. Kernel backends apply the same map in-kernel instead (at compute
    precision, after the native cast); with the planner's f32 compute for
    sumsq/norm2 both routes square the same f32 value."""
    if prologue == "identity":
        return x
    return _kcommon.apply_prologue(x.astype(plan.accum_jnp), prologue)


@_functools.lru_cache(maxsize=None)
def _method_takes(backend_cls, method: str, param: str) -> bool:
    """True when this Backend subclass's ``method`` accepts ``param``
    (older third-party subclasses may predate prologue/epilogue/census)."""
    try:
        sig = _pyinspect.signature(getattr(backend_cls, method))
    except (TypeError, ValueError):  # pragma: no cover - exotic callables
        return True
    return param in sig.parameters or any(
        p.kind is _pyinspect.Parameter.VAR_KEYWORD
        for p in sig.parameters.values()
    )


def _sum_all_takes(backend_cls, param: str) -> bool:
    return _method_takes(backend_cls, "sum_all", param)


def _sum_all_takes_prologue(backend_cls) -> bool:
    return _sum_all_takes(backend_cls, "prologue")


def sum_all_with_prologue(backend, x, plan, prologue: str):
    """Invoke ``backend.sum_all`` under a prologue, degrading to the
    host-side map for pre-prologue Backend subclasses -- a legacy custom
    backend keeps serving every kind exactly as it did when api.py squared
    host-side (the identity path never even passes the parameter)."""
    if prologue == "identity":
        return backend.sum_all(x, plan)
    if _sum_all_takes_prologue(type(backend)):
        return backend.sum_all(x, plan, prologue)
    return backend.sum_all(_host_prologue(x, plan, prologue), plan)


def sum_all_with_epilogue(backend, x, plan, prologue: str, epilogue: tuple):
    """Invoke ``backend.sum_all`` under a prologue AND an epilogue chain,
    degrading gracefully for subclasses that predate either: the empty
    chain never even passes the parameter (byte-for-byte the prologue-only
    call), and a pre-epilogue subclass gets the chain applied host-side on
    its returned total -- same ``apply_epilogue`` definition, same values."""
    if not epilogue:
        return sum_all_with_prologue(backend, x, plan, prologue)
    if _sum_all_takes(type(backend), "epilogue"):
        if prologue == "identity" and not _sum_all_takes_prologue(
            type(backend)
        ):  # pragma: no cover - epilogue-only exotic subclass
            return backend.sum_all(x, plan, epilogue=epilogue)
        return backend.sum_all(x, plan, prologue, epilogue=epilogue)
    return _kcommon.apply_epilogue(
        sum_all_with_prologue(backend, x, plan, prologue), epilogue
    )


def host_nonfinite_census(parts, dtype) -> jax.Array:
    """Reference non-finite census over a parts list: ``out[s]`` counts the
    NaN/Inf elements of part s, ``out[S]`` their cross-part total -- the
    host-side semantics the in-kernel census accumulator is pinned against.
    Non-inexact parts (ints, bools) have no non-finite values by
    construction and count 0 without ever touching ``isfinite``."""
    counts = []
    for p in parts:
        if p.size and jnp.issubdtype(p.dtype, jnp.inexact):
            counts.append(
                jnp.sum(~jnp.isfinite(p.reshape(-1))).astype(dtype)
            )
        else:
            counts.append(jnp.zeros((), dtype))
    per = jnp.stack(counts) if counts else jnp.zeros((0,), dtype)
    return jnp.concatenate([per, jnp.sum(per)[None]])


def sum_parts_total_with_census(
    backend, parts, plan, prologue, total_chains, census: bool
):
    """Invoke ``backend.sum_parts_total`` with the non-finite census,
    degrading gracefully for subclasses that predate it: ``census=False``
    never even passes the parameter (byte-for-byte the old call), and a
    pre-census subclass gets the reference host census appended to its
    returned row -- same layout, same values as the in-kernel count."""
    if not census:
        return backend.sum_parts_total(parts, plan, prologue, total_chains)
    if _method_takes(type(backend), "sum_parts_total", "census"):
        return backend.sum_parts_total(
            parts, plan, prologue, total_chains, census=True
        )
    out = backend.sum_parts_total(parts, plan, prologue, total_chains)
    return jnp.concatenate([out, host_nonfinite_census(parts, out.dtype)])


class Backend:
    """Base class; subclasses override the sum primitives."""

    name: str = "?"
    # True -> primitives are jnp-level code; jvp and vjp both flow through.
    native_autodiff: bool = False
    # True -> sum_all honours plan.precision == "kahan" internally (e.g. the
    # fused kernel's in-kernel per-lane compensation row). False -> api.py
    # wraps the backend in the blocked compensated combine instead.
    native_kahan: bool = False
    # True -> the elementwise prologues (and the moments dual accumulator)
    # run INSIDE the kernel on the raw leaf: single-stream sumsq/norm2/
    # moments with zero host-side staging. False -> the map is ordinary
    # fusible jnp code at accumulator precision.
    native_prologue: bool = False

    def sum_all(
        self,
        x: jax.Array,
        plan: ReducePlan,
        prologue: str = "identity",
        epilogue: tuple = (),
    ) -> jax.Array:
        raise NotImplementedError

    def sum_axis(self, x: jax.Array, plan: ReducePlan) -> jax.Array:
        raise NotImplementedError

    def scan_axis(self, x: jax.Array, plan, inclusive: bool = True,
                  trace=None) -> jax.Array:
        """``(..., L) -> (..., L)`` prefix sum over the last axis, in the
        STORAGE dtype (``plan`` is a ``ScanPlan``; accumulation at
        plan.accum_dtype). Default implementation: ``jnp.cumsum`` at f32
        with the exclusive variant via an exact shift -- NEVER
        ``cumsum - x``, whose re-rounding breaks the contract that an
        exclusive prefix is a true prefix -- so every pre-scan subclass
        inherits correct reference semantics. Integer/bool operands
        accumulate in their own dtype (exact adds; f32 would silently
        round past 2^24). ``trace`` is the kernel backends'
        instrumentation list (ignored here)."""
        acc = (
            plan.accum_jnp
            if jnp.issubdtype(x.dtype, jnp.floating) else x.dtype
        )
        out = jnp.cumsum(x.astype(acc), axis=-1)
        if not inclusive:
            out = jnp.concatenate(
                [jnp.zeros_like(out[..., :1]), out[..., :-1]], axis=-1
            )
        return out.astype(x.dtype)

    def moments_axis(self, x: jax.Array, plan: ReducePlan):
        """Fused (sum, sumsq) over the last axis. Default: the eq. (9)
        stacked all-ones dot -- both moments in ONE MXU pass (this is the
        LayerNorm statistics path; see row_moments_mma)."""
        return _core.row_moments_mma(
            x.astype(plan.accum_jnp),
            compute_dtype=plan.compute_jnp,
            accum_dtype=plan.accum_jnp,
        )

    def moments_all(self, x: jax.Array, plan: ReducePlan):
        """Full-array (sum, sumsq) scalar pair. Default: two ``sum_all``
        passes (identity + square) -- correct anywhere, including
        pre-prologue subclasses; the kernel backends override with the
        paired (x, x^2) dual-accumulator prologue so both statistics ride
        ONE pass over the raw leaf."""
        return (
            self.sum_all(x, plan),
            sum_all_with_prologue(self, x, plan, "square"),
        )

    def sum_segments(
        self,
        flat: jax.Array,
        offsets: Sequence[int],
        plan: ReducePlan,
        prologue: str = "identity",
        epilogue: tuple = (),
    ) -> jax.Array:
        """Independent sums ``out[s] = sum(P(flat[offsets[s]:offsets[s+1]]))``
        under the elementwise prologue P ("moments": the widened (2S,)
        vector -- sums in [0, S), sums of squares in [S, 2S)).

        ``offsets`` are *static* Python ints (len S+1, trace-time segment
        boundaries), so every slice below is a static lax.slice. Default
        implementation: one ``sum_all`` per segment -- correct for any
        subclass, but it is exactly the N-launch pattern the segmented
        engine exists to remove; the registered backends all override with
        single-pass implementations. ``epilogue`` (a normalized scalar
        chain; not with "moments") maps every per-segment total -- here via
        the host-side reference ``apply_epilogue``."""
        if prologue == "moments":
            if epilogue:
                raise ValueError(
                    "segment epilogues do not compose with "
                    "prologue='moments'"
                )
            return jnp.concatenate(
                [
                    self.sum_segments(flat, offsets, plan),
                    self.sum_segments(flat, offsets, plan, "square"),
                ]
            )
        accum = plan.accum_jnp
        outs = []
        for s in range(len(offsets) - 1):
            lo, hi = offsets[s], offsets[s + 1]
            if hi <= lo:
                outs.append(jnp.zeros((), accum))
            else:
                seg = jax.lax.slice(flat, (lo,), (hi,))
                outs.append(
                    sum_all_with_prologue(self, seg, plan, prologue).astype(
                        accum
                    )
                )
        if not outs:
            return jnp.zeros((0,), accum)
        return _kcommon.apply_epilogue(jnp.stack(outs), epilogue)

    def sum_parts(
        self,
        parts: Sequence[jax.Array],
        plan: ReducePlan,
        prologue="identity",
        epilogue: tuple = (),
    ) -> jax.Array:
        """Independent sums ``out[s] = sum(P_s(parts[s]))`` over SEPARATE
        arrays (``prologue``: one name, or one per part; any "moments"
        part widens the result to (2S,) with its sumsq in slot S + s).

        Default implementation: apply each part's map at accumulator
        precision, pack into one stream and ride ``sum_segments`` --
        correct for any subclass, and for the jnp-level backends both the
        map and the pack are ordinary fusible XLA code. Kernel backends
        override with the zero-copy parts kernel (each part enters the
        launch as its own operand, mapped in-kernel), because here the
        pack is a real n-sized concatenate+convert staging copy.
        ``epilogue`` (not with "moments") maps every per-part total."""
        accum = plan.accum_jnp
        nseg = len(parts)
        pros_probe = _kcommon.normalize_part_prologues(prologue, nseg)
        if epilogue and "moments" in pros_probe:
            raise ValueError(
                "parts epilogues do not compose with a 'moments' part"
            )
        if nseg == 0:
            return jnp.zeros((0,), accum)
        pros = pros_probe
        dual = "moments" in pros
        mapped = []
        for p, pro in zip(parts, pros):
            flat = p.reshape(-1)
            mapped.append(
                flat if pro == "moments"
                else _host_prologue(flat, plan, pro).astype(accum)
            )
        if dual:
            # widened layout: slot s sums P_s(part s); slot S + s sums the
            # square of a moments part (other square slots stay identity 0)
            mapped = [m.astype(accum) for m in mapped] + [
                _host_prologue(p.reshape(-1), plan, "square").astype(accum)
                if pro == "moments"
                else jnp.zeros((0,), accum)
                for p, pro in zip(parts, pros)
            ]
        sizes = [f.size for f in mapped]
        if sum(sizes) == 0:
            return _kcommon.apply_epilogue(
                jnp.zeros((len(mapped),), accum), epilogue
            )
        offsets = [0]
        for s in sizes:
            offsets.append(offsets[-1] + int(s))
        live = [f for f in mapped if f.size]
        flat = live[0] if len(live) == 1 else jnp.concatenate(live)
        return _kcommon.apply_epilogue(
            self.sum_segments(flat, tuple(offsets), plan), epilogue
        )

    def sum_parts_total(
        self,
        parts: Sequence[jax.Array],
        plan: ReducePlan,
        prologue="identity",
        total_chains: tuple = ((),),
        census: bool = False,
    ) -> jax.Array:
        """Per-part sums PLUS the epilogue'd cross-part total, one result:
        ``out[:S]`` = ``sum_parts`` and ``out[S + k]`` = chain k of
        ``total_chains`` applied to ``sum(out[:S])`` -- the whole-tree
        norm/clip statistic next to its per-leaf partials. Reference
        semantics here: host-side fold over the per-part sums, chains via
        ``apply_epilogue``; the Pallas backends override with the parts
        kernel's in-launch total accumulator, so the tree statistic never
        leaves the launch unfinished. Does not compose with "moments"
        parts.

        ``census=True`` widens the row by S + 1 more slots: per-part
        NON-FINITE element counts in ``out[S + K : S + K + S]`` and the
        cross-part total count last -- the guarded optimizer's NaN/Inf
        detector. Reference semantics here are the host
        ``host_nonfinite_census``; the Pallas backends count in-kernel on
        the tiles already streaming (zero extra input bytes)."""
        pros = _kcommon.normalize_part_prologues(prologue, len(parts))
        if "moments" in pros:
            raise ValueError(
                "sum_parts_total does not compose with a 'moments' part"
            )
        per = self.sum_parts(parts, plan, prologue)
        total = jnp.sum(per)
        totals = jnp.stack(
            [_kcommon.apply_epilogue(total, ch) for ch in total_chains]
        )
        pieces = [per, totals.astype(per.dtype)]
        if census:
            pieces.append(host_nonfinite_census(parts, per.dtype))
        return jnp.concatenate(pieces)

    def cross_device_combine(self, partials: jax.Array, plan: ReducePlan):
        """Combine per-device ADDITIVE partials across ``plan.mesh_axes``
        inside a shard_map body: the deterministic fixed-order all-gather
        fold (``core.collectives.fixed_order_combine``), NOT an opaque
        ``psum`` -- every device folds the identical gathered rows in the
        identical static order, so the global value is bit-identical on
        every replica at any device count. Backends targeting hardware with
        a deterministic in-network reduction may override; the contract is
        only that the result is replicated and bitwise replica-invariant."""
        if not plan.mesh_axes:
            return partials
        from repro.core import collectives as _coll  # deferred: cycle

        return _coll.fixed_order_combine(partials, plan.mesh_axes)


class XlaBackend(Backend):
    """Plain XLA reductions at accumulator precision -- the baseline/oracle."""

    name = "xla"
    native_autodiff = True

    def sum_all(self, x, plan, prologue="identity", epilogue=()):
        return _kcommon.apply_epilogue(
            jnp.sum(_host_prologue(x, plan, prologue).astype(plan.accum_jnp)),
            epilogue,
        )

    def sum_axis(self, x, plan):
        return jnp.sum(x.astype(plan.accum_jnp), axis=-1)

    def moments_axis(self, x, plan):
        xf = x.astype(plan.accum_jnp)
        return jnp.sum(xf, axis=-1), jnp.sum(xf * xf, axis=-1)

    def sum_segments(self, flat, offsets, plan, prologue="identity",
                     epilogue=()):
        # One exact segment_sum over the whole (prologue-mapped) stream
        # (the oracle the segmented test sweep pins every other backend
        # against). "moments" widens via the base-class concat of the
        # identity and square passes (XLA fuses both into one sweep).
        if prologue == "moments":
            return super().sum_segments(flat, offsets, plan, prologue,
                                        epilogue)
        sizes = np.diff(np.asarray(offsets, np.int64))
        ids = jnp.asarray(np.repeat(np.arange(sizes.size), sizes), jnp.int32)
        return _kcommon.apply_epilogue(
            jax.ops.segment_sum(
                _host_prologue(flat, plan, prologue).astype(plan.accum_jnp),
                ids,
                num_segments=int(sizes.size),
            ),
            epilogue,
        )


class MmaJnpBackend(Backend):
    """The paper's algorithm as jnp dots (runs on any backend, SPMD-safe)."""

    name = "mma_jnp"
    native_autodiff = True

    def sum_all(self, x, plan, prologue="identity", epilogue=()):
        return _kcommon.apply_epilogue(
            _core.mma_sum(
                _host_prologue(x, plan, prologue),
                m=plan.m,
                compute_dtype=plan.compute_jnp,
                accum_dtype=plan.accum_jnp,
            ),
            epilogue,
        )

    def sum_axis(self, x, plan):
        return _core.row_sum_mma(
            x.astype(plan.accum_jnp),
            compute_dtype=plan.compute_jnp,
            accum_dtype=plan.accum_jnp,
        )

    def scan_axis(self, x, plan, inclusive=True, trace=None):
        # The paper's triangular encoding as one batched chunk @ U einsum
        # plus an exact f32 strip-carry -- the algorithmic reference the
        # kernel is checked against, SPMD-safe on any backend.
        return _scan_kernels.mma_scan_jnp(
            x, inclusive=inclusive, m=plan.m,
            compute_dtype=plan.compute_jnp,
        )

    def sum_segments(self, flat, offsets, plan, prologue="identity",
                     epilogue=()):
        # Stage every segment as zero-padded rows of m, then ride ONE
        # batched eq. (9) all-ones dot over the whole padded row stream;
        # the n/m row partials combine with an exact f32 segment_sum (the
        # upper rungs of the paper's hierarchy, collapsed to one VPU pass).
        # The prologue maps the stream before the rows are built (zeros are
        # fixed points of every map, so the padding stays exact).
        if prologue == "moments":
            return super().sum_segments(flat, offsets, plan, prologue,
                                        epilogue)
        flat = _host_prologue(flat, plan, prologue)
        m = plan.m
        accum = plan.accum_jnp
        nseg = len(offsets) - 1
        rows, rcounts = [], []
        for s in range(nseg):
            lo, hi = offsets[s], offsets[s + 1]
            size = hi - lo
            r = -(-size // m) if size > 0 else 0
            rcounts.append(r)
            if r == 0:
                continue
            seg = jax.lax.slice(flat, (lo,), (hi,)).astype(accum)
            if r * m != size:
                seg = jnp.pad(seg, (0, r * m - size))
            rows.append(seg.reshape(r, m))
        if not rows:
            return _kcommon.apply_epilogue(jnp.zeros((nseg,), accum), epilogue)
        stream = jnp.concatenate(rows, 0) if len(rows) > 1 else rows[0]
        partials = _core.row_sum_mma(
            stream, compute_dtype=plan.compute_jnp, accum_dtype=accum
        )
        ids = jnp.asarray(np.repeat(np.arange(nseg), rcounts), jnp.int32)
        return _kcommon.apply_epilogue(
            jax.ops.segment_sum(partials, ids, num_segments=nseg), epilogue
        )


class _PallasBackend(Backend):
    """Shared plumbing for the two Pallas kernel modes. The kernels implement
    scalar (full) reductions; batched row reductions are the same eq. (9)
    all-ones dot the mma_jnp backend uses -- on TPU that single dot IS the
    kernel a row reduction would emit, and anything else would serialize one
    launch per row."""

    mode: str = "?"
    native_autodiff = False  # full reductions run inside pl.pallas_call
    # sumsq/norm2/moments map in-kernel on the raw leaf (single-stream).
    native_prologue = True

    @staticmethod
    def _check_m(plan):
        if plan.m != _pallas_ops.MXU:
            raise ValueError(
                f"pallas backends implement the m={_pallas_ops.MXU} MXU tile "
                f"only; got m={plan.m}. Use backend='mma_jnp' for tile-size "
                "ablations (m=2/4/16 per the paper)."
            )

    def sum_all(self, x, plan, prologue="identity", epilogue=()):
        self._check_m(plan)
        out = _pallas_ops.mma_sum_pallas(
            x,
            mode=self.mode,
            tiles_per_block=plan.tiles_per_block,
            num_cores=plan.num_cores,
            compute_dtype=plan.compute_jnp,
            kahan=self.native_kahan and plan.precision == "kahan",
            prologue=prologue,
            epilogue=epilogue,
        )
        return out.astype(plan.accum_jnp)

    def sum_axis(self, x, plan):
        return _core.row_sum_mma(
            x.astype(plan.accum_jnp),
            compute_dtype=plan.compute_jnp,
            accum_dtype=plan.accum_jnp,
        )

    def moments_axis(self, x, plan):
        # Batched ROW moments have no scalar-kernel form (one launch per
        # row would serialize the training hot path); they ride the same
        # stacked eq. (9) all-ones dot as mma_jnp -- on TPU that single dot
        # IS the MXU-native row reduction. This is a documented delegation,
        # not a silent fallback: full-array moments (axis=None) DO run the
        # in-kernel dual-accumulator prologue (``moments_all``).
        return super().moments_axis(x, plan)

    def moments_all(self, x, plan):
        # The paired (x, x^2) dual-accumulator prologue: both statistics
        # from ONE zero-copy pass over the raw leaf (single launch on the
        # fused mode; a single dual-emitting level-0 launch plus the f32
        # partial hierarchies on the hierarchical mode).
        self._check_m(plan)
        if self.native_kahan and plan.precision == "kahan":
            raise ValueError(
                "kind='moments' does not compose with precision='kahan' on "
                f"this backend (plan={plan!r}): the moments pass needs the "
                "dual (x, x^2) accumulator pair, which cannot share the "
                "kernel's in-kernel Kahan carry. Supported fallback: "
                "replan with precision='native' (e.g. reduce(x, "
                "kind='moments', precision='native')), or compensate the "
                "two sums separately via two kind='sum'/'sumsq' passes at "
                "precision='kahan'."
            )
        s, ss = _pallas_ops.mma_moments_pallas(
            x,
            mode=self.mode,
            tiles_per_block=plan.tiles_per_block,
            num_cores=plan.num_cores,
            compute_dtype=plan.compute_jnp,
        )
        return s.astype(plan.accum_jnp), ss.astype(plan.accum_jnp)

    def scan_axis(self, x, plan, inclusive=True, trace=None):
        # 1D streams take the triangular-MMA kernel: one pallas_call, native
        # ingest, block-padded prefix output, in-kernel carry chain.
        # Batched (ndim > 1) rows have no scalar-kernel form (one launch
        # per row would serialize the hot path); they ride the same batched
        # triangular einsum as mma_jnp -- a documented delegation exactly
        # like moments_axis, not a silent fallback.
        self._check_m(plan)
        if x.ndim > 1:
            return _scan_kernels.mma_scan_jnp(
                x, inclusive=inclusive, m=plan.m,
                compute_dtype=plan.compute_jnp,
            )
        return _scan_kernels.mma_scan_pallas(
            x,
            inclusive=inclusive,
            m=plan.m,
            tiles_per_block=plan.tiles_per_block,
            num_cores=plan.num_cores,
            compute_dtype=plan.compute_jnp,
            trace=trace,
        )

    def sum_segments(self, flat, offsets, plan, prologue="identity",
                     epilogue=()):
        # Both kernel modes share the single-launch segmented gather kernel:
        # the hierarchy's only distinction (relaunch on partials) is moot
        # once every boundary flushes inside one launch. The kernel reads
        # ``flat`` zero-copy through its aligned-block cover maps and maps
        # each gathered tile in-kernel; ``epilogue`` maps each flushed
        # per-segment total (in-kernel on single-lane launches, host-side
        # after the lane combine otherwise -- same chain, same values).
        self._check_m(plan)
        out = _pallas_ops.mma_sum_segments_pallas(
            flat,
            tuple(offsets),
            tiles_per_block=plan.tiles_per_block,
            num_cores=plan.num_cores,
            compute_dtype=plan.compute_jnp,
            prologue=prologue,
            epilogue=epilogue,
        )
        return out.astype(plan.accum_jnp)

    def sum_parts(self, parts, plan, prologue="identity", epilogue=()):
        # Zero-copy multi-reduce: every part is its own launch operand, so
        # the packed-stream concatenate (and its accumulator-dtype staging
        # cast) never materializes -- and the prologue maps each part
        # in-kernel, so sumsq/norm2/moments batches stream every raw leaf
        # exactly once. The parts kernel compiles one branch and keeps one
        # VMEM block per live part, so past PARTS_KERNEL_MAX live parts the
        # staged pack (small per-part buffers, one concat, host-side maps)
        # is the better trade -- documented fallback via the base class.
        # ``epilogue`` maps each flushed per-part total in-kernel.
        self._check_m(plan)
        live = sum(1 for p in parts if p.size)
        if live > _pallas_ops.PARTS_KERNEL_MAX:
            return super().sum_parts(parts, plan, prologue, epilogue)
        out = _pallas_ops.mma_sum_parts_pallas(
            parts, compute_dtype=plan.compute_jnp, prologue=prologue,
            slot_epilogue=epilogue,
        )
        return out.astype(plan.accum_jnp)

    def sum_parts_total(self, parts, plan, prologue="identity",
                        total_chains=((),), census=False):
        # The whole-tree statistic WITHOUT leaving the launch: the parts
        # kernel's (1,) VMEM total accumulator folds every flushed per-part
        # total in static part order (its sequential grid ignores
        # plan.num_cores entirely, so this holds at ANY core count) and the
        # final flush emits each chain of the raw total into its own extra
        # output slot. reduce_tree(kind="norm2", epilogue=...) therefore
        # costs ONE launch with zero host-side sqrt/min/div eqns -- and
        # census=True counts NaN/Inf on the same in-flight tiles into S + 1
        # more slots, still one launch, still zero extra input bytes. Past
        # PARTS_KERNEL_MAX live parts: base-class host fold (documented
        # fallback, same values -- including the host reference census).
        self._check_m(plan)
        pros = _kcommon.normalize_part_prologues(prologue, len(parts))
        live = sum(1 for p in parts if p.size)
        if "moments" in pros or live > _pallas_ops.PARTS_KERNEL_MAX:
            return super().sum_parts_total(parts, plan, prologue,
                                           total_chains, census=census)
        out = _pallas_ops.mma_sum_parts_pallas(
            parts, compute_dtype=plan.compute_jnp, prologue=prologue,
            total_chains=tuple(total_chains), census=census,
        )
        return out.astype(plan.accum_jnp)


class PallasHierBackend(_PallasBackend):
    name = "pallas_hier"
    mode = "hierarchical"


class PallasFusedBackend(_PallasBackend):
    name = "pallas_fused"
    mode = "fused"
    # The fused lane carries its compensation in a second VMEM scratch row,
    # so precision="kahan" stays a SINGLE launch (api.py's blocked combine
    # would pay one launch per kahan_block).
    native_kahan = True


class SegmentedBackend(Backend):
    """The registered "segmented" auto-route.

    The planner sends multi-reduce problems here (``plan_for(...,
    segments=N)`` -> backend "segmented"); the concrete executor is resolved
    *per call* from the live problem via ``plan.segmented_backend_for`` --
    exact XLA for non-float data, the single-launch Pallas kernel for large
    streams on a real TPU, the one-dot jnp path everywhere else -- so a plan
    cached on one problem key stays valid wherever it is replayed. The
    scalar/row primitives delegate the same way, which keeps an explicitly
    pinned ``backend="segmented"`` usable with the whole ``reduce`` API."""

    name = "segmented"
    # May resolve to a kernel-backed executor, so api.py conservatively
    # wraps full/segmented reductions in the custom VJP.
    native_autodiff = False

    def _delegate(self, n: int, dtype, plan: ReducePlan):
        name = segmented_backend_for(n, dtype, plan.m)
        return get_backend(name), plan.replace(backend=name)

    def sum_all(self, x, plan, prologue="identity", epilogue=()):
        b, p = self._delegate(x.size, x.dtype, plan)
        return b.sum_all(x, p, prologue, epilogue=epilogue)

    def sum_axis(self, x, plan):
        b, p = self._delegate(x.shape[-1], x.dtype, plan)
        return b.sum_axis(x, p)

    def moments_axis(self, x, plan):
        b, p = self._delegate(x.shape[-1], x.dtype, plan)
        return b.moments_axis(x, p)

    def moments_all(self, x, plan):
        b, p = self._delegate(x.size, x.dtype, plan)
        return b.moments_all(x, p)

    def sum_segments(self, flat, offsets, plan, prologue="identity",
                     epilogue=()):
        b, p = self._delegate(flat.size, flat.dtype, plan)
        return b.sum_segments(flat, offsets, p, prologue, epilogue=epilogue)

    def sum_parts(self, parts, plan, prologue="identity", epilogue=()):
        total = sum(int(p.size) for p in parts)
        dtype = jnp.result_type(*parts) if parts else jnp.float32
        b, p = self._delegate(total, dtype, plan)
        return b.sum_parts(parts, p, prologue, epilogue=epilogue)

    def sum_parts_total(self, parts, plan, prologue="identity",
                        total_chains=((),), census=False):
        total = sum(int(p.size) for p in parts)
        dtype = jnp.result_type(*parts) if parts else jnp.float32
        b, p = self._delegate(total, dtype, plan)
        return sum_parts_total_with_census(
            b, parts, p, prologue, total_chains, census
        )


_REGISTRY: Dict[str, Backend] = {}


def register_backend(backend: Backend, name: str | None = None) -> Backend:
    """Add a backend to the registry (later PRs: gpu wgmma, autotuned)."""
    _REGISTRY[name or backend.name] = backend
    return backend


def get_backend(name: str) -> Backend:
    try:
        return _REGISTRY[name]
    except KeyError:
        raise KeyError(
            f"unknown reduce backend {name!r}; available: "
            f"{', '.join(available_backends())}"
        ) from None


def available_backends() -> tuple[str, ...]:
    return tuple(sorted(_REGISTRY))


register_backend(XlaBackend())
register_backend(MmaJnpBackend())
register_backend(PallasHierBackend())
register_backend(PallasFusedBackend())
register_backend(SegmentedBackend())
