"""Backend registry: the interchangeable executors behind ``repro.reduce``.

A backend implements three primitives and nothing else:

  sum_all(x, plan)     -- every element of ``x`` -> scalar of plan.accum_dtype.
  sum_axis(x, plan)    -- ``(..., L) -> (...)`` sum over the last axis.
  moments_axis(x, plan)-- ``(..., L) -> ((...), (...))`` fused (sum, sumsq).

Every reduction kind ("mean", "sumsq", "norm2", "moments") is composed from
these in ``api.py``, so a new backend (GPU wgmma, segmented, autotuned) only
has to supply them to light up the whole API.

Differentiation contract: backends whose primitives are plain jnp/dot code
set ``native_autodiff = True`` and support both reverse- AND forward-mode
autodiff; kernel-backed backends leave it False and ``api`` wraps their
full reductions in a ``jax.custom_vjp`` (broadcast-of-cotangent rule).
Batched row reductions are *always* executed as native dot/sum code -- the
scalar kernels have no batched form, and serializing one launch per row
would be catastrophic in training hot paths -- so axis reductions stay
forward-differentiable on every backend.

Registered here:

  xla          -- ``jnp.sum`` baseline (the paper's comparison point, and the
                  oracle the test sweep checks every other backend against).
  mma_jnp      -- the paper's hierarchical 2-MMA algorithm in pure JAX
                  (``repro.core.mma_reduce``); rows via the eq. (9) all-ones
                  dot, full reductions via the eq. (13) recurrence.
  pallas_hier  -- Pallas TPU kernel, paper-faithful multi-launch hierarchy
                  (full reductions; rows ride the same eq. (9) dot as
                  mma_jnp -- that IS the MXU-native row reduction).
  pallas_fused -- Pallas TPU kernel, single-launch C-accumulator variant
                  (n/m^2 + 2 MMAs; see EXPERIMENTS.md).
"""

from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp

from repro.core import mma_reduce as _core
from repro.kernels.mma_reduce import ops as _pallas_ops
from repro.reduce.plan import ReducePlan


class Backend:
    """Base class; subclasses override the sum primitives."""

    name: str = "?"
    # True -> primitives are jnp-level code; jvp and vjp both flow through.
    native_autodiff: bool = False

    def sum_all(self, x: jax.Array, plan: ReducePlan) -> jax.Array:
        raise NotImplementedError

    def sum_axis(self, x: jax.Array, plan: ReducePlan) -> jax.Array:
        raise NotImplementedError

    def moments_axis(self, x: jax.Array, plan: ReducePlan):
        """Fused (sum, sumsq) over the last axis. Default: the eq. (9)
        stacked all-ones dot -- both moments in ONE MXU pass (this is the
        LayerNorm statistics path; see row_moments_mma)."""
        return _core.row_moments_mma(
            x.astype(plan.accum_jnp),
            compute_dtype=plan.compute_jnp,
            accum_dtype=plan.accum_jnp,
        )


class XlaBackend(Backend):
    """Plain XLA reductions at accumulator precision -- the baseline/oracle."""

    name = "xla"
    native_autodiff = True

    def sum_all(self, x, plan):
        return jnp.sum(x.astype(plan.accum_jnp))

    def sum_axis(self, x, plan):
        return jnp.sum(x.astype(plan.accum_jnp), axis=-1)

    def moments_axis(self, x, plan):
        xf = x.astype(plan.accum_jnp)
        return jnp.sum(xf, axis=-1), jnp.sum(xf * xf, axis=-1)


class MmaJnpBackend(Backend):
    """The paper's algorithm as jnp dots (runs on any backend, SPMD-safe)."""

    name = "mma_jnp"
    native_autodiff = True

    def sum_all(self, x, plan):
        return _core.mma_sum(
            x,
            m=plan.m,
            compute_dtype=plan.compute_jnp,
            accum_dtype=plan.accum_jnp,
        )

    def sum_axis(self, x, plan):
        return _core.row_sum_mma(
            x.astype(plan.accum_jnp),
            compute_dtype=plan.compute_jnp,
            accum_dtype=plan.accum_jnp,
        )


class _PallasBackend(Backend):
    """Shared plumbing for the two Pallas kernel modes. The kernels implement
    scalar (full) reductions; batched row reductions are the same eq. (9)
    all-ones dot the mma_jnp backend uses -- on TPU that single dot IS the
    kernel a row reduction would emit, and anything else would serialize one
    launch per row."""

    mode: str = "?"
    native_autodiff = False  # full reductions run inside pl.pallas_call

    def sum_all(self, x, plan):
        if plan.m != _pallas_ops.MXU:
            raise ValueError(
                f"pallas backends implement the m={_pallas_ops.MXU} MXU tile "
                f"only; got m={plan.m}. Use backend='mma_jnp' for tile-size "
                "ablations (m=2/4/16 per the paper)."
            )
        out = _pallas_ops.mma_sum_pallas(
            x,
            mode=self.mode,
            tiles_per_block=plan.tiles_per_block,
            compute_dtype=plan.compute_jnp,
        )
        return out.astype(plan.accum_jnp)

    def sum_axis(self, x, plan):
        return _core.row_sum_mma(
            x.astype(plan.accum_jnp),
            compute_dtype=plan.compute_jnp,
            accum_dtype=plan.accum_jnp,
        )


class PallasHierBackend(_PallasBackend):
    name = "pallas_hier"
    mode = "hierarchical"


class PallasFusedBackend(_PallasBackend):
    name = "pallas_fused"
    mode = "fused"


_REGISTRY: Dict[str, Backend] = {}


def register_backend(backend: Backend, name: str | None = None) -> Backend:
    """Add a backend to the registry (later PRs: gpu, segmented, autotuned)."""
    _REGISTRY[name or backend.name] = backend
    return backend


def get_backend(name: str) -> Backend:
    try:
        return _REGISTRY[name]
    except KeyError:
        raise KeyError(
            f"unknown reduce backend {name!r}; available: "
            f"{', '.join(available_backends())}"
        ) from None


def available_backends() -> tuple[str, ...]:
    return tuple(sorted(_REGISTRY))


register_backend(XlaBackend())
register_backend(MmaJnpBackend())
register_backend(PallasHierBackend())
register_backend(PallasFusedBackend())
