"""Backend registry: the interchangeable executors behind ``repro.reduce``.

A backend implements five primitives and nothing else:

  sum_all(x, plan)     -- every element of ``x`` -> scalar of plan.accum_dtype.
  sum_axis(x, plan)    -- ``(..., L) -> (...)`` sum over the last axis.
  moments_axis(x, plan)-- ``(..., L) -> ((...), (...))`` fused (sum, sumsq).
  sum_segments(flat, offsets, plan)
                       -- S independent sums over static slices of one
                          packed 1-D stream -> (S,).
  sum_parts(parts, plan)
                       -- S independent sums over S SEPARATE arrays
                          -> (S,); the zero-copy multi-reduce primitive
                          behind ``reduce_many`` / ``reduce_tree`` (ONE
                          launch for a whole training step's worth of
                          small reductions, with no packing concatenation
                          on the kernel backends).

Every reduction kind ("mean", "sumsq", "norm2", "moments") is composed from
these in ``api.py``, so a new backend (GPU wgmma, autotuned) only has to
supply them to light up the whole API; ``sum_segments`` and ``sum_parts``
have correct (if staged/multi-launch) defaults, so third-party backends
inherit the batched APIs for free.

Differentiation contract: backends whose primitives are plain jnp/dot code
set ``native_autodiff = True`` and support both reverse- AND forward-mode
autodiff; kernel-backed backends leave it False and ``api`` wraps their
full reductions in a ``jax.custom_vjp`` (broadcast-of-cotangent rule).
Batched row reductions are *always* executed as native dot/sum code -- the
scalar kernels have no batched form, and serializing one launch per row
would be catastrophic in training hot paths -- so axis reductions stay
forward-differentiable on every backend.

Registered here:

  xla          -- ``jnp.sum`` baseline (the paper's comparison point, and the
                  oracle the test sweep checks every other backend against).
  mma_jnp      -- the paper's hierarchical 2-MMA algorithm in pure JAX
                  (``repro.core.mma_reduce``); rows via the eq. (9) all-ones
                  dot, full reductions via the eq. (13) recurrence.
  pallas_hier  -- Pallas TPU kernel, paper-faithful multi-launch hierarchy
                  (full reductions; rows ride the same eq. (9) dot as
                  mma_jnp -- that IS the MXU-native row reduction).
  pallas_fused -- Pallas TPU kernel, single-launch C-accumulator variant,
                  striped across plan.num_cores parallel lanes with a
                  deterministic fixed-order lane combine (n/(m^2 c) + c
                  MMAs per lane; see EXPERIMENTS.md "Multi-core scaling").
  segmented    -- auto-routing registry entry for multi-reduce problems:
                  resolves the concrete executor per call
                  (``plan.segmented_backend_for``) and delegates.
"""

from __future__ import annotations

from typing import Dict, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import mma_reduce as _core
from repro.kernels.mma_reduce import ops as _pallas_ops
from repro.reduce.plan import ReducePlan, segmented_backend_for


class Backend:
    """Base class; subclasses override the sum primitives."""

    name: str = "?"
    # True -> primitives are jnp-level code; jvp and vjp both flow through.
    native_autodiff: bool = False
    # True -> sum_all honours plan.precision == "kahan" internally (e.g. the
    # fused kernel's in-kernel per-lane compensation row). False -> api.py
    # wraps the backend in the blocked compensated combine instead.
    native_kahan: bool = False

    def sum_all(self, x: jax.Array, plan: ReducePlan) -> jax.Array:
        raise NotImplementedError

    def sum_axis(self, x: jax.Array, plan: ReducePlan) -> jax.Array:
        raise NotImplementedError

    def moments_axis(self, x: jax.Array, plan: ReducePlan):
        """Fused (sum, sumsq) over the last axis. Default: the eq. (9)
        stacked all-ones dot -- both moments in ONE MXU pass (this is the
        LayerNorm statistics path; see row_moments_mma)."""
        return _core.row_moments_mma(
            x.astype(plan.accum_jnp),
            compute_dtype=plan.compute_jnp,
            accum_dtype=plan.accum_jnp,
        )

    def sum_segments(
        self, flat: jax.Array, offsets: Sequence[int], plan: ReducePlan
    ) -> jax.Array:
        """Independent sums ``out[s] = sum(flat[offsets[s]:offsets[s+1]])``.

        ``offsets`` are *static* Python ints (len S+1, trace-time segment
        boundaries), so every slice below is a static lax.slice. Default
        implementation: one ``sum_all`` per segment -- correct for any
        subclass, but it is exactly the N-launch pattern the segmented
        engine exists to remove; the registered backends all override with
        single-pass implementations."""
        accum = plan.accum_jnp
        outs = []
        for s in range(len(offsets) - 1):
            lo, hi = offsets[s], offsets[s + 1]
            if hi <= lo:
                outs.append(jnp.zeros((), accum))
            else:
                seg = jax.lax.slice(flat, (lo,), (hi,))
                outs.append(self.sum_all(seg, plan).astype(accum))
        if not outs:
            return jnp.zeros((0,), accum)
        return jnp.stack(outs)

    def sum_parts(
        self, parts: Sequence[jax.Array], plan: ReducePlan
    ) -> jax.Array:
        """Independent sums ``out[s] = sum(parts[s])`` over SEPARATE arrays.

        Default implementation: pack the parts into one accumulator-dtype
        stream and ride ``sum_segments`` -- correct for any subclass, and
        for the jnp-level backends the pack is ordinary fusible XLA code.
        Kernel backends override with the zero-copy parts kernel (each part
        enters the launch as its own operand), because here the pack is a
        real n-sized concatenate+convert staging copy."""
        accum = plan.accum_jnp
        nseg = len(parts)
        if nseg == 0:
            return jnp.zeros((0,), accum)
        flats = [p.reshape(-1).astype(accum) for p in parts]
        sizes = [f.size for f in flats]
        if sum(sizes) == 0:
            return jnp.zeros((nseg,), accum)
        offsets = [0]
        for s in sizes:
            offsets.append(offsets[-1] + int(s))
        live = [f for f in flats if f.size]
        flat = live[0] if len(live) == 1 else jnp.concatenate(live)
        return self.sum_segments(flat, tuple(offsets), plan)


class XlaBackend(Backend):
    """Plain XLA reductions at accumulator precision -- the baseline/oracle."""

    name = "xla"
    native_autodiff = True

    def sum_all(self, x, plan):
        return jnp.sum(x.astype(plan.accum_jnp))

    def sum_axis(self, x, plan):
        return jnp.sum(x.astype(plan.accum_jnp), axis=-1)

    def moments_axis(self, x, plan):
        xf = x.astype(plan.accum_jnp)
        return jnp.sum(xf, axis=-1), jnp.sum(xf * xf, axis=-1)

    def sum_segments(self, flat, offsets, plan):
        # One exact segment_sum over the whole stream (the oracle the
        # segmented test sweep pins every other backend against).
        sizes = np.diff(np.asarray(offsets, np.int64))
        ids = jnp.asarray(np.repeat(np.arange(sizes.size), sizes), jnp.int32)
        return jax.ops.segment_sum(
            flat.astype(plan.accum_jnp), ids, num_segments=int(sizes.size)
        )


class MmaJnpBackend(Backend):
    """The paper's algorithm as jnp dots (runs on any backend, SPMD-safe)."""

    name = "mma_jnp"
    native_autodiff = True

    def sum_all(self, x, plan):
        return _core.mma_sum(
            x,
            m=plan.m,
            compute_dtype=plan.compute_jnp,
            accum_dtype=plan.accum_jnp,
        )

    def sum_axis(self, x, plan):
        return _core.row_sum_mma(
            x.astype(plan.accum_jnp),
            compute_dtype=plan.compute_jnp,
            accum_dtype=plan.accum_jnp,
        )

    def sum_segments(self, flat, offsets, plan):
        # Stage every segment as zero-padded rows of m, then ride ONE
        # batched eq. (9) all-ones dot over the whole padded row stream;
        # the n/m row partials combine with an exact f32 segment_sum (the
        # upper rungs of the paper's hierarchy, collapsed to one VPU pass).
        m = plan.m
        accum = plan.accum_jnp
        nseg = len(offsets) - 1
        rows, rcounts = [], []
        for s in range(nseg):
            lo, hi = offsets[s], offsets[s + 1]
            size = hi - lo
            r = -(-size // m) if size > 0 else 0
            rcounts.append(r)
            if r == 0:
                continue
            seg = jax.lax.slice(flat, (lo,), (hi,)).astype(accum)
            if r * m != size:
                seg = jnp.pad(seg, (0, r * m - size))
            rows.append(seg.reshape(r, m))
        if not rows:
            return jnp.zeros((nseg,), accum)
        stream = jnp.concatenate(rows, 0) if len(rows) > 1 else rows[0]
        partials = _core.row_sum_mma(
            stream, compute_dtype=plan.compute_jnp, accum_dtype=accum
        )
        ids = jnp.asarray(np.repeat(np.arange(nseg), rcounts), jnp.int32)
        return jax.ops.segment_sum(partials, ids, num_segments=nseg)


class _PallasBackend(Backend):
    """Shared plumbing for the two Pallas kernel modes. The kernels implement
    scalar (full) reductions; batched row reductions are the same eq. (9)
    all-ones dot the mma_jnp backend uses -- on TPU that single dot IS the
    kernel a row reduction would emit, and anything else would serialize one
    launch per row."""

    mode: str = "?"
    native_autodiff = False  # full reductions run inside pl.pallas_call

    @staticmethod
    def _check_m(plan):
        if plan.m != _pallas_ops.MXU:
            raise ValueError(
                f"pallas backends implement the m={_pallas_ops.MXU} MXU tile "
                f"only; got m={plan.m}. Use backend='mma_jnp' for tile-size "
                "ablations (m=2/4/16 per the paper)."
            )

    def sum_all(self, x, plan):
        self._check_m(plan)
        out = _pallas_ops.mma_sum_pallas(
            x,
            mode=self.mode,
            tiles_per_block=plan.tiles_per_block,
            num_cores=plan.num_cores,
            compute_dtype=plan.compute_jnp,
            kahan=self.native_kahan and plan.precision == "kahan",
        )
        return out.astype(plan.accum_jnp)

    def sum_axis(self, x, plan):
        return _core.row_sum_mma(
            x.astype(plan.accum_jnp),
            compute_dtype=plan.compute_jnp,
            accum_dtype=plan.accum_jnp,
        )

    def sum_segments(self, flat, offsets, plan):
        # Both kernel modes share the single-launch segmented gather kernel:
        # the hierarchy's only distinction (relaunch on partials) is moot
        # once every boundary flushes inside one launch. The kernel reads
        # ``flat`` zero-copy through its aligned-block cover maps.
        self._check_m(plan)
        out = _pallas_ops.mma_sum_segments_pallas(
            flat,
            tuple(offsets),
            tiles_per_block=plan.tiles_per_block,
            num_cores=plan.num_cores,
            compute_dtype=plan.compute_jnp,
        )
        return out.astype(plan.accum_jnp)

    def sum_parts(self, parts, plan):
        # Zero-copy multi-reduce: every part is its own launch operand, so
        # the packed-stream concatenate (and its accumulator-dtype staging
        # cast) never materializes. The parts kernel compiles one branch
        # and keeps one VMEM block per live part, so past PARTS_KERNEL_MAX
        # live parts the staged pack (small per-part buffers, one concat)
        # is the better trade -- documented fallback via the base class.
        self._check_m(plan)
        live = sum(1 for p in parts if p.size)
        if live > _pallas_ops.PARTS_KERNEL_MAX:
            return super().sum_parts(parts, plan)
        out = _pallas_ops.mma_sum_parts_pallas(
            parts, compute_dtype=plan.compute_jnp
        )
        return out.astype(plan.accum_jnp)


class PallasHierBackend(_PallasBackend):
    name = "pallas_hier"
    mode = "hierarchical"


class PallasFusedBackend(_PallasBackend):
    name = "pallas_fused"
    mode = "fused"
    # The fused lane carries its compensation in a second VMEM scratch row,
    # so precision="kahan" stays a SINGLE launch (api.py's blocked combine
    # would pay one launch per kahan_block).
    native_kahan = True


class SegmentedBackend(Backend):
    """The registered "segmented" auto-route.

    The planner sends multi-reduce problems here (``plan_for(...,
    segments=N)`` -> backend "segmented"); the concrete executor is resolved
    *per call* from the live problem via ``plan.segmented_backend_for`` --
    exact XLA for non-float data, the single-launch Pallas kernel for large
    streams on a real TPU, the one-dot jnp path everywhere else -- so a plan
    cached on one problem key stays valid wherever it is replayed. The
    scalar/row primitives delegate the same way, which keeps an explicitly
    pinned ``backend="segmented"`` usable with the whole ``reduce`` API."""

    name = "segmented"
    # May resolve to a kernel-backed executor, so api.py conservatively
    # wraps full/segmented reductions in the custom VJP.
    native_autodiff = False

    def _delegate(self, n: int, dtype, plan: ReducePlan):
        name = segmented_backend_for(n, dtype, plan.m)
        return get_backend(name), plan.replace(backend=name)

    def sum_all(self, x, plan):
        b, p = self._delegate(x.size, x.dtype, plan)
        return b.sum_all(x, p)

    def sum_axis(self, x, plan):
        b, p = self._delegate(x.shape[-1], x.dtype, plan)
        return b.sum_axis(x, p)

    def moments_axis(self, x, plan):
        b, p = self._delegate(x.shape[-1], x.dtype, plan)
        return b.moments_axis(x, p)

    def sum_segments(self, flat, offsets, plan):
        b, p = self._delegate(flat.size, flat.dtype, plan)
        return b.sum_segments(flat, offsets, p)

    def sum_parts(self, parts, plan):
        total = sum(int(p.size) for p in parts)
        dtype = jnp.result_type(*parts) if parts else jnp.float32
        b, p = self._delegate(total, dtype, plan)
        return b.sum_parts(parts, p)


_REGISTRY: Dict[str, Backend] = {}


def register_backend(backend: Backend, name: str | None = None) -> Backend:
    """Add a backend to the registry (later PRs: gpu wgmma, autotuned)."""
    _REGISTRY[name or backend.name] = backend
    return backend


def get_backend(name: str) -> Backend:
    try:
        return _REGISTRY[name]
    except KeyError:
        raise KeyError(
            f"unknown reduce backend {name!r}; available: "
            f"{', '.join(available_backends())}"
        ) from None


def available_backends() -> tuple[str, ...]:
    return tuple(sorted(_REGISTRY))


register_backend(XlaBackend())
register_backend(MmaJnpBackend())
register_backend(PallasHierBackend())
register_backend(PallasFusedBackend())
register_backend(SegmentedBackend())
