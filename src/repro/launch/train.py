"""Training driver: config -> data -> jitted step -> checkpointed loop.

Runs anywhere: on this CPU container it trains the --tiny configs end to
end (examples/quickstart.py drives it); on a TPU fleet the same entry point
takes --arch <full> and the production mesh.

  PYTHONPATH=src python -m repro.launch.train --arch olmo-1b --tiny \
      --steps 50 --batch 8 --seq 128 --ckpt-dir /tmp/ckpt
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import optim
from repro import reduce as R
from repro.checkpoint import CheckpointManager
from repro.configs import TrainConfig, get_arch
from repro.data import Prefetcher, ShardInfo, SyntheticLM
from repro.launch.steps import make_jitted_train_step
from repro.models import init_params
from repro.models.frontends import synth_image_embeds
from repro.runtime import PreemptionGuard, TrainSupervisor


def build(cfg, tcfg, batch: int, seq: int, mesh=None):
    params, axes = init_params(jax.random.PRNGKey(tcfg.seed), cfg)
    opt_state = optim.init_state(
        params, fused_second_moment=tcfg.fused_second_moment
    )
    # donate_argnums: params and opt_state update IN PLACE (their buffers
    # are reused for the outputs) -- callers rebind both from the return
    step_fn = make_jitted_train_step(cfg, tcfg, mesh)
    return params, opt_state, step_fn


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--tiny", action="store_true")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=25)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument(
        "--fused-second-moment",
        action="store_true",
        help="olmax-style scalar v EMA fed by the norm launch's per-leaf "
        "sumsq slots (one HBM trip per grad leaf per step)",
    )
    ap.add_argument(
        "--reduce-backend",
        default=None,
        choices=R.available_backends() + ("auto",),
        help="process-wide repro.reduce backend (default: cost-model auto)",
    )
    args = ap.parse_args(argv)

    if args.reduce_backend:
        R.set_default_backend(args.reduce_backend)
    cfg = get_arch(args.arch, tiny=args.tiny)
    tcfg = TrainConfig(
        learning_rate=args.lr, total_steps=args.steps,
        warmup_steps=max(1, args.steps // 10), microbatches=args.microbatches,
        fused_second_moment=args.fused_second_moment,
    )
    params, opt_state, step_fn = build(cfg, tcfg, args.batch, args.seq)
    n_params = sum(x.size for x in jax.tree.leaves(params))
    print(f"arch={cfg.name} params={n_params/1e6:.1f}M steps={args.steps}")

    data = SyntheticLM(
        cfg.vocab_size, args.seq, args.batch, ShardInfo(), seed=tcfg.seed,
        n_codebooks=cfg.n_codebooks,
    )
    prefetch = Prefetcher(data)
    ctx = (
        synth_image_embeds(
            jax.random.PRNGKey(1), args.batch, cfg.n_img_tokens, cfg.d_model,
            jnp.dtype(cfg.dtype),
        )
        if cfg.n_img_tokens
        else None
    )

    ckpt = CheckpointManager(args.ckpt_dir) if args.ckpt_dir else None
    guard = PreemptionGuard()
    start_step = 0
    if ckpt and ckpt.latest() is not None:
        step0 = ckpt.latest()
        params, opt_state = ckpt.restore(step0, (params, opt_state))
        data.seek(ckpt.manifest(step0)["extra"]["data_step"])
        start_step = step0
        print(f"resumed from step {step0}")

    losses = []
    t0 = time.time()
    for step in range(start_step, args.steps):
        batch = prefetch.next()
        feed = {"tokens": jnp.asarray(batch["tokens"])}
        if ctx is not None:
            feed["image_embeds"] = ctx
        params, opt_state, metrics = step_fn(params, opt_state, feed)
        losses.append(float(metrics["loss"]))
        if (step + 1) % args.log_every == 0:
            dt = (time.time() - t0) / args.log_every
            print(
                f"step {step+1:5d} loss {losses[-1]:.4f} "
                f"gnorm {float(metrics['grad_norm']):.3f} "
                f"lr {float(metrics['lr']):.2e} {dt*1e3:.0f} ms/step"
            )
            t0 = time.time()
        if ckpt and ((step + 1) % args.ckpt_every == 0 or guard.should_stop):
            ckpt.save(step + 1, (params, opt_state),
                      extra={"data_step": data.state()["step"]})
        if guard.should_stop:
            print("preempted: checkpoint flushed, exiting cleanly")
            break
    if ckpt:
        ckpt.wait()
    prefetch.close()
    print(f"final loss {losses[-1]:.4f} (first {losses[0]:.4f})")
    return losses


if __name__ == "__main__":
    main()
