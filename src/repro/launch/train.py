"""Training driver: config -> data -> jitted step -> checkpointed loop.

Runs anywhere: on this CPU container it trains the --tiny configs end to
end (examples/quickstart.py drives it); on a TPU fleet the same entry point
takes --arch <full> and the production mesh.

  PYTHONPATH=src python -m repro.launch.train --arch olmo-1b --tiny \
      --steps 50 --batch 8 --seq 128 --ckpt-dir /tmp/ckpt
"""

from __future__ import annotations

import argparse
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import optim
from repro import reduce as R
from repro.checkpoint import CheckpointManager
from repro.configs import TrainConfig, get_arch
from repro.data import Prefetcher, ShardInfo, SyntheticLM
from repro.launch.mesh import make_data_mesh
from repro.launch.steps import (
    make_jitted_guarded_train_step,
    make_jitted_train_step,
    make_mesh_guarded_train_step,
)
from repro.models import init_params
from repro.models.frontends import synth_image_embeds
from repro.runtime import (
    ChaosMonkey,
    GuardMetrics,
    PreemptionGuard,
    StepGuard,
    TrainSupervisor,
)


def build(cfg, tcfg, batch: int, seq: int, mesh=None, *, guard=False,
          spike_z: float = 6.0, data_mesh=None):
    params, axes = init_params(jax.random.PRNGKey(tcfg.seed), cfg)
    opt_state = optim.init_state(
        params, fused_second_moment=tcfg.fused_second_moment
    )
    # donate_argnums: params and opt_state update IN PLACE (their buffers
    # are reused for the outputs) -- callers rebind both from the return
    if data_mesh is not None:
        step_fn = make_mesh_guarded_train_step(cfg, tcfg, data_mesh,
                                               spike_z=spike_z)
    elif guard:
        step_fn = make_jitted_guarded_train_step(cfg, tcfg, mesh,
                                                 spike_z=spike_z)
    else:
        step_fn = make_jitted_train_step(cfg, tcfg, mesh)
    return params, opt_state, step_fn


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--tiny", action="store_true")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=25)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument(
        "--fused-second-moment",
        action="store_true",
        help="olmax-style scalar v EMA fed by the norm launch's per-leaf "
        "sumsq slots (one HBM trip per grad leaf per step)",
    )
    ap.add_argument(
        "--guard",
        action="store_true",
        help="guarded step: the clip statistic's launch also counts NaN/Inf "
        "grad elements (in-launch census); a poisoned or loss-spiking step "
        "passes params/opt state through bitwise unchanged, and "
        "--max-bad-steps consecutive skips roll back to the last committed "
        "checkpoint (requires --ckpt-dir for rollback)",
    )
    ap.add_argument(
        "--spike-window", type=int, default=16,
        help="guarded step: accepted-loss window length for the "
        "median/MAD loss-spike detector",
    )
    ap.add_argument(
        "--spike-z", type=float, default=6.0,
        help="guarded step: robust z-score above the window median that "
        "forces a skip",
    )
    ap.add_argument(
        "--max-bad-steps", type=int, default=3,
        help="guarded step: consecutive skipped steps before rollback",
    )
    ap.add_argument(
        "--mesh", action="store_true",
        help="mesh-aware guard: data-parallel guarded step over every "
        "visible device under shard_map with the deterministic fixed-order "
        "gradient combine, so the skip/rollback decisions are bit-identical "
        "on every replica (requires --guard; --batch must divide the "
        "device count)",
    )
    ap.add_argument(
        "--chaos", type=float, default=0.0,
        help="deterministic fault-injection drill: per-step probability of "
        "an injected fault (half NaN-poisoned grads, half transient step "
        "failure), scheduled by --chaos-seed (requires --guard)",
    )
    ap.add_argument("--chaos-seed", type=int, default=0,
                    help="seed for the --chaos schedule (same seed = same "
                    "faults, on every host and every rerun)")
    ap.add_argument(
        "--chaos-host", type=int, default=0,
        help="with --mesh, the shard/host index whose LOCAL grads the NaN "
        "injection poisons -- the cross-device census must still skip "
        "every host in lockstep",
    )
    ap.add_argument(
        "--status-path", default=None,
        help="guard-metrics JSON status file, rewritten atomically at every "
        "checkpoint commit (default: <ckpt-dir>/guard_status.json)",
    )
    ap.add_argument(
        "--reduce-backend",
        default=None,
        choices=R.available_backends() + ("auto",),
        help="process-wide repro.reduce backend (default: cost-model auto)",
    )
    args = ap.parse_args(argv)

    if args.mesh and not args.guard:
        ap.error("--mesh requires --guard")
    if args.chaos and not args.guard:
        ap.error("--chaos requires --guard")
    if args.reduce_backend:
        R.set_default_backend(args.reduce_backend)
    data_mesh = None
    if args.mesh:
        data_mesh = make_data_mesh()
        world = int(data_mesh.devices.size)
        if args.batch % world:
            ap.error(f"--batch {args.batch} must divide {world} devices")
        print(f"mesh guard: {world}-way data mesh, deterministic combine")
    cfg = get_arch(args.arch, tiny=args.tiny)
    tcfg = TrainConfig(
        learning_rate=args.lr, total_steps=args.steps,
        warmup_steps=max(1, args.steps // 10), microbatches=args.microbatches,
        fused_second_moment=args.fused_second_moment,
    )
    params, opt_state, step_fn = build(
        cfg, tcfg, args.batch, args.seq, guard=args.guard,
        spike_z=args.spike_z, data_mesh=data_mesh,
    )
    n_params = sum(x.size for x in jax.tree.leaves(params))
    print(f"arch={cfg.name} params={n_params/1e6:.1f}M steps={args.steps}")

    data = SyntheticLM(
        cfg.vocab_size, args.seq, args.batch, ShardInfo(), seed=tcfg.seed,
        n_codebooks=cfg.n_codebooks,
    )
    # Guarded mode reads `data` directly: a rollback rewinds `data.seek`,
    # which a double-buffered prefetch queue would make inexact (batches
    # already queued under the old position would still be served).
    prefetch = None if args.guard else Prefetcher(data)
    ctx = (
        synth_image_embeds(
            jax.random.PRNGKey(1), args.batch, cfg.n_img_tokens, cfg.d_model,
            jnp.dtype(cfg.dtype),
        )
        if cfg.n_img_tokens
        else None
    )

    ckpt = CheckpointManager(args.ckpt_dir) if args.ckpt_dir else None
    guard = PreemptionGuard()
    guard_state = optim.init_guard_state(args.spike_window) if args.guard \
        else None
    step_guard = StepGuard(args.max_bad_steps) if args.guard else None
    chaos = None
    if args.chaos > 0:
        chaos = ChaosMonkey.from_seed(
            args.chaos_seed, n_steps=args.steps,
            nan_rate=args.chaos / 2, fail_rate=args.chaos / 2,
            host=args.chaos_host,
        )
        print(f"chaos: seed={args.chaos_seed} rate={args.chaos} "
              f"nan_steps={sorted(chaos.nan_steps)} "
              f"fail_steps={sorted(chaos.fail_steps)}")
    gmetrics = GuardMetrics() if args.guard else None
    status_path = args.status_path
    if status_path is None and args.ckpt_dir:
        status_path = os.path.join(args.ckpt_dir, "guard_status.json")
    start_step = 0
    if ckpt and ckpt.latest() is not None:
        ckpt.wait()  # drain any mid-flush save from a prior incarnation
        step0 = ckpt.latest()
        params, opt_state = ckpt.restore(step0, (params, opt_state))
        data.seek(ckpt.manifest(step0)["extra"]["data_step"])
        start_step = step0
        print(f"resumed from step {step0}")
    if args.guard and ckpt and ckpt.latest() is None:
        # anchor commit so a guard trip before the first periodic save
        # still has a rollback target
        ckpt.save(0, (params, opt_state),
                  extra={"data_step": data.state()["step"]})

    losses = []
    t0 = time.time()
    step = start_step
    while step < args.steps:
        batch = data.next() if prefetch is None else prefetch.next()
        feed = {"tokens": jnp.asarray(batch["tokens"])}
        if ctx is not None:
            feed["image_embeds"] = ctx
        if chaos is not None:
            # keyed on step+1 so the schedule names the step being taken;
            # fire-once semantics keep post-rollback replays clean
            if data_mesh is not None:
                world = int(data_mesh.devices.size)
                feed["chaos_scale"] = chaos.corrupt_shard(
                    jnp.ones((world,), jnp.float32), step + 1, shards=world
                )
            else:
                feed["chaos_scale"] = chaos.corrupt(
                    jnp.ones((1,), jnp.float32), step + 1
                )

        def attempt():
            if chaos is not None:
                chaos.on_step(step + 1, guard)
            if args.guard:
                return step_fn(params, opt_state, guard_state, feed)
            return step_fn(params, opt_state, feed)

        if step_guard is not None:
            failures_before = step_guard.transient_failures
            out = step_guard.retry(attempt)
            if gmetrics is not None:
                gmetrics.record_retry(
                    step_guard.transient_failures - failures_before
                )
        else:
            out = attempt()
        if args.guard:
            params, opt_state, guard_state, metrics = out
        else:
            params, opt_state, metrics = out
        losses.append(float(metrics["loss"]))
        step += 1
        if step % args.log_every == 0:
            dt = (time.time() - t0) / args.log_every
            extra = ""
            if args.guard:
                extra = (
                    f" nonfinite {float(metrics['nonfinite']):.0f}"
                    f" skips {int(guard_state.skipped)}"
                )
            print(
                f"step {step:5d} loss {losses[-1]:.4f} "
                f"gnorm {float(metrics['grad_norm']):.3f} "
                f"lr {float(metrics['lr']):.2e} {dt*1e3:.0f} ms/step"
                + extra
            )
            t0 = time.time()
        skipped = False
        if step_guard is not None:
            skipped = float(metrics["skipped"]) > 0.0
            step_guard.record(skipped)
            if gmetrics is not None:
                gmetrics.record_step(
                    step, skipped=skipped,
                    census_total=float(metrics.get("nonfinite", 0.0)),
                )
            if step_guard.should_rollback():
                if ckpt is None:
                    print("guard: rollback wanted but no --ckpt-dir; "
                          "resetting the bad-step counter only")
                    step_guard.reset()
                else:
                    ckpt.wait()
                    back = ckpt.latest()
                    params, opt_state = ckpt.restore(
                        back, (params, opt_state)
                    )
                    data.seek(ckpt.manifest(back)["extra"]["data_step"])
                    guard_state = optim.init_guard_state(args.spike_window)
                    step_guard.reset()
                    step_guard.rollbacks += 1
                    if gmetrics is not None:
                        gmetrics.record_rollback()
                        if status_path:
                            gmetrics.write(status_path)
                    step = back
                    print(f"guard: rolled back to step {back}")
                continue
        # never commit mid-skip-streak (see TrainSupervisor.run)
        if ckpt and ((step % args.ckpt_every == 0 and not skipped)
                     or guard.should_stop):
            ckpt.save(step, (params, opt_state),
                      extra={"data_step": data.state()["step"]})
            if gmetrics is not None:
                gmetrics.record_commit()
                if status_path:
                    gmetrics.write(status_path)
                snap = gmetrics.snapshot()
                print(
                    f"commit step {step}: skipped "
                    f"{snap['steps_skipped']}/{snap['steps_total']} "
                    f"retries {snap['retries']} "
                    f"rollbacks {snap['rollbacks']}"
                )
        if guard.should_stop:
            print("preempted: checkpoint flushed, exiting cleanly")
            break
    if ckpt:
        ckpt.wait()
    if prefetch is not None:
        prefetch.close()
    print(f"final loss {losses[-1]:.4f} (first {losses[0]:.4f})")
    return losses


if __name__ == "__main__":
    main()
