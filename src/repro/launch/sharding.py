"""Logical-axes -> mesh sharding rules (GSPMD, hybrid FSDP + TP + EP).

Parameters carry logical axis names from models/params.py; RULES maps them
onto mesh axes. The default is the hybrid used by production LM stacks:

  tensor-parallel  : ffn / heads / kv_heads / experts / inner / vocab -> "model"
  FSDP (ZeRO-3)    : embed (the d_model dim present in every matrix) -> "data"
                     -- parameter storage is sharded over the data axis and
                     all-gathered per layer by GSPMD; optimizer state (which
                     mirrors param sharding) is likewise partitioned.
  pod axis         : pure data parallelism (params replicated across pods;
                     gradients all-reduced over "pod").

Caches and activations: batch -> all data axes; head/state dims -> "model".

Rules are a plain dict so the perf loop can swap them (e.g. seq-parallel
variants) without touching model code.
"""

from __future__ import annotations

from typing import Any

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

DEFAULT_RULES: dict[str, str | tuple | None] = {
    "vocab": "model",
    "embed": "data",
    "ffn": "model",
    "heads": "model",
    "kv_heads": "model",
    "experts": "model",
    "inner": "model",
}

# Pure-TP variant (no FSDP): used by the perf loop for small models where
# per-layer all-gathers cost more than the replicated storage.
TP_ONLY_RULES = dict(DEFAULT_RULES, embed=None)

# 100B+ models (dbrx): FSDP over the pod axis as well -- params + optimizer
# state shard over all 512 chips instead of replicating across pods. On the
# single-pod mesh the absent "pod" axis is skipped automatically.
BIG_MODEL_RULES = dict(DEFAULT_RULES, embed=("pod", "data"))

# <3B models: DP+FSDP only. TP=16 over-parallelizes small layers -- the
# per-layer Megatron activation all-reduces dominate the step (olmo train:
# 144.8 GB -> 25-47 GB wire/device/step; EXPERIMENTS.md Perf iteration 4).
# Experts keep EP (capacity), vocab keeps the sharded CE head.
SMALL_MODEL_RULES = dict(
    DEFAULT_RULES, ffn=None, heads=None, kv_heads=None, inner=None
)


def _is_axes_leaf(a) -> bool:
    return a is None or (
        isinstance(a, tuple) and all(x is None or isinstance(x, str) for x in a)
    )


def spec_for(axes, rules: dict[str, str | None], mesh: Mesh, shape=None):
    """One logical-axes tuple -> PartitionSpec (skipping absent mesh axes).

    With ``shape`` given, a partition is dropped when the dim is smaller than
    the mesh axis (GSPMD cannot shard dim < n_shards; non-divisible-but-
    larger dims are allowed and padded)."""
    if axes is None:
        return P()
    used = set()
    parts = []
    for i, name in enumerate(axes):
        m = rules.get(name) if name else None
        if isinstance(m, str):
            m = (m,)
        cand = tuple(
            ax for ax in (m or ()) if ax in mesh.axis_names and ax not in used
        )
        deg = 1
        for ax in cand:
            deg *= mesh.shape[ax]
        # jit in_shardings require dims divisible by the mesh axes (e.g.
        # mamba2's vocab 50280 % 16 != 0 -> embed falls back to d_model/FSDP)
        if cand and (shape is None or shape[i] % deg == 0):
            parts.append(cand if len(cand) > 1 else cand[0])
            used.update(cand)
        else:
            parts.append(None)
    while parts and parts[-1] is None:
        parts.pop()
    return P(*parts)


def batch_partition(mesh: Mesh, global_batch: int):
    """Batch PartitionSpec entry over the data axes, or None when the batch
    does not divide them (long_500k batch=1 stays replicated)."""
    from repro.launch.mesh import batch_axes

    ba = batch_axes(mesh)
    deg = 1
    for ax in ba:
        deg *= mesh.shape[ax]
    if not ba or global_batch % deg != 0:
        return None
    return ba if len(ba) > 1 else ba[0]


def param_shardings(axes_tree, mesh: Mesh, rules=None, shapes_tree=None):
    """axes tree (+ optional matching ShapeDtypeStruct tree) -> NamedShardings."""
    rules = rules or DEFAULT_RULES
    if shapes_tree is None:
        return jax.tree.map(
            lambda a: NamedSharding(mesh, spec_for(a, rules, mesh)),
            axes_tree,
            is_leaf=_is_axes_leaf,
        )
    flat_axes = jax.tree.flatten(axes_tree, is_leaf=_is_axes_leaf)[0]
    flat_shapes, treedef = jax.tree.flatten(shapes_tree)
    out = [
        NamedSharding(mesh, spec_for(a, rules, mesh, s.shape))
        for a, s in zip(flat_axes, flat_shapes)
    ]
    return jax.tree.unflatten(treedef, out)


def like_tree(tree, sharding_tree):
    """Broadcast a sharding tree over a same-structure value tree (e.g.
    optimizer m/v mirror the params)."""
    return jax.tree.map(lambda _, s: s, tree, sharding_tree)


# ----------------------------- activations/caches ----------------------------


def batch_spec(mesh: Mesh, extra: tuple = ()) -> P:
    from repro.launch.mesh import batch_axes

    ba = batch_axes(mesh)
    return P(ba if len(ba) > 1 else (ba[0] if ba else None), *extra)


def cache_shardings(caches_shape, cfg, mesh: Mesh):
    """PartitionSpec tree for decode caches, keyed on leaf names.

    k/v:   (B, S, Hkv, D)   -> (batch, None, model*, None)
    ckv:   (B, S, R)        -> (batch, None, None)      [MLA latent]
    conv:  (B, K-1, C)      -> (batch, None, model)
    state: (B, H, P, N)     -> (batch, model, None, None)  [SSD]
    h:     (B, W)           -> (batch, model)              [RG-LRU]
    slot_pos: replicated
    (* only when the head count divides the model axis -- MQA kv=1 and
     dbrx kv=8 fall back to replicated-or-padded per GSPMD.)
    """
    from repro.launch.mesh import batch_axes

    ba = batch_axes(mesh)
    b = ba if len(ba) > 1 else (ba[0] if ba else None)

    model_n = mesh.shape["model"]
    from repro.launch.mesh import batch_axes as _ba
    data_n = 1
    for ax in _ba(mesh):
        data_n *= mesh.shape[ax]

    def leaf_spec(path, leaf):
        name = path[-1].key if hasattr(path[-1], "key") else str(path[-1])
        stacked = any(
            getattr(k, "key", None) == "units" for k in path
        )
        shape = leaf.shape[1:] if stacked else leaf.shape
        bspec = b if (shape and shape[0] % data_n == 0) else None

        def mdl(dim_ix):
            return "model" if shape[dim_ix] % model_n == 0 else None

        if name in ("k", "v") and len(shape) == 4:
            # prefer head sharding (softmax stays device-local); fall back to
            # SEQUENCE sharding (split-KV decode: per-shard partial softmax,
            # small cross-model AR) when Hkv does not divide the model axis.
            # Never shard d_head -- contracting a sharded minor dim makes
            # GSPMD replicate the cache in f32 (dry-run: 12.9 GB on musicgen
            # decode_32k; see EXPERIMENTS.md Perf iteration 3).
            if mdl(2):
                s = P(bspec, None, "model", None)
            else:
                s = P(bspec, mdl(1), None, None)
        elif name == "ckv":
            # MLA latent: split-KV over sequence (attention contracts s)
            s = P(bspec, mdl(1), None)
        elif name == "conv":
            s = P(bspec, None, mdl(2))
        elif name == "state":
            s = P(bspec, mdl(1), None, None)
        elif name == "h":
            s = P(bspec, mdl(1))
        elif name == "slot_pos":
            s = P(*([None] * len(shape)))
        else:
            s = P(*([bspec] + [None] * (len(shape) - 1)))
        parts = ([None] if stacked else []) + list(s)
        parts = parts[: len(leaf.shape)] + [None] * (len(leaf.shape) - len(parts))
        return NamedSharding(mesh, P(*parts))

    return jax.tree_util.tree_map_with_path(leaf_spec, caches_shape)
