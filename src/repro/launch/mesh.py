"""Production meshes.

Single pod : (16, 16)    axes ("data", "model")   = 256 chips (v5e pod)
Multi-pod  : (2, 16, 16) axes ("pod", "data", "model") = 512 chips

Defined as a FUNCTION so importing this module never touches jax device
state (the dry-run must set XLA_FLAGS before any jax initialization).
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(max_devices: int | None = None) -> jax.sharding.Mesh:
    """Degenerate mesh over whatever devices exist (CPU tests, examples)."""
    n = len(jax.devices()) if max_devices is None else max_devices
    return jax.make_mesh((1, n), ("data", "model"))


def make_data_mesh(n_devices: int | None = None) -> jax.sharding.Mesh:
    """Pure data-parallel 1-D mesh, axis name "data" -- the shape the
    distributed guarded reduce runs on (each device holds one shard of the
    grads; the mesh axis is the fixed-order combine's fold order). With
    ``n_devices=None`` spans every visible device (on the CI's forced
    8-way CPU host this is the 8-device test mesh)."""
    n = len(jax.devices()) if n_devices is None else int(n_devices)
    return jax.make_mesh((n,), ("data",))


def batch_axes(mesh: jax.sharding.Mesh) -> tuple[str, ...]:
    """Mesh axes the global batch is sharded over (all data-parallel axes)."""
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))
