import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST be the first two lines: jax locks the device count on first init.
# The dry-run (and ONLY the dry-run) builds the 512-chip production meshes
# out of host placeholder devices; smoke tests and benches see 1 device.

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this script:
  1. builds the production mesh (16,16) or (2,16,16),
  2. constructs the step function (train / prefill / decode) and its
     ShapeDtypeStruct inputs (launch/specs.py -- zero allocation),
  3. jit-lowers with explicit in/out shardings (FSDP+TP+EP rules),
  4. .compile()s -- any sharding mismatch, OOM-at-compile or unsupported
     collective fails the cell (that is a bug in the system),
  5. records memory_analysis(), cost_analysis() and the per-device
     collective-operand bytes parsed from the post-SPMD HLO into
     artifacts/dryrun/<arch>__<shape>__<mesh>.json.

Usage:
  python -m repro.launch.dryrun --arch deepseek-7b --shape train_4k --mesh single
  python -m repro.launch.dryrun --all --mesh both
"""

import argparse
import json
import pathlib
import re
import time
import traceback

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import ARCHS, SHAPES, TrainConfig, get_arch, get_shape, shape_applicable
from repro.launch import sharding as SH
from repro.launch import specs as SPECS
from repro.launch.mesh import batch_axes, make_production_mesh
from repro.launch.steps import make_decode_step, make_prefill_step, make_train_step

ART_DIR = pathlib.Path(__file__).resolve().parents[3] / "artifacts" / "dryrun"

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
}
_COLLECTIVES = (
    "all-reduce", "all-gather", "reduce-scatter", "all-to-all",
    "collective-permute",
)
# wire-cost multiplier per collective (ring algorithms, large-P limit)
_WIRE_FACTOR = {
    "all-reduce": 2.0, "all-gather": 1.0, "reduce-scatter": 1.0,
    "all-to-all": 1.0, "collective-permute": 1.0,
}

_SHAPE_RE = re.compile(r"(pred|[a-z]+[0-9]+)\[([0-9,]*)\]")


def _bytes_of_types(txt: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(txt):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def parse_collective_bytes(hlo_text: str) -> dict:
    """Sum per-device operand bytes of every collective op in post-SPMD HLO.

    Shapes in the partitioned module are shard shapes, so the totals are
    per-device. `-start` variants are counted; `-done` twins are skipped.

    Two buckets: collectives in the ENTRY computation execute once per step;
    collectives in any other computation live inside a while body (XLA's
    static text lists loop bodies once) and must be scaled by the loop trip
    counts (structural multipliers recorded in rec["struct"]; applied by
    benchmarks/roofline.py)."""
    def bucket():
        return {k: {"count": 0, "bytes": 0, "wire_bytes": 0} for k in _COLLECTIVES}

    out = {"entry": bucket(), "loop": bucket()}
    in_entry = False
    for line in hlo_text.splitlines():
        s = line.strip()
        if s.startswith("ENTRY "):
            in_entry = True
        elif s == "}":
            in_entry = False
        if "=" not in s:
            continue
        _, _, rhs = s.partition("=")  # HLO: name = TYPE op(...)
        for op in _COLLECTIVES:
            m = re.search(rf"\b{op}(-start)?\(", rhs)
            if m:
                if f"{op}-done" in rhs:
                    continue
                b = _bytes_of_types(rhs[: m.start()])  # result type(s)
                tgt = out["entry" if in_entry else "loop"][op]
                tgt["count"] += 1
                tgt["bytes"] += b
                tgt["wire_bytes"] += int(b * _WIRE_FACTOR[op])
                break
    for bkt in ("entry", "loop"):
        out[f"{bkt}_wire_bytes"] = sum(
            v["wire_bytes"] for v in out[bkt].values()
        )
    out["total_wire_bytes"] = out["entry_wire_bytes"] + out["loop_wire_bytes"]
    out["total_bytes"] = sum(
        v["bytes"] for bkt in ("entry", "loop") for v in out[bkt].values()
    )
    return out


# computation header at column 0: `%name (params...) -> type {` -- params may
# contain nested parens (tuple types), so just anchor on name( ... ){EOL}
_COMP_HDR = re.compile(r"^(ENTRY\s+)?%?([\w.\-]+)\s*\(.*\{\s*$")
_WHILE_BODY = re.compile(r"\bwhile\(.*?body=%?([\w.\-]+)")
_COND_BRANCH = re.compile(r"(?:true_computation|false_computation|branch_computations)=.?%?([\w.\-{,% ]+)")


def parse_collective_depths(hlo_text: str) -> dict:
    """Per-while-nesting-depth collective wire bytes.

    Builds the while-loop call graph (computation -> body computations) and
    assigns each collective the depth = number of enclosing while loops.
    Depth 0 = once per step (gradient reduce, optimizer); depth 1 = per
    microbatch (grad-accum reshards); depth 2 = per layer-unit per
    microbatch (FSDP gathers, TP activation reduces); depth >= 3 = inner
    chunk loops. benchmarks/roofline.py turns depths into trip counts."""
    comp_coll: dict[str, int] = {}
    comp_children: dict[str, list] = {}
    entry = None
    cur = None
    for line in hlo_text.splitlines():
        raw = line.rstrip()
        s = raw.strip()
        m = _COMP_HDR.match(raw)
        if m and not raw.startswith(" "):
            cur = m.group(2)
            comp_coll.setdefault(cur, 0)
            comp_children.setdefault(cur, [])
            if m.group(1):
                entry = cur
            continue
        if cur is None or "=" not in s:
            continue
        wb = _WHILE_BODY.search(s)
        if wb:
            comp_children[cur].append(wb.group(1))
        _, _, rhs = s.partition("=")
        for op in _COLLECTIVES:
            mm = re.search(rf"\b{op}(-start)?\(", rhs)
            if mm and f"{op}-done" not in rhs:
                b = _bytes_of_types(rhs[: mm.start()])
                comp_coll[cur] += int(b * _WIRE_FACTOR[op])
                break
    # BFS from entry over while-body edges
    depth_bytes: dict[int, int] = {}
    seen = set()
    frontier = [(entry, 0)] if entry else []
    while frontier:
        name, d = frontier.pop()
        if name in seen or name not in comp_coll:
            continue
        seen.add(name)
        depth_bytes[d] = depth_bytes.get(d, 0) + comp_coll[name]
        for child in comp_children.get(name, []):
            frontier.append((child, d + 1))
    # collectives in computations not reachable via while edges (fusion-
    # called regions cannot contain collectives; conditionals are rare) --
    # attribute leftovers conservatively to depth 2.
    leftover = sum(v for k, v in comp_coll.items() if k not in seen)
    if leftover:
        depth_bytes[2] = depth_bytes.get(2, 0) + leftover
    return {str(k): v for k, v in sorted(depth_bytes.items())}


def build_cell(arch: str, shape_name: str, mesh, rules=None):
    """Returns (jitted_fn, kwargs_of_specs) ready to .lower(**kwargs)."""
    cfg = get_arch(arch)
    shape = get_shape(shape_name)
    if rules is None:
        # Training: FSDP (ZeRO) over data; 100B+ models extend it over the
        # pod axis. Serving: FSDP has no optimizer state to shard and its
        # per-step weight regathers dominate decode collectives (Perf
        # iteration 3) -> TP-only, unless the TP weight shard alone exceeds
        # HBM (dbrx: 16.5 GB/16-way -> keep weights FSDP-sharded).
        big = cfg.param_count() > 40e9
        small = cfg.param_count() < 3e9
        if shape.mode == "train":
            rules = (SH.BIG_MODEL_RULES if big
                     else SH.SMALL_MODEL_RULES if small
                     else SH.DEFAULT_RULES)
        else:
            # serving: <3B archs also drop TP (Perf iteration 5; caches get
            # explicit out_shardings so they never replicate over model)
            rules = (SH.BIG_MODEL_RULES if big
                     else SH.SMALL_MODEL_RULES if small
                     else SH.TP_ONLY_RULES)
    # batch partition entry (None when batch does not divide the data axes,
    # e.g. long_500k batch=1)
    bspec = SH.batch_partition(mesh, shape.global_batch)

    # pin the activation layout (batch -> data axes) for GSPMD propagation;
    # trace-time context, read by models/context.constrain at unit boundaries
    from repro.models import context as CTX
    CTX.set_activation_sharding(NamedSharding(mesh, P(bspec, None, None)))

    pshapes, axes = SPECS.param_specs(cfg)
    pshard = SH.param_shardings(axes, mesh, rules, pshapes)

    if shape.mode == "train":
        data_degree = mesh.devices.size // mesh.shape["model"]
        tcfg = TrainConfig(
            microbatches=SPECS.microbatches_for(cfg, shape, data_degree)
        )
        oshapes = SPECS.opt_specs(pshapes)
        # optimizer state: step counter replicated, m/v mirror params
        oshard = type(oshapes)(
            step=NamedSharding(mesh, P()),
            m=jax.tree.map(lambda _, s: s, oshapes.m, pshard),
            v=jax.tree.map(lambda _, s: s, oshapes.v, pshard),
        )
        bshapes = SPECS.batch_specs(cfg, shape)
        bshard = {
            "tokens": NamedSharding(
                mesh, P(*([bspec] + [None] * (len(bshapes["tokens"].shape) - 1)))
            )
        }
        if "image_embeds" in bshapes:
            bshard["image_embeds"] = NamedSharding(mesh, P(bspec, None, None))
        fn = make_train_step(cfg, tcfg, mesh, param_shardings=pshard)
        jitted = jax.jit(
            fn,
            in_shardings=(pshard, oshard, bshard),
            donate_argnums=(0, 1),
        )
        return jitted, (pshapes, oshapes, bshapes)

    if shape.mode == "prefill":
        bshapes = SPECS.batch_specs(cfg, shape)
        fn = make_prefill_step(cfg, shape.seq_len)
        tok_sh = NamedSharding(
            mesh, P(*([bspec] + [None] * (len(bshapes["tokens"].shape) - 1)))
        )
        # explicit output shardings: logits batch-sharded; caches laid out
        # exactly as the decode step consumes them (head- or seq-sharded over
        # model) -- without this, DP-only weight rules would let GSPMD
        # replicate the caches over the model axis (Perf iteration 5).
        args = (pshapes, bshapes["tokens"])
        in_sh = (pshard, tok_sh)
        if "image_embeds" in bshapes:
            args += (bshapes["image_embeds"],)
            in_sh += (NamedSharding(mesh, P(bspec, None, None)),)
        logits_shape, cache_shapes = jax.eval_shape(fn, *args)
        logits_sh = NamedSharding(
            mesh, P(*([bspec] + [None] * (len(logits_shape.shape) - 1)))
        )
        cache_sh = SH.cache_shardings(cache_shapes, cfg, mesh)
        jitted = jax.jit(fn, in_shardings=in_sh,
                         out_shardings=(logits_sh, cache_sh))
        return jitted, args

    # decode
    dspecs = SPECS.decode_specs(cfg, shape)
    cshard = SH.cache_shardings(dspecs["caches"], cfg, mesh)
    tshard = NamedSharding(
        mesh, P(*([bspec] + [None] * (len(dspecs["token"].shape) - 1)))
    )
    fn = make_decode_step(cfg)
    jitted = jax.jit(
        fn,
        in_shardings=(pshard, cshard, tshard, NamedSharding(mesh, P())),
        donate_argnums=(1,),
    )
    return jitted, (pshapes, dspecs["caches"], dspecs["token"], dspecs["pos"])


def run_cell(arch: str, shape_name: str, multi_pod: bool, out_dir: pathlib.Path,
             rules=None, tag: str = "") -> dict:
    mesh_name = "multi" if multi_pod else "single"
    cfg = get_arch(arch)
    shape = get_shape(shape_name)
    runs, reason = shape_applicable(cfg, shape)
    rec: dict = {
        "arch": arch, "shape": shape_name, "mesh": mesh_name, "tag": tag,
        "mode": shape.mode,
    }
    out_path = out_dir / f"{arch}__{shape_name}__{mesh_name}{tag}.json"
    if not runs:
        rec["status"] = "skipped"
        rec["reason"] = reason
        out_path.write_text(json.dumps(rec, indent=1))
        print(f"[skip] {arch} x {shape_name} x {mesh_name}: {reason}")
        return rec
    t0 = time.time()
    try:
        mesh = make_production_mesh(multi_pod=multi_pod)
        jitted, args = build_cell(arch, shape_name, mesh, rules)
        lowered = jitted.lower(*args)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower
        rec["status"] = "ok"
        rec["lower_s"] = round(t_lower, 1)
        rec["compile_s"] = round(t_compile, 1)
        try:
            ma = compiled.memory_analysis()
            rec["memory"] = {
                k: int(getattr(ma, k))
                for k in (
                    "argument_size_in_bytes", "output_size_in_bytes",
                    "temp_size_in_bytes", "generated_code_size_in_bytes",
                    "alias_size_in_bytes",
                )
                if hasattr(ma, k)
            }
        except Exception as e:  # pragma: no cover
            rec["memory"] = {"error": str(e)}
        try:
            ca = compiled.cost_analysis()
            rec["cost"] = {
                k: float(v)
                for k, v in ca.items()
                if k in ("flops", "bytes accessed", "transcendentals",
                         "utilization operand 0 {}", "optimal_seconds")
                or k.startswith("bytes accessed")
            }
        except Exception as e:  # pragma: no cover
            rec["cost"] = {"error": str(e)}
        hlo = compiled.as_text()
        rec["collectives"] = parse_collective_bytes(hlo)
        rec["collective_depths"] = parse_collective_depths(hlo)
        rec["hlo_ops"] = {
            op: len(re.findall(rf"\b{op}(?:-start)?\(", hlo))
            for op in _COLLECTIVES
        }
        rec["n_devices"] = mesh.devices.size
        # structural trip counts for the roofline's loop multipliers
        pat = cfg.block_pattern
        n_units = cfg.n_layers // len(pat)
        data_degree = mesh.devices.size // mesh.shape["model"]
        rec["struct"] = {
            "n_units": n_units,
            "pattern": list(pat),
            "tail_layers": cfg.n_layers % len(pat),
            "microbatches": (
                SPECS.microbatches_for(cfg, shape, data_degree)
                if shape.mode == "train" else 1
            ),
            "params": cfg.param_count(),
            "active_params": cfg.active_param_count(),
            "model_degree": int(mesh.shape["model"]),
            "data_degree": int(data_degree),
        }
        print(
            f"[ok]   {arch} x {shape_name} x {mesh_name}{tag}: "
            f"lower {t_lower:.0f}s compile {t_compile:.0f}s "
            f"flops/dev {rec['cost'].get('flops', float('nan')):.3g} "
            f"coll {rec['collectives']['total_wire_bytes']/1e6:.1f}MB"
        )
    except Exception as e:
        rec["status"] = "failed"
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-4000:]
        print(f"[FAIL] {arch} x {shape_name} x {mesh_name}{tag}: {rec['error'][:200]}")
    out_path.write_text(json.dumps(rec, indent=1))
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", choices=("single", "multi", "both"), default="single")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--skip-existing", action="store_true")
    ap.add_argument("--out", default=str(ART_DIR))
    args = ap.parse_args()
    out_dir = pathlib.Path(args.out)
    out_dir.mkdir(parents=True, exist_ok=True)
    archs = list(ARCHS) if (args.all or not args.arch) else [args.arch]
    shapes = list(SHAPES) if (args.all or not args.shape) else [args.shape]
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]
    n_fail = 0
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                mesh_name = "multi" if mp else "single"
                p = out_dir / f"{arch}__{shape}__{mesh_name}.json"
                if args.skip_existing and p.exists():
                    prior = json.loads(p.read_text())
                    if prior.get("status") in ("ok", "skipped"):
                        print(f"[keep] {arch} x {shape} x {mesh_name}")
                        continue
                rec = run_cell(arch, shape, mp, out_dir)
                n_fail += rec["status"] == "failed"
    print(f"dry-run complete; failures: {n_fail}")
    raise SystemExit(1 if n_fail else 0)


if __name__ == "__main__":
    main()
