"""Jit-able training / serving steps with explicit shardings.

make_train_step: microbatched (gradient-accumulation lax.scan, f32 grad
accumulators), remat'd forward, MMA-clipped AdamW update. One function serves
single-pod and multi-pod meshes -- the mesh only changes the shardings.

make_prefill_step / make_decode_step: the serving pair. decode performs one
token step for the whole batch against resident caches (greedy sampling).
"""

from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import optim
from repro import reduce as R
from repro.configs.base import ModelConfig, TrainConfig
from repro.launch import sharding as SH
from repro.launch.mesh import batch_axes
from repro.models import decode_step as model_decode
from repro.models import make_caches, prefill
from repro.models.model import forward_hidden
from repro.models.losses import lm_loss_chunked


def _split_batch(tokens, n_micro: int):
    gb = tokens.shape[0]
    assert gb % n_micro == 0, (gb, n_micro)
    return tokens.reshape((n_micro, gb // n_micro) + tokens.shape[1:])


def make_train_step(
    cfg: ModelConfig,
    tcfg: TrainConfig,
    mesh=None,
    param_shardings=None,
    reduce_backend: str | None = None,
):
    """Returns train_step(params, opt_state, batch) -> (params, opt, metrics).

    batch: {"tokens": (GB, S[, K]) int32[, "image_embeds": (GB, N, d)]}.
    param_shardings (optional): NamedSharding tree; the f32 gradient
    accumulators are constrained to it so ZeRO partitioning extends to the
    accumulation buffers (otherwise GSPMD may leave them replicated).
    reduce_backend (optional): repro.reduce backend name for the optimizer's
    clipping statistic; defaults to the cfg flags' mapping.
    """
    if reduce_backend is None:
        reduce_backend = R.backend_for_flags(cfg.mma_reductions, cfg.use_pallas)
    bspec = None
    if mesh is not None:
        ba = batch_axes(mesh)
        bspec = ba if len(ba) > 1 else (ba[0] if ba else None)

    compute_grads = _make_grads_fn(cfg, tcfg, mesh, param_shardings, bspec)

    def train_step(params, opt_state, batch):
        grads, mean_loss = compute_grads(params, batch)
        new_params, new_opt, metrics = optim.apply_updates(
            params, grads, opt_state, tcfg, reduce_backend=reduce_backend,
            fused_second_moment=tcfg.fused_second_moment,
        )
        metrics = dict(metrics, loss=mean_loss)
        return new_params, new_opt, metrics

    return train_step


def _make_grads_fn(cfg, tcfg, mesh, param_shardings, bspec):
    """The microbatched (scan-accumulated, remat'd) gradient computation
    shared by the plain and the guarded train steps:
    ``compute_grads(params, batch) -> (grads, mean_loss)``."""

    def loss_fn(params, tokens, ctx):
        h, aux = forward_hidden(params, cfg, tokens[:, :-1], ctx)
        labels = tokens[:, 1:]  # (B, S-1[, K]); chunked CE handles codebooks
        loss, parts = lm_loss_chunked(params, cfg, h, labels, aux)
        return loss, parts

    def compute_grads(params, batch):
        tokens = batch["tokens"]
        ctx = batch.get("image_embeds")
        n_micro = tcfg.microbatches
        mtoks = _split_batch(tokens, n_micro)
        mctx = _split_batch(ctx, n_micro) if ctx is not None else None
        if mesh is not None:
            mtoks = jax.lax.with_sharding_constraint(
                mtoks, NamedSharding(mesh, P(None, bspec))
            )

        grad_zero = jax.tree.map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params
        )
        if param_shardings is not None:
            grad_zero = jax.tree.map(
                jax.lax.with_sharding_constraint, grad_zero, param_shardings
            )

        def micro(carry, xs):
            gacc, lacc = carry
            mb = xs if mctx is None else xs[0]
            cx = None if mctx is None else xs[1]
            (loss, _), grads = jax.value_and_grad(loss_fn, has_aux=True)(
                params, mb, cx
            )
            if param_shardings is not None:
                # reshard dW to the accumulator layout in the PRODUCED dtype
                # (bf16) BEFORE the f32 upcast -- otherwise GSPMD hoists the
                # upcast and moves the reshard traffic in f32 (2x wire;
                # Perf iteration 2b)
                grads = jax.tree.map(
                    jax.lax.with_sharding_constraint, grads, param_shardings
                )
            gacc = jax.tree.map(
                lambda a, g: a + g.astype(jnp.float32), gacc, grads
            )
            return (gacc, lacc + loss), None

        xs = mtoks if mctx is None else (mtoks, mctx)
        (grads, loss_sum), _ = jax.lax.scan(
            micro, (grad_zero, jnp.zeros((), jnp.float32)), xs
        )
        grads = jax.tree.map(lambda g: g / n_micro, grads)
        return grads, loss_sum / n_micro

    return compute_grads


def make_guarded_train_step(
    cfg: ModelConfig,
    tcfg: TrainConfig,
    mesh=None,
    param_shardings=None,
    reduce_backend: str | None = None,
    spike_z: float = 6.0,
    mesh_axes=None,
):
    """Returns guarded_step(params, opt_state, guard_state, batch) ->
    (params, opt_state, guard_state, metrics): the same microbatched
    gradient computation as ``make_train_step``, finished by
    ``optim.guarded_apply_updates`` -- the clip statistic's launch also
    counts NaN/Inf grad elements (in-launch census) and a poisoned or
    loss-spiking step passes params and optimizer state through BITWISE
    unchanged (``metrics['skipped']`` flags it for the supervisor's
    rollback counter). ``guard_state`` is ``optim.init_guard_state(W)``.

    ``mesh_axes`` is for calling the returned step INSIDE a shard_map body
    with params/grads sharded along those axes: the clip statistic,
    census, and skip decision then come out of the deterministic
    fixed-order cross-device combine, bit-identical on every replica.
    """
    if reduce_backend is None:
        reduce_backend = R.backend_for_flags(cfg.mma_reductions, cfg.use_pallas)
    bspec = None
    if mesh is not None:
        ba = batch_axes(mesh)
        bspec = ba if len(ba) > 1 else (ba[0] if ba else None)

    compute_grads = _make_grads_fn(cfg, tcfg, mesh, param_shardings, bspec)

    def guarded_step(params, opt_state, guard_state, batch):
        batch = dict(batch)
        # chaos drill hook: a scalar the injector drives to NaN/Inf on a
        # scheduled step; multiplying by 1.0 is bitwise identity otherwise
        scale = batch.pop("chaos_scale", None)
        grads, mean_loss = compute_grads(params, batch)
        if scale is not None:
            s = jnp.reshape(scale, (-1,))[0]
            grads = jax.tree.map(lambda g: g * s.astype(g.dtype), grads)
        new_params, new_opt, new_guard, metrics = optim.guarded_apply_updates(
            params, grads, opt_state, tcfg, loss=mean_loss,
            guard=guard_state, spike_z=spike_z,
            reduce_backend=reduce_backend,
            fused_second_moment=tcfg.fused_second_moment,
            mesh_axes=mesh_axes,
        )
        metrics = dict(metrics, loss=mean_loss)
        return new_params, new_opt, new_guard, metrics

    return guarded_step


def make_mesh_guarded_train_step(
    cfg: ModelConfig,
    tcfg: TrainConfig,
    mesh,
    reduce_backend: str | None = None,
    spike_z: float = 6.0,
):
    """Data-parallel guarded step under ``shard_map`` with a DETERMINISTIC
    gradient exchange: each device computes grads on its batch shard, the
    cross-device mean goes through ``fixed_order_combine`` (bit-identical
    on every replica, unlike ``psum`` whose reduction order is opaque), and
    the guarded update then runs on bit-identical inputs everywhere -- so
    the skip flag, the guard bookkeeping, and the supervisor's rollback
    counter are provably in lockstep across hosts. ``mesh`` is a 1-D data
    mesh (``make_data_mesh``); the batch's leading dim must divide its
    size.

    The batch may carry a ``chaos_scale`` array of shape (world,), sharded
    along the mesh axis like everything else: each device multiplies its
    LOCAL grads by its entry. Driving exactly one entry to NaN models one
    host's shard going bad -- the cross-device census must still skip
    EVERY host identically. Omit the key (or pass ones) for clean steps.

    Compiled with donation on (params, opt_state, guard_state).
    """
    from repro.core import collectives as coll

    if reduce_backend is None:
        reduce_backend = R.backend_for_flags(cfg.mma_reductions, cfg.use_pallas)
    (axis,) = mesh.axis_names
    compute_grads = _make_grads_fn(cfg, tcfg, None, None, None)

    def body(params, opt_state, guard_state, batch):
        batch = dict(batch)
        scale = batch.pop("chaos_scale", None)
        grads, loss = compute_grads(params, batch)
        if scale is not None:
            s = jnp.reshape(scale, (-1,))[0]
            grads = jax.tree.map(lambda g: g * s.astype(g.dtype), grads)
        world = coll.mesh_world_size((axis,))
        grads = jax.tree.map(
            lambda g: coll.fixed_order_combine(g, (axis,)) / world, grads
        )
        loss = coll.fixed_order_combine(loss, (axis,)) / world
        new_p, new_opt, new_guard, metrics = optim.guarded_apply_updates(
            params, grads, opt_state, tcfg, loss=loss, guard=guard_state,
            spike_z=spike_z, reduce_backend=reduce_backend,
            fused_second_moment=tcfg.fused_second_moment,
        )
        metrics = dict(metrics, loss=loss)
        return new_p, new_opt, new_guard, metrics

    rep = P()
    sharded = coll.shard_map_unchecked(
        body, mesh=mesh,
        in_specs=(rep, rep, rep, P(axis)),
        out_specs=(rep, rep, rep, rep),
    )
    return jax.jit(sharded, donate_argnums=(0, 1, 2))


def make_jitted_train_step(
    cfg: ModelConfig,
    tcfg: TrainConfig,
    mesh=None,
    param_shardings=None,
    reduce_backend: str | None = None,
):
    """``make_train_step`` compiled with BUFFER DONATION on (params,
    opt_state): XLA reuses their device buffers for the same-shaped outputs
    instead of allocating a second copy of every weight and moment tensor,
    so the step's update writes land in place -- the other half of the
    one-HBM-trip step (the epilogue fork removes the extra norm reads; the
    donation removes the extra update writes). Callers must rebind
    ``params, opt_state = step_fn(params, opt_state, batch)`` -- the donated
    inputs are dead after the call (jax enforces this)."""
    return jax.jit(
        make_train_step(cfg, tcfg, mesh, param_shardings, reduce_backend),
        donate_argnums=(0, 1),
    )


def make_jitted_guarded_train_step(
    cfg: ModelConfig,
    tcfg: TrainConfig,
    mesh=None,
    param_shardings=None,
    reduce_backend: str | None = None,
    spike_z: float = 6.0,
    mesh_axes=None,
):
    """``make_guarded_train_step`` compiled with donation on (params,
    opt_state, guard_state). Safe even on skipped steps: the bitwise
    keep/advance blend writes the (unchanged) bits back into the donated
    buffers -- there is no branch whose untaken side would need the dead
    input alive."""
    return jax.jit(
        make_guarded_train_step(
            cfg, tcfg, mesh, param_shardings, reduce_backend, spike_z,
            mesh_axes,
        ),
        donate_argnums=(0, 1, 2),
    )


def make_prefill_step(cfg: ModelConfig, s_max: int):
    def prefill_step(params, tokens, ctx=None):
        caches = make_caches(cfg, tokens.shape[0], s_max)
        logits, caches = prefill(params, cfg, tokens, caches, ctx)
        return logits, caches

    return prefill_step


def make_decode_step(cfg: ModelConfig, greedy: bool = True):
    def decode_one(params, caches, token, pos, ctx=None):
        logits, caches = model_decode(params, cfg, token, caches, pos, ctx)
        if greedy:
            nxt = jnp.argmax(logits, -1).astype(jnp.int32)
        else:
            nxt = logits
        return nxt, caches

    return decode_one
