"""ShapeDtypeStruct input specs for every (arch x shape) cell.

Shape-only stand-ins (never allocated) in the shannon/kernels style: the
dry-run lowers against these, so a 132B model's step compiles without a byte
of parameter memory on the host.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro import optim
from repro.configs.base import ModelConfig, ShapeConfig
from repro.models import init_params, make_caches


def microbatches_for(cfg: ModelConfig, shape: ShapeConfig, data_degree: int = 16) -> int:
    """Gradient-accumulation depth: sized so that ~1-2 sequences per device
    per microbatch keep unit-boundary residuals inside HBM (see DESIGN.md).

    Constrained so each microbatch still divides the data-parallel degree
    (mb % data_degree == 0) -- otherwise GSPMD must replicate activations
    (caught by the dry-run on the multi-pod mesh)."""
    if shape.mode != "train":
        return 1
    if cfg.name.startswith("dbrx"):
        want = 16
    elif cfg.param_count() < 3e9:
        want = 4  # DP-only small models: M=4 balances activation memory
        # against per-micro grad-reshard wire (Perf iteration 4 sweep)
    elif cfg.d_model >= 4096 or cfg.n_layers >= 48:
        want = 8
    else:
        want = 2
    cap = max(1, shape.global_batch // data_degree)
    micro = min(want, cap)
    while shape.global_batch % micro or (shape.global_batch // micro) % data_degree:
        micro -= 1  # terminates at 1
    return micro


def param_specs(cfg: ModelConfig):
    """(ShapeDtypeStruct params tree, logical axes tree) -- no allocation."""
    cell = {}

    def only_params(key):
        p, a = init_params(key, cfg)
        cell["axes"] = a
        return p

    shapes = jax.eval_shape(only_params, jax.random.PRNGKey(0))
    return shapes, cell["axes"]


def opt_specs(param_shapes):
    return jax.eval_shape(optim.init_state, param_shapes)


def batch_specs(cfg: ModelConfig, shape: ShapeConfig):
    """Train/prefill batch inputs."""
    gb, s = shape.global_batch, shape.seq_len
    tok_shape = (gb, s, cfg.n_codebooks) if cfg.n_codebooks else (gb, s)
    out = {"tokens": jax.ShapeDtypeStruct(tok_shape, jnp.int32)}
    if cfg.n_img_tokens:
        out["image_embeds"] = jax.ShapeDtypeStruct(
            (gb, cfg.n_img_tokens, cfg.d_model), jnp.dtype(cfg.dtype)
        )
    return out


def decode_specs(cfg: ModelConfig, shape: ShapeConfig):
    """(token, caches, pos) stand-ins for one decode step at kv_len=seq_len."""
    gb, s_max = shape.global_batch, shape.seq_len
    tok_shape = (gb, 1, cfg.n_codebooks) if cfg.n_codebooks else (gb, 1)
    caches = jax.eval_shape(lambda: make_caches(cfg, gb, s_max))
    return {
        "token": jax.ShapeDtypeStruct(tok_shape, jnp.int32),
        "caches": caches,
        "pos": jax.ShapeDtypeStruct((), jnp.int32),
    }


def input_specs(cfg: ModelConfig, shape: ShapeConfig):
    """All abstract inputs for the cell's step function."""
    if shape.mode in ("train", "prefill"):
        return batch_specs(cfg, shape)
    return decode_specs(cfg, shape)
