"""Serving driver: batched prefill + decode with resident caches.

Continuous-batching-lite: a request queue is packed into fixed slots; each
engine step decodes one token for every active slot; finished slots are
refilled from the queue (prefill) without stopping the decode stream.

Two paths share the jitted steps:

  Engine.serve        -- the plain happy-path loop (padded last wave uses a
                         MASKED dummy slot, never a duplicated request).
  GuardedEngine + runtime.ServingRuntime -- the resilient path (--guard):
                         bounded admission, per-request deadlines, the
                         census-guarded decode (every step's logit
                         statistic rides ``reduce_tree(census=True)`` --
                         NaN/Inf detected in the SAME launch, per slot,
                         zero extra kernel input bytes), and the
                         per-backend circuit breaker degrading
                         pallas -> mma_jnp -> xla under kernel faults.

  PYTHONPATH=src python -m repro.launch.serve --arch olmo-1b --tiny \
      --requests 8 --batch-slots 4 --max-new 16 --guard \
      --chaos --chaos-seed 7 --status-path /tmp/serve_status.json
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import reduce as R
from repro.configs import get_arch
from repro.launch.steps import make_decode_step, make_prefill_step
from repro.models import init_params, make_caches
from repro.models.frontends import synth_image_embeds
from repro.runtime.serving import (
    Request,
    ServingRuntime,
    guarded_logit_stat,
)


def _tok_ints(tok) -> np.ndarray:
    """Per-slot int token from a (B, 1) or (B, 1, K) greedy-argmax output
    (codebook models report codebook 0, as the plain loop always has)."""
    a = np.asarray(tok)
    return a[:, 0] if a.ndim == 2 else a[:, 0, 0]


class Engine:
    """Greedy decoding engine over fixed batch slots."""

    def __init__(self, cfg, s_max: int, batch_slots: int, seed: int = 0):
        self.cfg = cfg
        self.s_max = s_max
        self.slots = batch_slots
        self.params, _ = init_params(jax.random.PRNGKey(seed), cfg)
        # underscored: GuardedEngine exposes protocol methods named
        # start_wave/decode, which plain attributes here would shadow
        self._jit_prefill = jax.jit(make_prefill_step(cfg, s_max))
        self._jit_decode = jax.jit(make_decode_step(cfg))
        self.ctx = (
            synth_image_embeds(
                jax.random.PRNGKey(1), batch_slots, cfg.n_img_tokens,
                cfg.d_model, jnp.dtype(cfg.dtype))
            if cfg.n_img_tokens else None
        )

    def check_fits(self, prompt_len: int, max_new: int) -> None:
        """The cache-overflow guard: a prompt + its generation + the one
        trailing decode position must fit the resident caches."""
        need = int(prompt_len) + int(max_new) + 1
        if need > self.s_max:
            raise ValueError(
                f"prompt_len ({prompt_len}) + max_new ({max_new}) + 1 = "
                f"{need} exceeds the engine's cache length s_max="
                f"{self.s_max}; shorten the request or rebuild the engine"
            )

    def _pack_wave(self, wave: list) -> jnp.ndarray:
        """Stack a wave of prompts into (slots, L), padding the tail with
        MASKED dummy slots (zero prompts, excluded from token accounting by
        the caller) -- never by duplicating a live request."""
        n_live = len(wave)
        if n_live < self.slots:
            dummy = np.zeros_like(np.asarray(wave[0]))
            wave = wave + [dummy] * (self.slots - n_live)
        prompts = jnp.asarray(np.stack(wave))
        if self.cfg.n_codebooks and prompts.ndim == 2:
            prompts = jnp.tile(prompts[..., None], (1, 1, self.cfg.n_codebooks))
        return prompts

    def serve(self, requests: list[np.ndarray], max_new: int) -> list[list[int]]:
        """requests: list of prompt token arrays (same length for packing
        simplicity here; ragged packing is the documented extension).
        An empty request list serves zero requests (no crash)."""
        out: list[list[int]] = []
        if not requests:
            return out
        for r in requests:
            self.check_fits(np.asarray(r).shape[0], max_new)
        queue = list(requests)
        while queue:
            wave = queue[: self.slots]
            queue = queue[self.slots :]
            n_live = len(wave)
            prompts = self._pack_wave(wave)
            caches = make_caches(self.cfg, self.slots, self.s_max)
            logits, caches = self._jit_prefill(self.params, prompts, *(
                (self.ctx,) if self.ctx is not None else ()))
            tok = jnp.argmax(logits, -1).astype(jnp.int32)
            if tok.ndim == 2:
                tok = tok[:, :1]
            gen = [tok]
            pos = prompts.shape[1]
            for t in range(max_new - 1):
                tok, caches = self._jit_decode(
                    self.params, caches, gen[-1], jnp.asarray(pos + t, jnp.int32),
                    *((self.ctx,) if self.ctx is not None else ()),
                )
                gen.append(tok)
            toks = np.stack([_tok_ints(g) for g in gen], 1)
            out.extend(list(toks[:n_live]))
        return [list(map(int, o)) for o in out]


class GuardedEngine(Engine):
    """``runtime.serving`` protocol over the jitted prefill/decode pair.

    Each step is one jitted function per (stat) backend: model decode +
    the chaos scale multiply (x1.0 = bitwise identity) + the per-slot
    logit statistic with its in-launch non-finite census
    (``guarded_logit_stat`` -- one pallas_call on the kernel backends,
    zero input bytes beyond the logits the statistic already reads) + the
    greedy argmax. Steps are FUNCTIONAL: caches go in and come out, so
    the runtime can retry a step from committed state. Keying the jitted
    functions by backend NAME (not the process default) is what makes the
    breaker's re-route safe under jit -- a traced computation has its
    plan baked in, so each backend gets its own trace."""

    def __init__(self, cfg, s_max: int, batch_slots: int, seed: int = 0):
        super().__init__(cfg, s_max, batch_slots, seed)
        self._guarded_prefill = {}
        self._guarded_decode = {}

    def validate(self, prompt, max_new: int):
        try:
            self.check_fits(np.asarray(prompt).shape[0], max_new)
        except ValueError as e:
            return str(e)
        return None

    def _scale_logits(self, logits, scales):
        s = scales.reshape((-1,) + (1,) * (logits.ndim - 1))
        return logits * s.astype(logits.dtype)

    def _prefill_fn(self, backend):
        fn = self._guarded_prefill.get(backend)
        if fn is not None:
            return fn
        prefill = make_prefill_step(self.cfg, self.s_max)

        def step(params, prompts, scales, ctx=None):
            logits, caches = prefill(params, prompts, ctx)
            logits = self._scale_logits(logits, scales)
            stat, census = guarded_logit_stat(logits, backend=backend)
            tok = jnp.argmax(logits, -1).astype(jnp.int32)
            if tok.ndim == 2:
                tok = tok[:, :1]
            return tok, caches, stat, census

        fn = jax.jit(step)
        self._guarded_prefill[backend] = fn
        return fn

    def _decode_fn(self, backend):
        fn = self._guarded_decode.get(backend)
        if fn is not None:
            return fn
        decode_logits = make_decode_step(self.cfg, greedy=False)

        def step(params, caches, tok, pos, scales, ctx=None):
            logits, caches = decode_logits(params, caches, tok, pos, ctx)
            logits = self._scale_logits(logits, scales)
            stat, census = guarded_logit_stat(logits, backend=backend)
            nxt = jnp.argmax(logits, -1).astype(jnp.int32)
            return nxt, caches, stat, census

        fn = jax.jit(step)
        self._guarded_decode[backend] = fn
        return fn

    # -- the ServingRuntime protocol --------------------------------------

    def start_wave(self, prompts: list, scales, backend: str):
        live = [p for p in prompts if p is not None]
        if not live:
            raise ValueError("start_wave needs at least one live prompt")
        packed = self._pack_wave([np.asarray(p) for p in live])
        # dummy-slot scales are 1.0 (the runtime already sends 1.0 for
        # masked slots, but the wave list may be SHORTER than slots)
        s = np.ones((self.slots,), np.float32)
        s[: len(scales)] = np.asarray(scales, np.float32)[: self.slots]
        tok, caches, _stat, census = self._prefill_fn(backend)(
            self.params, packed, jnp.asarray(s),
            *((self.ctx,) if self.ctx is not None else ()),
        )
        state = {"caches": caches, "tok": tok, "pos": int(packed.shape[1]),
                 "t": 0}
        return state, _tok_ints(tok), np.asarray(census)

    def decode(self, state: dict, scales, backend: str):
        s = np.ones((self.slots,), np.float32)
        s[: len(scales)] = np.asarray(scales, np.float32)[: self.slots]
        tok, caches, _stat, census = self._decode_fn(backend)(
            self.params, state["caches"], state["tok"],
            jnp.asarray(state["pos"] + state["t"], jnp.int32),
            jnp.asarray(s),
            *((self.ctx,) if self.ctx is not None else ()),
        )
        new_state = {"caches": caches, "tok": tok, "pos": state["pos"],
                     "t": state["t"] + 1}
        return new_state, _tok_ints(tok), np.asarray(census)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--tiny", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--batch-slots", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument(
        "--reduce-backend",
        default=None,
        choices=R.available_backends() + ("auto",),
        help="process-wide repro.reduce backend (default: cost-model auto)",
    )
    ap.add_argument("--guard", action="store_true",
                    help="serve through the resilient runtime (admission "
                    "queue, deadlines, census-guarded decode, breaker)")
    ap.add_argument("--queue-capacity", type=int, default=64)
    ap.add_argument("--deadline-s", type=float, default=None,
                    help="per-request deadline, seconds from submission")
    ap.add_argument("--chaos", action="store_true",
                    help="per-request fault injection (--guard only)")
    ap.add_argument("--chaos-seed", type=int, default=0)
    ap.add_argument("--status-path", default=None,
                    help="atomic JSON ServeMetrics export path")
    args = ap.parse_args(argv)

    if args.reduce_backend:
        R.set_default_backend(args.reduce_backend)
    cfg = get_arch(args.arch, tiny=args.tiny)
    s_max = args.prompt_len + args.max_new + 1
    rng = np.random.default_rng(0)
    reqs = [
        rng.integers(0, cfg.vocab_size, size=(args.prompt_len,)).astype(np.int32)
        for _ in range(args.requests)
    ]
    t0 = time.time()
    if args.guard:
        from repro.runtime.chaos import ChaosMonkey

        eng = GuardedEngine(cfg, s_max, args.batch_slots)
        chaos = (
            ChaosMonkey.from_seed(
                args.chaos_seed, n_steps=args.requests,
                nan_rate=0.15, fail_rate=0.15, preempt_rate=0.1,
            )
            if args.chaos else None
        )
        runtime = ServingRuntime(
            eng, queue_capacity=args.queue_capacity, chaos=chaos,
            status_path=args.status_path,
        )
        now = runtime.clock()
        results = runtime.serve([
            Request(
                rid=i, prompt=p, max_new=args.max_new,
                deadline_s=(now + args.deadline_s
                            if args.deadline_s is not None else None),
            )
            for i, p in enumerate(reqs)
        ])
        dt = time.time() - t0
        outs = [list(r.tokens) for r in results if r.ok]
        n_tok = sum(len(o) for o in outs)
        snap = runtime.metrics.snapshot()
        print(f"served {len(outs)}/{len(reqs)} requests, {n_tok} tokens in "
              f"{dt:.2f}s ({n_tok / max(dt, 1e-9):.1f} tok/s incl. compile)")
        print(f"admitted={snap['admitted']} shed={snap['shed_queue_full']}"
              f"+{snap['shed_infeasible']} deadline_missed="
              f"{snap['deadline_missed']} quarantined={snap['quarantined']} "
              f"breaker_trips={snap['breaker_trips']} "
              f"p50={snap['token_latency_p50_s'] * 1e3:.1f}ms "
              f"p99={snap['token_latency_p99_s'] * 1e3:.1f}ms")
        return results
    eng = Engine(cfg, s_max, args.batch_slots)
    outs = eng.serve(reqs, args.max_new)
    dt = time.time() - t0
    n_tok = sum(len(o) for o in outs)
    print(f"served {len(outs)} requests, {n_tok} tokens in {dt:.2f}s "
          f"({n_tok/dt:.1f} tok/s incl. compile)")
    for i, o in enumerate(outs[:3]):
        print(f"req{i}: {o[:12]}...")
    return outs


if __name__ == "__main__":
    main()
