"""Serving driver: batched prefill + decode with resident caches.

Continuous-batching-lite: a request queue is packed into fixed slots; each
engine step decodes one token for every active slot; finished slots are
refilled from the queue (prefill) without stopping the decode stream.

  PYTHONPATH=src python -m repro.launch.serve --arch olmo-1b --tiny \
      --requests 8 --batch-slots 4 --max-new 16
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import reduce as R
from repro.configs import get_arch
from repro.launch.steps import make_decode_step, make_prefill_step
from repro.models import init_params, make_caches
from repro.models.frontends import synth_image_embeds


class Engine:
    """Greedy decoding engine over fixed batch slots."""

    def __init__(self, cfg, s_max: int, batch_slots: int, seed: int = 0):
        self.cfg = cfg
        self.s_max = s_max
        self.slots = batch_slots
        self.params, _ = init_params(jax.random.PRNGKey(seed), cfg)
        self.prefill = jax.jit(make_prefill_step(cfg, s_max))
        self.decode = jax.jit(make_decode_step(cfg))
        self.ctx = (
            synth_image_embeds(
                jax.random.PRNGKey(1), batch_slots, cfg.n_img_tokens,
                cfg.d_model, jnp.dtype(cfg.dtype))
            if cfg.n_img_tokens else None
        )

    def serve(self, requests: list[np.ndarray], max_new: int) -> list[list[int]]:
        """requests: list of prompt token arrays (same length for packing
        simplicity here; ragged packing is the documented extension)."""
        out: list[list[int]] = []
        queue = list(requests)
        while queue:
            wave = queue[: self.slots]
            queue = queue[self.slots :]
            while len(wave) < self.slots:  # pad the last wave
                wave.append(wave[0])
            prompts = jnp.asarray(np.stack(wave))
            if self.cfg.n_codebooks and prompts.ndim == 2:
                prompts = jnp.tile(prompts[..., None], (1, 1, self.cfg.n_codebooks))
            caches = make_caches(self.cfg, self.slots, self.s_max)
            logits, caches = self.prefill(self.params, prompts, *(
                (self.ctx,) if self.ctx is not None else ()))
            tok = jnp.argmax(logits, -1).astype(jnp.int32)
            if tok.ndim == 2:
                tok = tok[:, :1]
            gen = [tok]
            pos = prompts.shape[1]
            for t in range(max_new - 1):
                tok, caches = self.decode(
                    self.params, caches, gen[-1], jnp.asarray(pos + t, jnp.int32),
                    *((self.ctx,) if self.ctx is not None else ()),
                )
                gen.append(tok)
            toks = np.concatenate([np.asarray(g)[:, :1] if g.ndim == 2 else
                                   np.asarray(g)[:, :1, 0] for g in gen], 1)
            out.extend(list(toks[: len(requests) - len(out)]))
        return [list(map(int, o)) for o in out]


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--tiny", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--batch-slots", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument(
        "--reduce-backend",
        default=None,
        choices=R.available_backends() + ("auto",),
        help="process-wide repro.reduce backend (default: cost-model auto)",
    )
    args = ap.parse_args(argv)

    if args.reduce_backend:
        R.set_default_backend(args.reduce_backend)
    cfg = get_arch(args.arch, tiny=args.tiny)
    s_max = args.prompt_len + args.max_new + 1
    eng = Engine(cfg, s_max, args.batch_slots)
    rng = np.random.default_rng(0)
    reqs = [
        rng.integers(0, cfg.vocab_size, size=(args.prompt_len,)).astype(np.int32)
        for _ in range(args.requests)
    ]
    t0 = time.time()
    outs = eng.serve(reqs, args.max_new)
    dt = time.time() - t0
    n_tok = sum(len(o) for o in outs)
    print(f"served {len(outs)} requests, {n_tok} tokens in {dt:.2f}s "
          f"({n_tok/dt:.1f} tok/s incl. compile)")
    for i, o in enumerate(outs[:3]):
        print(f"req{i}: {o[:12]}...")
    return outs


if __name__ == "__main__":
    main()
