"""Sharded, atomic, async checkpointing with elastic restore.

Layout (one directory per step):
  <dir>/step_000420/
    manifest.json       -- step, leaf paths/shapes/dtypes, mesh shape,
                           data-pipeline state, wall time
    shard_00000.npz     -- this host's param/opt shards (one npz per host)
    _COMMITTED          -- written last; a checkpoint without it is ignored

Fault-tolerance contract:
  * atomicity   -- writes go to step_X.tmp-<nonce>/ then os.replace; a
    preempted writer never corrupts the latest good checkpoint.
  * async       -- save() snapshots to host RAM (device_get) and flushes on
    a background thread; the train loop blocks only on the snapshot.
  * keep-N      -- bounded disk; latest() scans for the newest committed.
  * elastic     -- restore(reshard=True) re-device_puts each leaf with the
    *current* sharding tree, so a job restarted on a different mesh shape
    (e.g. 512 -> 256 chips after losing a pod) loads the same weights.
  * integrity   -- every leaf's CRC32 is recorded in the manifest at save
    and verified at restore; a bit-flipped or truncated shard raises
    ``CheckpointCorruptionError``, ``quarantine()`` moves the bad step out
    of the committed namespace, and ``restore_latest_valid()`` falls back
    to the newest checkpoint that still verifies.
"""

from __future__ import annotations

import json
import os
import pathlib
import shutil
import threading
import time
import uuid
import zlib

import jax
import numpy as np


class CheckpointCorruptionError(RuntimeError):
    """A committed checkpoint failed integrity verification (CRC mismatch,
    unreadable shard archive, or leaf missing vs the manifest). The step
    number and offending path/leaf are in the message; the correct
    response is ``quarantine()`` + fall back to an older commit."""


def _flatten(tree):
    leaves, treedef = jax.tree_util.tree_flatten_with_path(tree)
    return {jax.tree_util.keystr(p): v for p, v in leaves}, treedef


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3, host_id: int = 0,
                 n_hosts: int = 1):
        self.dir = pathlib.Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep = keep
        self.host_id = host_id
        self.n_hosts = n_hosts
        self._pending: threading.Thread | None = None

    # ------------------------------ save --------------------------------

    def save(self, step: int, tree, extra: dict | None = None,
             blocking: bool = False) -> None:
        """Snapshot now, flush in background (one outstanding save max)."""
        self.wait()
        flat, _ = _flatten(tree)
        host_np = {k: np.asarray(jax.device_get(v)) for k, v in flat.items()}
        meta = {
            "step": int(step),
            "time": time.time(),
            "n_hosts": self.n_hosts,
            "leaves": {
                k: {
                    "shape": list(v.shape),
                    "dtype": str(v.dtype),
                    # CRC of the leaf's raw bytes: cheap (one pass at save
                    # time), catches bit rot / torn writes at restore
                    "crc32": zlib.crc32(
                        np.ascontiguousarray(v).tobytes()
                    ) & 0xFFFFFFFF,
                }
                for k, v in host_np.items()
            },
            "extra": extra or {},
        }

        def flush():
            tmp = self.dir / f"step_{step:08d}.tmp-{uuid.uuid4().hex[:8]}"
            tmp.mkdir(parents=True)
            np.savez(tmp / f"shard_{self.host_id:05d}.npz", **host_np)
            if self.host_id == 0:
                (tmp / "manifest.json").write_text(json.dumps(meta))
                (tmp / "_COMMITTED").write_text("ok")
            final = self.dir / f"step_{step:08d}"
            if final.exists():
                shutil.rmtree(final)
            os.replace(tmp, final)
            self._gc()

        t = threading.Thread(target=flush, daemon=True)
        t.start()
        self._pending = t
        if blocking:
            self.wait()

    def wait(self) -> None:
        if self._pending is not None:
            self._pending.join()
            self._pending = None

    def _gc(self) -> None:
        steps = sorted(self._committed_steps())
        for s in steps[: -self.keep]:
            shutil.rmtree(self.dir / f"step_{s:08d}", ignore_errors=True)

    # ----------------------------- restore ------------------------------

    def _committed_steps(self):
        out = []
        for p in self.dir.glob("step_*"):
            if p.name.endswith(tuple("0123456789")) and (p / "_COMMITTED").exists():
                out.append(int(p.name.split("_")[1]))
        return out

    def latest(self) -> int | None:
        steps = self._committed_steps()
        return max(steps) if steps else None

    def restore(self, step: int, like_tree, shardings=None,
                verify: bool = True):
        """Load into the structure of ``like_tree``. With ``shardings`` given
        (a matching NamedSharding tree) every leaf is device_put with the
        *current* sharding -- elastic reshard on a changed mesh.

        ``verify=True`` (default) checks every loaded leaf's CRC32 against
        the manifest written at save time: a flipped bit, a truncated npz,
        or a leaf the manifest promised but the shards lack raises
        ``CheckpointCorruptionError`` BEFORE any state reaches the model.
        Pre-CRC manifests (no ``crc32`` key) verify vacuously."""
        path = self.dir / f"step_{step:08d}"
        if not (path / "_COMMITTED").exists():
            raise FileNotFoundError(f"no committed checkpoint at {path}")
        crcs = {}
        if verify:
            try:
                man_leaves = json.loads(
                    (path / "manifest.json").read_text()
                ).get("leaves", {})
            except (OSError, json.JSONDecodeError) as e:
                raise CheckpointCorruptionError(
                    f"step {step}: unreadable manifest at {path}: {e}"
                ) from e
            crcs = {
                k: v["crc32"] for k, v in man_leaves.items() if "crc32" in v
            }
        data = {}
        for shard_file in sorted(path.glob("shard_*.npz")):
            try:
                with np.load(shard_file) as z:
                    for k in z.files:
                        data[k] = z[k]
            except Exception as e:  # truncated/garbled zip: BadZipFile,
                raise CheckpointCorruptionError(  # OSError, ValueError...
                    f"step {step}: unreadable shard {shard_file.name}: {e}"
                ) from e
        for k, want in crcs.items():
            if k not in data:
                raise CheckpointCorruptionError(
                    f"step {step}: manifest lists leaf {k} but no shard "
                    f"provides it"
                )
            got = zlib.crc32(
                np.ascontiguousarray(data[k]).tobytes()
            ) & 0xFFFFFFFF
            if got != want:
                raise CheckpointCorruptionError(
                    f"step {step}: leaf {k} CRC mismatch "
                    f"(manifest {want:#010x}, on disk {got:#010x})"
                )
        flat, treedef = _flatten(like_tree)
        out = []
        for k, like in flat.items():
            if k not in data:
                raise KeyError(f"checkpoint missing leaf {k}")
            arr = data[k]
            if tuple(arr.shape) != tuple(like.shape):
                raise ValueError(f"{k}: shape {arr.shape} != {like.shape}")
            out.append(arr.astype(like.dtype))
        tree = jax.tree_util.tree_unflatten(treedef, out)
        if shardings is not None:
            tree = jax.tree.map(jax.device_put, tree, shardings)
        return tree

    def quarantine(self, step: int) -> pathlib.Path:
        """Move a corrupt checkpoint out of the committed namespace
        (rename to ``quarantine_step_XXXXXXXX``, which the ``step_*``
        scan never matches) instead of deleting it -- the bytes stay on
        disk for forensics, but ``latest()``/``restore_latest_valid()``
        will never offer it again."""
        src = self.dir / f"step_{step:08d}"
        dst = self.dir / f"quarantine_step_{step:08d}"
        if dst.exists():
            shutil.rmtree(dst)
        os.replace(src, dst)
        return dst

    def restore_latest_valid(self, like_tree, shardings=None):
        """Newest committed checkpoint that passes CRC verification.

        Walks commits newest-first; each one that fails verification is
        quarantined and the walk falls back to the previous commit.
        Returns ``(tree, step)``; raises ``FileNotFoundError`` if no
        committed checkpoint survives."""
        while True:
            step = self.latest()
            if step is None:
                raise FileNotFoundError(
                    f"no committed checkpoint in {self.dir} passed "
                    f"integrity verification"
                )
            try:
                return self.restore(step, like_tree, shardings), step
            except CheckpointCorruptionError:
                self.quarantine(step)

    def manifest(self, step: int) -> dict:
        return json.loads(
            (self.dir / f"step_{step:08d}" / "manifest.json").read_text()
        )
