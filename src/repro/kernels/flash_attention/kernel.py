"""Flash attention for TPU with MMA-encoded softmax denominators.

IO-aware chunked attention (FlashAttention recast for the TPU memory
hierarchy): queries are tiled (block_q, D) into VMEM, the KV sequence streams
through VMEM (block_k, D) tiles along the last ("arbitrary") grid dimension,
and the online-softmax state (running max ``m``, denominator ``l``, output
accumulator ``acc``) lives in VMEM scratch across KV steps.

Paper tie-in: the denominator update ``l += sum_j exp(s_ij)`` is an
arithmetic row-reduction executed once per (q-block, k-block) pair -- we
issue it as an all-ones MMA (eq. 9) so it pipelines into the same MXU
schedule that just produced ``exp(S)``'s logits, instead of serializing a
VPU sweep. The running *max* has no MMA encoding (max is not +; see
DESIGN.md Arch-applicability) and stays on the VPU.

Supports GQA/MQA (head-index arithmetic in the BlockSpec index maps), causal
masking, sliding-window (local) attention, and a query-position offset so the
same kernel serves prefill and decode-append shapes.

Block geometry: at block_q = block_k = 128 and D <= 128 the working set is
q/k/v tiles (3 * 128 * 128 * 2B), S/P (128 * 128 * 4B), acc (128 * 128 * 4B)
~= 0.25 MiB -- small; real deployments raise block_k to 512+ to amortize, a
knob exposed in ops.py and swept by the perf loop.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels import common

NEG = -1e30


def _mma_row_sum(mat: jax.Array, compute_dtype=jnp.bfloat16) -> jax.Array:
    d = mat.shape[-1]
    ones = jnp.ones((d, common.MXU), compute_dtype)
    return jax.lax.dot_general(
        mat.astype(compute_dtype),
        ones,
        (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )[:, 0]


def _attn_kernel(
    q_ref,
    k_ref,
    v_ref,
    o_ref,
    m_ref,
    l_ref,
    acc_ref,
    *,
    sm_scale: float,
    causal: bool,
    window: int | None,
    q_offset: int,
    kv_len: int,
    block_q: int,
    block_k: int,
):
    iq, ik = pl.program_id(1), pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    qpos0 = q_offset + iq * block_q          # first query position this block
    kpos0 = ik * block_k                     # first key position this block
    run = kpos0 < kv_len                     # key block within real sequence
    if causal:
        run &= kpos0 <= qpos0 + block_q - 1  # not entirely in the future
    if window is not None:
        # skip only blocks too old for the OLDEST query in this q block
        # (newest key vs oldest query; using the newest query here skips
        # keys still visible to earlier rows -- caught by case5 sweep)
        run &= qpos0 - (kpos0 + block_k - 1) < window

    @pl.when(run)
    def _block():
        q = q_ref[0]  # (block_q, D)
        k = k_ref[0]  # (block_k, D)
        s = jax.lax.dot_general(
            q.astype(jnp.bfloat16),
            k.astype(jnp.bfloat16),
            (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32,
        ) * sm_scale  # (block_q, block_k) on MXU

        qpos = qpos0 + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
        kpos = kpos0 + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        mask = kpos < kv_len
        if causal:
            mask &= kpos <= qpos
        if window is not None:
            mask &= (qpos - kpos) < window
        s = jnp.where(mask, s, NEG)

        m_old = m_ref[...]
        m_new = jnp.maximum(m_old, jnp.max(s, axis=-1))        # VPU (no + form)
        p = jnp.exp(s - m_new[:, None])
        p = jnp.where(mask, p, 0.0)
        alpha = jnp.exp(m_old - m_new)
        l_ref[...] = l_ref[...] * alpha + _mma_row_sum(p)      # MMA denominator
        pv = jax.lax.dot_general(
            p.astype(jnp.bfloat16),
            v_ref[0].astype(jnp.bfloat16),
            (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
        acc_ref[...] = acc_ref[...] * alpha[:, None] + pv
        m_ref[...] = m_new

    @pl.when(ik == pl.num_programs(2) - 1)
    def _finalize():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


def flash_attention_kernel_call(
    q: jax.Array,   # (BHq, Sq, D)  -- batch*heads flattened
    k: jax.Array,   # (BHkv, Skv, D)
    v: jax.Array,
    *,
    n_q_heads: int,
    n_kv_heads: int,
    sm_scale: float,
    causal: bool,
    window: int | None,
    q_offset: int,
    kv_len: int,
    block_q: int,
    block_k: int,
    interpret: bool | None,
) -> jax.Array:
    interpret = common.resolve_interpret(interpret)
    bh, sq, d = q.shape
    skv = k.shape[1]
    nq = sq // block_q
    nk = skv // block_k
    qpk = n_q_heads // n_kv_heads

    def kv_index(bh_ix):
        b = bh_ix // n_q_heads
        h = bh_ix % n_q_heads
        return b * n_kv_heads + h // qpk

    kernel = functools.partial(
        _attn_kernel,
        sm_scale=sm_scale,
        causal=causal,
        window=window,
        q_offset=q_offset,
        kv_len=kv_len,
        block_q=block_q,
        block_k=block_k,
    )
    return pl.pallas_call(
        kernel,
        grid=(bh, nq, nk),
        in_specs=[
            pl.BlockSpec((1, block_q, d), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, block_k, d), lambda b, i, j: (kv_index(b), j, 0)),
            pl.BlockSpec((1, block_k, d), lambda b, i, j: (kv_index(b), j, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, d), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, sq, d), q.dtype),
        scratch_shapes=[
            common.vmem_scratch((block_q,), jnp.float32),
            common.vmem_scratch((block_q,), jnp.float32),
            common.vmem_scratch((block_q, d), jnp.float32),
        ],
        compiler_params=common.compiler_params(("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(q, k, v)
