"""Pure-jnp oracle for the flash attention kernel (dense, f32)."""

from __future__ import annotations

import jax.numpy as jnp


def attention_ref(
    q,
    k,
    v,
    *,
    causal: bool = True,
    window: int | None = None,
    q_offset: int = 0,
    sm_scale: float | None = None,
):
    """Dense reference attention.

    q: (B, Hq, Sq, D); k, v: (B, Hkv, Skv, D); Hq % Hkv == 0 (GQA).
    Query i sits at absolute position q_offset + i; key j at position j.
    causal: key_pos <= query_pos. window W: query_pos - key_pos < W.
    """
    b, hq, sq, d = q.shape
    hkv = k.shape[1]
    skv = k.shape[2]
    if sm_scale is None:
        sm_scale = d**-0.5
    rep = hq // hkv
    kk = jnp.repeat(k, rep, axis=1)
    vv = jnp.repeat(v, rep, axis=1)
    s = jnp.einsum(
        "bhqd,bhkd->bhqk",
        q.astype(jnp.float32),
        kk.astype(jnp.float32),
    ) * sm_scale
    qpos = q_offset + jnp.arange(sq)[:, None]
    kpos = jnp.arange(skv)[None, :]
    mask = jnp.ones((sq, skv), bool)
    if causal:
        mask &= kpos <= qpos
    if window is not None:
        mask &= (qpos - kpos) < window
    s = jnp.where(mask[None, None], s, -1e30)
    p = jnp.exp(s - jnp.max(s, -1, keepdims=True))
    p = p / jnp.maximum(jnp.sum(p, -1, keepdims=True), 1e-30)
    out = jnp.einsum("bhqk,bhkd->bhqd", p, vv.astype(jnp.float32))
    return out.astype(q.dtype)
