"""Public flash attention entry point: padding, GQA plumbing, custom VJP."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import common
from repro.kernels.flash_attention import kernel as _k
from repro.kernels.flash_attention import ref as _ref


def flash_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    window: int | None = None,
    q_offset: int = 0,
    sm_scale: float | None = None,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool | None = None,
) -> jax.Array:
    """IO-aware attention. q: (B, Hq, Sq, D); k, v: (B, Hkv, Skv, D)."""
    b, hq, sq, d = q.shape
    hkv, skv = k.shape[1], k.shape[2]
    if hq % hkv:
        raise ValueError(f"Hq={hq} must be a multiple of Hkv={hkv}")
    if sm_scale is None:
        sm_scale = d**-0.5
    block_q = min(block_q, common.round_up(sq, common.SUBLANES))
    block_k = min(block_k, common.round_up(skv, common.SUBLANES))
    sq_p = common.round_up(sq, block_q)
    skv_p = common.round_up(skv, block_k)
    qp = common.pad_to(q.reshape(b * hq, sq, d), sq_p, axis=1)
    kp = common.pad_to(k.reshape(b * hkv, skv, d), skv_p, axis=1)
    vp = common.pad_to(v.reshape(b * hkv, skv, d), skv_p, axis=1)
    out = _k.flash_attention_kernel_call(
        qp,
        kp,
        vp,
        n_q_heads=hq,
        n_kv_heads=hkv,
        sm_scale=sm_scale,
        causal=causal,
        window=window,
        q_offset=q_offset,
        kv_len=skv,
        block_q=block_q,
        block_k=block_k,
        interpret=interpret,
    )
    return out[:, :sq].reshape(b, hq, sq, d)


@functools.partial(
    jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6)
)
def flash_attention_diff(
    q, k, v, causal=True, window=None, q_offset=0, sm_scale=None
):
    """Differentiable wrapper: Pallas forward, recompute-style backward.

    Backward recomputes attention densely via the oracle (FlashAttention's
    recompute strategy; a dedicated Pallas backward kernel is the documented
    TPU-deployment follow-up and does not change the framework contract).
    """
    return flash_attention(
        q, k, v, causal=causal, window=window, q_offset=q_offset, sm_scale=sm_scale
    )


def _fwd(q, k, v, causal, window, q_offset, sm_scale):
    out = flash_attention(
        q, k, v, causal=causal, window=window, q_offset=q_offset, sm_scale=sm_scale
    )
    return out, (q, k, v)


def _bwd(causal, window, q_offset, sm_scale, res, g):
    q, k, v = res
    f = lambda q, k, v: _ref.attention_ref(
        q, k, v, causal=causal, window=window, q_offset=q_offset, sm_scale=sm_scale
    )
    _, vjp = jax.vjp(f, q, k, v)
    return vjp(g)


flash_attention_diff.defvjp(_fwd, _bwd)

attention_ref = _ref.attention_ref
