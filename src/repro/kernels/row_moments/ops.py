"""Public entry points for the fused norm kernels (shape-polymorphic,
differentiable). The Pallas forward is paired with an analytic custom VJP
(recompute style -- no residual tensors besides the inputs)."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.row_moments import kernel as _k
from repro.kernels.row_moments import ref as _ref


def _flatten_rows(x):
    return x.reshape(-1, x.shape[-1]), x.shape


@functools.partial(jax.custom_vjp, nondiff_argnums=(2, 3))
def rmsnorm(x, gamma, eps: float = 1e-6, interpret: bool | None = None):
    """RMSNorm over the last axis; any leading batch shape."""
    rows, shape = _flatten_rows(x)
    out = _k.rmsnorm(rows, gamma, eps=eps, interpret=interpret)
    return out.reshape(shape)


def _rms_fwd(x, gamma, eps, interpret):
    return rmsnorm(x, gamma, eps, interpret), (x, gamma)


def _rms_bwd(eps, interpret, res, g):
    x, gamma = res
    xf = x.astype(jnp.float32)
    gf = g.astype(jnp.float32)
    gam = gamma.astype(jnp.float32)
    d = x.shape[-1]
    ms = jnp.mean(xf * xf, -1, keepdims=True)
    rstd = jax.lax.rsqrt(ms + eps)
    xhat = xf * rstd
    dgamma = jnp.sum((gf * xhat).reshape(-1, d), 0).astype(gamma.dtype)
    gg = gf * gam
    # d/dx [x * rsqrt(mean(x^2)+eps) * gamma]
    dx = rstd * gg - xf * (rstd**3) * jnp.mean(gg * xf, -1, keepdims=True)
    return dx.astype(x.dtype), dgamma


rmsnorm.defvjp(_rms_fwd, _rms_bwd)


@functools.partial(jax.custom_vjp, nondiff_argnums=(1, 2))
def layernorm_np(x, eps: float = 1e-5, interpret: bool | None = None):
    """Non-parametric LayerNorm (OLMo) over the last axis."""
    rows, shape = _flatten_rows(x)
    return _k.layernorm_np(rows, eps=eps, interpret=interpret).reshape(shape)


def _ln_fwd(x, eps, interpret):
    return layernorm_np(x, eps, interpret), x


def _ln_bwd(eps, interpret, x, g):
    xf = x.astype(jnp.float32)
    gf = g.astype(jnp.float32)
    mu = jnp.mean(xf, -1, keepdims=True)
    xc = xf - mu
    var = jnp.mean(xc * xc, -1, keepdims=True)
    rstd = jax.lax.rsqrt(var + eps)
    xhat = xc * rstd
    gh = gf
    dx = rstd * (
        gh
        - jnp.mean(gh, -1, keepdims=True)
        - xhat * jnp.mean(gh * xhat, -1, keepdims=True)
    )
    return (dx.astype(x.dtype),)


layernorm_np.defvjp(_ln_fwd, _ln_bwd)

# re-export oracles for test convenience
rmsnorm_ref = _ref.rmsnorm_ref
layernorm_np_ref = _ref.layernorm_np_ref
