"""Fused normalization kernels whose statistics ride the MXU.

This is the highest-leverage TPU landing spot for the paper's idea: norm
statistics are per-row arithmetic reductions executed on *every* token of
*every* layer, and in a fused kernel the operand is already in VMEM. The
paper's first MMA (eq. 9, ``D = X @ 1``) computes exactly the row sums; the
row sums of ``X*X`` give the second moment. Both reductions are issued as
all-ones matmuls (f32 accumulation) so the VPU stays free for the square,
rsqrt and scale work, and the MXU -- idle during a conventional norm -- does
the reduction sweep.

The MXU's 128-lane output means an (R, d) x (d, 128) ones-product costs the
same systolic pass as a width-1 product; we read lane 0. (The paper's
"process the full matrix rather than filter a column" argument, literally.)

Block geometry: rows are tiled (block_rows, d) with d kept whole per block
(d <= ~8k => <= 8k*2B*block_rows bytes; block_rows=256 at d=6144/bf16 is
~3 MiB -- inside VMEM with room for the two ones operands and output).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels import common

MXU = common.MXU


def _mma_row_sum(mat: jax.Array, compute_dtype) -> jax.Array:
    """(R, d) -> (R,) row sums via one all-ones MMA, f32 accumulation."""
    d = mat.shape[-1]
    ones = jnp.ones((d, MXU), compute_dtype)
    out = jax.lax.dot_general(
        mat.astype(compute_dtype),
        ones,
        (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    return out[:, 0]


def rmsnorm_kernel(x_ref, gamma_ref, o_ref, *, eps, compute_dtype):
    x = x_ref[...].astype(jnp.float32)  # (R, d)
    d = x.shape[-1]
    sumsq = _mma_row_sum(x * x, compute_dtype)  # MMA 1 on MXU
    rstd = jax.lax.rsqrt(sumsq / d + eps)  # VPU
    o_ref[...] = (x * rstd[:, None] * gamma_ref[...].astype(jnp.float32)).astype(
        o_ref.dtype
    )


def layernorm_np_kernel(x_ref, o_ref, *, eps, compute_dtype):
    """Non-parametric LayerNorm (OLMo): both moments via MMA, no affine."""
    x = x_ref[...].astype(jnp.float32)
    d = x.shape[-1]
    s = _mma_row_sum(x, compute_dtype)        # MMA: sum
    ss = _mma_row_sum(x * x, compute_dtype)   # MMA: sum of squares
    mu = s / d
    var = jnp.maximum(ss / d - mu * mu, 0.0)
    o_ref[...] = ((x - mu[:, None]) * jax.lax.rsqrt(var + eps)[:, None]).astype(
        o_ref.dtype
    )


def _call_rows(kernel, x, extra_inputs, extra_specs, *, block_rows, interpret):
    interpret = common.resolve_interpret(interpret)
    rows, d = x.shape
    r = min(block_rows, max(rows, 1))
    rpad = common.round_up(rows, r)
    x = common.pad_to(x, rpad, axis=0)
    out = pl.pallas_call(
        kernel,
        grid=(rpad // r,),
        in_specs=[pl.BlockSpec((r, d), lambda i: (i, 0))] + extra_specs,
        out_specs=pl.BlockSpec((r, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rpad, d), x.dtype),
        interpret=interpret,
    )(x, *extra_inputs)
    return out[:rows]


def rmsnorm(
    x: jax.Array,
    gamma: jax.Array,
    *,
    eps: float = 1e-6,
    block_rows: int = 256,
    compute_dtype=jnp.bfloat16,
    interpret: bool | None = None,
) -> jax.Array:
    """Fused RMSNorm over the last axis of a (rows, d) array."""
    kernel = functools.partial(rmsnorm_kernel, eps=eps, compute_dtype=compute_dtype)
    gspec = pl.BlockSpec((x.shape[-1],), lambda i: (0,))
    return _call_rows(
        kernel, x, [gamma], [gspec], block_rows=block_rows, interpret=interpret
    )


def layernorm_np(
    x: jax.Array,
    *,
    eps: float = 1e-5,
    block_rows: int = 256,
    compute_dtype=jnp.bfloat16,
    interpret: bool | None = None,
) -> jax.Array:
    kernel = functools.partial(
        layernorm_np_kernel, eps=eps, compute_dtype=compute_dtype
    )
    return _call_rows(kernel, x, [], [], block_rows=block_rows, interpret=interpret)
