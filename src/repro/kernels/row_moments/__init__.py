from repro.kernels.row_moments.ops import (  # noqa: F401
    layernorm_np,
    layernorm_np_ref,
    rmsnorm,
    rmsnorm_ref,
)
