"""Pure-jnp oracles for the fused row-moment / normalization kernels."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def row_moments_ref(x: jax.Array) -> tuple[jax.Array, jax.Array]:
    xf = x.astype(jnp.float32)
    return jnp.sum(xf, -1), jnp.sum(xf * xf, -1)


def rmsnorm_ref(x: jax.Array, gamma: jax.Array, eps: float = 1e-6) -> jax.Array:
    xf = x.astype(jnp.float32)
    ms = jnp.mean(xf * xf, -1, keepdims=True)
    return (xf * jax.lax.rsqrt(ms + eps) * gamma.astype(jnp.float32)).astype(x.dtype)


def layernorm_np_ref(x: jax.Array, eps: float = 1e-5) -> jax.Array:
    """Non-parametric LayerNorm (OLMo): no scale, no bias."""
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, -1, keepdims=True)
    var = jnp.mean((xf - mu) ** 2, -1, keepdims=True)
    return ((xf - mu) * jax.lax.rsqrt(var + eps)).astype(x.dtype)
