"""Matmul with a fused MMA-reduction epilogue: Y = X @ W plus the row
moments (sum, sum-of-squares) of Y, in one kernel.

This is the paper's idea as a *fusion*: the very next op after most matmuls
in an LM is a normalization whose statistics are arithmetic row-reductions
over the matmul's output. Computing them conventionally costs a second
HBM pass over Y (2 x M x N bytes). Here each finished (bm, bn) output tile
is reduced while still VMEM-resident -- two all-ones MMAs per tile (eq. 9
applied to Y and Y*Y) pipelined into the same MXU schedule that produced the
tile -- and the (bm,) partials accumulate across the N grid dimension in
VMEM scratch. Extra HBM traffic: zero. Extra FLOPs: 2*2*bn*128 per tile
(the paper's "process the full matrix" redundancy), ~2% at bn=512.

Grid: (M/bm, N/bn, K/bk), dimension semantics (parallel, arbitrary,
arbitrary); K innermost accumulates the matmul, N accumulates the moments.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels import common


def _kernel(x_ref, w_ref, y_ref, s_ref, ss_ref, acc_ref, mom_ref, *, n_tiles_k):
    ik = pl.program_id(2)
    i_n = pl.program_id(1)

    @pl.when(ik == 0)
    def _init_acc():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    @pl.when((ik == 0) & (i_n == 0))
    def _init_mom():
        mom_ref[...] = jnp.zeros_like(mom_ref)

    acc_ref[...] += jnp.dot(
        x_ref[...].astype(jnp.bfloat16),
        w_ref[...].astype(jnp.bfloat16),
        preferred_element_type=jnp.float32,
    )

    @pl.when(ik == n_tiles_k - 1)
    def _finalize_tile():
        y = acc_ref[...]                                   # (bm, bn) f32
        y_ref[...] = y.astype(y_ref.dtype)
        bn = y.shape[-1]
        ones = jnp.ones((bn, common.MXU), jnp.float32)
        # eq. (9) on the resident tile: row-sums of Y and Y*Y ride the MXU
        s = jnp.dot(y, ones, preferred_element_type=jnp.float32)[:, 0]
        ss = jnp.dot(y * y, ones, preferred_element_type=jnp.float32)[:, 0]
        mom_ref[:, 0] += s
        mom_ref[:, 1] += ss

        @pl.when(i_n == pl.num_programs(1) - 1)
        def _emit():
            s_ref[...] = mom_ref[:, 0]
            ss_ref[...] = mom_ref[:, 1]


def matmul_stats_call(
    x: jax.Array, w: jax.Array, *,
    bm: int = 128, bn: int = 256, bk: int = 512,
    interpret: bool | None = None,
):
    interpret = common.resolve_interpret(interpret)
    m, k = x.shape
    _, n = w.shape
    bm = min(bm, m)
    bn = min(bn, n)
    bk = min(bk, k)
    mp, np_, kp = (common.round_up(v, b) for v, b in ((m, bm), (n, bn), (k, bk)))
    xp = common.pad_to(common.pad_to(x, mp, 0), kp, 1)
    wp = common.pad_to(common.pad_to(w, kp, 0), np_, 1)
    n_tiles_k = kp // bk
    kernel = functools.partial(_kernel, n_tiles_k=n_tiles_k)
    y, s, ss = pl.pallas_call(
        kernel,
        grid=(mp // bm, np_ // bn, n_tiles_k),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=[
            pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
            pl.BlockSpec((bm,), lambda i, j, kk: (i,)),
            pl.BlockSpec((bm,), lambda i, j, kk: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((mp, np_), x.dtype),
            jax.ShapeDtypeStruct((mp,), jnp.float32),
            jax.ShapeDtypeStruct((mp,), jnp.float32),
        ],
        scratch_shapes=[
            common.vmem_scratch((bm, bn), jnp.float32),
            common.vmem_scratch((bm, 2), jnp.float32),
        ],
        compiler_params=common.compiler_params(
            ("parallel", "arbitrary", "arbitrary")
        ),
        interpret=interpret,
    )(xp, wp)
    return y[:m, :n], s[:m], ss[:m]
