"""Oracle for the matmul + fused row-moment epilogue kernel."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def matmul_stats_ref(x: jax.Array, w: jax.Array):
    """Y = X @ W (f32 accum) plus per-row sum and sum-of-squares of Y.

    x: (M, K); w: (K, N) -> (y (M,N), row_sum (M,), row_sumsq (M,))."""
    y = jnp.dot(
        x.astype(jnp.bfloat16), w.astype(jnp.bfloat16),
        preferred_element_type=jnp.float32,
    )
    return y.astype(x.dtype), jnp.sum(y, -1), jnp.sum(y * y, -1)
