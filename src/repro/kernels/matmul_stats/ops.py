"""Public entry for the fused matmul+moments kernel."""

from __future__ import annotations

import jax

from repro.kernels.matmul_stats import kernel as _k
from repro.kernels.matmul_stats import ref as _ref


def matmul_stats(x: jax.Array, w: jax.Array, **kw):
    """(Y, row_sum(Y), row_sumsq(Y)) with the moments fused into the matmul.
    The moments feed a following normalization without re-reading Y."""
    return _k.matmul_stats_call(x, w, **kw)


matmul_stats_ref = _ref.matmul_stats_ref
