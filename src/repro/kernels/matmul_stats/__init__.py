from repro.kernels.matmul_stats.ops import matmul_stats, matmul_stats_ref  # noqa: F401
