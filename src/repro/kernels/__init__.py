"""Pallas TPU kernels for the paper's compute hot-spots.

The paper's contribution IS a kernel-level algorithm (reduction as MMA), so
this package carries its TPU-native implementations plus the fused kernels
where the reduction trick lands in a real training framework:

  mma_reduce      -- the paper's hierarchical 2-MMA reduction (+ the fused
                     C-accumulator variant; see EXPERIMENTS.md Perf). These
                     are the "pallas_hier" / "pallas_fused" backends of the
                     ``repro.reduce`` engine -- call them through that API.
  row_moments     -- fused RMSNorm / non-parametric LayerNorm, statistics on
                     the MXU via all-ones MMAs.
  flash_attention -- IO-aware attention; softmax denominators as MMAs.
  cross_entropy   -- fused CE over huge vocabs; logsumexp + one-hot-MMA
                     label gather.
  matmul_stats    -- matmul with the next norm's row moments fused as an
                     MMA epilogue on the resident output tiles (zero extra
                     HBM pass over Y).

Each subpackage: kernel.py (pl.pallas_call + BlockSpec), ops.py (jit'd,
differentiable wrapper), ref.py (pure-jnp oracle used by the test sweeps).
Validated with interpret=True on CPU; TPU is the deployment target.
"""

import warnings as _warnings

from repro.kernels.row_moments import (  # noqa: F401
    layernorm_np,
    rmsnorm,
)
from repro.kernels.flash_attention import flash_attention, flash_attention_diff  # noqa: F401
from repro.kernels.cross_entropy import cross_entropy  # noqa: F401
from repro.kernels.matmul_stats import matmul_stats  # noqa: F401


# Legacy deprecation shims: the scalar MMA-reduction kernels are backends of
# the unified engine now -- select them with
# ``repro.reduce.reduce(x, backend="pallas_fused" | "pallas_hier")``.


def mma_sum_pallas(*args, **kwargs):  # pragma: no cover - thin shim
    _warnings.warn(
        "repro.kernels.mma_sum_pallas is deprecated; use repro.reduce."
        'reduce(x, backend="pallas_fused"|"pallas_hier")',
        DeprecationWarning,
        stacklevel=2,
    )
    from repro.kernels.mma_reduce import ops as _ops

    return _ops.mma_sum_pallas(*args, **kwargs)


def mma_sum_pallas_diff(*args, **kwargs):  # pragma: no cover - thin shim
    _warnings.warn(
        "repro.kernels.mma_sum_pallas_diff is deprecated; use repro.reduce."
        "reduce (differentiable on every backend)",
        DeprecationWarning,
        stacklevel=2,
    )
    from repro.kernels.mma_reduce import ops as _ops

    return _ops.mma_sum_pallas_diff(*args, **kwargs)
