"""Pure-jnp oracle for the fused cross-entropy kernel."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def cross_entropy_ref(logits: jax.Array, labels: jax.Array) -> jax.Array:
    """Per-row CE loss: logsumexp(logits) - logits[label]. (R, V), (R,) -> (R,)."""
    lf = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(lf, axis=-1)
    picked = jnp.take_along_axis(lf, labels[:, None], axis=-1)[:, 0]
    return lse - picked
