"""Fused cross-entropy over huge vocabularies (up to 256 k), MMA reductions.

The CE loss is the longest row-reduction in an LM training step: logsumexp
over the vocabulary axis. The kernel streams (block_rows, block_v) logit
tiles through VMEM with an online logsumexp (same algebra as flash
attention's softmax): running max on the VPU, running denominator
``l += sum exp(s - m)`` as an all-ones MMA (the paper's eq. 9), and the
label logit gathered with a one-hot *matmul* -- reduction-as-MMA applied to
indexing, so the gather also rides the MXU instead of a scatter/gather unit.

Never materializes the (R, V) softmax; peak VMEM is one logits tile + three
(block_rows,) carries.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels import common

NEG = -1e30


def _mma_row_sum(mat: jax.Array, compute_dtype=jnp.bfloat16) -> jax.Array:
    d = mat.shape[-1]
    ones = jnp.ones((d, common.MXU), compute_dtype)
    return jax.lax.dot_general(
        mat.astype(compute_dtype),
        ones,
        (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )[:, 0]


def _ce_kernel(
    logits_ref,   # (R, BV)
    labels_ref,   # (R,)
    o_ref,        # (R,)
    m_ref,        # (R,) scratch: running max
    l_ref,        # (R,) scratch: running denominator
    pick_ref,     # (R,) scratch: label logit
    *,
    vocab: int,
    block_v: int,
):
    iv = pl.program_id(1)

    @pl.when(iv == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG)
        l_ref[...] = jnp.zeros_like(l_ref)
        pick_ref[...] = jnp.zeros_like(pick_ref)

    s = logits_ref[...].astype(jnp.float32)  # (R, BV)
    v0 = iv * block_v
    vpos = v0 + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    valid = vpos < vocab
    s = jnp.where(valid, s, NEG)

    # label gather as a one-hot MMA: onehot (R, BV) . s -> per-row picked
    onehot = (vpos == labels_ref[...][:, None]) & valid
    pick_ref[...] += _mma_row_sum(jnp.where(onehot, s, 0.0), jnp.float32)

    m_old = m_ref[...]
    m_new = jnp.maximum(m_old, jnp.max(s, axis=-1))
    p = jnp.where(valid, jnp.exp(s - m_new[:, None]), 0.0)
    l_ref[...] = l_ref[...] * jnp.exp(m_old - m_new) + _mma_row_sum(p)
    m_ref[...] = m_new

    @pl.when(iv == pl.num_programs(1) - 1)
    def _finalize():
        o_ref[...] = m_ref[...] + jnp.log(jnp.maximum(l_ref[...], 1e-30)) - pick_ref[...]


def cross_entropy_call(
    logits: jax.Array,   # (R, V)
    labels: jax.Array,   # (R,) int32
    *,
    block_rows: int = 8,
    block_v: int = 2048,
    interpret: bool | None = None,
) -> jax.Array:
    interpret = common.resolve_interpret(interpret)
    rows, vocab = logits.shape
    block_v = min(block_v, common.round_up(vocab, common.LANES))
    r = min(block_rows, max(rows, 1))
    rp = common.round_up(rows, r)
    vp = common.round_up(vocab, block_v)
    logits_p = common.pad_to(common.pad_to(logits, rp, axis=0), vp, axis=1)
    labels_p = common.pad_to(labels.astype(jnp.int32), rp, axis=0)
    kernel = functools.partial(_ce_kernel, vocab=vocab, block_v=block_v)
    out = pl.pallas_call(
        kernel,
        grid=(rp // r, vp // block_v),
        in_specs=[
            pl.BlockSpec((r, block_v), lambda i, j: (i, j)),
            pl.BlockSpec((r,), lambda i, j: (i,)),
        ],
        out_specs=pl.BlockSpec((r,), lambda i, j: (i,)),
        out_shape=jax.ShapeDtypeStruct((rp,), jnp.float32),
        scratch_shapes=[
            common.vmem_scratch((r,), jnp.float32),
            common.vmem_scratch((r,), jnp.float32),
            common.vmem_scratch((r,), jnp.float32),
        ],
        compiler_params=common.compiler_params(("parallel", "arbitrary")),
        interpret=interpret,
    )(logits_p, labels_p)
    return out[:rows]
