"""Public fused-CE entry: differentiable, any leading batch shape."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.cross_entropy import kernel as _k
from repro.kernels.cross_entropy import ref as _ref


@jax.custom_vjp
def cross_entropy(logits: jax.Array, labels: jax.Array) -> jax.Array:
    """Per-token CE loss. logits: (..., V); labels: (...,) int. -> (...,) f32."""
    batch = logits.shape[:-1]
    v = logits.shape[-1]
    out = _k.cross_entropy_call(logits.reshape(-1, v), labels.reshape(-1))
    return out.reshape(batch)


def _fwd(logits, labels):
    return cross_entropy(logits, labels), (logits, labels)


def _bwd(res, g):
    logits, labels = res
    lf = logits.astype(jnp.float32)
    p = jax.nn.softmax(lf, axis=-1)
    onehot = jax.nn.one_hot(labels, logits.shape[-1], dtype=jnp.float32)
    dlogits = (p - onehot) * g[..., None]
    return dlogits.astype(logits.dtype), None


cross_entropy.defvjp(_fwd, _bwd)

cross_entropy_ref = _ref.cross_entropy_ref
