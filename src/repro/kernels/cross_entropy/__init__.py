from repro.kernels.cross_entropy.ops import (  # noqa: F401
    cross_entropy,
    cross_entropy_ref,
)
