"""Triangular-MMA prefix sums (the scan op class).

Dakkak et al., "Accelerating Reduction and Scan Using Tensor Core Units"
(PAPERS.md), extend the source paper's encoding from reduction to SCAN by
swapping the all-ones MMA operands for triangular ones. Per (m, m) tile X
(row-major element order, so flat index p = i*m + j):

    T1 = X @ J    (J all-ones)      -> T1[i, :] broadcasts row i's sum
    D  = Ls @ T1  (Ls strict lower) -> D[i, :] = sum of rows before i
    R  = X @ U    (U upper-tri)     -> R[i, j] = row i's prefix through j
    P  = R + D                      -> P[i, j] = tile prefix through p

with U strictly-upper for EXCLUSIVE prefixes, and the tile's total read
off the last corner (D + T1)[m-1, m-1]. Three MMAs per tile replace the
paper's two; everything else -- flat 1D BlockSpecs, native-dtype in-VMEM
cast, ``broadcasted_iota`` tail masking, ``stripe_geometry`` -- is the
PR-4/5 reduction machinery reused verbatim.

Two-level scheme across tiles: the in-kernel f32 carry chain folds tile
totals strictly left to right, so block b's carry is the SAME fixed-order
fold at every core count. Multi-core lanes own CONTIGUOUS block ranges (a
scan is order-dependent; the reduction kernels' striping would interleave
carries) and each lane REBUILDS its incoming carry by re-streaming the
blocks before its range -- two MMAs per re-streamed tile (T1, D; no R, no
output write) -- rather than waiting on a cross-lane handoff. That is the
Dakkak decoupled trade: O(n) redundant read bandwidth buys a combine-free
scan whose output is bitwise identical at num_cores in {1, 2, 4, ...}.
"""

from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core import cost_model
from repro.kernels import common
from repro.kernels.mma_reduce.kernel import _load_tiles


@dataclasses.dataclass(frozen=True)
class ScanTrace:
    """Python-side instrumentation for one scan dispatch (the scan analogue
    of ``core.mma_reduce.ReductionTrace``): geometry + modeled MMA/byte
    counts, appended to the caller's ``trace`` list at trace time."""

    n: int
    m: int
    num_cores: int = 1
    mma_ops: int = 0          # chip-wide MMAs (cost_model.ScanMmaOps.total)
    lane_mma_ops: int = 0     # one lane's owned-stripe MMAs
    carry_mma_ops: int = 0    # the worst lane's carry-rebuild MMAs
    hbm_bytes: int = 0        # modeled total traffic (incl. refetch)
    inclusive: bool = True
    fallback: str = ""        # "" (zero-copy) or "ingest_f32"


def _matmul(a, b):
    """Plain (m, m) @ (m, m) with f32 accumulation -- every scan MMA."""
    return jax.lax.dot_general(
        a, b, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )


def scan_kernel(
    x_ref, o_ref, carry_ref, *,
    n, r, m, bpl, compute_dtype, out_dtype, inclusive, needs_mask,
):
    """One grid step of the striped triangular scan.

    Grid is (c, c*bpl): lane ci walks EVERY block index j, in three phases.
      j <  start: carry rebuild -- fold block totals into the f32 carry
                  (2 MMAs/tile; nothing written).
      j in [start, end): owned stripe -- same totals fold, plus the R MMA
                  and the (P + carry) output write.
      j >= end:   dwell -- the index maps clamp to the last owned block and
                  the body writes nothing.
    The carry scratch is reset at j == 0, so each lane's fold starts from
    the true zero and replays the identical left-to-right chain -- the
    whole bitwise-across-cores argument lives in that one invariant.
    Crucially the tile total is ALWAYS read off (D + T1)[m-1, m-1], never
    off R, so carry-phase and owned-phase folds of the same block are the
    same f32 ops in the same order.
    """
    ci = pl.program_id(0)
    j = pl.program_id(1)
    start = ci * bpl
    end = start + bpl
    base = jnp.minimum(j, end - 1) * (r * m * m)

    @pl.when(j == 0)
    def _reset():
        carry_ref[0, 0] = jnp.float32(0.0)

    tiles = _load_tiles(x_ref, base, n, r, m, compute_dtype, needs_mask)
    ones = common.ones_mma(m, compute_dtype)
    lower = common.tril_mma(m, jnp.float32, k=-1)
    upper = common.triu_mma(m, compute_dtype, k=0 if inclusive else 1)

    running = carry_ref[0, 0]
    carries, downs = [], []
    for t in range(r):
        t1 = _matmul(tiles[t], ones)
        down = _matmul(lower, t1)
        carries.append(running)
        downs.append(down)
        running = running + (down[m - 1, m - 1] + t1[m - 1, m - 1])

    active = jnp.logical_and(j >= start, j < end)

    @pl.when(active)
    def _emit():
        outs = []
        for t in range(r):
            rowpref = _matmul(tiles[t], upper)
            outs.append(rowpref + downs[t] + carries[t])
        flat = jnp.stack(outs).reshape(r * m, m).astype(out_dtype)
        o_ref[...] = flat.reshape(r * m * m)

    @pl.when(j < end)
    def _advance():
        carry_ref[0, 0] = running


def scan_geometry(n: int, m: int, tiles_per_block: int, num_cores: int):
    """(r, c, blocks_per_lane, padded_tiles) for a scan over n elements --
    ``cost_model.stripe_geometry`` verbatim, with the lane partition
    reinterpreted as contiguous ranges instead of stripes."""
    tiles = max(1, common.ceil_div(n, m * m))
    return cost_model.stripe_geometry(tiles, tiles_per_block, num_cores)


def mma_scan_pallas(
    x: jax.Array,
    *,
    inclusive: bool = True,
    m: int = common.MXU,
    tiles_per_block: int = 8,
    num_cores: int = 1,
    compute_dtype=None,
    interpret: bool | None = None,
    trace: list | None = None,
) -> jax.Array:
    """Single-launch triangular-MMA cumsum of a 1D (or flattened) operand.

    Streams the caller's buffer once at native dtype (non-native ingests
    fall back to one documented f32 pre-cast, like ``ops._ingest``), writes
    the full block-padded prefix array in the storage dtype, and slices it
    back to n -- one ``pallas_call``, no staging, no host combine.
    ``compute_dtype=None`` scans at the ingest dtype itself (an f32 operand
    scans at f32; see the ScanPlan contract -- prefix CONSUMERS read every
    partial result, so the reduce path's default bf16 demotion would be a
    visible precision change, not an internal one).
    """
    flat = x.reshape(-1)
    fallback = ""
    if not common.native_ingest_dtype(flat.dtype):
        flat = flat.astype(jnp.float32)
        fallback = "ingest_f32"
    n = flat.size
    cd = jnp.dtype(flat.dtype if compute_dtype is None else compute_dtype)
    if n == 0:
        if trace is not None:
            trace.append(ScanTrace(n=0, m=m, inclusive=inclusive))
        return jnp.zeros(x.shape, x.dtype)
    r, c, bpl, tpad = scan_geometry(n, m, tiles_per_block, num_cores)
    needs_mask = tpad * m * m != n
    if trace is not None:
        ops_model = cost_model.scan_mma_ops(
            n, m=m, num_cores=num_cores, tiles_per_block=tiles_per_block
        )
        bytes_model = cost_model.scan_hbm_bytes(
            n, flat.dtype.itemsize, m=m, num_cores=num_cores,
            tiles_per_block=tiles_per_block,
        )
        trace.append(ScanTrace(
            n=n, m=m, num_cores=c, mma_ops=ops_model.total,
            lane_mma_ops=ops_model.lane_scan,
            carry_mma_ops=ops_model.carry_worst,
            hbm_bytes=bytes_model.total, inclusive=inclusive,
            fallback=fallback,
        ))
    block = r * m * m
    kernel = functools.partial(
        scan_kernel,
        n=n, r=r, m=m, bpl=bpl, compute_dtype=cd, out_dtype=flat.dtype,
        inclusive=inclusive, needs_mask=needs_mask,
    )
    out = pl.pallas_call(
        kernel,
        grid=(c, c * bpl),
        in_specs=[pl.BlockSpec(
            (block,), lambda ci, j, bpl=bpl: (jnp.minimum(j, (ci + 1) * bpl - 1),)
        )],
        out_specs=pl.BlockSpec(
            (block,),
            lambda ci, j, bpl=bpl: (jnp.clip(j, ci * bpl, (ci + 1) * bpl - 1),),
        ),
        out_shape=jax.ShapeDtypeStruct((tpad * m * m,), flat.dtype),
        scratch_shapes=[common.vmem_scratch((1, 1), jnp.float32)],
        compiler_params=common.compiler_params(("parallel", "arbitrary")),
        interpret=common.resolve_interpret(interpret),
    )(flat)
    return out[:n].reshape(x.shape).astype(x.dtype)


def mma_scan_jnp(
    x: jax.Array,
    *,
    inclusive: bool = True,
    m: int = common.MXU,
    compute_dtype=None,
) -> jax.Array:
    """Triangular-einsum scan over the LAST axis, any rank -- the mma_jnp
    reference semantics and the batched delegate of the Pallas backend.

    Rows are chunked into (k, m) strips; one batched strip @ U einsum
    yields in-strip prefixes, and the strip carry is the exact f32 shifted
    cumsum of strip totals (never ``cumsum - x``, whose re-rounding breaks
    the exclusive contract). Same U-matrix algebra as the kernel, so the
    two agree wherever the einsum batching order does not re-associate --
    which the differential harness checks against the f64 oracle rather
    than bit-for-bit."""
    orig_dtype = x.dtype
    xf = x if common.native_ingest_dtype(x.dtype) else x.astype(jnp.float32)
    cd = jnp.dtype(xf.dtype if compute_dtype is None else compute_dtype)
    length = x.shape[-1]
    if length == 0:
        return jnp.zeros(x.shape, orig_dtype)
    k = common.ceil_div(length, m)
    chunks = common.pad_to(xf, k * m, axis=x.ndim - 1)
    chunks = chunks.reshape(x.shape[:-1] + (k, m)).astype(cd)
    upper = jnp.asarray(common.triu_tile(m, cd.name, 0 if inclusive else 1))
    rowpref = jnp.einsum(
        "...km,mn->...kn", chunks, upper, preferred_element_type=jnp.float32
    )
    totals = rowpref[..., m - 1]
    if not inclusive:
        totals = totals + chunks[..., m - 1].astype(jnp.float32)
    carry = jnp.cumsum(totals, axis=-1)
    carry = jnp.concatenate(
        [jnp.zeros_like(carry[..., :1]), carry[..., :-1]], axis=-1
    )
    out = rowpref + carry[..., None]
    out = out.reshape(x.shape[:-1] + (k * m,))[..., :length]
    return out.astype(orig_dtype)
