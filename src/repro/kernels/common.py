"""Shared Pallas kernel utilities.

All kernels in this package target TPU (pl.pallas_call + BlockSpec VMEM
tiling) and are *validated* on CPU with ``interpret=True`` -- this container
has no TPU. ``resolve_interpret()`` picks the right mode automatically so the
same call sites work in both worlds.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

MXU = 128          # MXU systolic dimension == the paper's m on TPU
LANES = 128        # vreg lane count; last-dim tiling unit
SUBLANES = 8       # vreg sublane count; second-minor tiling unit


def resolve_interpret(interpret: bool | None) -> bool:
    """interpret=None -> True unless we are actually on a TPU backend."""
    if interpret is not None:
        return interpret
    return jax.default_backend() != "tpu"


def ceil_div(a: int, b: int) -> int:
    return -(-a // b)


def round_up(a: int, b: int) -> int:
    return ceil_div(a, b) * b


def pad_to(x: jax.Array, size: int, axis: int = 0, value=0) -> jax.Array:
    pad = size - x.shape[axis]
    if pad <= 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths, constant_values=value)


def compiler_params(dimension_semantics: tuple[str, ...] | None = None):
    """Best-effort TPU compiler params; harmless under interpret mode."""
    if dimension_semantics is None:
        return None
    try:
        return pltpu.CompilerParams(dimension_semantics=dimension_semantics)
    except Exception:  # pragma: no cover - API drift guard
        return None


def vmem_scratch(shape, dtype):
    return pltpu.VMEM(shape, dtype)
