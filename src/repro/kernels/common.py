"""Shared Pallas kernel utilities.

All kernels in this package target TPU (pl.pallas_call + BlockSpec VMEM
tiling) and are *validated* on CPU with ``interpret=True`` -- this container
has no TPU. ``resolve_interpret()`` picks the right mode automatically so the
same call sites work in both worlds.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

MXU = 128          # MXU systolic dimension == the paper's m on TPU
LANES = 128        # vreg lane count; last-dim tiling unit
SUBLANES = 8       # vreg sublane count; second-minor tiling unit

# Dtypes the zero-copy kernels ingest directly from the caller's buffer (the
# MXU's native multiplier widths plus f32). Anything else (f64, ints, bools)
# is pre-cast to f32 by ops.py -- the one documented staging fallback.
NATIVE_INGEST_DTYPES = (jnp.float32, jnp.bfloat16, jnp.float16)


def native_ingest_dtype(dtype) -> bool:
    """True when the Pallas kernels can read this dtype straight from HBM."""
    return any(jnp.dtype(dtype) == jnp.dtype(d) for d in NATIVE_INGEST_DTYPES)


# In-kernel elementwise prologues: the per-element map every reduction kind
# needs, applied INSIDE the kernel body -- after the compute-dtype cast and
# the tail mask, before the eq. (9) MMA -- so sumsq/norm2/moments read the
# caller's raw native-dtype leaf exactly once (single-stream; no host-side
# n-sized square pass or f32 staging write). "moments" is the paired
# (x, x^2) dual-accumulator: the kernels carry a second accumulator and
# emit both statistics from one pass over the data.
PROLOGUES = ("identity", "square", "abs", "moments")

# Prologues apply_prologue can evaluate directly; "moments" is structural
# (it selects the dual-accumulator kernel variant, not a single map).
ELEMENTWISE_PROLOGUES = ("identity", "square", "abs")


def check_prologue(prologue: str, *, allow_moments: bool = True) -> str:
    """Validate a prologue name at trace time (kernels branch statically)."""
    allowed = PROLOGUES if allow_moments else ELEMENTWISE_PROLOGUES
    if prologue not in allowed:
        raise ValueError(
            f"unknown prologue {prologue!r}; expected one of {allowed}"
        )
    return prologue


def normalize_part_prologues(prologue, nseg: int) -> tuple:
    """One validated prologue name per part, from a uniform string or a
    sequence (THE normalization rule for every sum_parts layer -- ops,
    backends, and the api VJPs all share it)."""
    if isinstance(prologue, str):
        return (check_prologue(prologue),) * nseg
    pros = tuple(check_prologue(p) for p in prologue)
    if len(pros) != nseg:
        raise ValueError(f"got {len(pros)} part prologues for {nseg} parts")
    return pros


def apply_prologue(xv: jax.Array, prologue: str) -> jax.Array:
    """Elementwise prologue at compute precision (identity adds NO ops, so
    the kind="sum" path stays op-identical -- and therefore bit-identical --
    to the prologue-free kernels). A masked/padded zero is a fixed point of
    every map here, so tail lanes still contribute exact zeros."""
    if prologue == "identity":
        return xv
    if prologue == "square":
        return xv * xv
    if prologue == "abs":
        return jnp.abs(xv)
    raise ValueError(
        f"prologue {prologue!r} is not elementwise (moments selects the "
        f"dual-accumulator kernel variant); expected one of "
        f"{ELEMENTWISE_PROLOGUES}"
    )


@functools.lru_cache(maxsize=None)
def ones_tile(m: int, dtype_s: str):
    """The all-ones (m, m) MMA operand of eqs. (9)-(12) as a CACHED host
    constant -- for host-side code (the deterministic lane combines), which
    hands the same numpy object to every trace (jnp ops lift it as a
    constant per trace). It must stay numpy: any jnp array built during a
    jit trace is a tracer, and caching a tracer leaks it into later traces.
    Pallas kernel BODIES additionally must not capture concrete arrays at
    all (pallas rejects closed-over constants), so they use ``ones_mma``
    below -- the same single definition, materialized trace-locally."""
    import numpy as np

    return np.ones((m, m), jnp.dtype(dtype_s))


def ones_mma(m: int, dtype) -> jax.Array:
    """Trace-local all-ones (m, m) MMA operand: the one definition kernel
    bodies draw from (safe inside pallas; never captured)."""
    return jnp.ones((m, m), jnp.dtype(dtype))


def resolve_interpret(interpret: bool | None) -> bool:
    """interpret=None -> True unless we are actually on a TPU backend."""
    if interpret is not None:
        return interpret
    return jax.default_backend() != "tpu"


def ceil_div(a: int, b: int) -> int:
    return -(-a // b)


def round_up(a: int, b: int) -> int:
    return ceil_div(a, b) * b


def pad_to(x: jax.Array, size: int, axis: int = 0, value=0) -> jax.Array:
    pad = size - x.shape[axis]
    if pad <= 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths, constant_values=value)


def compiler_params(dimension_semantics: tuple[str, ...] | None = None):
    """Best-effort TPU compiler params; harmless under interpret mode."""
    if dimension_semantics is None:
        return None
    try:
        return pltpu.CompilerParams(dimension_semantics=dimension_semantics)
    except Exception:  # pragma: no cover - API drift guard
        return None


def vmem_scratch(shape, dtype):
    return pltpu.VMEM(shape, dtype)
