"""Shared Pallas kernel utilities.

All kernels in this package target TPU (pl.pallas_call + BlockSpec VMEM
tiling) and are *validated* on CPU with ``interpret=True`` -- this container
has no TPU. ``resolve_interpret()`` picks the right mode automatically so the
same call sites work in both worlds.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

MXU = 128          # MXU systolic dimension == the paper's m on TPU
LANES = 128        # vreg lane count; last-dim tiling unit
SUBLANES = 8       # vreg sublane count; second-minor tiling unit

# Dtypes the zero-copy kernels ingest directly from the caller's buffer (the
# MXU's native multiplier widths plus f32). Anything else (f64, ints, bools)
# is pre-cast to f32 by ops.py -- the one documented staging fallback.
NATIVE_INGEST_DTYPES = (jnp.float32, jnp.bfloat16, jnp.float16)


def native_ingest_dtype(dtype) -> bool:
    """True when the Pallas kernels can read this dtype straight from HBM."""
    return any(jnp.dtype(dtype) == jnp.dtype(d) for d in NATIVE_INGEST_DTYPES)


# In-kernel elementwise prologues: the per-element map every reduction kind
# needs, applied INSIDE the kernel body -- after the compute-dtype cast and
# the tail mask, before the eq. (9) MMA -- so sumsq/norm2/moments read the
# caller's raw native-dtype leaf exactly once (single-stream; no host-side
# n-sized square pass or f32 staging write). "moments" is the paired
# (x, x^2) dual-accumulator: the kernels carry a second accumulator and
# emit both statistics from one pass over the data.
PROLOGUES = ("identity", "square", "abs", "moments")

# Prologues apply_prologue can evaluate directly; "moments" is structural
# (it selects the dual-accumulator kernel variant, not a single map).
ELEMENTWISE_PROLOGUES = ("identity", "square", "abs")


def check_prologue(prologue: str, *, allow_moments: bool = True) -> str:
    """Validate a prologue name at trace time (kernels branch statically)."""
    allowed = PROLOGUES if allow_moments else ELEMENTWISE_PROLOGUES
    if prologue not in allowed:
        raise ValueError(
            f"unknown prologue {prologue!r}; expected one of {allowed}"
        )
    return prologue


def normalize_part_prologues(prologue, nseg: int) -> tuple:
    """One validated prologue name per part, from a uniform string or a
    sequence (THE normalization rule for every sum_parts layer -- ops,
    backends, and the api VJPs all share it)."""
    if isinstance(prologue, str):
        return (check_prologue(prologue),) * nseg
    pros = tuple(check_prologue(p) for p in prologue)
    if len(pros) != nseg:
        raise ValueError(f"got {len(pros)} part prologues for {nseg} parts")
    return pros


def apply_prologue(xv: jax.Array, prologue: str) -> jax.Array:
    """Elementwise prologue at compute precision (identity adds NO ops, so
    the kind="sum" path stays op-identical -- and therefore bit-identical --
    to the prologue-free kernels). A masked/padded zero is a fixed point of
    every map here, so tail lanes still contribute exact zeros."""
    if prologue == "identity":
        return xv
    if prologue == "square":
        return xv * xv
    if prologue == "abs":
        return jnp.abs(xv)
    raise ValueError(
        f"prologue {prologue!r} is not elementwise (moments selects the "
        f"dual-accumulator kernel variant); expected one of "
        f"{ELEMENTWISE_PROLOGUES}"
    )


# In-kernel scalar EPILOGUES: the post-combine chain applied to a REDUCED
# result inside the same launch -- the consumer-side dual of the prologues.
# Where a prologue maps every element before the eq. (9) MMA, an epilogue
# maps the one f32 scalar the reduction produced (sqrt for a norm, the AdamW
# clip coefficient, a mean's 1/n scale), so consumers like the optimizer
# read their statistic straight out of the reduction launch with no host-
# side sqrt/minimum/divide eqns on an n-derived scalar. A chain is a tuple
# of steps; each step is ``(name, *float_params)`` -- fully hashable, so
# chains ride the custom_vjp nondiff arguments exactly like plans do.
EPILOGUES = ("identity", "sqrt", "scale", "rsqrt", "add_eps", "clip_coeff")

# steps that take no parameters / their required parameter counts
_EPILOGUE_ARITY = {
    "identity": (0,),
    "sqrt": (0,),
    "scale": (1,),        # scale(a): t * a
    "rsqrt": (0, 1),      # rsqrt(eps=0): 1 / sqrt(t + eps)
    "add_eps": (1,),      # add_eps(eps): t + eps
    "clip_coeff": (1, 2),  # clip_coeff(max_norm, eps=0): min(1, max/max(t,eps))
}


def _normalize_step(step) -> tuple:
    """One epilogue step -> canonical hashable ``(name, *float_params)``."""
    if isinstance(step, str):
        step = (step,)
    step = tuple(step)
    if not step or not isinstance(step[0], str):
        raise ValueError(f"epilogue step must start with a name: {step!r}")
    name, params = step[0], step[1:]
    if name not in EPILOGUES:
        raise ValueError(
            f"unknown epilogue {name!r}; expected one of {EPILOGUES}"
        )
    if len(params) not in _EPILOGUE_ARITY[name]:
        raise ValueError(
            f"epilogue {name!r} takes {_EPILOGUE_ARITY[name]} parameter(s); "
            f"got {step!r}"
        )
    return (name,) + tuple(float(p) for p in params)


def normalize_epilogue(spec) -> tuple:
    """Canonical hashable chain for one epilogue spec.

    Accepts ``None`` / ``"identity"`` / ``()`` (-> the empty chain: no
    epilogue, the reduction's PR-5 code path byte-for-byte), a single step
    (a name string or a ``(name, *params)`` tuple), or a tuple of steps.
    The empty chain is THE no-epilogue marker every layer branches on."""
    if spec is None or spec == "identity" or spec == ():
        return ()
    if isinstance(spec, str):
        steps = (spec,)
    elif isinstance(spec, tuple) and spec and isinstance(spec[0], str):
        steps = (spec,)  # a single (name, *params) step
    else:
        steps = tuple(spec)
    chain = tuple(_normalize_step(s) for s in steps)
    return tuple(s for s in chain if s[0] != "identity")


def normalize_epilogue_fork(spec) -> tuple:
    """Canonical tuple of chains for a MULTI-OUTPUT epilogue.

    A Python list marks the fork: ``[chain_a, chain_b]`` asks the reduction
    to emit ``len(spec)`` scalars from one launch, chain k applied to the
    same reduced total (the AdamW consumer forks ``[(), clip_coeff]`` into
    ``(gnorm, clip)``). Anything else is a single chain."""
    if isinstance(spec, list):
        if not spec:
            raise ValueError("an epilogue fork needs at least one chain")
        return tuple(normalize_epilogue(c) for c in spec)
    return (normalize_epilogue(spec),)


def apply_epilogue(t: jax.Array, chain: tuple) -> jax.Array:
    """Evaluate an epilogue chain on a reduced f32 scalar (or a vector of
    per-slot totals -- every step is elementwise). Pure jnp scalar math, so
    the SAME definition runs inside a Pallas kernel body (post-flush) and
    host-side (the jnp-level backends' reference semantics); chain params
    are Python floats, which weak-type against the operand and never upcast
    it."""
    for step in chain:
        name, params = step[0], step[1:]
        if name == "sqrt":
            t = jnp.sqrt(t)
        elif name == "scale":
            t = t * params[0]
        elif name == "rsqrt":
            eps = params[0] if params else 0.0
            t = 1.0 / jnp.sqrt(t + eps)
        elif name == "add_eps":
            t = t + params[0]
        elif name == "clip_coeff":
            max_norm = params[0]
            eps = params[1] if len(params) > 1 else 0.0
            t = jnp.minimum(1.0, max_norm / jnp.maximum(t, eps))
        elif name != "identity":  # pragma: no cover - normalize_* rejects
            raise ValueError(f"unknown epilogue {name!r}")
    return t


@functools.lru_cache(maxsize=None)
def ones_tile(m: int, dtype_s: str):
    """The all-ones (m, m) MMA operand of eqs. (9)-(12) as a CACHED host
    constant -- for host-side code (the deterministic lane combines), which
    hands the same numpy object to every trace (jnp ops lift it as a
    constant per trace). It must stay numpy: any jnp array built during a
    jit trace is a tracer, and caching a tracer leaks it into later traces.
    Pallas kernel BODIES additionally must not capture concrete arrays at
    all (pallas rejects closed-over constants), so they use ``ones_mma``
    below -- the same single definition, materialized trace-locally."""
    import numpy as np

    return np.ones((m, m), jnp.dtype(dtype_s))


def ones_mma(m: int, dtype) -> jax.Array:
    """Trace-local all-ones (m, m) MMA operand: the one definition kernel
    bodies draw from (safe inside pallas; never captured)."""
    return jnp.ones((m, m), jnp.dtype(dtype))


@functools.lru_cache(maxsize=None)
def triu_tile(m: int, dtype_s: str, k: int = 0):
    """Upper-triangular ones (m, m) MMA operand as a CACHED host constant:
    the scan encoding's prefix matrix (Dakkak et al. -- x @ U turns each
    tile row into its running inclusive prefix; ``k=1`` is the strictly-
    upper variant for EXCLUSIVE prefixes). numpy for the same reason as
    ``ones_tile``: a cached jnp array would leak a tracer across traces."""
    import numpy as np

    return np.triu(np.ones((m, m), jnp.dtype(dtype_s)), k=k)


@functools.lru_cache(maxsize=None)
def tril_tile(m: int, dtype_s: str, k: int = 0):
    """Lower-triangular ones (m, m) host constant; ``k=-1`` (strict) is the
    scan encoding's carry-down matrix: Ls @ R replicates, into row i, the
    fold of rows < i."""
    import numpy as np

    return np.tril(np.ones((m, m), jnp.dtype(dtype_s)), k=k)


def triu_mma(m: int, dtype, k: int = 0) -> jax.Array:
    """Trace-local upper-triangular ones operand (safe inside pallas kernel
    bodies, which must not capture concrete arrays): built from two iotas,
    exactly how the tail masks are built."""
    row = jax.lax.broadcasted_iota(jnp.int32, (m, m), 0)
    col = jax.lax.broadcasted_iota(jnp.int32, (m, m), 1)
    return (row + k <= col).astype(jnp.dtype(dtype))


def tril_mma(m: int, dtype, k: int = 0) -> jax.Array:
    """Trace-local lower-triangular ones operand (see ``triu_mma``)."""
    row = jax.lax.broadcasted_iota(jnp.int32, (m, m), 0)
    col = jax.lax.broadcasted_iota(jnp.int32, (m, m), 1)
    return (row + k >= col).astype(jnp.dtype(dtype))


def resolve_interpret(interpret: bool | None) -> bool:
    """interpret=None -> True unless we are actually on a TPU backend."""
    if interpret is not None:
        return interpret
    return jax.default_backend() != "tpu"


def ceil_div(a: int, b: int) -> int:
    return -(-a // b)


def round_up(a: int, b: int) -> int:
    return ceil_div(a, b) * b


def pad_to(x: jax.Array, size: int, axis: int = 0, value=0) -> jax.Array:
    pad = size - x.shape[axis]
    if pad <= 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths, constant_values=value)


def compiler_params(dimension_semantics: tuple[str, ...] | None = None):
    """Best-effort TPU compiler params; harmless under interpret mode."""
    if dimension_semantics is None:
        return None
    try:
        return pltpu.CompilerParams(dimension_semantics=dimension_semantics)
    except Exception:  # pragma: no cover - API drift guard
        return None


def vmem_scratch(shape, dtype):
    return pltpu.VMEM(shape, dtype)
