"""Shared Pallas kernel utilities.

All kernels in this package target TPU (pl.pallas_call + BlockSpec VMEM
tiling) and are *validated* on CPU with ``interpret=True`` -- this container
has no TPU. ``resolve_interpret()`` picks the right mode automatically so the
same call sites work in both worlds.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

MXU = 128          # MXU systolic dimension == the paper's m on TPU
LANES = 128        # vreg lane count; last-dim tiling unit
SUBLANES = 8       # vreg sublane count; second-minor tiling unit

# Dtypes the zero-copy kernels ingest directly from the caller's buffer (the
# MXU's native multiplier widths plus f32). Anything else (f64, ints, bools)
# is pre-cast to f32 by ops.py -- the one documented staging fallback.
NATIVE_INGEST_DTYPES = (jnp.float32, jnp.bfloat16, jnp.float16)


def native_ingest_dtype(dtype) -> bool:
    """True when the Pallas kernels can read this dtype straight from HBM."""
    return any(jnp.dtype(dtype) == jnp.dtype(d) for d in NATIVE_INGEST_DTYPES)


@functools.lru_cache(maxsize=None)
def ones_tile(m: int, dtype_s: str):
    """The all-ones (m, m) MMA operand of eqs. (9)-(12) as a CACHED host
    constant -- for host-side code (the deterministic lane combines), which
    hands the same numpy object to every trace (jnp ops lift it as a
    constant per trace). It must stay numpy: any jnp array built during a
    jit trace is a tracer, and caching a tracer leaks it into later traces.
    Pallas kernel BODIES additionally must not capture concrete arrays at
    all (pallas rejects closed-over constants), so they use ``ones_mma``
    below -- the same single definition, materialized trace-locally."""
    import numpy as np

    return np.ones((m, m), jnp.dtype(dtype_s))


def ones_mma(m: int, dtype) -> jax.Array:
    """Trace-local all-ones (m, m) MMA operand: the one definition kernel
    bodies draw from (safe inside pallas; never captured)."""
    return jnp.ones((m, m), jnp.dtype(dtype))


def resolve_interpret(interpret: bool | None) -> bool:
    """interpret=None -> True unless we are actually on a TPU backend."""
    if interpret is not None:
        return interpret
    return jax.default_backend() != "tpu"


def ceil_div(a: int, b: int) -> int:
    return -(-a // b)


def round_up(a: int, b: int) -> int:
    return ceil_div(a, b) * b


def pad_to(x: jax.Array, size: int, axis: int = 0, value=0) -> jax.Array:
    pad = size - x.shape[axis]
    if pad <= 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths, constant_values=value)


def compiler_params(dimension_semantics: tuple[str, ...] | None = None):
    """Best-effort TPU compiler params; harmless under interpret mode."""
    if dimension_semantics is None:
        return None
    try:
        return pltpu.CompilerParams(dimension_semantics=dimension_semantics)
    except Exception:  # pragma: no cover - API drift guard
        return None


def vmem_scratch(shape, dtype):
    return pltpu.VMEM(shape, dtype)
