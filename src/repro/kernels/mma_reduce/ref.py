"""Pure-jnp oracles for the MMA reduction kernel.

``sum_ref`` is the ground-truth contract (f32 accumulation). ``two_mma_ref``
emulates the paper's eq. (9)-(12) tile algebra exactly (including the bf16
multiplier precision), so kernel partials can be checked step-for-step, not
just end-to-end.

Masked-tail model: the zero-copy kernels read the caller's buffer in its
NATIVE dtype and zero the ragged tail in-VMEM (``broadcasted_iota`` mask
applied after the compute-dtype cast). A masked lane contributes an exact
compute-dtype zero to the MMA -- indistinguishable from a zero-padded
element -- so these emulations model the masked loads by zero-padding the
native buffer and casting native -> compute DIRECTLY (never through a
staged f32 round-trip; for every native dtype that round-trip is
value-identical, which is exactly why the staging copy could be deleted).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import common


def sum_ref(x: jax.Array) -> jax.Array:
    """Ground truth: full-precision sum."""
    return jnp.sum(x.astype(jnp.float32))


def two_mma_ref(
    tiles: jax.Array, compute_dtype=jnp.bfloat16, accum_dtype=jnp.float32
) -> jax.Array:
    """Eq. (9)-(12) on a batch of (k, m, m) tiles -> (k,) group sums."""
    m = tiles.shape[-1]
    ones = jnp.ones((m, m), compute_dtype)
    d = jnp.einsum(
        "kij,jl->kil",
        tiles.astype(compute_dtype),
        ones,
        preferred_element_type=accum_dtype,
    )
    d2 = jnp.einsum(
        "ij,kjl->kil",
        ones,
        d.astype(compute_dtype),
        preferred_element_type=accum_dtype,
    )
    return d2[:, 0, 0]


def segmented_sum_ref(flat: jax.Array, offsets) -> jax.Array:
    """Ground truth for the segmented kernel: per-segment f32 sums."""
    return jnp.stack(
        [
            jnp.sum(flat[offsets[s] : offsets[s + 1]].astype(jnp.float32))
            for s in range(len(offsets) - 1)
        ]
    ) if len(offsets) > 1 else jnp.zeros((0,), jnp.float32)


def _native_tiles(x: jax.Array, tpad: int, m: int) -> jax.Array:
    """(n,) native buffer -> (tpad, m, m) tiles, tail zero-padded.

    Models the kernels' masked boundary loads: pad-with-zero and
    mask-to-zero are value-identical once the zeros are exact in the
    compute dtype (they are -- the kernels mask AFTER the cast)."""
    flat = x.reshape(-1)
    if not common.native_ingest_dtype(flat.dtype):
        flat = flat.astype(jnp.float32)  # ops._ingest's documented fallback
    flat = jnp.pad(flat, (0, tpad * m * m - flat.size))
    return flat.reshape(tpad, m, m)


def fused_lanes_ref(
    x: jax.Array,
    *,
    tiles_per_block: int = 8,
    num_cores: int = 1,
    compute_dtype=jnp.bfloat16,
    m: int = 128,
) -> jax.Array:
    """Bit-exact jnp emulation of the striped fused kernel's lane partials.

    Mirrors the kernel op-for-op -- same striping (lane c owns blocks
    c, c+C, ...), same native -> compute cast, same masked-tail zeros
    (modeled as zero-pad; see module docstring), same batched D = X @ 1 per
    block, same f32 block fold -- so ``reduce_fused`` under interpret mode
    must match it bit-for-bit, which pins the whole lane geometry
    (striping + padding + carry), the zero-copy ingestion contract, and
    the ``num_cores=1`` backward-compatibility story.
    """
    from repro.kernels.mma_reduce.kernel import _lane_geometry

    group = m * m
    k = max(1, -(-x.size // group))
    r, c, bpl, tpad = _lane_geometry(k, tiles_per_block, num_cores)
    tiles = _native_tiles(x, tpad, m)
    ones = jnp.ones((m, m), compute_dtype)
    lanes = []
    for ci in range(c):
        acc = jnp.zeros((m, m), jnp.float32)
        for j in range(bpl):
            block = tiles[(j * c + ci) * r : (j * c + ci + 1) * r]
            d = jax.lax.dot_general(
                block.astype(compute_dtype),
                jnp.broadcast_to(ones, block.shape),
                (((2,), (1,)), ((0,), (0,))),
                preferred_element_type=jnp.float32,
            )
            acc = acc + jnp.sum(d, axis=0)
        lanes.append(acc)
    return jnp.stack(lanes)


def hierarchy_ref(x: jax.Array, m: int = 128) -> jax.Array:
    """The full recurrence (eq. 13) in jnp -- matches the kernel's
    'hierarchical' mode bit-for-bit at each level boundary. Level 0 casts
    native -> compute directly (the in-kernel cast); upper levels run on
    the f32 partials, exactly like the relaunched kernel."""
    flat = x.reshape(-1)
    if not common.native_ingest_dtype(flat.dtype):
        flat = flat.astype(jnp.float32)
    group = m * m
    while flat.size > 1:
        k = -(-flat.size // group)
        flat = jnp.pad(flat, (0, k * group - flat.size))
        flat = two_mma_ref(flat.reshape(k, m, m))
    return flat.reshape(())


def parts_sum_ref(parts) -> jax.Array:
    """Ground truth for the parts kernel: per-part f32 totals in order."""
    if not parts:
        return jnp.zeros((0,), jnp.float32)
    return jnp.stack([sum_ref(jnp.asarray(p)) for p in parts])
