"""Pure-jnp oracles for the MMA reduction kernel.

``sum_ref`` is the ground-truth contract (f32 accumulation). ``two_mma_ref``
emulates the paper's eq. (9)-(12) tile algebra exactly (including the bf16
multiplier precision), so kernel partials can be checked step-for-step, not
just end-to-end.

Masked-tail model: the zero-copy kernels read the caller's buffer in its
NATIVE dtype and zero the ragged tail in-VMEM (``broadcasted_iota`` mask
applied after the compute-dtype cast). A masked lane contributes an exact
compute-dtype zero to the MMA -- indistinguishable from a zero-padded
element -- so these emulations model the masked loads by zero-padding the
native buffer and casting native -> compute DIRECTLY (never through a
staged f32 round-trip; for every native dtype that round-trip is
value-identical, which is exactly why the staging copy could be deleted).

Prologue bit-compat contract: the in-kernel elementwise prologues are
emulated at the same point the kernels apply them (after the compute-dtype
cast, before the MMA). With f32 compute -- the default plan for
sumsq/norm2 -- and for precision-exact maps at any width (identity, abs:
no rounding), kernel and emulation agree BIT-FOR-BIT. A bf16/f16-compute
SQUARE is the one case XLA's excess-precision rules leave open: the
multiply may retain f32 precision inside one fusion and round in another,
so kernel-vs-emulation agreement there is within one compute-dtype
rounding per element, not bitwise (tests/harness.py encodes exactly this
contract).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import common


def sum_ref(x: jax.Array) -> jax.Array:
    """Ground truth: full-precision sum."""
    return jnp.sum(x.astype(jnp.float32))


def prologue_ref(xv: jax.Array, prologue: str) -> jax.Array:
    """The in-kernel elementwise prologue, applied at whatever precision
    ``xv`` already carries (the kernels apply it AFTER the compute-dtype
    cast; emulations must do the same to stay bit-exact)."""
    return common.apply_prologue(xv, prologue)


def prologue_sum_ref(x: jax.Array, prologue: str = "identity") -> jax.Array:
    """Ground truth for one prologue'd full reduction: map at f32, sum at
    f32 (``"moments"`` -> the (sum, sumsq) pair)."""
    xf = x.astype(jnp.float32)
    if prologue == "moments":
        return jnp.sum(xf), jnp.sum(xf * xf)
    return jnp.sum(common.apply_prologue(xf, prologue))


def two_mma_ref(
    tiles: jax.Array, compute_dtype=jnp.bfloat16, accum_dtype=jnp.float32
) -> jax.Array:
    """Eq. (9)-(12) on a batch of (k, m, m) tiles -> (k,) group sums."""
    m = tiles.shape[-1]
    ones = jnp.ones((m, m), compute_dtype)
    d = jnp.einsum(
        "kij,jl->kil",
        tiles.astype(compute_dtype),
        ones,
        preferred_element_type=accum_dtype,
    )
    d2 = jnp.einsum(
        "ij,kjl->kil",
        ones,
        d.astype(compute_dtype),
        preferred_element_type=accum_dtype,
    )
    return d2[:, 0, 0]


def segmented_sum_ref(
    flat: jax.Array, offsets, prologue: str = "identity"
) -> jax.Array:
    """Ground truth for the segmented kernel: per-segment f32 sums of the
    prologue'd elements ("moments": sums in [0, S), sumsqs in [S, 2S) --
    the kernel's widened output layout)."""
    if len(offsets) <= 1:
        return jnp.zeros((0,), jnp.float32)
    segs = [
        flat[offsets[s] : offsets[s + 1]].astype(jnp.float32)
        for s in range(len(offsets) - 1)
    ]
    if prologue == "moments":
        return jnp.stack(
            [jnp.sum(s) for s in segs] + [jnp.sum(s * s) for s in segs]
        )
    return jnp.stack(
        [jnp.sum(common.apply_prologue(s, prologue)) for s in segs]
    )


def _native_tiles(x: jax.Array, tpad: int, m: int) -> jax.Array:
    """(n,) native buffer -> (tpad, m, m) tiles, tail zero-padded.

    Models the kernels' masked boundary loads: pad-with-zero and
    mask-to-zero are value-identical once the zeros are exact in the
    compute dtype (they are -- the kernels mask AFTER the cast)."""
    flat = x.reshape(-1)
    if not common.native_ingest_dtype(flat.dtype):
        flat = flat.astype(jnp.float32)  # ops._ingest's documented fallback
    flat = jnp.pad(flat, (0, tpad * m * m - flat.size))
    return flat.reshape(tpad, m, m)


def fused_lanes_ref(
    x: jax.Array,
    *,
    tiles_per_block: int = 8,
    num_cores: int = 1,
    compute_dtype=jnp.bfloat16,
    m: int = 128,
    prologue: str = "identity",
) -> jax.Array:
    """Bit-exact jnp emulation of the striped fused kernel's lane partials.

    Mirrors the kernel op-for-op -- same striping (lane c owns blocks
    c, c+C, ...), same native -> compute cast, same masked-tail zeros
    (modeled as zero-pad; see module docstring), same in-kernel prologue
    (applied AFTER the cast, exactly where the kernel applies it), same
    batched D = X @ 1 per block, same f32 block fold -- so ``reduce_fused``
    under interpret mode must match it bit-for-bit, which pins the whole
    lane geometry (striping + padding + carry), the zero-copy ingestion
    contract, and the ``num_cores=1`` backward-compatibility story.
    ``prologue="moments"`` returns the kernel's (C, 2, m, m) accumulator
    pairs.
    """
    from repro.kernels.mma_reduce.kernel import _lane_geometry

    group = m * m
    k = max(1, -(-x.size // group))
    r, c, bpl, tpad = _lane_geometry(k, tiles_per_block, num_cores)
    tiles = _native_tiles(x, tpad, m)
    ones = jnp.ones((m, m), compute_dtype)
    dual = prologue == "moments"
    lanes = []
    for ci in range(c):
        acc = jnp.zeros((m, m), jnp.float32)
        acc2 = jnp.zeros((m, m), jnp.float32)
        for j in range(bpl):
            block = tiles[(j * c + ci) * r : (j * c + ci + 1) * r]
            bv = block.astype(compute_dtype)

            def _fold(v, into):
                d = jax.lax.dot_general(
                    v,
                    jnp.broadcast_to(ones, v.shape),
                    (((2,), (1,)), ((0,), (0,))),
                    preferred_element_type=jnp.float32,
                )
                return into + jnp.sum(d, axis=0)

            if dual:
                acc = _fold(bv, acc)
                acc2 = _fold(bv * bv, acc2)
            else:
                acc = _fold(prologue_ref(bv, prologue), acc)
        lanes.append(jnp.stack([acc, acc2]) if dual else acc)
    return jnp.stack(lanes)


def scan_ref(
    x: jax.Array,
    *,
    inclusive: bool = True,
    tiles_per_block: int = 8,
    num_cores: int = 1,
    compute_dtype=None,
    m: int = 128,
) -> jax.Array:
    """Op-for-op jnp emulation of ``kernels.scan.scan_kernel``.

    Mirrors the triangular kernel exactly -- same contiguous lane ranges,
    same native -> compute cast and masked-tail zeros (modeled as zero-pad;
    see module docstring), same per-tile T1 = X @ J / D = Ls @ T1 fold with
    the tile total read off the (D + T1) corner (NEVER off R), same f32
    carry chain replayed from zero per lane, same R = X @ U emission on
    owned blocks only -- so ``mma_scan_pallas`` under interpret mode must
    match it bit-for-bit at every ``num_cores``, which pins the contiguous
    lane partition, the carry-rebuild redundancy, and the bitwise-across-
    cores contract in one oracle."""
    from repro.kernels.scan import _matmul, scan_geometry

    flat = x.reshape(-1)
    if not common.native_ingest_dtype(flat.dtype):
        flat = flat.astype(jnp.float32)
    n = flat.size
    cd = jnp.dtype(flat.dtype if compute_dtype is None else compute_dtype)
    if n == 0:
        return jnp.zeros(x.shape, x.dtype)
    r, c, bpl, tpad = scan_geometry(n, m, tiles_per_block, num_cores)
    tiles = _native_tiles(flat, tpad, m).astype(cd)
    ones = jnp.asarray(common.ones_tile(m, cd.name))
    lower = jnp.asarray(common.tril_tile(m, "float32", -1))
    upper = jnp.asarray(common.triu_tile(m, cd.name, 0 if inclusive else 1))
    out_blocks = [None] * (c * bpl)
    for ci in range(c):
        running = jnp.float32(0.0)
        for j in range((ci + 1) * bpl):           # carry rebuild + owned range
            owned = j >= ci * bpl
            outs = []
            for t in range(r):
                tile = tiles[j * r + t]
                t1 = _matmul(tile, ones)
                down = _matmul(lower, t1)
                if owned:
                    rowpref = _matmul(tile, upper)
                    outs.append(rowpref + down + running)
                running = running + (down[m - 1, m - 1] + t1[m - 1, m - 1])
            if owned:
                out_blocks[j] = (
                    jnp.stack(outs).reshape(r * m * m).astype(flat.dtype)
                )
    out = jnp.concatenate(out_blocks)
    return out[:n].reshape(x.shape).astype(x.dtype)


def hierarchy_ref(
    x: jax.Array,
    m: int = 128,
    prologue: str = "identity",
    compute_dtype=jnp.bfloat16,
) -> jax.Array:
    """The full recurrence (eq. 13) in jnp -- matches the kernel's
    'hierarchical' mode bit-for-bit at each level boundary. Level 0 casts
    native -> compute directly (the in-kernel cast) and applies the
    elementwise ``prologue`` AFTER that cast, exactly like the kernel;
    upper levels run on the f32 partials with the identity map, exactly
    like the relaunched kernel. ``prologue="moments"`` returns the
    (sum, sumsq) scalar pair (level 0 emits the partial pair; each column
    recurses independently)."""
    flat = x.reshape(-1)
    if not common.native_ingest_dtype(flat.dtype):
        flat = flat.astype(jnp.float32)
    group = m * m

    def _level(v, pro):
        k = -(-v.size // group)
        tiles = jnp.pad(v, (0, k * group - v.size)).reshape(k, m, m)
        tiles = prologue_ref(
            tiles.astype(compute_dtype), pro
        ) if pro != "identity" else tiles
        return two_mma_ref(tiles, compute_dtype=compute_dtype)

    def _collapse(v):
        while v.size > 1:
            v = _level(v, "identity")
        return v.reshape(())

    if prologue == "moments":
        k = -(-flat.size // group)
        tiles = jnp.pad(flat, (0, k * group - flat.size)).reshape(k, m, m)
        tv = tiles.astype(compute_dtype)
        s = two_mma_ref(tv, compute_dtype=compute_dtype)
        ss = two_mma_ref(tv * tv, compute_dtype=compute_dtype)
        return _collapse(s), _collapse(ss)
    flat = _level(flat, prologue)
    return _collapse(flat)


def parts_sum_ref(parts, prologues=None) -> jax.Array:
    """Ground truth for the parts kernel: per-part f32 totals in order
    (``prologues`` maps each part at f32). If ANY part carries "moments"
    the layout widens to the kernel's (2S,): slot s holds part s's mapped
    sum, slot S + s its sum of squares (the additive identity 0 for
    non-moments parts -- their square slot is never written)."""
    if not parts:
        return jnp.zeros((0,), jnp.float32)
    if prologues is None:
        prologues = ("identity",) * len(parts)
    head, tail = [], []
    for p, pro in zip(parts, prologues):
        xf = jnp.asarray(p).astype(jnp.float32)
        if pro == "moments":
            head.append(jnp.sum(xf))
            tail.append(jnp.sum(xf * xf))
        else:
            head.append(jnp.sum(common.apply_prologue(xf, pro)))
            tail.append(jnp.zeros((), jnp.float32))
    if "moments" not in prologues:
        return jnp.stack(head)
    return jnp.stack(head + tail)
