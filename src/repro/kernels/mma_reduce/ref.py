"""Pure-jnp oracles for the MMA reduction kernel.

``sum_ref`` is the ground-truth contract (f32 accumulation). ``two_mma_ref``
emulates the paper's eq. (9)-(12) tile algebra exactly (including the bf16
multiplier precision), so kernel partials can be checked step-for-step, not
just end-to-end.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def sum_ref(x: jax.Array) -> jax.Array:
    """Ground truth: full-precision sum."""
    return jnp.sum(x.astype(jnp.float32))


def two_mma_ref(
    tiles: jax.Array, compute_dtype=jnp.bfloat16, accum_dtype=jnp.float32
) -> jax.Array:
    """Eq. (9)-(12) on a batch of (k, m, m) tiles -> (k,) group sums."""
    m = tiles.shape[-1]
    ones = jnp.ones((m, m), compute_dtype)
    d = jnp.einsum(
        "kij,jl->kil",
        tiles.astype(compute_dtype),
        ones,
        preferred_element_type=accum_dtype,
    )
    d2 = jnp.einsum(
        "ij,kjl->kil",
        ones,
        d.astype(compute_dtype),
        preferred_element_type=accum_dtype,
    )
    return d2[:, 0, 0]


def segmented_sum_ref(flat: jax.Array, offsets) -> jax.Array:
    """Ground truth for the segmented kernel: per-segment f32 sums."""
    return jnp.stack(
        [
            jnp.sum(flat[offsets[s] : offsets[s + 1]].astype(jnp.float32))
            for s in range(len(offsets) - 1)
        ]
    ) if len(offsets) > 1 else jnp.zeros((0,), jnp.float32)


def hierarchy_ref(x: jax.Array, m: int = 128) -> jax.Array:
    """The full recurrence (eq. 13) in jnp -- matches the kernel's
    'hierarchical' mode bit-for-bit at each level boundary."""
    flat = x.reshape(-1).astype(jnp.float32)
    group = m * m
    while flat.size > 1:
        k = -(-flat.size // group)
        flat = jnp.pad(flat, (0, k * group - flat.size))
        flat = two_mma_ref(flat.reshape(k, m, m))
    return flat.reshape(())
