"""Public jit'd entry points for the MMA reduction kernels."""

from __future__ import annotations

import functools
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import common
from repro.kernels.mma_reduce import kernel as _k

MXU = common.MXU


def _to_tiles(x: jax.Array, m: int) -> jax.Array:
    flat = x.reshape(-1).astype(jnp.float32)
    group = m * m
    k = max(1, common.ceil_div(flat.size, group))
    flat = common.pad_to(flat, k * group)
    return flat.reshape(k, m, m)


def mma_sum_pallas(
    x: jax.Array,
    *,
    mode: str = "fused",
    tiles_per_block: int = 8,
    compute_dtype=jnp.bfloat16,
    interpret: bool | None = None,
) -> jax.Array:
    """Sum all elements of ``x`` on the MXU.

    mode="hierarchical": the paper's multi-launch recurrence (eq. 13) --
      each level is one pallas_call producing per-group partials.
    mode="fused": single launch using the MMA C-accumulator (beyond-paper).
    """
    if x.size == 0:
        # Empty reduction -> additive identity (matches mma_sum / jnp.sum).
        return jnp.zeros((), jnp.float32)
    if mode == "fused":
        tiles = _to_tiles(x, MXU)
        return _k.reduce_fused(
            tiles,
            tiles_per_block=tiles_per_block,
            compute_dtype=compute_dtype,
            interpret=interpret,
        )
    if mode != "hierarchical":
        raise ValueError(f"unknown mode {mode!r}")
    flat = x.reshape(-1).astype(jnp.float32)
    while flat.size > 1:
        tiles = _to_tiles(flat, MXU)
        flat = _k.reduce_tiles(
            tiles,
            tiles_per_block=tiles_per_block,
            compute_dtype=compute_dtype,
            interpret=interpret,
        )
    return flat.reshape(())


def segment_tile_layout(
    offsets: Sequence[int], group: int
) -> tuple[tuple[int, ...], np.ndarray, np.ndarray]:
    """Static tile bookkeeping for a segmented stream.

    Returns ``(tile_counts, seg_of_tile, flush_tile)``: per-segment tile
    counts (``ceil(size/group)``, 0 for empty segments), the tile->segment id
    map, and the boundary-flag map (1 on the last tile of each non-empty
    segment). All trace-time numpy -- segment offsets are static.
    """
    sizes = np.diff(np.asarray(offsets, np.int64))
    tcounts = tuple(int(-(-s // group)) if s > 0 else 0 for s in sizes)
    total = sum(tcounts)
    seg_of = np.zeros((total,), np.int32)
    flush = np.zeros((total,), np.int32)
    pos = 0
    for s, tc in enumerate(tcounts):
        if tc == 0:
            continue
        seg_of[pos : pos + tc] = s
        flush[pos + tc - 1] = 1
        pos += tc
    return tcounts, seg_of, flush


def mma_sum_segments_pallas(
    flat: jax.Array,
    offsets: Sequence[int],
    *,
    tiles_per_block: int = 8,
    compute_dtype=jnp.bfloat16,
    interpret: bool | None = None,
) -> jax.Array:
    """Sum S independent segments of ``flat`` in ONE kernel launch.

    ``offsets`` (static ints, len S+1) delimit the segments:
    ``out[s] = sum(flat[offsets[s]:offsets[s+1]])``. Each segment is padded
    to whole (MXU, MXU) tiles and the concatenated tile stream runs through
    the segmented C-accumulator kernel -- n/m^2 + S MMAs total, versus S
    launches of the fused kernel (and versus ~2.008 n/m^2 MMAs *per segment*
    for the paper's hierarchy). Empty segments cost no tiles and come back
    as the additive identity.
    """
    nseg = len(offsets) - 1
    if nseg <= 0:
        return jnp.zeros((0,), jnp.float32)
    flat = flat.reshape(-1).astype(jnp.float32)
    group = MXU * MXU
    tcounts, seg_of, flush = segment_tile_layout(offsets, group)
    t = sum(tcounts)
    if t == 0:  # every segment empty
        return jnp.zeros((nseg,), jnp.float32)
    parts = []
    for s, tc in enumerate(tcounts):
        if tc == 0:
            continue
        seg = jax.lax.slice(flat, (offsets[s],), (offsets[s + 1],))
        parts.append(common.pad_to(seg, tc * group))
    stream = jnp.concatenate(parts) if len(parts) > 1 else parts[0]
    r = min(tiles_per_block, t)
    tpad = common.round_up(t, r)
    stream = common.pad_to(stream, tpad * group)
    seg_of = common.pad_to(np.asarray(seg_of), tpad, axis=0)
    flush = common.pad_to(np.asarray(flush), tpad, axis=0)
    return _k.reduce_segments(
        stream.reshape(tpad, MXU, MXU),
        seg_of,
        flush,
        nseg,
        tiles_per_block=r,
        compute_dtype=compute_dtype,
        interpret=interpret,
    )


@functools.partial(jax.custom_vjp, nondiff_argnums=(1,))
def mma_sum_pallas_diff(x: jax.Array, mode: str = "fused") -> jax.Array:
    return mma_sum_pallas(x, mode=mode)


def _fwd(x, mode):
    return mma_sum_pallas(x, mode=mode), jnp.zeros((0,) + x.shape, x.dtype)


def _bwd(mode, res, g):
    return (jnp.broadcast_to(g, res.shape[1:]).astype(res.dtype),)


mma_sum_pallas_diff.defvjp(_fwd, _bwd)
