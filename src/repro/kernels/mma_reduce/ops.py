"""Public jit'd entry points for the MMA reduction kernels.

This layer owns everything the kernels keep static: tile/layout bookkeeping,
the lane-striping geometry for the multi-core grid, the lane-aware segment
flush maps, and the DETERMINISTIC lane combines. The combines run as plain
f32 XLA dots in a fixed lane order -- never an atomic or a
scheduling-dependent tree -- so every reduction is bit-reproducible
run-to-run regardless of how many cores streamed the partials.
"""

from __future__ import annotations

import functools
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.mma_reduce import ReductionTrace
from repro.kernels import common
from repro.kernels.mma_reduce import kernel as _k

MXU = common.MXU


def _to_tiles(x: jax.Array, m: int) -> jax.Array:
    flat = x.reshape(-1).astype(jnp.float32)
    group = m * m
    k = max(1, common.ceil_div(flat.size, group))
    flat = common.pad_to(flat, k * group)
    return flat.reshape(k, m, m)


def combine_lane_partials(partials: jax.Array) -> jax.Array:
    """(C, m, m) column-replicated lane accumulators -> scalar, fixed order.

    Two dots, both f32: one batched trailing MMA collapses each lane's
    accumulated row-sums (1 x acc, the fused kernel's old finalize step),
    then a single length-C all-ones dot folds the lane scalars in lane
    order. Everything is a static-order f32 contraction, so the result is
    bit-reproducible run-to-run; with C = 1 the second dot multiplies by
    1.0 and the value is bit-identical to the pre-striping kernel's.
    """
    c, m, _ = partials.shape
    onesf = jnp.ones((m, m), jnp.float32)
    d = jax.lax.dot_general(
        jnp.broadcast_to(onesf, partials.shape),
        partials,
        (((2,), (1,)), ((0,), (0,))),
        preferred_element_type=jnp.float32,
    )
    lane = d[:, 0, 0]  # (C,) per-lane totals
    return jnp.dot(
        jnp.ones((c,), jnp.float32), lane, preferred_element_type=jnp.float32
    )


def combine_lane_partials_kahan(partials: jax.Array) -> jax.Array:
    """(C, 2, m, m) (acc, comp) lane pairs -> scalar via one compensated pass.

    Kahan's corrected sum is ``s - c``; we fold, in fixed lane order, each
    lane's accumulator rows followed by its negated compensation rows
    through one serial Kahan scan, so the cross-lane AND cross-row combine
    are both compensated and deterministic.
    """
    from repro.core import precision as _precision

    acc = partials[:, 0, :, 0]  # (C, m): column 0 carries the row sums
    comp = partials[:, 1, :, 0]
    v = jnp.stack([acc, -comp], axis=1).reshape(-1)
    return _precision.kahan_sum(v, dtype=jnp.float32)


def combine_segment_partials(sub: jax.Array) -> jax.Array:
    """(C, S) lane sub-partials -> (S,) per-segment totals, fixed lane order.

    One exact-order f32 add per lane per segment (C is tiny); with C = 1
    this is the identity on the kernel's output bits.
    """
    return jnp.sum(sub, axis=0)


def mma_sum_pallas(
    x: jax.Array,
    *,
    mode: str = "fused",
    tiles_per_block: int = 8,
    num_cores: int = 1,
    compute_dtype=jnp.bfloat16,
    kahan: bool = False,
    interpret: bool | None = None,
    trace: Optional[list] = None,
) -> jax.Array:
    """Sum all elements of ``x`` on the MXU.

    mode="hierarchical": the paper's multi-launch recurrence (eq. 13) --
      each level is one pallas_call producing per-group partials (the grid
      is ``parallel``: every core reduces its own tiles concurrently).
    mode="fused": single launch using the MMA C-accumulator, striped across
      ``num_cores`` lanes of a ("parallel", "arbitrary") grid; the lane
      partials collapse through the deterministic fixed-order combine.
      ``kahan=True`` carries a per-lane compensation row in a second VMEM
      scratch (single launch, compensated cross-tile carry).

    ``trace``: optional list; a ``ReductionTrace`` with the per-lane /
    combine MMA split is appended (Python metadata only).
    """
    if x.size == 0:
        # Empty reduction -> additive identity (matches mma_sum / jnp.sum).
        if trace is not None:
            trace.append(ReductionTrace(n=0, m=MXU, levels=0, mma_ops=0))
        return jnp.zeros((), jnp.float32)
    if mode == "fused":
        tiles = _to_tiles(x, MXU)
        if trace is not None:
            trace.append(fused_trace(int(x.size), tiles_per_block, num_cores))
        partials = _k.reduce_fused(
            tiles,
            tiles_per_block=tiles_per_block,
            num_cores=num_cores,
            compute_dtype=compute_dtype,
            kahan=kahan,
            interpret=interpret,
        )
        if kahan:
            return combine_lane_partials_kahan(partials)
        return combine_lane_partials(partials)
    if mode != "hierarchical":
        raise ValueError(f"unknown mode {mode!r}")
    if kahan:
        raise ValueError(
            "kahan=True needs the fused carry; the hierarchical mode "
            "round-trips partials through HBM between launches"
        )
    flat = x.reshape(-1).astype(jnp.float32)
    n0, levels, mma_ops = flat.size, 0, 0
    while flat.size > 1:
        tiles = _to_tiles(flat, MXU)
        flat = _k.reduce_tiles(
            tiles,
            tiles_per_block=tiles_per_block,
            compute_dtype=compute_dtype,
            interpret=interpret,
        )
        levels += 1
        mma_ops += 2 * tiles.shape[0]
    if trace is not None:
        trace.append(
            ReductionTrace(n=n0, m=MXU, levels=levels, mma_ops=mma_ops)
        )
    return flat.reshape(())


def fused_trace(
    n: int, tiles_per_block: int = 8, num_cores: int = 1
) -> ReductionTrace:
    """Static per-lane / combine MMA instrumentation for one fused pass."""
    k = max(1, common.ceil_div(n, MXU * MXU))
    _, c, _, tpad = _k._lane_geometry(k, tiles_per_block, num_cores)
    lane = tpad // c
    combine = c + 1
    return ReductionTrace(
        n=n,
        m=MXU,
        levels=1,
        mma_ops=tpad + combine,
        num_cores=c,
        lane_mma_ops=lane,
        combine_mma_ops=combine,
    )


def segment_tile_layout(
    offsets: Sequence[int], group: int
) -> tuple[tuple[int, ...], np.ndarray, np.ndarray]:
    """Static tile bookkeeping for a segmented stream.

    Returns ``(tile_counts, seg_of_tile, flush_tile)``: per-segment tile
    counts (``ceil(size/group)``, 0 for empty segments), the tile->segment id
    map, and the SERIAL boundary-flag map (1 on the last tile of each
    non-empty segment -- the ``num_cores=1`` flush map; striped lanes use
    ``lane_flush_map``). All trace-time numpy -- segment offsets are static.
    """
    sizes = np.diff(np.asarray(offsets, np.int64))
    tcounts = tuple(int(-(-s // group)) if s > 0 else 0 for s in sizes)
    total = sum(tcounts)
    seg_of = np.zeros((total,), np.int32)
    flush = np.zeros((total,), np.int32)
    pos = 0
    for s, tc in enumerate(tcounts):
        if tc == 0:
            continue
        seg_of[pos : pos + tc] = s
        flush[pos + tc - 1] = 1
        pos += tc
    return tcounts, seg_of, flush


def lane_flush_map(
    seg_of: np.ndarray, tiles_per_block: int, num_cores: int
) -> np.ndarray:
    """Lane-aware flush flags for a striped segmented stream (trace-time).

    Lane ``ci`` of a C-lane grid streams blocks ``ci, ci+C, ci+2C, ...`` --
    so the tiles it visits are interleaved with the other lanes'. A lane
    must flush its accumulator whenever ITS OWN stripe leaves a segment:
    flag position p iff p is the last tile of its segment within the stripe
    that owns it. With C = 1 this reduces exactly to the serial
    last-tile-of-segment map.
    """
    seg_of = np.asarray(seg_of)
    t = int(seg_of.size)
    if t == 0:
        return np.zeros((0,), np.int32)
    r, c, _, _ = _k._lane_geometry(t, tiles_per_block, num_cores)
    flush = np.zeros((t,), np.int32)
    for ci in range(c):
        pos: list[int] = []
        j = 0
        while True:
            lo = (j * c + ci) * r
            if lo >= t:
                break
            pos.extend(range(lo, min(lo + r, t)))
            j += 1
        for k_, p in enumerate(pos):
            if k_ + 1 == len(pos) or seg_of[pos[k_ + 1]] != seg_of[p]:
                flush[p] = 1
    return flush


def segmented_trace(
    n: int, flushes: int, tiles: int, tiles_per_block: int, num_cores: int
) -> ReductionTrace:
    """Static instrumentation for one segmented pass (flush MMAs = combine)."""
    _, c, _, tpad = _k._lane_geometry(tiles, tiles_per_block, num_cores)
    return ReductionTrace(
        n=n,
        m=MXU,
        levels=1,
        mma_ops=tpad + flushes,
        num_cores=c,
        lane_mma_ops=tpad // c,
        combine_mma_ops=flushes,
    )


def mma_sum_segments_pallas(
    flat: jax.Array,
    offsets: Sequence[int],
    *,
    tiles_per_block: int = 8,
    num_cores: int = 1,
    compute_dtype=jnp.bfloat16,
    interpret: bool | None = None,
    trace: Optional[list] = None,
) -> jax.Array:
    """Sum S independent segments of ``flat`` in ONE kernel launch.

    ``offsets`` (static ints, len S+1) delimit the segments:
    ``out[s] = sum(flat[offsets[s]:offsets[s+1]])``. Each segment is padded
    to whole (MXU, MXU) tiles; the concatenated tile stream is striped
    across ``num_cores`` lanes of the segmented C-accumulator kernel (each
    lane flushing per-(lane, segment) sub-partials at its own lane-aware
    boundaries) and one exact fixed-order f32 per-segment combine folds the
    lanes -- n/m^2 striped main MMAs + one flush MMA per lane-segment visit
    (exactly S at C = 1, at most S per lane),
    versus S launches of the fused kernel (and versus ~2.008 n/m^2 MMAs
    *per segment* for the paper's hierarchy). Empty segments cost no tiles
    and come back as the additive identity.
    """
    nseg = len(offsets) - 1
    if nseg <= 0:
        return jnp.zeros((0,), jnp.float32)
    flat = flat.reshape(-1).astype(jnp.float32)
    group = MXU * MXU
    tcounts, seg_of, _ = segment_tile_layout(offsets, group)
    t = sum(tcounts)
    if t == 0:  # every segment empty
        return jnp.zeros((nseg,), jnp.float32)
    parts = []
    for s, tc in enumerate(tcounts):
        if tc == 0:
            continue
        seg = jax.lax.slice(flat, (offsets[s],), (offsets[s + 1],))
        parts.append(common.pad_to(seg, tc * group))
    stream = jnp.concatenate(parts) if len(parts) > 1 else parts[0]
    flush = lane_flush_map(seg_of, tiles_per_block, num_cores)
    if trace is not None:
        trace.append(
            segmented_trace(
                int(flat.size), int(flush.sum()), t, tiles_per_block, num_cores
            )
        )
    sub = _k.reduce_segments(
        stream.reshape(t, MXU, MXU),
        seg_of,
        flush,
        nseg,
        tiles_per_block=tiles_per_block,
        num_cores=num_cores,
        compute_dtype=compute_dtype,
        interpret=interpret,
    )
    return combine_segment_partials(sub)


@functools.partial(jax.custom_vjp, nondiff_argnums=(1,))
def mma_sum_pallas_diff(x: jax.Array, mode: str = "fused") -> jax.Array:
    return mma_sum_pallas(x, mode=mode)


def _fwd(x, mode):
    return mma_sum_pallas(x, mode=mode), jnp.zeros((0,) + x.shape, x.dtype)


def _bwd(mode, res, g):
    return (jnp.broadcast_to(g, res.shape[1:]).astype(res.dtype),)


mma_sum_pallas_diff.defvjp(_fwd, _bwd)
