"""Public jit'd entry points for the MMA reduction kernel."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import common
from repro.kernels.mma_reduce import kernel as _k

MXU = common.MXU


def _to_tiles(x: jax.Array, m: int) -> jax.Array:
    flat = x.reshape(-1).astype(jnp.float32)
    group = m * m
    k = max(1, common.ceil_div(flat.size, group))
    flat = common.pad_to(flat, k * group)
    return flat.reshape(k, m, m)


def mma_sum_pallas(
    x: jax.Array,
    *,
    mode: str = "fused",
    tiles_per_block: int = 8,
    compute_dtype=jnp.bfloat16,
    interpret: bool | None = None,
) -> jax.Array:
    """Sum all elements of ``x`` on the MXU.

    mode="hierarchical": the paper's multi-launch recurrence (eq. 13) --
      each level is one pallas_call producing per-group partials.
    mode="fused": single launch using the MMA C-accumulator (beyond-paper).
    """
    if x.size == 0:
        # Empty reduction -> additive identity (matches mma_sum / jnp.sum).
        return jnp.zeros((), jnp.float32)
    if mode == "fused":
        tiles = _to_tiles(x, MXU)
        return _k.reduce_fused(
            tiles,
            tiles_per_block=tiles_per_block,
            compute_dtype=compute_dtype,
            interpret=interpret,
        )
    if mode != "hierarchical":
        raise ValueError(f"unknown mode {mode!r}")
    flat = x.reshape(-1).astype(jnp.float32)
    while flat.size > 1:
        tiles = _to_tiles(flat, MXU)
        flat = _k.reduce_tiles(
            tiles,
            tiles_per_block=tiles_per_block,
            compute_dtype=compute_dtype,
            interpret=interpret,
        )
    return flat.reshape(())


@functools.partial(jax.custom_vjp, nondiff_argnums=(1,))
def mma_sum_pallas_diff(x: jax.Array, mode: str = "fused") -> jax.Array:
    return mma_sum_pallas(x, mode=mode)


def _fwd(x, mode):
    return mma_sum_pallas(x, mode=mode), jnp.zeros((0,) + x.shape, x.dtype)


def _bwd(mode, res, g):
    return (jnp.broadcast_to(g, res.shape[1:]).astype(res.dtype),)


mma_sum_pallas_diff.defvjp(_fwd, _bwd)
