"""Public jit'd entry points for the MMA reduction kernels.

This layer owns everything the kernels keep static: the zero-copy ingestion
contract (which dtypes stream natively, the one documented pre-cast
fallback), the aligned-block cover layout for segmented gathers, the
per-part tile schedule for multi-operand launches, the lane-striping
geometry for the multi-core grid, the lane-aware segment flush maps, and
the DETERMINISTIC lane combines. The combines run as plain f32 XLA dots in
a fixed lane order -- never an atomic or a scheduling-dependent tree -- so
every reduction is bit-reproducible run-to-run regardless of how many cores
streamed the partials.

Zero-copy ingestion: every entry point hands the kernels the caller's
buffer as a FLAT view in its native dtype (``reshape(-1)`` of a contiguous
buffer is free at the XLA level); reshaping to (r, m, m) tiles, casting to
the compute dtype, and masking the ragged tail all happen in-VMEM. The only
host-side copy left on any path is the ``_ingest`` pre-cast for dtypes the
MXU cannot read (f64, ints, bools -> f32), and the traces carry the modeled
HBM bytes (``cost_model.hbm_bytes``) of the geometry actually launched.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import cost_model
from repro.core import precision as _precision
from repro.core.mma_reduce import ReductionTrace
from repro.kernels import common
from repro.kernels.mma_reduce import kernel as _k

MXU = common.MXU

# The parts kernel compiles one (predicated) branch per part and keeps one
# m^2 block per part resident in VMEM, so both compile time and VMEM grow
# linearly in S. Past this many live parts the packed-stream fallback (one
# concatenation of the small per-part buffers) is the better trade -- see
# ``backends.Backend.sum_parts``.
PARTS_KERNEL_MAX = 128


def _ingest(x: jax.Array) -> jax.Array:
    """Flat native-dtype view of ``x`` for zero-copy kernel ingestion.

    bf16/f16/f32 stream straight from the caller's buffer; anything the MXU
    cannot read natively (f64, ints, bools) is pre-cast to f32 -- the one
    documented staging copy left, and one the planner already routes away
    from the Pallas backends (ints go to xla)."""
    flat = x.reshape(-1)
    if not common.native_ingest_dtype(flat.dtype):
        flat = flat.astype(jnp.float32)
    return flat


def combine_lane_partials(partials: jax.Array) -> jax.Array:
    """(C, m, m) column-replicated lane accumulators -> scalar, fixed order.

    Two dots, both f32: one batched trailing MMA collapses each lane's
    accumulated row-sums (1 x acc, the fused kernel's old finalize step),
    then a single length-C all-ones dot folds the lane scalars in lane
    order. Everything is a static-order f32 contraction, so the result is
    bit-reproducible run-to-run; with C = 1 the second dot multiplies by
    1.0 and the value is bit-identical to the pre-striping kernel's.
    """
    c, m, _ = partials.shape
    onesf = common.ones_tile(m, "float32")  # cached host-side constant
    d = jax.lax.dot_general(
        jnp.broadcast_to(onesf, partials.shape),
        partials,
        (((2,), (1,)), ((0,), (0,))),
        preferred_element_type=jnp.float32,
    )
    lane = d[:, 0, 0]  # (C,) per-lane totals
    return jnp.dot(
        jnp.ones((c,), jnp.float32), lane, preferred_element_type=jnp.float32
    )


def combine_lane_partials_kahan(partials: jax.Array) -> jax.Array:
    """(C, 2, m, m) (acc, comp) lane pairs -> scalar via one compensated pass.

    Kahan's corrected sum is ``s - c``; we fold, in fixed lane order, each
    lane's accumulator rows followed by its negated compensation rows
    through one serial Kahan scan, so the cross-lane AND cross-row combine
    are both compensated and deterministic.
    """
    acc = partials[:, 0, :, 0]  # (C, m): column 0 carries the row sums
    comp = partials[:, 1, :, 0]
    v = jnp.stack([acc, -comp], axis=1).reshape(-1)
    return _precision.kahan_sum(v, dtype=jnp.float32)


def combine_segment_partials(sub: jax.Array) -> jax.Array:
    """(C, S) lane sub-partials -> (S,) per-segment totals, fixed lane order.

    One exact-order f32 add per lane per segment (C is tiny); with C = 1
    this is the identity on the kernel's output bits.
    """
    return jnp.sum(sub, axis=0)


def combine_lane_pair_partials(partials: jax.Array) -> tuple:
    """(C, 2, m, m) dual-accumulator lane pairs (the moments prologue) ->
    the (sum, sumsq) scalar pair, each half collapsed by the SAME
    deterministic fixed-order combine as a plain lane stack."""
    return (
        combine_lane_partials(partials[:, 0]),
        combine_lane_partials(partials[:, 1]),
    )


def mma_sum_pallas(
    x: jax.Array,
    *,
    mode: str = "fused",
    tiles_per_block: int = 8,
    num_cores: int = 1,
    compute_dtype=jnp.bfloat16,
    kahan: bool = False,
    prologue: str = "identity",
    epilogue=(),
    census: bool = False,
    interpret: bool | None = None,
    trace: Optional[list] = None,
) -> jax.Array:
    """Sum all (prologue-mapped) elements of ``x`` on the MXU, reading ``x``
    zero-copy. ``prologue`` ("identity" | "square" | "abs") is the in-kernel
    elementwise map -- applied after the compute-dtype cast and tail mask,
    before the eq. (9) MMA -- so ``sumsq``/``norm2`` stream the caller's raw
    leaf exactly once (the moments pair has its own entry point,
    ``mma_moments_pallas``).

    ``census=True`` (fused mode only, not with Kahan) makes the SAME single
    launch also count ``x``'s non-finite elements on a second ones-dot
    accumulator -- the tiles are already in registers, so the count costs
    zero extra HBM input bytes -- and changes the return to the
    ``(total, nonfinite_count)`` pair. The count is exact (0/1 mask summed
    in f32) and the masked ragged tail never contributes.

    ``epilogue`` (a normalized scalar chain -- ``common.normalize_epilogue``)
    maps the reduced total. It runs IN-KERNEL whenever the total is formed
    inside the launch -- the single-lane fused collapse, or the final
    hierarchy level -- and falls back to the same ``apply_epilogue``
    definition host-side only where the total genuinely forms on the host
    (multi-lane or Kahan combines): the values are identical either way,
    and the empty chain leaves every path byte-for-byte unchanged.

    mode="hierarchical": the paper's multi-launch recurrence (eq. 13) --
      each level is one pallas_call producing per-group partials (the grid
      is ``parallel``: every core reduces its own tiles concurrently).
      Level 0 streams the native buffer (and applies the prologue); upper
      levels stream the f32 partials the previous launch wrote (identity --
      partials are already mapped).
    mode="fused": single launch using the MMA C-accumulator, striped across
      ``num_cores`` lanes of a ("parallel", "arbitrary") grid; the lane
      partials collapse through the deterministic fixed-order combine.
      ``kahan=True`` carries a per-lane compensation row in a second VMEM
      scratch (single launch, compensated cross-tile carry; composes with
      the elementwise prologues).

    ``trace``: optional list; a ``ReductionTrace`` with the per-lane /
    combine MMA split and the modeled HBM bytes is appended (Python
    metadata only).
    """
    common.check_prologue(prologue, allow_moments=False)
    epilogue = common.normalize_epilogue(epilogue)
    if census and mode != "fused":
        raise ValueError(
            "census rides the fused single launch; the hierarchical mode "
            "would need a second partials column per level"
        )
    if census and kahan:
        raise ValueError(
            "census does not compose with kahan=True (the compensation "
            "row occupies the second accumulator)"
        )
    if x.size == 0:
        # Empty reduction -> additive identity (matches mma_sum / jnp.sum).
        if trace is not None:
            trace.append(ReductionTrace(n=0, m=MXU, levels=0, mma_ops=0))
        total = common.apply_epilogue(jnp.zeros((), jnp.float32), epilogue)
        if census:  # nothing streamed -> nothing non-finite
            return total, jnp.zeros((), jnp.float32)
        return total
    flat = _ingest(x)
    if mode == "fused":
        t_ = max(1, common.ceil_div(int(flat.size), MXU * MXU))
        _, c_eff, _, _ = _k._lane_geometry(t_, tiles_per_block, num_cores)
        in_kernel = bool(epilogue) and c_eff == 1 and not kahan
        if trace is not None:
            trace.append(
                fused_trace(
                    int(flat.size),
                    tiles_per_block,
                    num_cores,
                    itemsize=flat.dtype.itemsize,
                    kahan=kahan,
                    epilogue=in_kernel,
                    census=census,
                    fallback="" if flat.dtype == x.dtype else "ingest_f32",
                )
            )
        partials = _k.reduce_fused(
            flat,
            tiles_per_block=tiles_per_block,
            num_cores=num_cores,
            compute_dtype=compute_dtype,
            kahan=kahan,
            prologue=prologue,
            epilogue=epilogue if in_kernel else (),
            census=census,
            interpret=interpret,
        )
        if in_kernel:
            if census:  # (1, 2): [finished total, non-finite count]
                return partials[0, 0], partials[0, 1]
            return partials.reshape(())  # chain already applied in-launch
        if census:
            # (C, 2, m, m): sum lanes in [:, 0], census lanes in [:, 1];
            # the chain maps the TOTAL only -- the count is a raw tally.
            total = common.apply_epilogue(
                combine_lane_partials(partials[:, 0]), epilogue
            )
            return total, combine_lane_partials(partials[:, 1])
        if kahan:
            total = combine_lane_partials_kahan(partials)
        else:
            total = combine_lane_partials(partials)
        # multi-lane / Kahan: the total forms on the host, so the chain
        # runs here (same apply_epilogue definition, identical values).
        return common.apply_epilogue(total, epilogue)
    if mode != "hierarchical":
        raise ValueError(f"unknown mode {mode!r}")
    if kahan:
        raise ValueError(
            "kahan=True needs the fused carry; the hierarchical mode "
            "round-trips partials through HBM between launches"
        )
    n0 = flat.size
    fallback = "" if flat.dtype == x.dtype else "ingest_f32"
    hbm = cost_model.hier_hbm_bytes(
        n0, flat.dtype.itemsize, m=MXU, tiles_per_block=tiles_per_block
    )
    levels, mma_ops = 0, 0
    level_prologue = prologue
    epilogue_applied = not epilogue
    while flat.size > 1:
        t = common.ceil_div(flat.size, MXU * MXU)
        flat = _k.reduce_tiles(
            flat,
            tiles_per_block=tiles_per_block,
            compute_dtype=compute_dtype,
            prologue=level_prologue,
            # the FINAL level (t == 1) forms the total in-kernel: the
            # chain maps it there, inside the last launch.
            epilogue=epilogue if t == 1 else (),
            interpret=interpret,
        )
        if t == 1:
            epilogue_applied = True
        level_prologue = "identity"  # upper levels run on mapped partials
        levels += 1
        mma_ops += 2 * t
    if level_prologue != "identity":
        # single-element input: no level ever ran, so apply the map here
        # (at compute precision, exactly like a level-0 launch would).
        flat = common.apply_prologue(
            flat.astype(compute_dtype), prologue
        ).astype(jnp.float32)
    if not epilogue_applied:
        flat = common.apply_epilogue(flat, epilogue)
    if trace is not None:
        trace.append(
            ReductionTrace(
                n=n0, m=MXU, levels=levels, mma_ops=mma_ops,
                hbm_bytes=hbm.total, fallback=fallback,
            )
        )
    return flat.reshape(())


def fused_trace(
    n: int,
    tiles_per_block: int = 8,
    num_cores: int = 1,
    *,
    itemsize: int = 4,
    kahan: bool = False,
    dual: bool = False,
    epilogue: bool = False,
    census: bool = False,
    fallback: str = "",
) -> ReductionTrace:
    """Static per-lane / combine MMA + HBM-byte instrumentation for one
    zero-copy fused pass (the geometry here is ``stripe_geometry``'s -- the
    same one the kernel launches, so trace, cost model, and silicon agree
    by construction). ``dual=True`` is the moments prologue: two MMAs per
    tile and a doubled combine; the elementwise prologues change neither
    count nor byte. ``epilogue=True`` is the in-kernel finish (single-lane
    only): the combine MMA moves inside the launch and the partials write
    shrinks to one finished f32 scalar. ``census=True`` (non-dual,
    non-kahan) carries the non-finite census: byte-identical to the
    moments dual accumulator on the partials path (same doubled output
    shape), and one extra f32 slot on the in-kernel-epilogue path --
    zero extra input bytes either way."""
    k = max(1, common.ceil_div(n, MXU * MXU))
    _, c, _, tpad = _k._lane_geometry(k, tiles_per_block, num_cores)
    d = 2 if (dual or census) else 1
    lane = d * (tpad // c)
    combine = d * (c + 1)
    if census and epilogue:
        # the epilogue model with the census count widening the finished
        # output from one f32 scalar to two
        hbm = cost_model.fused_hbm_bytes(
            n, itemsize, num_cores=num_cores,
            tiles_per_block=tiles_per_block, kahan=kahan, epilogue=True,
        )
        hbm = dataclasses.replace(hbm, kernel_write=2 * hbm.kernel_write)
    else:
        hbm = cost_model.fused_hbm_bytes(
            n, itemsize, num_cores=num_cores,
            tiles_per_block=tiles_per_block, kahan=kahan,
            dual=dual or census, epilogue=epilogue,
        )
    return ReductionTrace(
        n=n,
        m=MXU,
        levels=1,
        mma_ops=d * tpad + combine,
        num_cores=c,
        lane_mma_ops=lane,
        combine_mma_ops=combine,
        hbm_bytes=hbm.total,
        fallback=fallback,
        census=census,
    )


def mma_moments_pallas(
    x: jax.Array,
    *,
    mode: str = "fused",
    tiles_per_block: int = 8,
    num_cores: int = 1,
    compute_dtype=jnp.bfloat16,
    interpret: bool | None = None,
    trace: Optional[list] = None,
) -> tuple:
    """(sum, sum-of-squares) of every element of ``x`` from ONE zero-copy
    pass over the raw buffer -- the paired (x, x^2) dual-accumulator
    prologue. This is the full-reduction moments path: the old route paid a
    host-side f32 square (an n-sized elementwise pass + staging write) and
    a SECOND kernel pass; here both statistics ride the same stream.

    mode="fused": one launch, each lane carrying (acc, acc2); both halves
      collapse through the deterministic fixed-order combine.
    mode="hierarchical": level 0 emits the (T, 2) partial pair from one
      pass over the native buffer; each f32 column then recurses through
      the plain identity hierarchy (eq. 13).
    """
    if x.size == 0:
        if trace is not None:
            trace.append(ReductionTrace(n=0, m=MXU, levels=0, mma_ops=0))
        z = jnp.zeros((), jnp.float32)
        return z, z
    flat = _ingest(x)
    fallback = "" if flat.dtype == x.dtype else "ingest_f32"
    if mode == "fused":
        if trace is not None:
            trace.append(
                fused_trace(
                    int(flat.size),
                    tiles_per_block,
                    num_cores,
                    itemsize=flat.dtype.itemsize,
                    dual=True,
                    fallback=fallback,
                )
            )
        partials = _k.reduce_fused(
            flat,
            tiles_per_block=tiles_per_block,
            num_cores=num_cores,
            compute_dtype=compute_dtype,
            prologue="moments",
            interpret=interpret,
        )
        return combine_lane_pair_partials(partials)
    if mode != "hierarchical":
        raise ValueError(f"unknown mode {mode!r}")
    n0 = int(flat.size)
    hbm = cost_model.hier_moments_hbm_bytes(
        n0, flat.dtype.itemsize, m=MXU, tiles_per_block=tiles_per_block
    )
    t0 = common.ceil_div(n0, MXU * MXU)
    pair = _k.reduce_tiles(
        flat,
        tiles_per_block=tiles_per_block,
        compute_dtype=compute_dtype,
        prologue="moments",
        interpret=interpret,
    )  # (T, 2): both statistics from the single level-0 pass
    levels, mma_ops = 1, 4 * t0  # 2 MMAs per tile per statistic at level 0
    outs = []
    for col in (pair[:, 0], pair[:, 1]):
        v = col
        while v.size > 1:
            t = common.ceil_div(v.size, MXU * MXU)
            v = _k.reduce_tiles(
                v,
                tiles_per_block=tiles_per_block,
                compute_dtype=compute_dtype,
                interpret=interpret,
            )
            levels += 1
            mma_ops += 2 * t
        outs.append(v.reshape(()))
    if trace is not None:
        trace.append(
            ReductionTrace(
                n=n0, m=MXU, levels=levels, mma_ops=mma_ops,
                hbm_bytes=hbm.total, fallback=fallback,
            )
        )
    return outs[0], outs[1]


def segment_cover_layout(
    offsets: Sequence[int], group: int
) -> tuple[tuple[int, ...], np.ndarray, np.ndarray, np.ndarray, np.ndarray]:
    """Aligned-block cover of a segmented flat buffer (trace-time numpy).

    Segment s spans ``[offsets[s], offsets[s+1])`` of the flat buffer; its
    tiles are the ``group``-aligned blocks that OVERLAP it, each carrying
    the in-block validity window ``[lo, hi)`` of the elements that belong
    to s. Tile-aligned segments stream every block exactly once with a full
    window; a non-aligned boundary makes the straddled block appear in BOTH
    neighbours' covers (two masked fetches of one block -- the O(S m^2)
    "non-aligned remainder" traffic, never an n-sized staging copy).

    Returns ``(tile_counts, src_blk, seg_of, lo_in, hi_in)``: per-segment
    cover sizes (0 for empty segments) plus the four flat per-tile maps the
    gather kernel prefetches.
    """
    offs = np.asarray(offsets, np.int64)
    src, seg, lo, hi = [], [], [], []
    tcounts = []
    for s in range(offs.size - 1):
        a, b = int(offs[s]), int(offs[s + 1])
        if b <= a:
            tcounts.append(0)
            continue
        blk0, blk1 = a // group, -(-b // group)
        tcounts.append(blk1 - blk0)
        for k in range(blk0, blk1):
            src.append(k)
            seg.append(s)
            lo.append(max(a - k * group, 0))
            hi.append(min(b - k * group, group))
    return (
        tuple(tcounts),
        np.asarray(src, np.int32),
        np.asarray(seg, np.int32),
        np.asarray(lo, np.int32),
        np.asarray(hi, np.int32),
    )


def segment_tile_layout(
    offsets: Sequence[int], group: int
) -> tuple[tuple[int, ...], np.ndarray, np.ndarray]:
    """Static tile bookkeeping for a PACKED segmented stream (legacy layout).

    Describes the pre-gather stream build -- each segment zero-padded to
    whole tiles and concatenated: per-segment tile counts
    (``ceil(size/group)``, 0 for empty segments), the tile->segment id map,
    and the SERIAL boundary-flag map (1 on the last tile of each non-empty
    segment -- the ``num_cores=1`` flush map; striped lanes use
    ``lane_flush_map``). The zero-copy gather path uses
    ``segment_cover_layout`` instead (aligned-block covers of the caller's
    buffer, which may need one MORE tile per non-aligned segment start);
    this layout remains the right one for callers sizing a packed
    ``(T, m, m)`` stream. All trace-time numpy -- offsets are static."""
    sizes = np.diff(np.asarray(offsets, np.int64))
    tcounts = tuple(int(-(-s // group)) if s > 0 else 0 for s in sizes)
    total = sum(tcounts)
    seg_of = np.zeros((total,), np.int32)
    flush = np.zeros((total,), np.int32)
    pos = 0
    for s, tc in enumerate(tcounts):
        if tc == 0:
            continue
        seg_of[pos : pos + tc] = s
        flush[pos + tc - 1] = 1
        pos += tc
    return tcounts, seg_of, flush


def lane_flush_map(
    seg_of: np.ndarray, tiles_per_block: int, num_cores: int
) -> np.ndarray:
    """Lane-aware flush flags for a striped segmented stream (trace-time).

    Lane ``ci`` of a C-lane grid streams blocks ``ci, ci+C, ci+2C, ...`` --
    so the tiles it visits are interleaved with the other lanes'. A lane
    must flush its accumulator whenever ITS OWN stripe leaves a segment:
    flag position p iff p is the last tile of its segment within the stripe
    that owns it. With C = 1 this reduces exactly to the serial
    last-tile-of-segment map. The gather kernel stripes tile-granularly
    (``tiles_per_block=1``); the parameter is kept for block-striped
    streams and tests.
    """
    seg_of = np.asarray(seg_of)
    t = int(seg_of.size)
    if t == 0:
        return np.zeros((0,), np.int32)
    r, c, _, _ = _k._lane_geometry(t, tiles_per_block, num_cores)
    flush = np.zeros((t,), np.int32)
    for ci in range(c):
        pos: list[int] = []
        j = 0
        while True:
            lo = (j * c + ci) * r
            if lo >= t:
                break
            pos.extend(range(lo, min(lo + r, t)))
            j += 1
        for k_, p in enumerate(pos):
            if k_ + 1 == len(pos) or seg_of[pos[k_ + 1]] != seg_of[p]:
                flush[p] = 1
    return flush


def segmented_trace(
    n: int,
    flushes: int,
    tiles: int,
    num_cores: int,
    *,
    itemsize: int = 4,
    fetched_elems: int | None = None,
    segments: int = 1,
    dual: bool = False,
    census: bool = False,
) -> ReductionTrace:
    """Static instrumentation for one segmented gather pass (flush MMAs =
    combine; ``fetched_elems`` counts every element the cover actually
    DMAs, i.e. n plus the re-fetched straddled blocks). ``dual`` is the
    moments prologue: two main MMAs per tile, and ``segments``/``flushes``
    arrive already widened to the doubled output slots. ``census`` rides
    the same dual-accumulator shape (one extra ones-dot per tile, one
    extra flush per lane-segment visit, doubled slots -- the widened
    counts likewise arrive pre-folded into ``segments``/``flushes``) at
    zero extra input bytes."""
    _, c, _, tpad = _k._lane_geometry(tiles, 1, num_cores)
    d = 2 if (dual or census) else 1
    return ReductionTrace(
        n=n,
        m=MXU,
        levels=1,
        mma_ops=d * tpad + flushes,
        num_cores=c,
        lane_mma_ops=d * (tpad // c),
        combine_mma_ops=flushes,
        hbm_bytes=cost_model.segmented_hbm_bytes(
            fetched_elems if fetched_elems is not None else n,
            itemsize,
            segments=segments,
            tiles=tiles,
            num_cores=num_cores,
        ).total,
        census=census,
    )


def _cover_fetched_elems(
    src_blk: np.ndarray, flat_size: int, group: int
) -> int:
    """Elements the gather DMAs: one (possibly buffer-clipped) block per
    cover tile -- equals n for tile-aligned segments, n + O(S * group) when
    boundaries straddle blocks (shared blocks are fetched once per
    neighbour)."""
    return int(
        sum(min(group, flat_size - int(b) * group) for b in src_blk)
    )


def mma_sum_segments_pallas(
    flat: jax.Array,
    offsets: Sequence[int],
    *,
    tiles_per_block: int = 8,
    num_cores: int = 1,
    compute_dtype=jnp.bfloat16,
    prologue: str = "identity",
    epilogue=(),
    census: bool = False,
    interpret: bool | None = None,
    trace: Optional[list] = None,
) -> jax.Array:
    """Sum S independent segments of ``flat`` in ONE kernel launch, reading
    ``flat`` zero-copy.

    ``epilogue`` (normalized chain; not with "moments") maps every
    per-segment total -- in-kernel on single-lane launches (each segment
    flushes exactly once there), host-side after the lane combine otherwise
    (same ``apply_epilogue`` definition, identical values).

    ``offsets`` (static ints, len S+1) delimit the segments:
    ``out[s] = sum(flat[offsets[s]:offsets[s+1]])``. Each segment is
    covered by the m^2-aligned blocks of the caller's buffer that overlap
    it (``segment_cover_layout``); the cover maps are scalar-prefetched and
    the BlockSpec index map gathers each tile straight from the original
    buffer -- no slice-pad-concatenate stream is ever materialized.
    Tile-aligned segments stream every byte once; a non-aligned boundary
    re-fetches the one straddled block (masked both sides) -- the
    "non-aligned remainder" costs O(S) extra block fetches, modeled by
    ``cost_model.segmented_hbm_bytes``. The cover stream is striped
    tile-granularly across ``num_cores`` lanes (each lane flushing
    per-(lane, segment) sub-partials at its own lane-aware boundaries) and
    one exact fixed-order f32 per-segment combine folds the lanes --
    ~n/m^2 striped main MMAs + one flush MMA per lane-segment visit
    (exactly S at C = 1, at most S per lane). ``tiles_per_block`` is
    accepted for plan compatibility but plays no role on the gather path.
    Empty segments cost no tiles and come back as the additive identity.

    ``prologue`` maps each gathered tile in-kernel (sumsq/norm2 segments
    stream the raw buffer once); ``prologue="moments"`` returns the
    widened (2S,) vector -- per-segment sums in [0, S), sums of squares in
    [S, 2S) -- both statistics from the same single launch.

    ``census=True`` (not with "moments") widens the output the same way:
    per-segment sums in [0, S), per-segment NON-FINITE counts in [S, 2S),
    both from the one gather pass (the counts ride a second accumulator on
    the tiles already in registers -- zero extra input bytes; window-masked
    lanes are exact zeros and never miscount). The epilogue, when present,
    maps only the sum slots; the counts stay raw tallies.
    """
    del tiles_per_block  # gather path is tile-granular by construction
    common.check_prologue(prologue)
    epilogue = common.normalize_epilogue(epilogue)
    dual = prologue == "moments"
    if epilogue and dual:
        raise ValueError(
            "segment epilogues do not compose with prologue='moments' "
            "(each flush writes two coupled slots)"
        )
    if census and dual:
        raise ValueError(
            "census does not compose with prologue='moments' (both claim "
            "the second accumulator); run moments as separate segments"
        )
    nseg = len(offsets) - 1
    if nseg <= 0:
        return jnp.zeros((0,), jnp.float32)
    out_slots = (2 * nseg) if (dual or census) else nseg
    flat = _ingest(flat)
    group = MXU * MXU
    tcounts, src_blk, seg_of, lo_in, hi_in = segment_cover_layout(
        offsets, group
    )
    t = int(src_blk.size)
    if t == 0:  # every segment empty
        per = common.apply_epilogue(
            jnp.zeros((nseg,), jnp.float32), epilogue
        )
        if census:  # nothing streamed -> zero counts, epilogue-free
            return jnp.concatenate([per, jnp.zeros((nseg,), jnp.float32)])
        return per if not dual else jnp.zeros((out_slots,), jnp.float32)
    _, c_eff, _, _ = _k._lane_geometry(t, 1, num_cores)
    in_kernel = bool(epilogue) and c_eff == 1
    flush = lane_flush_map(seg_of, 1, num_cores)
    if trace is not None:
        trace.append(
            segmented_trace(
                int(flat.size),
                (2 if (dual or census) else 1) * int(flush.sum()),
                t,
                num_cores,
                itemsize=flat.dtype.itemsize,
                fetched_elems=_cover_fetched_elems(
                    src_blk, int(flat.size), group
                ),
                segments=out_slots,
                dual=dual,
                census=census,
            )
        )
    sub = _k.reduce_segments(
        flat,
        src_blk,
        seg_of,
        flush,
        lo_in,
        hi_in,
        nseg,
        num_cores=num_cores,
        compute_dtype=compute_dtype,
        prologue=prologue,
        epilogue=epilogue if in_kernel else (),
        census=census,
        interpret=interpret,
    )
    out = combine_segment_partials(sub)
    if in_kernel:
        # An EMPTY segment never flushes, so the in-kernel epilogue never
        # maps its slot: patch it to epilogue(0) host-side -- the value the
        # multi-lane and all-empty paths produce -- so the epilogue'd
        # result never depends on the lane count.
        empty = np.asarray(tcounts) == 0
        if empty.any():
            fixed = common.apply_epilogue(
                jnp.zeros((), jnp.float32), epilogue
            )
            mask = jnp.asarray(empty)
            if census:  # counts stay raw tallies (0 for an empty segment)
                mask = jnp.concatenate(
                    [mask, jnp.zeros_like(mask)]
                )
            out = jnp.where(mask, fixed, out)
    if epilogue and not in_kernel:
        if census:  # the chain maps sums only; counts are raw tallies
            out = jnp.concatenate(
                [common.apply_epilogue(out[:nseg], epilogue), out[nseg:]]
            )
        else:
            out = common.apply_epilogue(out, epilogue)
    return out


def parts_layout(
    sizes: Sequence[int], group: int
) -> tuple[tuple[int, int, int, int], ...]:
    """Static tile schedule for a multi-operand parts launch: one
    ``(seg, start, nblk, size)`` run per NON-EMPTY part, consecutive on the
    shared grid (``start`` = running block total)."""
    layout = []
    start = 0
    for s, size in enumerate(sizes):
        size = int(size)
        if size == 0:
            continue
        nblk = common.ceil_div(size, group)
        layout.append((s, start, nblk, size))
        start += nblk
    return tuple(layout)


def parts_trace(
    sizes: Sequence[int],
    itemsizes: Sequence[int],
    prologues=None,
    *,
    extra_slots: int = 0,
    census: bool = False,
) -> ReductionTrace:
    """Static instrumentation for one parts pass: one main MMA per tile
    (two for a moments part -- both statistics from the same read) + one
    flush MMA per live part slot; traffic = the parts' native bytes (the
    prologues move NO extra bytes -- the whole point). ``extra_slots``
    counts epilogue total-chain outputs: K finished scalars widen the
    output row by K f32 slots and cost nothing else. ``census=True`` adds
    the non-finite census: one extra ones-dot MMA per tile + one flush MMA
    per live part, and S + 1 more f32 output slots -- still ZERO extra
    input bytes."""
    group = MXU * MXU
    prologues = common.normalize_part_prologues(
        "identity" if prologues is None else prologues, len(sizes)
    )
    dual = "moments" in prologues
    layout = parts_layout(sizes, group)
    tiles = flushes = 0
    for (s, _, nblk, _) in layout:
        k = 2 if (prologues[s] == "moments" or census) else 1
        tiles += k * nblk
        flushes += k
    part_bytes = sum(
        int(s) * int(b) for s, b in zip(sizes, itemsizes) if int(s)
    )
    return ReductionTrace(
        n=int(sum(int(s) for s in sizes)),
        m=MXU,
        levels=1,
        mma_ops=tiles + flushes,
        num_cores=1,
        lane_mma_ops=tiles,
        combine_mma_ops=flushes,
        hbm_bytes=cost_model.parts_hbm_bytes(
            part_bytes,
            segments=(2 if dual else 1) * len(sizes) + extra_slots
            + ((len(sizes) + 1) if census else 0),
        ).total,
        census=census,
    )


def mma_sum_parts_pallas(
    parts: Sequence[jax.Array],
    *,
    compute_dtype=jnp.bfloat16,
    prologue="identity",
    slot_epilogue=(),
    total_chains=None,
    census: bool = False,
    interpret: bool | None = None,
    trace: Optional[list] = None,
) -> jax.Array:
    """Sum S separate (prologue-mapped) arrays in ONE kernel launch with NO
    packing copy.

    Every part enters the launch as its own operand (flattened in its
    native dtype -- free) and streams through the shared accumulator on its
    own statically-scheduled tile run; per-part totals flush to the (S,)
    output in part order. ``prologue`` (a name, or one name per part)
    selects each part's in-kernel elementwise map, so
    ``reduce_many(kind="sumsq")`` / ``reduce_tree(kind="norm2")`` stream
    every raw leaf exactly once -- no host-side square, no f32 staging
    write. If ANY part carries "moments" the output widens to (2S,): sums
    in [0, S), sums of squares in [S, 2S) (non-moments parts leave their
    square slot at the additive identity), both statistics riding the same
    single read per leaf. This is the zero-copy engine behind
    ``reduce_many(axis=None)`` / ``reduce_tree``: the packed-stream
    ``concatenate`` (and its accumulate-dtype cast) never happens. Compile
    cost and VMEM residency are O(S); callers bound S via
    ``PARTS_KERNEL_MAX`` (``backends.Backend.sum_parts`` falls back to the
    packed stream past it). Empty parts return the additive identity.

    ``slot_epilogue`` (normalized chain) maps every per-part total
    in-kernel at its flush. ``total_chains`` (tuple of K normalized
    chains) widens the output to (S + K,): slot ``S + k`` carries chain k
    applied to the RAW cross-part total, folded in-kernel in static part
    order -- this is ``reduce_tree``'s single-launch norm/clip finish,
    fully inside the launch at any core count. Neither composes with a
    "moments" part.

    ``census=True`` (non-moments) widens the output further by S + 1
    slots: slot ``S + K + s`` carries part s's NON-FINITE element count
    and the final slot the cross-part total count, both counted in-kernel
    on the tiles already in registers -- the guarded optimizer's NaN/Inf
    detector at ZERO extra input bytes (empty parts count 0; the ragged
    tail is masked to exact zeros before the isfinite test, so pad lanes
    never miscount).
    """
    nseg = len(parts)
    slot_epilogue = common.normalize_epilogue(slot_epilogue)
    if total_chains is not None:
        total_chains = tuple(
            common.normalize_epilogue(c) for c in total_chains
        ) or None
    n_chains = len(total_chains) if total_chains else 0
    if nseg == 0:
        if total_chains:
            raise ValueError("total_chains need at least one part")
        if census:
            raise ValueError("census needs at least one part")
        return jnp.zeros((0,), jnp.float32)
    pros = common.normalize_part_prologues(prologue, nseg)
    dual = "moments" in pros
    if (slot_epilogue or total_chains or census) and dual:
        raise ValueError(
            "parts epilogues/census do not compose with a 'moments' part "
            "(its flush writes two coupled slots); drop the epilogue or "
            "run the moments leaf as separate 'identity'/'square' parts"
        )
    out_slots = (2 * nseg) if dual else nseg
    flats = [_ingest(p) for p in parts]
    layout = parts_layout([f.size for f in flats], MXU * MXU)
    if not layout:  # every part empty
        per = common.apply_epilogue(
            jnp.zeros((out_slots,), jnp.float32), slot_epilogue
        )
        pieces = [per]
        if total_chains:
            pieces.append(
                jnp.stack(
                    [
                        common.apply_epilogue(
                            jnp.zeros((), jnp.float32), chain
                        )
                        for chain in total_chains
                    ]
                )
            )
        if census:  # nothing streamed -> nothing non-finite
            pieces.append(jnp.zeros((nseg + 1,), jnp.float32))
        return pieces[0] if len(pieces) == 1 else jnp.concatenate(pieces)
    if trace is not None:
        trace.append(
            parts_trace(
                [f.size for f in flats],
                [f.dtype.itemsize for f in flats],
                pros,
                extra_slots=n_chains,
                census=census,
            )
        )
    live = [flats[s] for (s, _, _, _) in layout]
    return _k.reduce_parts(
        live,
        layout,
        out_slots,
        compute_dtype=compute_dtype,
        prologues=tuple(pros[s] for (s, _, _, _) in layout),
        moments_offset=nseg if dual else 0,
        slot_epilogue=slot_epilogue,
        total_chains=total_chains,
        census=census,
        interpret=interpret,
    )


@functools.partial(jax.custom_vjp, nondiff_argnums=(1,))
def mma_sum_pallas_diff(x: jax.Array, mode: str = "fused") -> jax.Array:
    return mma_sum_pallas(x, mode=mode)


def _fwd(x, mode):
    return mma_sum_pallas(x, mode=mode), jnp.zeros((0,) + x.shape, x.dtype)


def _bwd(mode, res, g):
    return (jnp.broadcast_to(g, res.shape[1:]).astype(res.dtype),)


mma_sum_pallas_diff.defvjp(_fwd, _bwd)
