from repro.kernels.mma_reduce.ops import (  # noqa: F401
    mma_sum_pallas,
    mma_sum_pallas_diff,
    mma_sum_segments_pallas,
    segment_tile_layout,
)
from repro.kernels.mma_reduce import ref  # noqa: F401
