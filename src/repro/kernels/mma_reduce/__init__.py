from repro.kernels.mma_reduce.ops import (  # noqa: F401
    mma_sum_pallas,
    mma_sum_pallas_diff,
)
from repro.kernels.mma_reduce import ref  # noqa: F401
