"""Pallas TPU kernels for the paper's MMA reduction -- zero-copy ingestion.

Every kernel here consumes the caller's buffer DIRECTLY: a flat 1D BlockSpec
over the unpadded, native-dtype (bf16/f16/f32) input, with the (r, m, m) tile
view, the cast to ``compute_dtype``, and the tail handling all happening
in-VMEM. Nothing is reshaped-to-f32, padded, or concatenated host-side, so a
bf16 reduction moves n*2 bytes of HBM instead of the staged path's
read-n*2 + write-n*4 + read-n*4 (the reduction is memory-bound -- see
``cost_model.fused_hbm_bytes`` vs ``staged_fused_hbm_bytes``; the traces the
ops layer emits are asserted against those models). Tail tiles are masked
with ``broadcasted_iota`` against the true length -- a masked load of the
boundary block, not a padded copy -- which keeps tile-multiple f32 inputs
bit-identical to the pre-zero-copy kernels (the mask is statically elided
when the lane geometry needs none).

Every body takes a trace-time ELEMENTWISE PROLOGUE (identity / square /
abs, plus the paired (x, x^2) dual accumulator for moments), applied after
the compute-dtype cast and the tail mask, before the eq. (9) MMA -- so
sumsq/norm2/moments stream the caller's raw leaf exactly once (x^2 @ 1
instead of x @ 1; no host-side square pass, no f32 staging write). The
identity prologue adds no ops, keeping kind="sum" bit-identical to the
prologue-free kernels.

Four kernel bodies:

``tile_partials_kernel`` -- paper-faithful: every (m, m) tile of the flat
  block goes through the 2-MMA sequence of eqs. (9)-(12); each grid step
  emits its per-tile group sums. The hierarchy (eq. 13) is driven from
  ops.py by re-invoking the kernel on the (f32) partials, exactly like the
  paper's repeated kernel launches. Grid steps are independent, so the
  single grid dimension is ``parallel``.

``fused_accumulate_kernel`` -- beyond-paper optimization: a VMEM-resident
  f32 accumulator serves as the MMA C operand across grid steps
  (acc <- X_t @ 1 + acc), so each tile costs ONE MMA and no intermediate
  level touches HBM. Multi-core streaming: 2D ``(num_cores, blocks)`` grid
  with ``dimension_semantics=("parallel", "arbitrary")`` -- the flat element
  stream is STRIPED block-wise across lanes (lane c owns blocks c, c+C,
  ...), each lane carries its own accumulator and emits one (m, m) partial;
  ops.py collapses the lanes with a deterministic fixed-order f32 combine.
  ``kahan=True`` (``fused_kahan_kernel``) adds a second VMEM scratch row
  carrying a per-lane Kahan compensation, all inside the single launch.

``segmented_gather_kernel`` -- MANY independent reductions in ONE launch
  over ONE flat buffer, with NO stream staging: scalar-prefetched per-tile
  maps (source block, in-block [lo, hi) validity window, segment id,
  lane-aware flush flag) let the kernel gather every tile straight from the
  caller's buffer. Each segment is covered by the m^2-aligned blocks that
  overlap it -- tile-aligned segments stream every byte exactly once; a
  non-aligned boundary re-fetches (and masks) the one block it straddles,
  so the only overhead for arbitrary offsets is O(S) extra block fetches
  (the non-aligned remainder -- modeled by ``segmented_hbm_bytes``), never
  an n-sized copy. Striping is tile-granular (the gather fixes the block
  depth at one tile); flushes collapse per-(lane, segment) sub-partials
  exactly as before.

``parts_accumulate_kernel`` -- the multi-reduce behind ``reduce_many`` /
  ``reduce_tree``: S separate arrays enter the SAME launch as S operands
  (no packing concatenation). Each part is blocked over a shared
  sequential grid; part i's BlockSpec dwells on a clamped block index
  outside its tile run [start_i, start_i + nblk_i) -- Pallas only re-DMAs
  when a block index CHANGES, so the dwell costs no traffic -- and inside
  its run the statically-unrolled body masks the part's ragged tail
  against its true length and flushes its total at its last tile. The
  whole layout is trace-time static (sizes are static), so the kernel
  needs no scalar prefetch at all. Compile cost and VMEM residency are
  O(S) -- ops.py documents the fallback threshold.

Block geometry: each fused/hierarchical grid step stages
``tiles_per_block * m^2`` flat elements (8 * 16384 * 4B = 512 KiB f32, half
that for bf16) -- well inside the ~16 MiB VMEM budget and large enough to
hide DMA latency behind the systolic pipeline. The segmented gather and
parts kernels stage one m^2 block (64 KiB f32) per step by construction.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core import cost_model
from repro.kernels import common

MXU = common.MXU


def _two_mma(tiles: jax.Array, compute_dtype) -> jax.Array:
    """(R, m, m) -> (R,) via the paper's two all-ones MMAs, f32 accumulate."""
    m = tiles.shape[-1]
    ones = common.ones_mma(m, compute_dtype)
    d = jax.lax.dot_general(
        tiles.astype(compute_dtype),
        jnp.broadcast_to(ones, tiles.shape),
        (((2,), (1,)), ((0,), (0,))),
        preferred_element_type=jnp.float32,
    )
    d2 = jax.lax.dot_general(
        jnp.broadcast_to(ones, d.shape),
        d.astype(compute_dtype),
        (((2,), (1,)), ((0,), (0,))),
        preferred_element_type=jnp.float32,
    )
    return d2[:, 0, 0]


def _load_tiles(x_ref, base, n, r, m, compute_dtype, needs_mask):
    """Flat (r*m*m,) native block -> (r, m, m) compute-dtype tiles, in-VMEM.

    The three staged host-side ops this replaces -- reshape, astype, pad --
    all become register work: the 1D->2D view is a relayout (last dim = the
    128 lanes), the cast feeds the MXU at its native multiplier width, and
    the tail beyond the true length ``n`` is a ``broadcasted_iota`` mask
    (boundary blocks are CLIPPED reads of the caller's buffer; whatever the
    pad lanes hold is zeroed here, so garbage -- even NaN -- never reaches
    the accumulate). ``needs_mask`` is static: lane geometries that cover n
    exactly skip the mask entirely, keeping the tile-multiple fast path
    op-identical to the pre-zero-copy kernels."""
    rows = x_ref[...].reshape(r * m, m)  # lane-preserving 1D->2D relayout
    xv = rows.astype(compute_dtype)
    if needs_mask:
        row = jax.lax.broadcasted_iota(jnp.int32, (r * m, m), 0)
        col = jax.lax.broadcasted_iota(jnp.int32, (r * m, m), 1)
        xv = jnp.where(base + row * m + col < n, xv, jnp.zeros_like(xv))
    return xv.reshape(r, m, m)


def tile_partials_kernel(
    x_ref, o_ref, *, n, r, m, compute_dtype, needs_mask, prologue="identity",
    epilogue=(),
):
    """One grid step: (r*m*m,) flat native elements -> (r,) partials.

    ``prologue`` is the trace-time elementwise map applied after the
    compute-dtype cast and tail mask, before the eq. (9) MMA -- so
    sumsq/norm2 stream the caller's raw leaf (x^2 @ 1 instead of x @ 1).
    ``prologue="moments"`` emits the paired (r, 2) partials (group sums of
    x AND x^2) from one pass over the tile block.

    ``epilogue`` (a normalized scalar chain) is only passed on the FINAL
    hierarchy level, where the launch covers a single tile (r == 1) and its
    lone partial IS the total -- the chain maps it in-kernel, so the
    hierarchy's consumer reads its statistic (sqrt / clip / scale) straight
    from the last launch with no host-side scalar eqns."""
    base = pl.program_id(0) * r * m * m
    tiles = _load_tiles(x_ref, base, n, r, m, compute_dtype, needs_mask)
    if prologue == "moments":
        o_ref[:, 0] = _two_mma(tiles, compute_dtype)
        o_ref[:, 1] = _two_mma(tiles * tiles, compute_dtype)
        return
    tiles = common.apply_prologue(tiles, prologue)
    o_ref[...] = common.apply_epilogue(_two_mma(tiles, compute_dtype), epilogue)


def _tile_row_sums(xv, compute_dtype):
    """(m, m) compute-dtype tile -> (m, m) f32 column-replicated row sums:
    the single-tile eq. (9) MMA (D = X @ 1) the gather/parts bodies fold
    into their VMEM accumulators."""
    m = xv.shape[-1]
    return jax.lax.dot_general(
        xv,
        common.ones_mma(m, compute_dtype),
        (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )


def _block_row_sums(tiles, compute_dtype):
    """(r, m, m) compute-dtype block -> (r, m, m) f32 column-replicated row
    sums: D = X @ 1. One batched MMA per block; the accumulate operand (C)
    is carried by the caller's VMEM accumulator, the MXU's native
    accumulation mode."""
    m = tiles.shape[-1]
    ones = common.ones_mma(m, compute_dtype)
    return jax.lax.dot_general(
        tiles,
        jnp.broadcast_to(ones, tiles.shape),
        (((2,), (1,)), ((0,), (0,))),
        preferred_element_type=jnp.float32,
    )


def fused_accumulate_kernel(
    x_ref, o_ref, acc_ref, *maybe_cacc, n, r, c, m, compute_dtype,
    needs_mask, prologue="identity", epilogue=(), census=False,
):
    """Striped grid-accumulating reduction: one lane of the 2D grid.

    Grid is (num_cores, blocks_per_lane) with semantics ("parallel",
    "arbitrary"): dimension 0 indexes the lane (spread across cores, each
    with its own acc scratch instance), dimension 1 the lane's sequential
    block stream over the FLAT native input. Each step performs one batched
    MMA per tile block: acc += sum_t P(X_t) @ 1, where P is the trace-time
    elementwise ``prologue`` (identity adds no ops, keeping kind="sum"
    op-identical to the prologue-free kernel). On the lane's last step the
    raw (m, m) accumulator is emitted as this lane's partial; the
    deterministic collapse runs in ops.py (``combine_lane_partials``).

    ``epilogue`` (normalized scalar chain; single-lane grids only -- the
    launcher enforces c == 1) moves that collapse INTO the launch: the last
    step folds the accumulator with the trailing f32 MMA (1 x acc), maps
    the scalar through the chain, and emits a (1, 1) result -- the
    consumer's statistic leaves the kernel finished, with no host-side
    combine or scalar eqns.

    ``census=True`` adds the non-finite census, moments dual-accumulator
    style: a second VMEM scratch (``maybe_cacc``) folds the 0/1
    not-isfinite mask of every masked, pre-prologue block through the same
    ones-dot, and the emit widens -- the epilogue path emits (1, 2)
    [chained total, NaN/Inf count], the partials path (1, 2, m, m)
    [acc, census acc] -- at zero extra input bytes."""
    j = pl.program_id(1)
    cacc_ref = maybe_cacc[0] if census else None

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        if census:
            cacc_ref[...] = jnp.zeros_like(cacc_ref)

    base = (j * c + pl.program_id(0)) * r * m * m
    tiles = _load_tiles(x_ref, base, n, r, m, compute_dtype, needs_mask)
    if census:  # census BEFORE the prologue: count the raw masked values
        cacc_ref[...] += jnp.sum(
            _block_row_sums(_tile_nonfinite(tiles, compute_dtype),
                            compute_dtype),
            axis=0,
        )
    tiles = common.apply_prologue(tiles, prologue)
    d = _block_row_sums(tiles, compute_dtype)
    acc_ref[...] += jnp.sum(d, axis=0)  # batched-MMA partial fold (f32, VPU-add
    # of R tiles; R is small and this models the MXU's native C-accumulation)

    @pl.when(j == pl.num_programs(1) - 1)
    def _emit():
        if epilogue:  # static: in-launch collapse + scalar chain
            onesf = common.ones_mma(m, jnp.float32)
            total = jnp.dot(
                onesf, acc_ref[...], preferred_element_type=jnp.float32
            )
            o_ref[0, 0] = common.apply_epilogue(total[0, 0], epilogue)
            if census:
                ctotal = jnp.dot(
                    onesf, cacc_ref[...], preferred_element_type=jnp.float32
                )
                o_ref[0, 1] = ctotal[0, 0]
        elif census:
            o_ref[0, 0] = acc_ref[...]
            o_ref[0, 1] = cacc_ref[...]
        else:
            o_ref[0] = acc_ref[...]


def fused_moments_kernel(
    x_ref, o_ref, acc_ref, acc2_ref, *, n, r, c, m, compute_dtype, needs_mask
):
    """Fused lane under the moments prologue: the paired (x, x^2)
    DUAL-ACCUMULATOR. Each block is loaded once and feeds two batched MMAs
    (X_t @ 1 and X_t^2 @ 1) into separate VMEM accumulators, so one pass
    over the raw leaf yields both statistics LayerNorm-style consumers
    need; the lane emits the (2, m, m) pair and ops.py collapses each half
    with the same deterministic fixed-order combine."""
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        acc2_ref[...] = jnp.zeros_like(acc2_ref)

    base = (j * c + pl.program_id(0)) * r * m * m
    tiles = _load_tiles(x_ref, base, n, r, m, compute_dtype, needs_mask)
    acc_ref[...] += jnp.sum(_block_row_sums(tiles, compute_dtype), axis=0)
    acc2_ref[...] += jnp.sum(
        _block_row_sums(tiles * tiles, compute_dtype), axis=0
    )

    @pl.when(j == pl.num_programs(1) - 1)
    def _emit():
        o_ref[0, 0] = acc_ref[...]
        o_ref[0, 1] = acc2_ref[...]


def fused_kahan_kernel(
    x_ref, o_ref, acc_ref, comp_ref, *, n, r, c, m, compute_dtype, needs_mask,
    prologue="identity",
):
    """Fused lane with a per-lane Kahan carry in a second scratch row.

    Every tile's row-sum contribution is two-summed into (acc, comp), so the
    serial cross-tile carry -- the only part of the lane a single MMA cannot
    compensate -- accumulates O(1) error instead of O(tiles). Both matrices
    are emitted; the host-side combine folds acc and -comp in one
    compensated pass (Kahan's corrected sum is s - c). The elementwise
    prologues compose (a compensated in-kernel sumsq); "moments" does not
    (it needs its own accumulator pair -- the launcher rejects it).
    """
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        comp_ref[...] = jnp.zeros_like(comp_ref)

    base = (j * c + pl.program_id(0)) * r * m * m
    tiles = _load_tiles(x_ref, base, n, r, m, compute_dtype, needs_mask)
    tiles = common.apply_prologue(tiles, prologue)
    d = _block_row_sums(tiles, compute_dtype)
    for t in range(r):  # static unroll: every tile is a compensated add
        y = d[t] - comp_ref[...]
        s = acc_ref[...] + y
        comp_ref[...] = (s - acc_ref[...]) - y
        acc_ref[...] = s

    @pl.when(j == pl.num_programs(1) - 1)
    def _emit():
        o_ref[0, 0] = acc_ref[...]
        o_ref[0, 1] = comp_ref[...]


def reduce_tiles(
    flat: jax.Array,
    *,
    tiles_per_block: int = 8,
    compute_dtype=jnp.bfloat16,
    prologue: str = "identity",
    epilogue: tuple = (),
    interpret: bool | None = None,
) -> jax.Array:
    """Paper-faithful level: (n,) flat native elements -> (T,) partials
    (T = ceil(n / m^2)) via one pallas launch, zero-copy; under
    ``prologue="moments"`` the launch emits the (T, 2) partial PAIR (group
    sums of x and x^2 from one pass).

    Grid steps have no carried state, so the grid is declared ``parallel``:
    on a multi-core chip every core runs its own slice of the element
    stream concurrently -- the paper's "all tile MMAs in parallel"
    assumption. The ragged tail is a masked load of the boundary block.

    ``epilogue`` is legal only on a FINAL level -- a launch whose single
    partial is the total (t == 1) -- where the chain maps it in-kernel.
    """
    interpret = common.resolve_interpret(interpret)
    common.check_prologue(prologue)
    m = MXU
    n = flat.size
    t = max(1, common.ceil_div(n, m * m))
    if epilogue and (t != 1 or prologue == "moments"):
        raise ValueError(
            "reduce_tiles epilogue requires a final single-tile level "
            f"(t == 1, non-moments); got t={t}, prologue={prologue!r}"
        )
    r = max(1, min(tiles_per_block, t))
    blocks = common.ceil_div(t, r)
    tpad = blocks * r
    kernel = functools.partial(
        tile_partials_kernel,
        n=n,
        r=r,
        m=m,
        compute_dtype=compute_dtype,
        needs_mask=tpad * m * m != n,
        prologue=prologue,
        epilogue=epilogue,
    )
    if prologue == "moments":
        out_specs = pl.BlockSpec((r, 2), lambda i: (i, 0))
        out_shape = jax.ShapeDtypeStruct((tpad, 2), jnp.float32)
    else:
        out_specs = pl.BlockSpec((r,), lambda i: (i,))
        out_shape = jax.ShapeDtypeStruct((tpad,), jnp.float32)
    out = pl.pallas_call(
        kernel,
        grid=(blocks,),
        in_specs=[pl.BlockSpec((r * m * m,), lambda i: (i,))],
        out_specs=out_specs,
        out_shape=out_shape,
        compiler_params=common.compiler_params(("parallel",)),
        interpret=interpret,
    )(flat)
    return out[:t]


def _lane_geometry(t: int, tiles_per_block: int, num_cores: int):
    """Clamp + pad the (tiles, block, lanes) geometry for a striped stream.

    Returns ``(r, c, blocks_per_lane, tpad)``: block depth, effective lane
    count (never more lanes than blocks), per-lane sequential block count,
    and the padded tile-stream length ``r * c * blocks_per_lane``.
    Delegates to ``cost_model.stripe_geometry`` -- the kernels must run
    exactly the grid the cost model charges for.
    """
    return cost_model.stripe_geometry(t, tiles_per_block, num_cores)


def reduce_fused(
    flat: jax.Array,
    *,
    tiles_per_block: int = 8,
    num_cores: int = 1,
    compute_dtype=jnp.bfloat16,
    kahan: bool = False,
    prologue: str = "identity",
    epilogue: tuple = (),
    census: bool = False,
    interpret: bool | None = None,
) -> jax.Array:
    """Beyond-paper single-launch reduction: (n,) flat native elements ->
    (C, m, m) lane partials (``kahan=True`` or ``prologue="moments"``:
    (C, 2, m, m) -- compensation rows, resp. the dual-accumulator pair),
    zero-copy. The elementwise prologues (square/abs) map each element
    in-kernel after the cast and tail mask, so sumsq/norm2 stream the raw
    leaf once.

    ``census=True`` (non-kahan, non-moments -- both need the second scratch
    for themselves) rides the non-finite census on the same read: partials
    widen to (C, 2, m, m) with half 1 the census accumulator; with an
    in-kernel ``epilogue`` the launch emits (1, 2)
    [chained total, NaN/Inf count].

    The element stream is striped block-wise across ``num_cores`` lanes (the
    tail beyond n is a masked boundary load, never a padded copy); the
    caller collapses the partials with ``combine_lane_partials``
    (deterministic, fixed lane order).

    ``epilogue`` (single-lane, non-kahan, non-moments launches only -- the
    caller pre-computes the effective lane count via
    ``cost_model.stripe_geometry``) moves the collapse in-kernel: the
    launch returns the (1, 1) finished statistic instead of lane partials.
    """
    interpret = common.resolve_interpret(interpret)
    common.check_prologue(prologue)
    if kahan and prologue == "moments":
        raise ValueError(
            "prologue='moments' needs its own accumulator pair and does not "
            "compose with the in-kernel Kahan carry; run the moments pass "
            "at precision='native' (or compensate the two sums separately)"
        )
    m = MXU
    n = flat.size
    t = max(1, common.ceil_div(n, m * m))
    r, c, blocks_per_lane, tpad = _lane_geometry(t, tiles_per_block, num_cores)
    if epilogue and (c != 1 or kahan or prologue == "moments"):
        raise ValueError(
            "reduce_fused epilogue requires a single-lane, non-kahan, "
            f"non-moments launch; got c={c}, kahan={kahan}, "
            f"prologue={prologue!r}"
        )
    if census and (kahan or prologue == "moments"):
        raise ValueError(
            "reduce_fused census does not compose with kahan or "
            "prologue='moments' (both own the second scratch accumulator)"
        )
    needs_mask = tpad * m * m != n
    if kahan or prologue == "moments":
        if kahan:
            kernel = functools.partial(
                fused_kahan_kernel, n=n, r=r, c=c, m=m,
                compute_dtype=compute_dtype, needs_mask=needs_mask,
                prologue=prologue,
            )
        else:
            kernel = functools.partial(
                fused_moments_kernel, n=n, r=r, c=c, m=m,
                compute_dtype=compute_dtype, needs_mask=needs_mask,
            )
        out_shape = jax.ShapeDtypeStruct((c, 2, m, m), jnp.float32)
        out_specs = pl.BlockSpec((1, 2, m, m), lambda ci, j: (ci, 0, 0, 0))
        scratch = [
            common.vmem_scratch((m, m), jnp.float32),
            common.vmem_scratch((m, m), jnp.float32),
        ]
    else:
        kernel = functools.partial(
            fused_accumulate_kernel, n=n, r=r, c=c, m=m,
            compute_dtype=compute_dtype, needs_mask=needs_mask,
            prologue=prologue, epilogue=epilogue, census=census,
        )
        if epilogue:
            cols = 2 if census else 1
            out_shape = jax.ShapeDtypeStruct((1, cols), jnp.float32)
            out_specs = pl.BlockSpec((1, cols), lambda ci, j: (0, 0))
        elif census:
            out_shape = jax.ShapeDtypeStruct((c, 2, m, m), jnp.float32)
            out_specs = pl.BlockSpec(
                (1, 2, m, m), lambda ci, j: (ci, 0, 0, 0)
            )
        else:
            out_shape = jax.ShapeDtypeStruct((c, m, m), jnp.float32)
            out_specs = pl.BlockSpec((1, m, m), lambda ci, j: (ci, 0, 0))
        scratch = [common.vmem_scratch((m, m), jnp.float32)]
        if census:
            scratch.append(common.vmem_scratch((m, m), jnp.float32))
    return pl.pallas_call(
        kernel,
        grid=(c, blocks_per_lane),
        # striping: lane ci owns blocks ci, ci+c, ci+2c, ... so concurrent
        # lanes stream CONTIGUOUS HBM at every step (coalesced across cores).
        in_specs=[
            pl.BlockSpec((r * m * m,), lambda ci, j, c=c: (j * c + ci,))
        ],
        out_specs=out_specs,
        out_shape=out_shape,
        scratch_shapes=scratch,
        compiler_params=common.compiler_params(("parallel", "arbitrary")),
        interpret=interpret,
    )(flat)


def segmented_gather_kernel(
    src_ref, seg_ref, flush_ref, lo_ref, hi_ref, x_ref, o_ref, acc_ref,
    *maybe_acc2, num_cores, m, compute_dtype, prologue="identity",
    epilogue=(),
    moments_offset=0,
    census_offset=0,
):
    """Striped segmented single-launch multi-reduce over ONE flat buffer.

    The five scalar-prefetched (SMEM) int32 maps cover the whole
    aligned-block tile stream, indexed by ORIGINAL stream position
    (``ops.segment_cover_layout`` builds them trace-time):

      ``src_ref``   -- which m^2-aligned block of the caller's flat buffer
                       this tile reads (consumed by the BlockSpec index map,
                       so the DMA itself does the gather);
      ``lo_ref`` / ``hi_ref`` -- the tile's validity window within its
                       block: elements with in-block position in [lo, hi)
                       belong to this tile's segment, the rest are masked
                       (this is how a non-aligned boundary shares its block
                       with the neighbouring segment);
      ``seg_ref``   -- tile -> segment id;
      ``flush_ref`` -- lane-aware flush flag (1 on the last tile of each
                       segment *within its lane's stripe* -- ops.py builds
                       it, so each lane flushes exactly once per segment it
                       touches).

    The grid is (num_cores, tiles_per_lane) with ("parallel", "arbitrary")
    semantics; lane ci streams tiles ci, ci+C, ... sequentially, its
    accumulator carries across its own tiles only, and each flush collapses
    it with one trailing f32 MMA into the lane's row of the (num_cores, S)
    sub-partial output. Trailing pad tiles carry lo == hi == 0 (fully
    masked) and no flush bit: they add exact zeros to an accumulator nobody
    reads again.

    ``prologue`` maps each masked tile before the accumulate (identity adds
    no ops); ``prologue="moments"`` carries the (x, x^2) dual accumulator
    (``maybe_acc2`` holds the second scratch) and each flush writes the
    segment's sum to column ``seg`` and its sum of squares to column
    ``seg + moments_offset`` of the widened (C, 2S) output.

    ``epilogue`` (normalized scalar chain; single-lane launches only -- each
    segment then flushes exactly once, so its flushed value IS its total)
    maps every flushed per-segment scalar in-kernel before the write.

    ``census_offset`` (> 0 enables; does not compose with "moments" -- the
    launcher rejects that) rides the non-finite census on the same gather:
    a second scratch (the trailing ``maybe_acc2`` ref) folds the 0/1
    not-isfinite mask of each windowed tile, and every flush writes the
    segment's NaN/Inf count to column ``seg + census_offset`` of the
    widened (C, 2S) output. The [lo, hi) window masks shared boundary
    blocks to exact zeros, so each element is counted exactly once.
    """
    j = pl.program_id(1)
    cacc_ref = maybe_acc2[-1] if census_offset else None

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        o_ref[...] = jnp.zeros_like(o_ref)
        if prologue == "moments":
            maybe_acc2[0][...] = jnp.zeros_like(maybe_acc2[0])
        if census_offset:
            cacc_ref[...] = jnp.zeros_like(cacc_ref)

    t = j * num_cores + pl.program_id(0)  # original stream position
    xv = x_ref[...].reshape(m, m).astype(compute_dtype)
    row = jax.lax.broadcasted_iota(jnp.int32, (m, m), 0)
    col = jax.lax.broadcasted_iota(jnp.int32, (m, m), 1)
    lin = row * m + col
    mask = (lin >= lo_ref[t]) & (lin < hi_ref[t])
    xv = jnp.where(mask, xv, jnp.zeros_like(xv))
    if census_offset:  # census BEFORE the prologue: count raw masked values
        cacc_ref[...] += _tile_row_sums(
            _tile_nonfinite(xv, compute_dtype), compute_dtype
        )
    if prologue == "moments":
        acc_ref[...] += _tile_row_sums(xv, compute_dtype)
        maybe_acc2[0][...] += _tile_row_sums(xv * xv, compute_dtype)
    else:
        acc_ref[...] += _tile_row_sums(
            common.apply_prologue(xv, prologue), compute_dtype
        )

    @pl.when(flush_ref[t] != 0)
    def _flush():
        # one trailing MMA collapses the accumulated row-sums: 1 x acc.
        onesf = common.ones_mma(m, jnp.float32)
        total = jnp.dot(onesf, acc_ref[...], preferred_element_type=jnp.float32)
        o_ref[0, pl.ds(seg_ref[t], 1)] = common.apply_epilogue(
            total[:1, 0], epilogue
        )
        acc_ref[...] = jnp.zeros_like(acc_ref)
        if prologue == "moments":
            total2 = jnp.dot(
                onesf, maybe_acc2[0][...], preferred_element_type=jnp.float32
            )
            o_ref[0, pl.ds(seg_ref[t] + moments_offset, 1)] = total2[:1, 0]
            maybe_acc2[0][...] = jnp.zeros_like(maybe_acc2[0])
        if census_offset:
            ctotal = jnp.dot(
                onesf, cacc_ref[...], preferred_element_type=jnp.float32
            )
            o_ref[0, pl.ds(seg_ref[t] + census_offset, 1)] = ctotal[:1, 0]
            cacc_ref[...] = jnp.zeros_like(cacc_ref)


def reduce_segments(
    flat: jax.Array,
    src_blk: jax.Array,
    seg_of: jax.Array,
    flush: jax.Array,
    lo_in: jax.Array,
    hi_in: jax.Array,
    num_segments: int,
    *,
    num_cores: int = 1,
    compute_dtype=jnp.bfloat16,
    prologue: str = "identity",
    epilogue: tuple = (),
    census: bool = False,
    interpret: bool | None = None,
) -> jax.Array:
    """Single-launch segmented gather reduction: (n,) flat native buffer +
    (T,) cover maps -> (C, S) lane sub-partials; the caller sums lanes
    (``combine_segment_partials``). ``prologue="moments"`` widens the
    output to (C, 2S): columns [0, S) carry the per-segment sums, columns
    [S, 2S) the sums of squares, both from one pass over the buffer.
    ``census=True`` (non-moments) widens the same way, columns [S, 2S)
    instead carrying each segment's NON-FINITE element count (lanes add).

    The maps are trace-time constants (segment offsets are static) built by
    ``ops.segment_cover_layout`` / ``ops.lane_flush_map`` (``flush`` must be
    LANE-AWARE for ``num_cores > 1``). Striping is tile-granular -- the
    gather fixes the block depth at one tile, so ``tiles_per_block`` plays
    no role on this path -- and the maps are padded here to whole lanes
    (src 0, lo == hi == 0: fully-masked no-op tiles).

    ``epilogue`` (single-lane, non-moments launches only: every segment
    then flushes exactly once, so its flush IS its total) maps each
    per-segment scalar in-kernel before the slot write.
    """
    interpret = common.resolve_interpret(interpret)
    common.check_prologue(prologue)
    m = MXU
    t = int(src_blk.shape[0])
    _, c, tiles_per_lane, tpad = _lane_geometry(t, 1, num_cores)
    if epilogue and (c != 1 or prologue == "moments"):
        raise ValueError(
            "reduce_segments epilogue requires a single-lane, non-moments "
            f"launch; got c={c}, prologue={prologue!r}"
        )
    if census and prologue == "moments":
        raise ValueError(
            "reduce_segments census does not compose with prologue="
            "'moments' (both widen the output to (C, 2S))"
        )

    def _pad_map(a):
        return common.pad_to(jnp.asarray(a, jnp.int32), tpad, axis=0)

    src_blk, seg_of, flush, lo_in, hi_in = map(
        _pad_map, (src_blk, seg_of, flush, lo_in, hi_in)
    )
    dual = prologue == "moments"
    out_cols = (2 * num_segments) if (dual or census) else num_segments
    scratch = [common.vmem_scratch((m, m), jnp.float32)]
    if dual or census:
        scratch.append(common.vmem_scratch((m, m), jnp.float32))
    kernel = functools.partial(
        segmented_gather_kernel, num_cores=c, m=m,
        compute_dtype=compute_dtype, prologue=prologue, epilogue=epilogue,
        moments_offset=num_segments if dual else 0,
        census_offset=num_segments if census else 0,
    )
    return pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=5,
            grid=(c, tiles_per_lane),
            in_specs=[
                # the gather: the DMA source block is read from the
                # prefetched cover map, straight off the caller's buffer.
                pl.BlockSpec(
                    (m * m,),
                    lambda ci, j, src_ref, *_, c=c: (src_ref[j * c + ci],),
                )
            ],
            out_specs=pl.BlockSpec(
                (1, out_cols), lambda ci, j, *_: (ci, 0)
            ),
            scratch_shapes=scratch,
        ),
        out_shape=jax.ShapeDtypeStruct((c, out_cols), jnp.float32),
        compiler_params=common.compiler_params(("parallel", "arbitrary")),
        interpret=interpret,
    )(
        src_blk,
        seg_of,
        flush,
        lo_in,
        hi_in,
        flat,
    )


def _tile_nonfinite(xv, compute_dtype):
    """(m, m) compute-dtype tile -> (m, m) 0/1 non-finite mask, ready for the
    ones-dot fold: the finiteness CENSUS is just another masked reduction
    riding the same tile (NaN/Inf -> 1, everything else -> 0; masked pad
    lanes are exact zeros, hence finite, hence never counted). The 0/1 mask
    is exact in any compute dtype and the MMA accumulates it in f32, so the
    count is exact up to 2^24 elements per slot."""
    return (~jnp.isfinite(xv)).astype(compute_dtype)


def parts_accumulate_kernel(
    *refs, layout, m, compute_dtype, prologues=None, moments_offset=0,
    slot_epilogue=(), total_chains=None, chain_offset=None, census_offset=None,
):
    """S separate flat arrays -> (S,) per-segment totals, one launch.

    ``layout`` is the static schedule: one ``(seg, start, nblk, size)``
    tuple per live part, assigning it the tile run [start, start + nblk) of
    the shared sequential grid. The body is statically unrolled over parts;
    at any grid step exactly one ``pl.when`` fires (runs are disjoint), the
    active part's tile is masked against its true ``size`` and folded into
    the shared accumulator, and the part's last tile flushes its total with
    one trailing f32 MMA into the (static) output slot. Empty parts never
    enter the layout -- the j == 0 init leaves their slots at the additive
    identity. Everything the kernel branches on is trace-time static, so
    there is no scalar prefetch; the cost is O(S) compiled branches
    (ops.py bounds S).

    ``prologues`` (one name per layout entry; None = all identity) selects
    each part's in-kernel elementwise map. A part with prologue "moments"
    accumulates the (x, x^2) pair -- the second scratch accumulator is the
    trailing ref -- and flushes its sum to slot ``seg`` and its sum of
    squares to slot ``seg + moments_offset``, so both statistics of every
    leaf ride the SAME single read of its buffer.

    ``slot_epilogue`` (normalized scalar chain) maps EVERY flushed per-part
    total before its slot write. ``total_chains`` (tuple of K chains) adds
    the TREE total: a (1,) f32 scratch (the trailing ref) accumulates the
    raw flushed totals across the sequential grid -- part flush order is
    static and deterministic -- and the LAST part's flush emits chain k of
    the running cross-part total into slot ``num_slots + k``, so a whole
    tree's norm AND its clip coefficient leave this one launch finished
    (``total_chains`` composes with ``slot_epilogue`` on the per-slot
    writes but not with "moments" parts -- the launcher rejects that).

    ``census_offset`` (an output-slot index; None disables) adds the
    NON-FINITE CENSUS: a second (m, m) accumulator folds the 0/1
    not-isfinite mask of every masked tile through the SAME ones-dot MMA,
    each part's flush writes its count to slot ``census_offset + seg``, a
    (1,) scratch carries the running cross-part count, and the last part's
    flush emits it into the final slot -- per-leaf and total NaN/Inf counts
    with ZERO extra input bytes (the mask is computed on the tile already in
    registers). Pad lanes are masked to exact zeros before the mask, so the
    ragged tail never under- or over-counts. Census does not compose with
    "moments" parts (the launcher rejects that); ``chain_offset`` then pins
    the total-chain slots explicitly (census slots sit after them)."""
    if prologues is None:
        prologues = ("identity",) * len(layout)
    dual = "moments" in prologues
    part_refs = refs[: len(layout)]
    rest = refs[len(layout):]
    o_ref, acc_ref = rest[0], rest[1]
    idx = 2
    acc2_ref = None
    if dual:
        acc2_ref = rest[idx]
        idx += 1
    tot_ref = None
    if total_chains:
        tot_ref = rest[idx]
        idx += 1
    cacc_ref = ctot_ref = None
    if census_offset is not None:
        cacc_ref, ctot_ref = rest[idx], rest[idx + 1]
    n_chains = len(total_chains) if total_chains else 0
    num_slots = chain_offset if chain_offset is not None else (
        o_ref.shape[0] - n_chains
    )
    j = pl.program_id(0)

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        o_ref[...] = jnp.zeros_like(o_ref)
        if dual:
            acc2_ref[...] = jnp.zeros_like(acc2_ref)
        if total_chains:
            tot_ref[...] = jnp.zeros_like(tot_ref)
        if census_offset is not None:
            cacc_ref[...] = jnp.zeros_like(cacc_ref)
            ctot_ref[...] = jnp.zeros_like(ctot_ref)

    row = jax.lax.broadcasted_iota(jnp.int32, (m, m), 0)
    col = jax.lax.broadcasted_iota(jnp.int32, (m, m), 1)
    lin = row * m + col
    for ref, (seg, start, nblk, size), pro in zip(part_refs, layout, prologues):

        @pl.when((j >= start) & (j < start + nblk))
        def _accumulate(
            ref=ref, seg=seg, start=start, nblk=nblk, size=size, pro=pro
        ):
            valid = size - (j - start) * m * m  # ragged tail of THIS part
            xv = ref[...].reshape(m, m).astype(compute_dtype)
            if size % (m * m):  # static: tile-multiple parts skip the mask
                xv = jnp.where(lin < valid, xv, jnp.zeros_like(xv))
            if census_offset is not None:
                # census BEFORE the prologue: count the raw (masked) values,
                # not their squares -- same tile, one extra ones-dot MMA
                cacc_ref[...] += _tile_row_sums(
                    _tile_nonfinite(xv, compute_dtype), compute_dtype
                )
            if pro == "moments":
                acc_ref[...] += _tile_row_sums(xv, compute_dtype)
                acc2_ref[...] += _tile_row_sums(xv * xv, compute_dtype)
            else:
                acc_ref[...] += _tile_row_sums(
                    common.apply_prologue(xv, pro), compute_dtype
                )

            @pl.when(j == start + nblk - 1)
            def _flush():
                onesf = common.ones_mma(m, jnp.float32)
                total = jnp.dot(
                    onesf, acc_ref[...], preferred_element_type=jnp.float32
                )
                o_ref[seg] = common.apply_epilogue(total[0, 0], slot_epilogue)
                acc_ref[...] = jnp.zeros_like(acc_ref)
                if pro == "moments":
                    total2 = jnp.dot(
                        onesf, acc2_ref[...],
                        preferred_element_type=jnp.float32,
                    )
                    o_ref[seg + moments_offset] = common.apply_epilogue(
                        total2[0, 0], slot_epilogue
                    )
                    acc2_ref[...] = jnp.zeros_like(acc2_ref)
                if total_chains:
                    # sequential cross-part fold of the RAW totals (f32,
                    # static part order -> deterministic, same contraction
                    # order as the host-side jnp.sum over the (S,) slots).
                    tot_ref[0] += total[0, 0]
                    # layout is start-ordered, so the last layout entry
                    # flushes on the final grid step: emit the chains there.
                    if seg == layout[-1][0]:
                        for k, chain in enumerate(total_chains):
                            o_ref[num_slots + k] = common.apply_epilogue(
                                tot_ref[0], chain
                            )
                if census_offset is not None:
                    ctile = jnp.dot(
                        onesf, cacc_ref[...],
                        preferred_element_type=jnp.float32,
                    )
                    o_ref[census_offset + seg] = ctile[0, 0]
                    cacc_ref[...] = jnp.zeros_like(cacc_ref)
                    ctot_ref[0] += ctile[0, 0]
                    if seg == layout[-1][0]:
                        o_ref[o_ref.shape[0] - 1] = ctot_ref[0]


def reduce_parts(
    parts: list[jax.Array],
    layout: tuple[tuple[int, int, int, int], ...],
    num_segments: int,
    *,
    compute_dtype=jnp.bfloat16,
    prologues: tuple[str, ...] | None = None,
    moments_offset: int = 0,
    slot_epilogue: tuple = (),
    total_chains: tuple | None = None,
    census: bool = False,
    interpret: bool | None = None,
) -> jax.Array:
    """One launch over S separate native-dtype flat arrays -> (S,) totals
    (``num_segments`` counts OUTPUT slots: a moments part owns two).

    ``parts`` holds only the LIVE (non-empty) arrays, in ``layout`` order
    (``ops.parts_layout`` builds both; ``prologues`` aligns with it). Each
    part's BlockSpec clamps its block index into its own tile run, so
    outside the run the spec dwells on an already-resident block (Pallas
    re-DMAs only on index change -- the dwell moves no bytes) and the total
    traffic is exactly the parts' native bytes plus the output row --
    including under "moments", where both statistics ride one read.

    ``slot_epilogue`` maps every flushed per-part total in-kernel;
    ``total_chains`` (tuple of K normalized chains) widens the output to
    (num_segments + K,), slot ``num_segments + k`` carrying chain k of the
    cross-part RAW total -- the reduce_tree consumer's norm/clip, fully
    in-kernel at ANY core count (this grid is sequential and ignores
    ``num_cores`` altogether). Neither composes with "moments" parts.

    ``census=True`` widens the output further to
    (num_segments + K + num_segments + 1,): slot
    ``num_segments + K + seg`` carries part ``seg``'s NON-FINITE element
    count and the final slot the total across all parts -- the guarded
    optimizer's NaN/Inf detector, riding the same single read of every
    part (zero extra input bytes; see ``parts_accumulate_kernel``).
    """
    interpret = common.resolve_interpret(interpret)
    if prologues is not None:
        for p in prologues:
            common.check_prologue(p)
    if (slot_epilogue or total_chains or census) and (
        prologues is not None and "moments" in prologues
    ):
        raise ValueError(
            "parts epilogues/census do not compose with a 'moments' part "
            "(its flush writes two coupled slots); drop the epilogue or "
            "run the moments leaf as separate 'identity'/'square' parts"
        )
    m = MXU
    total_blocks = layout[-1][1] + layout[-1][2] if layout else 0
    n_chains = len(total_chains) if total_chains else 0
    num_out = num_segments + n_chains + ((num_segments + 1) if census else 0)
    in_specs = [
        pl.BlockSpec(
            (m * m,),
            lambda j, start=start, nblk=nblk: (
                jnp.clip(j - start, 0, nblk - 1),
            ),
        )
        for (_, start, nblk, _) in layout
    ]
    kernel = functools.partial(
        parts_accumulate_kernel,
        layout=layout,
        m=m,
        compute_dtype=compute_dtype,
        prologues=prologues,
        moments_offset=moments_offset,
        slot_epilogue=slot_epilogue,
        total_chains=total_chains,
        chain_offset=num_segments if census else None,
        census_offset=(num_segments + n_chains) if census else None,
    )
    scratch = [common.vmem_scratch((m, m), jnp.float32)]
    if prologues is not None and "moments" in prologues:
        scratch.append(common.vmem_scratch((m, m), jnp.float32))
    if total_chains:
        scratch.append(common.vmem_scratch((1,), jnp.float32))
    if census:
        scratch.append(common.vmem_scratch((m, m), jnp.float32))
        scratch.append(common.vmem_scratch((1,), jnp.float32))
    return pl.pallas_call(
        kernel,
        grid=(total_blocks,),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((num_out,), lambda j: (0,)),
        out_shape=jax.ShapeDtypeStruct((num_out,), jnp.float32),
        scratch_shapes=scratch,
        compiler_params=common.compiler_params(("arbitrary",)),
        interpret=interpret,
    )(*parts)
