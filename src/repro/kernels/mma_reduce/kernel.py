"""Pallas TPU kernels for the paper's MMA reduction.

Three kernel bodies:

``tile_partials_kernel`` -- paper-faithful: every (m, m) VMEM tile goes
  through the 2-MMA sequence of eqs. (9)-(12); each grid step emits its
  per-tile group sums. The hierarchy (eq. 13) is driven from ops.py by
  re-invoking the kernel on the partials, exactly like the paper's repeated
  kernel launches. Grid steps are independent, so the (single) grid
  dimension is marked ``parallel`` -- every core reduces its own tiles
  concurrently, which is the premise behind the paper's
  ``T(n) = 5 log_{m^2}(n)`` model (all n/m^2 tile MMAs in flight at once).

``fused_accumulate_kernel`` -- beyond-paper optimization: the paper always
  passes C = 0 to the MMA and writes partials back to memory between levels.
  On TPU we instead use the accumulate operand the hardware already gives us:
  a VMEM-resident f32 accumulator matrix serves as C across grid steps
  (acc <- X_t @ 1 + acc), so each tile costs ONE MMA instead of two and no
  intermediate level ever touches HBM.

  Multi-core streaming: the grid is 2D -- ``(num_cores, blocks_per_lane)``
  with ``dimension_semantics=("parallel", "arbitrary")``. The tile stream is
  STRIPED across ``num_cores`` independent lanes (lane c owns blocks
  c, c+C, c+2C, ...), each lane carries its own VMEM f32 accumulator across
  its sequential ``arbitrary`` dimension and emits one (m, m) partial; a tiny
  deterministic fixed-order combine in ops.py collapses the lanes (one
  batched f32 MMA + one length-C dot), so results are bit-reproducible
  run-to-run. MMA count: n/(m^2 c) + 1 per lane, + (c + 1) for the combine,
  vs the paper's ~2.008 n/m^2 on one core; see EXPERIMENTS.md.

  ``kahan=True`` adds a second VMEM scratch row carrying a per-lane Kahan
  compensation: every tile contribution is two-summed into (acc, comp) and
  both matrices are emitted, so the cross-tile carry -- the serial part of
  the reduction -- is compensated without leaving the single launch. The
  host-side combine then folds acc and -comp in one compensated pass.

``segmented_accumulate_kernel`` -- the fused C-accumulator loop generalized
  to MANY independent reductions in ONE launch (Dakkak et al.'s segmented
  TCU reduction transplanted onto the fused variant): the input is a single
  concatenated, tile-padded stream of every segment's data, plus two
  scalar-prefetched maps (tile -> segment id, tile -> flush flag). The same
  (cores, blocks) striped grid applies: each lane accumulates the slice of
  every segment that lands in its stripe and flushes a per-(lane, segment)
  sub-partial whenever its OWN stripe leaves a segment (the flush map is
  lane-aware, built trace-time in ops.py), then one exact f32 per-segment
  combine sums the (num_cores, S) sub-partials in fixed lane order. MMA
  count: n/m^2 main MMAs (striped across lanes) + one flush MMA per
  lane-segment visit -- at most S per lane (<= S*C total), exactly the
  serial S at C = 1.

Block geometry: each grid step stages `tiles_per_block` (m, m) tiles
(m = 128 = MXU dim) from HBM into VMEM -- at the default 8 tiles that is a
8*128*128*4B = 512 KiB f32 working set per core, well inside the ~16 MiB
VMEM budget and large enough to hide DMA latency behind the systolic
pipeline.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core import cost_model
from repro.kernels import common

MXU = common.MXU


def _two_mma(tiles_f32: jax.Array, compute_dtype) -> jax.Array:
    """(R, m, m) -> (R,) via the paper's two all-ones MMAs, f32 accumulate."""
    m = tiles_f32.shape[-1]
    ones = jnp.ones((m, m), compute_dtype)
    d = jax.lax.dot_general(
        tiles_f32.astype(compute_dtype),
        jnp.broadcast_to(ones, tiles_f32.shape),
        (((2,), (1,)), ((0,), (0,))),
        preferred_element_type=jnp.float32,
    )
    d2 = jax.lax.dot_general(
        jnp.broadcast_to(ones, d.shape),
        d.astype(compute_dtype),
        (((2,), (1,)), ((0,), (0,))),
        preferred_element_type=jnp.float32,
    )
    return d2[:, 0, 0]


def tile_partials_kernel(x_ref, o_ref, *, compute_dtype):
    """One grid step: (R, m, m) tiles -> (R,) partials. Paper-faithful."""
    o_ref[...] = _two_mma(x_ref[...], compute_dtype)


def _block_row_sums(tiles, compute_dtype):
    """(r, m, m) block -> (r, m, m) column-replicated row sums: D = X @ 1.

    One batched MMA per block; the accumulate operand (C) is carried by the
    caller's VMEM accumulator, exactly the MXU's native accumulation mode.
    """
    m = tiles.shape[-1]
    ones = jnp.ones((m, m), compute_dtype)
    return jax.lax.dot_general(
        tiles.astype(compute_dtype),
        jnp.broadcast_to(ones, tiles.shape),
        (((2,), (1,)), ((0,), (0,))),
        preferred_element_type=jnp.float32,
    )


def fused_accumulate_kernel(x_ref, o_ref, acc_ref, *, compute_dtype):
    """Striped grid-accumulating reduction: one lane of the 2D grid.

    Grid is (num_cores, blocks_per_lane) with semantics ("parallel",
    "arbitrary"): dimension 0 indexes the lane (spread across cores, each
    with its own acc scratch instance), dimension 1 the lane's sequential
    block stream. Each step performs one batched MMA per tile block:
    acc += sum_t X_t @ 1. On the lane's last step the raw (m, m) accumulator
    is emitted as this lane's partial; the deterministic collapse runs in
    ops.py (``combine_lane_partials``).
    """
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    d = _block_row_sums(x_ref[...], compute_dtype)
    acc_ref[...] += jnp.sum(d, axis=0)  # batched-MMA partial fold (f32, VPU-add
    # of R tiles; R is small and this models the MXU's native C-accumulation)

    @pl.when(j == pl.num_programs(1) - 1)
    def _emit():
        o_ref[0] = acc_ref[...]


def fused_kahan_kernel(x_ref, o_ref, acc_ref, comp_ref, *, compute_dtype):
    """Fused lane with a per-lane Kahan carry in a second scratch row.

    Every tile's row-sum contribution is two-summed into (acc, comp), so the
    serial cross-tile carry -- the only part of the lane a single MMA cannot
    compensate -- accumulates O(1) error instead of O(tiles). Both matrices
    are emitted; the host-side combine folds acc and -comp in one
    compensated pass (Kahan's corrected sum is s - c).
    """
    j = pl.program_id(1)
    r = x_ref.shape[0]

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        comp_ref[...] = jnp.zeros_like(comp_ref)

    d = _block_row_sums(x_ref[...], compute_dtype)
    for t in range(r):  # static unroll: every tile is a compensated add
        y = d[t] - comp_ref[...]
        s = acc_ref[...] + y
        comp_ref[...] = (s - acc_ref[...]) - y
        acc_ref[...] = s

    @pl.when(j == pl.num_programs(1) - 1)
    def _emit():
        o_ref[0, 0] = acc_ref[...]
        o_ref[0, 1] = comp_ref[...]


def reduce_tiles(
    tiles: jax.Array,
    *,
    tiles_per_block: int = 8,
    compute_dtype=jnp.bfloat16,
    interpret: bool | None = None,
) -> jax.Array:
    """Paper-faithful level: (T, m, m) tiles -> (T,) partials via pallas.

    Grid steps have no carried state, so the grid is declared ``parallel``:
    on a multi-core chip every core runs its own slice of the tile stream
    concurrently -- the paper's "all tile MMAs in parallel" assumption.
    """
    interpret = common.resolve_interpret(interpret)
    t, m, _ = tiles.shape
    r = min(tiles_per_block, t)
    tpad = common.round_up(t, r)
    tiles = common.pad_to(tiles, tpad, axis=0)
    kernel = functools.partial(tile_partials_kernel, compute_dtype=compute_dtype)
    out = pl.pallas_call(
        kernel,
        grid=(tpad // r,),
        in_specs=[pl.BlockSpec((r, m, m), lambda i: (i, 0, 0))],
        out_specs=pl.BlockSpec((r,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((tpad,), jnp.float32),
        compiler_params=common.compiler_params(("parallel",)),
        interpret=interpret,
    )(tiles)
    return out[:t]


def _lane_geometry(t: int, tiles_per_block: int, num_cores: int):
    """Clamp + pad the (tiles, block, lanes) geometry for a striped stream.

    Returns ``(r, c, blocks_per_lane, tpad)``: block depth, effective lane
    count (never more lanes than blocks), per-lane sequential block count,
    and the padded tile-stream length ``r * c * blocks_per_lane``.
    Delegates to ``cost_model.stripe_geometry`` -- the kernels must run
    exactly the grid the cost model charges for.
    """
    return cost_model.stripe_geometry(t, tiles_per_block, num_cores)


def reduce_fused(
    tiles: jax.Array,
    *,
    tiles_per_block: int = 8,
    num_cores: int = 1,
    compute_dtype=jnp.bfloat16,
    kahan: bool = False,
    interpret: bool | None = None,
) -> jax.Array:
    """Beyond-paper single-launch reduction: (T, m, m) -> (C, m, m) lane
    partials (``kahan=True``: (C, 2, m, m) with the compensation rows).

    The stream is zero-padded to whole lanes and striped block-wise across
    ``num_cores`` lanes; the caller collapses the partials with
    ``combine_lane_partials`` (deterministic, fixed lane order).
    """
    interpret = common.resolve_interpret(interpret)
    t, m, _ = tiles.shape
    r, c, blocks_per_lane, tpad = _lane_geometry(t, tiles_per_block, num_cores)
    tiles = common.pad_to(tiles, tpad, axis=0)
    if kahan:
        kernel = functools.partial(fused_kahan_kernel, compute_dtype=compute_dtype)
        out_shape = jax.ShapeDtypeStruct((c, 2, m, m), jnp.float32)
        out_specs = pl.BlockSpec((1, 2, m, m), lambda ci, j: (ci, 0, 0, 0))
        scratch = [
            common.vmem_scratch((m, m), jnp.float32),
            common.vmem_scratch((m, m), jnp.float32),
        ]
    else:
        kernel = functools.partial(
            fused_accumulate_kernel, compute_dtype=compute_dtype
        )
        out_shape = jax.ShapeDtypeStruct((c, m, m), jnp.float32)
        out_specs = pl.BlockSpec((1, m, m), lambda ci, j: (ci, 0, 0))
        scratch = [common.vmem_scratch((m, m), jnp.float32)]
    return pl.pallas_call(
        kernel,
        grid=(c, blocks_per_lane),
        # striping: lane ci owns blocks ci, ci+c, ci+2c, ... so concurrent
        # lanes stream CONTIGUOUS HBM at every step (coalesced across cores).
        in_specs=[pl.BlockSpec((r, m, m), lambda ci, j, c=c: (j * c + ci, 0, 0))],
        out_specs=out_specs,
        out_shape=out_shape,
        scratch_shapes=scratch,
        compiler_params=common.compiler_params(("parallel", "arbitrary")),
        interpret=interpret,
    )(tiles)


def segmented_accumulate_kernel(
    seg_ref, flush_ref, x_ref, o_ref, acc_ref, *, num_cores, compute_dtype
):
    """Striped segmented single-launch multi-reduce (see module docstring).

    ``seg_ref`` / ``flush_ref`` are scalar-prefetched (SMEM) int32 maps over
    the whole tile stream, indexed by ORIGINAL stream position: segment id
    per tile, and a lane-aware flush flag (1 on the last tile of each
    segment *within its lane's stripe* -- built by ops.py, so each lane
    flushes exactly once per segment it touches). The grid is
    (num_cores, blocks_per_lane) with ("parallel", "arbitrary") semantics;
    lane ci streams blocks ci, ci+C, ... sequentially, its accumulator
    carries across its own tiles only, and each flush collapses it with one
    trailing f32 MMA into the lane's row of the (num_cores, S) sub-partial
    output. Trailing pad tiles are all-zero with no flush bit: they only add
    zeros to an accumulator nobody reads again.
    """
    j = pl.program_id(1)
    r, m, _ = x_ref.shape

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        o_ref[...] = jnp.zeros_like(o_ref)

    d = _block_row_sums(x_ref[...], compute_dtype)
    base = (j * num_cores + pl.program_id(0)) * r  # original stream position
    for t in range(r):  # static unroll: r is the (small) block depth
        acc_ref[...] += d[t]

        @pl.when(flush_ref[base + t] != 0)
        def _flush():
            # one trailing MMA collapses the accumulated row-sums: 1 x acc.
            onesf = jnp.ones((m, m), jnp.float32)
            total = jnp.dot(
                onesf, acc_ref[...], preferred_element_type=jnp.float32
            )
            o_ref[0, pl.ds(seg_ref[base + t], 1)] = total[:1, 0]
            acc_ref[...] = jnp.zeros_like(acc_ref)


def reduce_segments(
    tiles: jax.Array,
    seg_of: jax.Array,
    flush: jax.Array,
    num_segments: int,
    *,
    tiles_per_block: int = 8,
    num_cores: int = 1,
    compute_dtype=jnp.bfloat16,
    interpret: bool | None = None,
) -> jax.Array:
    """Single-launch segmented reduction: (T, m, m) tiles -> (C, S) lane
    sub-partials; the caller sums lanes (``combine_segment_partials``).

    ``seg_of`` / ``flush`` are (T,) int32 tile->segment maps (trace-time
    constants in practice -- segment offsets are static). ``flush`` must be
    LANE-AWARE for ``num_cores > 1`` (``ops.lane_flush_map``). The stream is
    padded here to whole lanes (zero tiles, no flush bit), so callers share
    ``reduce_fused``'s any-length contract.
    """
    interpret = common.resolve_interpret(interpret)
    t, m, _ = tiles.shape
    r, c, blocks_per_lane, tpad = _lane_geometry(t, tiles_per_block, num_cores)
    tiles = common.pad_to(tiles, tpad, axis=0)
    seg_of = common.pad_to(jnp.asarray(seg_of, jnp.int32), tpad, axis=0)
    flush = common.pad_to(jnp.asarray(flush, jnp.int32), tpad, axis=0)
    kernel = functools.partial(
        segmented_accumulate_kernel, num_cores=c, compute_dtype=compute_dtype
    )
    return pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=(c, blocks_per_lane),
            in_specs=[
                pl.BlockSpec((r, m, m), lambda ci, j, *_, c=c: (j * c + ci, 0, 0))
            ],
            out_specs=pl.BlockSpec(
                (1, num_segments), lambda ci, j, *_: (ci, 0)
            ),
            scratch_shapes=[common.vmem_scratch((m, m), jnp.float32)],
        ),
        out_shape=jax.ShapeDtypeStruct((c, num_segments), jnp.float32),
        compiler_params=common.compiler_params(("parallel", "arbitrary")),
        interpret=interpret,
    )(
        seg_of,
        flush,
        tiles,
    )
