"""Pallas TPU kernels for the paper's MMA reduction.

Three kernel bodies:

``tile_partials_kernel`` -- paper-faithful: every (m, m) VMEM tile goes
  through the 2-MMA sequence of eqs. (9)-(12); each grid step emits its
  per-tile group sums. The hierarchy (eq. 13) is driven from ops.py by
  re-invoking the kernel on the partials, exactly like the paper's repeated
  kernel launches.

``fused_accumulate_kernel`` -- beyond-paper optimization: the paper always
  passes C = 0 to the MMA and writes partials back to memory between levels.
  On TPU we instead use the accumulate operand the hardware already gives us:
  a VMEM-resident f32 accumulator matrix serves as C across *all* grid steps
  (acc <- X_t @ 1 + acc), so each tile costs ONE MMA instead of two and no
  intermediate level ever touches HBM. A single trailing 2-MMA collapses the
  accumulator. MMA count: n/m^2 + 2 vs the paper's ~2.008 * n/m^2; see
  EXPERIMENTS.md section Perf.

``segmented_accumulate_kernel`` -- the fused C-accumulator loop generalized
  to MANY independent reductions in ONE launch (Dakkak et al.'s segmented
  TCU reduction transplanted onto the fused variant): the input is a single
  concatenated, tile-padded stream of every segment's data, plus two
  scalar-prefetched maps (tile -> segment id, tile -> is-last-tile-of-its-
  segment). The accumulator rides across tiles exactly as in the fused
  kernel; at each segment boundary one trailing MMA collapses it into the
  per-segment output slot and the accumulator resets. MMA count:
  n/m^2 + S for S segments -- versus S separate launches each paying their
  own staging, grid setup and trailing collapse.

Block geometry: each grid step stages `tiles_per_block` (m, m) tiles
(m = 128 = MXU dim) from HBM into VMEM -- at the default 8 tiles that is a
8*128*128*4B = 512 KiB f32 working set, well inside the ~16 MiB VMEM budget
and large enough to hide DMA latency behind the systolic pipeline.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import common

MXU = common.MXU


def _two_mma(tiles_f32: jax.Array, compute_dtype) -> jax.Array:
    """(R, m, m) -> (R,) via the paper's two all-ones MMAs, f32 accumulate."""
    m = tiles_f32.shape[-1]
    ones = jnp.ones((m, m), compute_dtype)
    d = jax.lax.dot_general(
        tiles_f32.astype(compute_dtype),
        jnp.broadcast_to(ones, tiles_f32.shape),
        (((2,), (1,)), ((0,), (0,))),
        preferred_element_type=jnp.float32,
    )
    d2 = jax.lax.dot_general(
        jnp.broadcast_to(ones, d.shape),
        d.astype(compute_dtype),
        (((2,), (1,)), ((0,), (0,))),
        preferred_element_type=jnp.float32,
    )
    return d2[:, 0, 0]


def tile_partials_kernel(x_ref, o_ref, *, compute_dtype):
    """One grid step: (R, m, m) tiles -> (R,) partials. Paper-faithful."""
    o_ref[...] = _two_mma(x_ref[...], compute_dtype)


def fused_accumulate_kernel(x_ref, o_ref, acc_ref, *, compute_dtype):
    """Grid-accumulating reduction using the MMA C-operand as running state.

    acc (m, m) f32 lives in VMEM scratch across grid steps (TPU grid steps on
    one core are sequential, so the carry is race-free). Each step performs
    one batched MMA per tile block: acc += sum_t X_t @ 1. On the last step a
    single 2-MMA collapse emits the scalar.
    """
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    tiles = x_ref[...]  # (R, m, m)
    m = tiles.shape[-1]
    ones = jnp.ones((m, m), compute_dtype)
    # D = A x 1 + C : the accumulate operand carries the running row-sums.
    d = jax.lax.dot_general(
        tiles.astype(compute_dtype),
        jnp.broadcast_to(ones, tiles.shape),
        (((2,), (1,)), ((0,), (0,))),
        preferred_element_type=jnp.float32,
    )
    acc_ref[...] += jnp.sum(d, axis=0)  # batched-MMA partial fold (f32, VPU-add
    # of R tiles; R is small and this models the MXU's native C-accumulation)

    @pl.when(i == pl.num_programs(0) - 1)
    def _finalize():
        # one trailing MMA collapses the accumulated row-sums: 1 x acc.
        onesf = jnp.ones((m, m), jnp.float32)
        total = jnp.dot(onesf, acc_ref[...], preferred_element_type=jnp.float32)
        o_ref[...] = total[:1, :1]


def reduce_tiles(
    tiles: jax.Array,
    *,
    tiles_per_block: int = 8,
    compute_dtype=jnp.bfloat16,
    interpret: bool | None = None,
) -> jax.Array:
    """Paper-faithful level: (T, m, m) tiles -> (T,) partials via pallas."""
    interpret = common.resolve_interpret(interpret)
    t, m, _ = tiles.shape
    r = min(tiles_per_block, t)
    tpad = common.round_up(t, r)
    tiles = common.pad_to(tiles, tpad, axis=0)
    kernel = functools.partial(tile_partials_kernel, compute_dtype=compute_dtype)
    out = pl.pallas_call(
        kernel,
        grid=(tpad // r,),
        in_specs=[pl.BlockSpec((r, m, m), lambda i: (i, 0, 0))],
        out_specs=pl.BlockSpec((r,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((tpad,), jnp.float32),
        interpret=interpret,
    )(tiles)
    return out[:t]


def reduce_fused(
    tiles: jax.Array,
    *,
    tiles_per_block: int = 8,
    compute_dtype=jnp.bfloat16,
    interpret: bool | None = None,
) -> jax.Array:
    """Beyond-paper single-launch reduction: (T, m, m) -> scalar."""
    interpret = common.resolve_interpret(interpret)
    t, m, _ = tiles.shape
    r = min(tiles_per_block, t)
    tpad = common.round_up(t, r)
    tiles = common.pad_to(tiles, tpad, axis=0)
    kernel = functools.partial(fused_accumulate_kernel, compute_dtype=compute_dtype)
    out = pl.pallas_call(
        kernel,
        grid=(tpad // r,),
        in_specs=[pl.BlockSpec((r, m, m), lambda i: (i, 0, 0))],
        out_specs=pl.BlockSpec((1, 1), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((1, 1), jnp.float32),
        scratch_shapes=[common.vmem_scratch((m, m), jnp.float32)],
        interpret=interpret,
    )(tiles)
    return out[0, 0]


def segmented_accumulate_kernel(
    seg_ref, flush_ref, x_ref, o_ref, acc_ref, *, compute_dtype
):
    """Segmented single-launch multi-reduce (see module docstring).

    ``seg_ref`` / ``flush_ref`` are scalar-prefetched (SMEM) int32 maps over
    the whole tile stream: segment id per tile, and a boundary flag on the
    last tile of each segment. The grid streams ``tiles_per_block`` tiles per
    step; the accumulator matrix carries across tiles AND across grid steps
    (sequential on one TPU core, so the carry is race-free), and is collapsed
    into ``o_ref[seg]`` by one trailing MMA whenever a boundary tile is
    consumed. Trailing pad tiles are all-zero with no flush bit: they only
    add zeros to an accumulator nobody reads again.
    """
    i = pl.program_id(0)
    r, m, _ = x_ref.shape

    @pl.when(i == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        o_ref[...] = jnp.zeros_like(o_ref)

    tiles = x_ref[...]  # (r, m, m)
    ones = jnp.ones((m, m), compute_dtype)
    # D = A x 1 + C: one batched MMA for the whole block (cf. fused kernel).
    d = jax.lax.dot_general(
        tiles.astype(compute_dtype),
        jnp.broadcast_to(ones, tiles.shape),
        (((2,), (1,)), ((0,), (0,))),
        preferred_element_type=jnp.float32,
    )
    for t in range(r):  # static unroll: r is the (small) block depth
        acc_ref[...] += d[t]

        @pl.when(flush_ref[i * r + t] != 0)
        def _flush():
            # one trailing MMA collapses the accumulated row-sums: 1 x acc.
            onesf = jnp.ones((m, m), jnp.float32)
            total = jnp.dot(
                onesf, acc_ref[...], preferred_element_type=jnp.float32
            )
            o_ref[pl.ds(seg_ref[i * r + t], 1)] = total[:1, 0]
            acc_ref[...] = jnp.zeros_like(acc_ref)


def reduce_segments(
    tiles: jax.Array,
    seg_of: jax.Array,
    flush: jax.Array,
    num_segments: int,
    *,
    tiles_per_block: int = 8,
    compute_dtype=jnp.bfloat16,
    interpret: bool | None = None,
) -> jax.Array:
    """Single-launch segmented reduction: (T, m, m) tiles -> (S,) sums.

    ``seg_of`` / ``flush`` are (T,) int32 tile->segment maps (trace-time
    constants in practice -- segment offsets are static); ``T`` must be a
    multiple of ``tiles_per_block`` (ops.py pads the stream).
    """
    interpret = common.resolve_interpret(interpret)
    t, m, _ = tiles.shape
    r = min(tiles_per_block, t)
    if t % r:
        raise ValueError(f"tile stream ({t}) not a multiple of block ({r})")
    kernel = functools.partial(
        segmented_accumulate_kernel, compute_dtype=compute_dtype
    )
    return pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=(t // r,),
            in_specs=[pl.BlockSpec((r, m, m), lambda i, *_: (i, 0, 0))],
            out_specs=pl.BlockSpec((num_segments,), lambda i, *_: (0,)),
            scratch_shapes=[common.vmem_scratch((m, m), jnp.float32)],
        ),
        out_shape=jax.ShapeDtypeStruct((num_segments,), jnp.float32),
        interpret=interpret,
    )(
        jnp.asarray(seg_of, jnp.int32),
        jnp.asarray(flush, jnp.int32),
        tiles,
    )
