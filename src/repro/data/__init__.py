from repro.data.pipeline import (  # noqa: F401
    MemmapTokens,
    Prefetcher,
    ShardInfo,
    SyntheticLM,
    packing_offsets,
)
