"""Deterministic, sharded, resumable data pipeline.

Two sources behind one iterator contract:
  * SyntheticLM  -- seeded on (seed, step, shard), so any host can
    reconstruct any batch without coordination: restart/elastic-rescale
    safe by construction.
  * MemmapTokens -- a packed uint32 token file (np.memmap); deterministic
    shuffled window order from a seeded permutation; per-host sharding by
    contiguous window strides.

Both yield {"tokens": (local_batch, seq+1) int32} -- the +1 supplies the
shifted labels. State is a plain dict {step} (checkpointable); `seek(step)`
is O(1), which is what makes failure recovery cheap at 1000-node scale: the
restarted host does not replay the stream, it jumps.
"""

from __future__ import annotations

import dataclasses
from typing import Iterator

import numpy as np


@dataclasses.dataclass
class ShardInfo:
    shard: int = 0      # this host's data shard index
    n_shards: int = 1   # total data-parallel hosts


class SyntheticLM:
    """Seeded synthetic token stream (zipfian unigram + markov-ish drift) --
    the substrate for examples/tests; exercises exactly the same interface
    and sharding discipline as the memmap source."""

    def __init__(self, vocab: int, seq: int, local_batch: int,
                 shard: ShardInfo | None = None, seed: int = 0,
                 n_codebooks: int = 0):
        self.vocab, self.seq, self.local_batch = vocab, seq, local_batch
        self.shard = shard or ShardInfo()
        self.seed = seed
        self.n_codebooks = n_codebooks
        self.step = 0

    def seek(self, step: int) -> None:
        self.step = step

    def state(self) -> dict:
        return {"step": self.step, "seed": self.seed}

    def load_state(self, st: dict) -> None:
        self.step = int(st["step"])
        assert int(st["seed"]) == self.seed, "data seed changed across restore"

    def _rng(self, step: int) -> np.random.Generator:
        return np.random.default_rng(
            np.random.SeedSequence([self.seed, step, self.shard.shard])
        )

    def next(self) -> dict:
        rng = self._rng(self.step)
        shape = (self.local_batch, self.seq + 1)
        if self.n_codebooks:
            shape = shape + (self.n_codebooks,)
        # zipf-ish distribution keeps CE losses realistic
        z = rng.zipf(1.3, size=shape)
        tokens = np.minimum(z, self.vocab - 1).astype(np.int32)
        self.step += 1
        return {"tokens": tokens}

    def __iter__(self) -> Iterator[dict]:
        while True:
            yield self.next()


class MemmapTokens:
    """Packed-token binary reader: windows of (seq+1) tokens in a seeded
    permuted order, strided across shards. Epoch boundary reshuffles with
    epoch-dependent seed."""

    def __init__(self, path: str, seq: int, local_batch: int,
                 shard: ShardInfo | None = None, seed: int = 0):
        self.tokens = np.memmap(path, dtype=np.uint32, mode="r")
        self.seq, self.local_batch = seq, local_batch
        self.shard = shard or ShardInfo()
        self.seed = seed
        self.step = 0
        self.n_windows = len(self.tokens) // (seq + 1)
        if self.n_windows < local_batch * (shard.n_shards if shard else 1):
            raise ValueError("dataset smaller than one global batch")

    def seek(self, step: int) -> None:
        self.step = step

    def state(self) -> dict:
        return {"step": self.step, "seed": self.seed}

    def load_state(self, st: dict) -> None:
        self.step = int(st["step"])

    def _perm(self, epoch: int) -> np.ndarray:
        rng = np.random.default_rng(np.random.SeedSequence([self.seed, epoch]))
        return rng.permutation(self.n_windows)

    def next(self) -> dict:
        gb = self.local_batch * self.shard.n_shards
        steps_per_epoch = self.n_windows // gb
        epoch, within = divmod(self.step, steps_per_epoch)
        perm = self._perm(epoch)
        base = within * gb + self.shard.shard * self.local_batch
        idx = perm[base : base + self.local_batch]
        w = self.seq + 1
        out = np.stack([self.tokens[i * w : (i + 1) * w] for i in idx])
        self.step += 1
        return {"tokens": out.astype(np.int32)}

    def __iter__(self) -> Iterator[dict]:
        while True:
            yield self.next()


class Prefetcher:
    """Double-buffered background prefetch (thread), hiding host time behind
    device steps."""

    def __init__(self, source, depth: int = 2):
        import queue
        import threading

        self.source = source
        self.q: "queue.Queue" = queue.Queue(maxsize=depth)
        self._stop = threading.Event()

        def worker():
            import queue as _q

            while not self._stop.is_set():
                batch = source.next()
                while not self._stop.is_set():  # never drop a drawn batch
                    try:
                        self.q.put(batch, timeout=0.5)
                        break
                    except _q.Full:
                        continue

        self.t = threading.Thread(target=worker, daemon=True)
        self.t.start()

    def next(self) -> dict:
        return self.q.get()

    def close(self) -> None:
        self._stop.set()


def packing_offsets(lengths, backend=None):
    """(N,) sequence lengths -> (N+1,) int32 packing offsets [0, l0, l0+l1, ...].

    The cumulative-offset table for packing ragged sequences into one flat
    buffer, routed through the engine scan (``repro.scan``) so offset
    computation shares the reduction backends' plan/quarantine machinery.
    ``backend=None`` takes the planner's auto route, which keeps integer
    inputs on the exact integer path; the MMA backends compute the prefix
    in f32, integer-exact for totals below 2**24.
    """
    import jax.numpy as jnp

    from repro import reduce as R

    lengths = jnp.asarray(lengths, jnp.int32)
    if lengths.ndim != 1:
        raise ValueError("packing_offsets expects a 1D length vector")
    incl = R.scan(lengths, backend=backend).astype(jnp.int32)
    return jnp.concatenate([jnp.zeros((1,), jnp.int32), incl])
