"""Precision tooling for low-precision MMA reductions.

The paper (section V) leaves "the level of precision loss by performing
reductions in FP16" as future work and cites Markidis et al.'s remedies
(Kahan summation, iterative refinement). This module supplies:

  * kahan_sum        -- compensated serial summation (error O(1) in n),
  * pairwise guarantees come from `classic_tree_sum` (error O(log n)),
  * blocked_kahan_mma -- the MMA hierarchy with a per-level Kahan carry,
  * relative_error / ulps -- the metrics used by bench_precision.py.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import mma_reduce


def kahan_sum(x: jax.Array, dtype=jnp.float32) -> jax.Array:
    """Kahan-compensated serial sum at `dtype` (scan; exact error model)."""
    xf = x.reshape(-1).astype(dtype)

    def step(carry, xi):
        s, c = carry
        y = xi - c
        t = s + y
        c = (t - s) - y
        return (t, c), None

    (s, _), _ = jax.lax.scan(step, (jnp.zeros((), dtype), jnp.zeros((), dtype)), xf)
    return s


def blocked_kahan_mma(
    x: jax.Array, *, m: int = mma_reduce.DEFAULT_M, block: int = 4096
) -> jax.Array:
    """MMA-reduce per block (f32 accum), then Kahan-combine block partials.

    This is the Markidis-style refinement adapted to the hierarchy: the MXU
    does the bandwidth-heavy inner reductions, the (tiny) cross-block
    combination is compensated. Cost: one extra scan of length n/block.
    """
    flat = x.reshape(-1)
    nblk = -(-flat.size // block)
    pad = nblk * block - flat.size
    if pad:
        flat = jnp.pad(flat, (0, pad))
    partials = jax.vmap(lambda b: mma_reduce.mma_sum(b, m=m))(
        flat.reshape(nblk, block)
    )
    return kahan_sum(partials)


def relative_error(approx: jax.Array, exact: jax.Array) -> jax.Array:
    exact = jnp.asarray(exact, jnp.float64)
    return jnp.abs(jnp.asarray(approx, jnp.float64) - exact) / jnp.maximum(
        jnp.abs(exact), 1e-300
    )
